//! Quickstart: the whole AxOCS loop on the smallest operator.
//!
//! Characterizes every approximate 4-bit adder (the operator model of
//! paper Fig. 3) through the engine's cached dataset path, prints the
//! Pareto designs, and runs a small NSGA-II search against the exact
//! characterization table.
//!
//! Run: `cargo run --release --example quickstart`

use repro::prelude::*;
use repro::dse::{GaOptions, ParetoFront};

fn main() -> repro::error::Result<()> {
    // 1. Characterize the full design space (15 usable configurations).
    //    The EngineContext caches datasets process-wide: a second
    //    `dataset(op)` call (or a concurrent one) reuses this result.
    let op = Operator::ADD4;
    let engine = EngineContext::new(repro::expcfg::ExperimentConfig::default());
    let ds = engine.dataset(op)?;
    println!("characterized {} designs of {op} (engine-cached)\n", ds.len());

    println!("{:<6} {:>14} {:>16} {:>8} {:>10}", "config", "avg_abs_err", "avg_abs_rel_err", "luts", "pdplut");
    for i in 0..ds.len() {
        println!(
            "{:<6} {:>14.4} {:>16.5} {:>8} {:>10.4}",
            ds.configs[i],
            ds.behav[i].avg_abs_err,
            ds.behav[i].avg_abs_rel_err,
            ds.ppa[i].luts,
            ds.ppa[i].pdplut,
        );
    }

    // 2. The (BEHAV, PPA) Pareto front of the space.
    let objs: Vec<[f64; 2]> = ds.headline_points().iter().map(|p| [p[1], p[0]]).collect();
    let front = ParetoFront::from_points(&objs);
    println!("\nPareto-optimal designs ({}):", front.len());
    for &i in &front.indices {
        println!(
            "  {}  err {:.5}  pdplut {:.4}",
            ds.configs[i], ds.behav[i].avg_abs_rel_err, ds.ppa[i].pdplut
        );
    }

    // 3. Constrained NSGA-II over the exact table (Eq. 3 with factor 0.75).
    let constraints = Constraints::from_scaling_factor(0.75, &objs)?;
    let table = repro::surrogate::TableSurrogate::from_dataset(&ds);
    let fitness = |c: &[AxoConfig]| table.predict(c);
    let runner = NsgaRunner::new(
        GaOptions { pop_size: 8, generations: 12, seed: 1, ..Default::default() },
        constraints,
    );
    let result = runner.run(op.config_len(), &fitness, &[])?;
    println!(
        "\nNSGA-II (factor 0.75): {} front designs, hypervolume {:.4} \
         ({} fitness evaluations)",
        result.front_points.len(),
        result.final_hypervolume(),
        result.evaluations
    );
    println!("\nnext: examples/conss_pipeline.rs scales 4-bit knowledge to 8 bits");
    Ok(())
}
