//! Application case study: approximate multipliers inside an image kernel.
//!
//! The paper motivates AxOs with embedded ML/DSP workloads whose outputs
//! tolerate arithmetic error. This example deploys Pareto-optimal 8×8
//! approximate multipliers found by the DSE inside a Sobel edge-detection
//! convolution over a synthetic image and reports application-level
//! quality (PSNR vs. the exact pipeline) against the PPA savings — the
//! classic cross-layer trade-off plot, one row per selected design.
//!
//! Run: `cargo run --release --example accelerator_case_study`

use repro::dse::{Objectives, ParetoFront};
use repro::expcfg::ExperimentConfig;
use repro::operator::{multiplier, AxoConfig, Operator};
use repro::prelude::*;
use repro::util::rng::Rng;

const W: usize = 96;
const H: usize = 96;

/// Deterministic synthetic test image: soft gradients + box features.
fn synth_image() -> Vec<i64> {
    let mut img = vec![0i64; W * H];
    let mut rng = Rng::seed_from_u64(7);
    for y in 0..H {
        for x in 0..W {
            let base = ((x * 96 / W) as i64 + (y * 64 / H) as i64) / 2;
            let feature = if (20..44).contains(&x) && (30..60).contains(&y) { 40 } else { 0 };
            let noise = (rng.gen_index(9) as i64) - 4;
            img[y * W + x] = (base + feature + noise).clamp(0, 127);
        }
    }
    img
}

/// Sobel gradient magnitude with a pluggable multiplier.
fn sobel(img: &[i64], mul: &dyn Fn(i64, i64) -> i64) -> Vec<i64> {
    const KX: [[i64; 3]; 3] = [[-1, 0, 1], [-2, 0, 2], [-1, 0, 1]];
    const KY: [[i64; 3]; 3] = [[-1, -2, -1], [0, 0, 0], [1, 2, 1]];
    let mut out = vec![0i64; W * H];
    for y in 1..H - 1 {
        for x in 1..W - 1 {
            let mut gx = 0i64;
            let mut gy = 0i64;
            for ky in 0..3 {
                for kx in 0..3 {
                    let p = img[(y + ky - 1) * W + (x + kx - 1)];
                    gx += mul(KX[ky][kx], p);
                    gy += mul(KY[ky][kx], p);
                }
            }
            out[y * W + x] = (gx.abs() + gy.abs()).min(255);
        }
    }
    out
}

fn psnr(exact: &[i64], approx: &[i64]) -> f64 {
    let mse: f64 = exact
        .iter()
        .zip(approx)
        .map(|(&a, &b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        / exact.len() as f64;
    if mse == 0.0 {
        f64::INFINITY
    } else {
        10.0 * ((255.0f64 * 255.0) / mse).log10()
    }
}

fn main() -> repro::error::Result<()> {
    // --- Find Pareto-optimal 8×8 multipliers (scaled-down DSE). ---
    // The engine caches the seeded characterization sample; the structured
    // library goes through its validation path.
    let op = Operator::MUL8;
    let engine = EngineContext::new(ExperimentConfig {
        train_samples: 1500,
        ..Default::default() // operator mul8, seed 2023
    });
    let ds = engine.dataset(op)?;
    // Augment the random sample with the structured EvoApprox-style
    // library — truncation families supply the low-error region that pure
    // random 36-bit sampling misses.
    let lib = repro::baselines::evoapprox_library(op);
    let lib_ds = engine.validate(op, &lib)?;
    let mut all = (*ds).clone();
    all.merge(&lib_ds)?;
    let objs: Vec<Objectives> = all.headline_points().iter().map(|p| [p[1], p[0]]).collect();
    let front = ParetoFront::from_points(&objs);
    println!(
        "characterized {} designs ({} structured); global front size {}",
        all.len(),
        lib.len(),
        front.len()
    );

    // One pick per error band: the cheapest design meeting each quality
    // floor (this is exactly how a designer consumes the library).
    let bands = [0.0005, 0.002, 0.01, 0.05, 0.2, 1.0];
    let mut picks: Vec<AxoConfig> = Vec::new();
    for band in bands {
        let best = (0..objs.len())
            .filter(|&i| objs[i][0] <= band && !all.configs[i].is_accurate())
            .min_by(|&a, &b| objs[a][1].partial_cmp(&objs[b][1]).unwrap());
        if let Some(i) = best {
            if !picks.contains(&all.configs[i]) {
                picks.push(all.configs[i]);
            }
        }
    }
    let ds = all;

    // --- Deploy each in the Sobel pipeline. ---
    let img = synth_image();
    let exact_mul = |a: i64, b: i64| a * b;
    let exact_out = sobel(&img, &exact_mul);
    let acc_ppa = repro::synth::mult_ppa(8, &AxoConfig::accurate(36));

    println!(
        "\n{:<38} {:>9} {:>11} {:>9} {:>9}",
        "config (36-bit)", "PSNR dB", "rel_err", "PDPLUT", "saving"
    );
    println!(
        "{:<38} {:>9} {:>11} {:>9.3} {:>9}",
        "accurate (all ones)", "inf", "0", acc_ppa.pdplut, "0.0%"
    );
    for cfg in &picks {
        let approx_mul =
            |a: i64, b: i64| multiplier::eval_one(8, cfg, a.clamp(-128, 127), b.clamp(-128, 127));
        let out = sobel(&img, &approx_mul);
        let q = psnr(&exact_out, &out);
        let i = ds.configs.iter().position(|c| c == cfg).unwrap();
        let ppa = &ds.ppa[i];
        println!(
            "{:<38} {:>9.2} {:>11.5} {:>9.3} {:>8.1}%",
            cfg,
            q,
            ds.behav[i].avg_abs_rel_err,
            ppa.pdplut,
            100.0 * (1.0 - ppa.pdplut / acc_ppa.pdplut)
        );
    }
    println!(
        "\ninterpretation: lower-PDPLUT designs trade PSNR for power/area —\n\
         pick the row meeting the application's quality floor (paper §I)."
    );
    Ok(())
}
