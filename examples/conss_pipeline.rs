//! ConSS pipeline walkthrough: scale 4-bit adder knowledge to 8 bits.
//!
//! Reproduces the paper's §IV flow on the adder pair: characterize
//! L = add4 and H = add8 (both exhaustive — small enough) through the
//! engine's cached dataset path, analyze the three distance measures
//! (Fig. 11), match with the Euclidean measure (Fig. 12), train the
//! random-forest supersampler with noise bits (Fig. 8/13), and compare the
//! supersampled pool's hypervolume against the training data.
//!
//! Run: `cargo run --release --example conss_pipeline`

use repro::conss::{ConssPipeline, SupersampleOptions};
use repro::dse::{hypervolume2d, Constraints, Objectives};
use repro::expcfg::ExperimentConfig;
use repro::matching::Matcher;
use repro::prelude::*;
use repro::stats::Histogram;

fn objectives(ds: &Dataset) -> Vec<Objectives> {
    ds.headline_points().iter().map(|p| [p[1], p[0]]).collect()
}

fn main() -> repro::error::Result<()> {
    // --- Characterize L and H (Fig. 4 "Statistical Analysis"). ---
    // The engine caches both datasets; re-running any step below (or the
    // figure harness in the same process) reuses them for free.
    let engine = EngineContext::new(ExperimentConfig::default());
    let l = engine.dataset(Operator::ADD4)?;
    let h = engine.dataset(Operator::ADD8)?;
    println!("L_CHAR: {} designs of add4; H_CHAR: {} designs of add8", l.len(), h.len());

    // --- Distance measure analysis (Fig. 11). ---
    println!("\ndistance distributions over all L×H pairs (scaled plane):");
    for kind in DistanceKind::ALL {
        let d = Matcher::new(kind).all_distances(&l, &h)?;
        let hist = Histogram::from_values_range(&d, 30, 0.0, 1.5);
        println!(
            "  {:<10} mean {:.3}  bin occupancy {:.2}",
            kind.name(),
            d.iter().sum::<f64>() / d.len() as f64,
            hist.occupancy()
        );
    }

    // --- Euclidean matching (Fig. 12). ---
    let matcher = Matcher::new(DistanceKind::Euclidean);
    let m = matcher.match_datasets(&l, &h)?;
    let counts = m.counts_per_l(l.len());
    println!("\none-to-many matching (H designs per L seed):");
    for (i, &c) in counts.iter().enumerate() {
        if c > 0 {
            println!("  {} ← {c} H designs", l.configs[i]);
        }
    }

    // --- Train the supersampler and generate the pool. ---
    let opts = SupersampleOptions::default(); // euclidean, 4 noise bits
    let pipe = ConssPipeline::train(&l, &h, opts)?;
    let pool = pipe.supersample(None, &[])?;
    println!(
        "\nConSS: {} L seeds × 2^{} noise values → {} unique 8-bit candidates",
        pool.n_seeds, pipe.options.noise_bits, pool.configs.len()
    );

    // --- Validate the pool and compare hypervolume vs TRAIN. ---
    let pool_ds = engine.validate(Operator::ADD8, &pool.configs)?;
    let h_obj = objectives(&h);
    let pool_obj = objectives(&pool_ds);
    for factor in [0.3, 0.5, 1.0] {
        let c = Constraints::from_scaling_factor(factor, &h_obj)?;
        let hv_train = hypervolume2d(&h_obj, c.reference());
        let hv_pool = hypervolume2d(&pool_obj, c.reference());
        println!(
            "factor {factor:.1}: train hv {hv_train:.4}  conss-pool hv {hv_pool:.4}  \
             (ratio {:.2})",
            hv_pool / hv_train.max(1e-12)
        );
    }
    println!("\nnext: examples/end_to_end_dse.rs runs the full 4×4→8×8 multiplier flow");
    Ok(())
}
