//! END-TO-END DRIVER: the complete AxOCS system on the paper's headline
//! workload — DSE of 8×8 signed approximate multipliers.
//!
//! Exercises the engine layer on one real run:
//!
//!   1. `EngineContext::prepare_dse` characterizes the 4×4 space
//!      exhaustively and a seeded sample of the 8×8 space (each exactly
//!      once, via the thread-safe dataset cache), trains the surrogate
//!      estimator — the AOT-compiled Pallas MLP via PJRT when `artifacts/`
//!      is built, else the native GBT — behind the shared batching
//!      service, and trains the ConSS random forest;
//!   2. `run_many` executes one [`DseJob`] per constraint scaling factor
//!      (Fig. 15) **concurrently** on scoped threads, every search
//!      funneling GA fitness through the one service so batches coalesce
//!      across factors;
//!   3. fronts are validated (PPF → VPF) with the real substrate and the
//!      headline comparison + service batching metrics are printed.
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.
//!
//! Run: `cargo run --release --example end_to_end_dse [-- --full]`

use repro::charac::Backend;
use repro::dse::hypervolume2d;
use repro::engine::vpf_candidates;
use repro::expcfg::{ExperimentConfig, GaConfig, SurrogateConfig};
use repro::prelude::*;
use std::path::Path;
use std::time::Instant;

fn main() -> repro::error::Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let (n_samples, pop, gens) = if full { (10_650, 100, 250) } else { (2_000, 48, 40) };
    let factors = [0.2, 0.5, 0.75, 1.0];
    let t0 = Instant::now();
    println!(
        "AxOCS end-to-end: mul4 → mul8 supersampled DSE \
         ({n_samples} samples, pop {pop}, {gens} gens{})",
        if full { ", FULL paper scale" } else { ", quick scale (--full for paper scale)" }
    );

    // ---- Engine context: operator pair, surrogate, GA scale. ----
    let artifacts = Path::new("artifacts");
    let backend = if Backend::pjrt_ready(artifacts) {
        println!("surrogate: AOT Pallas MLP on PJRT");
        EstimatorBackend::PjrtMlp
    } else {
        println!(
            "surrogate: native GBT (build with --features pjrt + `make artifacts` \
             for the PJRT path)"
        );
        EstimatorBackend::Gbt
    };
    let cfg = ExperimentConfig {
        train_samples: n_samples,
        surrogate: SurrogateConfig { backend, gbt_stages: None },
        ga: GaConfig { pop_size: pop, generations: gens, ..Default::default() },
        scaling_factors: factors.to_vec(),
        ..Default::default() // operator mul8, seed 2023
    };
    let engine = EngineContext::new(cfg);

    // ---- 1. Prepare: characterize L/H once, train ConSS + estimator. ----
    let prep = engine.prepare_dse()?;
    println!(
        "[{:7.2?}] characterized {} of 68.7e9 mul8 designs (and all {} mul4) — cached",
        t0.elapsed(),
        prep.h_ds.len(),
        prep.l_ds.len()
    );
    println!("[{:7.2?}] ConSS forest trained (euclidean matching)", t0.elapsed());

    // ---- 2. All four scaling factors concurrently through one service. ----
    let jobs: Vec<DseJob> = factors.iter().map(|&f| DseJob::new(f)).collect();
    let t_dse = Instant::now();
    let runs = prep.run_many(&jobs)?;
    println!(
        "[{:7.2?}] {} factor jobs ran concurrently in {:.2?}",
        t0.elapsed(),
        runs.len(),
        t_dse.elapsed()
    );

    // ---- 3. Per-factor: headline comparison + VPF validation. ----
    println!(
        "\n{:>7} {:>11} {:>11} {:>11} {:>11} | {:>11} {:>11} {:>6}",
        "factor", "TRAIN", "GA", "ConSS", "ConSS+GA", "VPF(GA)", "VPF(AxOCS)", "extra"
    );
    for run in &runs {
        let reference = run.constraints.reference();
        let (ga_front, _) =
            engine.validate_front(&prep, &vpf_candidates(&run.ga), &run.constraints)?;
        let (axocs_front, extra) = engine.validate_front(
            &prep,
            &vpf_candidates(&run.conss_ga),
            &run.constraints,
        )?;
        println!(
            "{:>7.2} {:>11.4} {:>11.4} {:>11.4} {:>11.4} | {:>11.4} {:>11.4} {extra:>6}",
            run.factor,
            run.hv_train,
            run.ga.final_hypervolume(),
            run.hv_conss,
            run.conss_ga.final_hypervolume(),
            hypervolume2d(&ga_front.points, reference),
            hypervolume2d(&axocs_front.points, reference),
        );
    }

    let snap = prep.service.metrics().snapshot();
    println!(
        "\nestimator service: {} requests / {} configs in {} batches \
         (mean fill {:.1}, max {}), backend busy {:.1} ms",
        snap.requests,
        snap.configs,
        snap.batches,
        snap.mean_batch_fill(),
        snap.max_batch_fill,
        snap.busy_micros as f64 / 1000.0
    );
    let cache = engine.cache_stats();
    println!(
        "dataset cache: {} entries, {} hits, {} misses — L/H characterized once each",
        cache.entries, cache.hits, cache.misses
    );
    println!("total wall clock: {:.2?}", t0.elapsed());
    println!("\npaper-shape checks: ConSS+GA ≥ GA per row; gap widest at factor 0.2;");
    println!("ConSS > TRAIN for tight constraints (§V-D).");
    Ok(())
}
