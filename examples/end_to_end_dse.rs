//! END-TO-END DRIVER: the complete AxOCS system on the paper's headline
//! workload — DSE of 8×8 signed approximate multipliers.
//!
//! Exercises every layer of the three-layer stack on one real run:
//!
//!   1. characterize the 4×4 space exhaustively and a seeded sample of the
//!      8×8 space (native substrate; Table II);
//!   2. train the surrogate estimator — the AOT-compiled Pallas MLP via
//!      PJRT when `artifacts/` is built, else the native GBT — and wrap it
//!      in the batching coordinator service;
//!   3. distance-match, train the ConSS random forest, supersample;
//!   4. run GA (AppAxO baseline) and ConSS+GA (AxOCS) through the service
//!      for every constraint scaling factor (Fig. 15);
//!   5. validate fronts (PPF → VPF) with the real substrate and print the
//!      headline comparison + service batching metrics.
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.
//!
//! Run: `cargo run --release --example end_to_end_dse [-- --full]`

use repro::charac::InputSet;
use repro::conss::{ConssPipeline, SupersampleOptions};
use repro::coordinator::{BatchOptions, EstimatorService};
use repro::dse::{hypervolume2d, Constraints, GaOptions, NsgaRunner, Objectives, ParetoFront};
use repro::prelude::*;
use repro::util::rng::Rng;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

fn objectives(ds: &Dataset) -> Vec<Objectives> {
    ds.headline_points().iter().map(|p| [p[1], p[0]]).collect()
}

/// The AOT Pallas MLP on PJRT — only reachable when `Backend::pjrt_ready`
/// says the feature is compiled in and artifacts exist.
#[cfg(feature = "pjrt")]
fn pjrt_surrogate(artifacts: &Path) -> repro::error::Result<Arc<dyn Surrogate>> {
    use repro::runtime::{MlpExec, Runtime};
    use repro::surrogate::PjrtSurrogate;
    let rt = Runtime::cpu(artifacts)?;
    println!("surrogate: AOT Pallas MLP on PJRT ({})", rt.platform());
    let exec = MlpExec::new(&rt, "estimator_mul8")?;
    Ok(Arc::new(PjrtSurrogate::new(exec)?))
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_surrogate(_artifacts: &Path) -> repro::error::Result<Arc<dyn Surrogate>> {
    Err(repro::error::Error::Config(
        "pjrt surrogate requires a build with --features pjrt".into(),
    ))
}

fn main() -> repro::error::Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let (n_samples, pop, gens) = if full { (10_650, 100, 250) } else { (2_000, 48, 40) };
    let seed = 2023u64;
    let t0 = Instant::now();
    println!(
        "AxOCS end-to-end: mul4 → mul8 supersampled DSE \
         ({n_samples} samples, pop {pop}, {gens} gens{})",
        if full { ", FULL paper scale" } else { ", quick scale — pass --full for paper scale" }
    );

    // ---- 1. Characterization (the paper's Vivado+RTL-sim step). ----
    let l_in = InputSet::exhaustive(Operator::MUL4);
    let h_in = InputSet::exhaustive(Operator::MUL8);
    let l_ds = characterize(
        Operator::MUL4,
        &AxoConfig::enumerate(10).collect::<Vec<_>>(),
        &l_in,
        &Backend::Native,
    )?;
    let mut rng = Rng::seed_from_u64(seed);
    let h_cfgs = AxoConfig::sample_unique(36, n_samples, &mut rng);
    let t = Instant::now();
    let h_ds = characterize(Operator::MUL8, &h_cfgs, &h_in, &Backend::Native)?;
    println!(
        "[{:7.2?}] characterized {} of 68.7e9 mul8 designs over 65536 input pairs ({:.0} cfg/s)",
        t0.elapsed(),
        h_ds.len(),
        h_ds.len() as f64 / t.elapsed().as_secs_f64()
    );
    let h_obj = objectives(&h_ds);

    // ---- 2. Surrogate estimator behind the batching service. ----
    let artifacts = Path::new("artifacts");
    let backend: Arc<dyn Surrogate> = if Backend::pjrt_ready(artifacts) {
        pjrt_surrogate(artifacts)?
    } else {
        println!(
            "[{:7.2?}] surrogate: native GBT (build with --features pjrt + `make artifacts` for the PJRT path)",
            t0.elapsed()
        );
        Arc::new(repro::surrogate::GbtSurrogate::train(&h_ds, Default::default())?)
    };
    let service = EstimatorService::spawn(backend, BatchOptions::default());

    // ---- 3. ConSS: match → forest → supersample. ----
    let pipe = ConssPipeline::train(&l_ds, &h_ds, SupersampleOptions::default())?;
    println!("[{:7.2?}] ConSS forest trained (euclidean matching, 4 noise bits)", t0.elapsed());

    // ---- 4+5. Per-factor: GA vs ConSS+GA through the service, then VPF. ----
    println!(
        "\n{:>7} {:>11} {:>11} {:>11} {:>11} | {:>11} {:>11} {:>6}",
        "factor", "TRAIN", "GA", "ConSS", "ConSS+GA", "VPF(GA)", "VPF(AxOCS)", "extra"
    );
    for factor in [0.2, 0.5, 0.75, 1.0] {
        let constraints = Constraints::from_scaling_factor(factor, &h_obj)?;
        let reference = constraints.reference();
        let hv_train = hypervolume2d(&h_obj, reference);

        let pool = pipe.supersample(Some(&constraints), &h_obj)?;
        let pool_pred = service.predict(pool.configs.clone())?;
        let hv_conss = hypervolume2d(&pool_pred, reference);

        let opts = GaOptions { pop_size: pop, generations: gens, seed, ..Default::default() };
        let ga = NsgaRunner::new(opts.clone(), constraints).run(36, &service, &[])?;
        let axocs =
            NsgaRunner::new(opts, constraints).run(36, &service, &pool.configs)?;

        // VPF: re-characterize front configs with the real substrate.
        let vpf = |front: &[AxoConfig]| -> repro::error::Result<(f64, usize)> {
            let fresh: Vec<AxoConfig> = front
                .iter()
                .filter(|c| !h_ds.configs.contains(c))
                .copied()
                .collect();
            let ds = characterize(Operator::MUL8, &fresh, &h_in, &Backend::Native)?;
            let objs: Vec<Objectives> = objectives(&ds)
                .into_iter()
                .filter(|o| constraints.feasible(*o))
                .collect();
            let front = ParetoFront::from_points(&objs);
            Ok((hypervolume2d(&front.points, reference), fresh.len()))
        };
        let (vpf_ga, _) = vpf(&ga.front_configs)?;
        let (vpf_axocs, extra) = vpf(&axocs.front_configs)?;

        println!(
            "{factor:>7.2} {hv_train:>11.4} {:>11.4} {hv_conss:>11.4} {:>11.4} | {vpf_ga:>11.4} {vpf_axocs:>11.4} {extra:>6}",
            ga.final_hypervolume(),
            axocs.final_hypervolume(),
        );
    }

    let snap = service.metrics().snapshot();
    println!(
        "\nestimator service: {} requests / {} configs in {} batches \
         (mean fill {:.1}, max {}), backend busy {:.1} ms",
        snap.requests,
        snap.configs,
        snap.batches,
        snap.mean_batch_fill(),
        snap.max_batch_fill,
        snap.busy_micros as f64 / 1000.0
    );
    println!("total wall clock: {:.2?}", t0.elapsed());
    println!("\npaper-shape checks: ConSS+GA ≥ GA per row; gap widest at factor 0.2;");
    println!("ConSS > TRAIN for tight constraints (§V-D).");
    Ok(())
}
