//! Hermetic stand-in for the `xla` crate (xla_extension / PJRT bindings).
//!
//! The `repro` crate's `pjrt` feature compiles against exactly the API
//! surface below. This stub keeps `cargo build --features pjrt` and
//! `cargo clippy --all-targets --features pjrt` working **offline** — no
//! network, no `xla_extension` tarball, no PJRT plugin. Host-side literal
//! bookkeeping (construction, reshape shape checks) behaves normally so
//! unit tests of the literal helpers pass; every operation that would need
//! a real PJRT backend (`PjRtClient::cpu`, compilation, execution) returns
//! [`Error`] instead.
//!
//! To run the AOT-compiled artifacts for real, point the workspace's
//! `xla` dependency at the actual bindings (path dependencies cannot be
//! `[patch]`ed — edit the entry itself in the root `Cargo.toml`):
//!
//! ```text
//! [dependencies]
//! xla = { git = "https://github.com/LaurentMazare/xla-rs", optional = true }
//! ```
//!
//! The capability probe (`charac::Backend::pjrt_ready`) detects this stub
//! by attempting `PjRtClient::cpu()`, so integration tests and benches
//! skip — never fail — while the stub is linked or artifacts are absent.

use std::fmt;
use std::path::Path;

/// Error returned by every operation that needs a live PJRT backend.
#[derive(Debug, Clone)]
pub struct Error {
    message: String,
}

impl Error {
    fn stub(what: &str) -> Error {
        Error {
            message: format!(
                "{what}: built against the hermetic xla stub (no PJRT backend linked); \
                 override the `xla` package with real bindings to execute artifacts"
            ),
        }
    }

    fn shape(message: String) -> Error {
        Error { message }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.message)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] can be built from / read back as.
pub trait Element: Copy {}
impl Element for f32 {}
impl Element for f64 {}
impl Element for i32 {}
impl Element for i64 {}
impl Element for u8 {}
impl Element for u32 {}

/// Host-side tensor handle. The stub tracks only the element count so
/// shape arithmetic (reshape validation) behaves like the real bindings.
#[derive(Debug, Clone)]
pub struct Literal {
    len: usize,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: Element>(data: &[T]) -> Literal {
        Literal { len: data.len() }
    }

    /// Reshape; fails when the element count does not match, like XLA.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let product: i64 = dims.iter().product();
        if product < 0 || product as usize != self.len {
            return Err(Error::shape(format!(
                "cannot reshape {} elements to {dims:?}",
                self.len
            )));
        }
        Ok(Literal { len: self.len })
    }

    /// Element count of the literal.
    pub fn element_count(&self) -> usize {
        self.len
    }

    /// Read back host data — only execution results carry data, and the
    /// stub cannot execute, so this always fails.
    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>> {
        Err(Error::stub("Literal::to_vec"))
    }

    /// Unwrap a 1-tuple output literal (aot.py lowers with
    /// `return_tuple=True`).
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(Error::stub("Literal::to_tuple1"))
    }
}

/// Parsed HLO module. Never constructible through the stub.
#[derive(Debug)]
pub struct HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file(_path: &Path) -> Result<HloModuleProto> {
        Err(Error::stub("HloModuleProto::from_text_file"))
    }
}

/// Computation wrapper around a parsed HLO module.
#[derive(Debug)]
pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

/// Device buffer produced by an execution.
#[derive(Debug)]
pub struct PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle. Never constructible through the stub.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    /// Execute over per-device argument lists; result is
    /// `[device][output]` buffers in the real bindings.
    pub fn execute<A>(&self, _args: &[A]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient {}

impl PjRtClient {
    /// The real bindings dlopen the CPU PJRT plugin here; the stub has
    /// nothing to load.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::stub("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_bookkeeping_works() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(lit.element_count(), 4);
        assert!(lit.reshape(&[2, 2]).is_ok());
        assert!(lit.reshape(&[3, 2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
    }

    #[test]
    fn backend_operations_fail_cleanly() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("stub"));
        assert!(HloModuleProto::from_text_file(Path::new("x.hlo.txt")).is_err());
    }
}
