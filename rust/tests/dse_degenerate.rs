//! Degenerate-input coverage for `dse::hypervolume` and `dse::pareto`:
//! empty fronts, single points, duplicated points, and reference points
//! that the front does not dominate. These inputs show up in practice at
//! tight constraint scaling factors (empty feasible set) and with exact
//! table lookups (duplicated objective vectors), so the edge behavior is
//! pinned here rather than left to the property suite's random draws.

use repro::dse::{
    dominates, hypervolume2d, pareto_front_indices, Constraints, Objectives, ParetoFront,
};

// ---------------------------------------------------------------------------
// Hypervolume
// ---------------------------------------------------------------------------

#[test]
fn hv_empty_front_is_zero() {
    assert_eq!(hypervolume2d(&[], [1.0, 1.0]), 0.0);
    assert_eq!(repro::dse::hypervolume::relative_hypervolume2d(&[], [1.0, 1.0]), 0.0);
}

#[test]
fn hv_single_point_is_its_rectangle() {
    let hv = hypervolume2d(&[[0.25, 0.5]], [1.0, 2.0]);
    assert!((hv - 0.75 * 1.5).abs() < 1e-12);
}

#[test]
fn hv_duplicated_points_count_once() {
    let single = hypervolume2d(&[[0.3, 0.4]], [1.0, 1.0]);
    let dup = hypervolume2d(&[[0.3, 0.4]; 5], [1.0, 1.0]);
    assert!((single - dup).abs() < 1e-12);
    // Duplicates mixed into a larger front change nothing either.
    let front = [[0.1, 0.8], [0.5, 0.2]];
    let with_dups = [[0.1, 0.8], [0.5, 0.2], [0.1, 0.8], [0.5, 0.2]];
    assert!(
        (hypervolume2d(&front, [1.0, 1.0]) - hypervolume2d(&with_dups, [1.0, 1.0])).abs()
            < 1e-12
    );
}

#[test]
fn hv_reference_dominated_by_front_is_zero() {
    // Minimization: a point contributes only when it is strictly inside
    // the reference box. A reference that dominates (is below/left of)
    // every front point yields zero volume.
    let front = [[0.5, 0.5], [0.9, 0.2]];
    assert_eq!(hypervolume2d(&front, [0.1, 0.1]), 0.0);
    // Points exactly ON the reference boundary also contribute nothing.
    assert_eq!(hypervolume2d(&[[0.5, 1.0]], [1.0, 1.0]), 0.0);
    assert_eq!(hypervolume2d(&[[1.0, 0.5]], [1.0, 1.0]), 0.0);
}

#[test]
fn hv_zero_area_reference_box() {
    // Degenerate (zero-area) reference boxes cannot enclose any volume.
    assert_eq!(hypervolume2d(&[[0.0, 0.0]], [0.0, 1.0]), 0.0);
    assert_eq!(
        repro::dse::hypervolume::relative_hypervolume2d(&[[0.0, 0.0]], [0.0, 1.0]),
        0.0
    );
}

#[test]
fn hv_identical_coordinate_column() {
    // All points share one coordinate — the sweep must not double-count.
    let pts = [[0.2, 0.5], [0.4, 0.5], [0.8, 0.5]];
    let hv = hypervolume2d(&pts, [1.0, 1.0]);
    assert!((hv - 0.8 * 0.5).abs() < 1e-12); // only [0.2, 0.5] matters
}

// ---------------------------------------------------------------------------
// Pareto front extraction
// ---------------------------------------------------------------------------

#[test]
fn pareto_empty_input() {
    assert!(pareto_front_indices(&[]).is_empty());
    let f = ParetoFront::from_points(&[]);
    assert!(f.is_empty());
    assert_eq!(f.len(), 0);
    assert!(f.sorted_points().is_empty());
}

#[test]
fn pareto_single_point_is_the_front() {
    let pts: Vec<Objectives> = vec![[3.0, 7.0]];
    assert_eq!(pareto_front_indices(&pts), vec![0]);
    let f = ParetoFront::from_points(&pts);
    assert_eq!(f.points, pts);
}

#[test]
fn pareto_all_points_identical() {
    // No duplicate dominates its copy, so every index survives.
    let pts: Vec<Objectives> = vec![[1.0, 2.0]; 4];
    assert_eq!(pareto_front_indices(&pts), vec![0, 1, 2, 3]);
    assert!(!dominates(pts[0], pts[1]));
}

#[test]
fn pareto_duplicates_of_dominated_point_all_dropped() {
    let pts: Vec<Objectives> = vec![[0.0, 0.0], [1.0, 1.0], [1.0, 1.0]];
    assert_eq!(pareto_front_indices(&pts), vec![0]);
}

#[test]
fn pareto_collinear_column_keeps_only_minimum() {
    // Same first objective everywhere: only the minimal second survives
    // (ties on both coordinates would all survive).
    let pts: Vec<Objectives> = vec![[1.0, 3.0], [1.0, 1.0], [1.0, 2.0], [1.0, 1.0]];
    assert_eq!(pareto_front_indices(&pts), vec![1, 3]);
}

#[test]
fn pareto_front_feeds_hypervolume_consistently() {
    // The front of a degenerate set gives the same HV as the full set.
    let pts: Vec<Objectives> =
        vec![[0.5, 0.5], [0.5, 0.5], [0.2, 0.9], [0.9, 0.9], [0.9, 0.2]];
    let front: Vec<Objectives> =
        pareto_front_indices(&pts).iter().map(|&i| pts[i]).collect();
    let reference = [1.0, 1.0];
    assert!(
        (hypervolume2d(&pts, reference) - hypervolume2d(&front, reference)).abs() < 1e-12
    );
}

// ---------------------------------------------------------------------------
// Constraints interplay (the producer of degenerate fronts in practice)
// ---------------------------------------------------------------------------

#[test]
fn constraints_reference_with_infeasible_set_gives_zero_hv() {
    let c = Constraints::new(0.5, 0.5).unwrap();
    let objs: Vec<Objectives> = vec![[0.9, 0.9], [0.6, 0.7]];
    let feasible: Vec<Objectives> =
        objs.into_iter().filter(|&o| c.feasible(o)).collect();
    assert!(feasible.is_empty());
    assert_eq!(hypervolume2d(&feasible, c.reference()), 0.0);
}
