//! Cross-language consistency: rust operator+synthesis models vs the
//! python canonical models, pinned through `artifacts/golden_behav.json`.
//!
//! `aot.py` characterizes a fixed config set (accurate + single-removal +
//! seeded random) for every Table II operator with the *python* models;
//! this test recomputes everything with the *rust* models. Bit-exact
//! arithmetic + identical metric formulas ⇒ agreement to float-summation
//! noise.

use repro::charac::{characterize, Backend, InputSet};
use repro::operator::{AxoConfig, Operator};
use repro::util::json::Json;
use std::path::PathBuf;

fn artifacts() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn golden() -> Option<Json> {
    let p = artifacts().join("golden_behav.json");
    if !p.exists() {
        eprintln!("skipping golden tests: run `make artifacts` first");
        return None;
    }
    Some(Json::parse(&std::fs::read_to_string(p).unwrap()).unwrap())
}

fn check_operator(golden: &Json, op: Operator) {
    let entry = golden.get("operators").unwrap().get(&op.name()).unwrap();
    let uints: Vec<u64> = entry
        .get("configs_uint")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_str().unwrap().parse().unwrap())
        .collect();
    let configs: Vec<AxoConfig> = uints
        .iter()
        .map(|&u| AxoConfig::new(u, op.config_len()).unwrap())
        .collect();
    let inputs = InputSet::for_operator(op, &artifacts()).unwrap();
    let ds = characterize(op, &configs, &inputs, &Backend::Native).unwrap();

    let rows = |key: &str| -> Vec<Vec<f64>> {
        entry
            .get(key)
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|r| r.as_arr().unwrap().iter().map(|v| v.as_f64().unwrap()).collect())
            .collect()
    };
    let behav_g = rows("behav");
    let ppa_g = rows("ppa");
    assert_eq!(behav_g.len(), ds.len());
    for i in 0..ds.len() {
        let b = ds.behav[i].to_array();
        for k in 0..4 {
            let denom = behav_g[i][k].abs().max(1e-12);
            assert!(
                ((b[k] - behav_g[i][k]).abs() / denom) < 1e-9,
                "{op} cfg {} behav[{k}]: rust {} python {}",
                configs[i],
                b[k],
                behav_g[i][k]
            );
        }
        let p = ds.ppa[i].to_array();
        for k in 0..5 {
            let denom = ppa_g[i][k].abs().max(1e-12);
            assert!(
                ((p[k] - ppa_g[i][k]).abs() / denom) < 1e-9,
                "{op} cfg {} ppa[{k}]: rust {} python {}",
                configs[i],
                p[k],
                ppa_g[i][k]
            );
        }
    }
}

#[test]
fn golden_add4() {
    if let Some(g) = golden() {
        check_operator(&g, Operator::ADD4);
    }
}

#[test]
fn golden_add8() {
    if let Some(g) = golden() {
        check_operator(&g, Operator::ADD8);
    }
}

#[test]
fn golden_add12_uses_shared_sampled_inputs() {
    if let Some(g) = golden() {
        check_operator(&g, Operator::ADD12);
    }
}

#[test]
fn golden_mul4() {
    if let Some(g) = golden() {
        check_operator(&g, Operator::MUL4);
    }
}

#[test]
fn golden_mul8() {
    if let Some(g) = golden() {
        check_operator(&g, Operator::MUL8);
    }
}
