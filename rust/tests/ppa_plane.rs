//! Bit-identity of the two PPA backends.
//!
//! The config-parallel plane path (`synth/plane.rs`, 64 configurations
//! per u64 operation) is the default; the per-config scalar path is its
//! oracle. "Equivalent" means *bit-identical* [`PpaMetrics`] — every f64
//! compared by `to_bits`, never by tolerance — across operator kinds,
//! exhaustive and random config sets, ragged non-×64 batch tails, and
//! whole datasets out of the fused sharded pipeline under either BEHAV
//! backend (so cache and store entries never depend on which backends
//! characterized them).

use repro::charac::{
    characterize_sharded_timed, BehavBackend, Dataset, InputSet, PpaBackend,
};
use repro::operator::{AxoConfig, Operator};
use repro::synth::{ppa_batch_with, PpaMetrics};
use repro::util::rng::Rng;

fn assert_bit_identical(scalar: &[PpaMetrics], plane: &[PpaMetrics], what: &str) {
    assert_eq!(scalar.len(), plane.len(), "{what}: row count");
    for (i, (s, p)) in scalar.iter().zip(plane).enumerate() {
        assert_eq!(
            s.to_array().map(f64::to_bits),
            p.to_array().map(f64::to_bits),
            "{what}: config row {i} ({s:?} vs {p:?})"
        );
    }
}

/// Both backends over one operator/config pair.
fn both(op: Operator, configs: &[AxoConfig]) -> (Vec<PpaMetrics>, Vec<PpaMetrics>) {
    (
        ppa_batch_with(op, configs, PpaBackend::Scalar),
        ppa_batch_with(op, configs, PpaBackend::Plane),
    )
}

#[test]
fn add8_exhaustive_space_is_bit_identical() {
    // 255 configs: three full 64-lane blocks plus a 63-lane tail.
    let configs: Vec<AxoConfig> = AxoConfig::enumerate(8).collect();
    assert_eq!(configs.len(), 255);
    let (scalar, plane) = both(Operator::ADD8, &configs);
    assert_bit_identical(&scalar, &plane, "add8 exhaustive");
}

#[test]
fn mul4_exhaustive_space_is_bit_identical() {
    let configs: Vec<AxoConfig> = AxoConfig::enumerate(10).collect();
    assert_eq!(configs.len(), 1023);
    let (scalar, plane) = both(Operator::MUL4, &configs);
    assert_bit_identical(&scalar, &plane, "mul4 exhaustive");
}

#[test]
fn add12_random_configs_are_bit_identical() {
    let mut rng = Rng::seed_from_u64(41);
    let configs = AxoConfig::sample_unique(12, 200, &mut rng);
    let (scalar, plane) = both(Operator::ADD12, &configs);
    assert_bit_identical(&scalar, &plane, "add12 random configs");
}

#[test]
fn mul8_random_configs_are_bit_identical() {
    let mut rng = Rng::seed_from_u64(43);
    let configs = AxoConfig::sample_unique(36, 300, &mut rng);
    let (scalar, plane) = both(Operator::MUL8, &configs);
    assert_bit_identical(&scalar, &plane, "mul8 random configs");
}

#[test]
fn ragged_batch_tails_are_bit_identical() {
    // Block boundaries must be invisible: a lane's metrics depend only on
    // its own keep-mask, so partial tail blocks change nothing.
    let mut rng = Rng::seed_from_u64(47);
    let adds = AxoConfig::sample_unique(12, 130, &mut rng);
    let muls = AxoConfig::sample_unique(36, 130, &mut rng);
    for n in [1usize, 63, 64, 65, 130] {
        let (scalar, plane) = both(Operator::ADD12, &adds[..n]);
        assert_bit_identical(&scalar, &plane, &format!("add12 len {n}"));
        let (scalar, plane) = both(Operator::MUL8, &muls[..n]);
        assert_bit_identical(&scalar, &plane, &format!("mul8 len {n}"));
    }
}

fn assert_datasets_identical(a: &Dataset, b: &Dataset, what: &str) {
    assert_eq!(a.configs, b.configs, "{what}: config column");
    for i in 0..a.len() {
        assert_eq!(
            a.behav[i].to_array().map(f64::to_bits),
            b.behav[i].to_array().map(f64::to_bits),
            "{what}: behav row {i}"
        );
        assert_eq!(
            a.ppa[i].to_array().map(f64::to_bits),
            b.ppa[i].to_array().map(f64::to_bits),
            "{what}: ppa row {i}"
        );
    }
}

#[test]
fn fused_sharded_datasets_are_bit_identical_across_backend_corners() {
    // The backend pair must be invisible end to end: whole datasets out
    // of the fused sharded pipeline match bit-for-bit across all four
    // (BEHAV, PPA) backend corners, and each run reports its phase
    // clocks.
    let inputs = InputSet::exhaustive(Operator::MUL4);
    let mut rng = Rng::seed_from_u64(53);
    let configs = AxoConfig::sample_unique(10, 101, &mut rng);
    let (reference, timing) = characterize_sharded_timed(
        Operator::MUL4,
        &configs,
        &inputs,
        32,
        BehavBackend::Bitslice,
        PpaBackend::Plane,
    )
    .unwrap();
    assert!(timing.behav_ns > 0, "fused pipeline must clock its BEHAV phase");
    assert!(timing.ppa_ns > 0, "fused pipeline must clock its PPA phase");
    for (behav, ppa) in [
        (BehavBackend::Bitslice, PpaBackend::Scalar),
        (BehavBackend::Scalar, PpaBackend::Plane),
        (BehavBackend::Scalar, PpaBackend::Scalar),
    ] {
        let (ds, _) = characterize_sharded_timed(
            Operator::MUL4,
            &configs,
            &inputs,
            32,
            behav,
            ppa,
        )
        .unwrap();
        let what = format!("mul4 dataset ({}, {})", behav.name(), ppa.name());
        assert_datasets_identical(&reference, &ds, &what);
    }
}
