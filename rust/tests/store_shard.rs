//! Integration tests for the sharded characterization scheduler and the
//! persistent dataset store: shard-merge determinism (1 vs N shards
//! bit-identical), store round-trips across fresh contexts (warm runs
//! perform zero characterizations), corrupted / hash-mismatched entries
//! falling back to recompute, input-set caching in `validate`, and
//! concurrent misses on distinct keys completing without convoying.

use repro::charac::{characterize, characterize_sharded, Backend, Dataset, InputSet};
use repro::engine::{key_slug, CharacSubstrate, DatasetKey, EngineContext, SampleSpec};
use repro::expcfg::{CharacConfig, ExperimentConfig, StoreConfig};
use repro::operator::{AxoConfig, Operator};
use repro::util::rng::Rng;
use repro::util::tempdir::TempDir;
use std::io::Write as _;

fn assert_bit_identical(a: &Dataset, b: &Dataset) {
    assert_eq!(a.operator, b.operator);
    assert_eq!(a.configs, b.configs);
    assert_eq!(a.len(), b.len());
    for i in 0..a.len() {
        assert_eq!(
            a.behav[i].to_array().map(f64::to_bits),
            b.behav[i].to_array().map(f64::to_bits),
            "behav row {i}"
        );
        assert_eq!(
            a.ppa[i].to_array().map(f64::to_bits),
            b.ppa[i].to_array().map(f64::to_bits),
            "ppa row {i}"
        );
    }
}

/// A store-enabled configuration rooted in a fresh temp dir.
fn store_cfg(tmp: &TempDir) -> ExperimentConfig {
    ExperimentConfig {
        operator: "add8".into(),
        train_samples: 60,
        artifacts_dir: tmp.path().to_path_buf(),
        charac: CharacConfig { shard_size: 16, ..Default::default() },
        store: StoreConfig { enabled: Some(true), dir: None, max_bytes: None },
        ..Default::default()
    }
}

fn seeded_key() -> (Operator, SampleSpec) {
    (Operator::ADD8, SampleSpec::Seeded { seed: 5, n: 60 })
}

#[test]
fn sharded_seeded_characterization_matches_sequential_bit_for_bit() {
    // The engine's actual seeded path (sample → shard → merge) against a
    // hand-rolled sequential characterization of the same sample.
    let (op, spec) = seeded_key();
    let SampleSpec::Seeded { seed, n } = spec else { unreachable!() };
    let mut rng = Rng::seed_from_u64(seed);
    let cfgs = AxoConfig::sample_unique(op.config_len(), n, &mut rng);
    let inputs = InputSet::exhaustive(op);
    let sequential = characterize(op, &cfgs, &inputs, &Backend::Native).unwrap();

    for shard_size in [1, 7, 16, 60, 1000] {
        let sharded = characterize_sharded(op, &cfgs, &inputs, shard_size).unwrap();
        assert_bit_identical(&sharded, &sequential);
    }

    // And through the engine (store off → pure characterization).
    let ctx = EngineContext::new(ExperimentConfig {
        operator: "add8".into(),
        charac: CharacConfig { shard_size: 16, ..Default::default() },
        ..Default::default()
    });
    let engine_ds = ctx.dataset_with(op, spec).unwrap();
    assert_bit_identical(&engine_ds, &sequential);
}

#[test]
fn warm_store_run_characterizes_nothing_and_is_bit_identical() {
    let tmp = TempDir::new().unwrap();
    let (op, spec) = seeded_key();

    // Cold: characterizes and persists.
    let cold = EngineContext::new(store_cfg(&tmp));
    let ds_cold = cold.dataset_with(op, spec).unwrap();
    let s = cold.cache_stats();
    assert_eq!((s.characterized, s.store_hits), (1, 0));
    let store_dir = tmp.path().join("datasets");
    assert!(store_dir.join("manifest.json").exists());
    let slug = key_slug(&DatasetKey { op, substrate: CharacSubstrate::Native, spec });
    assert!(store_dir.join(format!("{slug}.json")).exists());

    // Warm: a fresh process-equivalent context loads from disk only.
    let warm = EngineContext::new(store_cfg(&tmp));
    let ds_warm = warm.dataset_with(op, spec).unwrap();
    let s = warm.cache_stats();
    assert_eq!(s.characterized, 0, "warm run must not characterize");
    assert_eq!(s.store_hits, 1);
    assert_bit_identical(&ds_warm, &ds_cold);

    // `--no-store` semantics: an explicitly disabled store ignores disk.
    let off = EngineContext::new(ExperimentConfig {
        store: StoreConfig { enabled: Some(false), dir: None, max_bytes: None },
        ..store_cfg(&tmp)
    });
    off.dataset_with(op, spec).unwrap();
    let s = off.cache_stats();
    assert_eq!((s.characterized, s.store_hits), (1, 0));
}

#[test]
fn corrupted_entry_falls_back_to_recompute_and_heals() {
    let tmp = TempDir::new().unwrap();
    let (op, spec) = seeded_key();
    let cold = EngineContext::new(store_cfg(&tmp));
    let ds_cold = cold.dataset_with(op, spec).unwrap();

    // Truncate the payload: hash check must fail, characterization must
    // rerun, and the save-back must heal the entry.
    let slug = key_slug(&DatasetKey { op, substrate: CharacSubstrate::Native, spec });
    let entry = tmp.path().join("datasets").join(format!("{slug}.json"));
    let text = std::fs::read_to_string(&entry).unwrap();
    std::fs::write(&entry, &text[..text.len() / 2]).unwrap();

    let ctx = EngineContext::new(store_cfg(&tmp));
    let ds = ctx.dataset_with(op, spec).unwrap();
    let s = ctx.cache_stats();
    assert_eq!((s.characterized, s.store_hits), (1, 0));
    assert_bit_identical(&ds, &ds_cold);

    let healed = EngineContext::new(store_cfg(&tmp));
    healed.dataset_with(op, spec).unwrap();
    assert_eq!(healed.cache_stats().store_hits, 1, "entry healed on save-back");
}

#[test]
fn manifest_hash_mismatch_falls_back_to_recompute() {
    let tmp = TempDir::new().unwrap();
    let (op, spec) = seeded_key();
    EngineContext::new(store_cfg(&tmp)).dataset_with(op, spec).unwrap();

    // Corrupt the recorded hash (payload untouched).
    let manifest = tmp.path().join("datasets").join("manifest.json");
    let text = std::fs::read_to_string(&manifest).unwrap();
    let start = text.find("\"hash\":\"").expect("manifest records a hash") + 8;
    let mut bytes = text.into_bytes();
    bytes[start] = if bytes[start] == b'0' { b'1' } else { b'0' };
    let mut f = std::fs::File::create(&manifest).unwrap();
    f.write_all(&bytes).unwrap();
    drop(f);

    let ctx = EngineContext::new(store_cfg(&tmp));
    ctx.dataset_with(op, spec).unwrap();
    let s = ctx.cache_stats();
    assert_eq!((s.characterized, s.store_hits), (1, 0));
}

#[test]
fn validate_reuses_cached_inputs_instead_of_rereading_disk() {
    // Persist a tiny add12 input sample, validate once (reads the file),
    // then delete the file: a second validate must produce bit-identical
    // metrics — proof it reused the cached inputs rather than falling
    // back to the (different) hermetic sample.
    let tmp = TempDir::new().unwrap();
    let path = tmp.path().join("inputs_add12.bin");
    let a: Vec<u32> = vec![1, 2, 3, 4000];
    let b: Vec<u32> = vec![7, 4095, 0, 9];
    let mut f = std::fs::File::create(&path).unwrap();
    f.write_all(b"AXIN").unwrap();
    f.write_all(&1u32.to_le_bytes()).unwrap();
    f.write_all(&(a.len() as u32).to_le_bytes()).unwrap();
    for v in a.iter().chain(&b) {
        f.write_all(&v.to_le_bytes()).unwrap();
    }
    drop(f);

    let ctx = EngineContext::new(ExperimentConfig {
        artifacts_dir: tmp.path().to_path_buf(),
        ..Default::default()
    });
    let cfgs =
        vec![AxoConfig::accurate(12), AxoConfig::new(0b0111_1111_1111, 12).unwrap()];
    let first = ctx.validate(Operator::ADD12, &cfgs).unwrap();
    std::fs::remove_file(&path).unwrap();
    let second = ctx.validate(Operator::ADD12, &cfgs).unwrap();
    assert_bit_identical(&second, &first);
    // 4 inputs, not the 65536-sample hermetic fallback.
    assert_eq!(ctx.inputs(Operator::ADD12).unwrap().len(), 4);
}

#[test]
fn store_entry_is_not_served_across_different_input_sets() {
    // The 12-bit adder characterizes against artifacts/inputs_add12.bin
    // when present but a seeded native fallback otherwise — the same
    // DatasetKey can mean two different input sets across processes. The
    // store records an input fingerprint and must refuse the stale entry.
    let tmp = TempDir::new().unwrap();
    let spec = SampleSpec::Seeded { seed: 9, n: 5 };
    let cfg = ExperimentConfig {
        operator: "add8".into(),
        artifacts_dir: tmp.path().to_path_buf(),
        store: StoreConfig { enabled: Some(true), dir: None, max_bytes: None },
        ..Default::default()
    };

    // Cold, no persisted inputs: hermetic fallback sample.
    let fallback = EngineContext::new(cfg.clone());
    fallback.dataset_with(Operator::ADD12, spec).unwrap();
    assert_eq!(fallback.cache_stats().characterized, 1);

    // The persisted numpy-style sample appears (tiny stand-in here): a
    // fresh context must re-characterize, not serve the fallback entry.
    let path = tmp.path().join("inputs_add12.bin");
    let mut f = std::fs::File::create(&path).unwrap();
    f.write_all(b"AXIN").unwrap();
    f.write_all(&1u32.to_le_bytes()).unwrap();
    f.write_all(&2u32.to_le_bytes()).unwrap();
    for v in [1u32, 2, 3, 4] {
        f.write_all(&v.to_le_bytes()).unwrap();
    }
    drop(f);
    let persisted = EngineContext::new(cfg.clone());
    let ds = persisted.dataset_with(Operator::ADD12, spec).unwrap();
    let s = persisted.cache_stats();
    assert_eq!((s.characterized, s.store_hits), (1, 0), "stale inputs must not hit");
    assert_eq!(ds.len(), 5);

    // Same inputs again: now it warm-starts.
    let warm = EngineContext::new(cfg);
    warm.dataset_with(Operator::ADD12, spec).unwrap();
    assert_eq!(warm.cache_stats().store_hits, 1);
}

#[test]
fn concurrent_misses_on_distinct_keys_both_complete() {
    // Two different keys requested from two threads: with the per-key
    // in-flight guard both characterize (the fine-grained concurrency
    // proof lives in the engine's KeyedOnce unit tests — this exercises
    // the real dataset path end to end).
    let ctx = EngineContext::new(ExperimentConfig {
        operator: "add8".into(),
        ..Default::default()
    });
    let (a, b) = std::thread::scope(|s| {
        let ha = s.spawn(|| ctx.dataset_with(Operator::ADD4, SampleSpec::Exhaustive));
        let hb = s.spawn(|| ctx.dataset_with(Operator::MUL4, SampleSpec::Exhaustive));
        (ha.join().unwrap().unwrap(), hb.join().unwrap().unwrap())
    });
    assert_eq!(a.len(), 15);
    assert_eq!(b.len(), 1023);
    let s = ctx.cache_stats();
    assert_eq!((s.misses, s.entries, s.characterized), (2, 2, 2));
}
