//! Property-based invariants (hand-rolled generator loop over the
//! deterministic [`Rng`]; no external proptest crate is linked).
//!
//! Each property runs across many random cases with seeds printed on
//! failure, covering the coordinator contract, GA legality, hypervolume
//! monotonicity, dataset round-trips, matching minimality, and config
//! algebra.

use repro::charac::{characterize, Backend, Dataset, InputSet};
use repro::coordinator::{BatchOptions, EstimatorService};
use repro::dse::{
    dominates, hypervolume2d, pareto_front_indices, Constraints, GaOptions, NsgaRunner,
    Objectives,
};
use repro::matching::{DistanceKind, Matcher};
use repro::operator::{AxoConfig, Operator};
use repro::surrogate::Surrogate;
use repro::util::rng::Rng;
use repro::util::tempdir::TempDir;
use std::sync::Arc;

const CASES: u64 = 40;

// ---------------------------------------------------------------------------
// Configuration algebra
// ---------------------------------------------------------------------------

#[test]
fn prop_crossover_preserves_bits_per_position() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(seed);
        let len = 2 + rng.gen_index(34) as u32;
        let a = AxoConfig::sample_unique(len, 1, &mut rng)[0];
        let b = AxoConfig::sample_unique(len, 1, &mut rng)[0];
        let point = 1 + rng.gen_index((len - 1) as usize) as u32;
        let (c1, c2) = a.crossover(&b, point);
        for k in 0..len {
            let parents = [a.keeps(k), b.keeps(k)];
            for c in [c1, c2].into_iter().flatten() {
                assert!(
                    parents.contains(&c.keeps(k)),
                    "seed {seed}: child bit {k} not from a parent"
                );
            }
        }
        // Children never encode all-zeros.
        for c in [c1, c2].into_iter().flatten() {
            assert_ne!(c.as_uint(), 0, "seed {seed}");
        }
    }
}

#[test]
fn prop_hamming_triangle_inequality() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(1000 + seed);
        let len = 2 + rng.gen_index(34) as u32;
        let cfgs = AxoConfig::sample_unique(len, 3, &mut rng);
        let (a, b, c) = (cfgs[0], cfgs[1], cfgs[2]);
        assert!(a.hamming(&c) <= a.hamming(&b) + b.hamming(&c), "seed {seed}");
        assert_eq!(a.hamming(&b), b.hamming(&a));
        assert_eq!(a.hamming(&a), 0);
    }
}

// ---------------------------------------------------------------------------
// Pareto / hypervolume
// ---------------------------------------------------------------------------

fn random_points(rng: &mut Rng, n: usize) -> Vec<Objectives> {
    (0..n).map(|_| [rng.gen_f64() * 2.0, rng.gen_f64() * 2.0]).collect()
}

#[test]
fn prop_front_members_are_mutually_nondominated() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(2000 + seed);
        let n = 50 + rng.gen_index(200);
        let pts = random_points(&mut rng, n);
        let front = pareto_front_indices(&pts);
        for &i in &front {
            for &j in &front {
                assert!(!dominates(pts[j], pts[i]) || i == j, "seed {seed}");
            }
            // Every non-front point is dominated by some front point.
        }
        for k in 0..pts.len() {
            if !front.contains(&k) {
                assert!(
                    front.iter().any(|&i| dominates(pts[i], pts[k])),
                    "seed {seed}: dropped point {k} not dominated"
                );
            }
        }
    }
}

#[test]
fn prop_hypervolume_monotone_under_adding_points() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(3000 + seed);
        let mut pts = random_points(&mut rng, 30);
        let reference = [1.5, 1.5];
        let hv1 = hypervolume2d(&pts, reference);
        pts.extend(random_points(&mut rng, 10));
        let hv2 = hypervolume2d(&pts, reference);
        assert!(hv2 >= hv1 - 1e-12, "seed {seed}: {hv2} < {hv1}");
        // Bounded by the reference box.
        assert!(hv2 <= 1.5 * 1.5 + 1e-12);
    }
}

#[test]
fn prop_hypervolume_equals_front_hypervolume() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(4000 + seed);
        let pts = random_points(&mut rng, 120);
        let reference = [2.0, 2.0];
        let front: Vec<Objectives> =
            pareto_front_indices(&pts).iter().map(|&i| pts[i]).collect();
        let a = hypervolume2d(&pts, reference);
        let b = hypervolume2d(&front, reference);
        assert!((a - b).abs() < 1e-12, "seed {seed}");
    }
}

// ---------------------------------------------------------------------------
// GA invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_ga_population_legal_and_front_feasible() {
    let fitness = |cfgs: &[AxoConfig]| -> repro::error::Result<Vec<Objectives>> {
        Ok(cfgs
            .iter()
            .map(|c| {
                let ones = c.count_kept() as f64 / c.len() as f64;
                [1.0 - ones, ones]
            })
            .collect())
    };
    for seed in 0..8 {
        let constraints = Constraints::new(0.7, 0.9).unwrap();
        let runner = NsgaRunner::new(
            GaOptions { pop_size: 16, generations: 8, seed, ..Default::default() },
            constraints,
        );
        let r = runner.run(14, &fitness, &[]).unwrap();
        assert_eq!(r.population.len(), 16, "seed {seed}");
        assert!(r.population.iter().all(|c| c.as_uint() != 0 && c.len() == 14));
        assert!(r.front_points.iter().all(|&o| constraints.feasible(o)));
        for w in r.hv_history.windows(2) {
            assert!(w[1] >= w[0] - 1e-12, "seed {seed}: archive HV decreased");
        }
    }
}

// ---------------------------------------------------------------------------
// Coordinator service contract (fuzzed)
// ---------------------------------------------------------------------------

struct EchoBackend;
impl Surrogate for EchoBackend {
    fn predict(
        &self,
        configs: &[AxoConfig],
    ) -> repro::error::Result<Vec<Objectives>> {
        Ok(configs
            .iter()
            .map(|c| [c.as_uint() as f64, c.count_kept() as f64])
            .collect())
    }
}

#[test]
fn prop_service_never_drops_reorders_or_duplicates() {
    let svc = EstimatorService::spawn(Arc::new(EchoBackend), BatchOptions::default());
    std::thread::scope(|s| {
        for t in 0..6u64 {
            let svc = svc.clone();
            s.spawn(move || {
                let mut rng = Rng::seed_from_u64(5000 + t);
                for round in 0..30 {
                    let n = 1 + rng.gen_index(40);
                    let cfgs = AxoConfig::sample_unique(20, n, &mut rng);
                    let out = svc.predict(cfgs.clone()).unwrap();
                    assert_eq!(out.len(), n, "thread {t} round {round}");
                    for (c, o) in cfgs.iter().zip(&out) {
                        assert_eq!(o[0], c.as_uint() as f64);
                        assert_eq!(o[1], c.count_kept() as f64);
                    }
                }
            });
        }
    });
    let snap = svc.metrics().snapshot();
    assert_eq!(snap.requests, 6 * 30);
    assert_eq!(snap.errors, 0);
}

// ---------------------------------------------------------------------------
// Dataset round-trip
// ---------------------------------------------------------------------------

#[test]
fn prop_dataset_json_roundtrip_exact() {
    let inputs = InputSet::exhaustive(Operator::MUL4);
    for seed in 0..6 {
        let mut rng = Rng::seed_from_u64(6000 + seed);
        let cfgs = AxoConfig::sample_unique(10, 20, &mut rng);
        let ds = characterize(Operator::MUL4, &cfgs, &inputs, &Backend::Native).unwrap();
        let dir = TempDir::new().unwrap();
        let p = dir.join("ds.json");
        ds.save_json(&p).unwrap();
        let back = Dataset::load_json(&p).unwrap();
        assert_eq!(back.operator, ds.operator);
        assert_eq!(back.configs, ds.configs);
        for i in 0..ds.len() {
            // f64 survives the JSON round-trip through our writer exactly
            // for these magnitudes? Not guaranteed for all doubles — check
            // to 1e-12 relative.
            for (a, b) in ds.behav[i].to_array().iter().zip(back.behav[i].to_array()) {
                assert!((a - b).abs() <= a.abs().max(1.0) * 1e-12, "seed {seed}");
            }
            for (a, b) in ds.ppa[i].to_array().iter().zip(back.ppa[i].to_array()) {
                assert!((a - b).abs() <= a.abs().max(1.0) * 1e-12, "seed {seed}");
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Matching minimality
// ---------------------------------------------------------------------------

#[test]
fn prop_matching_picks_global_minimum() {
    let l_in = InputSet::exhaustive(Operator::ADD4);
    let h_in = InputSet::exhaustive(Operator::ADD8);
    let l = characterize(
        Operator::ADD4,
        &AxoConfig::enumerate(4).collect::<Vec<_>>(),
        &l_in,
        &Backend::Native,
    )
    .unwrap();
    for (seed, kind) in [(0u64, DistanceKind::Euclidean), (1, DistanceKind::Manhattan), (2, DistanceKind::Pareto)] {
        let mut rng = Rng::seed_from_u64(7000 + seed);
        let cfgs = AxoConfig::sample_unique(8, 60, &mut rng);
        let h = characterize(Operator::ADD8, &cfgs, &h_in, &Backend::Native).unwrap();
        let matcher = Matcher::new(kind);
        let m = matcher.match_datasets(&l, &h).unwrap();
        let all = matcher.all_distances(&l, &h).unwrap();
        for (hi, &li) in m.h_to_l.iter().enumerate() {
            let row = &all[hi * l.len()..(hi + 1) * l.len()];
            let min = row.iter().cloned().fold(f64::INFINITY, f64::min);
            assert!(
                (row[li] - min).abs() < 1e-12,
                "{kind:?} h {hi}: matched {} but min {}",
                row[li],
                min
            );
        }
    }
}
