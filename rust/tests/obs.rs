//! Integration tests over the `repro::obs` surface: sharded histograms
//! merge to one truth, the span ring survives wraparound (alone and under
//! concurrent writers), span parentage holds across a thread handoff, and
//! the Chrome trace export is well-formed.
//!
//! Tests that need the tracer call [`repro::obs::force_enable`] — the
//! gate is process-global and never turned back off here, so every test
//! filters the shared ring by a unique `arg` payload instead of assuming
//! it is empty.

use repro::obs::{self, HistSnapshot, Histogram, SpanEvent, SpanRing};
use repro::util::json::Json;

#[test]
fn histogram_thread_shards_merge_to_one_truth() {
    // Four threads record disjoint slices into private histograms and one
    // shared histogram; bucket-merging the shards must reproduce the
    // shared readout exactly — the property `/metrics` leans on.
    let shared = Histogram::new();
    let shards: Vec<HistSnapshot> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..4u64)
            .map(|t| {
                let shared = &shared;
                s.spawn(move || {
                    let mine = Histogram::new();
                    for i in 0..256u64 {
                        let v = (t * 1000 + i * 37) % 5000;
                        mine.record(v);
                        shared.record(v);
                    }
                    mine.snapshot()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let merged = shards.iter().fold(HistSnapshot::default(), |a, s| a.merged(s));
    let whole = shared.snapshot();
    assert_eq!(merged, whole);
    for p in [1.0, 25.0, 50.0, 90.0, 99.0, 100.0] {
        assert_eq!(merged.percentile(p), whole.percentile(p), "p{p}");
    }
    assert_eq!(whole.count, 1024);
    assert!(whole.percentile(100.0) >= whole.percentile(50.0));
}

fn ev(id: u64, trace: u64, tid: u16, start_ns: u64) -> SpanEvent {
    SpanEvent { id, parent: 0, trace, name: 0, tid, arg: 0, start_ns, dur_ns: 10 }
}

#[test]
fn span_ring_overwrites_oldest_and_counts_drops() {
    let ring = SpanRing::new(16);
    for i in 1..=40u64 {
        ring.record(&ev(i, i, 1, i * 100));
    }
    assert_eq!(ring.recorded(), 40);
    assert_eq!(ring.dropped(), 24);
    let ids: Vec<u64> = ring.snapshot().iter().map(|e| e.id).collect();
    assert_eq!(ids, (25..=40).collect::<Vec<u64>>());
}

#[test]
fn span_ring_concurrent_writers_never_lose_the_count() {
    // The head cursor is exact even when the slots churn; snapshots under
    // contention may skip torn slots but never exceed capacity and stay
    // sorted by (start_ns, id).
    let ring = SpanRing::new(64);
    std::thread::scope(|s| {
        for t in 0..4u64 {
            let ring = &ring;
            s.spawn(move || {
                for i in 0..1000u64 {
                    ring.record(&ev(t * 10_000 + i + 1, t + 1, t as u16 + 1, i));
                }
            });
        }
    });
    assert_eq!(ring.recorded(), 4_000);
    assert_eq!(ring.dropped(), 4_000 - 64);
    let snap = ring.snapshot();
    assert!(snap.len() <= 64);
    for e in &snap {
        assert!(e.id != 0);
    }
    for w in snap.windows(2) {
        assert!((w[0].start_ns, w[0].id) <= (w[1].start_ns, w[1].id));
    }
}

#[test]
fn span_parentage_survives_thread_handoff() {
    obs::force_enable();
    let mut root = obs::span(obs::n::JOB_SUBMIT);
    root.set_arg(414_141);
    let ctx = root.ctx();
    std::thread::scope(|s| {
        for i in 0..3u64 {
            s.spawn(move || {
                let mut child = obs::span_under(ctx, obs::n::JOB_EXECUTE);
                child.set_arg(424_242 + i);
            });
        }
    });
    drop(root);
    let events = obs::tracer().ring().snapshot();
    let root_ev = events
        .iter()
        .find(|e| e.name == obs::n::JOB_SUBMIT && e.arg == 414_141)
        .expect("root span recorded");
    assert_eq!(root_ev.parent, 0);
    let children: Vec<&SpanEvent> =
        events.iter().filter(|e| (424_242..424_245).contains(&e.arg)).collect();
    assert_eq!(children.len(), 3);
    for c in children {
        assert_eq!(c.parent, root_ev.id);
        assert_eq!(c.trace, root_ev.trace);
        assert_eq!(c.name, obs::n::JOB_EXECUTE);
        assert!(c.tid != root_ev.tid, "child ran on its own thread");
        assert!(c.start_ns >= root_ev.start_ns);
    }
}

#[test]
fn chrome_export_is_well_formed_trace_event_json() {
    obs::force_enable();
    {
        let mut s = obs::span(obs::n::ENGINE_CHARACTERIZE);
        s.set_arg(777_001);
    }
    let text = obs::export_chrome().to_string();
    let parsed = Json::parse(&text).expect("chrome trace parses");
    assert_eq!(parsed.get("displayTimeUnit").and_then(Json::as_str), Some("ms"));
    let items = parsed.get("traceEvents").unwrap().as_arr().unwrap();
    let arg_of =
        |e: &Json| e.get("args").and_then(|a| a.get("arg")).and_then(Json::as_u64);
    let ours = items
        .iter()
        .find(|e| arg_of(e) == Some(777_001))
        .expect("our span exported");
    assert_eq!(ours.get("ph").and_then(Json::as_str), Some("X"));
    let name = ours.get("name").and_then(Json::as_str);
    assert_eq!(name, Some("engine.characterize"));
    assert_eq!(ours.get("cat").and_then(Json::as_str), Some("engine"));
    assert!(ours.get("ts").and_then(Json::as_f64).is_some());
    assert!(ours.get("dur").and_then(Json::as_f64).is_some());
    let span_id = ours.get("args").and_then(|a| a.get("span")).and_then(Json::as_str);
    assert!(span_id.is_some_and(|s| s.len() == 16), "span id is 16 hex chars");
}
