//! Serve-subsystem integration tests: the full job lifecycle (submit →
//! pending → running → done/failed), bit-identical hypervolumes for a
//! mixed add12+mul8 queue vs the equivalent direct `DseJob` runs, and the
//! exactly-once resource story — each dataset characterized and each
//! estimator backend spawned at most once per process, asserted via
//! `CacheStats` + `PoolStats` while concurrent mixed-operator jobs drain.

use repro::conss::SeedSelection;
use repro::engine::{DseJob, EngineContext};
use repro::expcfg::{ConssConfig, ExperimentConfig, GaConfig, SurrogateConfig};
use repro::operator::Operator;
use repro::serve::{
    JobQueue, JobRunner, JobSpec, ServeOptions, ServeSummary, LOG_FILE,
};
use repro::surrogate::EstimatorBackend;
use repro::util::json::Json;
use repro::util::tempdir::TempDir;
use std::path::Path;

/// Write a tiny persisted add12 input sample (`AXIN` v1, 192 pairs) so the
/// 12-bit adder characterizes over a small deterministic operand set
/// instead of the 65,536-pair hermetic fallback — the mixed-operator tests
/// stay fast while exercising the persisted-inputs path.
fn write_add12_inputs(artifacts_dir: &Path) {
    let n: u32 = 192;
    let mut buf = Vec::new();
    buf.extend_from_slice(b"AXIN");
    buf.extend_from_slice(&1u32.to_le_bytes());
    buf.extend_from_slice(&n.to_le_bytes());
    for k in 0..n {
        buf.extend_from_slice(&((k.wrapping_mul(131)) % 4096).to_le_bytes());
    }
    for k in 0..n {
        let b = (k.wrapping_mul(197).wrapping_add(77)) % 4096;
        buf.extend_from_slice(&b.to_le_bytes());
    }
    std::fs::create_dir_all(artifacts_dir).unwrap();
    std::fs::write(artifacts_dir.join("inputs_add12.bin"), buf).unwrap();
}

/// Heterogeneous-queue configuration: GBT surrogate (total over any
/// operator, unlike the exact table), tiny forests/GA, a 12-sample mul8
/// H_CHAR draw.
fn mixed_cfg(artifacts_dir: &Path) -> ExperimentConfig {
    ExperimentConfig {
        operator: "add12".into(),
        artifacts_dir: artifacts_dir.to_path_buf(),
        train_samples: 12,
        surrogate: SurrogateConfig {
            backend: EstimatorBackend::Gbt,
            gbt_stages: Some(4),
        },
        conss: ConssConfig { forest_trees: Some(3), noise_bits: 2, ..Default::default() },
        ga: GaConfig { pop_size: 8, generations: 3, ..Default::default() },
        ..Default::default()
    }
}

/// Homogeneous fast configuration: exhaustive add8, exact-table surrogate.
fn add8_cfg() -> ExperimentConfig {
    ExperimentConfig {
        operator: "add8".into(),
        surrogate: SurrogateConfig { backend: EstimatorBackend::Table, gbt_stages: None },
        conss: ConssConfig { forest_trees: Some(4), noise_bits: 2, ..Default::default() },
        ga: GaConfig { pop_size: 10, generations: 3, ..Default::default() },
        ..Default::default()
    }
}

#[test]
fn mixed_queue_matches_direct_runs_bit_for_bit_with_exactly_once_resources() {
    let tmp = TempDir::new().unwrap();
    let artifacts = tmp.path().join("artifacts");
    write_add12_inputs(&artifacts);
    let cfg = mixed_cfg(&artifacts);

    // Direct ground truth: the equivalent library calls on a fresh engine.
    let direct = EngineContext::new(cfg.clone());
    let add12_prep = direct.prepare_dse_for(Operator::ADD12).unwrap();
    let add12_runs =
        add12_prep.run_many(&[DseJob::new(0.5), DseJob::new(0.8)]).unwrap();
    let mul8_prep = direct.prepare_dse_for(Operator::MUL8).unwrap();
    let mul8_all = mul8_prep.run_job(&DseJob::new(0.9)).unwrap();
    let mul8_pareto = mul8_prep
        .run_job(&DseJob::new(0.75).seed_selection(SeedSelection::ParetoOnly))
        .unwrap();

    // Served: the same three workloads as specs through the spool, two
    // workers draining concurrently against a fresh engine.
    let queue = JobQueue::open(tmp.path().join("jobs")).unwrap();
    let mut sweep = JobSpec::new("add12-sweep", vec![0.5, 0.8]);
    sweep.operator = Some(Operator::ADD12);
    queue.submit(&sweep).unwrap();
    let mut all = JobSpec::new("mul8-all", vec![0.9]);
    all.operator = Some(Operator::MUL8);
    queue.submit(&all).unwrap();
    let mut pareto = JobSpec::new("mul8-pareto", vec![0.75]);
    pareto.operator = Some(Operator::MUL8);
    pareto.seed_selection = SeedSelection::ParetoOnly;
    queue.submit(&pareto).unwrap();

    let served = EngineContext::new(cfg);
    let runner = JobRunner::new(
        &served,
        &queue,
        ServeOptions { workers: 2, ..Default::default() },
    )
    .unwrap();
    let summary = runner.run().unwrap();
    assert_eq!(summary, ServeSummary { done: 3, failed: 0 });
    assert_eq!(
        queue.done_ids().unwrap(),
        vec!["add12-sweep", "mul8-all", "mul8-pareto"]
    );

    // Recorded hypervolumes are bit-identical to the direct runs (the
    // JSON writer emits shortest round-tripping float representations).
    let r = queue.result("add12-sweep").unwrap();
    assert_eq!(r.operator, Operator::ADD12);
    assert_eq!(r.factors.len(), 2);
    for (got, want) in r.factors.iter().zip(&add12_runs) {
        assert_eq!(got.factor, want.factor);
        assert_eq!(got.hv_train.to_bits(), want.hv_train.to_bits());
        assert_eq!(got.hv_conss.to_bits(), want.hv_conss.to_bits());
        assert_eq!(got.hv_ga.to_bits(), want.ga.final_hypervolume().to_bits());
        assert_eq!(
            got.hv_conss_ga.to_bits(),
            want.conss_ga.final_hypervolume().to_bits()
        );
        assert_eq!(got.evaluations_ga, want.ga.evaluations);
        assert_eq!(got.evaluations_conss_ga, want.conss_ga.evaluations);
        assert_eq!(got.pool_size, want.conss_pool.configs.len());
    }
    let ra = queue.result("mul8-all").unwrap();
    assert_eq!(
        ra.factors[0].hv_conss_ga.to_bits(),
        mul8_all.conss_ga.final_hypervolume().to_bits()
    );
    assert_eq!(ra.factors[0].hv_train.to_bits(), mul8_all.hv_train.to_bits());
    assert!(ra.factors[0].hv_conss_ga > 0.0, "nonzero hypervolume");
    let rp = queue.result("mul8-pareto").unwrap();
    assert_eq!(
        rp.factors[0].hv_conss_ga.to_bits(),
        mul8_pareto.conss_ga.final_hypervolume().to_bits()
    );

    // Exactly-once resources on the serving engine: four datasets (add8
    // L, add12 H, mul4 L, mul8 H) characterized once each, two estimator
    // services (add12, mul8) spawned once each — concurrent mixed jobs
    // never re-characterized or re-spawned anything.
    let s = served.cache_stats();
    assert_eq!(s.characterized, 4, "one characterization per dataset key");
    assert_eq!(s.entries, 4);
    assert_eq!(s.store_hits, 0, "store is off in hermetic tests");
    let p = served.pool_stats();
    assert_eq!(p.spawned, 2, "one estimator per operator key");
    assert_eq!(p.services, 2);
}

#[test]
fn job_failing_at_execution_is_quarantined_with_the_engine_error() {
    let tmp = TempDir::new().unwrap();
    let queue = JobQueue::open(tmp.path().join("jobs")).unwrap();
    // add4 is a valid operator but has no smaller ConSS partner, so the
    // job passes spec validation and fails inside the engine.
    let mut spec = JobSpec::new("bad-op", vec![0.5]);
    spec.operator = Some(Operator::ADD4);
    queue.submit(&spec).unwrap();

    let ctx = EngineContext::new(add8_cfg());
    let runner = JobRunner::new(&ctx, &queue, ServeOptions::default()).unwrap();
    let summary = runner.run().unwrap();
    assert_eq!(summary, ServeSummary { done: 0, failed: 1 });
    assert_eq!(queue.failed_ids().unwrap(), vec!["bad-op"]);
    let err = queue.error("bad-op").unwrap();
    assert!(err.contains("no smaller ConSS partner"), "recorded: {err}");
    // The quarantined spec is intact for post-mortem resubmission.
    let kept = JobSpec::parse(
        &std::fs::read_to_string(tmp.path().join("jobs/failed/bad-op.json")).unwrap(),
    )
    .unwrap();
    assert_eq!(kept.operator, Some(Operator::ADD4));
    // Nothing was paid for: no datasets, no estimators.
    assert_eq!(ctx.cache_stats().characterized, 0);
    assert_eq!(ctx.pool_stats().spawned, 0);
}

#[test]
fn concurrent_same_operator_jobs_share_one_estimator_and_prepared_state() {
    let tmp = TempDir::new().unwrap();
    let queue = JobQueue::open(tmp.path().join("jobs")).unwrap();
    for (i, f) in [0.4, 0.6, 0.8, 1.0].iter().enumerate() {
        queue.submit(&JobSpec::new(format!("f{i}"), vec![*f])).unwrap();
    }
    let ctx = EngineContext::new(add8_cfg());
    let runner = JobRunner::new(
        &ctx,
        &queue,
        ServeOptions { workers: 4, ..Default::default() },
    )
    .unwrap();
    let summary = runner.run().unwrap();
    assert_eq!(summary, ServeSummary { done: 4, failed: 0 });

    // Four concurrent same-operator jobs: two datasets (add4 L, add8 H),
    // one estimator — the per-key in-flight guards held under the race.
    let s = ctx.cache_stats();
    assert_eq!(s.characterized, 2);
    assert_eq!(s.entries, 2);
    let p = ctx.pool_stats();
    assert_eq!(p.spawned, 1);
    assert_eq!(p.services, 1);

    // The event stream recorded the whole lifecycle: one start/stop pair
    // per run, a claim+done per job, no failures.
    let log = std::fs::read_to_string(queue.dir().join(LOG_FILE)).unwrap();
    let events: Vec<Json> = log.lines().map(|l| Json::parse(l).unwrap()).collect();
    let count = |kind: &str| {
        events
            .iter()
            .filter(|e| e.get("event").and_then(Json::as_str) == Some(kind))
            .count()
    };
    assert_eq!(count("claim"), 4);
    assert_eq!(count("done"), 4);
    assert_eq!(count("fail"), 0);
    assert_eq!(count("start"), 1);
    assert_eq!(count("stop"), 1);
    // Done events carry the operator and wall time.
    let done = events
        .iter()
        .find(|e| e.get("event").and_then(Json::as_str) == Some("done"))
        .unwrap();
    assert_eq!(done.get("operator").and_then(Json::as_str), Some("add8"));
    assert!(done.get("wall_ms").and_then(Json::as_u64).is_some());

    // Drain-mode exit left a clean spool.
    let c = queue.counts().unwrap();
    assert_eq!((c.pending, c.running, c.done, c.failed), (0, 0, 4, 0));
}
