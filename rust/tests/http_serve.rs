//! HTTP front-end integration tests: loopback end-to-end submit → drain →
//! result round trips that are bit-identical to direct `DseJob` runs,
//! concurrent duplicate submissions collapsing onto one spooled job with
//! many waiters, protocol rejections (`400`) that never spool, and
//! backpressure (`429`) that leaves the queue untouched.

use repro::engine::{DseJob, EngineContext};
use repro::expcfg::{ConssConfig, ExperimentConfig, GaConfig, SurrogateConfig};
use repro::serve::{
    http_call, HttpOptions, HttpServer, JobQueue, JobResult, LOG_FILE,
};
use repro::surrogate::EstimatorBackend;
use repro::util::json::Json;
use repro::util::tempdir::TempDir;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Homogeneous fast configuration: exhaustive add8, exact-table surrogate
/// (the `serve_jobs` idiom — small enough for end-to-end execution).
fn add8_cfg() -> ExperimentConfig {
    ExperimentConfig {
        operator: "add8".into(),
        surrogate: SurrogateConfig { backend: EstimatorBackend::Table, gbt_stages: None },
        conss: ConssConfig { forest_trees: Some(4), noise_bits: 2, ..Default::default() },
        ga: GaConfig { pop_size: 10, generations: 3, ..Default::default() },
        ..Default::default()
    }
}

/// A running server over a fresh spool: queue handle, bound address, and
/// the serving thread (joined via `stop`).
struct Harness {
    _dir: TempDir,
    queue: Arc<JobQueue>,
    server: Arc<HttpServer>,
    addr: String,
    handle: std::thread::JoinHandle<()>,
}

impl Harness {
    fn start(opts: HttpOptions) -> Harness {
        let dir = TempDir::new().unwrap();
        let queue = Arc::new(JobQueue::open(dir.path().join("jobs")).unwrap());
        let ctx = Arc::new(EngineContext::new(add8_cfg()));
        let server = Arc::new(
            HttpServer::bind(ctx, Arc::clone(&queue), "127.0.0.1:0", opts).unwrap(),
        );
        let addr = server.local_addr().to_string();
        let handle = {
            let server = Arc::clone(&server);
            std::thread::spawn(move || server.run().unwrap())
        };
        Harness { _dir: dir, queue, server, addr, handle }
    }

    /// Poll `GET /jobs/<id>` until the job reaches `done` (panicking on
    /// `failed` or timeout — both mean the pipeline is broken).
    fn wait_done(&self, id: &str) {
        let deadline = Instant::now() + Duration::from_secs(120);
        loop {
            let status =
                http_call(&self.addr, "GET", &format!("/jobs/{id}"), None).unwrap();
            assert_eq!(status.status, 200, "{}", status.body);
            let state = status
                .json()
                .unwrap()
                .get("state")
                .and_then(Json::as_str)
                .unwrap()
                .to_string();
            match state.as_str() {
                "done" => return,
                "failed" => panic!(
                    "job {id} failed: {}",
                    http_call(&self.addr, "GET", &format!("/jobs/{id}/result"), None)
                        .map(|r| r.body)
                        .unwrap_or_default()
                ),
                _ if Instant::now() > deadline => {
                    panic!("job {id} stuck in `{state}`")
                }
                _ => std::thread::sleep(Duration::from_millis(50)),
            }
        }
    }

    fn stop(self) {
        self.server.shutdown();
        self.handle.join().unwrap();
    }
}

#[test]
fn http_round_trip_is_bit_identical_to_direct_runs() {
    // Direct ground truth: the same two factor jobs on a fresh engine.
    let direct = EngineContext::new(add8_cfg());
    let prep = direct.prepare_dse_for(repro::operator::Operator::ADD8).unwrap();
    let want = prep.run_many(&[DseJob::new(0.6), DseJob::new(0.9)]).unwrap();

    // Served: the equivalent spec over HTTP, drained by the embedded
    // exec loop, result fetched back over HTTP.
    let h = Harness::start(HttpOptions { workers: 2, ..Default::default() });
    let spec = r#"{"factors":[0.6,0.9],"operator":"add8"}"#;
    let created = http_call(&h.addr, "POST", "/jobs", Some(spec)).unwrap();
    assert_eq!(created.status, 201, "{}", created.body);
    let id = created
        .json()
        .unwrap()
        .get("id")
        .and_then(Json::as_str)
        .unwrap()
        .to_string();
    h.wait_done(&id);

    let fetched =
        http_call(&h.addr, "GET", &format!("/jobs/{id}/result"), None).unwrap();
    assert_eq!(fetched.status, 200);
    // The HTTP body is the done/ record verbatim...
    assert_eq!(fetched.body, h.queue.result_text(&id).unwrap());
    // ...and its hypervolumes are bit-identical to the direct runs.
    let result = JobResult::parse(&fetched.body).unwrap();
    assert_eq!(result.id, id);
    assert_eq!(result.factors.len(), 2);
    for (got, direct) in result.factors.iter().zip(&want) {
        assert_eq!(got.factor, direct.factor);
        assert_eq!(got.hv_train.to_bits(), direct.hv_train.to_bits());
        assert_eq!(got.hv_conss.to_bits(), direct.hv_conss.to_bits());
        assert_eq!(got.hv_ga.to_bits(), direct.ga.final_hypervolume().to_bits());
        assert_eq!(
            got.hv_conss_ga.to_bits(),
            direct.conss_ga.final_hypervolume().to_bits()
        );
        assert_eq!(got.evaluations_ga, direct.ga.evaluations);
        assert_eq!(got.evaluations_conss_ga, direct.conss_ga.evaluations);
        assert!(got.hv_conss_ga > 0.0, "nonzero hypervolume");
    }

    // A resubmission after completion is a pure cache hit: 200, shared
    // id, state done, no new queue entry.
    let replay = http_call(&h.addr, "POST", "/jobs", Some(spec)).unwrap();
    assert_eq!(replay.status, 200);
    let replay = replay.json().unwrap();
    assert_eq!(replay.get("id").and_then(Json::as_str), Some(id.as_str()));
    assert_eq!(replay.get("state").and_then(Json::as_str), Some("done"));
    assert_eq!(h.queue.done_ids().unwrap(), vec![id]);

    h.stop();
}

#[test]
fn concurrent_duplicates_spool_one_job_with_many_waiters() {
    let h = Harness::start(HttpOptions { workers: 2, ..Default::default() });
    // Eight clients race byte-different spellings of identical work
    // (key order and float formatting vary; canonical hashing unifies).
    let spellings = [
        r#"{"factors":[0.7],"operator":"add8","ga_seed":5}"#,
        r#"{"ga_seed":5,"operator":"add8","factors":[0.70]}"#,
    ];
    let responses: Vec<(u16, String)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..8)
            .map(|k| {
                let addr = h.addr.as_str();
                let body = spellings[k % 2];
                s.spawn(move || {
                    let r = http_call(addr, "POST", "/jobs", Some(body)).unwrap();
                    let id = r
                        .json()
                        .unwrap()
                        .get("id")
                        .and_then(Json::as_str)
                        .unwrap()
                        .to_string();
                    (r.status, id)
                })
            })
            .collect();
        handles.into_iter().map(|t| t.join().unwrap()).collect()
    });

    let created = responses.iter().filter(|(s, _)| *s == 201).count();
    let shared = responses.iter().filter(|(s, _)| *s == 200).count();
    assert_eq!(created, 1, "exactly one creator: {responses:?}");
    assert_eq!(shared, 7, "everyone else shares");
    let id = responses[0].1.clone();
    assert!(responses.iter().all(|(_, i)| *i == id), "one shared id");

    // One spooled job, executed once.
    h.wait_done(&id);
    assert_eq!(h.queue.done_ids().unwrap(), vec![id.clone()]);
    let log = std::fs::read_to_string(h.queue.dir().join(LOG_FILE)).unwrap();
    let claims = log
        .lines()
        .map(|l| Json::parse(l).unwrap())
        .filter(|e| {
            e.get("event").and_then(Json::as_str) == Some("claim")
                && e.get("id").and_then(Json::as_str) == Some(id.as_str())
        })
        .count();
    assert_eq!(claims, 1, "deduped job claimed exactly once");

    // Every waiter reads the same result bytes.
    let a = http_call(&h.addr, "GET", &format!("/jobs/{id}/result"), None).unwrap();
    let b = http_call(&h.addr, "GET", &format!("/jobs/{id}/result"), None).unwrap();
    assert_eq!(a.status, 200);
    assert_eq!(a.body, b.body);

    h.stop();
}

#[test]
fn protocol_rejections_never_spool() {
    let h = Harness::start(HttpOptions {
        workers: 0,
        max_body_bytes: 256,
        ..Default::default()
    });
    let cases: Vec<(String, &str)> = vec![
        ("{not json".into(), "malformed JSON"),
        (r#"{"factrs":[0.5]}"#.into(), "unknown key"),
        (r#"{"factors":[0.5],"ga":{"popsize":4}}"#.into(), "unknown nested key"),
        (r#"{"factors":[1.5]}"#.into(), "factor out of range"),
        (r#"{"factors":[]}"#.into(), "no factors"),
        (r#"{"id":"mine","factors":[0.5]}"#.into(), "client-supplied id"),
        (
            // Oversized: a valid spec bloated past max_body_bytes.
            format!(r#"{{"factors":[0.5],"ga_seed":1{}}}"#, " ".repeat(300)),
            "oversized body",
        ),
    ];
    for (body, what) in &cases {
        let r = http_call(&h.addr, "POST", "/jobs", Some(body)).unwrap();
        assert_eq!(r.status, 400, "{what}: {}", r.body);
    }
    let counts = h.queue.counts().unwrap();
    assert_eq!(counts.pending, 0, "no rejected body reached the spool");
    assert_eq!(counts.running + counts.done + counts.failed, 0);

    let m = http_call(&h.addr, "GET", "/metrics", None).unwrap().json().unwrap();
    assert_eq!(
        m.get("http").and_then(|x| x.get("bad_requests")).and_then(Json::as_u64),
        Some(cases.len() as u64)
    );

    h.stop();
}

#[test]
fn backpressure_rejects_without_touching_the_queue() {
    let h = Harness::start(HttpOptions {
        workers: 0, // nothing drains: pending depth is fully controlled
        high_water: 2,
        retry_after_secs: 3,
        ..Default::default()
    });
    let specs = [
        r#"{"factors":[0.2],"ga_seed":1}"#,
        r#"{"factors":[0.4],"ga_seed":2}"#,
        r#"{"factors":[0.6],"ga_seed":3}"#,
    ];
    assert_eq!(http_call(&h.addr, "POST", "/jobs", Some(specs[0])).unwrap().status, 201);
    assert_eq!(http_call(&h.addr, "POST", "/jobs", Some(specs[1])).unwrap().status, 201);

    // At the high-water mark: new work bounces with the retry hint...
    let rejected = http_call(&h.addr, "POST", "/jobs", Some(specs[2])).unwrap();
    assert_eq!(rejected.status, 429);
    assert_eq!(rejected.header("retry-after"), Some("3"));
    assert_eq!(
        rejected.json().unwrap().get("retry_after_secs").and_then(Json::as_u64),
        Some(3)
    );
    // ...repeatably (the rejected spec was not spooled on the way out).
    assert_eq!(http_call(&h.addr, "POST", "/jobs", Some(specs[2])).unwrap().status, 429);
    assert_eq!(h.queue.counts().unwrap().pending, 2, "queue untouched by 429s");

    // Duplicates of spooled jobs are still served under full load.
    let dup = http_call(&h.addr, "POST", "/jobs", Some(specs[0])).unwrap();
    assert_eq!(dup.status, 200);
    assert_eq!(
        dup.json().unwrap().get("state").and_then(Json::as_str),
        Some("pending")
    );
    assert_eq!(h.queue.counts().unwrap().pending, 2);

    h.stop();
}

#[test]
fn prometheus_exposition_counts_a_known_workload_exactly() {
    let h = Harness::start(HttpOptions { workers: 0, ..Default::default() });
    for _ in 0..3 {
        assert_eq!(http_call(&h.addr, "GET", "/healthz", None).unwrap().status, 200);
    }
    // Route latencies are recorded before the response bytes go out, so a
    // client that saw its three responses scrapes exactly three.
    let scrape =
        http_call(&h.addr, "GET", "/metrics?format=prometheus", None).unwrap();
    assert_eq!(scrape.status, 200);
    let ct = scrape.header("content-type").unwrap_or("");
    assert!(ct.starts_with("text/plain"), "content type `{ct}`");
    for needle in [
        "# TYPE http_request_seconds histogram",
        r#"http_request_seconds_count{route="healthz"} 3"#,
        r#"http_request_seconds_bucket{route="healthz",le="+Inf"} 3"#,
        r#"http_request_seconds_count{route="jobs_submit"} 0"#,
        r#"queue_jobs{state="pending"} 0"#,
        "log_dropped_total 0",
        "# TYPE job_execute_seconds histogram",
        "# TYPE uptime_seconds gauge",
    ] {
        assert!(scrape.body.contains(needle), "missing `{needle}`:\n{}", scrape.body);
    }
    // The default stays JSON (existing dashboards), with the new latency
    // and observability sections alongside the old keys.
    let json = http_call(&h.addr, "GET", "/metrics", None).unwrap().json().unwrap();
    let healthz = json
        .get("latency")
        .and_then(|l| l.get("http"))
        .and_then(|routes| routes.get("healthz"));
    assert_eq!(healthz.and_then(|s| s.get("count")).and_then(Json::as_u64), Some(3));
    assert_eq!(
        json.get("obs").and_then(|o| o.get("log_dropped")).and_then(Json::as_u64),
        Some(0)
    );
    h.stop();
}

#[test]
fn timeline_records_the_full_lifecycle_of_an_executed_job() {
    let h = Harness::start(HttpOptions { workers: 2, ..Default::default() });
    let spec = r#"{"factors":[0.3],"operator":"add8","ga_seed":11}"#;
    let created = http_call(&h.addr, "POST", "/jobs", Some(spec)).unwrap();
    assert_eq!(created.status, 201, "{}", created.body);
    let id = created
        .json()
        .unwrap()
        .get("id")
        .and_then(Json::as_str)
        .unwrap()
        .to_string();
    h.wait_done(&id);

    let tl = http_call(&h.addr, "GET", &format!("/jobs/{id}/timeline"), None).unwrap();
    assert_eq!(tl.status, 200, "{}", tl.body);
    let doc = tl.json().unwrap();
    assert_eq!(doc.get("id").and_then(Json::as_str), Some(id.as_str()));
    assert_eq!(doc.get("state").and_then(Json::as_str), Some("done"));
    let events: Vec<&str> = doc
        .get("events")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|e| e.get("event").and_then(Json::as_str).unwrap())
        .collect();
    assert_eq!(events, ["submit", "claim", "start", "done"]);
    assert!(doc.get("queue_wait_ms").and_then(Json::as_f64).is_some());
    assert!(doc.get("execute_ms").and_then(Json::as_f64).is_some_and(|v| v >= 0.0));

    // The executed job shows up in the Prometheus job-lifecycle families.
    let scrape =
        http_call(&h.addr, "GET", "/metrics?format=prometheus", None).unwrap();
    assert!(scrape.body.contains("job_execute_seconds_count 1"), "{}", scrape.body);
    assert!(scrape.body.contains("job_queue_wait_seconds_count 1"), "{}", scrape.body);

    // Unknown ids 404 without a timeline file materializing.
    let missing = http_call(&h.addr, "GET", "/jobs/nope/timeline", None).unwrap();
    assert_eq!(missing.status, 404);
    h.stop();
}
