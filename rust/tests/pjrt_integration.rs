//! PJRT integration: the AOT-compiled Pallas kernels and MLPs, loaded and
//! executed from rust, must agree with the native substrate.
//!
//! These tests exercise the full three-layer contract:
//!   L1/L2 (python, build time)  →  HLO text  →  L3 (this crate, PJRT).
//! The whole suite compiles out without `--features pjrt`, and skips
//! gracefully (via the `charac::Backend` capability probe) when `make
//! artifacts` has not run — absence of a backend is never a test failure.

#![cfg(feature = "pjrt")]

use repro::charac::{characterize, Backend, InputSet};
use repro::operator::{AxoConfig, Operator};
use repro::runtime::{AxoEvalExec, MlpExec, Runtime};
use repro::surrogate::{PjrtSurrogate, Surrogate};
use repro::util::rng::Rng;
use std::path::PathBuf;

fn artifacts() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn runtime() -> Option<Runtime> {
    if !Backend::pjrt_ready(&artifacts()) {
        eprintln!(
            "skipping PJRT tests: artifacts missing (`make artifacts`) or only the \
             stub xla is linked"
        );
        return None;
    }
    Some(Runtime::cpu(&artifacts()).unwrap())
}

fn pjrt_matches_native(rt: &Runtime, op: Operator, configs: &[AxoConfig]) {
    let inputs = InputSet::for_operator(op, &artifacts()).unwrap();
    let exec = AxoEvalExec::new(rt, op, &inputs).unwrap();
    let pjrt = characterize(op, configs, &inputs, &Backend::Evaluator(&exec)).unwrap();
    let native = characterize(op, configs, &inputs, &Backend::Native).unwrap();
    for i in 0..configs.len() {
        let a = pjrt.behav[i].to_array();
        let b = native.behav[i].to_array();
        for k in 0..4 {
            let denom = b[k].abs().max(1.0);
            assert!(
                ((a[k] - b[k]).abs() / denom) < 1e-4, // kernel runs in f32
                "{op} cfg {} metric {k}: pjrt {} native {}",
                configs[i],
                a[k],
                b[k]
            );
        }
    }
}

#[test]
fn axo_eval_add4_matches_native_exhaustive() {
    if let Some(rt) = runtime() {
        let cfgs: Vec<AxoConfig> = AxoConfig::enumerate(4).collect();
        pjrt_matches_native(&rt, Operator::ADD4, &cfgs);
    }
}

#[test]
fn axo_eval_add8_matches_native_sampled() {
    if let Some(rt) = runtime() {
        let mut rng = Rng::seed_from_u64(11);
        let cfgs = AxoConfig::sample_unique(8, 32, &mut rng);
        pjrt_matches_native(&rt, Operator::ADD8, &cfgs);
    }
}

#[test]
fn axo_eval_add12_matches_native_on_shared_inputs() {
    if let Some(rt) = runtime() {
        let mut rng = Rng::seed_from_u64(12);
        let cfgs = AxoConfig::sample_unique(12, 16, &mut rng);
        pjrt_matches_native(&rt, Operator::ADD12, &cfgs);
    }
}

#[test]
fn axo_eval_mul4_matches_native_sampled() {
    if let Some(rt) = runtime() {
        let mut rng = Rng::seed_from_u64(13);
        let cfgs = AxoConfig::sample_unique(10, 48, &mut rng);
        pjrt_matches_native(&rt, Operator::MUL4, &cfgs);
    }
}

#[test]
fn axo_eval_mul8_matches_native_sampled() {
    if let Some(rt) = runtime() {
        let mut rng = Rng::seed_from_u64(14);
        let cfgs = AxoConfig::sample_unique(36, 16, &mut rng);
        pjrt_matches_native(&rt, Operator::MUL8, &cfgs);
    }
}

#[test]
fn axo_eval_batch_padding_roundtrip() {
    // Non-multiple-of-batch config counts exercise the padding path.
    if let Some(rt) = runtime() {
        let inputs = InputSet::exhaustive(Operator::MUL4);
        let exec = AxoEvalExec::new(&rt, Operator::MUL4, &inputs).unwrap();
        for n in [1usize, 3, 63, 65, 127] {
            let mut rng = Rng::seed_from_u64(n as u64);
            let cfgs = AxoConfig::sample_unique(10, n, &mut rng);
            let out = exec.eval_configs(&cfgs).unwrap();
            assert_eq!(out.len(), n);
        }
    }
}

#[test]
fn estimator_mlp_predictions_are_sane() {
    if let Some(rt) = runtime() {
        let exec = MlpExec::new(&rt, "estimator_mul8").unwrap();
        let sur = PjrtSurrogate::new(exec).unwrap();
        let mut rng = Rng::seed_from_u64(15);
        let cfgs = AxoConfig::sample_unique(36, 300, &mut rng);
        let preds = sur.predict(&cfgs).unwrap();
        assert_eq!(preds.len(), 300);
        // Sanity: non-negative, finite, and correlated with the real error —
        // fewer retained LUTs should predict more error on average.
        assert!(preds.iter().all(|p| p[0].is_finite() && p[1].is_finite()));
        assert!(preds.iter().all(|p| p[0] >= 0.0 && p[1] >= 0.0));
        let mut few = Vec::new();
        let mut many = Vec::new();
        for (c, p) in cfgs.iter().zip(&preds) {
            if c.count_kept() <= 12 {
                few.push(p[0]);
            } else if c.count_kept() >= 24 {
                many.push(p[0]);
            }
        }
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
        assert!(
            mean(&few) > mean(&many),
            "estimator should predict higher error for sparser configs: {} vs {}",
            mean(&few),
            mean(&many)
        );
    }
}

#[test]
fn estimator_mlp_quality_against_real_characterization() {
    if let Some(rt) = runtime() {
        let exec = MlpExec::new(&rt, "estimator_mul8").unwrap();
        let sur = PjrtSurrogate::new(exec).unwrap();
        let mut rng = Rng::seed_from_u64(16);
        let cfgs = AxoConfig::sample_unique(36, 128, &mut rng);
        let inputs = InputSet::exhaustive(Operator::MUL8);
        let ds = characterize(Operator::MUL8, &cfgs, &inputs, &Backend::Native).unwrap();
        let preds = sur.predict(&cfgs).unwrap();
        // Rank correlation between predicted and real PDPLUT should be
        // strongly positive (the estimator steers the GA, it need not be
        // perfect).
        let real: Vec<f64> = ds.ppa.iter().map(|p| p.pdplut).collect();
        let pred: Vec<f64> = preds.iter().map(|p| p[1]).collect();
        let rho = repro::stats::correlation::spearman(&real, &pred);
        assert!(rho > 0.7, "pdplut rank correlation too weak: {rho}");
    }
}

#[test]
fn conss_mlp_generates_valid_bit_probabilities() {
    if let Some(rt) = runtime() {
        let exec = MlpExec::new(&rt, "conss_mul4to8").unwrap();
        assert_eq!(exec.out_features, 36);
        let noise_bits = 4usize;
        let mut rows = Vec::new();
        for v in 1u64..=64 {
            let cfg = AxoConfig::new(v % 1023 + 1, 10).unwrap();
            let mut r: Vec<f32> = cfg.to_bits_f32();
            for k in 0..noise_bits {
                r.push(((v >> k) & 1) as f32);
            }
            rows.extend(r);
        }
        let out = exec.forward(&rows).unwrap();
        assert_eq!(out.len(), 64 * 36);
        assert!(out.iter().all(|&p| (0.0..=1.0).contains(&p)), "sigmoid outputs");
    }
}

#[test]
fn missing_artifact_fails_cleanly() {
    if let Some(rt) = runtime() {
        let err = rt.load("no_such_executable");
        assert!(err.is_err());
    }
}
