//! Engine-layer integration tests: concurrent multi-factor DSE is
//! bit-identical to sequential runs (determinism under cross-search
//! batching), the dataset cache characterizes each dataset exactly once,
//! and one shared `EstimatorService` serves every search.

use repro::coordinator::{BatchOptions, EstimatorService};
use repro::dse::{Constraints, GaOptions, NsgaRunner, Objectives};
use repro::engine::{DseJob, EngineContext};
use repro::error::Result;
use repro::expcfg::{ConssConfig, ExperimentConfig, GaConfig, SurrogateConfig};
use repro::operator::{AxoConfig, Operator};
use repro::surrogate::{EstimatorBackend, Surrogate};
use std::sync::Arc;
use std::time::Duration;

/// Small add4 → add8 configuration: exhaustive spaces, exact-table
/// surrogate (total over add8, so every GA query hits), tiny GA.
fn tiny_cfg() -> ExperimentConfig {
    ExperimentConfig {
        operator: "add8".into(),
        surrogate: SurrogateConfig { backend: EstimatorBackend::Table, gbt_stages: None },
        conss: ConssConfig { forest_trees: Some(4), noise_bits: 2, ..Default::default() },
        ga: GaConfig { pop_size: 12, generations: 6, ..Default::default() },
        ..Default::default()
    }
}

#[test]
fn concurrent_run_many_matches_sequential_bit_for_bit() {
    let jobs = vec![DseJob::new(0.4), DseJob::new(0.7), DseJob::new(1.0)];

    // Sequential ground truth: fresh context, one job at a time.
    let seq_ctx = EngineContext::new(tiny_cfg());
    let seq_prep = seq_ctx.prepare_dse().unwrap();
    let sequential: Vec<_> =
        jobs.iter().map(|j| seq_prep.run_job(j).unwrap()).collect();

    // Concurrent: fresh context, all jobs through run_many, every search
    // sharing the one batching estimator service.
    let par_ctx = EngineContext::new(tiny_cfg());
    let par_prep = par_ctx.prepare_dse().unwrap();
    let concurrent = par_prep.run_many(&jobs).unwrap();

    assert_eq!(sequential.len(), concurrent.len());
    for (a, b) in sequential.iter().zip(&concurrent) {
        assert_eq!(a.factor, b.factor);
        assert_eq!(a.hv_train.to_bits(), b.hv_train.to_bits());
        assert_eq!(a.hv_conss.to_bits(), b.hv_conss.to_bits());
        assert_eq!(a.conss_pool.configs, b.conss_pool.configs);
        assert_eq!(a.ga.hv_history, b.ga.hv_history);
        assert_eq!(a.ga.front_points, b.ga.front_points);
        assert_eq!(a.conss_ga.hv_history, b.conss_ga.hv_history);
        assert_eq!(a.conss_ga.front_points, b.conss_ga.front_points);
        assert_eq!(a.conss_ga.evaluations, b.conss_ga.evaluations);
    }

    // The shared service saw every search's traffic, error-free.
    let snap = par_prep.service.metrics().snapshot();
    assert!(snap.requests >= jobs.len() as u64);
    assert_eq!(snap.errors, 0);
}

#[test]
fn dataset_cache_characterizes_each_dataset_exactly_once() {
    let ctx = EngineContext::new(tiny_cfg());
    let a = ctx.dataset(Operator::ADD4).unwrap();
    let b = ctx.dataset(Operator::ADD4).unwrap();
    assert!(Arc::ptr_eq(&a, &b), "cache must hand out the same dataset");
    let s = ctx.cache_stats();
    assert_eq!(s.misses, 1);
    assert_eq!(s.hits, 1);
    assert_eq!(s.entries, 1);

    // prepare_dse pulls L, H, and the estimator's training set — all
    // cache traffic, only one new characterization (add8).
    ctx.prepare_dse().unwrap();
    let s = ctx.cache_stats();
    assert_eq!(s.entries, 2, "L/H characterized exactly once per process");
    assert_eq!(s.misses, 2);
    assert!(s.hits >= 3);
}

#[test]
fn engine_estimator_is_shared_across_callers() {
    let ctx = EngineContext::new(tiny_cfg());
    let a = ctx.estimator().unwrap();
    let b = ctx.estimator().unwrap();
    assert!(std::ptr::eq(a.metrics(), b.metrics()), "one service, two handles");
    a.predict(vec![AxoConfig::new(9, 8).unwrap()]).unwrap();
    assert_eq!(b.metrics().snapshot().requests, 1);
}

/// Deterministic toy surrogate with a tunable delay; slow enough that
/// concurrent searches pile requests behind the batcher.
struct SlowToy {
    delay: Duration,
}

impl Surrogate for SlowToy {
    fn predict(&self, configs: &[AxoConfig]) -> Result<Vec<Objectives>> {
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        Ok(configs
            .iter()
            .map(|c| {
                let ones = c.count_kept() as f64 / c.len() as f64;
                [1.0 - ones, ones * ones]
            })
            .collect())
    }
}

#[test]
fn two_searches_sharing_one_service_match_their_sequential_runs() {
    let constraints = Constraints::new(1.0, 1.0).unwrap();
    let mk_runner = |seed| {
        NsgaRunner::new(
            GaOptions { pop_size: 16, generations: 6, seed, ..Default::default() },
            constraints,
        )
    };

    // Sequential ground truth: plain closure fitness, no service.
    let direct =
        |cfgs: &[AxoConfig]| SlowToy { delay: Duration::ZERO }.predict(cfgs);
    let seq_a = mk_runner(11).run(12, &direct, &[]).unwrap();
    let seq_b = mk_runner(22).run(12, &direct, &[]).unwrap();

    // Concurrent: both searches share one batching service over a slow
    // backend, so their per-generation requests coalesce into joint
    // batches.
    // max_batch = both searches' population: the batch flushes the moment
    // the two per-generation requests are both in (no deadline spin), and
    // the generous max_wait keeps them paired even on loaded CI runners.
    let svc = EstimatorService::spawn(
        Arc::new(SlowToy { delay: Duration::from_millis(2) }),
        BatchOptions { max_batch: 32, max_wait: Duration::from_millis(150) },
    );
    let (par_a, par_b) = std::thread::scope(|s| {
        let sa = svc.clone();
        let ha = s.spawn(move || mk_runner(11).run(12, &sa, &[]).unwrap());
        let sb = svc.clone();
        let hb = s.spawn(move || mk_runner(22).run(12, &sb, &[]).unwrap());
        (ha.join().unwrap(), hb.join().unwrap())
    });

    // Batching cannot change any objective value: hypervolume traces and
    // fronts are bit-identical to the sequential runs.
    assert_eq!(seq_a.hv_history, par_a.hv_history);
    assert_eq!(seq_b.hv_history, par_b.hv_history);
    assert_eq!(seq_a.front_points, par_a.front_points);
    assert_eq!(seq_b.front_points, par_b.front_points);

    // Cross-search coalescing actually happened: fewer backend batches
    // than requests means at least one batch mixed both searches.
    let snap = svc.metrics().snapshot();
    assert!(snap.requests > 0);
    assert!(
        snap.batches < snap.requests,
        "no cross-search coalescing: {} batches for {} requests",
        snap.batches,
        snap.requests
    );
}
