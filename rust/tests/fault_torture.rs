//! Crash-torture suite: prove the spool and the dataset store converge to
//! a consistent state when processes are killed at armed failpoints.
//!
//! Structure: each scenario re-execs *this* test binary as a worker
//! subprocess (`--exact worker_*`), pointing it at a shared spool via
//! `TORTURE_DIR` and arming a crash site via `REPRO_FAULTS=<site>=abort`.
//! The worker dies with SIGABRT at exactly the armed site; the parent
//! then runs the documented recovery (a clean worker performing
//! `requeue_stale` + drain) and asserts the invariants the fault model
//! promises:
//!
//! * every submitted job ends in **exactly one** terminal state
//!   (`done/` or `failed/`), never lost, never duplicated;
//! * recorded results are bit-identical to an undisturbed reference run
//!   (jobs are deterministic, so re-execution after a crash replays the
//!   same answer);
//! * `pending/` and `running/` are empty after recovery — no stranded
//!   specs, no sidecar debris;
//! * the dataset store heals torn and half-published entries, and a lock
//!   left by a dead holder is taken over.
//!
//! The worker `#[test]`s are no-ops without `TORTURE_DIR`, so a plain
//! `cargo test` run of this binary passes them trivially. Everything is
//! linux-only: the recovery sweep's PID liveness probe, SIGABRT exit
//! decoding, and `kill -TERM` all need it.

#![cfg(target_os = "linux")]

use repro::charac::{BehavMetrics, Dataset};
use repro::engine::{
    key_slug, CharacSubstrate, DatasetKey, DatasetStore, EngineContext, SampleSpec,
    VerifyStatus,
};
use repro::expcfg::{ConssConfig, ExperimentConfig, GaConfig, SurrogateConfig};
use repro::operator::{AxoConfig, Operator};
use repro::serve::{
    http_call, HttpOptions, HttpServer, JobQueue, JobResult, JobRunner, JobSpec,
    ServeOptions, LOG_FILE, MAX_REVIVALS,
};
use repro::surrogate::EstimatorBackend;
use repro::synth::PpaMetrics;
use repro::util::json::Json;
use repro::util::tempdir::TempDir;
use std::os::unix::process::ExitStatusExt as _;
use std::path::{Path, PathBuf};
use std::process::Command;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// The failpoint registry and the `TORTURE_DIR`/`REPRO_FAULTS` env are
/// process-global; every test in this file serializes on this lock.
static TORTURE_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    TORTURE_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

/// Fast deterministic serve configuration (the `serve_jobs` add8 idiom,
/// trimmed further — torture rounds re-execute jobs several times).
fn torture_cfg() -> ExperimentConfig {
    ExperimentConfig {
        operator: "add8".into(),
        surrogate: SurrogateConfig { backend: EstimatorBackend::Table, gbt_stages: None },
        conss: ConssConfig { forest_trees: Some(4), noise_bits: 2, ..Default::default() },
        ga: GaConfig { pop_size: 8, generations: 2, ..Default::default() },
        ..Default::default()
    }
}

/// Worker-side gate: `None` in a plain test run; in a torture subprocess,
/// arms `REPRO_FAULTS` and hands back the spool root.
fn worker_dir() -> Option<PathBuf> {
    let dir = std::env::var_os("TORTURE_DIR")?;
    repro::fault::apply_env().expect("REPRO_FAULTS spec must parse");
    Some(PathBuf::from(dir))
}

/// Re-exec this test binary to run exactly one worker test against `dir`
/// with `faults` armed. `REPRO_ORPHAN_GRACE_MS=0` lets recovery workers
/// reap sidecar-less claims immediately instead of waiting out the
/// production grace window.
fn worker_command(test: &str, dir: &Path, faults: &str) -> Command {
    let mut cmd = Command::new(std::env::current_exe().unwrap());
    cmd.arg(test)
        .arg("--exact")
        .arg("--test-threads=1")
        .arg("--nocapture")
        .env("TORTURE_DIR", dir)
        .env("REPRO_FAULTS", faults)
        .env("REPRO_ORPHAN_GRACE_MS", "0");
    cmd
}

fn run_worker(test: &str, dir: &Path, faults: &str) -> std::process::Output {
    worker_command(test, dir, faults).output().expect("spawn torture worker")
}

/// The worker died of SIGABRT — i.e. the armed `abort` site fired, rather
/// than the test failing for some unrelated reason.
fn assert_aborted(out: &std::process::Output, ctx: &str) {
    assert_eq!(
        out.status.signal(),
        Some(6),
        "{ctx}: expected SIGABRT, got {:?}\nstdout:\n{}\nstderr:\n{}",
        out.status,
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr),
    );
}

/// The worker ran its single test to completion.
fn assert_clean(out: &std::process::Output, ctx: &str) {
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        out.status.success() && stdout.contains("1 passed"),
        "{ctx}: expected a clean 1-test pass, got {:?}\nstdout:\n{stdout}\nstderr:\n{}",
        out.status,
        String::from_utf8_lossy(&out.stderr),
    );
}

// ---------------------------------------------------------------------------
// Worker bodies (no-ops without TORTURE_DIR; see module docs).
// ---------------------------------------------------------------------------

/// Server-start semantics: recover the spool, then drain it to empty.
#[test]
fn worker_sweep_and_drain() {
    let Some(dir) = worker_dir() else { return };
    let queue = JobQueue::open(dir.join("jobs")).unwrap();
    queue.requeue_stale().unwrap();
    let ctx = EngineContext::new(torture_cfg());
    let runner = JobRunner::new(
        &ctx,
        &queue,
        ServeOptions { workers: 1, ..Default::default() },
    )
    .unwrap();
    runner.run().unwrap();
}

/// A lone submitter (killed between its durable temp write and the
/// publishing hard link when `queue.submit.link=abort` is armed).
#[test]
fn worker_submit_one() {
    let Some(dir) = worker_dir() else { return };
    let queue = JobQueue::open(dir.join("jobs")).unwrap();
    queue.submit(&JobSpec::new("s0", vec![0.5])).unwrap();
}

/// A lone dataset-store writer (killed between payload write and rename
/// when `store.payload.rename=abort` is armed).
#[test]
fn worker_store_save() {
    let Some(dir) = worker_dir() else { return };
    let store = DatasetStore::open(dir.join("datasets"));
    store.save(&store_key(), &tiny_dataset(), 0xfeed).unwrap();
}

/// Watch-mode server: recover, then poll `pending/` until a drain signal
/// (SIGTERM from the parent) retires the workers.
#[test]
fn worker_watch_until_drained() {
    let Some(dir) = worker_dir() else { return };
    repro::serve::signal::install();
    let queue = JobQueue::open(dir.join("jobs")).unwrap();
    queue.requeue_stale().unwrap();
    let ctx = EngineContext::new(torture_cfg());
    let runner = JobRunner::new(
        &ctx,
        &queue,
        ServeOptions {
            workers: 2,
            drain: false,
            poll: Duration::from_millis(25),
            ..Default::default()
        },
    )
    .unwrap();
    runner.run().unwrap();
}

// ---------------------------------------------------------------------------
// Queue crash consistency.
// ---------------------------------------------------------------------------

/// Every job ends in exactly one terminal state with the expected bytes,
/// and the spool carries no debris.
fn assert_converged(queue: &JobQueue, want: &[(&str, &JobResult)], ctx: &str) {
    let ids: Vec<String> = want.iter().map(|(id, _)| id.to_string()).collect();
    assert_eq!(queue.done_ids().unwrap(), ids, "{ctx}: every job done exactly once");
    assert_eq!(queue.failed_ids().unwrap(), Vec::<String>::new(), "{ctx}");
    let counts = queue.counts().unwrap();
    assert_eq!((counts.pending, counts.running), (0, 0), "{ctx}: spool drained");
    // running/ is *literally* empty: no PID sidecars, no revival ledgers.
    let leftovers: Vec<_> = std::fs::read_dir(queue.dir().join("running"))
        .unwrap()
        .map(|e| e.unwrap().file_name())
        .collect();
    assert!(leftovers.is_empty(), "{ctx}: running/ debris: {leftovers:?}");
    for &(id, reference) in want {
        let got = queue.result(id).unwrap();
        assert_eq!(got.operator, reference.operator, "{ctx}: {id}");
        // wall_ms is the one legitimately nondeterministic field; the
        // science payload must be bit-identical to the reference run.
        assert_eq!(got.factors, reference.factors, "{ctx}: {id} result drifted");
    }
}

#[test]
fn abort_at_each_queue_site_converges_with_bit_identical_results() {
    let _g = lock();
    // Reference: the same two jobs through an undisturbed in-process drain.
    let ref_dir = TempDir::new().unwrap();
    let ref_queue = JobQueue::open(ref_dir.path().join("jobs")).unwrap();
    ref_queue.submit(&JobSpec::new("t0", vec![0.5])).unwrap();
    ref_queue.submit(&JobSpec::new("t1", vec![0.8])).unwrap();
    let ctx = EngineContext::new(torture_cfg());
    JobRunner::new(&ctx, &ref_queue, ServeOptions { workers: 1, ..Default::default() })
        .unwrap()
        .run()
        .unwrap();
    let want_t0 = ref_queue.result("t0").unwrap();
    let want_t1 = ref_queue.result("t1").unwrap();

    for site in [
        "queue.claim.rename",   // dies before any state moves
        "queue.claim.pid",      // claim renamed, PID sidecar never written
        "queue.complete.write", // executed, result temp never written
        "queue.complete.rename", // result temp durable, never published
        "queue.complete.cleanup", // published, stranded in done/ AND running/
    ] {
        let dir = TempDir::new().unwrap();
        let queue = JobQueue::open(dir.path().join("jobs")).unwrap();
        queue.submit(&JobSpec::new("t0", vec![0.5])).unwrap();
        queue.submit(&JobSpec::new("t1", vec![0.8])).unwrap();

        let killed =
            run_worker("worker_sweep_and_drain", dir.path(), &format!("{site}=abort"));
        assert_aborted(&killed, site);

        let recovered = run_worker("worker_sweep_and_drain", dir.path(), "");
        assert_clean(&recovered, site);
        assert_converged(&queue, &[("t0", &want_t0), ("t1", &want_t1)], site);
    }
}

#[test]
fn abort_during_revival_still_converges_without_losing_the_job() {
    let _g = lock();
    let dir = TempDir::new().unwrap();
    let queue = JobQueue::open(dir.path().join("jobs")).unwrap();
    queue.submit(&JobSpec::new("r0", vec![0.6])).unwrap();

    // Kill 1: claimer dies mid-claim (no PID sidecar left behind).
    let killed = run_worker("worker_sweep_and_drain", dir.path(), "queue.claim.pid=abort");
    assert_aborted(&killed, "claimer");

    // Kill 2: the *sweeper* dies between the revival rename and the
    // ledger write — the job is back in pending/ but the revival was
    // never tallied (the documented untallied-revival window).
    let killed =
        run_worker("worker_sweep_and_drain", dir.path(), "queue.revive.ledger=abort");
    assert_aborted(&killed, "sweeper");
    assert_eq!(queue.counts().unwrap().pending, 1, "revived before the abort");
    assert_eq!(queue.revivals_of("r0"), 0, "ledger write never happened");

    let recovered = run_worker("worker_sweep_and_drain", dir.path(), "");
    assert_clean(&recovered, "recovery");
    assert_eq!(queue.done_ids().unwrap(), vec!["r0"]);
    let counts = queue.counts().unwrap();
    assert_eq!((counts.pending, counts.running, counts.failed), (0, 0, 0));
}

#[test]
fn crash_looping_job_is_quarantined_after_real_kills() {
    let _g = lock();
    let dir = TempDir::new().unwrap();
    let queue = JobQueue::open(dir.path().join("jobs")).unwrap();
    queue.submit(&JobSpec::new("loopy", vec![0.7])).unwrap();

    // The job "kills its claimer" every time: each round's worker sweeps
    // (reviving the orphan), claims, executes, and dies at the result
    // write. Rounds 1..=MAX_REVIVALS each burn one revival.
    for round in 0..=MAX_REVIVALS {
        let killed =
            run_worker("worker_sweep_and_drain", dir.path(), "queue.complete.write=abort");
        assert_aborted(&killed, &format!("round {round}"));
        assert_eq!(queue.revivals_of("loopy"), round, "ledger after round {round}");
    }

    // Budget burned: the recovery sweep quarantines instead of reviving.
    let recovered = run_worker("worker_sweep_and_drain", dir.path(), "");
    assert_clean(&recovered, "quarantine sweep");
    assert_eq!(queue.failed_ids().unwrap(), vec!["loopy"]);
    assert!(queue.done_ids().unwrap().is_empty());
    let err = queue.error("loopy").unwrap();
    assert!(err.contains("crash loop"), "recorded error: {err}");
    let counts = queue.counts().unwrap();
    assert_eq!((counts.pending, counts.running), (0, 0));
    let leftovers: Vec<_> = std::fs::read_dir(queue.dir().join("running"))
        .unwrap()
        .map(|e| e.unwrap().file_name())
        .collect();
    assert!(leftovers.is_empty(), "sidecars cleaned with the quarantine: {leftovers:?}");
}

#[test]
fn submitter_killed_before_link_leaves_only_a_sweepable_temp() {
    let _g = lock();
    let dir = TempDir::new().unwrap();
    let queue = JobQueue::open(dir.path().join("jobs")).unwrap();

    let killed = run_worker("worker_submit_one", dir.path(), "queue.submit.link=abort");
    assert_aborted(&killed, "submitter");

    // The orphaned temp is there, but no spec was published.
    let pending: Vec<String> = std::fs::read_dir(queue.dir().join("pending"))
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .collect();
    assert_eq!(pending.len(), 1, "exactly the temp: {pending:?}");
    assert!(pending[0].starts_with(".s0.") && pending[0].ends_with(".tmp"));
    assert_eq!(queue.counts().unwrap().pending, 0, "temp is not a job");

    // The sweep proves the embedded submitter PID dead and reclaims it.
    let report = queue.requeue_stale().unwrap();
    assert_eq!(report.swept_temps, pending);
    let leftovers: Vec<_> = std::fs::read_dir(queue.dir().join("pending"))
        .unwrap()
        .map(|e| e.unwrap().file_name())
        .collect();
    assert!(leftovers.is_empty(), "pending/ clean after the sweep: {leftovers:?}");

    // The id was never published, so a fresh submission just works.
    queue.submit(&JobSpec::new("s0", vec![0.5])).unwrap();
}

// ---------------------------------------------------------------------------
// Dataset-store crash consistency.
// ---------------------------------------------------------------------------

fn tiny_dataset() -> Dataset {
    let cfgs = vec![AxoConfig::accurate(4), AxoConfig::new(0b0111, 4).unwrap()];
    let behav = vec![
        BehavMetrics::ZERO,
        BehavMetrics {
            avg_abs_err: 1.0,
            avg_abs_rel_err: 0.1,
            max_abs_err: 8.0,
            err_prob: 0.5,
        },
    ];
    let ppa = vec![
        PpaMetrics { luts: 4.0, cpd_ns: 0.75, power_mw: 0.8, pdp: 0.6, pdplut: 2.4 },
        PpaMetrics { luts: 3.0, cpd_ns: 0.7, power_mw: 0.7, pdp: 0.49, pdplut: 1.47 },
    ];
    Dataset::new(Operator::ADD4, cfgs, behav, ppa).unwrap()
}

fn store_key() -> DatasetKey {
    DatasetKey {
        op: Operator::ADD4,
        substrate: CharacSubstrate::Native,
        spec: SampleSpec::Seeded { seed: 7, n: 2 },
    }
}

#[test]
fn store_save_killed_at_rename_is_recoverable_and_stale_lock_taken_over() {
    let _g = lock();
    let dir = TempDir::new().unwrap();

    let killed = run_worker("worker_store_save", dir.path(), "store.payload.rename=abort");
    assert_aborted(&killed, "store writer");

    // The manifest was never written, so the store is observably empty —
    // but the dead writer left its payload temp AND its manifest.lock.
    let store = DatasetStore::open(dir.path().join("datasets"));
    assert!(store.verify().unwrap().is_empty(), "no entry was published");
    assert!(store.load(&store_key(), 0xfeed).unwrap().is_none());
    let lock_path = dir.path().join("datasets").join("manifest.lock");
    assert!(lock_path.exists(), "dead holder's lock file survives the crash");

    // A healing save takes the stale lock over (the holder PID provably
    // no longer runs) and publishes payload + manifest normally.
    let ds = tiny_dataset();
    store.save(&store_key(), &ds, 0xfeed).unwrap();
    assert!(!lock_path.exists(), "lock released after the save");
    assert_eq!(
        store.verify().unwrap(),
        vec![(key_slug(&store_key()), VerifyStatus::Ok)]
    );
    let loaded = store.load(&store_key(), 0xfeed).unwrap().expect("healed entry loads");
    assert_eq!(loaded.operator, Operator::ADD4);
    assert_eq!(loaded.len(), ds.len());
}

#[test]
fn torn_store_payload_is_a_miss_and_resave_heals() {
    let _g = lock();
    let dir = TempDir::new().unwrap();
    let store = DatasetStore::open(dir.path().join("datasets"));
    let ds = tiny_dataset();

    // Power-loss model: the payload write is torn (half the bytes, no
    // fsync) but *reports success*, and the manifest records the hash of
    // the full payload.
    repro::fault::arm_from_spec("store.payload.write=partial:1").unwrap();
    store.save(&store_key(), &ds, 0xfeed).unwrap();
    repro::fault::disarm_all();

    // The integrity check catches it: a miss (re-characterize), not an
    // error — and verify names the mismatch.
    assert!(store.load(&store_key(), 0xfeed).unwrap().is_none());
    assert_eq!(
        store.verify().unwrap(),
        vec![(key_slug(&store_key()), VerifyStatus::HashMismatch)]
    );

    // Re-saving overwrites the torn payload and heals the entry.
    store.save(&store_key(), &ds, 0xfeed).unwrap();
    assert_eq!(
        store.verify().unwrap(),
        vec![(key_slug(&store_key()), VerifyStatus::Ok)]
    );
    let loaded = store.load(&store_key(), 0xfeed).unwrap().expect("healed entry loads");
    assert_eq!(loaded.len(), ds.len());
}

// ---------------------------------------------------------------------------
// HTTP load-shedding and graceful drain.
// ---------------------------------------------------------------------------

#[test]
fn full_spool_disk_sheds_submissions_with_503_until_a_write_lands() {
    let _g = lock();
    let dir = TempDir::new().unwrap();
    let queue = Arc::new(JobQueue::open(dir.path().join("jobs")).unwrap());
    let ctx = Arc::new(EngineContext::new(torture_cfg()));
    // Front-end only (workers: 0): no engine work, just the admit path.
    let server = Arc::new(
        HttpServer::bind(
            ctx,
            Arc::clone(&queue),
            "127.0.0.1:0",
            HttpOptions { threads: 1, workers: 0, retry_after_secs: 7, ..Default::default() },
        )
        .unwrap(),
    );
    let addr = server.local_addr().to_string();
    let handle = {
        let server = Arc::clone(&server);
        std::thread::spawn(move || server.run().unwrap())
    };

    let spec = r#"{"factors":[0.5],"operator":"add8"}"#;
    // One ENOSPC on the spool write: the submission is shed, not crashed.
    repro::fault::arm_from_spec("queue.submit.write=enospc:1").unwrap();
    let shed = http_call(&addr, "POST", "/jobs", Some(spec)).unwrap();
    assert_eq!(shed.status, 503, "{}", shed.body);
    assert_eq!(shed.header("retry-after"), Some("7"));
    assert_eq!(
        shed.json().unwrap().get("retry_after_secs").and_then(Json::as_u64),
        Some(7)
    );
    assert_eq!(queue.counts().unwrap().pending, 0, "nothing spooled");

    // The client retries, the disk has space again: admitted normally.
    let created = http_call(&addr, "POST", "/jobs", Some(spec)).unwrap();
    assert_eq!(created.status, 201, "{}", created.body);
    assert_eq!(queue.counts().unwrap().pending, 1);

    // The shed and the armed site's hit tally are both visible in
    // metrics (two hits: one fired ENOSPC, one passed through exhausted).
    let m = http_call(&addr, "GET", "/metrics", None).unwrap().json().unwrap();
    assert_eq!(
        m.get("http").and_then(|x| x.get("shed")).and_then(Json::as_u64),
        Some(1)
    );
    assert_eq!(
        m.get("fault")
            .and_then(|f| f.get("queue.submit.write"))
            .and_then(Json::as_u64),
        Some(2)
    );
    let prom = http_call(&addr, "GET", "/metrics?format=prometheus", None).unwrap();
    assert!(prom.body.contains("http_shed_total 1"), "{}", prom.body);
    assert!(
        prom.body.contains("fault_hits_total{site=\"queue.submit.write\"} 2"),
        "{}",
        prom.body
    );
    repro::fault::disarm_all();

    server.shutdown();
    handle.join().unwrap();
}

#[test]
fn sigterm_drains_a_watch_mode_worker_cleanly() {
    let _g = lock();
    let dir = TempDir::new().unwrap();
    let queue = JobQueue::open(dir.path().join("jobs")).unwrap();
    queue.submit(&JobSpec::new("d0", vec![0.5])).unwrap();
    queue.submit(&JobSpec::new("d1", vec![0.8])).unwrap();

    let mut child = worker_command("worker_watch_until_drained", dir.path(), "")
        .stdout(std::process::Stdio::null())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawn watch worker");

    // Let it finish both jobs (it keeps polling — watch mode never exits
    // on its own), then ask it to drain.
    let deadline = Instant::now() + Duration::from_secs(180);
    while queue.done_ids().unwrap().len() < 2 {
        assert!(
            child.try_wait().unwrap().is_none(),
            "watch worker exited before the drain signal"
        );
        if Instant::now() > deadline {
            let _ = child.kill();
            panic!("watch worker never finished the jobs");
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    let term = Command::new("kill")
        .arg("-TERM")
        .arg(child.id().to_string())
        .status()
        .expect("send SIGTERM");
    assert!(term.success());

    let status = loop {
        if let Some(status) = child.try_wait().unwrap() {
            break status;
        }
        if Instant::now() > deadline {
            let _ = child.kill();
            panic!("watch worker ignored SIGTERM");
        }
        std::thread::sleep(Duration::from_millis(25));
    };
    assert!(status.success(), "drain exits 0, got {status:?}");

    // The spool is consistent and the drain was recorded.
    let counts = queue.counts().unwrap();
    assert_eq!(
        (counts.pending, counts.running, counts.done, counts.failed),
        (0, 0, 2, 0)
    );
    let log = std::fs::read_to_string(queue.dir().join(LOG_FILE)).unwrap();
    let drained = log
        .lines()
        .filter_map(|l| Json::parse(l).ok())
        .filter(|e| e.get("event").and_then(Json::as_str) == Some("drain"))
        .count();
    assert_eq!(drained, 2, "each watch worker logged its drain exit");
}
