//! Bit-identity of the two native BEHAV backends.
//!
//! The bit-sliced path (`operator/bitslice.rs`, 64 input vectors per u64
//! operation) is the default; the per-vector scalar path is its oracle.
//! "Equivalent" here means *bit-identical* `BehavMetrics` — every f64
//! compared by `to_bits`, never by tolerance — across operator kinds,
//! exhaustive and random config sets, and ragged input lengths that
//! exercise the tail-lane zero padding.

use repro::charac::behav::{
    adder_behav_with, mult_behav, mult_behav_bitslice, native_behav_with,
};
use repro::charac::{characterize_sharded_as, BehavBackend, BehavMetrics, InputSet};
use repro::operator::{multiplier, AxoConfig, Operator};
use repro::util::rng::Rng;

fn assert_bit_identical(scalar: &[BehavMetrics], bitslice: &[BehavMetrics], what: &str) {
    assert_eq!(scalar.len(), bitslice.len(), "{what}: row count");
    for (i, (s, b)) in scalar.iter().zip(bitslice).enumerate() {
        assert_eq!(
            s.to_array().map(f64::to_bits),
            b.to_array().map(f64::to_bits),
            "{what}: config row {i} ({s:?} vs {b:?})"
        );
    }
}

/// Both backends over one operator/config/input triple.
fn both(
    op: Operator,
    configs: &[AxoConfig],
    inputs: &InputSet,
) -> (Vec<BehavMetrics>, Vec<BehavMetrics>) {
    (
        native_behav_with(op, configs, inputs, BehavBackend::Scalar),
        native_behav_with(op, configs, inputs, BehavBackend::Bitslice),
    )
}

#[test]
fn add4_exhaustive_space_is_bit_identical() {
    let inputs = InputSet::exhaustive(Operator::ADD4);
    let configs: Vec<AxoConfig> = AxoConfig::enumerate(4).collect();
    assert_eq!(configs.len(), 15);
    let (scalar, bitslice) = both(Operator::ADD4, &configs, &inputs);
    assert_bit_identical(&scalar, &bitslice, "add4 exhaustive");
}

#[test]
fn mul4_exhaustive_space_is_bit_identical() {
    let inputs = InputSet::exhaustive(Operator::MUL4);
    let configs: Vec<AxoConfig> = AxoConfig::enumerate(10).collect();
    assert_eq!(configs.len(), 1023);
    let (scalar, bitslice) = both(Operator::MUL4, &configs, &inputs);
    assert_bit_identical(&scalar, &bitslice, "mul4 exhaustive");
}

#[test]
fn add8_random_configs_are_bit_identical() {
    let inputs = InputSet::exhaustive(Operator::ADD8);
    let mut rng = Rng::seed_from_u64(11);
    let configs = AxoConfig::sample_unique(8, 24, &mut rng);
    let (scalar, bitslice) = both(Operator::ADD8, &configs, &inputs);
    assert_bit_identical(&scalar, &bitslice, "add8 random configs");
}

#[test]
fn add12_sampled_inputs_are_bit_identical() {
    // 12-bit operands exercise magnitude planes past the 8-bit cases, and
    // 5000 vectors leave a 8-lane tail in the last block.
    let inputs = InputSet::sampled_adder(12, 5000, 7);
    let mut rng = Rng::seed_from_u64(13);
    let configs = AxoConfig::sample_unique(12, 16, &mut rng);
    let (scalar, bitslice) = both(Operator::ADD12, &configs, &inputs);
    assert_bit_identical(&scalar, &bitslice, "add12 sampled inputs");
}

#[test]
fn mul8_random_configs_are_bit_identical() {
    let inputs = InputSet::exhaustive(Operator::MUL8);
    let mut rng = Rng::seed_from_u64(17);
    let configs = AxoConfig::sample_unique(36, 12, &mut rng);
    let (scalar, bitslice) = both(Operator::MUL8, &configs, &inputs);
    assert_bit_identical(&scalar, &bitslice, "mul8 random configs");
}

#[test]
fn ragged_adder_lengths_mask_tail_lanes_identically() {
    let full = InputSet::sampled_adder(8, 300, 23);
    let a: Vec<u32> = full.a.iter().map(|&v| v as u32).collect();
    let b: Vec<u32> = full.b.iter().map(|&v| v as u32).collect();
    let mut rng = Rng::seed_from_u64(29);
    let configs = AxoConfig::sample_unique(8, 8, &mut rng);
    for len in [1usize, 63, 64, 65, 130, 256, 300] {
        let scalar =
            adder_behav_with(&configs, &a[..len], &b[..len], BehavBackend::Scalar);
        let bitslice =
            adder_behav_with(&configs, &a[..len], &b[..len], BehavBackend::Bitslice);
        assert_bit_identical(&scalar, &bitslice, &format!("adder len {len}"));
    }
}

#[test]
fn ragged_multiplier_lengths_mask_tail_lanes_identically() {
    let full = InputSet::exhaustive(Operator::MUL4);
    let mut rng = Rng::seed_from_u64(31);
    let configs = AxoConfig::sample_unique(10, 8, &mut rng);
    for len in [1usize, 63, 64, 65, 130] {
        let (a, b) = (&full.a[..len], &full.b[..len]);
        let terms = multiplier::term_matrix(4, a, b);
        let scalar = mult_behav(&configs, &terms, 10);
        let bitslice = mult_behav_bitslice(4, &configs, a, b);
        assert_bit_identical(&scalar, &bitslice, &format!("multiplier len {len}"));
    }
}

#[test]
fn sharded_pipeline_is_bit_identical_across_backends() {
    // The backend choice must be invisible end to end: whole datasets out
    // of the sharded pipeline match bit-for-bit, so cache and store
    // entries never depend on which backend characterized them.
    let inputs = InputSet::exhaustive(Operator::MUL4);
    let mut rng = Rng::seed_from_u64(37);
    let configs = AxoConfig::sample_unique(10, 101, &mut rng);
    let scalar = characterize_sharded_as(
        Operator::MUL4,
        &configs,
        &inputs,
        32,
        BehavBackend::Scalar,
    )
    .unwrap();
    let bitslice = characterize_sharded_as(
        Operator::MUL4,
        &configs,
        &inputs,
        32,
        BehavBackend::Bitslice,
    )
    .unwrap();
    assert_eq!(scalar.configs, bitslice.configs);
    assert_bit_identical(&scalar.behav, &bitslice.behav, "sharded mul4 dataset");
}
