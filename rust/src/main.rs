//! `repro` — the AxOCS leader binary.
//!
//! Subcommands cover the full Fig. 4 pipeline: characterization, distance
//! matching, (augmented) GA-based DSE, validation, figure regeneration, and
//! a batched estimator-service demo. Python never runs here; everything
//! executes against the Rust substrates and — in `--features pjrt` builds
//! with `make artifacts` — the AOT-compiled PJRT executables.

use repro::charac::{characterize, characterize_all, Backend, Dataset, InputSet};
use repro::cli::ParsedArgs;
use repro::conss::SeedSelection;
use repro::dse::{Constraints, NsgaRunner};
use repro::engine::{vpf_candidates, DatasetStore, DseJob, EngineContext};
use repro::error::{Error, Result};
use repro::expcfg::ExperimentConfig;
use repro::matching::{DistanceKind, Matcher};
use repro::operator::{AxoConfig, Operator};
use repro::report::Harness;
use repro::serve::{
    http_call_retry, HttpOptions, HttpServer, JobQueue, JobRunner, JobSpec,
    RequeueReport, RetryPolicy, ServeOptions, LOG_FILE, MAX_REVIVALS,
};
use repro::surrogate::{EstimatorBackend, Surrogate, TableSurrogate};
use repro::util::rng::Rng;
use std::path::PathBuf;

const USAGE: &str = "\
repro — AxOCS: scaling FPGA-based approximate operators using configuration supersampling

USAGE: repro <COMMAND> [OPTIONS]

COMMANDS:
  characterize <op>    Characterize a design space (add4|add8|add12|mul4|mul8)
                         [--samples N] [--pjrt] [--output PATH]
  match <l> <h>        Distance-based matching between two operators
                         [--distance euclidean|manhattan|pareto]
  dse                  Full DSE comparison across constraint scaling factors
                         [--factor F | --factors F1,F2,...]
                         [--backend table|gbt|pjrt-mlp]
                         Multiple factors run concurrently through one
                         shared batching estimator service.
  figures [ids...]     Regenerate paper figures/tables (fig1..fig18, tab2,
                         tab_est, or `all`)
  submit [spec.json..] Enqueue DSE job specs for `serve-dse` (spool:
                         artifacts/jobs/pending). With no files, builds a
                         spec from flags: --id NAME --factors F1,F2,...
                         [--operator OP] [--seed-selection all|pareto-only|
                         constraint-filtered] [--ga-seed N]
                         With --addr HOST:PORT, POSTs the specs to a running
                         serve-http instead (capped-backoff retries on 429/
                         503 and transport errors; --retries N, default 5).
  serve-dse            Job server: run queued DSE jobs against one resident
                         engine. --drain runs the queue to empty and exits;
                         default watches pending/ forever. SIGTERM/SIGINT
                         drain gracefully: workers stop claiming, finish
                         their in-flight job, and exit 0.
                         [--workers N] [--max-jobs N]
  serve-http           HTTP front-end over the job spool: POST /jobs,
                         GET /jobs/<id>[/result|/timeline], /healthz,
                         /metrics (JSON, or Prometheus text via
                         ?format=prometheus), /trace (Chrome trace JSON).
                         Identical specs dedupe onto one content-addressed
                         job; a full queue answers 429 + Retry-After; a full
                         disk sheds with 503 instead of crashing. SIGTERM/
                         SIGINT drain gracefully (/healthz -> \"draining\").
                         [--addr HOST:PORT] [--http-threads N]
                         [--workers N (0 = front-end only)] [--high-water N]
  trace export         Export the span ring of a running serve-http as
                         Chrome trace-event JSON (Perfetto-loadable).
                         Spans record when REPRO_TRACE=1 (or [obs] trace).
                         [--addr HOST:PORT] [--output PATH (trace.json)]
  serve                Batched estimator-service demo
                         [--clients N] [--requests-per-client N]
  store <action>       Persistent dataset store maintenance:
                         ls (list entries + total size), clear (delete all),
                         verify (re-hash + re-parse every entry),
                         gc [--max-bytes N] (LRU-by-mtime eviction; defaults
                         to [store] max_bytes, which serve-dse --watch and
                         serve-http also GC against while idle)
  verify               Cross-check the PJRT runtime against the native model
  quickstart           Tiny end-to-end tour of the API

GLOBAL OPTIONS:
  --config PATH        Experiment TOML (defaults = paper-scale settings)
  --artifacts PATH     AOT artifacts directory (default: artifacts)
  --out PATH           Results directory (default: results)
  --no-store           Skip the persistent dataset store (on by default:
                         datasets are loaded from / saved to
                         artifacts/datasets across invocations)
  --quick              Scaled-down sample sizes / generations
  --help               This help

The `--pjrt` switch, the `pjrt-mlp` backend, and `verify` need a binary
built with `--features pjrt` plus `make artifacts`; every other path is
hermetic (native substrates only).
";

const GLOBAL_OPTS: &[&str] = &[
    "config",
    "artifacts",
    "out",
    "samples",
    "output",
    "distance",
    "factor",
    "factors",
    "backend",
    "clients",
    "requests-per-client",
    "id",
    "operator",
    "seed-selection",
    "ga-seed",
    "workers",
    "max-jobs",
    "max-bytes",
    "addr",
    "http-threads",
    "high-water",
    "retries",
];

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() || args.iter().any(|a| a == "--help" || a == "-h") {
        print!("{USAGE}");
        return;
    }
    match run(args) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}

fn run(args: Vec<String>) -> Result<()> {
    let parsed =
        ParsedArgs::parse(args, &["quick", "pjrt", "no-store", "drain", "watch"])?;
    parsed.ensure_known(GLOBAL_OPTS)?;
    let cfg = load_config(&parsed)?;
    match parsed.command.as_str() {
        "characterize" => cmd_characterize(&cfg, &parsed),
        "match" => cmd_match(&cfg, &parsed),
        "dse" => cmd_dse(&cfg, &parsed),
        "store" => cmd_store(&cfg, &parsed),
        "submit" => cmd_submit(&cfg, &parsed),
        "serve-dse" => cmd_serve_dse(&cfg, &parsed),
        "serve-http" => cmd_serve_http(&cfg, &parsed),
        "trace" => cmd_trace(&cfg, &parsed),
        "figures" => {
            let harness = Harness::new(cfg);
            for s in harness.run(&parsed.positionals)? {
                println!("{s}");
            }
            Ok(())
        }
        "serve" => cmd_serve(&cfg, &parsed),
        "verify" => cmd_verify(&cfg),
        "quickstart" => cmd_quickstart(&cfg),
        other => Err(Error::Config(format!("unknown command `{other}` (try --help)"))),
    }
}

fn load_config(parsed: &ParsedArgs) -> Result<ExperimentConfig> {
    let mut cfg = match parsed.opt("config") {
        Some(p) => ExperimentConfig::load(&PathBuf::from(p))
            .map_err(|e| Error::Config(format!("loading --config {p}: {e}")))?,
        None => ExperimentConfig::default(),
    };
    if let Some(a) = parsed.opt("artifacts") {
        cfg.artifacts_dir = PathBuf::from(a);
    }
    if let Some(o) = parsed.opt("out") {
        cfg.out_dir = PathBuf::from(o);
    }
    if parsed.flag("quick") {
        cfg.train_samples = cfg.train_samples.min(2000);
        cfg.ga.generations = cfg.ga.generations.min(40);
        cfg.ga.pop_size = cfg.ga.pop_size.min(48);
    }
    // The CLI defaults the persistent dataset store ON (repeated
    // invocations warm-start from artifacts/datasets); `--no-store` or an
    // explicit `store.enabled` in the TOML wins.
    if parsed.flag("no-store") {
        cfg.store.enabled = Some(false);
    } else {
        cfg.store.enabled.get_or_insert(true);
    }
    cfg.validate()?;
    // Arm (or size) the tracing layer before any engine work runs:
    // REPRO_TRACE in the environment overrides `[obs] trace`.
    repro::obs::apply(&cfg.obs);
    // Same precedence for failpoints: REPRO_FAULTS overrides `[fault]
    // spec` (set-but-empty disarms). Disarmed is a single relaxed load.
    repro::fault::apply(&cfg.fault)?;
    Ok(cfg)
}

fn cmd_store(cfg: &ExperimentConfig, parsed: &ParsedArgs) -> Result<()> {
    let store = DatasetStore::open(cfg.store.dir_under(&cfg.artifacts_dir));
    match parsed.positional(0, "store action (ls|clear|verify|gc)")? {
        "ls" => {
            let entries = store.entries()?;
            if entries.is_empty() {
                println!("dataset store empty at {}", store.dir().display());
                return Ok(());
            }
            let mut total = 0u64;
            for e in &entries {
                total += e.bytes;
                println!(
                    "{:<44} {:>8} designs {:>10} B  fnv1a64 {:016x}  {}",
                    e.slug,
                    e.len,
                    e.bytes,
                    e.hash,
                    e.path.display()
                );
            }
            println!(
                "{} entries, {total} bytes total at {}",
                entries.len(),
                store.dir().display()
            );
            Ok(())
        }
        "gc" => {
            let max_bytes: u64 = parsed
                .opt_parse("max-bytes")?
                .or(cfg.store.max_bytes)
                .ok_or_else(|| {
                    Error::Config(
                        "store gc needs --max-bytes N (or [store] max_bytes in the \
                         config)"
                            .into(),
                    )
                })?;
            let report = store.gc(max_bytes)?;
            for slug in &report.evicted {
                println!("evicted {slug}");
            }
            println!(
                "store gc: {} evicted, {} kept; {} -> {} bytes (cap {max_bytes}) at {}",
                report.evicted.len(),
                report.kept,
                report.bytes_before,
                report.bytes_after,
                store.dir().display()
            );
            Ok(())
        }
        "clear" => {
            let n = store.clear()?;
            println!("removed {n} dataset(s) from {}", store.dir().display());
            Ok(())
        }
        "verify" => {
            let results = store.verify()?;
            if results.is_empty() {
                println!("dataset store empty at {}", store.dir().display());
                return Ok(());
            }
            let mut bad = 0usize;
            for (slug, status) in &results {
                println!("{slug:<44} {status}");
                if *status != repro::engine::VerifyStatus::Ok {
                    bad += 1;
                }
            }
            if bad != 0 {
                return Err(Error::Dataset(format!(
                    "{bad}/{} store entries failed verification",
                    results.len()
                )));
            }
            println!("{} entries verified", results.len());
            Ok(())
        }
        other => Err(Error::Config(format!(
            "unknown store action `{other}` (expected ls|clear|verify|gc)"
        ))),
    }
}

/// Enqueue job specs for `serve-dse`: positional `spec.json` files, or an
/// inline spec built from `--id`/`--factors`/... flags when none given.
/// With `--addr`, the specs are POSTed to a running `serve-http` (with
/// retries) instead of spooled locally.
fn cmd_submit(cfg: &ExperimentConfig, parsed: &ParsedArgs) -> Result<()> {
    let queue = JobQueue::open(cfg.serve.dir_under(&cfg.artifacts_dir))?;
    let mut specs: Vec<JobSpec> = Vec::new();
    if parsed.positionals.is_empty() {
        let factors: Vec<f64> = parsed
            .opt_parse_list("factors")?
            .ok_or_else(|| Error::Config("submit needs spec files or --factors".into()))?;
        let id = parsed
            .opt("id")
            .ok_or_else(|| Error::Config("inline submit needs --id NAME".into()))?;
        let mut spec = JobSpec::new(id, factors);
        if let Some(op) = parsed.opt("operator") {
            spec.operator = Some(Operator::from_name(op)?);
        }
        if let Some(sel) = parsed.opt("seed-selection") {
            spec.seed_selection = SeedSelection::from_name(sel).ok_or_else(|| {
                Error::Config(format!(
                    "unknown --seed-selection `{sel}` \
                     (expected all|pareto-only|constraint-filtered)"
                ))
            })?;
        }
        spec.ga_seed = parsed.opt_parse("ga-seed")?;
        specs.push(spec);
    } else {
        for file in &parsed.positionals {
            let path = PathBuf::from(file);
            let text = std::fs::read_to_string(&path)
                .map_err(|_| Error::ArtifactMissing { path: path.clone() })?;
            let mut spec = JobSpec::parse(&text)
                .map_err(|e| Error::Config(format!("{file}: {e}")))?;
            if spec.id.is_empty() {
                spec.id = path
                    .file_stem()
                    .map(|s| s.to_string_lossy().into_owned())
                    .unwrap_or_default();
            }
            specs.push(spec);
        }
    }
    if let Some(addr) = parsed.opt("addr") {
        return submit_over_http(addr, &specs, parsed);
    }
    for spec in &specs {
        let dest = queue.submit(spec)?;
        println!(
            "submitted job `{}` ({} factor(s)) -> {}",
            spec.id,
            spec.factors.len(),
            dest.display()
        );
    }
    let c = queue.counts()?;
    println!(
        "queue at {}: {} pending, {} running, {} done, {} failed",
        queue.dir().display(),
        c.pending,
        c.running,
        c.done,
        c.failed
    );
    Ok(())
}

/// `submit --addr`: POST each spec to a running `serve-http`, retrying
/// `429`/`503` (honoring `Retry-After`) and transport failures under a
/// capped-backoff [`RetryPolicy`]. Ids are server-assigned
/// (content-addressed), so any local `--id` is display-only.
fn submit_over_http(addr: &str, specs: &[JobSpec], parsed: &ParsedArgs) -> Result<()> {
    use repro::util::json::Json;
    let mut policy = RetryPolicy::default();
    if let Some(n) = parsed.opt_parse::<u32>("retries")? {
        policy.max_retries = n;
    }
    let mut total_retries: u32 = 0;
    for spec in specs {
        let mut wire = spec.clone();
        wire.id = String::new(); // the server content-addresses identity
        let body = wire.to_json().to_string();
        let (response, retries) =
            http_call_retry(addr, "POST", "/jobs", Some(&body), &policy)?;
        total_retries += retries;
        match response.status {
            201 | 200 => {
                let id = response
                    .json()
                    .ok()
                    .and_then(|j| j.get("id").and_then(Json::as_str).map(String::from))
                    .unwrap_or_else(|| "?".into());
                println!(
                    "submitted job `{}` -> {id} on {addr} ({}{})",
                    spec.id,
                    if response.status == 201 { "created" } else { "deduped" },
                    if retries > 0 {
                        format!(", {retries} retry(ies)")
                    } else {
                        String::new()
                    }
                );
            }
            status => {
                return Err(Error::Config(format!(
                    "submit to {addr} answered {status} after {retries} retry(ies): {}",
                    response.body
                )));
            }
        }
    }
    if total_retries > 0 {
        println!("{total_retries} retry(ies) across {} spec(s)", specs.len());
    }
    Ok(())
}

/// The job server: drain (or watch) the spool against one resident engine.
fn cmd_serve_dse(cfg: &ExperimentConfig, parsed: &ParsedArgs) -> Result<()> {
    if parsed.flag("drain") && parsed.flag("watch") {
        return Err(Error::Config("pass either --drain or --watch, not both".into()));
    }
    let queue = JobQueue::open(cfg.serve.dir_under(&cfg.artifacts_dir))?;
    print_requeue_report(&queue.requeue_stale()?);
    let opts = ServeOptions {
        workers: parsed.opt_parse("workers")?.unwrap_or(cfg.serve.workers),
        max_jobs: parsed.opt_parse("max-jobs")?,
        drain: parsed.flag("drain"),
        poll: cfg.serve.poll(),
        log_max_bytes: cfg.serve.log_max_bytes,
    };
    if opts.workers == 0 {
        return Err(Error::Config("--workers must be > 0".into()));
    }
    // SIGTERM/SIGINT drain: stop claiming, finish in-flight, exit 0.
    repro::serve::signal::install();
    let engine = EngineContext::new(cfg.clone());
    let runner = JobRunner::new(&engine, &queue, opts.clone())?;
    println!(
        "serve-dse: {} worker(s), {} mode, queue at {}",
        opts.workers,
        if opts.drain { "drain" } else { "watch" },
        queue.dir().display()
    );
    let started = std::time::Instant::now();
    let summary = runner.run()?;
    let elapsed = started.elapsed();
    let c = queue.counts()?;
    println!(
        "{} job(s) done, {} failed in {elapsed:.2?} — queue now: {} pending, \
         {} running, {} done, {} failed",
        summary.done, summary.failed, c.pending, c.running, c.done, c.failed
    );
    let snap = engine.pool_metrics();
    println!(
        "estimator pool: {} service(s) spawned ({} pool hits) — {} requests / \
         {} configs in {} batches (mean fill {:.1}, max {}), {:.0} configs/s",
        engine.pool_stats().spawned,
        engine.pool_stats().hits,
        snap.requests,
        snap.configs,
        snap.batches,
        snap.mean_batch_fill(),
        snap.max_batch_fill,
        snap.configs_per_sec(elapsed)
    );
    let cache = engine.cache_stats();
    println!(
        "dataset cache: {} entries, {} hits, {} misses; characterizations: {}; \
         store hits: {}; phase time: behav {:.1} ms, ppa {:.1} ms",
        cache.entries,
        cache.hits,
        cache.misses,
        cache.characterized,
        cache.store_hits,
        cache.behav_ns as f64 / 1e6,
        cache.ppa_ns as f64 / 1e6
    );
    println!("event log: {}", queue.dir().join(LOG_FILE).display());
    if summary.failed > 0 {
        return Err(Error::Config(format!(
            "{} job(s) failed — see {}/failed/",
            summary.failed,
            queue.dir().display()
        )));
    }
    Ok(())
}

/// Narrate one start-of-server stale-claim sweep.
fn print_requeue_report(report: &RequeueReport) {
    for id in &report.requeued {
        println!("requeued orphaned job `{id}` (claiming process is gone)");
    }
    for id in &report.quarantined {
        println!(
            "quarantined crash-looping job `{id}` after {MAX_REVIVALS} revivals \
             — see failed/"
        );
    }
    for id in &report.cleaned {
        println!("cleaned finished job `{id}` stranded in running/ by a crash");
    }
    for name in &report.swept_temps {
        println!("swept orphaned submit temp `{name}` (submitter is gone)");
    }
}

/// The HTTP front-end: bind, sweep orphaned claims, serve until killed.
fn cmd_serve_http(cfg: &ExperimentConfig, parsed: &ParsedArgs) -> Result<()> {
    let queue =
        std::sync::Arc::new(JobQueue::open(cfg.serve.dir_under(&cfg.artifacts_dir))?);
    print_requeue_report(&queue.requeue_stale()?);
    let opts = HttpOptions {
        threads: parsed.opt_parse("http-threads")?.unwrap_or(cfg.http.threads),
        workers: parsed.opt_parse("workers")?.unwrap_or(cfg.serve.workers),
        high_water: parsed.opt_parse("high-water")?.unwrap_or(cfg.http.high_water),
        retry_after_secs: cfg.http.retry_after_secs,
        max_body_bytes: cfg.http.max_body_bytes,
        poll: cfg.serve.poll(),
        log_max_bytes: cfg.serve.log_max_bytes,
    };
    if opts.threads == 0 {
        return Err(Error::Config("--http-threads must be > 0".into()));
    }
    let addr = parsed.opt("addr").unwrap_or(&cfg.http.addr);
    // SIGTERM/SIGINT drain: the server's watcher thread turns the flag
    // into an orderly shutdown (exec loop drains, acceptors retire).
    repro::serve::signal::install();
    let engine = std::sync::Arc::new(EngineContext::new(cfg.clone()));
    let server = HttpServer::bind(engine, queue.clone(), addr, opts.clone())?;
    println!(
        "serve-http: listening on http://{} — {} acceptor(s), {} exec worker(s), \
         high-water {}, queue at {}",
        server.local_addr(),
        opts.threads,
        opts.workers,
        opts.high_water,
        queue.dir().display()
    );
    println!("event log: {}", queue.dir().join(LOG_FILE).display());
    server.run()
}

/// `trace export`: fetch `GET /trace` from a running `serve-http` and
/// write the Chrome trace-event JSON (load it in Perfetto or
/// `chrome://tracing`). Spans only record while tracing is enabled on
/// the *server* (`REPRO_TRACE=1` or `[obs] trace = true`).
fn cmd_trace(cfg: &ExperimentConfig, parsed: &ParsedArgs) -> Result<()> {
    match parsed.positional(0, "trace action (export)")? {
        "export" => {
            let addr = parsed.opt("addr").unwrap_or(&cfg.http.addr);
            let response = repro::serve::http_call(addr, "GET", "/trace", None)?;
            if response.status != 200 {
                return Err(Error::Config(format!(
                    "GET /trace on {addr} answered {}",
                    response.status
                )));
            }
            let spans = response
                .json()?
                .get("traceEvents")
                .and_then(|e| e.as_arr().map(|v| v.len()))
                .unwrap_or(0);
            let out = PathBuf::from(parsed.opt("output").unwrap_or("trace.json"));
            std::fs::write(&out, &response.body)?;
            println!("wrote {spans} span(s) from {addr} to {}", out.display());
            Ok(())
        }
        other => {
            Err(Error::Config(format!("unknown trace action `{other}` (try export)")))
        }
    }
}

fn parse_distance(s: &str) -> Result<DistanceKind> {
    DistanceKind::from_name(s)
        .ok_or_else(|| Error::Config(format!("unknown distance `{s}`")))
}

/// Config selection shared by the native and PJRT characterization paths:
/// `None` = exhaustive enumeration, `Some` = seeded sample.
fn select_configs(
    cfg: &ExperimentConfig,
    op: Operator,
    samples: Option<usize>,
) -> Option<Vec<AxoConfig>> {
    if op.exhaustive() && samples.is_none() {
        None
    } else {
        let n = samples.unwrap_or(cfg.train_samples);
        let mut rng = Rng::seed_from_u64(cfg.seed);
        Some(AxoConfig::sample_unique(op.config_len(), n, &mut rng))
    }
}

#[cfg(feature = "pjrt")]
fn characterize_pjrt(
    cfg: &ExperimentConfig,
    op: Operator,
    inputs: &InputSet,
    configs: Option<&[AxoConfig]>,
) -> Result<Dataset> {
    use repro::runtime::{AxoEvalExec, Runtime};
    let rt = Runtime::cpu(&cfg.artifacts_dir)?;
    let exec = AxoEvalExec::new(&rt, op, inputs)?;
    let backend = Backend::Evaluator(&exec);
    match configs {
        None => characterize_all(op, inputs, &backend),
        Some(c) => characterize(op, c, inputs, &backend),
    }
}

#[cfg(not(feature = "pjrt"))]
fn characterize_pjrt(
    _cfg: &ExperimentConfig,
    op: Operator,
    _inputs: &InputSet,
    _configs: Option<&[AxoConfig]>,
) -> Result<Dataset> {
    Err(Error::Config(format!(
        "--pjrt characterization of {op} needs a build with `--features pjrt` \
         (and `make artifacts`); drop --pjrt for the native backend"
    )))
}

fn cmd_characterize(cfg: &ExperimentConfig, parsed: &ParsedArgs) -> Result<()> {
    let op = Operator::from_name(parsed.positional(0, "operator name")?)?;
    let samples: Option<usize> = parsed.opt_parse("samples")?;
    let pjrt = parsed.flag("pjrt");
    let inputs = InputSet::for_operator(op, &cfg.artifacts_dir)?;
    let configs = select_configs(cfg, op, samples);
    let started = std::time::Instant::now();
    let ds = if pjrt {
        characterize_pjrt(cfg, op, &inputs, configs.as_deref())?
    } else {
        match &configs {
            None => characterize_all(op, &inputs, &Backend::Native)?,
            Some(c) => characterize(op, c, &inputs, &Backend::Native)?,
        }
    };
    let elapsed = started.elapsed();
    let out = parsed
        .opt("output")
        .map(PathBuf::from)
        .unwrap_or_else(|| cfg.out_dir.join(format!("{}.json", op.name())));
    ds.save_json(&out)?;
    ds.save_csv(&out.with_extension("csv"))?;
    println!(
        "characterized {} designs of {op} over {} inputs in {elapsed:.2?} ({} backend)\nwrote {}",
        ds.len(),
        inputs.len(),
        if pjrt { "pjrt" } else { "native" },
        out.display()
    );
    Ok(())
}

fn cmd_match(cfg: &ExperimentConfig, parsed: &ParsedArgs) -> Result<()> {
    let harness = Harness::new(cfg.clone());
    let l = harness.dataset(Operator::from_name(parsed.positional(0, "L operator")?)?)?;
    let h = harness.dataset(Operator::from_name(parsed.positional(1, "H operator")?)?)?;
    let distance = parsed.opt("distance").unwrap_or("euclidean");
    let matcher = Matcher::new(parse_distance(distance)?);
    let m = matcher.match_datasets(&l, &h)?;
    let counts = m.counts_per_l(l.len());
    println!(
        "matched {} H designs onto {} L designs ({distance} distance)",
        m.h_to_l.len(),
        l.len()
    );
    let used = counts.iter().filter(|&&c| c > 0).count();
    println!(
        "L designs used as matches: {used}/{}; max fan-out {}",
        l.len(),
        counts.iter().max().unwrap_or(&0)
    );
    if m.distances.is_empty() {
        println!("mean matched distance (scaled plane): n/a (no matched pairs)");
    } else {
        let mean: f64 = m.distances.iter().sum::<f64>() / m.distances.len() as f64;
        println!("mean matched distance (scaled plane): {mean:.4}");
    }
    Ok(())
}

fn cmd_dse(cfg: &ExperimentConfig, parsed: &ParsedArgs) -> Result<()> {
    let mut cfg = cfg.clone();
    if let Some(b) = parsed.opt("backend") {
        cfg.surrogate.backend = EstimatorBackend::from_name(b)
            .ok_or_else(|| Error::Config(format!("unknown backend `{b}`")))?;
    }
    let factors: Vec<f64> = match parsed.opt_parse_list("factors")? {
        Some(_) if parsed.opt("factor").is_some() => {
            return Err(Error::Config(
                "pass either --factor or --factors, not both".into(),
            ))
        }
        Some(list) if list.is_empty() => {
            return Err(Error::Config("--factors needs at least one value".into()))
        }
        Some(list) => list,
        None => vec![parsed.opt_parse("factor")?.unwrap_or(0.5)],
    };
    let engine = EngineContext::new(cfg);
    let prep = engine.prepare_dse()?;
    let jobs: Vec<DseJob> = factors.iter().map(|&f| DseJob::new(f)).collect();
    let started = std::time::Instant::now();
    let runs = prep.run_many(&jobs)?;
    let elapsed = started.elapsed();
    for run in &runs {
        let (vpf, extra) = engine.validate_front(
            &prep,
            &vpf_candidates(&run.conss_ga),
            &run.constraints,
        )?;
        let vpf_hv = repro::dse::hypervolume2d(&vpf.points, run.constraints.reference());
        println!(
            "factor {}: B_MAX {:.4} P_MAX {:.4}",
            run.factor, run.constraints.b_max, run.constraints.p_max
        );
        println!("  TRAIN     hv {:.4}", run.hv_train);
        println!(
            "  GA        hv {:.4}  ({} evals)",
            run.ga.final_hypervolume(),
            run.ga.evaluations
        );
        println!(
            "  ConSS     hv {:.4}  (pool {}, {} seeds)",
            run.hv_conss,
            run.conss_pool.configs.len(),
            run.conss_pool.n_seeds
        );
        println!(
            "  ConSS+GA  hv {:.4}  ({} evals)",
            run.conss_ga.final_hypervolume(),
            run.conss_ga.evaluations
        );
        println!(
            "  VPF: {} designs ({extra} extra characterizations), hv {vpf_hv:.4}",
            vpf.len()
        );
    }
    let snap = prep.service.metrics().snapshot();
    println!(
        "{} factor(s) in {elapsed:.2?} — estimator service: {} requests / {} configs \
         in {} batches (mean fill {:.1}, max {}), backend busy {:.1} ms",
        runs.len(),
        snap.requests,
        snap.configs,
        snap.batches,
        snap.mean_batch_fill(),
        snap.max_batch_fill,
        snap.busy_micros as f64 / 1000.0
    );
    let cache = engine.cache_stats();
    println!(
        "dataset cache: {} entries, {} hits, {} misses; characterizations: {}; \
         store hits: {}{}; phase time: behav {:.1} ms, ppa {:.1} ms",
        cache.entries,
        cache.hits,
        cache.misses,
        cache.characterized,
        cache.store_hits,
        match engine.store() {
            Some(s) => format!(" ({})", s.dir().display()),
            None => " (store off)".to_string(),
        },
        cache.behav_ns as f64 / 1e6,
        cache.ppa_ns as f64 / 1e6
    );
    Ok(())
}

fn cmd_serve(cfg: &ExperimentConfig, parsed: &ParsedArgs) -> Result<()> {
    let clients: usize = parsed.opt_parse("clients")?.unwrap_or(8);
    let requests: usize = parsed.opt_parse("requests-per-client")?.unwrap_or(64);
    let engine = EngineContext::new(cfg.clone());
    let op = Operator::from_name(&cfg.operator)?;
    let svc = engine.estimator()?;
    let op_len = op.config_len();
    let seed = cfg.seed;
    let started = std::time::Instant::now();
    std::thread::scope(|s| {
        for c in 0..clients {
            let svc = svc.clone();
            s.spawn(move || {
                let mut rng = Rng::seed_from_u64(seed + c as u64);
                for _ in 0..requests {
                    let cfgs = AxoConfig::sample_unique(op_len, 8, &mut rng);
                    svc.predict(cfgs).expect("prediction failed");
                }
            });
        }
    });
    let elapsed = started.elapsed();
    let snap = svc.metrics().snapshot();
    // configs_per_sec clamps the zero-request / instant-run case to 0.0
    // instead of printing `NaN configs/s`.
    println!(
        "{} requests / {} configs in {elapsed:.2?} — {:.0} configs/s",
        snap.requests,
        snap.configs,
        snap.configs_per_sec(elapsed)
    );
    println!(
        "{} backend batches, mean fill {:.1}, max fill {}, backend busy {:.1} ms",
        snap.batches,
        snap.mean_batch_fill(),
        snap.max_batch_fill,
        snap.busy_micros as f64 / 1000.0
    );
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_verify(cfg: &ExperimentConfig) -> Result<()> {
    use repro::runtime::{AxoEvalExec, Runtime};
    let rt = Runtime::cpu(&cfg.artifacts_dir)?;
    println!("PJRT platform: {}", rt.platform());
    let mut failures = 0;
    for op in [Operator::ADD4, Operator::MUL4] {
        let inputs = InputSet::for_operator(op, &cfg.artifacts_dir)?;
        let exec = AxoEvalExec::new(&rt, op, &inputs)?;
        let cfgs: Vec<AxoConfig> = AxoConfig::enumerate(op.config_len()).take(16).collect();
        let pjrt = characterize(op, &cfgs, &inputs, &Backend::Evaluator(&exec))?;
        let native = characterize(op, &cfgs, &inputs, &Backend::Native)?;
        for i in 0..cfgs.len() {
            let a = pjrt.behav[i].to_array();
            let b = native.behav[i].to_array();
            for k in 0..4 {
                let denom = b[k].abs().max(1.0);
                if ((a[k] - b[k]).abs() / denom) > 1e-4 {
                    println!(
                        "  MISMATCH {op} cfg {} metric {k}: pjrt {} native {}",
                        cfgs[i], a[k], b[k]
                    );
                    failures += 1;
                }
            }
        }
        println!("{op}: pjrt == native over {} configs ✓", cfgs.len());
    }
    if failures != 0 {
        return Err(Error::Xla(format!("{failures} metric mismatches")));
    }
    println!("runtime verification OK");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_verify(_cfg: &ExperimentConfig) -> Result<()> {
    Err(Error::Config(
        "`verify` cross-checks the PJRT runtime and needs a build with \
         `--features pjrt` plus `make artifacts`"
            .into(),
    ))
}

fn cmd_quickstart(cfg: &ExperimentConfig) -> Result<()> {
    println!("AxOCS quickstart — 4-bit adder tour (see examples/ for the full flows)");
    let op = Operator::ADD4;
    let engine = EngineContext::new(cfg.clone());
    let ds = engine.dataset(op)?;
    println!("characterized all {} designs of {op}", ds.len());
    let pts: Vec<[f64; 2]> = ds.headline_points().iter().map(|p| [p[1], p[0]]).collect();
    let constraints = Constraints::from_scaling_factor(0.75, &pts)?;
    let table = TableSurrogate::from_dataset(&ds);
    let fitness = |c: &[AxoConfig]| table.predict(c);
    let runner = NsgaRunner::new(
        repro::dse::GaOptions {
            pop_size: 8,
            generations: 10,
            seed: cfg.seed,
            ..Default::default()
        },
        constraints,
    );
    let result = runner.run(op.config_len(), &fitness, &[])?;
    println!(
        "NSGA-II over the exact table: front {} designs, hv {:.4}",
        result.front_points.len(),
        result.final_hypervolume()
    );
    Ok(())
}
