//! Configuration Supersampling — ConSS (paper §IV-C-1, Figs. 13/14).
//!
//! The heart of AxOCS: a multi-output classifier trained on distance-
//! matched (L_CONFIG → H_CONFIG) pairs generates candidate high-bit-width
//! configurations from low-bit-width seeds. Noise bits appended to the
//! input let one seed fan out into up to `2^n` distinct candidates; seeds
//! can be all L designs or only the L Pareto front (Fig. 14 compares
//! both). The generated pool is used directly (standalone ConSS) or as the
//! initial population of the augmented GA (Fig. 9).

pub mod pipeline;

pub use pipeline::{ConssPipeline, ConssPool, SeedSelection, SupersampleOptions};

use crate::error::{Error, Result};
use crate::matching::noise::noise_row;
use crate::ml::forest::{ForestParams, RandomForest};
use crate::operator::AxoConfig;

/// A trained supersampling model: L-bits (+ noise) → H-bit probabilities.
pub struct ConssModel {
    forest: RandomForest,
    pub l_len: u32,
    pub h_len: u32,
    pub noise_bits: u32,
}

impl ConssModel {
    /// Train the random forest on row-major (x, y) from
    /// [`crate::matching::conss_training_set`].
    pub fn train(
        x: &[f64],
        x_features: usize,
        y: &[f64],
        y_features: usize,
        l_len: u32,
        noise_bits: u32,
        params: ForestParams,
    ) -> Result<ConssModel> {
        if x_features != (l_len + noise_bits) as usize {
            return Err(Error::Ml(format!(
                "x features {x_features} != l_len {l_len} + noise {noise_bits}"
            )));
        }
        let forest = RandomForest::fit(x, x_features, y, y_features, params)?;
        Ok(ConssModel { forest, l_len, h_len: y_features as u32, noise_bits })
    }

    /// Generate candidate H configurations for one L seed across all
    /// `2^noise_bits` noise values. All-zero predictions are dropped
    /// (invalid configurations by the operator model).
    pub fn supersample_one(&self, l_config: &AxoConfig) -> Result<Vec<AxoConfig>> {
        if l_config.len() != self.l_len {
            return Err(Error::Shape(format!(
                "seed length {} != model l_len {}",
                l_config.len(),
                self.l_len
            )));
        }
        let base: Vec<f64> =
            l_config.to_bits_f32().iter().map(|&v| v as f64).collect();
        let mut out = Vec::new();
        for noise in 0..(1usize << self.noise_bits) {
            let mut row = base.clone();
            row.extend(noise_row(noise, self.noise_bits));
            let bits = self.forest.predict_bits_row(&row);
            if let Ok(cfg) = AxoConfig::from_bits(&bits) {
                out.push(cfg);
            }
        }
        Ok(out)
    }

    /// Supersample a set of seeds, deduplicating the resulting pool.
    pub fn supersample(&self, seeds: &[AxoConfig]) -> Result<Vec<AxoConfig>> {
        let mut seen = std::collections::HashSet::new();
        let mut pool = Vec::new();
        for s in seeds {
            for c in self.supersample_one(s)? {
                if seen.insert(c.as_uint()) {
                    pool.push(c);
                }
            }
        }
        Ok(pool)
    }

    /// Per-bit probabilities for diagnostics (Fig. 13 accuracy analysis).
    pub fn predict_proba(&self, l_config: &AxoConfig, noise: usize) -> Result<Vec<f64>> {
        let mut row: Vec<f64> =
            l_config.to_bits_f32().iter().map(|&v| v as f64).collect();
        row.extend(noise_row(noise, self.noise_bits));
        Ok(self.forest.predict_proba_row(&row))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Train on a synthetic identity-ish mapping: h bits = l bits repeated.
    fn trained_model(noise_bits: u32) -> ConssModel {
        let mut pairs: Vec<(Vec<f64>, Vec<f64>)> = Vec::new();
        for v in 1u64..16 {
            let l: Vec<f64> = (0..4).map(|k| ((v >> k) & 1) as f64).collect();
            let h: Vec<f64> = l.iter().chain(l.iter()).copied().collect();
            pairs.push((l, h));
        }
        let (x, y) = crate::matching::augment_with_noise(&pairs, noise_bits);
        // All features per split + a deeper ensemble: the tiny identity
        // dataset must be learned exactly despite bootstrap omissions.
        let params = ForestParams {
            n_trees: 60,
            tree: crate::ml::tree::TreeParams {
                max_depth: 12,
                min_samples_leaf: 1,
                max_features: Some((4 + noise_bits) as usize),
            },
            ..Default::default()
        };
        ConssModel::train(
            &x,
            (4 + noise_bits) as usize,
            &y,
            8,
            4,
            noise_bits,
            params,
        )
        .unwrap()
    }

    #[test]
    fn learns_identity_mapping() {
        let m = trained_model(0);
        for v in 1u64..16 {
            let l = AxoConfig::new(v, 4).unwrap();
            let out = m.supersample_one(&l).unwrap();
            assert_eq!(out.len(), 1);
            assert_eq!(out[0].as_uint(), v | (v << 4), "seed {v}");
        }
    }

    #[test]
    fn noise_fans_out_and_dedups() {
        let m = trained_model(2);
        let l = AxoConfig::new(0b1010, 4).unwrap();
        let out = m.supersample_one(&l).unwrap();
        assert!(!out.is_empty() && out.len() <= 4);
        let pool = m.supersample(&[l, AxoConfig::new(0b0101, 4).unwrap()]).unwrap();
        let uniq: std::collections::HashSet<u64> =
            pool.iter().map(|c| c.as_uint()).collect();
        assert_eq!(uniq.len(), pool.len());
    }

    #[test]
    fn rejects_wrong_seed_length() {
        let m = trained_model(1);
        assert!(m.supersample_one(&AxoConfig::accurate(8)).is_err());
    }

    #[test]
    fn proba_bounded() {
        let m = trained_model(1);
        let p = m.predict_proba(&AxoConfig::accurate(4), 1).unwrap();
        assert_eq!(p.len(), 8);
        assert!(p.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }
}
