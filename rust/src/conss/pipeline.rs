//! End-to-end ConSS pipeline: L_CHAR + H_CHAR → trained model → pool.
//!
//! Mirrors the left-to-right flow of paper Fig. 4: distance-based matching
//! of the characterized datasets, noise augmentation, random-forest
//! training, and supersampling from L seeds (all designs or Pareto-front
//! designs only — the two variants of Fig. 14).

use super::ConssModel;
use crate::charac::Dataset;
use crate::dse::{pareto_front_indices, Constraints, Objectives};
use crate::error::{Error, Result};
use crate::matching::{conss_training_set, DistanceKind, Matcher};
use crate::ml::forest::ForestParams;
use crate::operator::AxoConfig;

/// Which L designs seed the supersampler (Fig. 14 compares both).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SeedSelection {
    /// Every design in the L dataset.
    All,
    /// Only the L Pareto front in the (BEHAV, PPA) plane.
    ParetoOnly,
    /// Only L designs satisfying the scaled constraints (standalone
    /// constrained search of §IV-C-1).
    ConstraintFiltered,
}

impl SeedSelection {
    /// Identifier used by serve-mode job specs and ablation configs.
    pub fn name(&self) -> &'static str {
        match self {
            SeedSelection::All => "all",
            SeedSelection::ParetoOnly => "pareto-only",
            SeedSelection::ConstraintFiltered => "constraint-filtered",
        }
    }

    /// Parse [`SeedSelection::name`] identifiers.
    pub fn from_name(name: &str) -> Option<SeedSelection> {
        [Self::All, Self::ParetoOnly, Self::ConstraintFiltered]
            .into_iter()
            .find(|s| s.name() == name)
    }
}

/// Supersampling options.
#[derive(Debug, Clone)]
pub struct SupersampleOptions {
    pub distance: DistanceKind,
    pub noise_bits: u32,
    pub seeds: SeedSelection,
    pub forest: ForestParams,
}

impl Default for SupersampleOptions {
    fn default() -> Self {
        SupersampleOptions {
            distance: DistanceKind::Euclidean, // §V-C selection
            noise_bits: 4,
            seeds: SeedSelection::All,
            forest: ForestParams::default(),
        }
    }
}

/// The generated candidate pool.
#[derive(Debug, Clone)]
pub struct ConssPool {
    pub configs: Vec<AxoConfig>,
    /// Seeds actually used (after selection).
    pub n_seeds: usize,
}

/// The trained pipeline.
pub struct ConssPipeline {
    pub model: ConssModel,
    pub options: SupersampleOptions,
    l_objectives: Vec<Objectives>,
    l_configs: Vec<AxoConfig>,
}

impl ConssPipeline {
    /// Match, augment, and train from characterized L/H datasets.
    pub fn train(
        l: &Dataset,
        h: &Dataset,
        options: SupersampleOptions,
    ) -> Result<ConssPipeline> {
        let matcher = Matcher::new(options.distance);
        let m = matcher.match_datasets(l, h)?;
        let (x, xf, y, yf) = conss_training_set(l, h, &m, options.noise_bits)?;
        let model = ConssModel::train(
            &x,
            xf,
            &y,
            yf,
            l.operator.config_len(),
            options.noise_bits,
            options.forest.clone(),
        )?;
        let l_objectives: Vec<Objectives> = l
            .headline_points()
            .iter()
            .map(|p| [p[1], p[0]]) // [behav, ppa]
            .collect();
        Ok(ConssPipeline {
            model,
            options,
            l_objectives,
            l_configs: l.configs.clone(),
        })
    }

    /// Seed subset per the configured selection strategy.
    pub fn select_seeds(&self, constraints: Option<&Constraints>, h_train: &[Objectives])
        -> Result<Vec<AxoConfig>>
    {
        self.select_seeds_as(self.options.seeds, constraints, h_train)
    }

    /// Seed subset per an explicit selection strategy (the engine layer
    /// varies the strategy per job without retraining the forest).
    ///
    /// For `ConstraintFiltered` the H constraints are transferred to the L
    /// space by *scaled position*: an L design qualifies when its min-max
    /// scaled metrics fall inside the scaled constraint box (the paper's
    /// "L_CONFIGs satisfying the scaled constraints").
    pub fn select_seeds_as(
        &self,
        selection: SeedSelection,
        constraints: Option<&Constraints>,
        h_train: &[Objectives],
    ) -> Result<Vec<AxoConfig>> {
        match selection {
            SeedSelection::All => Ok(self.l_configs.clone()),
            SeedSelection::ParetoOnly => {
                let idx = pareto_front_indices(&self.l_objectives);
                Ok(idx.iter().map(|&i| self.l_configs[i]).collect())
            }
            SeedSelection::ConstraintFiltered => {
                let c = constraints.ok_or_else(|| {
                    Error::Dse("ConstraintFiltered seeds need constraints".into())
                })?;
                if h_train.is_empty() {
                    return Err(Error::Dse("empty H training set".into()));
                }
                // Scaled constraint box position in H space. The 1e-30
                // floor mirrors the L side below: a degenerate H training
                // set (all-zero behav or ppa) must clamp the filter to
                // "everything passes" instead of scaling by inf/NaN.
                let hb = h_train
                    .iter()
                    .map(|o| o[0])
                    .fold(f64::NEG_INFINITY, f64::max)
                    .max(1e-30);
                let hp = h_train
                    .iter()
                    .map(|o| o[1])
                    .fold(f64::NEG_INFINITY, f64::max)
                    .max(1e-30);
                let fb = (c.b_max / hb).min(1.0);
                let fp = (c.p_max / hp).min(1.0);
                // L metrics scaled to [0,1].
                let lb_max = self
                    .l_objectives
                    .iter()
                    .map(|o| o[0])
                    .fold(f64::NEG_INFINITY, f64::max)
                    .max(1e-30);
                let lp_max = self
                    .l_objectives
                    .iter()
                    .map(|o| o[1])
                    .fold(f64::NEG_INFINITY, f64::max)
                    .max(1e-30);
                Ok(self
                    .l_configs
                    .iter()
                    .zip(&self.l_objectives)
                    .filter(|(_, o)| o[0] / lb_max <= fb && o[1] / lp_max <= fp)
                    .map(|(c, _)| *c)
                    .collect())
            }
        }
    }

    /// Run supersampling and return the deduplicated candidate pool.
    pub fn supersample(
        &self,
        constraints: Option<&Constraints>,
        h_train: &[Objectives],
    ) -> Result<ConssPool> {
        self.supersample_as(self.options.seeds, constraints, h_train)
    }

    /// Supersample under an explicit seed-selection strategy, reusing the
    /// trained forest (selection does not affect training).
    pub fn supersample_as(
        &self,
        selection: SeedSelection,
        constraints: Option<&Constraints>,
        h_train: &[Objectives],
    ) -> Result<ConssPool> {
        let seeds = self.select_seeds_as(selection, constraints, h_train)?;
        if seeds.is_empty() {
            return Err(Error::Dse("seed selection produced no seeds".into()));
        }
        let configs = self.model.supersample(&seeds)?;
        Ok(ConssPool { configs, n_seeds: seeds.len() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::charac::{characterize, characterize_all, Backend, InputSet};
    use crate::operator::Operator;
    use crate::util::rng::Rng;

    fn datasets() -> (Dataset, Dataset) {
        let li = InputSet::exhaustive(Operator::ADD4);
        let hi = InputSet::exhaustive(Operator::ADD8);
        let l = characterize_all(Operator::ADD4, &li, &Backend::Native).unwrap();
        // Sampled H to keep the test fast.
        let mut rng = Rng::seed_from_u64(1);
        let cfgs = AxoConfig::sample_unique(8, 120, &mut rng);
        let h = characterize(Operator::ADD8, &cfgs, &hi, &Backend::Native).unwrap();
        (l, h)
    }

    #[test]
    fn pipeline_generates_valid_pool() {
        let (l, h) = datasets();
        let p = ConssPipeline::train(&l, &h, SupersampleOptions::default()).unwrap();
        let pool = p.supersample(None, &[]).unwrap();
        assert!(!pool.configs.is_empty());
        assert_eq!(pool.n_seeds, 15);
        for c in &pool.configs {
            assert_eq!(c.len(), 8);
            assert_ne!(c.as_uint(), 0);
        }
        // Dedup holds.
        let uniq: std::collections::HashSet<u64> =
            pool.configs.iter().map(|c| c.as_uint()).collect();
        assert_eq!(uniq.len(), pool.configs.len());
    }

    #[test]
    fn pareto_seeds_are_fewer() {
        let (l, h) = datasets();
        let opts = SupersampleOptions {
            seeds: SeedSelection::ParetoOnly,
            ..Default::default()
        };
        let p = ConssPipeline::train(&l, &h, opts).unwrap();
        let seeds = p.select_seeds(None, &[]).unwrap();
        assert!(!seeds.is_empty());
        assert!(seeds.len() < 15);
    }

    #[test]
    fn constraint_filter_tightens_seed_set() {
        let (l, h) = datasets();
        let opts = SupersampleOptions {
            seeds: SeedSelection::ConstraintFiltered,
            ..Default::default()
        };
        let p = ConssPipeline::train(&l, &h, opts).unwrap();
        let h_train: Vec<Objectives> = h
            .headline_points()
            .iter()
            .map(|p| [p[1], p[0]])
            .collect();
        let tight = Constraints::from_scaling_factor(0.3, &h_train).unwrap();
        let loose = Constraints::from_scaling_factor(1.0, &h_train).unwrap();
        let st = p.select_seeds(Some(&tight), &h_train).unwrap();
        let sl = p.select_seeds(Some(&loose), &h_train).unwrap();
        assert!(st.len() <= sl.len());
        assert_eq!(sl.len(), 15);
        // Missing constraints is an error for this mode.
        assert!(p.select_seeds(None, &h_train).is_err());
    }

    #[test]
    fn constraint_filter_survives_degenerate_h_training_set() {
        let (l, h) = datasets();
        let opts = SupersampleOptions {
            seeds: SeedSelection::ConstraintFiltered,
            ..Default::default()
        };
        let p = ConssPipeline::train(&l, &h, opts).unwrap();
        let c = Constraints::new(0.5, 0.5).unwrap();
        // All-zero behav AND ppa: the floored maxima clamp both scale
        // factors to 1.0, so every L seed passes instead of an inf/NaN
        // comparison deciding the filter.
        let degenerate = vec![[0.0, 0.0]; 4];
        let seeds = p.select_seeds(Some(&c), &degenerate).unwrap();
        assert_eq!(seeds.len(), 15);
        // One zero axis only: the other axis still filters normally.
        let h_train: Vec<Objectives> =
            h.headline_points().iter().map(|p| [p[1], 0.0]).collect();
        let seeds = p.select_seeds(Some(&c), &h_train).unwrap();
        assert!(!seeds.is_empty());
    }

    #[test]
    fn supersample_as_varies_selection_without_retraining() {
        let (l, h) = datasets();
        let p = ConssPipeline::train(&l, &h, SupersampleOptions::default()).unwrap();
        let all = p.supersample_as(SeedSelection::All, None, &[]).unwrap();
        let pareto = p.supersample_as(SeedSelection::ParetoOnly, None, &[]).unwrap();
        assert_eq!(all.n_seeds, 15);
        assert!(pareto.n_seeds < all.n_seeds);
        // The baked-in default still routes through the same path.
        let default = p.supersample(None, &[]).unwrap();
        assert_eq!(default.n_seeds, all.n_seeds);
        assert_eq!(default.configs, all.configs);
    }
}
