//! Characterization datasets (the paper's `L_CHAR` / `H_CHAR`).
//!
//! A [`Dataset`] couples configurations with their BEHAV and PPA metric
//! rows. Persistence is JSON (lossless, schema-versioned) with a CSV export
//! for the figure harness / external plotting.

use super::BehavMetrics;
use crate::error::{Error, Result};
use crate::operator::{AxoConfig, Operator};
use crate::synth::PpaMetrics;
use crate::util::json::Json;
use std::io::Write;
use std::path::Path;

/// A characterized set of approximate designs of one operator.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub operator: Operator,
    pub configs: Vec<AxoConfig>,
    pub behav: Vec<BehavMetrics>,
    pub ppa: Vec<PpaMetrics>,
}

impl Dataset {
    pub fn new(
        operator: Operator,
        configs: Vec<AxoConfig>,
        behav: Vec<BehavMetrics>,
        ppa: Vec<PpaMetrics>,
    ) -> Result<Self> {
        if configs.len() != behav.len() || configs.len() != ppa.len() {
            return Err(Error::Dataset(format!(
                "length mismatch: {} configs, {} behav, {} ppa",
                configs.len(),
                behav.len(),
                ppa.len()
            )));
        }
        Ok(Dataset { operator, configs, behav, ppa })
    }

    pub fn len(&self) -> usize {
        self.configs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.configs.is_empty()
    }

    /// Headline (PPA, BEHAV) = (PDPLUT, AVG_ABS_REL_ERR) pairs — the metric
    /// plane of every figure in the paper's evaluation.
    pub fn headline_points(&self) -> Vec<[f64; 2]> {
        self.ppa
            .iter()
            .zip(&self.behav)
            .map(|(p, b)| [p.pdplut, b.avg_abs_rel_err])
            .collect()
    }

    /// Arbitrary metric column by name (behav or ppa namespace).
    pub fn column(&self, name: &str) -> Result<Vec<f64>> {
        if let Some(k) = BehavMetrics::NAMES.iter().position(|&n| n == name) {
            return Ok(self.behav.iter().map(|m| m.to_array()[k]).collect());
        }
        if let Some(k) = PpaMetrics::NAMES.iter().position(|&n| n == name) {
            return Ok(self.ppa.iter().map(|m| m.to_array()[k]).collect());
        }
        Err(Error::Dataset(format!("unknown metric column `{name}`")))
    }

    /// Subset by index list (used by Pareto filtering and matching).
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        Dataset {
            operator: self.operator,
            configs: idx.iter().map(|&i| self.configs[i]).collect(),
            behav: idx.iter().map(|&i| self.behav[i]).collect(),
            ppa: idx.iter().map(|&i| self.ppa[i]).collect(),
        }
    }

    /// Append another dataset of the same operator (deduplicating configs).
    pub fn merge(&mut self, other: &Dataset) -> Result<()> {
        if other.operator != self.operator {
            return Err(Error::Dataset("operator mismatch in merge".into()));
        }
        let mut seen: std::collections::HashSet<u64> =
            self.configs.iter().map(|c| c.as_uint()).collect();
        for i in 0..other.len() {
            if seen.insert(other.configs[i].as_uint()) {
                self.configs.push(other.configs[i]);
                self.behav.push(other.behav[i]);
                self.ppa.push(other.ppa[i]);
            }
        }
        Ok(())
    }

    /// JSON schema: `{"operator": "<name>", "configs": [uint...],
    /// "behav": [[4 floats]...], "ppa": [[5 floats]...]}`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("operator", Json::Str(self.operator.name())),
            (
                "configs",
                Json::Arr(
                    self.configs
                        .iter()
                        .map(|c| Json::Num(c.as_uint() as f64))
                        .collect(),
                ),
            ),
            (
                "behav",
                Json::Arr(
                    self.behav.iter().map(|b| Json::arr_f64(&b.to_array())).collect(),
                ),
            ),
            (
                "ppa",
                Json::Arr(self.ppa.iter().map(|p| Json::arr_f64(&p.to_array())).collect()),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Dataset> {
        let bad = |m: &str| Error::Dataset(format!("dataset json: {m}"));
        let operator = Operator::from_name(
            v.get("operator").and_then(Json::as_str).ok_or_else(|| bad("operator"))?,
        )?;
        let l = operator.config_len();
        let configs = v
            .get("configs")
            .and_then(Json::as_arr)
            .ok_or_else(|| bad("configs"))?
            .iter()
            .map(|c| {
                c.as_u64()
                    .ok_or_else(|| bad("config uint"))
                    .and_then(|u| AxoConfig::new(u, l))
            })
            .collect::<Result<Vec<_>>>()?;
        let rows = |key: &str, n: usize| -> Result<Vec<Vec<f64>>> {
            v.get(key)
                .and_then(Json::as_arr)
                .ok_or_else(|| bad(key))?
                .iter()
                .map(|row| {
                    let r: Option<Vec<f64>> =
                        row.as_arr().map(|a| a.iter().filter_map(Json::as_f64).collect());
                    match r {
                        Some(vals) if vals.len() == n => Ok(vals),
                        _ => Err(bad(&format!("{key} row"))),
                    }
                })
                .collect()
        };
        let behav = rows("behav", 4)?
            .into_iter()
            .map(|r| BehavMetrics::from_array([r[0], r[1], r[2], r[3]]))
            .collect();
        let ppa = rows("ppa", 5)?
            .into_iter()
            .map(|r| PpaMetrics::from_array([r[0], r[1], r[2], r[3], r[4]]))
            .collect();
        Dataset::new(operator, configs, behav, ppa)
    }

    pub fn save_json(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load_json(path: &Path) -> Result<Dataset> {
        // Only a genuinely absent file is `ArtifactMissing`; permission
        // and short-read faults surface as `ArtifactCorrupt` with the OS
        // reason, so callers (the dataset store in particular) never
        // silently re-characterize over a real I/O fault.
        let text = std::fs::read_to_string(path).map_err(|e| {
            if e.kind() == std::io::ErrorKind::NotFound {
                Error::ArtifactMissing { path: path.to_path_buf() }
            } else {
                Error::ArtifactCorrupt { path: path.to_path_buf(), reason: e.to_string() }
            }
        })?;
        let v = Json::parse(&text).map_err(|e| Error::ArtifactCorrupt {
            path: path.to_path_buf(),
            reason: e.to_string(),
        })?;
        Self::from_json(&v).map_err(|e| Error::ArtifactCorrupt {
            path: path.to_path_buf(),
            reason: e.to_string(),
        })
    }

    /// CSV export: `config_uint, config_bits, behav..., ppa...`.
    pub fn save_csv(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        write!(w, "config_uint,config_bits")?;
        for n in BehavMetrics::NAMES {
            write!(w, ",{n}")?;
        }
        for n in PpaMetrics::NAMES {
            write!(w, ",{n}")?;
        }
        writeln!(w)?;
        for i in 0..self.len() {
            write!(w, "{},{}", self.configs[i].as_uint(), self.configs[i])?;
            for v in self.behav[i].to_array() {
                write!(w, ",{v}")?;
            }
            for v in self.ppa[i].to_array() {
                write!(w, ",{v}")?;
            }
            writeln!(w)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        let cfgs = vec![AxoConfig::accurate(4), AxoConfig::new(0b0111, 4).unwrap()];
        let behav = vec![
            BehavMetrics::ZERO,
            BehavMetrics { avg_abs_err: 1.0, avg_abs_rel_err: 0.1, max_abs_err: 8.0, err_prob: 0.5 },
        ];
        let ppa = vec![
            PpaMetrics { luts: 4.0, cpd_ns: 0.75, power_mw: 0.8, pdp: 0.6, pdplut: 2.4 },
            PpaMetrics { luts: 3.0, cpd_ns: 0.70, power_mw: 0.7, pdp: 0.49, pdplut: 1.47 },
        ];
        Dataset::new(Operator::ADD4, cfgs, behav, ppa).unwrap()
    }

    #[test]
    fn rejects_mismatched_lengths() {
        let d = tiny();
        assert!(Dataset::new(d.operator, d.configs.clone(), vec![], d.ppa.clone()).is_err());
    }

    #[test]
    fn headline_points() {
        let d = tiny();
        assert_eq!(d.headline_points(), vec![[2.4, 0.0], [1.47, 0.1]]);
    }

    #[test]
    fn column_lookup() {
        let d = tiny();
        assert_eq!(d.column("err_prob").unwrap(), vec![0.0, 0.5]);
        assert_eq!(d.column("luts").unwrap(), vec![4.0, 3.0]);
        assert!(d.column("nope").is_err());
    }

    #[test]
    fn json_roundtrip_and_csv() {
        let dir = crate::util::tempdir::TempDir::new().unwrap();
        let d = tiny();
        let jp = dir.path().join("d.json");
        d.save_json(&jp).unwrap();
        let d2 = Dataset::load_json(&jp).unwrap();
        assert_eq!(d2.len(), 2);
        assert_eq!(d2.configs, d.configs);
        let cp = dir.path().join("d.csv");
        d.save_csv(&cp).unwrap();
        let text = std::fs::read_to_string(cp).unwrap();
        assert!(text.starts_with("config_uint,config_bits,avg_abs_err"));
        assert_eq!(text.lines().count(), 3);
    }

    #[test]
    fn load_json_distinguishes_missing_from_io_faults() {
        let dir = crate::util::tempdir::TempDir::new().unwrap();
        // Absent file: missing.
        assert!(matches!(
            Dataset::load_json(&dir.path().join("absent.json")),
            Err(Error::ArtifactMissing { .. })
        ));
        // Reading a directory is an I/O fault, not a missing artifact —
        // it must carry the OS reason, never trigger re-characterization.
        let sub = dir.path().join("is_a_dir.json");
        std::fs::create_dir(&sub).unwrap();
        match Dataset::load_json(&sub) {
            Err(Error::ArtifactCorrupt { reason, .. }) => assert!(!reason.is_empty()),
            other => panic!("expected ArtifactCorrupt, got {other:?}"),
        }
        // Unparseable content is corrupt too.
        let bad = dir.path().join("bad.json");
        std::fs::write(&bad, "{not json").unwrap();
        assert!(matches!(
            Dataset::load_json(&bad),
            Err(Error::ArtifactCorrupt { .. })
        ));
    }

    #[test]
    fn merge_dedups() {
        let mut d = tiny();
        let other = tiny();
        d.merge(&other).unwrap();
        assert_eq!(d.len(), 2);
        let sub = other.subset(&[1]);
        assert_eq!(sub.len(), 1);
        assert_eq!(sub.configs[0].as_uint(), 0b0111);
    }
}
