//! The characterization pipeline: configs → [`Dataset`].
//!
//! BEHAV metrics come from a pluggable [`Backend`]; PPA always comes from
//! the analytical synthesis estimator (it is cheap and deterministic).
//! The PJRT backend is injected as a [`BehavEvaluator`] trait object so the
//! pipeline does not depend on the runtime module (and tests can inject
//! failing/fake evaluators).

use super::{behav, BehavMetrics, Dataset, InputSet};
use crate::error::Result;
use crate::operator::{AxoConfig, Operator};
use crate::synth;

/// Behavioral evaluation backend interface (implemented by
/// `runtime::AxoEvalExec` for the AOT/PJRT path). Deliberately not
/// `Send`/`Sync`-bounded: the PJRT wrapper holds raw FFI handles and is
/// driven synchronously from the pipeline.
pub trait BehavEvaluator {
    fn eval(
        &self,
        op: Operator,
        configs: &[AxoConfig],
        inputs: &InputSet,
    ) -> Result<Vec<BehavMetrics>>;
}

/// Which engine computes BEHAV metrics.
pub enum Backend<'a> {
    /// Scoped-thread-parallel bit-exact native simulation.
    Native,
    /// An injected evaluator — in production the AOT-compiled Pallas
    /// `axo_eval` executable running on the PJRT CPU client.
    Evaluator(&'a dyn BehavEvaluator),
}

impl Backend<'_> {
    /// Human-readable backend tag for logs and stamps.
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Native => "native",
            Backend::Evaluator(_) => "evaluator",
        }
    }

    /// Capability probe, build-time half: true when PJRT support was
    /// compiled into this binary (`--features pjrt`).
    pub fn pjrt_compiled() -> bool {
        cfg!(feature = "pjrt")
    }

    /// Capability probe, runtime half: true when the PJRT path is fully
    /// usable — compiled in, the AOT artifacts are present, *and* a real
    /// PJRT backend is linked (the vendored `xla` stub is not one). Tests
    /// and CLI paths use this to *skip* (not fail) the PJRT route.
    pub fn pjrt_ready(artifacts_dir: &std::path::Path) -> bool {
        Self::pjrt_compiled()
            && artifacts_dir.join("manifest.json").exists()
            && pjrt_backend_linked()
    }
}

/// Whether the linked `xla` package can actually produce a PJRT client.
/// The hermetic stub always errors here; real bindings return a client.
#[cfg(feature = "pjrt")]
fn pjrt_backend_linked() -> bool {
    xla::PjRtClient::cpu().is_ok()
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_backend_linked() -> bool {
    false
}

/// Characterize `configs` of `op` over `inputs`.
pub fn characterize(
    op: Operator,
    configs: &[AxoConfig],
    inputs: &InputSet,
    backend: &Backend<'_>,
) -> Result<Dataset> {
    let behav = match backend {
        Backend::Native => behav::native_behav(op, configs, inputs),
        Backend::Evaluator(e) => e.eval(op, configs, inputs)?,
    };
    let ppa = synth::ppa_batch(op, configs);
    Dataset::new(op, configs.to_vec(), behav, ppa)
}

/// Characterize the operator's *entire* design space (exhaustive operators
/// only — panics for the 8×8 multiplier, which must be sampled).
pub fn characterize_all(
    op: Operator,
    inputs: &InputSet,
    backend: &Backend<'_>,
) -> Result<Dataset> {
    assert!(op.exhaustive(), "{op} design space must be sampled, not enumerated");
    let configs: Vec<AxoConfig> = AxoConfig::enumerate(op.config_len()).collect();
    characterize(op, &configs, inputs, backend)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::Error;

    #[test]
    fn backend_probe_is_consistent_with_build() {
        assert_eq!(Backend::pjrt_compiled(), cfg!(feature = "pjrt"));
        // Without a manifest the PJRT path is never "ready".
        assert!(!Backend::pjrt_ready(std::path::Path::new("/nonexistent")));
        assert_eq!(Backend::Native.name(), "native");
    }

    #[test]
    fn native_characterize_add4_exhaustive() {
        let inputs = InputSet::exhaustive(Operator::ADD4);
        let ds = characterize_all(Operator::ADD4, &inputs, &Backend::Native).unwrap();
        assert_eq!(ds.len(), 15);
        // Accurate config (uint 15) has zero error and max PDPLUT of its
        // carry-chain class.
        let acc_idx = ds.configs.iter().position(|c| c.is_accurate()).unwrap();
        assert_eq!(ds.behav[acc_idx], BehavMetrics::ZERO);
        assert!(ds.ppa[acc_idx].luts == 4.0);
    }

    struct FailingEval;
    impl BehavEvaluator for FailingEval {
        fn eval(
            &self,
            _op: Operator,
            _configs: &[AxoConfig],
            _inputs: &InputSet,
        ) -> Result<Vec<BehavMetrics>> {
            Err(Error::Xla("injected failure".into()))
        }
    }

    #[test]
    fn evaluator_failure_propagates() {
        let inputs = InputSet::exhaustive(Operator::ADD4);
        let cfgs = vec![AxoConfig::accurate(4)];
        let r = characterize(
            Operator::ADD4,
            &cfgs,
            &inputs,
            &Backend::Evaluator(&FailingEval),
        );
        assert!(matches!(r, Err(Error::Xla(_))));
    }

    struct ZeroEval;
    impl BehavEvaluator for ZeroEval {
        fn eval(
            &self,
            _op: Operator,
            configs: &[AxoConfig],
            _inputs: &InputSet,
        ) -> Result<Vec<BehavMetrics>> {
            Ok(vec![BehavMetrics::ZERO; configs.len()])
        }
    }

    #[test]
    fn injected_evaluator_is_used() {
        let inputs = InputSet::exhaustive(Operator::ADD4);
        let cfgs = vec![AxoConfig::new(1, 4).unwrap()];
        let ds =
            characterize(Operator::ADD4, &cfgs, &inputs, &Backend::Evaluator(&ZeroEval))
                .unwrap();
        assert_eq!(ds.behav[0], BehavMetrics::ZERO); // native would be nonzero
    }
}
