//! The characterization pipeline: configs → [`Dataset`].
//!
//! BEHAV metrics come from a pluggable [`Backend`]; PPA always comes from
//! the analytical synthesis estimator (it is cheap and deterministic).
//! The PJRT backend is injected as a [`BehavEvaluator`] trait object so the
//! pipeline does not depend on the runtime module (and tests can inject
//! failing/fake evaluators).
//!
//! The native path is **fused**: instead of one parallel fan-out for BEHAV
//! followed by a barrier and a second fan-out for PPA, each work-stealing
//! task computes *both* metric sets for its config sub-range in one pass
//! (nested parallel maps run serially inside pool workers, so the fused
//! task is the only fan-out). Per-config metrics are independent, so the
//! fused partition is bit-identical to the two-pass sweep; each task also
//! clocks its two phases, and the summed [`PhaseTiming`] flows through
//! `engine::CacheStats` into `/metrics`.

use super::behav::BehavBackend;
use super::{behav, BehavMetrics, Dataset, InputSet};
use crate::error::Result;
use crate::obs;
use crate::operator::{AxoConfig, Operator};
use crate::synth::{self, PpaBackend, PpaMetrics};
use std::time::Instant;

/// Behavioral evaluation backend interface (implemented by
/// `runtime::AxoEvalExec` for the AOT/PJRT path). Deliberately not
/// `Send`/`Sync`-bounded: the PJRT wrapper holds raw FFI handles and is
/// driven synchronously from the pipeline.
pub trait BehavEvaluator {
    fn eval(
        &self,
        op: Operator,
        configs: &[AxoConfig],
        inputs: &InputSet,
    ) -> Result<Vec<BehavMetrics>>;
}

/// Which engine computes BEHAV metrics.
pub enum Backend<'a> {
    /// Scoped-thread-parallel bit-exact native simulation.
    Native,
    /// An injected evaluator — in production the AOT-compiled Pallas
    /// `axo_eval` executable running on the PJRT CPU client.
    Evaluator(&'a dyn BehavEvaluator),
}

impl Backend<'_> {
    /// Human-readable backend tag for logs and stamps.
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Native => "native",
            Backend::Evaluator(_) => "evaluator",
        }
    }

    /// Capability probe, build-time half: true when PJRT support was
    /// compiled into this binary (`--features pjrt`).
    pub fn pjrt_compiled() -> bool {
        cfg!(feature = "pjrt")
    }

    /// Capability probe, runtime half: true when the PJRT path is fully
    /// usable — compiled in, the AOT artifacts are present, *and* a real
    /// PJRT backend is linked (the vendored `xla` stub is not one). Tests
    /// and CLI paths use this to *skip* (not fail) the PJRT route.
    pub fn pjrt_ready(artifacts_dir: &std::path::Path) -> bool {
        Self::pjrt_compiled()
            && artifacts_dir.join("manifest.json").exists()
            && pjrt_backend_linked()
    }
}

/// Whether the linked `xla` package can actually produce a PJRT client.
/// The hermetic stub always errors here; real bindings return a client.
#[cfg(feature = "pjrt")]
fn pjrt_backend_linked() -> bool {
    xla::PjRtClient::cpu().is_ok()
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_backend_linked() -> bool {
    false
}

/// Aggregate per-phase wall time of one characterization, summed across
/// its work-stealing tasks (CPU-seconds-style totals, not elapsed time —
/// concurrent shards each contribute their own clock).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PhaseTiming {
    /// Nanoseconds spent computing BEHAV metrics.
    pub behav_ns: u64,
    /// Nanoseconds spent computing PPA metrics.
    pub ppa_ns: u64,
}

impl PhaseTiming {
    fn add(&mut self, other: PhaseTiming) {
        self.behav_ns += other.behav_ns;
        self.ppa_ns += other.ppa_ns;
    }
}

/// Config sub-range per fused task when the caller did not shard
/// explicitly: a multiple of the 64-lane plane block, coarse enough that
/// per-task setup amortizes.
const FUSED_GRAIN: usize = 256;

/// Both metric sets for one config slice in one pass, each phase clocked.
/// Called from inside pool workers, where the nested BEHAV/PPA parallel
/// maps run serially inline — so one task computes everything its slice
/// needs with no intermediate barrier. `ctx` parents the per-phase spans
/// under the caller's span across the pool-thread boundary; both phase
/// times also land in the process-global shard histograms.
fn fused_slice(
    op: Operator,
    configs: &[AxoConfig],
    inputs: &InputSet,
    behav: BehavBackend,
    ppa: PpaBackend,
    ctx: obs::SpanCtx,
) -> (Vec<BehavMetrics>, Vec<PpaMetrics>, PhaseTiming) {
    let mut sp = obs::span_under(ctx, obs::n::CHARAC_BEHAV);
    sp.set_arg(configs.len() as u64);
    let t0 = Instant::now();
    let behav_rows = behav::native_behav_with(op, configs, inputs, behav);
    let behav_ns = t0.elapsed().as_nanos() as u64;
    drop(sp);
    let mut sp = obs::span_under(ctx, obs::n::CHARAC_PPA);
    sp.set_arg(configs.len() as u64);
    let t1 = Instant::now();
    let ppa_rows = synth::ppa_batch_with(op, configs, ppa);
    let ppa_ns = t1.elapsed().as_nanos() as u64;
    drop(sp);
    obs::metrics().behav_shard_ns.record(behav_ns);
    obs::metrics().ppa_shard_ns.record(ppa_ns);
    (behav_rows, ppa_rows, PhaseTiming { behav_ns, ppa_ns })
}

/// Fused native characterization with explicit backends and a phase-time
/// readout: one work-stealing fan-out whose tasks each compute BEHAV
/// *and* PPA for a [`FUSED_GRAIN`]-sized sub-range, merged order-stably.
pub fn characterize_timed(
    op: Operator,
    configs: &[AxoConfig],
    inputs: &InputSet,
    behav: BehavBackend,
    ppa: PpaBackend,
) -> Result<(Dataset, PhaseTiming)> {
    let ctx = obs::current();
    let ranges = shard_ranges(configs.len(), FUSED_GRAIN);
    if ranges.len() <= 1 {
        let (b, p, timing) = fused_slice(op, configs, inputs, behav, ppa, ctx);
        return Ok((Dataset::new(op, configs.to_vec(), b, p)?, timing));
    }
    let parts = crate::util::par::parallel_map_dynamic(&ranges, 1, |_, r| {
        fused_slice(op, &configs[r.clone()], inputs, behav, ppa, ctx)
    });
    let mut behav_rows = Vec::with_capacity(configs.len());
    let mut ppa_rows = Vec::with_capacity(configs.len());
    let mut timing = PhaseTiming::default();
    for (b, p, t) in parts {
        behav_rows.extend(b);
        ppa_rows.extend(p);
        timing.add(t);
    }
    Ok((Dataset::new(op, configs.to_vec(), behav_rows, ppa_rows)?, timing))
}

/// Characterize `configs` of `op` over `inputs`.
pub fn characterize(
    op: Operator,
    configs: &[AxoConfig],
    inputs: &InputSet,
    backend: &Backend<'_>,
) -> Result<Dataset> {
    match backend {
        Backend::Native => characterize_as(op, configs, inputs, BehavBackend::resolve(None)),
        Backend::Evaluator(e) => {
            let behav = e.eval(op, configs, inputs)?;
            let ppa = synth::ppa_batch(op, configs);
            Dataset::new(op, configs.to_vec(), behav, ppa)
        }
    }
}

/// [`characterize`] on the native backend with an explicit BEHAV
/// implementation (bit-sliced vs the scalar oracle); the PPA backend is
/// resolved from the environment/default.
pub fn characterize_as(
    op: Operator,
    configs: &[AxoConfig],
    inputs: &InputSet,
    behav: BehavBackend,
) -> Result<Dataset> {
    characterize_timed(op, configs, inputs, behav, PpaBackend::resolve(None))
        .map(|(ds, _)| ds)
}

/// Characterize the operator's *entire* design space (exhaustive operators
/// only — panics for the 8×8 multiplier, which must be sampled).
pub fn characterize_all(
    op: Operator,
    inputs: &InputSet,
    backend: &Backend<'_>,
) -> Result<Dataset> {
    assert!(op.exhaustive(), "{op} design space must be sampled, not enumerated");
    let configs: Vec<AxoConfig> = AxoConfig::enumerate(op.config_len()).collect();
    characterize(op, &configs, inputs, backend)
}

/// [`characterize_all`] on the native backend with an explicit BEHAV
/// implementation.
pub fn characterize_all_as(
    op: Operator,
    inputs: &InputSet,
    behav: BehavBackend,
) -> Result<Dataset> {
    assert!(op.exhaustive(), "{op} design space must be sampled, not enumerated");
    let configs: Vec<AxoConfig> = AxoConfig::enumerate(op.config_len()).collect();
    characterize_as(op, &configs, inputs, behav)
}

/// Deterministic contiguous shard ranges covering `0..n`: every shard but
/// the last is exactly `shard_size` long, independent of pool width, so a
/// shard plan is a pure function of `(n, shard_size)`.
pub fn shard_ranges(n: usize, shard_size: usize) -> Vec<std::ops::Range<usize>> {
    let s = shard_size.max(1);
    let mut out = Vec::with_capacity(n.div_ceil(s));
    let mut start = 0;
    while start < n {
        let end = (start + s).min(n);
        out.push(start..end);
        start = end;
    }
    out
}

/// Characterize `configs` natively in deterministic sub-range shards
/// executed by the work-stealing pool, merged order-stably into one
/// [`Dataset`] — bit-identical to [`characterize`] over the whole slice
/// (per-config metrics are independent and the shared input-derived
/// precomputations are pure functions of `inputs`). Native-only: the
/// injected-evaluator backend is not `Sync` and stays on the unsharded
/// path. Shards run serially inside pool workers (no nested fan-out).
pub fn characterize_sharded(
    op: Operator,
    configs: &[AxoConfig],
    inputs: &InputSet,
    shard_size: usize,
) -> Result<Dataset> {
    characterize_sharded_as(op, configs, inputs, shard_size, BehavBackend::resolve(None))
}

/// [`characterize_sharded`] with an explicit BEHAV implementation (the
/// engine threads `[charac] behav` through here); the PPA backend is
/// resolved from the environment/default.
pub fn characterize_sharded_as(
    op: Operator,
    configs: &[AxoConfig],
    inputs: &InputSet,
    shard_size: usize,
    behav: BehavBackend,
) -> Result<Dataset> {
    characterize_sharded_timed(
        op,
        configs,
        inputs,
        shard_size,
        behav,
        PpaBackend::resolve(None),
    )
    .map(|(ds, _)| ds)
}

/// The fused sharded pipeline with explicit backends and a phase-time
/// readout: every shard is one work-stealing task computing both metric
/// sets for its sub-range (no barrier between a BEHAV sweep and a PPA
/// sweep), merged order-stably — bit-identical to the whole-slice path.
pub fn characterize_sharded_timed(
    op: Operator,
    configs: &[AxoConfig],
    inputs: &InputSet,
    shard_size: usize,
    behav: BehavBackend,
    ppa: PpaBackend,
) -> Result<(Dataset, PhaseTiming)> {
    let ctx = obs::current();
    let ranges = shard_ranges(configs.len(), shard_size);
    if ranges.len() <= 1 {
        return characterize_timed(op, configs, inputs, behav, ppa);
    }
    let shards = crate::util::par::parallel_map_dynamic(&ranges, 1, |_, r| {
        fused_slice(op, &configs[r.clone()], inputs, behav, ppa, ctx)
    });
    let mut behav_rows = Vec::with_capacity(configs.len());
    let mut ppa_rows = Vec::with_capacity(configs.len());
    let mut timing = PhaseTiming::default();
    for (b, p, t) in shards {
        behav_rows.extend(b);
        ppa_rows.extend(p);
        timing.add(t);
    }
    Ok((Dataset::new(op, configs.to_vec(), behav_rows, ppa_rows)?, timing))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::Error;

    #[test]
    fn backend_probe_is_consistent_with_build() {
        assert_eq!(Backend::pjrt_compiled(), cfg!(feature = "pjrt"));
        // Without a manifest the PJRT path is never "ready".
        assert!(!Backend::pjrt_ready(std::path::Path::new("/nonexistent")));
        assert_eq!(Backend::Native.name(), "native");
    }

    #[test]
    fn native_characterize_add4_exhaustive() {
        let inputs = InputSet::exhaustive(Operator::ADD4);
        let ds = characterize_all(Operator::ADD4, &inputs, &Backend::Native).unwrap();
        assert_eq!(ds.len(), 15);
        // Accurate config (uint 15) has zero error and max PDPLUT of its
        // carry-chain class.
        let acc_idx = ds.configs.iter().position(|c| c.is_accurate()).unwrap();
        assert_eq!(ds.behav[acc_idx], BehavMetrics::ZERO);
        assert!(ds.ppa[acc_idx].luts == 4.0);
    }

    struct FailingEval;
    impl BehavEvaluator for FailingEval {
        fn eval(
            &self,
            _op: Operator,
            _configs: &[AxoConfig],
            _inputs: &InputSet,
        ) -> Result<Vec<BehavMetrics>> {
            Err(Error::Xla("injected failure".into()))
        }
    }

    #[test]
    fn evaluator_failure_propagates() {
        let inputs = InputSet::exhaustive(Operator::ADD4);
        let cfgs = vec![AxoConfig::accurate(4)];
        let r = characterize(
            Operator::ADD4,
            &cfgs,
            &inputs,
            &Backend::Evaluator(&FailingEval),
        );
        assert!(matches!(r, Err(Error::Xla(_))));
    }

    struct ZeroEval;
    impl BehavEvaluator for ZeroEval {
        fn eval(
            &self,
            _op: Operator,
            configs: &[AxoConfig],
            _inputs: &InputSet,
        ) -> Result<Vec<BehavMetrics>> {
            Ok(vec![BehavMetrics::ZERO; configs.len()])
        }
    }

    #[test]
    fn shard_ranges_cover_exactly() {
        assert!(shard_ranges(0, 4).is_empty());
        assert_eq!(shard_ranges(10, 4), vec![0..4, 4..8, 8..10]);
        assert_eq!(shard_ranges(4, 4), vec![0..4]);
        assert_eq!(shard_ranges(3, 100), vec![0..3]);
        // Zero shard size is clamped to 1 rather than looping forever.
        assert_eq!(shard_ranges(2, 0), vec![0..1, 1..2]);
    }

    #[test]
    fn sharded_characterization_is_bit_identical() {
        let inputs = InputSet::exhaustive(Operator::MUL4);
        let cfgs: Vec<AxoConfig> = AxoConfig::enumerate(10).take(101).collect();
        let whole =
            characterize(Operator::MUL4, &cfgs, &inputs, &Backend::Native).unwrap();
        for shard_size in [7, 32, 101, 500] {
            let sharded =
                characterize_sharded(Operator::MUL4, &cfgs, &inputs, shard_size).unwrap();
            assert_eq!(sharded.configs, whole.configs, "shard {shard_size}");
            for i in 0..whole.len() {
                assert_eq!(
                    sharded.behav[i].to_array().map(f64::to_bits),
                    whole.behav[i].to_array().map(f64::to_bits),
                    "behav row {i}, shard {shard_size}"
                );
                assert_eq!(
                    sharded.ppa[i].to_array().map(f64::to_bits),
                    whole.ppa[i].to_array().map(f64::to_bits),
                    "ppa row {i}, shard {shard_size}"
                );
            }
        }
    }

    #[test]
    fn injected_evaluator_is_used() {
        let inputs = InputSet::exhaustive(Operator::ADD4);
        let cfgs = vec![AxoConfig::new(1, 4).unwrap()];
        let ds =
            characterize(Operator::ADD4, &cfgs, &inputs, &Backend::Evaluator(&ZeroEval))
                .unwrap();
        assert_eq!(ds.behav[0], BehavMetrics::ZERO); // native would be nonzero
    }
}
