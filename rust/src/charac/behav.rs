//! BEHAV error metrics (paper Eq. 1) — native computation.
//!
//! Metric definitions mirror `operator_model.behav_metrics`:
//! `avg_abs_rel_err` divides by `max(|exact|, 1)` to avoid the zero-output
//! singularity. Column order is shared with the Pallas kernel and the
//! golden fixtures.
//!
//! Two native backends compute the same metrics bit-for-bit
//! ([`BehavBackend`]): the per-vector *scalar* path (the verification
//! oracle) and the default *bit-sliced* path, which evaluates 64 test
//! vectors per operation in `u64` lanes via [`crate::operator::bitslice`]
//! and never materializes the per-vector output plane — for the 8×8
//! multiplier it also skips the ~19 MB i32 term-matrix stream entirely,
//! reconstructing `exact − approx` as the signed sum of the *removed*
//! partial-product planes. Equivalence rests on three invariants, asserted
//! by `rust/tests/behav_bitslice.rs`:
//! - absolute-error sums are exact integers in f64, so a per-block popcount
//!   sum lands on the identical float as per-vector accumulation;
//! - zero-error vectors contribute `+0.0` to the (non-negative) relative
//!   sum — the additive identity — so only nonzero lanes are folded;
//! - [`MetricAccumulator`] stripes its float sums by `index % STRIPES`, so
//!   both backends perform the identical rounding sequence per stripe.

use crate::operator::bitslice::{self, BitMatrix};
use crate::operator::{adder, multiplier, AxoConfig, Operator, OperatorKind};
use crate::util::par::parallel_map_dynamic;

/// Behavioral error metrics of one approximate design over an input set.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BehavMetrics {
    /// Mean absolute error.
    pub avg_abs_err: f64,
    /// Mean `|err| / max(|exact|, 1)` — the paper's headline BEHAV metric.
    pub avg_abs_rel_err: f64,
    /// Maximum absolute error.
    pub max_abs_err: f64,
    /// Error probability `P(err != 0)`.
    pub err_prob: f64,
}

impl BehavMetrics {
    pub const NAMES: [&'static str; 4] =
        ["avg_abs_err", "avg_abs_rel_err", "max_abs_err", "err_prob"];

    pub const ZERO: BehavMetrics = BehavMetrics {
        avg_abs_err: 0.0,
        avg_abs_rel_err: 0.0,
        max_abs_err: 0.0,
        err_prob: 0.0,
    };

    pub fn to_array(&self) -> [f64; 4] {
        [self.avg_abs_err, self.avg_abs_rel_err, self.max_abs_err, self.err_prob]
    }

    pub fn from_array(a: [f64; 4]) -> Self {
        BehavMetrics {
            avg_abs_err: a[0],
            avg_abs_rel_err: a[1],
            max_abs_err: a[2],
            err_prob: a[3],
        }
    }
}

/// Which native implementation computes BEHAV metrics. Both produce
/// bit-identical [`BehavMetrics`]; the scalar path is the oracle the
/// bit-sliced default is verified against.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BehavBackend {
    /// Per-vector evaluation (`adder::eval_one`, i32 term-matrix scan).
    Scalar,
    /// 64 vectors per operation in u64 lanes (`operator::bitslice`).
    Bitslice,
}

impl BehavBackend {
    pub fn name(self) -> &'static str {
        match self {
            BehavBackend::Scalar => "scalar",
            BehavBackend::Bitslice => "bitslice",
        }
    }

    pub fn from_name(s: &str) -> Option<BehavBackend> {
        match s {
            "scalar" => Some(BehavBackend::Scalar),
            "bitslice" => Some(BehavBackend::Bitslice),
            _ => None,
        }
    }

    /// Resolution order: the `REPRO_BEHAV` escape hatch, then the caller's
    /// preference (typically `[charac] behav` from expcfg), then the
    /// bit-sliced default.
    pub fn resolve(preferred: Option<BehavBackend>) -> BehavBackend {
        if let Ok(v) = std::env::var("REPRO_BEHAV") {
            match BehavBackend::from_name(v.trim()) {
                Some(b) => return b,
                None => eprintln!(
                    "warning: ignoring invalid REPRO_BEHAV={v:?} \
                     (expected `scalar` or `bitslice`)"
                ),
            }
        }
        preferred.unwrap_or(BehavBackend::Bitslice)
    }
}

/// Independent accumulation lanes inside [`MetricAccumulator`]: vector `t`
/// folds into stripe `t % STRIPES`, and `finalize` reduces the stripes in a
/// fixed tree. Striping breaks the serial f64-add latency chain that would
/// otherwise bound the scalar hot loop *and* pins an accumulation order
/// both backends reproduce exactly (see the module docs).
const STRIPES: usize = 4;

/// Streaming accumulator — lets backends fold (exact, approx) pairs without
/// materializing the (B, T) output plane.
#[derive(Debug, Default, Clone, Copy)]
pub struct MetricAccumulator {
    sum_abs: [f64; STRIPES],
    sum_rel: [f64; STRIPES],
    max_abs: f64,
    n_err: u64,
    n: u64,
}

impl MetricAccumulator {
    #[inline]
    pub fn push(&mut self, exact: i64, approx: i64) {
        let err = (exact - approx).abs() as f64;
        let k = (self.n as usize) & (STRIPES - 1);
        self.sum_abs[k] += err;
        self.sum_rel[k] += err / (exact.abs().max(1) as f64);
        if err > self.max_abs {
            self.max_abs = err;
        }
        self.n_err += (err > 0.0) as u64;
        self.n += 1;
    }

    /// Hot-loop variant: caller supplies |err| and the precomputed
    /// reciprocal of `max(|exact|, 1)` (§Perf L3-2).
    #[inline]
    pub fn push_with_recip(&mut self, err: f64, recip: f64) {
        let k = (self.n as usize) & (STRIPES - 1);
        self.sum_abs[k] += err;
        self.sum_rel[k] += err * recip;
        if err > self.max_abs {
            self.max_abs = err;
        }
        self.n_err += (err > 0.0) as u64;
        self.n += 1;
    }

    /// Bit-sliced fold of one 64-lane block of integer |err| magnitudes.
    ///
    /// `errs[t]` carries the magnitude of lane `t` in bits
    /// `shift..shift + MAG_BITS`; `nonzero` masks the lanes with any error
    /// (never a padding lane). Bit-identical to `lanes` ordered
    /// [`push_with_recip`] calls: the integer block sum is folded whole
    /// (exact in f64), zero lanes are skipped (`+0.0` is the identity on
    /// these non-negative sums), and nonzero lanes land in the same stripe,
    /// in the same order, as the scalar path.
    #[inline]
    pub(crate) fn push_block(
        &mut self,
        errs: &[u64; 64],
        shift: u32,
        mut nonzero: u64,
        lanes: usize,
        recip: &[f64],
    ) {
        debug_assert_eq!(recip.len(), lanes);
        let base = self.n as usize;
        let mut block_sum = 0u64;
        let mut block_max = 0u64;
        while nonzero != 0 {
            let t = nonzero.trailing_zeros() as usize;
            nonzero &= nonzero - 1;
            let e = (errs[t] >> shift) & 0xFFFF;
            block_sum += e;
            if e > block_max {
                block_max = e;
            }
            self.sum_rel[(base + t) & (STRIPES - 1)] += e as f64 * recip[t];
            self.n_err += 1;
        }
        self.sum_abs[0] += block_sum as f64;
        let m = block_max as f64;
        if m > self.max_abs {
            self.max_abs = m;
        }
        self.n += lanes as u64;
    }

    /// Bit-sliced fold of a block with no erring lanes.
    #[inline]
    pub(crate) fn push_zero_block(&mut self, lanes: usize) {
        self.n += lanes as u64;
    }

    pub fn finalize(&self) -> BehavMetrics {
        let n = self.n.max(1) as f64;
        let sum_abs =
            (self.sum_abs[0] + self.sum_abs[1]) + (self.sum_abs[2] + self.sum_abs[3]);
        let sum_rel =
            (self.sum_rel[0] + self.sum_rel[1]) + (self.sum_rel[2] + self.sum_rel[3]);
        BehavMetrics {
            avg_abs_err: sum_abs / n,
            avg_abs_rel_err: sum_rel / n,
            max_abs_err: self.max_abs,
            err_prob: self.n_err as f64 / n,
        }
    }
}

/// §Perf L3-3: exact sums and relative-error reciprocals depend only on
/// the shared input set — computed once per batch instead of per config.
fn adder_exact_recip(a: &[u32], b: &[u32]) -> (Vec<i64>, Vec<f64>) {
    let exact: Vec<i64> =
        a.iter().zip(b).map(|(&x, &y)| (x as i64) + (y as i64)).collect();
    let recip: Vec<f64> = exact.iter().map(|&e| 1.0 / (e.max(1) as f64)).collect();
    (exact, recip)
}

/// Native BEHAV metrics for a batch of adder configurations, on the backend
/// chosen by [`BehavBackend::resolve`] (bit-sliced unless overridden).
pub fn adder_behav(configs: &[AxoConfig], a: &[u32], b: &[u32]) -> Vec<BehavMetrics> {
    adder_behav_with(configs, a, b, BehavBackend::resolve(None))
}

/// [`adder_behav`] with an explicit backend.
pub fn adder_behav_with(
    configs: &[AxoConfig],
    a: &[u32],
    b: &[u32],
    backend: BehavBackend,
) -> Vec<BehavMetrics> {
    match backend {
        BehavBackend::Scalar => adder_behav_scalar(configs, a, b),
        BehavBackend::Bitslice => adder_behav_bitslice(configs, a, b),
    }
}

/// Scalar oracle: per-vector `adder::eval_one` scan.
///
/// Grain 1: each config scans the whole input set, so per-chunk cursor
/// overhead is negligible and work-stealing rebalances stragglers.
pub fn adder_behav_scalar(
    configs: &[AxoConfig],
    a: &[u32],
    b: &[u32],
) -> Vec<BehavMetrics> {
    let (exact, recip) = adder_exact_recip(a, b);
    parallel_map_dynamic(configs, 1, |_, cfg| {
        let mut acc = MetricAccumulator::default();
        for (((&ai, &bi), &ex), &r) in a.iter().zip(b).zip(&exact).zip(&recip) {
            let approx = adder::eval_one(cfg, ai as u64, bi as u64) as i64;
            acc.push_with_recip((ex - approx).abs() as f64, r);
        }
        acc.finalize()
    })
}

/// Bit-sliced adder path: operands are packed once per batch; per config,
/// the MUXCY recurrence, the exact/approx borrow-subtract and the |err|
/// fold all run on whole 64-lane planes. Magnitude planes of
/// `GROUP_BLOCKS` blocks share one unpack transpose.
pub fn adder_behav_bitslice(
    configs: &[AxoConfig],
    a: &[u32],
    b: &[u32],
) -> Vec<BehavMetrics> {
    assert_eq!(a.len(), b.len());
    let n_bits = configs.first().map_or(0, |c| c.len() as usize);
    let w = n_bits + 1;
    assert!(
        w <= bitslice::MAG_BITS,
        "bitsliced adder caps at {} bits",
        bitslice::MAG_BITS - 1
    );
    let (_, recip) = adder_exact_recip(a, b);
    let am = BitMatrix::pack(a.len(), n_bits, |t| a[t] as u64);
    let bm = BitMatrix::pack(b.len(), n_bits, |t| b[t] as u64);
    let n_blocks = am.n_blocks();
    // Exact-sum planes are config-independent: one ripple per block, shared
    // by the whole batch.
    let mut xplanes = vec![0u64; n_blocks * w];
    for (blk, x) in xplanes.chunks_exact_mut(w).enumerate() {
        bitslice::exact_sum_planes(am.block(blk), bm.block(blk), x);
    }
    parallel_map_dynamic(configs, 1, |_, cfg| {
        assert_eq!(cfg.len() as usize, n_bits, "mixed config widths in one batch");
        let mut keep = [0u64; bitslice::MAG_BITS];
        for (i, k) in keep.iter_mut().enumerate().take(n_bits) {
            *k = if cfg.keeps(i as u32) { !0u64 } else { 0 };
        }
        let mut acc = MetricAccumulator::default();
        let mut approx = [0u64; bitslice::MAG_BITS];
        let mut group = [0u64; 64];
        let mut errs = [0u64; 64];
        let mut nzs = [0u64; bitslice::GROUP_BLOCKS];
        let mut blk = 0usize;
        while blk < n_blocks {
            let gn = (n_blocks - blk).min(bitslice::GROUP_BLOCKS);
            let mut any = 0u64;
            for g in 0..gn {
                let bi = blk + g;
                bitslice::approx_sum_planes(
                    am.block(bi),
                    bm.block(bi),
                    &keep[..n_bits],
                    &mut approx[..w],
                );
                nzs[g] = bitslice::abs_diff_into(
                    &xplanes[bi * w..(bi + 1) * w],
                    &approx[..w],
                    &mut group[g * bitslice::MAG_BITS..(g + 1) * bitslice::MAG_BITS],
                );
                any |= nzs[g];
            }
            if any != 0 {
                bitslice::unpack64(&group, &mut errs);
            }
            for g in 0..gn {
                let bi = blk + g;
                let lanes = am.lanes_in(bi);
                if nzs[g] == 0 {
                    acc.push_zero_block(lanes);
                } else {
                    acc.push_block(
                        &errs,
                        (g * bitslice::MAG_BITS) as u32,
                        nzs[g],
                        lanes,
                        &recip[bi * 64..bi * 64 + lanes],
                    );
                }
            }
            blk += gn;
        }
        acc.finalize()
    })
}

/// Native BEHAV metrics for a batch of multiplier configurations, given the
/// precomputed `(T, L)` term matrix (shared across the batch). This is the
/// scalar oracle path; [`mult_behav_bitslice`] is the default.
///
/// Perf (EXPERIMENTS.md §Perf L3-1): the straightforward i64 scan streams
/// ~19 MB of term matrix per configuration. Narrowing to i32 (every term
/// and retained-sum of an M ≤ 8 multiplier fits comfortably) halves the
/// traffic, and the branch-free mask accumulation vectorizes.
pub fn mult_behav(configs: &[AxoConfig], terms: &[i64], l: usize) -> Vec<BehavMetrics> {
    assert_eq!(terms.len() % l, 0);
    // Narrow once: |term| < 2^15 and |config-sum| < 2^20 for M <= 8.
    let terms32: Vec<i32> = terms.iter().map(|&v| v as i32).collect();
    let exact: Vec<i32> = terms
        .chunks_exact(l)
        .map(|c| c.iter().sum::<i64>() as i32)
        .collect();
    // §Perf L3-2: the relative-error divisor depends only on the input,
    // not the configuration — precompute reciprocals once for the batch.
    let recip: Vec<f64> = exact.iter().map(|&e| 1.0 / (e.abs().max(1) as f64)).collect();
    let masks: Vec<Vec<i32>> = configs
        .iter()
        .map(|cfg| (0..l as u32).map(|k| -(cfg.keeps(k) as i32)).collect())
        .collect();
    let accs: Vec<MetricAccumulator> = parallel_map_dynamic(&masks, 1, |_, mask| {
        let mut acc = MetricAccumulator::default();
        for ((chunk, &ex), &r) in terms32.chunks_exact(l).zip(&exact).zip(&recip) {
            let mut approx = 0i32;
            for (v, m) in chunk.iter().zip(mask) {
                // branch-free retained-term accumulation
                approx += v & m;
            }
            acc.push_with_recip((ex - approx).abs() as f64, r);
        }
        acc
    });
    accs.iter().map(|a| a.finalize()).collect()
}

/// Two's-complement plane accumulator width for the multiplier's removed
/// terms: |any partial sum| ≤ (2^M − 1)² < 2^16, so 17 signed bits suffice
/// — one spare plane keeps the top strictly sign-extended.
const ACC_PLANES: usize = bitslice::MAG_BITS + 2;

/// Bit-sliced multiplier path, straight from the operands — the term
/// matrix is never built. Since `Σ all terms == a·b` exactly, the error of
/// a config is the signed sum of its *removed* terms; each removed LUT
/// `(i, j)` contributes its `a_i·b_j` AND plane(s) at weight ±2^(i+j) into
/// a per-block plane accumulator, whose |·| feeds the shared metric fold.
pub fn mult_behav_bitslice(
    m_bits: u32,
    configs: &[AxoConfig],
    a: &[i64],
    b: &[i64],
) -> Vec<BehavMetrics> {
    assert_eq!(a.len(), b.len());
    assert!(
        m_bits <= 8,
        "bitsliced multiplier magnitudes must fit {} planes",
        bitslice::MAG_BITS
    );
    let m = m_bits as usize;
    let l = m * (m + 1) / 2;
    let opmask = (1u64 << m_bits) - 1;
    let exact: Vec<i64> = a.iter().zip(b).map(|(&x, &y)| x * y).collect();
    let recip: Vec<f64> = exact.iter().map(|&e| 1.0 / (e.abs().max(1) as f64)).collect();
    // Low m bits of the two's-complement operands — same `au`/`bu` as
    // `multiplier::terms_one`.
    let am = BitMatrix::pack(a.len(), m, |t| (a[t] as u64) & opmask);
    let bm = BitMatrix::pack(b.len(), m, |t| (b[t] as u64) & opmask);
    let pairs = multiplier::pairs(m_bits);
    let n_blocks = am.n_blocks();
    parallel_map_dynamic(configs, 1, |_, cfg| {
        assert_eq!(cfg.len() as usize, l, "config length != L for mul{m_bits}");
        // (shift, i, j, negative) of every term this config removes.
        let removed: Vec<(usize, usize, usize, bool)> = pairs
            .iter()
            .enumerate()
            .filter(|&(k, _)| !cfg.keeps(k as u32))
            .map(|(_, &(i, j))| {
                let neg = (i == m_bits - 1) != (j == m_bits - 1);
                (i as usize + j as usize, i as usize, j as usize, neg)
            })
            .collect();
        let mut acc = MetricAccumulator::default();
        let mut group = [0u64; 64];
        let mut errs = [0u64; 64];
        let mut nzs = [0u64; bitslice::GROUP_BLOCKS];
        let mut blk = 0usize;
        while blk < n_blocks {
            let gn = (n_blocks - blk).min(bitslice::GROUP_BLOCKS);
            let mut any = 0u64;
            for g in 0..gn {
                let (ap, bp) = (am.block(blk + g), bm.block(blk + g));
                let mut w_acc = [0u64; ACC_PLANES];
                for &(shift, i, j, neg) in &removed {
                    if neg {
                        bitslice::acc_sub(&mut w_acc, ap[i] & bp[j], shift);
                        if i != j {
                            bitslice::acc_sub(&mut w_acc, ap[j] & bp[i], shift);
                        }
                    } else {
                        bitslice::acc_add(&mut w_acc, ap[i] & bp[j], shift);
                        if i != j {
                            bitslice::acc_add(&mut w_acc, ap[j] & bp[i], shift);
                        }
                    }
                }
                nzs[g] = bitslice::abs_acc_into(
                    &w_acc,
                    &mut group[g * bitslice::MAG_BITS..(g + 1) * bitslice::MAG_BITS],
                );
                any |= nzs[g];
            }
            if any != 0 {
                bitslice::unpack64(&group, &mut errs);
            }
            for g in 0..gn {
                let bi = blk + g;
                let lanes = am.lanes_in(bi);
                if nzs[g] == 0 {
                    acc.push_zero_block(lanes);
                } else {
                    acc.push_block(
                        &errs,
                        (g * bitslice::MAG_BITS) as u32,
                        nzs[g],
                        lanes,
                        &recip[bi * 64..bi * 64 + lanes],
                    );
                }
            }
            blk += gn;
        }
        acc.finalize()
    })
}

/// Dispatch over operator kind with the operator's default input set, on
/// the backend chosen by [`BehavBackend::resolve`].
pub fn native_behav(
    op: Operator,
    configs: &[AxoConfig],
    inputs: &super::InputSet,
) -> Vec<BehavMetrics> {
    native_behav_with(op, configs, inputs, BehavBackend::resolve(None))
}

/// [`native_behav`] with an explicit backend.
pub fn native_behav_with(
    op: Operator,
    configs: &[AxoConfig],
    inputs: &super::InputSet,
    backend: BehavBackend,
) -> Vec<BehavMetrics> {
    match op.kind {
        OperatorKind::UnsignedAdder => {
            let a: Vec<u32> = inputs.a.iter().map(|&v| v as u32).collect();
            let b: Vec<u32> = inputs.b.iter().map(|&v| v as u32).collect();
            adder_behav_with(configs, &a, &b, backend)
        }
        OperatorKind::SignedMultiplier => match backend {
            BehavBackend::Scalar => {
                let l = op.config_len() as usize;
                let terms = multiplier::term_matrix(op.bits, &inputs.a, &inputs.b);
                mult_behav(configs, &terms, l)
            }
            BehavBackend::Bitslice => {
                mult_behav_bitslice(op.bits, configs, &inputs.a, &inputs.b)
            }
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::charac::InputSet;

    #[test]
    fn accurate_configs_have_zero_error() {
        let inputs = InputSet::exhaustive(Operator::ADD4);
        for backend in [BehavBackend::Scalar, BehavBackend::Bitslice] {
            let m = native_behav_with(
                Operator::ADD4,
                &[AxoConfig::accurate(4)],
                &inputs,
                backend,
            );
            assert_eq!(m[0], BehavMetrics::ZERO, "{}", backend.name());
        }

        let inputs = InputSet::exhaustive(Operator::MUL4);
        for backend in [BehavBackend::Scalar, BehavBackend::Bitslice] {
            let m = native_behav_with(
                Operator::MUL4,
                &[AxoConfig::accurate(10)],
                &inputs,
                backend,
            );
            assert_eq!(m[0], BehavMetrics::ZERO, "{}", backend.name());
        }
    }

    #[test]
    fn metrics_known_values() {
        // exact [0, 2, -4], approx [1, 1, -2] -> errs 1,1,2.
        let mut acc = MetricAccumulator::default();
        acc.push(0, 1);
        acc.push(2, 1);
        acc.push(-4, -2);
        let m = acc.finalize();
        assert!((m.avg_abs_err - 4.0 / 3.0).abs() < 1e-12);
        assert!((m.avg_abs_rel_err - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(m.max_abs_err, 2.0);
        assert_eq!(m.err_prob, 1.0);
    }

    #[test]
    fn adder_error_grows_with_significance() {
        let inputs = InputSet::exhaustive(Operator::ADD8);
        let a: Vec<u32> = inputs.a.iter().map(|&v| v as u32).collect();
        let b: Vec<u32> = inputs.b.iter().map(|&v| v as u32).collect();
        let cfgs: Vec<AxoConfig> = [0u32, 3, 7]
            .iter()
            .map(|&k| AxoConfig::accurate(8).flipped(k).unwrap())
            .collect();
        for backend in [BehavBackend::Scalar, BehavBackend::Bitslice] {
            let m = adder_behav_with(&cfgs, &a, &b, backend);
            assert!(m[0].avg_abs_err < m[1].avg_abs_err, "{}", backend.name());
            assert!(m[1].avg_abs_err < m[2].avg_abs_err, "{}", backend.name());
        }
    }

    #[test]
    fn mult_behav_matches_scalar_eval() {
        let inputs = InputSet::exhaustive(Operator::MUL4);
        let terms = multiplier::term_matrix(4, &inputs.a, &inputs.b);
        let cfg = AxoConfig::new(0b1010101011, 10).unwrap();
        let fast = mult_behav(&[cfg], &terms, 10)[0];
        let mut acc = MetricAccumulator::default();
        for (&a, &b) in inputs.a.iter().zip(&inputs.b) {
            acc.push(a * b, multiplier::eval_one(4, &cfg, a, b));
        }
        assert_eq!(fast, acc.finalize());
    }

    #[test]
    fn backend_names_round_trip() {
        for b in [BehavBackend::Scalar, BehavBackend::Bitslice] {
            assert_eq!(BehavBackend::from_name(b.name()), Some(b));
        }
        assert_eq!(BehavBackend::from_name("pallas"), None);
        // The env escape hatch outranks the preference, which outranks the
        // bit-sliced default — only assertable when the env is not set.
        if std::env::var_os("REPRO_BEHAV").is_none() {
            assert_eq!(BehavBackend::resolve(None), BehavBackend::Bitslice);
            assert_eq!(
                BehavBackend::resolve(Some(BehavBackend::Scalar)),
                BehavBackend::Scalar
            );
        }
    }
}
