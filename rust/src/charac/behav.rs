//! BEHAV error metrics (paper Eq. 1) — native computation.
//!
//! Metric definitions mirror `operator_model.behav_metrics`:
//! `avg_abs_rel_err` divides by `max(|exact|, 1)` to avoid the zero-output
//! singularity. Column order is shared with the Pallas kernel and the
//! golden fixtures.

use crate::operator::{adder, multiplier, AxoConfig, Operator, OperatorKind};
use crate::util::par::parallel_map_dynamic;

/// Behavioral error metrics of one approximate design over an input set.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BehavMetrics {
    /// Mean absolute error.
    pub avg_abs_err: f64,
    /// Mean `|err| / max(|exact|, 1)` — the paper's headline BEHAV metric.
    pub avg_abs_rel_err: f64,
    /// Maximum absolute error.
    pub max_abs_err: f64,
    /// Error probability `P(err != 0)`.
    pub err_prob: f64,
}

impl BehavMetrics {
    pub const NAMES: [&'static str; 4] =
        ["avg_abs_err", "avg_abs_rel_err", "max_abs_err", "err_prob"];

    pub const ZERO: BehavMetrics = BehavMetrics {
        avg_abs_err: 0.0,
        avg_abs_rel_err: 0.0,
        max_abs_err: 0.0,
        err_prob: 0.0,
    };

    pub fn to_array(&self) -> [f64; 4] {
        [self.avg_abs_err, self.avg_abs_rel_err, self.max_abs_err, self.err_prob]
    }

    pub fn from_array(a: [f64; 4]) -> Self {
        BehavMetrics {
            avg_abs_err: a[0],
            avg_abs_rel_err: a[1],
            max_abs_err: a[2],
            err_prob: a[3],
        }
    }
}

/// Streaming accumulator — lets backends fold (exact, approx) pairs without
/// materializing the (B, T) output plane.
#[derive(Debug, Default, Clone, Copy)]
pub struct MetricAccumulator {
    sum_abs: f64,
    sum_rel: f64,
    max_abs: f64,
    n_err: u64,
    n: u64,
}

impl MetricAccumulator {
    #[inline]
    pub fn push(&mut self, exact: i64, approx: i64) {
        let err = (exact - approx).abs() as f64;
        self.sum_abs += err;
        self.sum_rel += err / (exact.abs().max(1) as f64);
        if err > self.max_abs {
            self.max_abs = err;
        }
        self.n_err += (err > 0.0) as u64;
        self.n += 1;
    }

    /// Hot-loop variant: caller supplies |err| and the precomputed
    /// reciprocal of `max(|exact|, 1)` (§Perf L3-2).
    #[inline]
    pub fn push_with_recip(&mut self, err: f64, recip: f64) {
        self.sum_abs += err;
        self.sum_rel += err * recip;
        if err > self.max_abs {
            self.max_abs = err;
        }
        self.n_err += (err > 0.0) as u64;
        self.n += 1;
    }

    pub fn finalize(&self) -> BehavMetrics {
        let n = self.n.max(1) as f64;
        BehavMetrics {
            avg_abs_err: self.sum_abs / n,
            avg_abs_rel_err: self.sum_rel / n,
            max_abs_err: self.max_abs,
            err_prob: self.n_err as f64 / n,
        }
    }
}

/// Native BEHAV metrics for a batch of adder configurations.
///
/// §Perf L3-3: exact sums and relative-error reciprocals depend only on
/// the shared input set — computed once per batch instead of per config.
/// Grain 1: each config scans the whole input set, so per-chunk cursor
/// overhead is negligible and work-stealing rebalances stragglers.
pub fn adder_behav(configs: &[AxoConfig], a: &[u32], b: &[u32]) -> Vec<BehavMetrics> {
    let exact: Vec<i64> = a.iter().zip(b).map(|(&x, &y)| (x as i64) + (y as i64)).collect();
    let recip: Vec<f64> = exact.iter().map(|&e| 1.0 / (e.max(1) as f64)).collect();
    parallel_map_dynamic(configs, 1, |_, cfg| {
        let mut acc = MetricAccumulator::default();
        for (((&ai, &bi), &ex), &r) in a.iter().zip(b).zip(&exact).zip(&recip) {
            let approx = adder::eval_one(cfg, ai as u64, bi as u64) as i64;
            acc.push_with_recip((ex - approx).abs() as f64, r);
        }
        acc.finalize()
    })
}

/// Native BEHAV metrics for a batch of multiplier configurations, given the
/// precomputed `(T, L)` term matrix (shared across the batch).
///
/// Perf (EXPERIMENTS.md §Perf L3-1): the straightforward i64 scan streams
/// ~19 MB of term matrix per configuration. Narrowing to i32 (every term
/// and retained-sum of an M ≤ 8 multiplier fits comfortably) halves the
/// traffic, and the branch-free mask accumulation vectorizes.
pub fn mult_behav(configs: &[AxoConfig], terms: &[i64], l: usize) -> Vec<BehavMetrics> {
    assert_eq!(terms.len() % l, 0);
    // Narrow once: |term| < 2^15 and |config-sum| < 2^20 for M <= 8.
    let terms32: Vec<i32> = terms.iter().map(|&v| v as i32).collect();
    let exact: Vec<i32> = terms
        .chunks_exact(l)
        .map(|c| c.iter().sum::<i64>() as i32)
        .collect();
    // §Perf L3-2: the relative-error divisor depends only on the input,
    // not the configuration — precompute reciprocals once for the batch.
    let recip: Vec<f64> = exact.iter().map(|&e| 1.0 / (e.abs().max(1) as f64)).collect();
    let masks: Vec<Vec<i32>> = configs
        .iter()
        .map(|cfg| (0..l as u32).map(|k| -(cfg.keeps(k) as i32)).collect())
        .collect();
    let accs: Vec<MetricAccumulator> = parallel_map_dynamic(&masks, 1, |_, mask| {
        let mut acc = MetricAccumulator::default();
        for ((chunk, &ex), &r) in terms32.chunks_exact(l).zip(&exact).zip(&recip) {
            let mut approx = 0i32;
            for (v, m) in chunk.iter().zip(mask) {
                // branch-free retained-term accumulation
                approx += v & m;
            }
            acc.push_with_recip((ex - approx).abs() as f64, r);
        }
        acc
    });
    accs.iter().map(|a| a.finalize()).collect()
}

/// Dispatch over operator kind with the operator's default input set.
pub fn native_behav(
    op: Operator,
    configs: &[AxoConfig],
    inputs: &super::InputSet,
) -> Vec<BehavMetrics> {
    match op.kind {
        OperatorKind::UnsignedAdder => {
            let a: Vec<u32> = inputs.a.iter().map(|&v| v as u32).collect();
            let b: Vec<u32> = inputs.b.iter().map(|&v| v as u32).collect();
            adder_behav(configs, &a, &b)
        }
        OperatorKind::SignedMultiplier => {
            let l = op.config_len() as usize;
            let terms = multiplier::term_matrix(op.bits, &inputs.a, &inputs.b);
            mult_behav(configs, &terms, l)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::charac::InputSet;

    #[test]
    fn accurate_configs_have_zero_error() {
        let inputs = InputSet::exhaustive(Operator::ADD4);
        let m = native_behav(Operator::ADD4, &[AxoConfig::accurate(4)], &inputs);
        assert_eq!(m[0], BehavMetrics::ZERO);

        let inputs = InputSet::exhaustive(Operator::MUL4);
        let m = native_behav(Operator::MUL4, &[AxoConfig::accurate(10)], &inputs);
        assert_eq!(m[0], BehavMetrics::ZERO);
    }

    #[test]
    fn metrics_known_values() {
        // exact [0, 2, -4], approx [1, 1, -2] -> errs 1,1,2.
        let mut acc = MetricAccumulator::default();
        acc.push(0, 1);
        acc.push(2, 1);
        acc.push(-4, -2);
        let m = acc.finalize();
        assert!((m.avg_abs_err - 4.0 / 3.0).abs() < 1e-12);
        assert!((m.avg_abs_rel_err - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(m.max_abs_err, 2.0);
        assert_eq!(m.err_prob, 1.0);
    }

    #[test]
    fn adder_error_grows_with_significance() {
        let inputs = InputSet::exhaustive(Operator::ADD8);
        let a: Vec<u32> = inputs.a.iter().map(|&v| v as u32).collect();
        let b: Vec<u32> = inputs.b.iter().map(|&v| v as u32).collect();
        let cfgs: Vec<AxoConfig> = [0u32, 3, 7]
            .iter()
            .map(|&k| AxoConfig::accurate(8).flipped(k).unwrap())
            .collect();
        let m = adder_behav(&cfgs, &a, &b);
        assert!(m[0].avg_abs_err < m[1].avg_abs_err);
        assert!(m[1].avg_abs_err < m[2].avg_abs_err);
    }

    #[test]
    fn mult_behav_matches_scalar_eval() {
        let inputs = InputSet::exhaustive(Operator::MUL4);
        let terms = multiplier::term_matrix(4, &inputs.a, &inputs.b);
        let cfg = AxoConfig::new(0b1010101011, 10).unwrap();
        let fast = mult_behav(&[cfg], &terms, 10)[0];
        let mut acc = MetricAccumulator::default();
        for (&a, &b) in inputs.a.iter().zip(&inputs.b) {
            acc.push(a * b, multiplier::eval_one(4, &cfg, a, b));
        }
        assert_eq!(fast, acc.finalize());
    }
}
