//! Characterization pipeline: configuration → (BEHAV, PPA) datasets.
//!
//! The paper characterizes every configuration by RTL simulation (BEHAV)
//! plus Vivado synthesis (PPA). Here BEHAV comes from bit-exact behavioral
//! simulation — either an injected evaluator ([`Backend::Evaluator`], in
//! production the AOT-compiled Pallas `axo_eval` executable via PJRT) or
//! the thread-parallel native default ([`Backend::Native`]), cross-checked
//! against each other in integration tests — and PPA from the analytical
//! synthesis estimator ([`crate::synth`]). `Backend::pjrt_ready` is the
//! capability probe backend selection goes through.

pub mod behav;
pub mod dataset;
pub mod inputs;
pub mod pipeline;

pub use behav::{BehavBackend, BehavMetrics};
pub use dataset::Dataset;
pub use inputs::InputSet;
pub use pipeline::{
    characterize, characterize_all, characterize_all_as, characterize_as,
    characterize_sharded, characterize_sharded_as, characterize_sharded_timed,
    characterize_timed, shard_ranges, Backend, PhaseTiming,
};
pub use crate::synth::PpaBackend;
