//! Characterization pipeline: configuration → (BEHAV, PPA) datasets.
//!
//! The paper characterizes every configuration by RTL simulation (BEHAV)
//! plus Vivado synthesis (PPA). Here BEHAV comes from bit-exact behavioral
//! simulation — either the AOT-compiled Pallas `axo_eval` executable via
//! PJRT ([`Backend::Pjrt`]) or the rayon-parallel native fallback
//! ([`Backend::Native`]), cross-checked against each other in integration
//! tests — and PPA from the analytical synthesis estimator ([`crate::synth`]).

pub mod behav;
pub mod dataset;
pub mod inputs;
pub mod pipeline;

pub use behav::BehavMetrics;
pub use dataset::Dataset;
pub use inputs::InputSet;
pub use pipeline::{characterize, characterize_all, Backend};
