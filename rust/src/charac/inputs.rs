//! Characterization input sets.
//!
//! Exhaustive input spaces for every operator except the 12-bit adder,
//! whose 2^24-pair space is sampled: the sample is generated *once* by
//! `aot.py` (seeded) and persisted as `artifacts/inputs_add12.bin` so the
//! python golden fixtures and the rust pipeline characterize against the
//! identical input set.
//!
//! `inputs_add12.bin` layout (little-endian):
//! `"AXIN"` magic · u32 version=1 · u32 n · u32 a[n] · u32 b[n].

use crate::error::{Error, Result};
use crate::operator::{adder, multiplier, Operator, OperatorKind};
use std::io::Read;
use std::path::Path;

/// A shared (a, b) operand set. Adders store unsigned values in `i64`.
#[derive(Debug, Clone)]
pub struct InputSet {
    pub a: Vec<i64>,
    pub b: Vec<i64>,
}

impl InputSet {
    pub fn len(&self) -> usize {
        self.a.len()
    }

    pub fn is_empty(&self) -> bool {
        self.a.is_empty()
    }

    /// Exhaustive input space (panics for operators that require sampling —
    /// use [`InputSet::load_add12`] or [`InputSet::for_operator`]).
    pub fn exhaustive(op: Operator) -> InputSet {
        match op.kind {
            OperatorKind::UnsignedAdder => {
                assert!(op.bits <= 8, "{op} input space needs the sampled set");
                let (a, b) = adder::exhaustive_inputs(op.bits);
                InputSet {
                    a: a.into_iter().map(|v| v as i64).collect(),
                    b: b.into_iter().map(|v| v as i64).collect(),
                }
            }
            OperatorKind::SignedMultiplier => {
                let (a, b) = multiplier::exhaustive_inputs(op.bits);
                InputSet { a, b }
            }
        }
    }

    /// Load the persisted 12-bit adder sample.
    pub fn load_add12(path: &Path) -> Result<InputSet> {
        let mut f = std::fs::File::open(path).map_err(|_| Error::ArtifactMissing {
            path: path.to_path_buf(),
        })?;
        let mut hdr = [0u8; 12];
        f.read_exact(&mut hdr).map_err(|e| corrupt(path, &e.to_string()))?;
        if &hdr[0..4] != b"AXIN" {
            return Err(corrupt(path, "bad magic"));
        }
        let version = u32::from_le_bytes(hdr[4..8].try_into().unwrap());
        if version != 1 {
            return Err(corrupt(path, &format!("unsupported version {version}")));
        }
        let n = u32::from_le_bytes(hdr[8..12].try_into().unwrap()) as usize;
        let mut buf = vec![0u8; n * 8];
        f.read_exact(&mut buf).map_err(|e| corrupt(path, &e.to_string()))?;
        let word = |k: usize| {
            u32::from_le_bytes(buf[4 * k..4 * k + 4].try_into().unwrap()) as i64
        };
        let a = (0..n).map(word).collect();
        let b = (n..2 * n).map(word).collect();
        Ok(InputSet { a, b })
    }

    /// The input set the paper's Table II experiments use for `op`,
    /// resolving the sampled 12-bit set from `artifacts_dir`.
    pub fn for_operator(op: Operator, artifacts_dir: &Path) -> Result<InputSet> {
        if op.kind == OperatorKind::UnsignedAdder && op.bits > 8 {
            Self::load_add12(&artifacts_dir.join("inputs_add12.bin"))
        } else {
            Ok(Self::exhaustive(op))
        }
    }
}

fn corrupt(path: &Path, reason: &str) -> Error {
    Error::ArtifactCorrupt { path: path.to_path_buf(), reason: reason.into() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn exhaustive_sizes() {
        assert_eq!(InputSet::exhaustive(Operator::ADD4).len(), 256);
        assert_eq!(InputSet::exhaustive(Operator::ADD8).len(), 65536);
        assert_eq!(InputSet::exhaustive(Operator::MUL4).len(), 256);
    }

    #[test]
    fn load_add12_roundtrip() {
        let dir = crate::util::tempdir::TempDir::new().unwrap();
        let path = dir.path().join("inputs_add12.bin");
        let a: Vec<u32> = vec![1, 2, 3];
        let b: Vec<u32> = vec![4000, 5, 4095];
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(b"AXIN").unwrap();
        f.write_all(&1u32.to_le_bytes()).unwrap();
        f.write_all(&3u32.to_le_bytes()).unwrap();
        for v in a.iter().chain(&b) {
            f.write_all(&v.to_le_bytes()).unwrap();
        }
        drop(f);
        let s = InputSet::load_add12(&path).unwrap();
        assert_eq!(s.a, vec![1, 2, 3]);
        assert_eq!(s.b, vec![4000, 5, 4095]);
    }

    #[test]
    fn load_add12_failures() {
        let dir = crate::util::tempdir::TempDir::new().unwrap();
        let missing = dir.path().join("nope.bin");
        assert!(matches!(
            InputSet::load_add12(&missing),
            Err(Error::ArtifactMissing { .. })
        ));
        let bad = dir.path().join("bad.bin");
        std::fs::write(&bad, b"NOPE00000000").unwrap();
        assert!(matches!(
            InputSet::load_add12(&bad),
            Err(Error::ArtifactCorrupt { .. })
        ));
    }
}
