//! Characterization input sets.
//!
//! Exhaustive input spaces for every operator except the 12-bit adder,
//! whose 2^24-pair space is sampled: the sample is generated *once* by
//! `aot.py` (seeded) and persisted as `artifacts/inputs_add12.bin` so the
//! python golden fixtures and the rust pipeline characterize against the
//! identical input set.
//!
//! `inputs_add12.bin` layout (little-endian):
//! `"AXIN"` magic · u32 version=1 · u32 n · u32 a[n] · u32 b[n].

use crate::error::{Error, Result};
use crate::operator::{adder, multiplier, Operator, OperatorKind};
use crate::util::rng::Rng;
use std::io::Read;
use std::path::Path;

/// Sample size and seed of the hermetic 12-bit fallback set (mirrors the
/// `max_samples`/`seed` defaults of `operator_model.adder_inputs`).
const SAMPLED_INPUTS: usize = 65_536;
const SAMPLED_SEED: u64 = 2023;

/// A shared (a, b) operand set. Adders store unsigned values in `i64`.
#[derive(Debug, Clone)]
pub struct InputSet {
    pub a: Vec<i64>,
    pub b: Vec<i64>,
}

impl InputSet {
    pub fn len(&self) -> usize {
        self.a.len()
    }

    pub fn is_empty(&self) -> bool {
        self.a.is_empty()
    }

    /// Exhaustive input space (panics for operators that require sampling —
    /// use [`InputSet::load_add12`] or [`InputSet::for_operator`]).
    pub fn exhaustive(op: Operator) -> InputSet {
        match op.kind {
            OperatorKind::UnsignedAdder => {
                assert!(op.bits <= 8, "{op} input space needs the sampled set");
                let (a, b) = adder::exhaustive_inputs(op.bits);
                InputSet {
                    a: a.into_iter().map(|v| v as i64).collect(),
                    b: b.into_iter().map(|v| v as i64).collect(),
                }
            }
            OperatorKind::SignedMultiplier => {
                let (a, b) = multiplier::exhaustive_inputs(op.bits);
                InputSet { a, b }
            }
        }
    }

    /// Load the persisted 12-bit adder sample.
    pub fn load_add12(path: &Path) -> Result<InputSet> {
        let mut f = std::fs::File::open(path).map_err(|_| Error::ArtifactMissing {
            path: path.to_path_buf(),
        })?;
        let mut hdr = [0u8; 12];
        f.read_exact(&mut hdr).map_err(|e| corrupt(path, &e.to_string()))?;
        if &hdr[0..4] != b"AXIN" {
            return Err(corrupt(path, "bad magic"));
        }
        let version = u32::from_le_bytes(hdr[4..8].try_into().unwrap());
        if version != 1 {
            return Err(corrupt(path, &format!("unsupported version {version}")));
        }
        let n = u32::from_le_bytes(hdr[8..12].try_into().unwrap()) as usize;
        let mut buf = vec![0u8; n * 8];
        f.read_exact(&mut buf).map_err(|e| corrupt(path, &e.to_string()))?;
        let word = |k: usize| {
            u32::from_le_bytes(buf[4 * k..4 * k + 4].try_into().unwrap()) as i64
        };
        let a = (0..n).map(word).collect();
        let b = (n..2 * n).map(word).collect();
        Ok(InputSet { a, b })
    }

    /// Deterministic seeded operand sample for adders too wide to
    /// enumerate — the hermetic fallback when `aot.py`'s persisted sample
    /// is absent. The stream comes from the crate [`Rng`], so it is *not*
    /// bit-identical to the numpy sample; cross-language golden tests
    /// always read the persisted `inputs_add12.bin` instead.
    pub fn sampled_adder(n_bits: u32, n: usize, seed: u64) -> InputSet {
        let mask = (1u64 << n_bits) - 1;
        let mut rng = Rng::seed_from_u64(seed);
        let mut a = Vec::with_capacity(n);
        let mut b = Vec::with_capacity(n);
        for _ in 0..n {
            let idx = rng.next_u64();
            a.push((idx & mask) as i64);
            b.push(((idx >> n_bits) & mask) as i64);
        }
        InputSet { a, b }
    }

    /// The input set the paper's Table II experiments use for `op`:
    /// exhaustive spaces directly, the 12-bit adder from the persisted
    /// `artifacts_dir` sample when present, else the seeded native
    /// fallback — so the hermetic build characterizes every operator
    /// without `make artifacts`.
    pub fn for_operator(op: Operator, artifacts_dir: &Path) -> Result<InputSet> {
        if op.kind == OperatorKind::UnsignedAdder && op.bits > 8 {
            let path = artifacts_dir.join("inputs_add12.bin");
            if path.exists() {
                Self::load_add12(&path)
            } else {
                // Provenance matters: the native sample differs from the
                // persisted numpy one, so say which set is in play.
                eprintln!(
                    "note: {} not found — characterizing {op} on the seeded \
                     native input sample (hermetic fallback)",
                    path.display()
                );
                Ok(Self::sampled_adder(op.bits, SAMPLED_INPUTS, SAMPLED_SEED))
            }
        } else {
            Ok(Self::exhaustive(op))
        }
    }
}

fn corrupt(path: &Path, reason: &str) -> Error {
    Error::ArtifactCorrupt { path: path.to_path_buf(), reason: reason.into() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn exhaustive_sizes() {
        assert_eq!(InputSet::exhaustive(Operator::ADD4).len(), 256);
        assert_eq!(InputSet::exhaustive(Operator::ADD8).len(), 65536);
        assert_eq!(InputSet::exhaustive(Operator::MUL4).len(), 256);
    }

    #[test]
    fn load_add12_roundtrip() {
        let dir = crate::util::tempdir::TempDir::new().unwrap();
        let path = dir.path().join("inputs_add12.bin");
        let a: Vec<u32> = vec![1, 2, 3];
        let b: Vec<u32> = vec![4000, 5, 4095];
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(b"AXIN").unwrap();
        f.write_all(&1u32.to_le_bytes()).unwrap();
        f.write_all(&3u32.to_le_bytes()).unwrap();
        for v in a.iter().chain(&b) {
            f.write_all(&v.to_le_bytes()).unwrap();
        }
        drop(f);
        let s = InputSet::load_add12(&path).unwrap();
        assert_eq!(s.a, vec![1, 2, 3]);
        assert_eq!(s.b, vec![4000, 5, 4095]);
    }

    #[test]
    fn sampled_adder_is_deterministic_and_in_range() {
        let a = InputSet::sampled_adder(12, 1000, 7);
        let b = InputSet::sampled_adder(12, 1000, 7);
        assert_eq!(a.a, b.a);
        assert_eq!(a.b, b.b);
        assert_eq!(a.len(), 1000);
        assert!(a.a.iter().chain(&a.b).all(|&v| (0..4096).contains(&v)));
        let c = InputSet::sampled_adder(12, 1000, 8);
        assert_ne!(a.a, c.a);
    }

    #[test]
    fn for_operator_falls_back_without_artifacts() {
        let dir = crate::util::tempdir::TempDir::new().unwrap();
        let s = InputSet::for_operator(Operator::ADD12, dir.path()).unwrap();
        assert_eq!(s.len(), 65_536);
        // Exhaustive operators never consult the artifacts dir.
        let e = InputSet::for_operator(Operator::ADD4, dir.path()).unwrap();
        assert_eq!(e.len(), 256);
    }

    #[test]
    fn load_add12_failures() {
        let dir = crate::util::tempdir::TempDir::new().unwrap();
        let missing = dir.path().join("nope.bin");
        assert!(matches!(
            InputSet::load_add12(&missing),
            Err(Error::ArtifactMissing { .. })
        ));
        let bad = dir.path().join("bad.bin");
        std::fs::write(&bad, b"NOPE00000000").unwrap();
        assert!(matches!(
            InputSet::load_add12(&bad),
            Err(Error::ArtifactCorrupt { .. })
        ));
    }
}
