//! Pareto dominance and front extraction (minimization).

use super::Objectives;

/// `a` dominates `b`: no-worse in both objectives, strictly better in one.
#[inline]
pub fn dominates(a: Objectives, b: Objectives) -> bool {
    (a[0] <= b[0] && a[1] <= b[1]) && (a[0] < b[0] || a[1] < b[1])
}

/// Indices of the non-dominated points (stable order).
///
/// O(n log n): sort by first objective then sweep the second. Duplicated
/// points are all kept (none dominates its copy).
pub fn pareto_front_indices(points: &[Objectives]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..points.len()).collect();
    idx.sort_by(|&a, &b| {
        points[a][0]
            .partial_cmp(&points[b][0])
            .unwrap()
            .then(points[a][1].partial_cmp(&points[b][1]).unwrap())
    });
    let mut out = Vec::new();
    let mut best_second = f64::INFINITY;
    let mut i = 0;
    while i < idx.len() {
        // group of equal first-objective values
        let mut j = i;
        let x = points[idx[i]][0];
        let mut group_min = f64::INFINITY;
        while j < idx.len() && points[idx[j]][0] == x {
            group_min = group_min.min(points[idx[j]][1]);
            j += 1;
        }
        for k in i..j {
            let y = points[idx[k]][1];
            // kept iff not dominated by any strictly-smaller-x point and is
            // minimal within its x group (ties on both coords all kept).
            if y < best_second && y == group_min {
                out.push(idx[k]);
            }
        }
        best_second = best_second.min(group_min);
        i = j;
    }
    out.sort_unstable();
    out
}

/// A Pareto front in the (BEHAV, PPA) plane with back-references to the
/// originating rows.
#[derive(Debug, Clone)]
pub struct ParetoFront {
    pub indices: Vec<usize>,
    pub points: Vec<Objectives>,
}

impl ParetoFront {
    pub fn from_points(points: &[Objectives]) -> ParetoFront {
        let indices = pareto_front_indices(points);
        let pts = indices.iter().map(|&i| points[i]).collect();
        ParetoFront { indices, points: pts }
    }

    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// Front sorted by the first objective (for plotting/report output).
    pub fn sorted_points(&self) -> Vec<Objectives> {
        let mut pts = self.points.clone();
        pts.sort_by(|a, b| a[0].partial_cmp(&b[0]).unwrap());
        pts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dominance_cases() {
        assert!(dominates([1.0, 1.0], [2.0, 2.0]));
        assert!(dominates([1.0, 2.0], [1.0, 3.0]));
        assert!(!dominates([1.0, 1.0], [1.0, 1.0]));
        assert!(!dominates([1.0, 3.0], [2.0, 2.0]));
    }

    #[test]
    fn front_extraction() {
        let pts = vec![
            [1.0, 5.0], // front
            [2.0, 3.0], // front
            [3.0, 4.0], // dominated by [2,3]
            [4.0, 1.0], // front
            [4.0, 2.0], // dominated (same x, worse y)
        ];
        assert_eq!(pareto_front_indices(&pts), vec![0, 1, 3]);
    }

    #[test]
    fn duplicates_all_kept() {
        let pts = vec![[1.0, 1.0], [1.0, 1.0], [2.0, 0.5]];
        assert_eq!(pareto_front_indices(&pts), vec![0, 1, 2]);
    }

    #[test]
    fn front_matches_naive_on_random() {
        let mut rng = crate::util::rng::Rng::seed_from_u64(5);
        let pts: Vec<Objectives> =
            (0..200).map(|_| [rng.gen_f64(), rng.gen_f64()]).collect();
        let fast = pareto_front_indices(&pts);
        let naive: Vec<usize> = (0..pts.len())
            .filter(|&i| !pts.iter().any(|&q| dominates(q, pts[i])))
            .collect();
        assert_eq!(fast, naive);
    }

    #[test]
    fn front_struct_sorted() {
        let pts = vec![[2.0, 1.0], [1.0, 2.0]];
        let f = ParetoFront::from_points(&pts);
        assert_eq!(f.len(), 2);
        assert_eq!(f.sorted_points(), vec![[1.0, 2.0], [2.0, 1.0]]);
    }
}
