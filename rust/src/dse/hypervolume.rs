//! 2-D hypervolume (minimization) — the paper's quality metric.
//!
//! "Hypervolume ... is estimated as the area (for two objectives) swept by
//! a point or Pareto-front w.r.t. a reference point, usually defined by
//! the problem's constraints" (§V-D). Points not dominating the reference
//! contribute nothing.

use super::{pareto::pareto_front_indices, Objectives};

/// Exact 2-objective hypervolume of `points` w.r.t. `reference`
/// (minimization: only points with both coordinates `< reference` count).
pub fn hypervolume2d(points: &[Objectives], reference: Objectives) -> f64 {
    let mut inside: Vec<Objectives> = points
        .iter()
        .copied()
        .filter(|p| p[0] < reference[0] && p[1] < reference[1])
        .collect();
    if inside.is_empty() {
        return 0.0;
    }
    // Reduce to the non-dominated set, then sweep in ascending x.
    let front = pareto_front_indices(&inside);
    let mut pts: Vec<Objectives> = front.iter().map(|&i| inside[i]).collect();
    pts.sort_by(|a, b| a[0].partial_cmp(&b[0]).unwrap());
    inside.clear();

    let mut hv = 0.0;
    let mut prev_y = reference[1];
    for p in &pts {
        // On a sorted non-dominated front y strictly decreases.
        hv += (reference[0] - p[0]) * (prev_y - p[1]);
        prev_y = p[1];
    }
    hv
}

/// Hypervolume normalized by the reference box area — comparable across
/// scaling factors (Fig. 18's "relative hypervolume").
pub fn relative_hypervolume2d(points: &[Objectives], reference: Objectives) -> f64 {
    let area = reference[0] * reference[1];
    if area <= 0.0 {
        return 0.0;
    }
    hypervolume2d(points, reference) / area
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_point_rectangle() {
        let hv = hypervolume2d(&[[1.0, 1.0]], [3.0, 4.0]);
        assert!((hv - 6.0).abs() < 1e-12);
    }

    #[test]
    fn union_of_two_points() {
        // Points (1,2) and (2,1) wrt (3,3): 2x1 + 1x2 - overlap handled by sweep = 3.
        let hv = hypervolume2d(&[[1.0, 2.0], [2.0, 1.0]], [3.0, 3.0]);
        assert!((hv - 3.0).abs() < 1e-12);
    }

    #[test]
    fn dominated_points_do_not_change_hv() {
        let base = hypervolume2d(&[[1.0, 1.0]], [4.0, 4.0]);
        let more = hypervolume2d(&[[1.0, 1.0], [2.0, 2.0], [3.0, 1.5]], [4.0, 4.0]);
        assert!((base - more).abs() < 1e-12);
    }

    #[test]
    fn outside_reference_contributes_zero() {
        assert_eq!(hypervolume2d(&[[5.0, 5.0]], [4.0, 4.0]), 0.0);
        assert_eq!(hypervolume2d(&[[4.0, 1.0]], [4.0, 4.0]), 0.0);
        assert_eq!(hypervolume2d(&[], [4.0, 4.0]), 0.0);
    }

    #[test]
    fn adding_nondominated_point_increases_hv() {
        let a = hypervolume2d(&[[2.0, 1.0]], [4.0, 4.0]);
        let b = hypervolume2d(&[[2.0, 1.0], [1.0, 3.0]], [4.0, 4.0]);
        assert!(b > a);
    }

    #[test]
    fn relative_bounded_by_one() {
        let r = relative_hypervolume2d(&[[0.0, 0.0]], [2.0, 5.0]);
        assert!((r - 1.0).abs() < 1e-12);
        let r = relative_hypervolume2d(&[[1.0, 2.5]], [2.0, 5.0]);
        assert!((r - 0.25).abs() < 1e-12);
    }

    #[test]
    fn monotone_under_improvement() {
        // Moving a point toward the origin never decreases HV.
        let hv1 = hypervolume2d(&[[2.0, 2.0], [1.0, 3.0]], [4.0, 4.0]);
        let hv2 = hypervolume2d(&[[1.5, 2.0], [1.0, 3.0]], [4.0, 4.0]);
        assert!(hv2 >= hv1);
    }
}
