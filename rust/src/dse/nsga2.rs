//! NSGA-II machinery: constrained fast non-dominated sort + crowding.
//!
//! Constrained domination (Deb): a feasible solution dominates any
//! infeasible one; between two infeasible solutions the smaller total
//! violation wins; between feasible solutions ordinary Pareto dominance
//! applies. This matches DEAP's `selNSGA2` behaviour with a feasibility
//! decorator — the setup the paper's GA uses.

use super::{pareto::dominates, Constraints, Objectives};

/// Constrained-domination predicate.
#[inline]
pub fn constrained_dominates(
    a: Objectives,
    va: f64,
    b: Objectives,
    vb: f64,
) -> bool {
    match (va <= 0.0, vb <= 0.0) {
        (true, false) => true,
        (false, true) => false,
        (false, false) => va < vb,
        (true, true) => dominates(a, b),
    }
}

/// Fast non-dominated sort. Returns front index per individual
/// (0 = best front) and the list of fronts.
pub fn fast_non_dominated_sort(
    objs: &[Objectives],
    constraints: Option<&Constraints>,
) -> (Vec<usize>, Vec<Vec<usize>>) {
    let n = objs.len();
    let viol: Vec<f64> = match constraints {
        Some(c) => objs.iter().map(|&o| c.violation(o)).collect(),
        None => vec![0.0; n],
    };
    let mut dominated_by: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut dom_count = vec![0usize; n];
    for i in 0..n {
        for j in (i + 1)..n {
            if constrained_dominates(objs[i], viol[i], objs[j], viol[j]) {
                dominated_by[i].push(j);
                dom_count[j] += 1;
            } else if constrained_dominates(objs[j], viol[j], objs[i], viol[i]) {
                dominated_by[j].push(i);
                dom_count[i] += 1;
            }
        }
    }
    let mut rank = vec![usize::MAX; n];
    let mut fronts: Vec<Vec<usize>> = Vec::new();
    let mut current: Vec<usize> =
        (0..n).filter(|&i| dom_count[i] == 0).collect();
    let mut level = 0;
    while !current.is_empty() {
        for &i in &current {
            rank[i] = level;
        }
        let mut next = Vec::new();
        for &i in &current {
            for &j in &dominated_by[i] {
                dom_count[j] -= 1;
                if dom_count[j] == 0 {
                    next.push(j);
                }
            }
        }
        fronts.push(std::mem::take(&mut current));
        current = next;
        level += 1;
    }
    (rank, fronts)
}

/// Crowding distance within one front (boundary points get +inf).
pub fn crowding_distance(objs: &[Objectives], front: &[usize]) -> Vec<f64> {
    let m = front.len();
    let mut dist = vec![0.0f64; m];
    if m <= 2 {
        return vec![f64::INFINITY; m];
    }
    for obj_k in 0..2 {
        let mut order: Vec<usize> = (0..m).collect();
        order.sort_by(|&a, &b| {
            objs[front[a]][obj_k].partial_cmp(&objs[front[b]][obj_k]).unwrap()
        });
        let lo = objs[front[order[0]]][obj_k];
        let hi = objs[front[order[m - 1]]][obj_k];
        dist[order[0]] = f64::INFINITY;
        dist[order[m - 1]] = f64::INFINITY;
        let span = hi - lo;
        if span <= 0.0 {
            continue;
        }
        for w in 1..m - 1 {
            let prev = objs[front[order[w - 1]]][obj_k];
            let next = objs[front[order[w + 1]]][obj_k];
            dist[order[w]] += (next - prev) / span;
        }
    }
    dist
}

/// NSGA-II environmental selection: best `k` individuals by (rank,
/// crowding). Returns selected indices into `objs`.
pub fn select(
    objs: &[Objectives],
    constraints: Option<&Constraints>,
    k: usize,
) -> Vec<usize> {
    let (_, fronts) = fast_non_dominated_sort(objs, constraints);
    let mut out = Vec::with_capacity(k);
    for front in &fronts {
        if out.len() + front.len() <= k {
            out.extend_from_slice(front);
        } else {
            let cd = crowding_distance(objs, front);
            let mut order: Vec<usize> = (0..front.len()).collect();
            order.sort_by(|&a, &b| cd[b].partial_cmp(&cd[a]).unwrap());
            for &w in order.iter().take(k - out.len()) {
                out.push(front[w]);
            }
            break;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sort_ranks_simple_fronts() {
        let objs = vec![
            [1.0, 1.0], // front 0
            [2.0, 2.0], // front 1
            [0.5, 3.0], // front 0
            [3.0, 3.0], // front 2
        ];
        let (rank, fronts) = fast_non_dominated_sort(&objs, None);
        assert_eq!(rank, vec![0, 1, 0, 2]);
        assert_eq!(fronts.len(), 3);
        assert_eq!(fronts[0].len(), 2);
    }

    #[test]
    fn feasible_always_beats_infeasible() {
        let c = Constraints::new(1.0, 1.0).unwrap();
        // a is feasible but objectively worse than infeasible b.
        let a = [0.9, 0.9];
        let b = [0.1, 2.0];
        assert!(constrained_dominates(a, c.violation(a), b, c.violation(b)));
        assert!(!constrained_dominates(b, c.violation(b), a, c.violation(a)));
    }

    #[test]
    fn infeasible_ordered_by_violation() {
        let c = Constraints::new(1.0, 1.0).unwrap();
        let a = [1.5, 0.5];
        let b = [3.0, 0.5];
        assert!(constrained_dominates(a, c.violation(a), b, c.violation(b)));
    }

    #[test]
    fn crowding_boundary_infinite() {
        let objs = vec![[0.0, 3.0], [1.0, 2.0], [2.0, 1.0], [3.0, 0.0]];
        let front: Vec<usize> = vec![0, 1, 2, 3];
        let cd = crowding_distance(&objs, &front);
        assert!(cd[0].is_infinite() && cd[3].is_infinite());
        assert!(cd[1].is_finite() && cd[1] > 0.0);
    }

    #[test]
    fn select_prefers_lower_fronts_then_spread() {
        let objs = vec![
            [0.0, 2.0],
            [1.0, 1.0],
            [2.0, 0.0],
            [1.01, 1.01], // front 1
            [5.0, 5.0],   // front 2
        ];
        let sel = select(&objs, None, 3);
        assert_eq!(sel.len(), 3);
        assert!(sel.contains(&0) && sel.contains(&1) && sel.contains(&2));
        let sel4 = select(&objs, None, 4);
        assert!(sel4.contains(&3));
    }

    #[test]
    fn select_k_larger_than_population() {
        let objs = vec![[0.0, 0.0], [1.0, 1.0]];
        let sel = select(&objs, None, 10);
        assert_eq!(sel.len(), 2);
    }
}
