//! Multi-objective DSE (paper §IV-C-2, Eq. 3).
//!
//! The search minimizes the headline objective pair
//! `(BEHAV, PPA) = (AVG_ABS_REL_ERR, PDPLUT)` subject to the constraints
//! `BEHAV <= B_MAX` and `PPA <= P_MAX` of Eq. 3. The engine is an NSGA-II
//! genetic algorithm with the paper's operators — tournament selection,
//! single-point crossover, bit-flip mutation, up to 250 generations — and
//! constrained domination for feasibility handling. Quality is assessed by
//! the 2-D hypervolume w.r.t. the constraint point (Figs. 15/16/18).

pub mod ga;
pub mod hypervolume;
pub mod nsga2;
pub mod pareto;

pub use ga::{Fitness, GaOptions, GaResult, NsgaRunner};
pub use hypervolume::hypervolume2d;
pub use pareto::{dominates, pareto_front_indices, ParetoFront};

use crate::error::{Error, Result};

/// An objective vector in minimization form: `[behav, ppa]`.
pub type Objectives = [f64; 2];

/// The Eq. 3 constraint box. A design is feasible when
/// `behav <= b_max && ppa <= p_max`; the same point is the hypervolume
/// reference.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Constraints {
    pub b_max: f64,
    pub p_max: f64,
}

impl Constraints {
    pub fn new(b_max: f64, p_max: f64) -> Result<Constraints> {
        if !(b_max > 0.0 && p_max > 0.0) {
            return Err(Error::Dse(format!(
                "constraints must be positive (b_max {b_max}, p_max {p_max})"
            )));
        }
        Ok(Constraints { b_max, p_max })
    }

    /// Paper §V-D: the constraint scaling factor multiplies the *maximum*
    /// PPA and BEHAV of the training dataset to obtain `P_MAX` / `B_MAX`.
    /// Smaller factor = tighter problem.
    pub fn from_scaling_factor(
        factor: f64,
        train_points: &[Objectives],
    ) -> Result<Constraints> {
        if train_points.is_empty() {
            return Err(Error::Dse("empty training set for constraints".into()));
        }
        let b = train_points.iter().map(|p| p[0]).fold(f64::NEG_INFINITY, f64::max);
        let p = train_points.iter().map(|p| p[1]).fold(f64::NEG_INFINITY, f64::max);
        Constraints::new(factor * b, factor * p)
    }

    #[inline]
    pub fn feasible(&self, obj: Objectives) -> bool {
        obj[0] <= self.b_max && obj[1] <= self.p_max
    }

    /// Total constraint violation (0 when feasible) for constrained
    /// domination.
    #[inline]
    pub fn violation(&self, obj: Objectives) -> f64 {
        (obj[0] - self.b_max).max(0.0) / self.b_max
            + (obj[1] - self.p_max).max(0.0) / self.p_max
    }

    /// Hypervolume reference point (the constraint corner).
    pub fn reference(&self) -> Objectives {
        [self.b_max, self.p_max]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaling_factor_uses_train_max() {
        let pts = vec![[0.2, 10.0], [0.5, 40.0], [0.1, 25.0]];
        let c = Constraints::from_scaling_factor(0.5, &pts).unwrap();
        assert_eq!(c.b_max, 0.25);
        assert_eq!(c.p_max, 20.0);
    }

    #[test]
    fn feasibility_and_violation() {
        let c = Constraints::new(1.0, 10.0).unwrap();
        assert!(c.feasible([1.0, 10.0]));
        assert!(!c.feasible([1.1, 5.0]));
        assert_eq!(c.violation([0.5, 5.0]), 0.0);
        assert!((c.violation([2.0, 10.0]) - 1.0).abs() < 1e-12);
        assert!(c.violation([2.0, 20.0]) > c.violation([2.0, 10.0]));
    }

    #[test]
    fn rejects_nonpositive() {
        assert!(Constraints::new(0.0, 1.0).is_err());
        assert!(Constraints::from_scaling_factor(0.5, &[]).is_err());
    }
}
