//! The (augmented) NSGA-II runner — paper Fig. 9.
//!
//! The problem-agnostic GA seeds its initial population randomly; the
//! *augmented* AxOCS variant injects the ConSS solution pool as initial
//! individuals in addition to random ones, which "directs the search
//! toward Pareto-optimal solutions faster" (§IV-C-2). Operators follow the
//! paper: tournament selection, single-point crossover, per-bit mutation,
//! up to 250 generations.
//!
//! Fitness is a trait so the same runner drives every backend: the exact
//! characterization table (small operators), the native GBT surrogate, or
//! the batched PJRT MLP behind the coordinator service.

use super::nsga2;
use super::{hypervolume2d, Constraints, Objectives, ParetoFront};
use crate::error::{Error, Result};
use crate::operator::AxoConfig;
use crate::util::rng::Rng;
use std::collections::HashMap;

/// Batched objective evaluation (`[behav, ppa]`, minimization).
pub trait Fitness: Send + Sync {
    fn evaluate(&self, configs: &[AxoConfig]) -> Result<Vec<Objectives>>;
}

impl<F> Fitness for F
where
    F: Fn(&[AxoConfig]) -> Result<Vec<Objectives>> + Send + Sync,
{
    fn evaluate(&self, configs: &[AxoConfig]) -> Result<Vec<Objectives>> {
        self(configs)
    }
}

/// GA hyper-parameters (defaults follow the paper's DEAP setup).
#[derive(Debug, Clone)]
pub struct GaOptions {
    pub pop_size: usize,
    pub generations: u32,
    pub crossover_prob: f64,
    /// Per-bit flip probability; `None` = `1 / config_len`.
    pub mutation_prob: Option<f64>,
    pub tournament_size: usize,
    pub seed: u64,
}

impl Default for GaOptions {
    fn default() -> Self {
        GaOptions {
            pop_size: 100,
            generations: 250, // paper: "maximum of 250 generations"
            crossover_prob: 0.9,
            mutation_prob: None,
            tournament_size: 2,
            seed: 2023,
        }
    }
}

/// Outcome of one GA run.
#[derive(Debug, Clone)]
pub struct GaResult {
    pub population: Vec<AxoConfig>,
    pub objectives: Vec<Objectives>,
    /// Final pseudo Pareto-front (PPF) over every evaluated design.
    pub front_configs: Vec<AxoConfig>,
    pub front_points: Vec<Objectives>,
    /// Hypervolume after each generation (Fig. 16 trace), index 0 = the
    /// initial population.
    pub hv_history: Vec<f64>,
    /// Unique fitness evaluations spent.
    pub evaluations: usize,
}

impl GaResult {
    pub fn final_hypervolume(&self) -> f64 {
        *self.hv_history.last().unwrap_or(&0.0)
    }
}

/// NSGA-II search driver.
pub struct NsgaRunner {
    pub options: GaOptions,
    pub constraints: Constraints,
}

impl NsgaRunner {
    pub fn new(options: GaOptions, constraints: Constraints) -> NsgaRunner {
        NsgaRunner { options, constraints }
    }

    /// Run the search. `initial_seeds` is empty for the problem-agnostic GA
    /// and the ConSS pool for the augmented variant.
    pub fn run(
        &self,
        config_len: u32,
        fitness: &dyn Fitness,
        initial_seeds: &[AxoConfig],
    ) -> Result<GaResult> {
        let o = &self.options;
        if o.pop_size < 2 {
            return Err(Error::Dse("population size must be >= 2".into()));
        }
        let mut rng = Rng::seed_from_u64(o.seed);
        let pmut = o.mutation_prob.unwrap_or(1.0 / config_len as f64);

        // Archive of every evaluated design (the PPF source) + cache.
        let mut cache: HashMap<u64, Objectives> = HashMap::new();
        let mut archive: Vec<(AxoConfig, Objectives)> = Vec::new();

        // --- Initial population: seeds first, random fill (Fig. 9). ---
        let mut pop: Vec<AxoConfig> = Vec::with_capacity(o.pop_size);
        let mut seen = std::collections::HashSet::new();
        for s in initial_seeds.iter().take(o.pop_size) {
            debug_assert_eq!(s.len(), config_len);
            if seen.insert(s.as_uint()) {
                pop.push(*s);
            }
        }
        while pop.len() < o.pop_size {
            let c = AxoConfig::sample_unique(config_len, 1, &mut rng)[0];
            if seen.insert(c.as_uint()) {
                pop.push(c);
            }
        }

        let mut objs =
            self.evaluate_cached(&pop, fitness, &mut cache, &mut archive)?;
        let mut hv_history =
            vec![self.front_hypervolume(&archive)];

        for _gen in 0..o.generations {
            // --- Variation: tournament → crossover → mutation. ---
            let (rank, fronts) = nsga2::fast_non_dominated_sort(&objs, Some(&self.constraints));
            let mut crowd = vec![0.0f64; pop.len()];
            for front in &fronts {
                let cd = nsga2::crowding_distance(&objs, front);
                for (w, &i) in front.iter().enumerate() {
                    crowd[i] = cd[w];
                }
            }
            let mut offspring: Vec<AxoConfig> = Vec::with_capacity(o.pop_size);
            while offspring.len() < o.pop_size {
                let p1 = self.tournament(&rank, &crowd, &mut rng);
                let p2 = self.tournament(&rank, &crowd, &mut rng);
                let (mut c1, mut c2) = (pop[p1], pop[p2]);
                if config_len > 1 && rng.gen_f64() < o.crossover_prob {
                    let point = 1 + rng.gen_below((config_len - 1) as u64) as u32;
                    let (a, b) = c1.crossover(&c2, point);
                    c1 = a.unwrap_or(c1);
                    c2 = b.unwrap_or(c2);
                }
                offspring.push(self.mutate(c1, pmut, &mut rng));
                if offspring.len() < o.pop_size {
                    offspring.push(self.mutate(c2, pmut, &mut rng));
                }
            }
            let off_objs =
                self.evaluate_cached(&offspring, fitness, &mut cache, &mut archive)?;

            // --- Environmental selection over parents + offspring. ---
            let mut all_cfg = pop.clone();
            all_cfg.extend_from_slice(&offspring);
            let mut all_obj = objs.clone();
            all_obj.extend_from_slice(&off_objs);
            let sel = nsga2::select(&all_obj, Some(&self.constraints), o.pop_size);
            pop = sel.iter().map(|&i| all_cfg[i]).collect();
            objs = sel.iter().map(|&i| all_obj[i]).collect();

            hv_history.push(self.front_hypervolume(&archive));
        }

        // PPF = feasible non-dominated subset of the archive.
        let feasible: Vec<&(AxoConfig, Objectives)> = archive
            .iter()
            .filter(|(_, o)| self.constraints.feasible(*o))
            .collect();
        let pts: Vec<Objectives> = feasible.iter().map(|(_, o)| *o).collect();
        let front = ParetoFront::from_points(&pts);
        let front_configs = front.indices.iter().map(|&i| feasible[i].0).collect();
        let front_points = front.points.clone();

        Ok(GaResult {
            population: pop,
            objectives: objs,
            front_configs,
            front_points,
            hv_history,
            evaluations: cache.len(),
        })
    }

    fn evaluate_cached(
        &self,
        configs: &[AxoConfig],
        fitness: &dyn Fitness,
        cache: &mut HashMap<u64, Objectives>,
        archive: &mut Vec<(AxoConfig, Objectives)>,
    ) -> Result<Vec<Objectives>> {
        let fresh: Vec<AxoConfig> = {
            let mut seen = std::collections::HashSet::new();
            configs
                .iter()
                .filter(|c| !cache.contains_key(&c.as_uint()) && seen.insert(c.as_uint()))
                .copied()
                .collect()
        };
        if !fresh.is_empty() {
            let objs = fitness.evaluate(&fresh)?;
            if objs.len() != fresh.len() {
                return Err(Error::Dse(format!(
                    "fitness returned {} objectives for {} configs",
                    objs.len(),
                    fresh.len()
                )));
            }
            for (c, o) in fresh.iter().zip(&objs) {
                cache.insert(c.as_uint(), *o);
                archive.push((*c, *o));
            }
        }
        Ok(configs.iter().map(|c| cache[&c.as_uint()]).collect())
    }

    fn front_hypervolume(&self, archive: &[(AxoConfig, Objectives)]) -> f64 {
        let pts: Vec<Objectives> = archive
            .iter()
            .map(|(_, o)| *o)
            .filter(|o| self.constraints.feasible(*o))
            .collect();
        hypervolume2d(&pts, self.constraints.reference())
    }

    fn tournament(&self, rank: &[usize], crowd: &[f64], rng: &mut Rng) -> usize {
        let n = rank.len();
        let mut best = rng.gen_index(n);
        for _ in 1..self.options.tournament_size.max(2) {
            let cand = rng.gen_index(n);
            let better = rank[cand] < rank[best]
                || (rank[cand] == rank[best] && crowd[cand] > crowd[best]);
            if better {
                best = cand;
            }
        }
        best
    }

    fn mutate(&self, cfg: AxoConfig, pmut: f64, rng: &mut Rng) -> AxoConfig {
        let mut cur = cfg;
        for k in 0..cfg.len() {
            if rng.gen_f64() < pmut {
                if let Some(next) = cur.flipped(k) {
                    cur = next;
                }
            }
        }
        cur
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Synthetic separable fitness: behav = fraction of zeros in low half,
    /// ppa = fraction of ones overall — a clean trade-off.
    fn toy_fitness(configs: &[AxoConfig]) -> Result<Vec<Objectives>> {
        Ok(configs
            .iter()
            .map(|c| {
                let l = c.len();
                let ones = c.count_kept() as f64;
                let low_zeros = (0..l / 2).filter(|&k| !c.keeps(k)).count() as f64;
                [low_zeros / (l / 2) as f64, ones / l as f64]
            })
            .collect())
    }

    fn runner(gens: u32, seed: u64) -> NsgaRunner {
        NsgaRunner::new(
            GaOptions {
                pop_size: 24,
                generations: gens,
                seed,
                ..GaOptions::default()
            },
            Constraints::new(1.0, 1.0).unwrap(),
        )
    }

    #[test]
    fn hv_history_is_monotone_nondecreasing() {
        let r = runner(20, 1).run(12, &toy_fitness, &[]).unwrap();
        for w in r.hv_history.windows(2) {
            assert!(w[1] >= w[0] - 1e-12);
        }
        assert_eq!(r.hv_history.len(), 21);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = runner(10, 7).run(10, &toy_fitness, &[]).unwrap();
        let b = runner(10, 7).run(10, &toy_fitness, &[]).unwrap();
        assert_eq!(a.hv_history, b.hv_history);
        assert_eq!(a.front_points, b.front_points);
    }

    #[test]
    fn seeded_run_starts_at_least_as_good() {
        // Give the augmented run the all-ones + low-half-ones seeds, which
        // score well on behav.
        let seeds = vec![
            AxoConfig::accurate(12),
            AxoConfig::new(0b111111, 12).unwrap(),
        ];
        let plain = runner(0, 3).run(12, &toy_fitness, &[]).unwrap();
        let mut aug_runner = runner(0, 3);
        aug_runner.options.seed = 3;
        let aug = aug_runner.run(12, &toy_fitness, &seeds).unwrap();
        assert!(aug.hv_history[0] >= plain.hv_history[0] - 1e-12);
    }

    #[test]
    fn population_never_contains_zero_config() {
        let r = runner(15, 9).run(8, &toy_fitness, &[]).unwrap();
        assert!(r.population.iter().all(|c| c.as_uint() != 0));
        assert_eq!(r.population.len(), 24);
    }

    #[test]
    fn front_is_nondominated_and_feasible() {
        let r = runner(15, 11).run(10, &toy_fitness, &[]).unwrap();
        for (i, a) in r.front_points.iter().enumerate() {
            assert!(a[0] <= 1.0 && a[1] <= 1.0);
            for (j, b) in r.front_points.iter().enumerate() {
                if i != j {
                    assert!(!super::super::pareto::dominates(*b, *a));
                }
            }
        }
    }

    #[test]
    fn fitness_error_propagates() {
        let failing = |_: &[AxoConfig]| -> Result<Vec<Objectives>> {
            Err(Error::Xla("boom".into()))
        };
        assert!(runner(1, 0).run(8, &failing, &[]).is_err());
    }

    #[test]
    fn fitness_length_mismatch_detected() {
        let bad = |c: &[AxoConfig]| -> Result<Vec<Objectives>> {
            Ok(vec![[0.0, 0.0]; c.len().saturating_sub(1)])
        };
        let e = runner(1, 0).run(8, &bad, &[]);
        assert!(matches!(e, Err(Error::Dse(_))));
    }
}
