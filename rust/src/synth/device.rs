//! Virtex-7-like device timing/power coefficients.
//!
//! Shared constants of the analytical synthesis model; the same values live
//! in `python/compile/synth_model.py` (both are pinned by
//! `golden_behav.json` and by unit tests on each side). Magnitudes follow
//! published Virtex-7 (7VX330T, the paper's device) characteristics: LUT6
//! logic delay ≈ 0.124 ns, one CARRY4 hop ≈ 0.042 ns/bit, sub-mW per-LUT
//! dynamic power at moderate toggle rates.

/// LUT6 logic delay (ns).
pub const T_LUT_NS: f64 = 0.124;
/// One CARRY4 hop, per bit (ns).
pub const T_CARRY_NS: f64 = 0.042;
/// Fixed routing + IOB overhead on the critical path (ns).
pub const T_NET_NS: f64 = 0.458;
/// Clock-tree / fixed-logic dynamic power (mW).
pub const P_BASE_MW: f64 = 0.050;
/// Per-LUT dynamic power at activity 1.0 (mW).
pub const P_LUT_MW: f64 = 0.350;
