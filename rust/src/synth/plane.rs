//! Config-parallel bit-sliced PPA estimation — 64 configurations per op.
//!
//! PR 6 bit-sliced the BEHAV half of characterization across *input
//! vectors*; this module applies the same transform to the analytical PPA
//! estimator across *configurations*: [`BitMatrix`](bitslice::BitMatrix)
//! transposes 64 keep-masks so plane `i` is a `u64` whose bit `t` is
//! keep-bit `i` of configuration `t`, and the per-config walks of
//! [`super::adder_ppa`]/[`super::mult_ppa`] become plane recurrences:
//!
//! * the adder's longest retained run is a lane-parallel saturating
//!   counter — `cur = keep ? cur + 1 : 0` as a ripple increment whose
//!   carry-in *is* the keep plane, with `best = max(best, cur)` by
//!   borrow-compare + plane mux;
//! * multiplier column heights accumulate through the same ±2^shift plane
//!   adder the BEHAV path uses ([`bitslice::acc_add`]), over the cached
//!   pair table shared with the scalar oracle; `hmax` is a plane
//!   compare-select across columns and the active-column span comes from
//!   first/last-nonzero scans that OR column-index bits under a
//!   not-yet-found mask;
//! * activity sums are weight-indexed masked broadcasts
//!   (`act[t] += w_i · keep_bit`), bit-identical to the scalar
//!   conditional add because every weight is positive, `w·1 == w`,
//!   `w·0 == +0.0`, and `x + 0.0 == x` for the non-negative partial sums —
//!   the same accumulation order per config, so results match the scalar
//!   oracle by `f64::to_bits`, never by tolerance
//!   (`rust/tests/ppa_plane.rs` asserts this end to end).
//!
//! Integer-valued quantities (`count_kept`, run lengths, heights, spans)
//! are exact in f64 no matter how they are counted, and the multiplier's
//! `ceil(log_1.5 h)` depth is a pure function of the integer `hmax`, so it
//! is read from a per-table lookup evaluated with the identical scalar
//! expression.

use super::device::*;
use super::{PairTable, PpaMetrics};
use crate::operator::bitslice::{self, BitMatrix};
use crate::operator::{AxoConfig, Operator, OperatorKind};

/// Counter planes for the adder's run recurrence: runs are at most the
/// config length (≤ 36 for mul8-sized masks, ≤ 16 for adders) < 2^6.
const RUN_PLANES: usize = 6;

/// Counter planes per multiplier column: heights are at most `m_bits`
/// (≤ 8) < 2^4.
const HEIGHT_PLANES: usize = 4;

/// Planes holding a column index (≤ 14 for mul8) < 2^4.
const COL_PLANES: usize = 4;

/// `best = max(best, cur)` lane-parallel: borrow-compare (`borrow` lane
/// bits are 1 where `cur < best`), then mux-select the winner's planes.
#[inline]
fn plane_max(best: &mut [u64], cur: &[u64]) {
    let mut borrow = 0u64;
    for (&c, &b) in cur.iter().zip(best.iter()) {
        borrow = (!c & (b | borrow)) | (b & borrow);
    }
    let take = !borrow;
    for (b, &c) in best.iter_mut().zip(cur) {
        *b = (c & take) | (*b & !take);
    }
}

/// Add `w · keep_bit` into every live lane's activity sum, in the same
/// per-config order as the scalar loop (ascending plane index). An
/// all-zero plane contributes `+0.0` everywhere — the additive identity —
/// so it is skipped outright.
#[inline]
fn masked_broadcast(act: &mut [f64; 64], lanes: usize, plane: u64, w: f64) {
    if plane == 0 {
        return;
    }
    for (t, a) in act.iter_mut().enumerate().take(lanes) {
        *a += w * ((plane >> t) & 1) as f64;
    }
}

/// One ≤64-config block of adder PPA (tail lanes of a ragged batch are
/// zero-padded by `pack` and never read back).
fn adder_block(cfgs: &[AxoConfig], out: &mut Vec<PpaMetrics>) {
    let lanes = cfgs.len();
    debug_assert!(0 < lanes && lanes <= 64);
    let n = cfgs[0].len();
    let keep = BitMatrix::pack(lanes, n as usize, |t| cfgs[t].as_uint());
    let keep = keep.block(0);

    // Longest run: per-plane `cur = keep ? cur + 1 : 0` (ripple increment
    // with carry-in = keep plane, then reset-where-removed), folded into a
    // running lane-parallel max.
    let mut cur = [0u64; RUN_PLANES];
    let mut best = [0u64; RUN_PLANES];
    let mut act = [0.0f64; 64];
    for (i, &k) in keep.iter().enumerate() {
        let mut carry = k;
        for c in cur.iter_mut() {
            let t = *c;
            *c = (t ^ carry) & k;
            carry = t & carry;
        }
        plane_max(&mut best, &cur);
        masked_broadcast(&mut act, lanes, k, 0.5 + (i as f64 + 1.0) / (4.0 * n as f64));
    }
    let mut runs = [0u64; 64];
    bitslice::unpack64(&best, &mut runs);

    for (t, cfg) in cfgs.iter().enumerate() {
        // count_kept is the keep-mask popcount — exact as f64 either way.
        let luts = cfg.count_kept() as f64;
        let cpd = T_NET_NS + T_LUT_NS + T_CARRY_NS * runs[t] as f64;
        let power = P_BASE_MW + P_LUT_MW * act[t];
        out.push(PpaMetrics::from_parts(luts, cpd, power));
    }
}

/// One ≤64-config block of multiplier PPA over the cached pair table.
fn mult_block(m_bits: u32, table: &PairTable, cfgs: &[AxoConfig], out: &mut Vec<PpaMetrics>) {
    let lanes = cfgs.len();
    debug_assert!(0 < lanes && lanes <= 64);
    let l = table.pairs.len();
    debug_assert_eq!(l as u32, cfgs[0].len());
    let keep = BitMatrix::pack(lanes, l, |t| cfgs[t].as_uint());
    let keep = keep.block(0);

    // Column heights as per-column counter planes: a kept pair adds its
    // weight (1 or 2 → shift 0 or 1) into column i+j, 64 configs at once.
    let mut heights = vec![[0u64; HEIGHT_PLANES]; table.n_cols];
    let mut act = [0.0f64; 64];
    for (k, &kp) in keep.iter().enumerate() {
        let shift = (table.weight[k] == 2) as usize;
        bitslice::acc_add(&mut heights[table.col[k] as usize], kp, shift);
        masked_broadcast(&mut act, lanes, kp, table.act_w[k]);
    }

    // hmax: lane-parallel compare-select across columns.
    let mut hmax = heights[0];
    for col in &heights[1..] {
        plane_max(&mut hmax, col);
    }
    let mut hmax_lanes = [0u64; 64];
    bitslice::unpack64(&hmax, &mut hmax_lanes);

    // Active-column span: ascending and descending first-nonzero scans.
    // A column's nonzero mask is the OR of its counter planes; where a
    // lane first turns nonzero, the column index's bits are OR-ed into
    // the first/last planes under the not-yet-found mask.
    let nz: Vec<u64> = heights.iter().map(|h| h.iter().fold(0, |a, &p| a | p)).collect();
    let mut pending = !0u64;
    let mut first = [0u64; COL_PLANES];
    for (ci, &m) in nz.iter().enumerate() {
        let newly = m & pending;
        for (b, f) in first.iter_mut().enumerate() {
            if (ci >> b) & 1 == 1 {
                *f |= newly;
            }
        }
        pending &= !m;
    }
    let found = !pending;
    let mut pending = !0u64;
    let mut last = [0u64; COL_PLANES];
    for (ci, &m) in nz.iter().enumerate().rev() {
        let newly = m & pending;
        for (b, f) in last.iter_mut().enumerate() {
            if (ci >> b) & 1 == 1 {
                *f |= newly;
            }
        }
        pending &= !m;
    }
    let (mut first_l, mut last_l) = ([0u64; 64], [0u64; 64]);
    bitslice::unpack64(&first, &mut first_l);
    bitslice::unpack64(&last, &mut last_l);

    for (t, cfg) in cfgs.iter().enumerate() {
        let luts = cfg.count_kept() as f64 + m_bits as f64;
        let depth = table.depth[hmax_lanes[t] as usize];
        let span = if (found >> t) & 1 == 1 {
            (last_l[t] - first_l[t] + 1) as f64
        } else {
            0.0
        };
        let cpd = T_NET_NS + T_LUT_NS * (1.0 + depth) + T_CARRY_NS * span;
        let power = P_BASE_MW + P_LUT_MW * act[t];
        out.push(PpaMetrics::from_parts(luts, cpd, power));
    }
}

/// Batch PPA on the plane backend: 64-config blocks on the work-stealing
/// pool, merged order-stably. Block boundaries never affect values (each
/// lane's metrics are a function of its own keep-mask only), so results
/// are partition-independent and bit-identical to the scalar oracle.
pub fn ppa_batch_plane(op: Operator, configs: &[AxoConfig]) -> Vec<PpaMetrics> {
    if configs.is_empty() {
        return Vec::new();
    }
    let chunks: Vec<&[AxoConfig]> = configs.chunks(64).collect();
    let grain = crate::util::par::default_grain(chunks.len());
    let table = match op.kind {
        OperatorKind::UnsignedAdder => None,
        OperatorKind::SignedMultiplier => Some(super::pair_table(op.bits)),
    };
    let blocks = crate::util::par::parallel_map_dynamic(&chunks, grain, |_, chunk| {
        let mut out = Vec::with_capacity(chunk.len());
        match table {
            None => adder_block(chunk, &mut out),
            Some(table) => mult_block(op.bits, table, chunk, &mut out),
        }
        out
    });
    let mut out = Vec::with_capacity(configs.len());
    for block in blocks {
        out.extend(block);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::{ppa_batch_with, PpaBackend};
    use crate::util::rng::Rng;

    fn assert_bits(op: Operator, cfgs: &[AxoConfig], what: &str) {
        let scalar = ppa_batch_with(op, cfgs, PpaBackend::Scalar);
        let plane = ppa_batch_with(op, cfgs, PpaBackend::Plane);
        assert_eq!(scalar.len(), plane.len());
        for (i, (s, p)) in scalar.iter().zip(&plane).enumerate() {
            assert_eq!(
                s.to_array().map(f64::to_bits),
                p.to_array().map(f64::to_bits),
                "{what}: config {i} ({s:?} vs {p:?})"
            );
        }
    }

    #[test]
    fn adder_exhaustive_add4_is_bit_identical() {
        let cfgs: Vec<AxoConfig> = AxoConfig::enumerate(4).collect();
        assert_bits(Operator::ADD4, &cfgs, "add4 exhaustive");
    }

    #[test]
    fn mult_exhaustive_mul4_is_bit_identical() {
        let cfgs: Vec<AxoConfig> = AxoConfig::enumerate(10).collect();
        assert_bits(Operator::MUL4, &cfgs, "mul4 exhaustive");
    }

    #[test]
    fn ragged_tails_are_bit_identical() {
        let mut rng = Rng::seed_from_u64(3);
        for n in [1usize, 63, 64, 65, 130] {
            let cfgs = AxoConfig::sample_unique(12, n, &mut rng);
            assert_bits(Operator::ADD12, &cfgs, &format!("add12 n={n}"));
        }
    }

    #[test]
    fn plane_is_the_default_backend() {
        assert_eq!(PpaBackend::resolve(None), PpaBackend::Plane);
        assert_eq!(PpaBackend::from_name("scalar"), Some(PpaBackend::Scalar));
        assert_eq!(PpaBackend::from_name("plane"), Some(PpaBackend::Plane));
        assert_eq!(PpaBackend::from_name("bitslice"), None);
    }
}
