//! Analytical FPGA synthesis estimator — the Vivado substitute.
//!
//! Replaces Xilinx Vivado 19.2 / Virtex-7 7VX330T characterization (paper
//! §V-A), which is unavailable here (see DESIGN.md §2 substitution 1).
//! Produces the paper's PPA metric set — LUT utilization, critical path
//! delay, dynamic power, PDP, PDPLUT — as deterministic structural
//! functions of the configuration. Formulas and constants mirror
//! `python/compile/synth_model.py` exactly; `golden_behav.json` pins both.

pub mod device;
pub mod plane;

use crate::operator::{multiplier, AxoConfig, Operator, OperatorKind};
use device::*;
use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};

/// The PPA metric bundle the paper characterizes per design (Eq. 2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PpaMetrics {
    /// LUT utilization (paper `U`).
    pub luts: f64,
    /// Critical path delay in ns (paper `C`).
    pub cpd_ns: f64,
    /// Dynamic power in mW (paper `W`).
    pub power_mw: f64,
    /// Power-delay product `W × C` (pJ).
    pub pdp: f64,
    /// `PDPLUT = W × C × U` — the paper's headline PPA metric.
    pub pdplut: f64,
}

impl PpaMetrics {
    pub const NAMES: [&'static str; 5] = ["luts", "cpd_ns", "power_mw", "pdp", "pdplut"];

    fn from_parts(luts: f64, cpd: f64, power: f64) -> Self {
        let pdp = power * cpd;
        PpaMetrics { luts, cpd_ns: cpd, power_mw: power, pdp, pdplut: pdp * luts }
    }

    pub fn to_array(&self) -> [f64; 5] {
        [self.luts, self.cpd_ns, self.power_mw, self.pdp, self.pdplut]
    }

    pub fn from_array(a: [f64; 5]) -> Self {
        PpaMetrics { luts: a[0], cpd_ns: a[1], power_mw: a[2], pdp: a[3], pdplut: a[4] }
    }
}

/// Which implementation computes PPA metrics. Both produce bit-identical
/// [`PpaMetrics`]; the scalar path is the oracle the config-parallel
/// plane default is verified against (`rust/tests/ppa_plane.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PpaBackend {
    /// Per-config evaluation (the `longest_run` / column-height walks).
    Scalar,
    /// 64 configs per operation in u64 keep-mask planes ([`plane`]).
    Plane,
}

impl PpaBackend {
    pub fn name(self) -> &'static str {
        match self {
            PpaBackend::Scalar => "scalar",
            PpaBackend::Plane => "plane",
        }
    }

    pub fn from_name(s: &str) -> Option<PpaBackend> {
        match s {
            "scalar" => Some(PpaBackend::Scalar),
            "plane" => Some(PpaBackend::Plane),
            _ => None,
        }
    }

    /// Resolution order: the `REPRO_PPA` escape hatch, then the caller's
    /// preference (typically `[charac] ppa` from expcfg), then the
    /// plane default — mirroring
    /// [`BehavBackend::resolve`](crate::charac::BehavBackend::resolve).
    pub fn resolve(preferred: Option<PpaBackend>) -> PpaBackend {
        if let Ok(v) = std::env::var("REPRO_PPA") {
            match PpaBackend::from_name(v.trim()) {
                Some(b) => return b,
                None => eprintln!(
                    "warning: ignoring invalid REPRO_PPA={v:?} \
                     (expected `scalar` or `plane`)"
                ),
            }
        }
        preferred.unwrap_or(PpaBackend::Plane)
    }
}

/// Immutable per-`m_bits` multiplier geometry, built once per process and
/// shared by the scalar and plane backends: the Baugh-Wooley pair list
/// with each pair's target column, weight, and precomputed activity
/// contribution, plus the `ceil(log_1.5 h)` compressor-depth lookup.
/// Hoisting this out of `mult_ppa` removes a `Vec` allocation per config
/// from the batch hot loop without changing any accumulation order (the
/// cached `act_w` values are the identical pure-function f64s the scalar
/// loop recomputed per config).
pub(crate) struct PairTable {
    /// Lexicographic `(i, j)` pairs, `i ≤ j` — `multiplier::pairs` order.
    pub pairs: Vec<(u32, u32)>,
    /// `col[k] = i + j`, the partial-product column of pair `k`.
    pub col: Vec<u32>,
    /// `weight[k]` — 2 bits land in the column when `i < j`, 1 when `i == j`.
    pub weight: Vec<u32>,
    /// `weight · (0.3 + 0.4 (i+j)/(2M−2))`, pair `k`'s activity term.
    pub act_w: Vec<f64>,
    /// Number of partial-product columns, `2M − 1`.
    pub n_cols: usize,
    /// `depth[h]` for integer column heights `0 ..= M`.
    pub depth: Vec<f64>,
}

impl PairTable {
    fn build(m_bits: u32) -> PairTable {
        let pairs = multiplier::pairs(m_bits);
        let col: Vec<u32> = pairs.iter().map(|&(i, j)| i + j).collect();
        let weight: Vec<u32> =
            pairs.iter().map(|&(i, j)| if i < j { 2 } else { 1 }).collect();
        let act_w: Vec<f64> = pairs
            .iter()
            .zip(&weight)
            .map(|(&(i, j), &w)| {
                w as f64 * (0.3 + 0.4 * (i + j) as f64 / (2 * m_bits - 2) as f64)
            })
            .collect();
        // A column holds at most M partial-product bits (the middle
        // column of the accurate design), so the depth lookup is tiny.
        let depth: Vec<f64> = (0..=m_bits)
            .map(|h| {
                let hmax = h as f64;
                if hmax > 1.0 { (hmax.ln() / 1.5f64.ln()).ceil() } else { 0.0 }
            })
            .collect();
        PairTable {
            pairs,
            col,
            weight,
            act_w,
            n_cols: (2 * m_bits - 1) as usize,
            depth,
        }
    }
}

/// The process-wide [`PairTable`] for `m_bits`, built on first use.
/// Leaked on purpose: the set of multiplier widths is tiny and fixed.
pub(crate) fn pair_table(m_bits: u32) -> &'static PairTable {
    static TABLES: OnceLock<Mutex<HashMap<u32, &'static PairTable>>> = OnceLock::new();
    let mut map = TABLES
        .get_or_init(|| Mutex::new(HashMap::new()))
        .lock()
        .expect("pair table cache poisoned");
    *map.entry(m_bits).or_insert_with(|| Box::leak(Box::new(PairTable::build(m_bits))))
}

/// Longest run of consecutive retained LUTs — the surviving ripple length.
fn longest_run(config: &AxoConfig) -> u32 {
    let mut best = 0;
    let mut cur = 0;
    for i in 0..config.len() {
        cur = if config.keeps(i) { cur + 1 } else { 0 };
        best = best.max(cur);
    }
    best
}

/// PPA of an unsigned adder configuration.
///
/// `CPD = T_NET + T_LUT + T_CARRY × R` with `R` the longest run of
/// consecutive retained LUTs: a removed LUT regenerates the carry
/// (`c_{i+1} = b_i`), cutting the ripple path. Activity of LUT i is
/// `0.5 + (i+1)/(4N)` — propagate toggles at 0.5 for uniform inputs plus a
/// significance-growing carry term.
pub fn adder_ppa(config: &AxoConfig) -> PpaMetrics {
    let n = config.len();
    let luts = config.count_kept() as f64;
    let cpd = T_NET_NS + T_LUT_NS + T_CARRY_NS * longest_run(config) as f64;
    let mut act_sum = 0.0;
    for i in 0..n {
        if config.keeps(i) {
            act_sum += 0.5 + (i as f64 + 1.0) / (4.0 * n as f64);
        }
    }
    let power = P_BASE_MW + P_LUT_MW * act_sum;
    PpaMetrics::from_parts(luts, cpd, power)
}

/// PPA of a signed Baugh-Wooley multiplier configuration.
///
/// Fixed logic: M LUT-equivalents of final carry-propagate adder. Column
/// heights count retained partial-product bits (pair `(i,j)` adds 2 bits to
/// column `i+j` when `i < j`, 1 when `i == j`); compressor-tree depth is
/// `ceil(log_1.5(max height))` (Dadda-style 3:2 reduction) and the final
/// adder ripples across the active-column span. Activity of LUT `(i,j)` is
/// `(2 if i<j else 1) × (0.3 + 0.4 (i+j)/(2M-2))`.
pub fn mult_ppa(m_bits: u32, config: &AxoConfig) -> PpaMetrics {
    // The pair geometry is cached per m_bits and the heights scratch is
    // per-thread, so the batch hot loop performs zero allocations. The
    // cached act_w values are the identical f64s the old per-config
    // recomputation produced, added in the identical order.
    thread_local! {
        static HEIGHTS: RefCell<Vec<u32>> = const { RefCell::new(Vec::new()) };
    }
    let table = pair_table(m_bits);
    debug_assert_eq!(table.pairs.len() as u32, config.len());
    HEIGHTS.with(|cell| {
        let mut heights = cell.borrow_mut();
        heights.clear();
        heights.resize(table.n_cols, 0);
        let mut act_sum = 0.0;
        for k in 0..table.pairs.len() {
            if config.keeps(k as u32) {
                heights[table.col[k] as usize] += table.weight[k];
                act_sum += table.act_w[k];
            }
        }
        let luts = config.count_kept() as f64 + m_bits as f64;
        let hmax = *heights.iter().max().unwrap() as usize;
        let depth = table.depth[hmax];
        let first = heights.iter().position(|&h| h > 0);
        let span = match first {
            Some(f) => {
                let l = heights.iter().rposition(|&h| h > 0).unwrap();
                (l - f + 1) as f64
            }
            None => 0.0,
        };
        let cpd = T_NET_NS + T_LUT_NS * (1.0 + depth) + T_CARRY_NS * span;
        let power = P_BASE_MW + P_LUT_MW * act_sum;
        PpaMetrics::from_parts(luts, cpd, power)
    })
}

/// Dispatch on operator kind.
pub fn ppa(op: Operator, config: &AxoConfig) -> PpaMetrics {
    match op.kind {
        OperatorKind::UnsignedAdder => adder_ppa(config),
        OperatorKind::SignedMultiplier => mult_ppa(op.bits, config),
    }
}

/// Batch characterization under an explicit backend. The scalar path
/// fans per-config on the work-stealing pool (coarse grain — per-config
/// cost is a few hundred ops); the plane path fans 64-config blocks
/// ([`plane::ppa_batch_plane`]). Both orders are stable and the rows
/// bit-identical.
pub fn ppa_batch_with(
    op: Operator,
    configs: &[AxoConfig],
    backend: PpaBackend,
) -> Vec<PpaMetrics> {
    match backend {
        PpaBackend::Scalar => {
            let grain = crate::util::par::default_grain(configs.len()).max(256);
            crate::util::par::parallel_map_dynamic(configs, grain, |_, c| ppa(op, c))
        }
        PpaBackend::Plane => plane::ppa_batch_plane(op, configs),
    }
}

/// [`ppa_batch_with`] under the resolved default backend
/// (`REPRO_PPA` env > plane).
pub fn ppa_batch(op: Operator, configs: &[AxoConfig]) -> Vec<PpaMetrics> {
    ppa_batch_with(op, configs, PpaBackend::resolve(None))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx_eq(a: f64, b: f64) {
        assert!((a - b).abs() < 1e-12, "{a} != {b}");
    }

    #[test]
    fn adder_accurate_pinned_values() {
        // Mirror of python test_adder_accurate_pinned_values.
        let m = adder_ppa(&AxoConfig::accurate(8));
        approx_eq(m.luts, 8.0);
        approx_eq(m.cpd_ns, T_NET_NS + T_LUT_NS + T_CARRY_NS * 8.0);
        approx_eq(m.power_mw, P_BASE_MW + P_LUT_MW * (4.0 + 36.0 / 32.0));
        approx_eq(m.pdp, m.power_mw * m.cpd_ns);
        approx_eq(m.pdplut, m.pdp * 8.0);
    }

    #[test]
    fn adder_removal_breaks_carry_chain() {
        let full = adder_ppa(&AxoConfig::accurate(8));
        let cut = adder_ppa(&AxoConfig::new(0b1110_1111, 8).unwrap());
        assert!(cut.cpd_ns < full.cpd_ns);
        approx_eq(cut.luts, 7.0);
        assert!(cut.power_mw < full.power_mw);
    }

    #[test]
    fn longest_run_cases() {
        assert_eq!(longest_run(&AxoConfig::new(0b111011, 6).unwrap()), 3);
        assert_eq!(longest_run(&AxoConfig::new(0b111111, 6).unwrap()), 6);
        assert_eq!(longest_run(&AxoConfig::new(0b000001, 6).unwrap()), 1);
    }

    #[test]
    fn mult_accurate_pinned_values() {
        // Mirror of python test_mult_accurate_pinned_values (M = 4):
        // heights [1,2,3,4,3,2,1], hmax 4, depth ceil(ln4/ln1.5)=4, span 7.
        let m = mult_ppa(4, &AxoConfig::accurate(10));
        approx_eq(m.luts, 14.0);
        approx_eq(m.cpd_ns, T_NET_NS + T_LUT_NS * 5.0 + T_CARRY_NS * 7.0);
        assert!(m.power_mw > P_BASE_MW);
        approx_eq(m.pdplut, m.pdp * 14.0);
    }

    #[test]
    fn mult_removal_monotone() {
        let base = mult_ppa(8, &AxoConfig::accurate(36));
        for k in [0u32, 17, 35] {
            let cfg = AxoConfig::accurate(36).flipped(k).unwrap();
            let red = mult_ppa(8, &cfg);
            assert!(red.luts < base.luts);
            assert!(red.power_mw < base.power_mw);
            assert!(red.cpd_ns <= base.cpd_ns);
        }
    }

    #[test]
    fn dispatch_matches_direct() {
        let c = AxoConfig::accurate(8);
        assert_eq!(ppa(Operator::ADD8, &c), adder_ppa(&c));
        let c = AxoConfig::accurate(36);
        assert_eq!(ppa(Operator::MUL8, &c), mult_ppa(8, &c));
    }
}
