//! Correlation coefficients for the cross-bit-width similarity analysis.
//!
//! Figs. 2/5 observe that the Configuration→PPA/BEHAV *pattern* is similar
//! across operand widths; we quantify that with Pearson and Spearman
//! coefficients over (sub-sampled) aligned metric sequences, plus the
//! non-overlapping window sub-sampling the paper applies to the 12-bit
//! sequence in Fig. 2.

/// Pearson linear correlation of two equal-length sequences.
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    if x.is_empty() {
        return 0.0;
    }
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (&a, &b) in x.iter().zip(y) {
        sxy += (a - mx) * (b - my);
        sxx += (a - mx) * (a - mx);
        syy += (b - my) * (b - my);
    }
    if sxx <= 0.0 || syy <= 0.0 {
        0.0
    } else {
        sxy / (sxx * syy).sqrt()
    }
}

/// Ranks with average tie handling.
fn ranks(x: &[f64]) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..x.len()).collect();
    idx.sort_by(|&a, &b| x[a].partial_cmp(&x[b]).unwrap());
    let mut out = vec![0.0; x.len()];
    let mut i = 0;
    while i < idx.len() {
        let mut j = i;
        while j + 1 < idx.len() && x[idx[j + 1]] == x[idx[i]] {
            j += 1;
        }
        let rank = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            out[idx[k]] = rank;
        }
        i = j + 1;
    }
    out
}

/// Spearman rank correlation.
pub fn spearman(x: &[f64], y: &[f64]) -> f64 {
    pearson(&ranks(x), &ranks(y))
}

/// Non-overlapping window means — the Fig. 2 sub-sampling that reduces the
/// 4096-point 12-bit sequence to 256 points comparable with the 8-bit one.
pub fn window_means(x: &[f64], window: usize) -> Vec<f64> {
    assert!(window >= 1);
    x.chunks(window)
        .map(|c| c.iter().sum::<f64>() / c.len() as f64)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pearson_perfect() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let yn: Vec<f64> = y.iter().map(|v| -v).collect();
        assert!((pearson(&x, &yn) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_constant_is_zero() {
        assert_eq!(pearson(&[1.0, 1.0, 1.0], &[1.0, 2.0, 3.0]), 0.0);
    }

    #[test]
    fn spearman_monotonic_nonlinear() {
        let x = [1.0, 2.0, 3.0, 4.0, 5.0];
        let y = [1.0, 8.0, 27.0, 64.0, 125.0];
        assert!((spearman(&x, &y) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ranks_handle_ties() {
        assert_eq!(ranks(&[10.0, 20.0, 20.0, 30.0]), vec![1.0, 2.5, 2.5, 4.0]);
    }

    #[test]
    fn window_means_shape() {
        let x: Vec<f64> = (0..16).map(|v| v as f64).collect();
        let m = window_means(&x, 4);
        assert_eq!(m, vec![1.5, 5.5, 9.5, 13.5]);
        // 4096 -> 256 with window 16, like the paper.
        let big: Vec<f64> = (0..4096).map(|v| v as f64).collect();
        assert_eq!(window_means(&big, 16).len(), 256);
    }
}
