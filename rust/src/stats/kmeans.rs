//! k-means clustering with k-means++ seeding and elbow-based k selection.
//!
//! Reproduces the clustering analysis of Figs. 1 and 10: the paper clusters
//! the (PPA, BEHAV) design points of two bit-widths (scaled and unscaled)
//! with k from the elbow method and compares centroid alignment.

use crate::util::rng::Rng;

/// Result of one k-means run over 2-D points.
#[derive(Debug, Clone)]
pub struct KMeans {
    pub centroids: Vec<[f64; 2]>,
    pub assignment: Vec<usize>,
    /// Total within-cluster sum of squared distances.
    pub inertia: f64,
    pub iterations: u32,
}

fn d2(a: [f64; 2], b: [f64; 2]) -> f64 {
    let dx = a[0] - b[0];
    let dy = a[1] - b[1];
    dx * dx + dy * dy
}

impl KMeans {
    /// Lloyd's algorithm with k-means++ init (seeded, deterministic).
    pub fn fit(points: &[[f64; 2]], k: usize, seed: u64) -> KMeans {
        assert!(k >= 1 && !points.is_empty());
        let k = k.min(points.len());
        let mut rng = Rng::seed_from_u64(seed);

        // k-means++ seeding.
        let mut centroids: Vec<[f64; 2]> = Vec::with_capacity(k);
        centroids.push(points[rng.gen_index(points.len())]);
        while centroids.len() < k {
            let dists: Vec<f64> = points
                .iter()
                .map(|p| centroids.iter().map(|c| d2(*p, *c)).fold(f64::INFINITY, f64::min))
                .collect();
            let total: f64 = dists.iter().sum();
            if total <= 0.0 {
                // all points coincide with centroids; fill arbitrarily
                centroids.push(points[rng.gen_index(points.len())]);
                continue;
            }
            let mut target = rng.gen_f64() * total;
            let mut pick = 0;
            for (i, d) in dists.iter().enumerate() {
                target -= d;
                if target <= 0.0 {
                    pick = i;
                    break;
                }
            }
            centroids.push(points[pick]);
        }

        let mut assignment = vec![0usize; points.len()];
        let mut iterations = 0;
        for _ in 0..200 {
            iterations += 1;
            // Assign.
            let mut changed = false;
            for (i, p) in points.iter().enumerate() {
                let best = (0..centroids.len())
                    .min_by(|&a, &b| {
                        d2(*p, centroids[a]).partial_cmp(&d2(*p, centroids[b])).unwrap()
                    })
                    .unwrap();
                if assignment[i] != best {
                    assignment[i] = best;
                    changed = true;
                }
            }
            // Update.
            let mut sums = vec![[0.0f64; 2]; centroids.len()];
            let mut counts = vec![0usize; centroids.len()];
            for (i, p) in points.iter().enumerate() {
                sums[assignment[i]][0] += p[0];
                sums[assignment[i]][1] += p[1];
                counts[assignment[i]] += 1;
            }
            for (c, (s, &n)) in centroids.iter_mut().zip(sums.iter().zip(&counts)) {
                if n > 0 {
                    *c = [s[0] / n as f64, s[1] / n as f64];
                }
            }
            if !changed {
                break;
            }
        }

        let inertia = points
            .iter()
            .zip(&assignment)
            .map(|(p, &a)| d2(*p, centroids[a]))
            .sum();
        KMeans { centroids, assignment, inertia, iterations }
    }

    /// Elbow method: fit k = 1..=k_max, pick the k with the largest drop in
    /// the second difference of inertia (the classic knee heuristic).
    pub fn elbow(points: &[[f64; 2]], k_max: usize, seed: u64) -> (usize, Vec<f64>) {
        let k_max = k_max.min(points.len()).max(1);
        let inertias: Vec<f64> =
            (1..=k_max).map(|k| KMeans::fit(points, k, seed).inertia).collect();
        if inertias.len() < 3 {
            return (inertias.len(), inertias);
        }
        let mut best_k = 2;
        let mut best_curv = f64::NEG_INFINITY;
        for k in 1..inertias.len() - 1 {
            let curv = inertias[k - 1] - 2.0 * inertias[k] + inertias[k + 1];
            if curv > best_curv {
                best_curv = curv;
                best_k = k + 1; // inertias[k] is for k+1 clusters
            }
        }
        (best_k, inertias)
    }

    /// Cluster sizes (used by the figure harness).
    pub fn sizes(&self) -> Vec<usize> {
        let mut out = vec![0usize; self.centroids.len()];
        for &a in &self.assignment {
            out[a] += 1;
        }
        out
    }
}

/// Greedy minimal-total-distance matching between two centroid sets —
/// quantifies the Fig. 1(b)/10 "centroid alignment" observation.
pub fn centroid_alignment(a: &[[f64; 2]], b: &[[f64; 2]]) -> f64 {
    let mut used = vec![false; b.len()];
    let mut total = 0.0;
    for ca in a {
        let mut best = f64::INFINITY;
        let mut best_j = None;
        for (j, cb) in b.iter().enumerate() {
            if !used[j] {
                let d = d2(*ca, *cb).sqrt();
                if d < best {
                    best = d;
                    best_j = Some(j);
                }
            }
        }
        if let Some(j) = best_j {
            used[j] = true;
            total += best;
        }
    }
    total / a.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blobs() -> Vec<[f64; 2]> {
        let mut pts = Vec::new();
        for i in 0..20 {
            let t = i as f64 * 0.01;
            pts.push([0.0 + t, 0.0 + t]);
            pts.push([1.0 + t, 1.0 + t]);
            pts.push([0.0 + t, 1.0 - t]);
        }
        pts
    }

    #[test]
    fn separates_blobs() {
        let km = KMeans::fit(&blobs(), 3, 1);
        assert_eq!(km.centroids.len(), 3);
        let sizes = km.sizes();
        assert_eq!(sizes.iter().sum::<usize>(), 60);
        assert!(sizes.iter().all(|&s| s == 20), "{sizes:?}");
        assert!(km.inertia < 1.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = KMeans::fit(&blobs(), 3, 7);
        let b = KMeans::fit(&blobs(), 3, 7);
        assert_eq!(a.centroids, b.centroids);
        assert_eq!(a.assignment, b.assignment);
    }

    #[test]
    fn inertia_nonincreasing_in_k() {
        let pts = blobs();
        let (_, inertias) = KMeans::elbow(&pts, 6, 3);
        for w in inertias.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "{inertias:?}");
        }
    }

    #[test]
    fn elbow_finds_three_blobs() {
        let (k, _) = KMeans::elbow(&blobs(), 8, 5);
        assert!((2..=4).contains(&k), "elbow k = {k}");
    }

    #[test]
    fn alignment_zero_for_identical() {
        let c = vec![[0.0, 0.0], [1.0, 1.0]];
        assert_eq!(centroid_alignment(&c, &c), 0.0);
        let d = vec![[0.5, 0.0], [1.0, 1.0]];
        assert!(centroid_alignment(&c, &d) > 0.0);
    }

    #[test]
    fn k_clamped_to_points() {
        let pts = vec![[0.0, 0.0], [1.0, 1.0]];
        let km = KMeans::fit(&pts, 10, 0);
        assert!(km.centroids.len() <= 2);
    }
}
