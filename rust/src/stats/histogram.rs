//! Fixed-bin histograms — the Fig. 11 distance-distribution analysis.

/// A simple equal-width histogram over `[lo, hi]`.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub n: u64,
}

impl Histogram {
    pub fn from_values(values: &[f64], bins: usize) -> Histogram {
        assert!(bins >= 1);
        let lo = values.iter().copied().fold(f64::INFINITY, f64::min);
        let hi = values.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        let (lo, hi) = if values.is_empty() { (0.0, 1.0) } else { (lo, hi) };
        Self::from_values_range(values, bins, lo, hi)
    }

    pub fn from_values_range(values: &[f64], bins: usize, lo: f64, hi: f64) -> Histogram {
        let span = if hi > lo { hi - lo } else { 1.0 };
        let mut counts = vec![0u64; bins];
        for &v in values {
            let k = (((v - lo) / span) * bins as f64).floor() as isize;
            let k = k.clamp(0, bins as isize - 1) as usize;
            counts[k] += 1;
        }
        Histogram { lo, hi, counts, n: values.len() as u64 }
    }

    /// Bin centers for plotting.
    pub fn centers(&self) -> Vec<f64> {
        let bins = self.counts.len();
        let w = (self.hi - self.lo) / bins as f64;
        (0..bins).map(|k| self.lo + w * (k as f64 + 0.5)).collect()
    }

    /// Normalized densities (sum = 1).
    pub fn densities(&self) -> Vec<f64> {
        let n = self.n.max(1) as f64;
        self.counts.iter().map(|&c| c as f64 / n).collect()
    }

    /// Spread proxy used in the paper's distance-measure selection: the
    /// fraction of non-empty bins. A long-tailed measure (Pareto) piles
    /// mass into few bins; Euclidean/Manhattan spread widely (Fig. 11).
    pub fn occupancy(&self) -> f64 {
        let nz = self.counts.iter().filter(|&&c| c > 0).count();
        nz as f64 / self.counts.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_and_centers() {
        let h = Histogram::from_values_range(&[0.1, 0.9, 0.5, 0.55], 2, 0.0, 1.0);
        assert_eq!(h.counts, vec![1, 3]);
        assert_eq!(h.centers(), vec![0.25, 0.75]);
        assert_eq!(h.densities(), vec![0.25, 0.75]);
    }

    #[test]
    fn values_at_edges_clamp() {
        let h = Histogram::from_values_range(&[0.0, 1.0, 1.5, -0.5], 4, 0.0, 1.0);
        assert_eq!(h.counts.iter().sum::<u64>(), 4);
        assert_eq!(h.counts[0], 2); // 0.0 and clamped -0.5
        assert_eq!(h.counts[3], 2); // 1.0 and clamped 1.5
    }

    #[test]
    fn occupancy_detects_long_tail() {
        let wide: Vec<f64> = (0..100).map(|i| i as f64 / 100.0).collect();
        let tail = vec![0.0; 99].into_iter().chain([1.0]).collect::<Vec<_>>();
        let hw = Histogram::from_values_range(&wide, 10, 0.0, 1.0);
        let ht = Histogram::from_values_range(&tail, 10, 0.0, 1.0);
        assert!(hw.occupancy() > ht.occupancy());
    }

    #[test]
    fn empty_values() {
        let h = Histogram::from_values(&[], 4);
        assert_eq!(h.n, 0);
        assert_eq!(h.counts, vec![0, 0, 0, 0]);
    }
}
