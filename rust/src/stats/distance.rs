//! Distance measures for the similarity analysis (paper §IV-A-2, Fig. 6).
//!
//! Points live in the 2-D (BEHAV, PPA) metric plane (scaled). Three
//! measures, each with an optional *sign* encoding the relative location of
//! the L point w.r.t. the H point (paper: "adding a sign ... provides
//! information regarding their relative location"):
//!
//! * Euclidean `d_e = sqrt(Δb² + Δp²)` — used for the supersampling
//!   datasets (§V-C picks it for its wide, well-differentiated
//!   distribution, Fig. 11);
//! * Manhattan `d_m = |Δb| + |Δp|` — similar spread, slower growth;
//! * Pareto `d_p = max(|Δb|, |Δp|)` — DSE-specific dominance-style
//!   measure; long-tailed distribution (many ties), hence *not* chosen.

/// Distance measure selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DistanceKind {
    Euclidean,
    Manhattan,
    Pareto,
}

impl DistanceKind {
    pub const ALL: [DistanceKind; 3] =
        [DistanceKind::Euclidean, DistanceKind::Manhattan, DistanceKind::Pareto];

    pub fn name(&self) -> &'static str {
        match self {
            DistanceKind::Euclidean => "euclidean",
            DistanceKind::Manhattan => "manhattan",
            DistanceKind::Pareto => "pareto",
        }
    }

    pub fn from_name(name: &str) -> Option<DistanceKind> {
        Self::ALL.into_iter().find(|k| k.name() == name)
    }

    /// Unsigned distance between two (BEHAV, PPA) points.
    #[inline]
    pub fn distance(&self, a: [f64; 2], b: [f64; 2]) -> f64 {
        let db = (a[0] - b[0]).abs();
        let dp = (a[1] - b[1]).abs();
        match self {
            DistanceKind::Euclidean => (db * db + dp * dp).sqrt(),
            DistanceKind::Manhattan => db + dp,
            DistanceKind::Pareto => db.max(dp),
        }
    }

    /// Signed variant: negative when `to` dominates `from` (both coordinates
    /// strictly smaller — i.e. the L design is better on both axes).
    #[inline]
    pub fn signed_distance(&self, from: [f64; 2], to: [f64; 2]) -> f64 {
        let d = self.distance(from, to);
        if to[0] < from[0] && to[1] < from[1] {
            -d
        } else {
            d
        }
    }
}

/// Full pairwise distance matrix, row-major `(h.len(), l.len())` — the
/// Fig. 12(a) heat-map and the matching substrate.
pub fn distance_matrix(
    kind: DistanceKind,
    h_points: &[[f64; 2]],
    l_points: &[[f64; 2]],
) -> Vec<f64> {
    let mut out = Vec::with_capacity(h_points.len() * l_points.len());
    for h in h_points {
        for l in l_points {
            out.push(kind.distance(*h, *l));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_agree_on_axis() {
        let a = [0.0, 0.0];
        let b = [3.0, 0.0];
        for k in DistanceKind::ALL {
            assert_eq!(k.distance(a, b), 3.0);
        }
    }

    #[test]
    fn measure_ordering_off_axis() {
        // For a 3-4-5 triangle: manhattan 7 > euclid 5 > pareto 4.
        let a = [0.0, 0.0];
        let b = [3.0, 4.0];
        assert_eq!(DistanceKind::Euclidean.distance(a, b), 5.0);
        assert_eq!(DistanceKind::Manhattan.distance(a, b), 7.0);
        assert_eq!(DistanceKind::Pareto.distance(a, b), 4.0);
    }

    #[test]
    fn signed_distance_negative_iff_dominating() {
        let h = [0.5, 0.5];
        assert!(DistanceKind::Euclidean.signed_distance(h, [0.1, 0.1]) < 0.0);
        assert!(DistanceKind::Euclidean.signed_distance(h, [0.1, 0.9]) > 0.0);
        assert!(DistanceKind::Euclidean.signed_distance(h, [0.9, 0.1]) > 0.0);
    }

    #[test]
    fn matrix_layout() {
        let h = [[0.0, 0.0], [1.0, 1.0]];
        let l = [[0.0, 1.0], [1.0, 0.0], [0.0, 0.0]];
        let m = distance_matrix(DistanceKind::Manhattan, &h, &l);
        assert_eq!(m.len(), 6);
        assert_eq!(m[0], 1.0); // h0-l0
        assert_eq!(m[2], 0.0); // h0-l2
        assert_eq!(m[3 + 2], 2.0); // h1-l2
    }

    #[test]
    fn symmetry() {
        let a = [0.3, 0.9];
        let b = [0.7, 0.2];
        for k in DistanceKind::ALL {
            assert!((k.distance(a, b) - k.distance(b, a)).abs() < 1e-15);
        }
    }
}
