//! Statistical analysis toolkit (paper §IV-A).
//!
//! Everything the paper's "Statistical Analysis" block needs: min-max
//! scaling, k-means clustering with elbow-based k selection (Figs. 1/10),
//! the three distance measures with optional sign (Fig. 6, Fig. 11
//! distributions), histograms, and correlation coefficients used in the
//! similarity analysis across bit-widths (Figs. 2/5).

pub mod correlation;
pub mod distance;
pub mod histogram;
pub mod kmeans;
pub mod scaling;

pub use distance::DistanceKind;
pub use histogram::Histogram;
pub use kmeans::KMeans;
pub use scaling::MinMaxScaler;
