//! Column-wise min-max scaling to [0, 1].
//!
//! The paper compares operators of different bit-widths in *scaled* metric
//! space (Fig. 1b) and trains ConSS on scaled constraint values; constant
//! columns map to 0 (same convention as `matching.minmax_scale` in python).

use crate::error::{Error, Result};

/// Fitted min-max scaler over fixed-width rows.
#[derive(Debug, Clone)]
pub struct MinMaxScaler {
    pub min: Vec<f64>,
    pub max: Vec<f64>,
}

impl MinMaxScaler {
    /// Fit over row-major `data` with `dim` columns.
    pub fn fit(data: &[f64], dim: usize) -> Result<MinMaxScaler> {
        if dim == 0 || data.is_empty() || data.len() % dim != 0 {
            return Err(Error::Dataset(format!(
                "cannot fit scaler: len {} dim {dim}",
                data.len()
            )));
        }
        let mut min = vec![f64::INFINITY; dim];
        let mut max = vec![f64::NEG_INFINITY; dim];
        for row in data.chunks_exact(dim) {
            for (k, &v) in row.iter().enumerate() {
                min[k] = min[k].min(v);
                max[k] = max[k].max(v);
            }
        }
        Ok(MinMaxScaler { min, max })
    }

    pub fn fit_points2(points: &[[f64; 2]]) -> Result<MinMaxScaler> {
        let flat: Vec<f64> = points.iter().flatten().copied().collect();
        Self::fit(&flat, 2)
    }

    pub fn dim(&self) -> usize {
        self.min.len()
    }

    /// Scale one value in column `k` (constant columns map to 0).
    #[inline]
    pub fn scale_value(&self, k: usize, v: f64) -> f64 {
        let span = self.max[k] - self.min[k];
        if span > 0.0 {
            (v - self.min[k]) / span
        } else {
            0.0
        }
    }

    /// Inverse transform of one column value.
    #[inline]
    pub fn unscale_value(&self, k: usize, s: f64) -> f64 {
        self.min[k] + s * (self.max[k] - self.min[k])
    }

    pub fn transform_row(&self, row: &[f64]) -> Vec<f64> {
        row.iter().enumerate().map(|(k, &v)| self.scale_value(k, v)).collect()
    }

    pub fn transform_points2(&self, points: &[[f64; 2]]) -> Vec<[f64; 2]> {
        points
            .iter()
            .map(|p| [self.scale_value(0, p[0]), self.scale_value(1, p[1])])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_transform_roundtrip() {
        let pts = vec![[0.0, 5.0], [10.0, 5.0], [5.0, 5.0]];
        let s = MinMaxScaler::fit_points2(&pts).unwrap();
        let t = s.transform_points2(&pts);
        assert_eq!(t, vec![[0.0, 0.0], [1.0, 0.0], [0.5, 0.0]]);
        assert_eq!(s.unscale_value(0, 0.5), 5.0);
    }

    #[test]
    fn rejects_bad_shapes() {
        assert!(MinMaxScaler::fit(&[1.0, 2.0, 3.0], 2).is_err());
        assert!(MinMaxScaler::fit(&[], 2).is_err());
        assert!(MinMaxScaler::fit(&[1.0], 0).is_err());
    }

    #[test]
    fn scale_is_bounded() {
        let s = MinMaxScaler::fit(&[1.0, 3.0, 9.0], 1).unwrap();
        for v in [1.0, 3.0, 9.0] {
            let t = s.scale_value(0, v);
            assert!((0.0..=1.0).contains(&t));
        }
    }
}
