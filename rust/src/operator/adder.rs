//! Unsigned N-bit approximate ripple-carry adder (L = N).
//!
//! LUT *i* computes the propagate signal `p_i = a_i XOR b_i`; the carry
//! chain MUXCY selects `c_{i+1} = c_i` when `p_i` else the DI input `b_i`,
//! and the XORCY forms `s_i = p_i XOR c_i`. Removing LUT *i* (`l_i = 0`)
//! forces `p_i = 0`, hence `s_i = c_i` and `c_{i+1} = b_i` — the carry
//! chain is *cut and re-seeded* at that bit, which is exactly the sub-adder
//! truncation effect the synthesis model's timing rule rewards.
//!
//! Mirrors `python/compile/operator_model.py::adder_eval` bit-for-bit.

use super::AxoConfig;

/// Approximate sum of one operand pair under `config`.
#[inline]
pub fn eval_one(config: &AxoConfig, a: u64, b: u64) -> u64 {
    let n = config.len();
    let cfg = config.as_uint();
    let mut carry = 0u64;
    let mut out = 0u64;
    for i in 0..n {
        let ai = (a >> i) & 1;
        let bi = (b >> i) & 1;
        let p = (ai ^ bi) & ((cfg >> i) & 1);
        out |= (p ^ carry) << i;
        // Branch-free MUXCY: select carry when p else DI = b_i (§Perf L3-3).
        let pm = p.wrapping_neg();
        carry = (carry & pm) | (bi & !pm);
    }
    out | (carry << n)
}

/// Exact sum (reference semantics).
#[inline]
pub fn exact(a: u64, b: u64) -> u64 {
    a + b
}

/// Approximate sums for a batch of configs × shared input set.
///
/// Returns a `configs.len() × inputs.len()` row-major matrix. This is the
/// native fallback for the Pallas `axo_eval` kernel; the characterization
/// pipeline prefers the PJRT path and cross-checks against this one.
pub fn eval_batch(configs: &[AxoConfig], a: &[u32], b: &[u32]) -> Vec<u64> {
    assert_eq!(a.len(), b.len());
    let mut out = Vec::with_capacity(configs.len() * a.len());
    for cfg in configs {
        for (&ai, &bi) in a.iter().zip(b) {
            out.push(eval_one(cfg, ai as u64, bi as u64));
        }
    }
    out
}

/// Exhaustive input set: all `2^(2n)` (a, b) pairs (n <= 8 in practice).
pub fn exhaustive_inputs(n_bits: u32) -> (Vec<u32>, Vec<u32>) {
    let n = 1u64 << n_bits;
    let total = (n * n) as usize;
    let mut a = Vec::with_capacity(total);
    let mut b = Vec::with_capacity(total);
    for idx in 0..(n * n) {
        a.push((idx & (n - 1)) as u32);
        b.push((idx >> n_bits) as u32);
    }
    (a, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accurate_config_is_exact_exhaustive_4bit() {
        let cfg = AxoConfig::accurate(4);
        let (a, b) = exhaustive_inputs(4);
        for (&ai, &bi) in a.iter().zip(&b) {
            assert_eq!(eval_one(&cfg, ai as u64, bi as u64), (ai + bi) as u64);
        }
    }

    #[test]
    fn accurate_config_is_exact_sampled_12bit() {
        let cfg = AxoConfig::accurate(12);
        for (a, b) in [(0u64, 0u64), (4095, 4095), (1234, 987), (2048, 2047)] {
            assert_eq!(eval_one(&cfg, a, b), a + b);
        }
    }

    #[test]
    fn removal_rule_bit0() {
        // Same fixture as python test_adder_removal_rule_bit0.
        let cfg = AxoConfig::from_bits(&[0, 1, 1]).unwrap();
        assert_eq!(eval_one(&cfg, 1, 1), 2);
        assert_eq!(eval_one(&cfg, 1, 0), 0);
    }

    #[test]
    fn eval_batch_matches_eval_one() {
        let cfgs: Vec<AxoConfig> = AxoConfig::enumerate(4).collect();
        let (a, b) = exhaustive_inputs(4);
        let m = eval_batch(&cfgs, &a, &b);
        for (ci, cfg) in cfgs.iter().enumerate() {
            for (t, (&ai, &bi)) in a.iter().zip(&b).enumerate() {
                assert_eq!(m[ci * a.len() + t], eval_one(cfg, ai as u64, bi as u64));
            }
        }
    }

    #[test]
    fn exhaustive_inputs_layout() {
        let (a, b) = exhaustive_inputs(2);
        assert_eq!(a.len(), 16);
        assert_eq!(a[..4], [0, 1, 2, 3]);
        assert_eq!(b[..4], [0, 0, 0, 0]);
        assert_eq!(b[4], 1);
    }
}
