//! LUT-level approximate operator model (paper Section III).
//!
//! An FPGA arithmetic operator is an ordered tuple `O_i(l_0..l_{L-1})`,
//! `l = 1` keeps the corresponding LUT of the accurate implementation,
//! `l = 0` removes it. The all-ones configuration is the accurate operator;
//! the all-zeros configuration is excluded from every experiment (paper
//! footnote 4).
//!
//! Two families are modelled bit-exactly, mirroring
//! `python/compile/operator_model.py` (cross-checked by
//! `artifacts/golden_behav.json`):
//!
//! * [`adder`] — unsigned N-bit ripple-carry adders (`L = N`);
//! * [`multiplier`] — signed M×M Baugh-Wooley multipliers
//!   (`L = M(M+1)/2`: 10 for 4×4, 36 for 8×8 — Table II).

pub mod adder;
pub mod bitslice;
pub mod config;
pub mod multiplier;

pub use config::AxoConfig;

use crate::error::{Error, Result};

/// Operator family.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OperatorKind {
    /// Unsigned ripple-carry adder.
    UnsignedAdder,
    /// Signed Baugh-Wooley multiplier.
    SignedMultiplier,
}

/// A concrete operator instance from Table II of the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Operator {
    pub kind: OperatorKind,
    /// Operand bit-width (N for adders, M for multipliers).
    pub bits: u32,
}

impl Operator {
    pub const ADD4: Operator = Operator { kind: OperatorKind::UnsignedAdder, bits: 4 };
    pub const ADD8: Operator = Operator { kind: OperatorKind::UnsignedAdder, bits: 8 };
    pub const ADD12: Operator = Operator { kind: OperatorKind::UnsignedAdder, bits: 12 };
    pub const MUL4: Operator = Operator { kind: OperatorKind::SignedMultiplier, bits: 4 };
    pub const MUL8: Operator = Operator { kind: OperatorKind::SignedMultiplier, bits: 8 };

    /// Every operator evaluated in the paper (Table II).
    pub const ALL: [Operator; 5] =
        [Self::ADD4, Self::ADD8, Self::ADD12, Self::MUL4, Self::MUL8];

    /// Configuration string length `L`.
    pub fn config_len(&self) -> u32 {
        match self.kind {
            OperatorKind::UnsignedAdder => self.bits,
            OperatorKind::SignedMultiplier => self.bits * (self.bits + 1) / 2,
        }
    }

    /// Number of usable approximate designs (`2^L - 1`, all-zeros excluded).
    /// `None` when it exceeds `u64` practicality reporting (not the case here).
    pub fn design_space_size(&self) -> u128 {
        (1u128 << self.config_len()) - 1
    }

    /// Short identifier used for artifact and dataset names
    /// (`add4`, `add8`, `add12`, `mul4`, `mul8`).
    pub fn name(&self) -> String {
        match self.kind {
            OperatorKind::UnsignedAdder => format!("add{}", self.bits),
            OperatorKind::SignedMultiplier => format!("mul{}", self.bits),
        }
    }

    /// Parse `add4`-style identifiers.
    pub fn from_name(name: &str) -> Result<Operator> {
        let op = match name {
            "add4" => Self::ADD4,
            "add8" => Self::ADD8,
            "add12" => Self::ADD12,
            "mul4" => Self::MUL4,
            "mul8" => Self::MUL8,
            _ => {
                return Err(Error::InvalidConfig(format!(
                    "unknown operator `{name}` (expected add4|add8|add12|mul4|mul8)"
                )))
            }
        };
        Ok(op)
    }

    /// Whether the full design space is exhaustively characterizable
    /// (everything except the 8×8 multiplier's 68.7-billion space).
    pub fn exhaustive(&self) -> bool {
        self.config_len() <= 16
    }

    /// Exact outputs for operand pairs (reference semantics).
    pub fn exact(&self, a: i64, b: i64) -> i64 {
        match self.kind {
            OperatorKind::UnsignedAdder => a + b,
            OperatorKind::SignedMultiplier => a * b,
        }
    }

    /// Approximate output under `config` for one operand pair.
    ///
    /// Batch paths ([`adder::eval_batch`], [`multiplier::eval_batch`]) are
    /// the hot ones; this scalar form is the readable reference used by the
    /// application case-study example and tests.
    pub fn approx(&self, config: &AxoConfig, a: i64, b: i64) -> i64 {
        debug_assert_eq!(config.len(), self.config_len());
        match self.kind {
            OperatorKind::UnsignedAdder => adder::eval_one(config, a as u64, b as u64) as i64,
            OperatorKind::SignedMultiplier => multiplier::eval_one(self.bits, config, a, b),
        }
    }
}

impl std::fmt::Display for Operator {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_lens_match_table2() {
        assert_eq!(Operator::ADD4.config_len(), 4);
        assert_eq!(Operator::ADD8.config_len(), 8);
        assert_eq!(Operator::ADD12.config_len(), 12);
        assert_eq!(Operator::MUL4.config_len(), 10);
        assert_eq!(Operator::MUL8.config_len(), 36);
    }

    #[test]
    fn design_space_sizes_match_table2() {
        assert_eq!(Operator::ADD4.design_space_size(), 15); // 16 incl. zero
        assert_eq!(Operator::ADD8.design_space_size(), 255);
        assert_eq!(Operator::ADD12.design_space_size(), 4095);
        assert_eq!(Operator::MUL4.design_space_size(), 1023);
        // "68.7 Billion" in Table II.
        assert_eq!(Operator::MUL8.design_space_size(), (1u128 << 36) - 1);
    }

    #[test]
    fn name_roundtrip() {
        for op in Operator::ALL {
            assert_eq!(Operator::from_name(&op.name()).unwrap(), op);
        }
        assert!(Operator::from_name("div2").is_err());
    }

    #[test]
    fn exhaustive_flags() {
        assert!(Operator::ADD12.exhaustive());
        assert!(Operator::MUL4.exhaustive());
        assert!(!Operator::MUL8.exhaustive());
    }
}
