//! Approximate configuration bit-strings.
//!
//! A configuration is stored as the UINT encoding (bit k == `l_k`) in a
//! `u64` — every operator in the paper has `L <= 36`. The all-zeros
//! configuration is rejected at construction (paper footnote 4).

use crate::error::{Error, Result};
use crate::util::rng::Rng;
use std::collections::HashSet;

/// An approximate operator configuration `O_i(l_0..l_{L-1})`.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct AxoConfig {
    bits: u64,
    len: u32,
}

impl AxoConfig {
    /// Construct from a UINT encoding. Rejects zero and out-of-range values.
    pub fn new(bits: u64, len: u32) -> Result<Self> {
        if len == 0 || len > 64 {
            return Err(Error::InvalidConfig(format!("bad config length {len}")));
        }
        if len < 64 && bits >> len != 0 {
            return Err(Error::InvalidConfig(format!(
                "value {bits:#x} does not fit in {len} bits"
            )));
        }
        if bits == 0 {
            return Err(Error::InvalidConfig(
                "all-zeros configuration is excluded (paper fn. 4)".into(),
            ));
        }
        Ok(AxoConfig { bits, len })
    }

    /// The accurate implementation `O_Ac(1,1,...,1)`.
    pub fn accurate(len: u32) -> Self {
        AxoConfig { bits: if len == 64 { u64::MAX } else { (1 << len) - 1 }, len }
    }

    /// UINT encoding (paper Figs. 2/5 horizontal axis).
    pub fn as_uint(&self) -> u64 {
        self.bits
    }

    pub fn len(&self) -> u32 {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        false // all-zeros is unrepresentable
    }

    /// Whether LUT `k` is kept.
    #[inline]
    pub fn keeps(&self, k: u32) -> bool {
        debug_assert!(k < self.len);
        (self.bits >> k) & 1 == 1
    }

    /// Number of retained LUTs.
    #[inline]
    pub fn count_kept(&self) -> u32 {
        self.bits.count_ones()
    }

    pub fn is_accurate(&self) -> bool {
        self.count_kept() == self.len
    }

    /// 0/1 vector (LSB first), the representation fed to kernels and ML.
    pub fn to_bits_f32(&self) -> Vec<f32> {
        (0..self.len).map(|k| if self.keeps(k) { 1.0 } else { 0.0 }).collect()
    }

    pub fn to_bits_u8(&self) -> Vec<u8> {
        (0..self.len).map(|k| self.keeps(k) as u8).collect()
    }

    /// Build from a 0/1 slice (LSB first). Values > 0 count as 1.
    pub fn from_bits(bits: &[u8]) -> Result<Self> {
        let mut v = 0u64;
        for (k, &b) in bits.iter().enumerate() {
            if b > 0 {
                v |= 1 << k;
            }
        }
        Self::new(v, bits.len() as u32)
    }

    /// Flip LUT `k`, returning `None` if that would produce all-zeros.
    pub fn flipped(&self, k: u32) -> Option<Self> {
        let bits = self.bits ^ (1 << k);
        (bits != 0).then_some(AxoConfig { bits, len: self.len })
    }

    /// Hamming distance between two configurations of equal length.
    pub fn hamming(&self, other: &AxoConfig) -> u32 {
        debug_assert_eq!(self.len, other.len);
        (self.bits ^ other.bits).count_ones()
    }

    /// Single-point crossover at `point` (1..len), paper §IV-C-2.
    pub fn crossover(&self, other: &AxoConfig, point: u32) -> (Option<Self>, Option<Self>) {
        debug_assert_eq!(self.len, other.len);
        debug_assert!(point > 0 && point < self.len);
        let low_mask = (1u64 << point) - 1;
        let c1 = (self.bits & low_mask) | (other.bits & !low_mask);
        let c2 = (other.bits & low_mask) | (self.bits & !low_mask);
        let mk = |b: u64| (b != 0).then_some(AxoConfig { bits: b, len: self.len });
        (mk(c1), mk(c2))
    }

    /// All `2^L - 1` usable configurations, ascending UINT order.
    pub fn enumerate(len: u32) -> impl Iterator<Item = AxoConfig> {
        debug_assert!(len <= 20, "enumerate() is for exhaustive small spaces");
        (1..(1u64 << len)).map(move |v| AxoConfig { bits: v, len })
    }

    /// `n` unique seeded random non-zero configurations (paper §V-A samples
    /// 10,650 of the 8×8 multiplier space).
    pub fn sample_unique(len: u32, n: usize, rng: &mut Rng) -> Vec<AxoConfig> {
        let space = if len >= 63 { u64::MAX } else { (1u64 << len) - 1 };
        assert!((n as u64) <= space, "cannot sample {n} unique from 2^{len}-1");
        let mut seen = HashSet::with_capacity(n);
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            let v = rng.gen_range_inclusive(1, space);
            if seen.insert(v) {
                out.push(AxoConfig { bits: v, len });
            }
        }
        out
    }
}

impl std::fmt::Debug for AxoConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "AxoConfig({:0width$b})", self.bits, width = self.len as usize)
    }
}

/// `Display` shows the bit-string MSB-first, like the paper's figures.
/// Goes through `Formatter::pad` so width/alignment flags work in tables.
impl std::fmt::Display for AxoConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::with_capacity(self.len as usize);
        for k in (0..self.len).rev() {
            s.push(if self.keeps(k) { '1' } else { '0' });
        }
        f.pad(&s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejects_zero_and_overflow() {
        assert!(AxoConfig::new(0, 8).is_err());
        assert!(AxoConfig::new(256, 8).is_err());
        assert!(AxoConfig::new(255, 8).is_ok());
    }

    #[test]
    fn accurate_is_all_ones() {
        let c = AxoConfig::accurate(8);
        assert!(c.is_accurate());
        assert_eq!(c.as_uint(), 255);
        assert_eq!(c.count_kept(), 8);
    }

    #[test]
    fn bits_roundtrip() {
        let c = AxoConfig::new(0b1011, 4).unwrap();
        assert_eq!(c.to_bits_u8(), vec![1, 1, 0, 1]);
        assert_eq!(AxoConfig::from_bits(&[1, 1, 0, 1]).unwrap(), c);
        assert_eq!(c.to_bits_f32(), vec![1.0, 1.0, 0.0, 1.0]);
    }

    #[test]
    fn crossover_masks() {
        let a = AxoConfig::new(0b1111, 4).unwrap();
        let b = AxoConfig::new(0b0001, 4).unwrap();
        let (c1, c2) = a.crossover(&b, 2);
        assert_eq!(c1.unwrap().as_uint(), 0b0011);
        assert_eq!(c2.unwrap().as_uint(), 0b1101);
    }

    #[test]
    fn crossover_never_yields_zero() {
        let a = AxoConfig::new(0b1100, 4).unwrap();
        let b = AxoConfig::new(0b1100, 4).unwrap();
        let (c1, c2) = a.crossover(&b, 2);
        // low(a)=00, high(b)=11xx -> 1100 fine; but low zero + high zero -> None
        assert!(c1.is_some() && c2.is_some());
        let z1 = AxoConfig::new(0b0011, 4).unwrap();
        let z2 = AxoConfig::new(0b1100, 4).unwrap();
        let (d1, d2) = z1.crossover(&z2, 2);
        // low(z1)=11 | high(z2)=11xx -> 1111; low(z2)=00 | high(z1)=00 -> zero
        assert_eq!(d1.unwrap().as_uint(), 0b1111);
        assert_eq!(d2, None);
    }

    #[test]
    fn enumerate_counts() {
        assert_eq!(AxoConfig::enumerate(4).count(), 15);
        assert_eq!(AxoConfig::enumerate(10).count(), 1023);
    }

    #[test]
    fn sample_unique_deterministic() {
        let mut r1 = Rng::seed_from_u64(42);
        let mut r2 = Rng::seed_from_u64(42);
        let a = AxoConfig::sample_unique(36, 500, &mut r1);
        let b = AxoConfig::sample_unique(36, 500, &mut r2);
        assert_eq!(a, b);
        let set: HashSet<u64> = a.iter().map(|c| c.as_uint()).collect();
        assert_eq!(set.len(), 500);
        assert!(!set.contains(&0));
    }

    #[test]
    fn hamming_distance() {
        let a = AxoConfig::new(0b1010, 4).unwrap();
        let b = AxoConfig::new(0b0110, 4).unwrap();
        assert_eq!(a.hamming(&b), 2);
    }

    #[test]
    fn display_msb_first() {
        let c = AxoConfig::new(0b0011, 4).unwrap();
        assert_eq!(c.to_string(), "0011");
    }
}
