//! Bit-sliced (64-lane) evaluation primitives for the operator models.
//!
//! The approximate adder and Baugh-Wooley multiplier are gate-level boolean
//! circuits, so the classic bit-slicing transform applies: transpose the
//! input set so that *plane* `i` is a `u64` whose bit `t` is bit `i` of test
//! vector `t`, then run the circuit's boolean recurrences on whole planes —
//! one pass evaluates 64 vectors. [`BitMatrix`] holds the packed planes in
//! 64-lane blocks; the plane-level evaluators below mirror
//! [`adder::eval_one`](super::adder::eval_one) and the removed-term algebra
//! of [`multiplier::terms_one`](super::multiplier::terms_one) exactly, and
//! `charac::behav` folds the resulting |err| planes into metrics.
//!
//! Layout invariants shared with `charac::behav`:
//! - blocks are 64 consecutive vectors; the tail block is zero-padded, and
//!   padding lanes always evaluate to zero error (0 ⊕ 0 under any config);
//! - error magnitudes fit [`MAG_BITS`] planes (asserted), so the magnitude
//!   planes of [`GROUP_BLOCKS`] blocks tile one 64×64 transpose, amortizing
//!   the unpack cost across four blocks.

/// Bit-planes per error magnitude: adders up to 15 bits (`n + 1` sum
/// planes) and multipliers up to 8×8 (|err| ≤ 255² < 2¹⁶) fit 16 planes.
pub const MAG_BITS: usize = 16;

/// Blocks whose magnitude planes share one 64×64 unpack transpose.
pub const GROUP_BLOCKS: usize = 64 / MAG_BITS;

/// In-place 64×64 bit-matrix transpose (Hacker's Delight §7-3 delta swap):
/// bit `63 - c` of output word `r` is bit `63 - r` of input word `c`.
pub fn transpose64(a: &mut [u64; 64]) {
    let mut j = 32usize;
    let mut m: u64 = 0x0000_0000_FFFF_FFFF;
    while j != 0 {
        let mut k = 0usize;
        while k < 64 {
            let t = (a[k] ^ (a[k + j] >> j)) & m;
            a[k] ^= t;
            a[k + j] ^= t << j;
            k = (k + j + 1) & !j;
        }
        j >>= 1;
        m ^= m << j;
    }
}

/// One operand column of an input set, transposed into bit planes.
///
/// Block-major layout: `block(blk)[i]` is plane `i` (weight 2^i) of vectors
/// `blk*64 .. blk*64+64`; bit `t` of that plane is bit `i` of vector
/// `blk*64 + t`. Lanes past `len()` in the tail block are packed as zero.
#[derive(Debug, Clone)]
pub struct BitMatrix {
    n: usize,
    n_bits: usize,
    planes: Vec<u64>,
}

impl BitMatrix {
    /// Pack `value(0..n)` (low `n_bits` significant) into planes.
    pub fn pack(n: usize, n_bits: usize, value: impl Fn(usize) -> u64) -> BitMatrix {
        assert!(n_bits <= 64);
        let n_blocks = n.div_ceil(64);
        let mut planes = vec![0u64; n_blocks * n_bits];
        let mut buf = [0u64; 64];
        for blk in 0..n_blocks {
            let base = blk * 64;
            let lanes = (n - base).min(64);
            buf.fill(0);
            // transpose64 is MSB-first on both axes — fill and read reversed
            // so that plane p bit t == value(base + t) bit p.
            for (t, slot) in buf.iter_mut().rev().enumerate().take(lanes) {
                *slot = value(base + t);
            }
            transpose64(&mut buf);
            let row = &mut planes[blk * n_bits..(blk + 1) * n_bits];
            for (i, p) in row.iter_mut().enumerate() {
                *p = buf[63 - i];
            }
        }
        BitMatrix { n, n_bits, planes }
    }

    /// Number of packed vectors.
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Planes per vector.
    pub fn n_bits(&self) -> usize {
        self.n_bits
    }

    /// Number of 64-lane blocks (tail block possibly partial).
    pub fn n_blocks(&self) -> usize {
        self.n.div_ceil(64)
    }

    /// Live lanes in `blk` — 64 for all but possibly the tail block.
    pub fn lanes_in(&self, blk: usize) -> usize {
        (self.n - blk * 64).min(64)
    }

    /// The `n_bits` planes of `blk`.
    pub fn block(&self, blk: usize) -> &[u64] {
        &self.planes[blk * self.n_bits..(blk + 1) * self.n_bits]
    }
}

/// Scatter planes back to per-lane values: `out[t] = Σ_p ((planes[p]>>t)&1)
/// << p`. Inverse of [`BitMatrix::pack`] for one block (`planes.len() ≤ 64`,
/// missing high planes read as zero).
pub fn unpack64(planes: &[u64], out: &mut [u64; 64]) {
    debug_assert!(planes.len() <= 64);
    let mut buf = [0u64; 64];
    for (p, &w) in planes.iter().enumerate() {
        buf[63 - p] = w;
    }
    transpose64(&mut buf);
    for (t, o) in out.iter_mut().enumerate() {
        *o = buf[63 - t];
    }
}

/// Exact-sum planes of one block: `out[0..=n] = a + b` via lane-parallel
/// ripple carry (`out.len() == a.len() + 1`).
pub fn exact_sum_planes(a: &[u64], b: &[u64], out: &mut [u64]) {
    let n = a.len();
    debug_assert_eq!(b.len(), n);
    debug_assert_eq!(out.len(), n + 1);
    let mut carry = 0u64;
    for ((&ai, &bi), o) in a.iter().zip(b).zip(out.iter_mut()) {
        let p = ai ^ bi;
        *o = p ^ carry;
        carry = (ai & bi) | (carry & p);
    }
    out[n] = carry;
}

/// Approximate-sum planes of one block under per-bit `keep` masks (`!0`
/// keeps LUT *i*, `0` removes it) — the lane-wide form of the MUXCY
/// recurrence in [`adder::eval_one`](super::adder::eval_one): a removed LUT
/// forces `p_i = 0`, so the sum bit passes the carry through and the chain
/// re-seeds from `b_i`.
pub fn approx_sum_planes(a: &[u64], b: &[u64], keep: &[u64], out: &mut [u64]) {
    let n = a.len();
    debug_assert_eq!(b.len(), n);
    debug_assert_eq!(keep.len(), n);
    debug_assert_eq!(out.len(), n + 1);
    let mut carry = 0u64;
    for (((&ai, &bi), &ki), o) in a.iter().zip(b).zip(keep).zip(out.iter_mut()) {
        let p = (ai ^ bi) & ki;
        *o = p ^ carry;
        carry = (carry & p) | (bi & !p);
    }
    out[n] = carry;
}

/// `mag[0..MAG_BITS] = |x − y|` planes of two equal-width unsigned plane
/// vectors (`x.len() == y.len() ≤ MAG_BITS`; planes past the width are
/// zeroed). Returns the mask of lanes with a nonzero difference.
///
/// Lane-parallel borrow subtract, then a conditional two's-complement
/// negate steered by the borrow-out (the per-lane sign).
pub fn abs_diff_into(x: &[u64], y: &[u64], mag: &mut [u64]) -> u64 {
    let w = x.len();
    debug_assert_eq!(y.len(), w);
    debug_assert!(w <= MAG_BITS);
    debug_assert_eq!(mag.len(), MAG_BITS);
    let mut borrow = 0u64;
    for ((&xi, &yi), m) in x.iter().zip(y).zip(mag.iter_mut()) {
        *m = xi ^ yi ^ borrow;
        borrow = (!xi & (yi | borrow)) | (yi & borrow);
    }
    let sign = borrow;
    let mut carry = sign;
    let mut nonzero = 0u64;
    for m in mag.iter_mut().take(w) {
        let t = *m ^ sign;
        *m = t ^ carry;
        carry = t & carry;
        nonzero |= *m;
    }
    for m in mag.iter_mut().skip(w) {
        *m = 0;
    }
    nonzero
}

/// Add a ±2^shift-weighted boolean plane into a two's-complement plane
/// accumulator (lane-parallel ripple with early exit; a carry off the top
/// is the usual modular wrap).
#[inline]
pub fn acc_add(acc: &mut [u64], mut carry: u64, shift: usize) {
    let mut i = shift;
    while carry != 0 && i < acc.len() {
        let t = acc[i];
        acc[i] = t ^ carry;
        carry = t & carry;
        i += 1;
    }
}

/// Subtract counterpart of [`acc_add`].
#[inline]
pub fn acc_sub(acc: &mut [u64], mut borrow: u64, shift: usize) {
    let mut i = shift;
    while borrow != 0 && i < acc.len() {
        let t = acc[i];
        acc[i] = t ^ borrow;
        borrow = !t & borrow;
        i += 1;
    }
}

/// `mag[0..MAG_BITS] = |acc|` of a two's-complement plane accumulator whose
/// lane values are known to fit `MAG_BITS` magnitude bits
/// (`acc.len() > MAG_BITS`; the top planes must equal the sign — checked in
/// debug builds). Returns the mask of nonzero lanes.
pub fn abs_acc_into(acc: &[u64], mag: &mut [u64]) -> u64 {
    debug_assert!(acc.len() > MAG_BITS);
    debug_assert_eq!(mag.len(), MAG_BITS);
    let sign = acc[acc.len() - 1];
    let mut carry = sign;
    let mut nonzero = 0u64;
    for (&aq, m) in acc.iter().zip(mag.iter_mut()) {
        let t = aq ^ sign;
        *m = t ^ carry;
        carry = t & carry;
        nonzero |= *m;
    }
    debug_assert_eq!(carry, 0, "lane magnitude exceeded {MAG_BITS} planes");
    #[cfg(debug_assertions)]
    for &aq in &acc[MAG_BITS..] {
        debug_assert_eq!(aq, sign, "lane magnitude exceeded {MAG_BITS} planes");
    }
    nonzero
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::operator::{adder, multiplier, AxoConfig};
    use crate::util::rng::Rng;

    #[test]
    fn transpose_is_self_inverse_and_oriented() {
        let mut rng = Rng::seed_from_u64(7);
        let vals: Vec<u64> = (0..64).map(|_| rng.next_u64()).collect();
        let m = BitMatrix::pack(64, 64, |t| vals[t]);
        for p in [0usize, 1, 31, 63] {
            for t in [0usize, 5, 63] {
                let got = (m.block(0)[p] >> t) & 1;
                assert_eq!(got, (vals[t] >> p) & 1, "plane {p} lane {t}");
            }
        }
        let mut back = [0u64; 64];
        unpack64(m.block(0), &mut back);
        assert_eq!(back.to_vec(), vals);
    }

    #[test]
    fn pack_pads_tail_block_with_zero() {
        let m = BitMatrix::pack(70, 8, |t| t as u64 + 1);
        assert_eq!(m.n_blocks(), 2);
        assert_eq!(m.lanes_in(0), 64);
        assert_eq!(m.lanes_in(1), 6);
        let mut back = [0u64; 64];
        unpack64(m.block(1), &mut back);
        assert_eq!(back[5], 70);
        assert!(back[6..].iter().all(|&v| v == 0));
    }

    #[test]
    fn sum_planes_match_scalar_adder() {
        let mut rng = Rng::seed_from_u64(11);
        let n_bits = 8usize;
        let a: Vec<u64> = (0..100).map(|_| rng.next_u64() & 0xFF).collect();
        let b: Vec<u64> = (0..100).map(|_| rng.next_u64() & 0xFF).collect();
        let am = BitMatrix::pack(a.len(), n_bits, |t| a[t]);
        let bm = BitMatrix::pack(b.len(), n_bits, |t| b[t]);
        let cfg = AxoConfig::new(0b1011_0101, 8).unwrap();
        let keep: Vec<u64> =
            (0..8u32).map(|i| if cfg.keeps(i) { !0 } else { 0 }).collect();
        let mut exact = [0u64; 9];
        let mut approx = [0u64; 9];
        let mut lanes = [0u64; 64];
        for blk in 0..am.n_blocks() {
            exact_sum_planes(am.block(blk), bm.block(blk), &mut exact);
            unpack64(&exact, &mut lanes);
            for t in 0..am.lanes_in(blk) {
                let v = blk * 64 + t;
                assert_eq!(lanes[t], a[v] + b[v], "exact vector {v}");
            }
            approx_sum_planes(am.block(blk), bm.block(blk), &keep, &mut approx);
            unpack64(&approx, &mut lanes);
            for t in 0..am.lanes_in(blk) {
                let v = blk * 64 + t;
                assert_eq!(
                    lanes[t],
                    adder::eval_one(&cfg, a[v], b[v]),
                    "approx vector {v}"
                );
            }
        }
    }

    #[test]
    fn abs_diff_matches_scalar() {
        let mut rng = Rng::seed_from_u64(13);
        let w = 9usize;
        let x: Vec<u64> = (0..64).map(|_| rng.next_u64() & 0x1FF).collect();
        let y: Vec<u64> = (0..64).map(|_| rng.next_u64() & 0x1FF).collect();
        let xm = BitMatrix::pack(64, w, |t| x[t]);
        let ym = BitMatrix::pack(64, w, |t| y[t]);
        let mut mag = [0u64; MAG_BITS];
        let nz = abs_diff_into(xm.block(0), ym.block(0), &mut mag);
        let mut lanes = [0u64; 64];
        unpack64(&mag, &mut lanes);
        for t in 0..64 {
            let want = x[t].abs_diff(y[t]);
            assert_eq!(lanes[t], want, "lane {t}");
            assert_eq!((nz >> t) & 1, (want != 0) as u64, "nz lane {t}");
        }
    }

    #[test]
    fn plane_accumulator_matches_signed_sums() {
        // Random ±2^shift plane add/sub programs vs per-lane i64 arithmetic.
        let mut rng = Rng::seed_from_u64(17);
        for _ in 0..20 {
            let mut acc = [0u64; MAG_BITS + 2];
            let mut want = [0i64; 64];
            for _ in 0..12 {
                let plane = rng.next_u64();
                let shift = rng.gen_index(10);
                let neg = rng.next_u64() & 1 == 1;
                if neg {
                    acc_sub(&mut acc, plane, shift);
                } else {
                    acc_add(&mut acc, plane, shift);
                }
                for (t, w) in want.iter_mut().enumerate() {
                    let bit = ((plane >> t) & 1) as i64;
                    *w += if neg { -(bit << shift) } else { bit << shift };
                }
                // Keep |value| within the MAG_BITS magnitude bound so
                // abs_acc_into below stays in its contract.
                if want.iter().any(|w| w.abs() > 30_000) {
                    break;
                }
            }
            let mut mag = [0u64; MAG_BITS];
            let nz = abs_acc_into(&acc, &mut mag);
            let mut lanes = [0u64; 64];
            unpack64(&mag, &mut lanes);
            for (t, &w) in want.iter().enumerate() {
                assert_eq!(lanes[t], w.unsigned_abs(), "lane {t}");
                assert_eq!((nz >> t) & 1, (w != 0) as u64, "nz lane {t}");
            }
        }
    }

    #[test]
    fn removed_term_planes_match_multiplier_error() {
        // exact − approx == Σ removed terms, evaluated as ± AND planes.
        let m_bits = 4u32;
        let (a, b) = multiplier::exhaustive_inputs(m_bits);
        let cfg = AxoConfig::new(0b1010101011, 10).unwrap();
        let mask = (1u64 << m_bits) - 1;
        let am = BitMatrix::pack(a.len(), m_bits as usize, |t| (a[t] as u64) & mask);
        let bm = BitMatrix::pack(b.len(), m_bits as usize, |t| (b[t] as u64) & mask);
        let pairs = multiplier::pairs(m_bits);
        let mut lanes = [0u64; 64];
        for blk in 0..am.n_blocks() {
            let (ap, bp) = (am.block(blk), bm.block(blk));
            let mut acc = [0u64; MAG_BITS + 2];
            for (k, &(i, j)) in pairs.iter().enumerate() {
                if cfg.keeps(k as u32) {
                    continue;
                }
                let (i, j) = (i as usize, j as usize);
                let shift = i + j;
                let neg = (i == m_bits as usize - 1) != (j == m_bits as usize - 1);
                if neg {
                    acc_sub(&mut acc, ap[i] & bp[j], shift);
                    if i != j {
                        acc_sub(&mut acc, ap[j] & bp[i], shift);
                    }
                } else {
                    acc_add(&mut acc, ap[i] & bp[j], shift);
                    if i != j {
                        acc_add(&mut acc, ap[j] & bp[i], shift);
                    }
                }
            }
            let mut mag = [0u64; MAG_BITS];
            let nz = abs_acc_into(&acc, &mut mag);
            unpack64(&mag, &mut lanes);
            for t in 0..am.lanes_in(blk) {
                let v = blk * 64 + t;
                let err = a[v] * b[v] - multiplier::eval_one(m_bits, &cfg, a[v], b[v]);
                assert_eq!(lanes[t], err.unsigned_abs(), "vector {v}");
                assert_eq!((nz >> t) & 1, (err != 0) as u64, "nz vector {v}");
            }
        }
    }
}
