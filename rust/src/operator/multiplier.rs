//! Signed M×M approximate Baugh-Wooley multiplier (L = M(M+1)/2).
//!
//! LUT `(i, j)`, `i <= j` in lexicographic order, generates the signed
//! partial-product pair `w_i w_j (a_i b_j + a_j b_i)` (the single diagonal
//! term when `i == j`), where `w_i = -2^(M-1)` for the sign bit and `2^i`
//! otherwise. The sum of all pairs is exactly `A × B` for two's-complement
//! operands, so the all-ones configuration is accurate by construction.
//! Removing LUT `(i, j)` zeroes both partial products.
//!
//! `L = 10` for 4×4 and `L = 36` for 8×8, matching Table II.
//! Mirrors `python/compile/operator_model.py::mult_*` bit-for-bit.

use super::AxoConfig;

/// Ordered `(i, j)`, `i <= j` LUT index pairs (lexicographic, i ascending).
pub fn pairs(m_bits: u32) -> Vec<(u32, u32)> {
    let mut v = Vec::with_capacity((m_bits * (m_bits + 1) / 2) as usize);
    for i in 0..m_bits {
        for j in i..m_bits {
            v.push((i, j));
        }
    }
    v
}

/// Baugh-Wooley bit weight: `-2^(M-1)` at the sign position, else `2^i`.
#[inline]
pub fn weight(m_bits: u32, i: u32) -> i64 {
    if i == m_bits - 1 {
        -(1i64 << i)
    } else {
        1i64 << i
    }
}

/// Per-LUT contributions to the exact product of one operand pair.
///
/// `terms.iter().sum() == a * b`; the approximate product is the sum over
/// retained LUTs only. Operands are signed two's-complement M-bit values.
pub fn terms_one(m_bits: u32, a: i64, b: i64) -> Vec<i64> {
    let n = 1i64 << m_bits;
    let au = if a < 0 { a + n } else { a } as u64;
    let bu = if b < 0 { b + n } else { b } as u64;
    let mut out = Vec::with_capacity((m_bits * (m_bits + 1) / 2) as usize);
    for i in 0..m_bits {
        let ai = ((au >> i) & 1) as i64;
        let bi_i = ((bu >> i) & 1) as i64;
        for j in i..m_bits {
            let aj = ((au >> j) & 1) as i64;
            let bj = ((bu >> j) & 1) as i64;
            let w = weight(m_bits, i) * weight(m_bits, j);
            out.push(if i == j {
                w * ai * bi_i
            } else {
                w * (ai * bj + aj * bi_i)
            });
        }
    }
    out
}

/// Approximate product of one operand pair under `config`.
#[inline]
pub fn eval_one(m_bits: u32, config: &AxoConfig, a: i64, b: i64) -> i64 {
    let terms = terms_one(m_bits, a, b);
    let mut acc = 0i64;
    for (k, t) in terms.iter().enumerate() {
        if config.keeps(k as u32) {
            acc += t;
        }
    }
    acc
}

/// Row-major `(T, L)` term matrix for an input set — the operand the PJRT
/// `mult_eval` kernel consumes (`approx = configs @ terms.T`).
pub fn term_matrix(m_bits: u32, a: &[i64], b: &[i64]) -> Vec<i64> {
    assert_eq!(a.len(), b.len());
    let l = (m_bits * (m_bits + 1) / 2) as usize;
    let mut out = Vec::with_capacity(a.len() * l);
    for (&ai, &bi) in a.iter().zip(b) {
        out.extend(terms_one(m_bits, ai, bi));
    }
    out
}

/// Approximate products for a batch of configs × shared term matrix.
///
/// `terms` is the `(T, L)` row-major matrix from [`term_matrix`]; returns a
/// `(B, T)` row-major matrix. Native fallback for the Pallas kernel.
pub fn eval_batch(configs: &[AxoConfig], terms: &[i64], l: usize) -> Vec<i64> {
    assert_eq!(terms.len() % l, 0);
    let t = terms.len() / l;
    let mut out = vec![0i64; configs.len() * t];
    for (ci, cfg) in configs.iter().enumerate() {
        let mask: Vec<i64> = (0..l as u32).map(|k| cfg.keeps(k) as i64).collect();
        let row = &mut out[ci * t..(ci + 1) * t];
        for (ti, chunk) in terms.chunks_exact(l).enumerate() {
            let mut acc = 0i64;
            for (v, m) in chunk.iter().zip(&mask) {
                acc += v * m;
            }
            row[ti] = acc;
        }
    }
    out
}

/// Exhaustive signed input set: all `2^(2m)` pairs, a fastest-varying.
pub fn exhaustive_inputs(m_bits: u32) -> (Vec<i64>, Vec<i64>) {
    let n = 1i64 << m_bits;
    let half = n / 2;
    let signed = |v: i64| if v >= half { v - n } else { v };
    let mut a = Vec::with_capacity((n * n) as usize);
    let mut b = Vec::with_capacity((n * n) as usize);
    // Match python mult_inputs: a = repeat(signed), b = tile(signed).
    for av in 0..n {
        for bv in 0..n {
            a.push(signed(av));
            b.push(signed(bv));
        }
    }
    (a, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pairs_order_and_len() {
        assert_eq!(pairs(2), vec![(0, 0), (0, 1), (1, 1)]);
        assert_eq!(pairs(4).len(), 10);
        assert_eq!(pairs(8).len(), 36);
    }

    #[test]
    fn terms_sum_to_exact_product_exhaustive_4bit() {
        let (a, b) = exhaustive_inputs(4);
        for (&ai, &bi) in a.iter().zip(&b) {
            let s: i64 = terms_one(4, ai, bi).iter().sum();
            assert_eq!(s, ai * bi, "a={ai} b={bi}");
        }
    }

    #[test]
    fn terms_sum_to_exact_product_sampled_8bit() {
        for (a, b) in [(-128i64, -128i64), (-128, 127), (127, 127), (-37, 91), (0, -5)] {
            let s: i64 = terms_one(8, a, b).iter().sum();
            assert_eq!(s, a * b);
        }
    }

    #[test]
    fn accurate_config_eval_one() {
        let cfg = AxoConfig::accurate(10);
        assert_eq!(eval_one(4, &cfg, -8, 7), -56);
        assert_eq!(eval_one(4, &cfg, 3, 3), 9);
    }

    #[test]
    fn removing_pair00_zeroes_lsb_product() {
        let mut bits = vec![1u8; 10];
        bits[0] = 0; // pair (0,0)
        let cfg = AxoConfig::from_bits(&bits).unwrap();
        // a,b odd: product loses exactly a0*b0 = 1.
        assert_eq!(eval_one(4, &cfg, 3, 5), 15 - 1);
        assert_eq!(eval_one(4, &cfg, 2, 6), 12);
    }

    #[test]
    fn eval_batch_matches_eval_one() {
        let cfgs: Vec<AxoConfig> =
            [0b1111111111u64, 0b1010101010, 0b0000000001, 0b1000000000]
                .iter()
                .map(|&v| AxoConfig::new(v, 10).unwrap())
                .collect();
        let (a, b) = exhaustive_inputs(4);
        let tm = term_matrix(4, &a, &b);
        let out = eval_batch(&cfgs, &tm, 10);
        for (ci, cfg) in cfgs.iter().enumerate() {
            for t in 0..a.len() {
                assert_eq!(out[ci * a.len() + t], eval_one(4, cfg, a[t], b[t]));
            }
        }
    }

    #[test]
    fn exhaustive_inputs_signed_range() {
        let (a, b) = exhaustive_inputs(4);
        assert_eq!(a.len(), 256);
        assert_eq!(*a.iter().min().unwrap(), -8);
        assert_eq!(*b.iter().max().unwrap(), 7);
    }
}
