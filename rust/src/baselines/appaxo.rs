//! AppAxO baseline [12]: GA + ML fitness, random initialization.
//!
//! AxOCS's augmented GA differs from AppAxO only in the initial population
//! (ConSS pool vs. random), so the baseline reuses [`NsgaRunner`] with no
//! seeds — the "GA" bars of Figs. 15/16 and the AppAxO fronts of
//! Figs. 17/18.

use crate::dse::{Constraints, Fitness, GaOptions, GaResult, NsgaRunner};
use crate::error::Result;

/// Run the AppAxO-style search: random init, ML fitness.
pub fn appaxo_search(
    config_len: u32,
    fitness: &dyn Fitness,
    constraints: Constraints,
    options: GaOptions,
) -> Result<GaResult> {
    NsgaRunner::new(options, constraints).run(config_len, fitness, &[])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::Objectives;
    use crate::operator::AxoConfig;

    fn fitness(configs: &[AxoConfig]) -> Result<Vec<Objectives>> {
        Ok(configs
            .iter()
            .map(|c| {
                let ones = c.count_kept() as f64 / c.len() as f64;
                [1.0 - ones, ones]
            })
            .collect())
    }

    #[test]
    fn runs_and_improves() {
        let opts = GaOptions { pop_size: 16, generations: 10, ..Default::default() };
        let r = appaxo_search(10, &fitness, Constraints::new(1.0, 1.0).unwrap(), opts)
            .unwrap();
        assert!(r.final_hypervolume() > 0.0);
        assert!(r.hv_history.len() == 11);
    }
}
