//! EvoApprox-like fixed library baseline [6].
//!
//! The published EvoApprox designs are ASIC netlists outside our operator
//! model; following DESIGN.md's substitution rule we synthesize the
//! *structured* design families such libraries contain, expressed as
//! configurations of the Baugh-Wooley multiplier model:
//!
//! * **Column truncation** — drop all partial products below significance
//!   `k` (the classic truncated-multiplier family, e.g. [20]).
//! * **Operand-bit elimination** — drop every pair touching operand bit
//!   `i` (DRUM-style range reduction [5]).
//! * **Diagonal-only / block patterns** — keep diagonal pairs plus the top
//!   block (functional 2×2-style decompositions [22]).
//!
//! The library is characterized with the same substrate as everything else
//! and the baseline "selects" its Pareto front — no iterative search,
//! mirroring how designers pick from a published library.

use crate::operator::{multiplier, AxoConfig, Operator, OperatorKind};

/// Generate the structured library for a signed multiplier.
pub fn evoapprox_library(op: Operator) -> Vec<AxoConfig> {
    assert_eq!(op.kind, OperatorKind::SignedMultiplier);
    let m = op.bits;
    let pairs = multiplier::pairs(m);
    let l = pairs.len() as u32;
    let mut seen = std::collections::HashSet::new();
    let mut lib = Vec::new();
    let mut push = |bits: Vec<u8>, lib: &mut Vec<AxoConfig>| {
        if let Ok(c) = AxoConfig::from_bits(&bits) {
            if seen.insert(c.as_uint()) {
                lib.push(c);
            }
        }
    };

    // Column truncation: keep pairs with i+j >= k.
    for k in 0..(2 * m - 1) {
        let bits: Vec<u8> = pairs.iter().map(|&(i, j)| (i + j >= k) as u8).collect();
        push(bits, &mut lib);
    }
    // Operand-bit elimination: drop pairs touching bits < e (LSB side).
    for e in 1..m {
        let bits: Vec<u8> =
            pairs.iter().map(|&(i, j)| (i >= e && j >= e) as u8).collect();
        push(bits, &mut lib);
    }
    // Single-bit elimination: drop pairs touching exactly bit t.
    for t in 0..m {
        let bits: Vec<u8> =
            pairs.iter().map(|&(i, j)| (i != t && j != t) as u8).collect();
        push(bits, &mut lib);
    }
    // Diagonal + top-block hybrids: keep diagonals and any pair with both
    // indices >= s.
    for s in 0..m {
        let bits: Vec<u8> = pairs
            .iter()
            .map(|&(i, j)| (i == j || (i >= s && j >= s)) as u8)
            .collect();
        push(bits, &mut lib);
    }
    // Truncation + exact-MSB combinations (two-parameter family).
    for k in 1..(2 * m - 1) {
        for keep_msb in 0..m {
            let bits: Vec<u8> = pairs
                .iter()
                .map(|&(i, j)| {
                    (i + j >= k || i >= m - 1 - keep_msb || j >= m - 1 - keep_msb) as u8
                })
                .collect();
            push(bits, &mut lib);
        }
    }
    debug_assert!(lib.iter().all(|c| c.len() == l));
    lib
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::charac::{characterize, Backend, InputSet};

    #[test]
    fn library_is_nonempty_unique_valid() {
        for op in [Operator::MUL4, Operator::MUL8] {
            let lib = evoapprox_library(op);
            let min = if op.bits >= 8 { 40 } else { 15 };
            assert!(lib.len() >= min, "{op}: {}", lib.len());
            let uniq: std::collections::HashSet<u64> =
                lib.iter().map(|c| c.as_uint()).collect();
            assert_eq!(uniq.len(), lib.len());
            assert!(lib.iter().all(|c| c.len() == op.config_len()));
        }
    }

    #[test]
    fn library_contains_accurate_design() {
        // k = 0 truncation keeps everything.
        let lib = evoapprox_library(Operator::MUL4);
        assert!(lib.iter().any(|c| c.is_accurate()));
    }

    #[test]
    fn truncation_members_behave_monotonically() {
        // Deeper truncation ⇒ error does not decrease.
        let op = Operator::MUL4;
        let pairs = multiplier::pairs(4);
        let inputs = InputSet::exhaustive(op);
        let mut cfgs = Vec::new();
        for k in 0..4 {
            let bits: Vec<u8> =
                pairs.iter().map(|&(i, j)| (i + j >= k) as u8).collect();
            cfgs.push(AxoConfig::from_bits(&bits).unwrap());
        }
        let ds = characterize(op, &cfgs, &inputs, &Backend::Native).unwrap();
        for w in ds.behav.windows(2) {
            assert!(w[1].avg_abs_err >= w[0].avg_abs_err);
        }
    }
}
