//! State-of-the-art baselines for Figs. 17/18.
//!
//! * [`appaxo`] — AppAxO [12]: the same LUT-removal operator model driven
//!   by a problem-agnostic GA with ML-based fitness and *random* initial
//!   population (no supersampling seeds) — exactly AxOCS minus ConSS.
//! * [`evoapprox`] — EvoApprox-like [6]: a fixed library of *structured*
//!   approximate designs (truncation / row-elimination / radix-block
//!   patterns), standing in for the published ASIC-optimized library; the
//!   baseline picks its Pareto front from the library, no search.

pub mod appaxo;
pub mod evoapprox;

pub use appaxo::appaxo_search;
pub use evoapprox::evoapprox_library;
