//! Runtime layer: artifact schemas always, PJRT execution behind `pjrt`.
//!
//! The artifact *formats* — [`manifest`] (`manifest.json`) and [`weights`]
//! (AXOW containers) — are plain std-only parsers and are always compiled,
//! so the hermetic default build can validate artifacts it cannot execute.
//! Everything that touches the `xla` bindings — the `Runtime` client
//! wrapper in `client` and the typed executables in `executables` —
//! compiles only with the `pjrt` cargo feature; the default backends
//! (native characterization, exact table, GBT surrogate) cover the same
//! roles without it.

#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub mod executables;
pub mod manifest;
pub mod weights;

#[cfg(feature = "pjrt")]
pub use client::{literal_f32_2d, literal_i32_2d, LoadedExec, Runtime};
#[cfg(feature = "pjrt")]
pub use executables::{AxoEvalExec, MlpExec};
pub use manifest::{ExecEntry, Manifest};
pub use weights::WeightsFile;
