//! PJRT client wrapper — only compiled with the `pjrt` cargo feature.
//!
//! Wraps the `xla` crate (xla_extension 0.5.1 via the PJRT C API):
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`. HLO **text** is the interchange format —
//! jax ≥ 0.5 emits serialized protos with 64-bit instruction ids that this
//! XLA rejects, while the text parser reassigns ids (see aot.py).
//!
//! One compiled executable per (graph, batch-shape) variant; the
//! coordinator's batcher pads requests to the compiled batch size.

use super::Manifest;
use crate::error::{Error, Result};
use crate::runtime::manifest::ExecEntry;
use std::path::{Path, PathBuf};

/// A live PJRT client plus the artifact directory it loads from.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    pub manifest: Manifest,
}

impl Runtime {
    /// Create a CPU PJRT client and read `manifest.json`.
    pub fn cpu(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(&artifacts_dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, artifacts_dir: artifacts_dir.to_path_buf(), manifest })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    /// Load + compile one HLO-text artifact.
    pub fn load(&self, name: &str) -> Result<LoadedExec> {
        let entry = self.manifest.entry(name)?.clone();
        let path = self.artifacts_dir.join(&entry.hlo);
        if !path.exists() {
            return Err(Error::ArtifactMissing { path });
        }
        let proto = xla::HloModuleProto::from_text_file(&path).map_err(|e| {
            Error::ArtifactCorrupt { path: path.clone(), reason: e.to_string() }
        })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(LoadedExec { exe, name: name.to_string(), entry })
    }
}

/// A compiled executable plus its manifest entry.
pub struct LoadedExec {
    pub(crate) exe: xla::PjRtLoadedExecutable,
    pub name: String,
    pub entry: ExecEntry,
}

impl LoadedExec {
    /// Execute and unwrap the 1-tuple output (aot.py lowers with
    /// `return_tuple=True`).
    pub fn execute(&self, args: &[xla::Literal]) -> Result<xla::Literal> {
        let result = self.exe.execute::<xla::Literal>(args)?;
        let lit = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| Error::Xla("empty execution result".into()))?
            .to_literal_sync()?;
        Ok(lit.to_tuple1()?)
    }

    /// Output as f32 vector.
    pub fn execute_f32(&self, args: &[xla::Literal]) -> Result<Vec<f32>> {
        Ok(self.execute(args)?.to_vec::<f32>()?)
    }
}

/// Build a row-major f32 literal of shape `(rows, cols)`.
pub fn literal_f32_2d(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    if data.len() != rows * cols {
        return Err(Error::Shape(format!(
            "literal data {} != {rows}x{cols}",
            data.len()
        )));
    }
    Ok(xla::Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
}

/// Build a row-major i32 literal of shape `(rows, cols)`.
pub fn literal_i32_2d(data: &[i32], rows: usize, cols: usize) -> Result<xla::Literal> {
    if data.len() != rows * cols {
        return Err(Error::Shape(format!(
            "literal data {} != {rows}x{cols}",
            data.len()
        )));
    }
    Ok(xla::Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn missing_manifest_is_artifact_missing() {
        let r = Runtime::cpu(Path::new("/nonexistent"));
        assert!(matches!(r, Err(Error::ArtifactMissing { .. })));
    }

    #[test]
    fn literal_shape_checks() {
        assert!(literal_f32_2d(&[1.0, 2.0], 2, 2).is_err());
        assert!(literal_f32_2d(&[1.0; 4], 2, 2).is_ok());
        assert!(literal_i32_2d(&[1; 6], 2, 3).is_ok());
    }

    // Full PJRT-backed tests live in rust/tests/ and need `make artifacts`
    // plus a real xla override (the capability probe covers both).
    #[test]
    fn runtime_loads_if_artifacts_present() {
        let dir = artifacts();
        if !crate::charac::Backend::pjrt_ready(&dir) {
            eprintln!("skipping: PJRT backend not ready (artifacts or real xla missing)");
            return;
        }
        let rt = Runtime::cpu(&dir).unwrap();
        assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
        assert!(rt.manifest.entry("axo_eval_add4").is_ok());
    }
}
