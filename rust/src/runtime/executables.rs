//! Typed wrappers over the compiled artifacts.
//!
//! * [`AxoEvalExec`] — the Pallas characterization kernel
//!   (`axo_eval_*.hlo.txt`); implements [`BehavEvaluator`] so the
//!   characterization pipeline can run on PJRT.
//! * [`MlpExec`] — the surrogate-estimator / ConSS-generator MLP forwards
//!   with weights fed as runtime literals from the AXOW container.
//!
//! Compiled shapes are static; callers may pass any number of rows and the
//! wrapper pads the final batch (replicating the last row) and trims the
//! outputs.

use super::{literal_f32_2d, literal_i32_2d, LoadedExec, Runtime, WeightsFile};
use crate::charac::pipeline::BehavEvaluator;
use crate::charac::{BehavMetrics, InputSet};
use crate::error::{Error, Result};
use crate::operator::{multiplier, AxoConfig, Operator, OperatorKind};

/// PJRT-backed behavioral characterization.
///
/// Constructed for one (operator, input set): the heavy operands — the
/// `(T, L)` term matrix / `(T, 1)` operand columns — are uploaded once as
/// literals and reused across every batch.
pub struct AxoEvalExec {
    exec: LoadedExec,
    op: Operator,
    batch: usize,
    n_inputs: usize,
    /// Cached input literals: adder → [a, b]; multiplier → [terms, exact].
    input_literals: Vec<xla::Literal>,
}

impl AxoEvalExec {
    /// Load `axo_eval_<op>` and pre-build the input literals.
    pub fn new(rt: &Runtime, op: Operator, inputs: &InputSet) -> Result<AxoEvalExec> {
        let exec = rt.load(&format!("axo_eval_{}", op.name()))?;
        let batch = exec.entry.config_batch;
        let n_inputs = exec.entry.n_inputs.unwrap_or(inputs.len());
        if n_inputs != inputs.len() {
            return Err(Error::Shape(format!(
                "executable compiled for {n_inputs} inputs, got {}",
                inputs.len()
            )));
        }
        let input_literals = match op.kind {
            OperatorKind::UnsignedAdder => {
                let a: Vec<i32> = inputs.a.iter().map(|&v| v as i32).collect();
                let b: Vec<i32> = inputs.b.iter().map(|&v| v as i32).collect();
                vec![
                    literal_i32_2d(&a, n_inputs, 1)?,
                    literal_i32_2d(&b, n_inputs, 1)?,
                ]
            }
            OperatorKind::SignedMultiplier => {
                let l = op.config_len() as usize;
                let terms = multiplier::term_matrix(op.bits, &inputs.a, &inputs.b);
                let terms_f: Vec<f32> = terms.iter().map(|&v| v as f32).collect();
                let exact_f: Vec<f32> = terms
                    .chunks_exact(l)
                    .map(|c| c.iter().sum::<i64>() as f32)
                    .collect();
                vec![
                    literal_f32_2d(&terms_f, n_inputs, l)?,
                    literal_f32_2d(&exact_f, n_inputs, 1)?,
                ]
            }
        };
        Ok(AxoEvalExec { exec, op, batch, n_inputs, input_literals })
    }

    pub fn operator(&self) -> Operator {
        self.op
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Evaluate BEHAV metrics for any number of configurations.
    pub fn eval_configs(&self, configs: &[AxoConfig]) -> Result<Vec<BehavMetrics>> {
        let l = self.op.config_len() as usize;
        let mut out = Vec::with_capacity(configs.len());
        for chunk in configs.chunks(self.batch) {
            let mut rows: Vec<&AxoConfig> = chunk.iter().collect();
            while rows.len() < self.batch {
                rows.push(&chunk[chunk.len() - 1]); // pad with last row
            }
            let cfg_lit = match self.op.kind {
                OperatorKind::UnsignedAdder => {
                    let data: Vec<i32> = rows
                        .iter()
                        .flat_map(|c| c.to_bits_u8().into_iter().map(|b| b as i32))
                        .collect();
                    literal_i32_2d(&data, self.batch, l)?
                }
                OperatorKind::SignedMultiplier => {
                    let data: Vec<f32> =
                        rows.iter().flat_map(|c| c.to_bits_f32()).collect();
                    literal_f32_2d(&data, self.batch, l)?
                }
            };
            let raw = self.execute_with_inputs(&cfg_lit)?;
            for row in raw.chunks_exact(4).take(chunk.len()) {
                out.push(BehavMetrics {
                    avg_abs_err: row[0] as f64,
                    avg_abs_rel_err: row[1] as f64,
                    max_abs_err: row[2] as f64,
                    err_prob: row[3] as f64,
                });
            }
        }
        Ok(out)
    }

    fn execute_with_inputs(&self, cfg_lit: &xla::Literal) -> Result<Vec<f32>> {
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(3);
        args.push(cfg_lit);
        for lit in &self.input_literals {
            args.push(lit);
        }
        let result = self.exec.execute_refs(&args)?;
        Ok(result)
    }
}

impl LoadedExec {
    /// Execute with borrowed literals (avoids copying the cached heavy
    /// operands) and return the f32 contents of the 1-tuple output.
    pub fn execute_refs(&self, args: &[&xla::Literal]) -> Result<Vec<f32>> {
        let result = self.exe.execute::<&xla::Literal>(args)?;
        let lit = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| Error::Xla("empty execution result".into()))?
            .to_literal_sync()?;
        Ok(lit.to_tuple1()?.to_vec::<f32>()?)
    }
}

impl BehavEvaluator for AxoEvalExec {
    fn eval(
        &self,
        op: Operator,
        configs: &[AxoConfig],
        inputs: &InputSet,
    ) -> Result<Vec<BehavMetrics>> {
        if op != self.op {
            return Err(Error::Shape(format!(
                "executable is for {}, asked to evaluate {op}",
                self.op
            )));
        }
        if inputs.len() != self.n_inputs {
            return Err(Error::Shape(format!(
                "executable compiled for {} inputs, got {}",
                self.n_inputs,
                inputs.len()
            )));
        }
        self.eval_configs(configs)
    }
}

/// A compiled MLP forward (estimator or ConSS generator).
pub struct MlpExec {
    exec: LoadedExec,
    weights: Vec<xla::Literal>,
    pub batch: usize,
    pub in_features: usize,
    pub out_features: usize,
    /// Target unscaling (estimator only): (min, max) per output column.
    pub target_min: Vec<f64>,
    pub target_max: Vec<f64>,
}

impl MlpExec {
    pub fn new(rt: &Runtime, name: &str) -> Result<MlpExec> {
        let exec = rt.load(name)?;
        let entry = exec.entry.clone();
        let weights_name = entry.weights.clone().ok_or_else(|| {
            Error::ArtifactCorrupt {
                path: "manifest.json".into(),
                reason: format!("executable `{name}` has no weights"),
            }
        })?;
        let wf = WeightsFile::load(&rt.artifacts_dir().join(weights_name))?;
        let weights = wf.literals_in_order(&entry.param_order)?;
        let in_features = entry.inputs[0].shape[1];
        let out_features = wf
            .tensors
            .last()
            .map(|t| *t.dims.last().unwrap_or(&0))
            .unwrap_or(0);
        Ok(MlpExec {
            exec,
            weights,
            batch: entry.config_batch,
            in_features,
            out_features,
            target_min: entry.target_min.clone(),
            target_max: entry.target_max.clone(),
        })
    }

    /// Raw forward over row-major f32 features (any row count; padded).
    pub fn forward(&self, rows: &[f32]) -> Result<Vec<f32>> {
        if rows.len() % self.in_features != 0 {
            return Err(Error::Shape(format!(
                "feature rows not divisible by {}",
                self.in_features
            )));
        }
        let n = rows.len() / self.in_features;
        let mut out = Vec::with_capacity(n * self.out_features);
        for chunk in rows.chunks(self.batch * self.in_features) {
            let rows_in_chunk = chunk.len() / self.in_features;
            let mut padded = chunk.to_vec();
            let last_row = &chunk[(rows_in_chunk - 1) * self.in_features..];
            while padded.len() < self.batch * self.in_features {
                padded.extend_from_slice(last_row);
            }
            let x = literal_f32_2d(&padded, self.batch, self.in_features)?;
            let mut args: Vec<&xla::Literal> = vec![&x];
            for w in &self.weights {
                args.push(w);
            }
            let raw = self.exec.execute_refs(&args)?;
            out.extend_from_slice(&raw[..rows_in_chunk * self.out_features]);
        }
        Ok(out)
    }

    /// Estimator mode: unscale outputs to metric units using the manifest's
    /// min/max (column order = manifest `targets`).
    pub fn predict_unscaled(&self, rows: &[f32]) -> Result<Vec<Vec<f64>>> {
        if self.target_min.len() != self.out_features {
            return Err(Error::Ml("executable has no target scaling info".into()));
        }
        let raw = self.forward(rows)?;
        Ok(raw
            .chunks_exact(self.out_features)
            .map(|r| {
                r.iter()
                    .enumerate()
                    .map(|(k, &v)| {
                        self.target_min[k]
                            + (v as f64) * (self.target_max[k] - self.target_min[k])
                    })
                    .collect()
            })
            .collect())
    }
}
