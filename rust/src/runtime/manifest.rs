//! `artifacts/manifest.json` schema (written by `python/compile/aot.py`).

use crate::error::{Error, Result};
use crate::util::json::Json;
use std::collections::HashMap;
use std::path::Path;

/// One tensor argument of a compiled executable.
#[derive(Debug, Clone)]
pub struct InputSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
    pub role: String,
}

/// One executable's metadata.
#[derive(Debug, Clone, Default)]
pub struct ExecEntry {
    pub hlo: String,
    pub kind: String,
    pub weights: Option<String>,
    pub bits: Option<u32>,
    pub config_len: Option<u32>,
    pub config_batch: usize,
    pub n_inputs: Option<usize>,
    pub noise_bits: Option<u32>,
    pub inputs: Vec<InputSpec>,
    pub param_order: Vec<String>,
    pub target_min: Vec<f64>,
    pub target_max: Vec<f64>,
    pub targets: Vec<String>,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: u32,
    pub executables: HashMap<String, ExecEntry>,
}

fn str_vec(v: Option<&Json>) -> Vec<String> {
    v.and_then(Json::as_arr)
        .map(|a| a.iter().filter_map(|x| x.as_str().map(String::from)).collect())
        .unwrap_or_default()
}

fn f64_vec(v: Option<&Json>) -> Vec<f64> {
    v.and_then(Json::as_arr)
        .map(|a| a.iter().filter_map(Json::as_f64).collect())
        .unwrap_or_default()
}

impl ExecEntry {
    fn from_json(v: &Json) -> Option<ExecEntry> {
        let inputs = v
            .get("inputs")?
            .as_arr()?
            .iter()
            .map(|i| {
                Some(InputSpec {
                    shape: i
                        .get("shape")?
                        .as_arr()?
                        .iter()
                        .map(|d| d.as_usize())
                        .collect::<Option<Vec<usize>>>()?,
                    dtype: i.get("dtype")?.as_str()?.to_string(),
                    role: i.get("role")?.as_str()?.to_string(),
                })
            })
            .collect::<Option<Vec<InputSpec>>>()?;
        Some(ExecEntry {
            hlo: v.get("hlo")?.as_str()?.to_string(),
            kind: v.get("kind")?.as_str()?.to_string(),
            weights: v.get("weights").and_then(Json::as_str).map(String::from),
            bits: v.get("bits").and_then(Json::as_u64).map(|b| b as u32),
            config_len: v.get("config_len").and_then(Json::as_u64).map(|b| b as u32),
            config_batch: v.get("config_batch")?.as_usize()?,
            n_inputs: v.get("n_inputs").and_then(Json::as_usize),
            noise_bits: v.get("noise_bits").and_then(Json::as_u64).map(|b| b as u32),
            inputs,
            param_order: str_vec(v.get("param_order")),
            target_min: f64_vec(v.get("target_min")),
            target_max: f64_vec(v.get("target_max")),
            targets: str_vec(v.get("targets")),
        })
    }
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .map_err(|_| Error::ArtifactMissing { path: path.to_path_buf() })?;
        Self::parse(&text).map_err(|reason| Error::ArtifactCorrupt {
            path: path.to_path_buf(),
            reason,
        })
    }

    pub fn parse(text: &str) -> std::result::Result<Manifest, String> {
        let v = Json::parse(text).map_err(|e| e.to_string())?;
        let version =
            v.get("version").and_then(Json::as_u64).ok_or("missing version")? as u32;
        if version != 1 {
            return Err(format!("unsupported manifest version {version}"));
        }
        let mut executables = HashMap::new();
        let execs = v
            .get("executables")
            .and_then(Json::as_obj)
            .ok_or("missing executables")?;
        for (name, entry) in execs {
            let e = ExecEntry::from_json(entry)
                .ok_or_else(|| format!("malformed entry `{name}`"))?;
            executables.insert(name.clone(), e);
        }
        Ok(Manifest { version, executables })
    }

    pub fn entry(&self, name: &str) -> Result<&ExecEntry> {
        self.executables.get(name).ok_or_else(|| Error::ArtifactCorrupt {
            path: "manifest.json".into(),
            reason: format!("no executable `{name}` in manifest"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tempdir::TempDir;

    #[test]
    fn parses_minimal_manifest() {
        let m = Manifest::parse(
            r#"{"version":1,"executables":{"x":{"hlo":"x.hlo.txt","kind":"adder_eval","config_batch":64,"inputs":[{"shape":[64,8],"dtype":"i32","role":"configs"}]}}}"#,
        )
        .unwrap();
        let e = m.entry("x").unwrap();
        assert_eq!(e.config_batch, 64);
        assert_eq!(e.inputs[0].shape, vec![64, 8]);
        assert_eq!(e.inputs[0].role, "configs");
        assert!(e.weights.is_none());
        assert!(m.entry("y").is_err());
    }

    #[test]
    fn rejects_bad_version_and_shape() {
        assert!(Manifest::parse(r#"{"version":9,"executables":{}}"#).is_err());
        assert!(Manifest::parse(r#"{"executables":{}}"#).is_err());
        assert!(Manifest::parse(r#"{"version":1}"#).is_err());
        assert!(Manifest::parse(r#"{"version":1,"executables":{"x":{"kind":"y"}}}"#).is_err());
    }

    #[test]
    fn load_missing_is_artifact_missing() {
        let dir = TempDir::new().unwrap();
        assert!(matches!(
            Manifest::load(&dir.path().join("nope.json")),
            Err(Error::ArtifactMissing { .. })
        ));
    }

    #[test]
    fn real_manifest_parses_if_present() {
        let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
        if p.exists() {
            let m = Manifest::load(&p).unwrap();
            assert!(m.executables.contains_key("axo_eval_mul8"));
            let est = m.entry("estimator_mul8").unwrap();
            assert_eq!(est.param_order.len(), 6); // 3 layers × (w, b)
            assert_eq!(est.target_min.len(), 2);
            assert_eq!(est.targets, vec!["pdplut", "avg_abs_rel_err"]);
        }
    }
}
