//! AXOW weights file parser.
//!
//! Trained MLP parameters are *runtime arguments* of the AOT-compiled
//! forwards, shipped in a flat little-endian container written by
//! `aot.py::write_weights_bin`:
//!
//! ```text
//! "AXOW" | u32 version=1 | u32 n_tensors |
//! per tensor: u32 name_len | name | u32 ndim | u32 dims[] | f32 data[]
//! ```

use crate::error::{Error, Result};
use std::io::Read;
use std::path::Path;

/// One named tensor.
#[derive(Debug, Clone)]
pub struct WeightTensor {
    pub name: String,
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl WeightTensor {
    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }
}

/// A parsed weights container (ordered as written).
#[derive(Debug, Clone)]
pub struct WeightsFile {
    pub tensors: Vec<WeightTensor>,
}

impl WeightsFile {
    pub fn load(path: &Path) -> Result<WeightsFile> {
        let mut f = std::fs::File::open(path)
            .map_err(|_| Error::ArtifactMissing { path: path.to_path_buf() })?;
        let mut buf = Vec::new();
        f.read_to_end(&mut buf)?;
        Self::parse(&buf).map_err(|reason| Error::ArtifactCorrupt {
            path: path.to_path_buf(),
            reason,
        })
    }

    fn parse(buf: &[u8]) -> std::result::Result<WeightsFile, String> {
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> std::result::Result<&[u8], String> {
            if *pos + n > buf.len() {
                return Err(format!("truncated at offset {pos}"));
            }
            let s = &buf[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        let u32le = |pos: &mut usize| -> std::result::Result<u32, String> {
            Ok(u32::from_le_bytes(take(pos, 4)?.try_into().unwrap()))
        };

        if take(&mut pos, 4)? != b"AXOW" {
            return Err("bad magic".into());
        }
        let version = u32le(&mut pos)?;
        if version != 1 {
            return Err(format!("unsupported version {version}"));
        }
        let n_tensors = u32le(&mut pos)? as usize;
        if n_tensors > 10_000 {
            return Err(format!("implausible tensor count {n_tensors}"));
        }
        let mut tensors = Vec::with_capacity(n_tensors);
        for _ in 0..n_tensors {
            let name_len = u32le(&mut pos)? as usize;
            let name = String::from_utf8(take(&mut pos, name_len)?.to_vec())
                .map_err(|e| e.to_string())?;
            let ndim = u32le(&mut pos)? as usize;
            if ndim > 8 {
                return Err(format!("implausible ndim {ndim} for `{name}`"));
            }
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(u32le(&mut pos)? as usize);
            }
            let count: usize = dims.iter().product();
            let raw = take(&mut pos, count * 4)?;
            let data: Vec<f32> = raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            tensors.push(WeightTensor { name, dims, data });
        }
        if pos != buf.len() {
            return Err(format!("{} trailing bytes", buf.len() - pos));
        }
        Ok(WeightsFile { tensors })
    }

    pub fn get(&self, name: &str) -> Result<&WeightTensor> {
        self.tensors
            .iter()
            .find(|t| t.name == name)
            .ok_or_else(|| Error::Ml(format!("weight tensor `{name}` not found")))
    }

    /// Tensors as XLA literals in `order` (the manifest's `param_order`) —
    /// 1-D tensors stay rank-1, 2-D reshape to their matrix shape.
    /// Only available with the `pjrt` feature (needs the `xla` bindings).
    #[cfg(feature = "pjrt")]
    pub fn literals_in_order(&self, order: &[String]) -> Result<Vec<xla::Literal>> {
        let mut out = Vec::with_capacity(order.len());
        for name in order {
            let t = self.get(name)?;
            let lit = xla::Literal::vec1(&t.data);
            let lit = if t.dims.len() >= 2 {
                let dims: Vec<i64> = t.dims.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims)?
            } else {
                lit
            };
            out.push(lit);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_test_file(path: &Path) {
        let mut f = std::fs::File::create(path).unwrap();
        f.write_all(b"AXOW").unwrap();
        f.write_all(&1u32.to_le_bytes()).unwrap();
        f.write_all(&2u32.to_le_bytes()).unwrap();
        // tensor "w": 2x2
        f.write_all(&1u32.to_le_bytes()).unwrap();
        f.write_all(b"w").unwrap();
        f.write_all(&2u32.to_le_bytes()).unwrap();
        f.write_all(&2u32.to_le_bytes()).unwrap();
        f.write_all(&2u32.to_le_bytes()).unwrap();
        for v in [1.0f32, 2.0, 3.0, 4.0] {
            f.write_all(&v.to_le_bytes()).unwrap();
        }
        // tensor "b": 2
        f.write_all(&1u32.to_le_bytes()).unwrap();
        f.write_all(b"b").unwrap();
        f.write_all(&1u32.to_le_bytes()).unwrap();
        f.write_all(&2u32.to_le_bytes()).unwrap();
        for v in [0.5f32, -0.5] {
            f.write_all(&v.to_le_bytes()).unwrap();
        }
    }

    #[test]
    fn parse_roundtrip() {
        let dir = crate::util::tempdir::TempDir::new().unwrap();
        let p = dir.path().join("w.bin");
        write_test_file(&p);
        let w = WeightsFile::load(&p).unwrap();
        assert_eq!(w.tensors.len(), 2);
        assert_eq!(w.get("w").unwrap().dims, vec![2, 2]);
        assert_eq!(w.get("w").unwrap().data, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(w.get("b").unwrap().data, vec![0.5, -0.5]);
        assert!(w.get("nope").is_err());
    }

    #[test]
    fn corrupt_files_rejected() {
        let dir = crate::util::tempdir::TempDir::new().unwrap();
        let p = dir.path().join("bad.bin");
        std::fs::write(&p, b"NOPE").unwrap();
        assert!(matches!(WeightsFile::load(&p), Err(Error::ArtifactCorrupt { .. })));
        // Truncated.
        let p2 = dir.path().join("trunc.bin");
        write_test_file(&p2);
        let full = std::fs::read(&p2).unwrap();
        std::fs::write(&p2, &full[..full.len() - 3]).unwrap();
        assert!(matches!(WeightsFile::load(&p2), Err(Error::ArtifactCorrupt { .. })));
        // Trailing garbage.
        let p3 = dir.path().join("trail.bin");
        let mut with_trailer = full.clone();
        with_trailer.extend_from_slice(b"xx");
        std::fs::write(&p3, &with_trailer).unwrap();
        assert!(matches!(WeightsFile::load(&p3), Err(Error::ArtifactCorrupt { .. })));
    }

    #[test]
    fn real_weights_parse_if_present() {
        let p = Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/estimator_mul8.weights.bin");
        if p.exists() {
            let w = WeightsFile::load(&p).unwrap();
            assert_eq!(w.tensors.len(), 6);
            assert_eq!(w.get("estimator.layer0.w").unwrap().dims, vec![36, 64]);
        }
    }
}
