//! Figure/table regeneration harness.
//!
//! One function per figure and table of the paper's evaluation (see
//! DESIGN.md §4 for the index). Every function writes machine-readable CSV
//! series into the configured `out_dir` and returns a human-readable
//! summary that the CLI prints; EXPERIMENTS.md records the paper-vs-
//! measured comparison.

pub mod ablations;
pub mod dse_figs;
pub mod figures;
pub mod tables;

use crate::charac::Dataset;
use crate::engine::EngineContext;
use crate::error::{Error, Result};
use crate::expcfg::ExperimentConfig;
use crate::operator::{AxoConfig, Operator};
use std::io::Write;
use std::path::PathBuf;
use std::sync::Arc;

/// Figure-generation harness: CSV plumbing over a shared [`EngineContext`]
/// (which owns the thread-safe dataset cache and estimator service).
pub struct Harness {
    pub cfg: ExperimentConfig,
    engine: EngineContext,
}

impl Harness {
    pub fn new(cfg: ExperimentConfig) -> Harness {
        let engine = EngineContext::new(cfg.clone());
        Harness { cfg, engine }
    }

    /// The engine behind this harness (dataset cache, estimator service,
    /// DSE job drivers).
    pub fn engine(&self) -> &EngineContext {
        &self.engine
    }

    /// The low-bit-width partner used for ConSS (paper Table II arrows).
    pub fn l_operator(h: Operator) -> Result<Operator> {
        crate::engine::l_operator(h)
    }

    /// Characterized dataset for `op` (exhaustive, or seeded sample for the
    /// 8×8 multiplier), cached across figures by the engine.
    pub fn dataset(&self, op: Operator) -> Result<Arc<Dataset>> {
        self.engine.dataset(op)
    }

    /// Validate (characterize) arbitrary configs of `op` natively.
    pub fn validate(&self, op: Operator, configs: &[AxoConfig]) -> Result<Dataset> {
        self.engine.validate(op, configs)
    }

    pub fn out_path(&self, name: &str) -> Result<PathBuf> {
        std::fs::create_dir_all(&self.cfg.out_dir)?;
        Ok(self.cfg.out_dir.join(name))
    }

    /// Write a CSV with a header row.
    pub fn write_csv(
        &self,
        name: &str,
        header: &[&str],
        rows: &[Vec<String>],
    ) -> Result<PathBuf> {
        let path = self.out_path(name)?;
        let mut w = std::io::BufWriter::new(std::fs::File::create(&path)?);
        writeln!(w, "{}", header.join(","))?;
        for r in rows {
            writeln!(w, "{}", r.join(","))?;
        }
        Ok(path)
    }

    /// Run a set of figure ids (or all), returning the printed summaries.
    pub fn run(&self, which: &[String]) -> Result<Vec<String>> {
        let all = [
            "fig1", "fig2", "fig5", "fig10", "fig11", "fig12", "fig13", "fig14",
            "fig15", "fig16", "fig17", "fig18", "tab2", "tab_est",
            "ablate_distance", "ablate_noise", "ablate_seeds",
        ];
        let selected: Vec<&str> = if which.is_empty() || which.iter().any(|w| w == "all") {
            all.to_vec()
        } else {
            which.iter().map(|s| s.as_str()).collect()
        };
        let mut summaries = Vec::new();
        for id in selected {
            let summary = match id {
                "fig1" => figures::fig1_clustering_adders(self)?,
                "fig2" => figures::fig2_trends_subsampled(self)?,
                "fig5" => figures::fig5_trends_all_adders(self)?,
                "fig10" => figures::fig10_clustering_multipliers(self)?,
                "fig11" => figures::fig11_distance_distributions(self)?,
                "fig12" => figures::fig12_matching(self)?,
                "fig13" => figures::fig13_conss_accuracy(self)?,
                "fig14" => figures::fig14_supersampling_regions(self)?,
                "fig15" => dse_figs::fig15_hypervolume_comparison(self)?,
                "fig16" => dse_figs::fig16_hv_progress(self)?,
                "fig17" => dse_figs::fig17_pareto_fronts(self)?,
                "fig18" => dse_figs::fig18_relative_hypervolume(self)?,
                "tab2" => tables::tab2_operators(self)?,
                "tab_est" => tables::tab_estimator_quality(self)?,
                "ablate" => {
                    let mut s = ablations::ablate_distance(self)?;
                    s.push_str(&ablations::ablate_noise(self)?);
                    s.push_str(&ablations::ablate_seeds(self)?);
                    s
                }
                "ablate_distance" => ablations::ablate_distance(self)?,
                "ablate_noise" => ablations::ablate_noise(self)?,
                "ablate_seeds" => ablations::ablate_seeds(self)?,
                other => return Err(Error::Config(format!("unknown figure id `{other}`"))),
            };
            summaries.push(format!("== {id} ==\n{summary}"));
        }
        Ok(summaries)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tempdir::TempDir;

    fn tiny_harness(tmp: &TempDir) -> Harness {
        let cfg = ExperimentConfig {
            out_dir: tmp.path().to_path_buf(),
            train_samples: 200,
            conss: crate::expcfg::ConssConfig { forest_trees: Some(5), ..Default::default() },
            ..Default::default()
        };
        Harness::new(cfg)
    }

    #[test]
    fn dataset_caching_returns_same_arc() {
        let tmp = TempDir::new().unwrap();
        let h = tiny_harness(&tmp);
        let a = h.dataset(Operator::ADD4).unwrap();
        let b = h.dataset(Operator::ADD4).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(a.len(), 15);
    }

    #[test]
    fn l_operator_pairs() {
        assert_eq!(Harness::l_operator(Operator::MUL8).unwrap(), Operator::MUL4);
        assert_eq!(Harness::l_operator(Operator::ADD8).unwrap(), Operator::ADD4);
        assert!(Harness::l_operator(Operator::ADD4).is_err());
    }

    #[test]
    fn cheap_figures_produce_csv() {
        let tmp = TempDir::new().unwrap();
        let h = tiny_harness(&tmp);
        let out = h.run(&["tab2".to_string(), "fig12".to_string()]).unwrap();
        assert_eq!(out.len(), 2);
        assert!(tmp.join("tab2_operators.csv").exists());
        assert!(tmp.join("fig12_match_counts.csv").exists());
        assert!(out[0].contains("68.7 Billion"));
    }

    #[test]
    fn unknown_figure_id_rejected() {
        let tmp = TempDir::new().unwrap();
        let h = tiny_harness(&tmp);
        assert!(h.run(&["fig99".to_string()]).is_err());
    }

    #[test]
    fn csv_writer_layout() {
        let tmp = TempDir::new().unwrap();
        let h = tiny_harness(&tmp);
        let p = h
            .write_csv("t.csv", &["a", "b"], &[vec!["1".into(), "2".into()]])
            .unwrap();
        assert_eq!(std::fs::read_to_string(p).unwrap(), "a,b\n1,2\n");
    }
}
