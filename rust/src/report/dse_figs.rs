//! DSE evaluation figures (Figs. 15, 16, 17, 18) — the headline results.
//!
//! Shared setup: characterize the L (4×4) and H (8×8 sampled) multiplier
//! datasets, train the surrogate estimator and the ConSS pipeline, then per
//! constraint scaling factor run the four methods the paper compares:
//! TRAIN (the characterized sample itself), GA (random-init NSGA-II =
//! AppAxO), ConSS (standalone supersampling pool), and ConSS+GA (the
//! augmented AxOCS search). Hypervolumes are measured on predicted metrics
//! (the PPF, exactly as §V-D) and the VPF validation re-characterizes the
//! front configurations.

use super::Harness;
use crate::baselines::{appaxo_search, evoapprox_library};
use crate::charac::Dataset;
use crate::conss::{ConssPipeline, ConssPool, SupersampleOptions};
use crate::dse::{
    hypervolume::relative_hypervolume2d, hypervolume2d, Constraints, GaResult,
    NsgaRunner, Objectives, ParetoFront,
};
use crate::error::Result;
use crate::expcfg::ExperimentConfig;
use crate::operator::{AxoConfig, Operator};
use crate::surrogate::{build_backend, Surrogate};
use std::fmt::Write as _;
use std::sync::Arc;

/// Everything the DSE figures share (built once per harness call).
pub struct DseSetup {
    pub op: Operator,
    pub l_ds: Arc<Dataset>,
    pub h_ds: Arc<Dataset>,
    pub surrogate: Arc<dyn Surrogate>,
    pub pipeline: ConssPipeline,
    /// H_CHAR objectives `[behav, ppa]` (the TRAIN method's points).
    pub h_objectives: Vec<Objectives>,
}

pub fn setup(h: &Harness) -> Result<DseSetup> {
    let op = Operator::from_name(&h.cfg.operator)?;
    let l_op = Harness::l_operator(op)?;
    let l_ds = h.dataset(l_op)?;
    let h_ds = h.dataset(op)?;
    let surrogate: Arc<dyn Surrogate> = build_backend(
        h.cfg.surrogate.backend,
        h.cfg.surrogate.gbt_stages,
        &h.cfg.artifacts_dir,
        op,
        || Ok(h_ds.clone()),
    )?;
    let opts = SupersampleOptions {
        distance: h.cfg.conss.distance,
        noise_bits: h.cfg.conss.noise_bits,
        seeds: crate::conss::pipeline::SeedSelection::All,
        forest: crate::ml::forest::ForestParams {
            n_trees: h.cfg.conss.forest_trees.unwrap_or(25),
            ..Default::default()
        },
    };
    let pipeline = ConssPipeline::train(&l_ds, &h_ds, opts)?;
    let h_objectives: Vec<Objectives> = h_ds
        .headline_points()
        .iter()
        .map(|p| [p[1], p[0]])
        .collect();
    Ok(DseSetup { op, l_ds, h_ds, surrogate, pipeline, h_objectives })
}

/// One (factor, method) experiment bundle.
pub struct FactorRun {
    pub factor: f64,
    pub constraints: Constraints,
    pub hv_train: f64,
    pub hv_conss: f64,
    pub conss_pool: ConssPool,
    pub conss_objs: Vec<Objectives>,
    pub ga: GaResult,
    pub conss_ga: GaResult,
}

pub fn run_factor(setup: &DseSetup, cfg: &ExperimentConfig, factor: f64) -> Result<FactorRun> {
    let constraints = Constraints::from_scaling_factor(factor, &setup.h_objectives)?;
    let reference = constraints.reference();

    // TRAIN: hypervolume of the characterized sample itself.
    let hv_train = hypervolume2d(&setup.h_objectives, reference);

    // Standalone ConSS: supersample → predicted objectives → HV.
    let pool = setup.pipeline.supersample(Some(&constraints), &setup.h_objectives)?;
    let conss_objs = setup.surrogate.predict(&pool.configs)?;
    let hv_conss = hypervolume2d(&conss_objs, reference);

    // GA (AppAxO-style, random init). The blanket closure impl adapts the
    // dyn-surrogate to the Fitness trait.
    let sur = setup.surrogate.clone();
    let fitness = move |c: &[AxoConfig]| sur.predict(c);
    let ga = appaxo_search(
        setup.op.config_len(),
        &fitness,
        constraints,
        cfg.ga.to_options(cfg.seed),
    )?;

    // ConSS+GA (augmented).
    let runner = NsgaRunner::new(cfg.ga.to_options(cfg.seed), constraints);
    let conss_ga = runner.run(setup.op.config_len(), &fitness, &pool.configs)?;

    Ok(FactorRun {
        factor,
        constraints,
        hv_train,
        hv_conss,
        conss_pool: pool,
        conss_objs,
        ga,
        conss_ga,
    })
}

/// Candidate set for VPF validation: the predicted front plus the final
/// population (the paper re-characterizes 31-390 designs per factor, far
/// more than the front alone).
pub fn vpf_candidates(result: &GaResult) -> Vec<AxoConfig> {
    let mut seen = std::collections::HashSet::new();
    let mut out = Vec::new();
    for c in result.front_configs.iter().chain(&result.population) {
        if seen.insert(c.as_uint()) {
            out.push(*c);
        }
    }
    out
}

/// VPF: validate front configs with the real substrate; returns the
/// validated front and the number of *additional* characterizations (the
/// paper reports 31/282/365/390 for the four factors).
pub fn validate_front(
    h: &Harness,
    setup: &DseSetup,
    configs: &[AxoConfig],
    constraints: &Constraints,
) -> Result<(ParetoFront, usize)> {
    let known: std::collections::HashSet<u64> =
        setup.h_ds.configs.iter().map(|c| c.as_uint()).collect();
    let fresh: Vec<AxoConfig> = configs
        .iter()
        .filter(|c| !known.contains(&c.as_uint()))
        .copied()
        .collect();
    let mut objs: Vec<Objectives> = Vec::new();
    if !fresh.is_empty() {
        let ds = h.validate(setup.op, &fresh)?;
        objs.extend(
            ds.headline_points().iter().map(|p| [p[1], p[0]] as Objectives),
        );
    }
    // Known configs reuse their characterized metrics.
    for c in configs.iter().filter(|c| known.contains(&c.as_uint())) {
        let i = setup
            .h_ds
            .configs
            .iter()
            .position(|k| k.as_uint() == c.as_uint())
            .unwrap();
        let p = setup.h_ds.headline_points()[i];
        objs.push([p[1], p[0]]);
    }
    let feasible: Vec<Objectives> =
        objs.into_iter().filter(|o| constraints.feasible(*o)).collect();
    Ok((ParetoFront::from_points(&feasible), fresh.len()))
}

/// Fig. 15 — final PPF hypervolume: TRAIN / GA / ConSS / ConSS+GA across
/// the constraint scaling factors.
pub fn fig15_hypervolume_comparison(h: &Harness) -> Result<String> {
    let setup = setup(h)?;
    let mut rows = Vec::new();
    let mut s = String::new();
    writeln!(
        s,
        "{:>7} {:>12} {:>12} {:>12} {:>12} {:>6}",
        "factor", "TRAIN", "GA", "ConSS", "ConSS+GA", "VPF+"
    )
    .unwrap();
    for &factor in &h.cfg.scaling_factors {
        let run = run_factor(&setup, &h.cfg, factor)?;
        let (_, extra) =
            validate_front(h, &setup, &vpf_candidates(&run.conss_ga), &run.constraints)?;
        let hv_ga = run.ga.final_hypervolume();
        let hv_cga = run.conss_ga.final_hypervolume();
        writeln!(
            s,
            "{factor:>7.2} {:>12.4} {:>12.4} {:>12.4} {:>12.4} {extra:>6}",
            run.hv_train, hv_ga, run.hv_conss, hv_cga
        )
        .unwrap();
        rows.push(vec![
            factor.to_string(),
            run.hv_train.to_string(),
            hv_ga.to_string(),
            run.hv_conss.to_string(),
            hv_cga.to_string(),
            extra.to_string(),
        ]);
    }
    let path = h.write_csv(
        "fig15_hypervolume.csv",
        &["factor", "hv_train", "hv_ga", "hv_conss", "hv_conss_ga", "vpf_extra_configs"],
        &rows,
    )?;
    writeln!(s, "(paper shape: ConSS+GA ≥ GA; ConSS > TRAIN, up to ~40% when tight)").unwrap();
    writeln!(s, "csv: {}", path.display()).unwrap();
    Ok(s)
}

/// Fig. 16 — hypervolume progression over generations at factor 0.5.
pub fn fig16_hv_progress(h: &Harness) -> Result<String> {
    let setup = setup(h)?;
    let run = run_factor(&setup, &h.cfg, 0.5)?;
    let n = run.ga.hv_history.len().max(run.conss_ga.hv_history.len());
    let last = |v: &Vec<f64>, i: usize| *v.get(i).or(v.last()).unwrap_or(&0.0);
    let rows: Vec<Vec<String>> = (0..n)
        .map(|i| {
            vec![
                i.to_string(),
                last(&run.ga.hv_history, i).to_string(),
                last(&run.conss_ga.hv_history, i).to_string(),
            ]
        })
        .collect();
    let path =
        h.write_csv("fig16_hv_progress.csv", &["generation", "hv_ga", "hv_conss_ga"], &rows)?;
    Ok(format!(
        "factor 0.5: GA starts {:.4} ends {:.4}; ConSS+GA starts {:.4} ends {:.4}\n\
         (paper: 'ConSS+GA starts with much better solutions ... and ends with far better hypervolume')\n\
         csv: {}",
        run.ga.hv_history.first().unwrap(),
        run.ga.final_hypervolume(),
        run.conss_ga.hv_history.first().unwrap(),
        run.conss_ga.final_hypervolume(),
        path.display()
    ))
}

/// Methods compared in Figs. 17/18.
fn method_fronts(
    h: &Harness,
    setup: &DseSetup,
    cfg: &ExperimentConfig,
    factor: f64,
) -> Result<(Constraints, Vec<(String, ParetoFront, usize)>)> {
    let run = run_factor(setup, cfg, factor)?;
    let c = run.constraints;
    // TRAIN front: characterized sample.
    let feasible: Vec<Objectives> = setup
        .h_objectives
        .iter()
        .copied()
        .filter(|o| c.feasible(*o))
        .collect();
    let train_front = ParetoFront::from_points(&feasible);
    // AppAxO: GA-only VPF (front + final population, as validated designs).
    let (appaxo_front, appaxo_extra) =
        validate_front(h, setup, &vpf_candidates(&run.ga), &c)?;
    // EvoApprox: structured library, characterized, Pareto-selected.
    let lib = evoapprox_library(setup.op);
    let lib_ds = h.validate(setup.op, &lib)?;
    let lib_objs: Vec<Objectives> = lib_ds
        .headline_points()
        .iter()
        .map(|p| [p[1], p[0]] as Objectives)
        .filter(|o| c.feasible(*o))
        .collect();
    let evo_front = ParetoFront::from_points(&lib_objs);
    // AxOCS: ConSS+GA VPF — front + population + the ConSS pool itself
    // (standalone ConSS designs are part of the AxOCS flow, Fig. 4).
    let mut axocs_cand = vpf_candidates(&run.conss_ga);
    let mut seen: std::collections::HashSet<u64> =
        axocs_cand.iter().map(|c| c.as_uint()).collect();
    for c in &run.conss_pool.configs {
        if seen.insert(c.as_uint()) {
            axocs_cand.push(*c);
        }
    }
    let (axocs_front, axocs_extra) = validate_front(h, setup, &axocs_cand, &c)?;
    Ok((
        c,
        vec![
            ("TRAIN".into(), train_front, 0),
            ("AppAxO".into(), appaxo_front, appaxo_extra),
            ("EvoApprox".into(), evo_front, lib.len()),
            ("AxOCS".into(), axocs_front, axocs_extra),
        ],
    ))
}

/// Fig. 17 — validated Pareto fronts at factor 0.5.
pub fn fig17_pareto_fronts(h: &Harness) -> Result<String> {
    let setup = setup(h)?;
    let (c, fronts) = method_fronts(h, &setup, &h.cfg, 0.5)?;
    let mut rows = Vec::new();
    let mut s = String::new();
    for (name, front, extra) in &fronts {
        let hv = hypervolume2d(&front.points, c.reference());
        writeln!(
            s,
            "{name:<10} front size {:>3}  hv {hv:.4}  extra charac {extra}",
            front.len()
        )
        .unwrap();
        for p in front.sorted_points() {
            rows.push(vec![name.clone(), p[0].to_string(), p[1].to_string()]);
        }
    }
    let path = h.write_csv(
        "fig17_fronts.csv",
        &["method", "avg_abs_rel_err", "pdplut"],
        &rows,
    )?;
    writeln!(s, "(paper shape: AxOCS beats AppAxO, ≈ EvoApprox when loose)").unwrap();
    writeln!(s, "csv: {}", path.display()).unwrap();
    Ok(s)
}

/// Fig. 18 — relative hypervolume vs scaling factor for all methods.
pub fn fig18_relative_hypervolume(h: &Harness) -> Result<String> {
    let setup = setup(h)?;
    let mut rows = Vec::new();
    let mut s = String::new();
    writeln!(
        s,
        "{:>7} {:>10} {:>10} {:>10} {:>10}",
        "factor", "TRAIN", "AppAxO", "EvoApprox", "AxOCS"
    )
    .unwrap();
    for &factor in &h.cfg.scaling_factors {
        let (c, fronts) = method_fronts(h, &setup, &h.cfg, factor)?;
        let mut vals = Vec::new();
        for (_, front, _) in &fronts {
            vals.push(relative_hypervolume2d(&front.points, c.reference()));
        }
        writeln!(
            s,
            "{factor:>7.2} {:>10.4} {:>10.4} {:>10.4} {:>10.4}",
            vals[0], vals[1], vals[2], vals[3]
        )
        .unwrap();
        rows.push(vec![
            factor.to_string(),
            vals[0].to_string(),
            vals[1].to_string(),
            vals[2].to_string(),
            vals[3].to_string(),
        ]);
    }
    let path = h.write_csv(
        "fig18_relative_hv.csv",
        &["factor", "train", "appaxo", "evoapprox", "axocs"],
        &rows,
    )?;
    writeln!(s, "csv: {}", path.display()).unwrap();
    Ok(s)
}
