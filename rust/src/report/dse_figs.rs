//! DSE evaluation figures (Figs. 15, 16, 17, 18) — the headline results.
//!
//! All pipeline wiring lives in the [`engine`](crate::engine) layer: the
//! harness's [`EngineContext`](crate::engine::EngineContext) caches the L
//! (4×4) and H (8×8 sampled) datasets and shares one batching estimator
//! service, `prepare_dse` trains the ConSS pipeline once, and per
//! constraint scaling factor a [`DseJob`] runs the four methods the paper
//! compares: TRAIN (the characterized sample itself), GA (random-init
//! NSGA-II = AppAxO), ConSS (standalone supersampling pool), and ConSS+GA
//! (the augmented AxOCS search). Fig. 15 runs its factors *concurrently*
//! through `run_many`. Hypervolumes are measured on predicted metrics (the
//! PPF, exactly as §V-D) and the VPF validation re-characterizes the front
//! configurations.

use super::Harness;
use crate::baselines::evoapprox_library;
use crate::dse::{
    hypervolume::relative_hypervolume2d, hypervolume2d, Constraints, Objectives,
    ParetoFront,
};
use crate::engine::{vpf_candidates, DseJob, DsePrepared};
use crate::error::Result;
use std::fmt::Write as _;

/// Fig. 15 — final PPF hypervolume: TRAIN / GA / ConSS / ConSS+GA across
/// the constraint scaling factors, all factors running concurrently
/// through the shared estimator service.
pub fn fig15_hypervolume_comparison(h: &Harness) -> Result<String> {
    let prep = h.engine().prepare_dse()?;
    let jobs: Vec<DseJob> =
        h.cfg.scaling_factors.iter().map(|&f| DseJob::new(f)).collect();
    let before = prep.service.metrics().snapshot();
    let runs = prep.run_many(&jobs)?;
    let after = prep.service.metrics().snapshot();
    let mut rows = Vec::new();
    let mut s = String::new();
    writeln!(
        s,
        "{:>7} {:>12} {:>12} {:>12} {:>12} {:>6}",
        "factor", "TRAIN", "GA", "ConSS", "ConSS+GA", "VPF+"
    )
    .unwrap();
    for run in &runs {
        let (_, extra) = h.engine().validate_front(
            &prep,
            &vpf_candidates(&run.conss_ga),
            &run.constraints,
        )?;
        let hv_ga = run.ga.final_hypervolume();
        let hv_cga = run.conss_ga.final_hypervolume();
        writeln!(
            s,
            "{:>7.2} {:>12.4} {:>12.4} {:>12.4} {:>12.4} {extra:>6}",
            run.factor, run.hv_train, hv_ga, run.hv_conss, hv_cga
        )
        .unwrap();
        rows.push(vec![
            run.factor.to_string(),
            run.hv_train.to_string(),
            hv_ga.to_string(),
            run.hv_conss.to_string(),
            hv_cga.to_string(),
            extra.to_string(),
        ]);
    }
    let path = h.write_csv(
        "fig15_hypervolume.csv",
        &["factor", "hv_train", "hv_ga", "hv_conss", "hv_conss_ga", "vpf_extra_configs"],
        &rows,
    )?;
    // This figure's own service traffic (the shared engine service is
    // process-cumulative, so report the run_many delta).
    let (requests, configs, batches) = (
        after.requests - before.requests,
        after.configs - before.configs,
        after.batches - before.batches,
    );
    writeln!(s, "(paper shape: ConSS+GA ≥ GA; ConSS > TRAIN, up to ~40% when tight)").unwrap();
    writeln!(
        s,
        "estimator service: {requests} requests / {configs} configs in {batches} \
         batches (mean fill {:.1})",
        if batches == 0 { 0.0 } else { configs as f64 / batches as f64 }
    )
    .unwrap();
    writeln!(s, "csv: {}", path.display()).unwrap();
    Ok(s)
}

/// Fig. 16 — hypervolume progression over generations at factor 0.5.
pub fn fig16_hv_progress(h: &Harness) -> Result<String> {
    let prep = h.engine().prepare_dse()?;
    let run = prep.run_job(&DseJob::new(0.5))?;
    let n = run.ga.hv_history.len().max(run.conss_ga.hv_history.len());
    let last = |v: &Vec<f64>, i: usize| *v.get(i).or(v.last()).unwrap_or(&0.0);
    let rows: Vec<Vec<String>> = (0..n)
        .map(|i| {
            vec![
                i.to_string(),
                last(&run.ga.hv_history, i).to_string(),
                last(&run.conss_ga.hv_history, i).to_string(),
            ]
        })
        .collect();
    let path =
        h.write_csv("fig16_hv_progress.csv", &["generation", "hv_ga", "hv_conss_ga"], &rows)?;
    Ok(format!(
        "factor 0.5: GA starts {:.4} ends {:.4}; ConSS+GA starts {:.4} ends {:.4}\n\
         (paper: 'ConSS+GA starts with much better solutions ... and ends with far better hypervolume')\n\
         csv: {}",
        run.ga.hv_history.first().unwrap(),
        run.ga.final_hypervolume(),
        run.conss_ga.hv_history.first().unwrap(),
        run.conss_ga.final_hypervolume(),
        path.display()
    ))
}

/// Methods compared in Figs. 17/18.
fn method_fronts(
    h: &Harness,
    prep: &DsePrepared,
    factor: f64,
) -> Result<(Constraints, Vec<(String, ParetoFront, usize)>)> {
    let run = prep.run_job(&DseJob::new(factor))?;
    let c = run.constraints;
    // TRAIN front: characterized sample.
    let feasible: Vec<Objectives> = prep
        .h_objectives
        .iter()
        .copied()
        .filter(|o| c.feasible(*o))
        .collect();
    let train_front = ParetoFront::from_points(&feasible);
    // AppAxO: GA-only VPF (front + final population, as validated designs).
    let (appaxo_front, appaxo_extra) =
        h.engine().validate_front(prep, &vpf_candidates(&run.ga), &c)?;
    // EvoApprox: structured library, characterized, Pareto-selected.
    let lib = evoapprox_library(prep.op);
    let lib_ds = h.validate(prep.op, &lib)?;
    let lib_objs: Vec<Objectives> = lib_ds
        .headline_points()
        .iter()
        .map(|p| [p[1], p[0]] as Objectives)
        .filter(|o| c.feasible(*o))
        .collect();
    let evo_front = ParetoFront::from_points(&lib_objs);
    // AxOCS: ConSS+GA VPF — front + population + the ConSS pool itself
    // (standalone ConSS designs are part of the AxOCS flow, Fig. 4).
    let mut axocs_cand = vpf_candidates(&run.conss_ga);
    let mut seen: std::collections::HashSet<u64> =
        axocs_cand.iter().map(|c| c.as_uint()).collect();
    for c in &run.conss_pool.configs {
        if seen.insert(c.as_uint()) {
            axocs_cand.push(*c);
        }
    }
    let (axocs_front, axocs_extra) = h.engine().validate_front(prep, &axocs_cand, &c)?;
    Ok((
        c,
        vec![
            ("TRAIN".into(), train_front, 0),
            ("AppAxO".into(), appaxo_front, appaxo_extra),
            ("EvoApprox".into(), evo_front, lib.len()),
            ("AxOCS".into(), axocs_front, axocs_extra),
        ],
    ))
}

/// Fig. 17 — validated Pareto fronts at factor 0.5.
pub fn fig17_pareto_fronts(h: &Harness) -> Result<String> {
    let prep = h.engine().prepare_dse()?;
    let (c, fronts) = method_fronts(h, &prep, 0.5)?;
    let mut rows = Vec::new();
    let mut s = String::new();
    for (name, front, extra) in &fronts {
        let hv = hypervolume2d(&front.points, c.reference());
        writeln!(
            s,
            "{name:<10} front size {:>3}  hv {hv:.4}  extra charac {extra}",
            front.len()
        )
        .unwrap();
        for p in front.sorted_points() {
            rows.push(vec![name.clone(), p[0].to_string(), p[1].to_string()]);
        }
    }
    let path = h.write_csv(
        "fig17_fronts.csv",
        &["method", "avg_abs_rel_err", "pdplut"],
        &rows,
    )?;
    writeln!(s, "(paper shape: AxOCS beats AppAxO, ≈ EvoApprox when loose)").unwrap();
    writeln!(s, "csv: {}", path.display()).unwrap();
    Ok(s)
}

/// Fig. 18 — relative hypervolume vs scaling factor for all methods.
pub fn fig18_relative_hypervolume(h: &Harness) -> Result<String> {
    let prep = h.engine().prepare_dse()?;
    let mut rows = Vec::new();
    let mut s = String::new();
    writeln!(
        s,
        "{:>7} {:>10} {:>10} {:>10} {:>10}",
        "factor", "TRAIN", "AppAxO", "EvoApprox", "AxOCS"
    )
    .unwrap();
    for &factor in &h.cfg.scaling_factors {
        let (c, fronts) = method_fronts(h, &prep, factor)?;
        let mut vals = Vec::new();
        for (_, front, _) in &fronts {
            vals.push(relative_hypervolume2d(&front.points, c.reference()));
        }
        writeln!(
            s,
            "{factor:>7.2} {:>10.4} {:>10.4} {:>10.4} {:>10.4}",
            vals[0], vals[1], vals[2], vals[3]
        )
        .unwrap();
        rows.push(vec![
            factor.to_string(),
            vals[0].to_string(),
            vals[1].to_string(),
            vals[2].to_string(),
            vals[3].to_string(),
        ]);
    }
    let path = h.write_csv(
        "fig18_relative_hv.csv",
        &["factor", "train", "appaxo", "evoapprox", "axocs"],
        &rows,
    )?;
    writeln!(s, "csv: {}", path.display()).unwrap();
    Ok(s)
}
