//! Table II (operator inventory) and the §V-B estimator-quality table.

use super::Harness;
use crate::error::Result;
use crate::ml::gbt::{GbtParams, GradientBoostedTrees};
use crate::ml::metrics::{r2, rmse};
use crate::operator::Operator;
use std::fmt::Write as _;

/// Table II — integer arithmetic operators used in the evaluation.
pub fn tab2_operators(h: &Harness) -> Result<String> {
    let mut s = String::new();
    let mut rows = Vec::new();
    writeln!(
        s,
        "{:<22} {:>9} {:>16} {:>14}",
        "operator", "bit-width", "possible designs", "config length"
    )
    .unwrap();
    for op in Operator::ALL {
        let designs = if op.exhaustive() {
            (op.design_space_size() + 1).to_string() // paper counts incl. zero
        } else {
            "68.7 Billion".into()
        };
        writeln!(
            s,
            "{:<22} {:>9} {:>16} {:>11}-bit",
            match op.kind {
                crate::operator::OperatorKind::UnsignedAdder => "Unsigned Adder",
                crate::operator::OperatorKind::SignedMultiplier => "Signed Multiplier",
            },
            op.bits,
            designs,
            op.config_len()
        )
        .unwrap();
        rows.push(vec![
            op.name(),
            op.bits.to_string(),
            designs,
            op.config_len().to_string(),
        ]);
    }
    // ConSS upscale factors (ratio of configuration lengths, Table II).
    writeln!(s, "\nConSS upscale factors (config-length ratios):").unwrap();
    for (l, hop) in [
        (Operator::ADD4, Operator::ADD8),
        (Operator::ADD4, Operator::ADD12),
        (Operator::ADD8, Operator::ADD12),
        (Operator::MUL4, Operator::MUL8),
    ] {
        writeln!(
            s,
            "  {} -> {}: {:.1}x",
            l.name(),
            hop.name(),
            hop.config_len() as f64 / l.config_len() as f64
        )
        .unwrap();
    }
    let path = h.write_csv(
        "tab2_operators.csv",
        &["operator", "bits", "possible_designs", "config_len"],
        &rows,
    )?;
    writeln!(s, "csv: {}", path.display()).unwrap();
    Ok(s)
}

/// §V-B — estimator quality per metric: products (PDP, PDPLUT) regress
/// worse than their factor metrics, reproducing the paper's observation.
pub fn tab_estimator_quality(h: &Harness) -> Result<String> {
    let op = Operator::from_name(&h.cfg.operator)?;
    let ds = h.dataset(op)?;
    let l = op.config_len() as usize;
    let x: Vec<f64> = ds
        .configs
        .iter()
        .flat_map(|c| c.to_bits_f32().into_iter().map(|v| v as f64))
        .collect();
    let n = ds.len();
    let split = n * 4 / 5;

    let metrics: Vec<(&str, Vec<f64>)> = vec![
        ("power_mw", ds.ppa.iter().map(|p| p.power_mw).collect()),
        ("cpd_ns", ds.ppa.iter().map(|p| p.cpd_ns).collect()),
        ("luts", ds.ppa.iter().map(|p| p.luts).collect()),
        ("pdp", ds.ppa.iter().map(|p| p.pdp).collect()),
        ("pdplut", ds.ppa.iter().map(|p| p.pdplut).collect()),
        (
            "avg_abs_rel_err",
            ds.behav.iter().map(|b| b.avg_abs_rel_err).collect(),
        ),
    ];

    let mut s = String::new();
    let mut rows = Vec::new();
    writeln!(s, "{:<18} {:>12} {:>8} {:>12}", "metric", "test RMSE", "R2", "norm RMSE").unwrap();
    for (name, y) in &metrics {
        let gbt = GradientBoostedTrees::fit(
            &x[..split * l],
            l,
            &y[..split],
            GbtParams::default(),
        )?;
        let pred: Vec<f64> = (split..n)
            .map(|i| gbt.predict_row(&x[i * l..(i + 1) * l]))
            .collect();
        let truth = &y[split..];
        let e = rmse(truth, &pred);
        let r = r2(truth, &pred);
        let span = truth.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
            - truth.iter().cloned().fold(f64::INFINITY, f64::min);
        let nrmse = if span > 0.0 { e / span } else { 0.0 };
        writeln!(s, "{name:<18} {e:>12.5} {r:>8.4} {nrmse:>12.5}").unwrap();
        rows.push(vec![
            name.to_string(),
            e.to_string(),
            r.to_string(),
            nrmse.to_string(),
        ]);
    }
    let path = h.write_csv(
        "tab_estimator_quality.csv",
        &["metric", "rmse", "r2", "normalized_rmse"],
        &rows,
    )?;
    writeln!(
        s,
        "(paper §V-B: product metrics PDP/PDPLUT report larger RMSE than raw metrics)"
    )
    .unwrap();
    writeln!(s, "csv: {}", path.display()).unwrap();
    Ok(s)
}
