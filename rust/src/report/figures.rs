//! Statistical-analysis figures (Figs. 1, 2, 5, 10, 11, 12, 13, 14).

use super::Harness;
use crate::charac::Dataset;
use crate::conss::{ConssPipeline, SupersampleOptions};
use crate::dse::Objectives;
use crate::error::Result;
use crate::matching::{conss_training_set, DistanceKind, Matcher};
use crate::ml::metrics::hamming_accuracy;
use crate::ml::RandomForest;
use crate::operator::Operator;
use crate::stats::kmeans::centroid_alignment;
use crate::stats::{correlation, Histogram, KMeans, MinMaxScaler};
use crate::surrogate::{GbtSurrogate, Surrogate};
use std::fmt::Write as _;

fn scaled_headline(ds: &Dataset) -> Result<Vec<[f64; 2]>> {
    Matcher::scaled_points(ds)
}

fn kmeans_compare(
    h: &Harness,
    name: &str,
    op_a: Operator,
    op_b: Operator,
    k: usize,
) -> Result<String> {
    let da = h.dataset(op_a)?;
    let db = h.dataset(op_b)?;
    // (a) absolute-metric clustering per dataset.
    let abs_a = KMeans::fit(&da.headline_points(), k, h.cfg.seed);
    let abs_b = KMeans::fit(&db.headline_points(), k, h.cfg.seed + 1);
    // (b) scaled clustering (the Fig. 1b/10b comparison).
    let sa = scaled_headline(&da)?;
    let sb = scaled_headline(&db)?;
    let ka = KMeans::fit(&sa, k, h.cfg.seed);
    let kb = KMeans::fit(&sb, k, h.cfg.seed + 1);
    let align = centroid_alignment(&ka.centroids, &kb.centroids);
    let (elbow_a, _) = KMeans::elbow(&sa, 8, h.cfg.seed);
    let (elbow_b, _) = KMeans::elbow(&sb, 8, h.cfg.seed);

    let mut rows = Vec::new();
    for (tag, km) in [
        (format!("{op_a}-abs"), &abs_a),
        (format!("{op_b}-abs"), &abs_b),
        (format!("{op_a}-scaled"), &ka),
        (format!("{op_b}-scaled"), &kb),
    ] {
        for (i, c) in km.centroids.iter().enumerate() {
            rows.push(vec![
                tag.clone(),
                i.to_string(),
                c[0].to_string(),
                c[1].to_string(),
                km.sizes()[i].to_string(),
            ]);
        }
    }
    let path = h.write_csv(
        &format!("{name}_centroids.csv"),
        &["dataset", "cluster", "pdplut", "avg_abs_rel_err", "size"],
        &rows,
    )?;
    let mut s = String::new();
    writeln!(s, "k = {k} clusters over (PDPLUT, AVG_ABS_REL_ERR)").unwrap();
    writeln!(s, "elbow-selected k: {op_a} = {elbow_a}, {op_b} = {elbow_b}").unwrap();
    writeln!(
        s,
        "scaled centroid alignment (mean matched distance): {align:.4} \
         (paper: centroids 'in the vicinity of each other')"
    )
    .unwrap();
    writeln!(s, "csv: {}", path.display()).unwrap();
    Ok(s)
}

/// Fig. 1 — k-means clustering of 8- vs 12-bit unsigned adder AxOs.
pub fn fig1_clustering_adders(h: &Harness) -> Result<String> {
    kmeans_compare(h, "fig1", Operator::ADD8, Operator::ADD12, 5)
}

/// Fig. 10 — k-means clustering of 4×4 vs 8×8 signed multiplier AxOs.
pub fn fig10_clustering_multipliers(h: &Harness) -> Result<String> {
    kmeans_compare(h, "fig10", Operator::MUL4, Operator::MUL8, 5)
}

fn uint_ordered_scaled_series(ds: &Dataset) -> Result<(Vec<f64>, Vec<f64>)> {
    let mut idx: Vec<usize> = (0..ds.len()).collect();
    idx.sort_by_key(|&i| ds.configs[i].as_uint());
    let pts = ds.headline_points();
    let scaler = MinMaxScaler::fit_points2(&pts)?;
    let ppa: Vec<f64> = idx.iter().map(|&i| scaler.scale_value(0, pts[i][0])).collect();
    let beh: Vec<f64> = idx.iter().map(|&i| scaler.scale_value(1, pts[i][1])).collect();
    Ok((ppa, beh))
}

/// Fig. 2 — scaled PDPLUT / error vs UINT config, 8- vs 12-bit adders with
/// 16-wide window sub-sampling of the 12-bit sequence.
pub fn fig2_trends_subsampled(h: &Harness) -> Result<String> {
    let d8 = h.dataset(Operator::ADD8)?;
    let d12 = h.dataset(Operator::ADD12)?;
    let (p8, b8) = uint_ordered_scaled_series(&d8)?;
    let (p12, b12) = uint_ordered_scaled_series(&d12)?;
    let p12s = correlation::window_means(&p12, 16);
    let b12s = correlation::window_means(&b12, 16);
    // 255 vs 256 points: compare over the common prefix.
    let n = p8.len().min(p12s.len());
    let rows: Vec<Vec<String>> = (0..n)
        .map(|i| {
            vec![
                i.to_string(),
                p8[i].to_string(),
                b8[i].to_string(),
                p12s[i].to_string(),
                b12s[i].to_string(),
            ]
        })
        .collect();
    let path = h.write_csv(
        "fig2_trends.csv",
        &["rank", "pdplut_add8", "err_add8", "pdplut_add12_w16", "err_add12_w16"],
        &rows,
    )?;
    let cp = correlation::pearson(&p8[..n], &p12s[..n]);
    let cb = correlation::pearson(&b8[..n], &b12s[..n]);
    let sp = correlation::spearman(&p8[..n], &p12s[..n]);
    let sb = correlation::spearman(&b8[..n], &b12s[..n]);
    Ok(format!(
        "config-ordered scaled metric sequences, 12-bit sub-sampled x16\n\
         PDPLUT  pearson {cp:.3} spearman {sp:.3}\n\
         BEHAV   pearson {cb:.3} spearman {sb:.3}\n\
         (paper: 'similar patterns for both bit-width operators')\n\
         csv: {}",
        path.display()
    ))
}

/// Fig. 5 — Configuration-PPA/BEHAV trends for 4/8/12-bit adders.
pub fn fig5_trends_all_adders(h: &Harness) -> Result<String> {
    let mut s = String::new();
    let mut all: Vec<(Operator, Vec<f64>, Vec<f64>)> = Vec::new();
    for op in [Operator::ADD4, Operator::ADD8, Operator::ADD12] {
        let ds = h.dataset(op)?;
        let (p, b) = uint_ordered_scaled_series(&ds)?;
        let rows: Vec<Vec<String>> = (0..p.len())
            .map(|i| vec![i.to_string(), p[i].to_string(), b[i].to_string()])
            .collect();
        h.write_csv(
            &format!("fig5_{}.csv", op.name()),
            &["uint_rank", "pdplut_scaled", "err_scaled"],
            &rows,
        )?;
        all.push((op, p, b));
    }
    // Cross-width pattern similarity via window-matched Spearman.
    for w in all.windows(2) {
        let (op_a, pa, ba) = &w[0];
        let (op_b, pb, bb) = &w[1];
        let win = pb.len() / pa.len().max(1);
        let pbs = correlation::window_means(pb, win.max(1));
        let bbs = correlation::window_means(bb, win.max(1));
        let n = pa.len().min(pbs.len());
        writeln!(
            s,
            "{op_a} vs {op_b}: PDPLUT spearman {:.3}, BEHAV spearman {:.3}",
            correlation::spearman(&pa[..n], &pbs[..n]),
            correlation::spearman(&ba[..n], &bbs[..n]),
        )
        .unwrap();
    }
    writeln!(s, "csv: fig5_add4/add8/add12.csv").unwrap();
    Ok(s)
}

/// Fig. 11 — distributions of the three distance measures, 4- vs 8-bit
/// adders.
pub fn fig11_distance_distributions(h: &Harness) -> Result<String> {
    let l = h.dataset(Operator::ADD4)?;
    let hds = h.dataset(Operator::ADD8)?;
    let mut rows = Vec::new();
    let mut s = String::new();
    let mut occupancy = Vec::new();
    for kind in DistanceKind::ALL {
        let d = Matcher::new(kind).all_distances(&l, &hds)?;
        let hist = Histogram::from_values_range(&d, 30, 0.0, 1.5);
        occupancy.push((kind, hist.occupancy()));
        for (c, (&count, dens)) in hist
            .centers()
            .iter()
            .zip(hist.counts.iter().zip(hist.densities()))
        {
            rows.push(vec![
                kind.name().into(),
                c.to_string(),
                count.to_string(),
                dens.to_string(),
            ]);
        }
    }
    let path = h.write_csv(
        "fig11_distance_hist.csv",
        &["measure", "bin_center", "count", "density"],
        &rows,
    )?;
    for (kind, occ) in &occupancy {
        writeln!(s, "{:<10} bin occupancy {occ:.3}", kind.name()).unwrap();
    }
    let e = occupancy[0].1;
    let p = occupancy[2].1;
    writeln!(
        s,
        "euclidean/manhattan spread wider than pareto: {} (paper Fig. 11 shape)",
        e > p
    )
    .unwrap();
    writeln!(s, "csv: {}", path.display()).unwrap();
    Ok(s)
}

/// Fig. 12 — Euclidean heat-map + one-to-many match counts, 4→8-bit adders.
pub fn fig12_matching(h: &Harness) -> Result<String> {
    let l = h.dataset(Operator::ADD4)?;
    let hds = h.dataset(Operator::ADD8)?;
    let matcher = Matcher::new(DistanceKind::Euclidean);
    let dm = matcher.all_distances(&l, &hds)?; // (H, L) row-major
    let mut rows = Vec::new();
    for (hi, chunk) in dm.chunks(l.len()).enumerate() {
        for (li, d) in chunk.iter().enumerate() {
            rows.push(vec![
                hds.configs[hi].as_uint().to_string(),
                l.configs[li].as_uint().to_string(),
                d.to_string(),
            ]);
        }
    }
    h.write_csv("fig12_heatmap.csv", &["h_uint", "l_uint", "distance"], &rows)?;

    let m = matcher.match_datasets(&l, &hds)?;
    let counts = m.counts_per_l(l.len());
    let count_rows: Vec<Vec<String>> = counts
        .iter()
        .enumerate()
        .map(|(li, &c)| {
            vec![
                l.configs[li].as_uint().to_string(),
                l.configs[li].to_string(),
                c.to_string(),
            ]
        })
        .collect();
    let path = h.write_csv(
        "fig12_match_counts.csv",
        &["l_uint", "l_bits", "h_matches"],
        &count_rows,
    )?;
    let top: Vec<String> = {
        let mut order: Vec<usize> = (0..counts.len()).collect();
        order.sort_by_key(|&i| std::cmp::Reverse(counts[i]));
        order
            .iter()
            .take(3)
            .map(|&i| format!("{} → {} matches", l.configs[i], counts[i]))
            .collect()
    };
    Ok(format!(
        "one-to-many matching of 255 H configs onto 15 L configs\n{}\ncsv: {}",
        top.join("\n"),
        path.display()
    ))
}

/// Fig. 13 — ConSS random-forest accuracy (Hamming) vs number of noise
/// bits, 4×4 → 8×8 signed multipliers.
pub fn fig13_conss_accuracy(h: &Harness) -> Result<String> {
    let l = h.dataset(Operator::MUL4)?;
    let hds = h.dataset(Operator::MUL8)?;
    let matcher = Matcher::new(DistanceKind::Euclidean);
    let m = matcher.match_datasets(&l, &hds)?;
    let mut rows = Vec::new();
    let mut s = String::new();
    for noise_bits in 0..=4u32 {
        let (x, xf, y, yf) = conss_training_set(&l, &hds, &m, noise_bits)?;
        let n = x.len() / xf;
        // 80/20 deterministic split on row index.
        let split = n * 4 / 5;
        let params = crate::ml::forest::ForestParams {
            n_trees: h.cfg.conss.forest_trees.unwrap_or(15),
            ..Default::default()
        };
        let forest = RandomForest::fit(&x[..split * xf], xf, &y[..split * yf], yf, params)?;
        let acc_over = |lo: usize, hi: usize| {
            let mut t = Vec::new();
            let mut p = Vec::new();
            for r in lo..hi {
                let row = &x[r * xf..(r + 1) * xf];
                p.extend(forest.predict_bits_row(row));
                t.extend(y[r * yf..(r + 1) * yf].iter().map(|&v| v as u8));
            }
            hamming_accuracy(&t, &p)
        };
        let acc_train = acc_over(0, split);
        let acc = acc_over(split, n);
        rows.push(vec![
            noise_bits.to_string(),
            acc_train.to_string(),
            acc.to_string(),
            (n - split).to_string(),
        ]);
        writeln!(
            s,
            "noise_bits {noise_bits}: hamming accuracy train {acc_train:.4} / holdout {acc:.4}"
        )
        .unwrap();
    }
    let path = h.write_csv(
        "fig13_conss_accuracy.csv",
        &["noise_bits", "train_accuracy", "holdout_accuracy", "test_rows"],
        &rows,
    )?;
    writeln!(s, "(paper: 'additional noise bits do not affect the accuracy')").unwrap();
    writeln!(s, "csv: {}", path.display()).unwrap();
    Ok(s)
}

/// Fig. 14 — unique supersampled 8×8 designs per BEHAV-PPA region, all-seed
/// vs Pareto-only-seed variants.
pub fn fig14_supersampling_regions(h: &Harness) -> Result<String> {
    let l = h.dataset(Operator::MUL4)?;
    let hds = h.dataset(Operator::MUL8)?;
    let surrogate = GbtSurrogate::train(&hds, Default::default())?;
    let mut rows = Vec::new();
    let mut s = String::new();
    for (label, seeds) in [
        ("all", crate::conss::pipeline::SeedSelection::All),
        ("pareto", crate::conss::pipeline::SeedSelection::ParetoOnly),
    ] {
        let opts = SupersampleOptions {
            noise_bits: h.cfg.conss.noise_bits,
            seeds,
            ..Default::default()
        };
        let pipe = ConssPipeline::train(&l, &hds, opts)?;
        let pool = pipe.supersample(None, &[])?;
        let preds: Vec<Objectives> = surrogate.predict(&pool.configs)?;
        // 3×3 regions over the scaled predicted plane.
        let scaler = MinMaxScaler::fit(
            &preds.iter().flatten().copied().collect::<Vec<f64>>(),
            2,
        )?;
        let mut grid = [[0usize; 3]; 3];
        for p in &preds {
            let b = (scaler.scale_value(0, p[0]) * 3.0).min(2.999) as usize;
            let q = (scaler.scale_value(1, p[1]) * 3.0).min(2.999) as usize;
            grid[b][q] += 1;
        }
        for (bi, row) in grid.iter().enumerate() {
            for (pi, &c) in row.iter().enumerate() {
                rows.push(vec![
                    label.into(),
                    bi.to_string(),
                    pi.to_string(),
                    c.to_string(),
                ]);
            }
        }
        writeln!(
            s,
            "{label}-seeds: {} seeds → {} unique predicted 8×8 designs",
            pool.n_seeds,
            pool.configs.len()
        )
        .unwrap();
    }
    let path = h.write_csv(
        "fig14_regions.csv",
        &["seed_mode", "behav_region", "ppa_region", "unique_designs"],
        &rows,
    )?;
    writeln!(s, "csv: {}", path.display()).unwrap();
    Ok(s)
}
