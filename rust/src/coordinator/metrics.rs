//! Service metrics: request/batch counters, batch fill, latency.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Lock-free counters shared between the batcher loop and its clients.
#[derive(Debug, Default)]
pub struct ServiceMetrics {
    requests: AtomicU64,
    configs: AtomicU64,
    batches: AtomicU64,
    errors: AtomicU64,
    busy_micros: AtomicU64,
    max_batch_fill: AtomicU64,
}

impl ServiceMetrics {
    pub fn record_request(&self, n_configs: usize) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.configs.fetch_add(n_configs as u64, Ordering::Relaxed);
    }

    pub fn record_batch(&self, fill: usize, busy: Duration, ok: bool) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        if !ok {
            self.errors.fetch_add(1, Ordering::Relaxed);
        }
        self.busy_micros
            .fetch_add(busy.as_micros() as u64, Ordering::Relaxed);
        self.max_batch_fill.fetch_max(fill as u64, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            configs: self.configs.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            busy_micros: self.busy_micros.load(Ordering::Relaxed),
            max_batch_fill: self.max_batch_fill.load(Ordering::Relaxed),
        }
    }
}

/// A point-in-time copy of the counters.
#[derive(Debug, Clone, Copy)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub configs: u64,
    pub batches: u64,
    pub errors: u64,
    pub busy_micros: u64,
    pub max_batch_fill: u64,
}

impl MetricsSnapshot {
    /// Mean configurations per backend batch — the batching win.
    pub fn mean_batch_fill(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.configs as f64 / self.batches as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = ServiceMetrics::default();
        m.record_request(10);
        m.record_request(5);
        m.record_batch(15, Duration::from_micros(100), true);
        m.record_batch(3, Duration::from_micros(50), false);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.configs, 15);
        assert_eq!(s.batches, 2);
        assert_eq!(s.errors, 1);
        assert_eq!(s.busy_micros, 150);
        assert_eq!(s.max_batch_fill, 15);
        assert!((s.mean_batch_fill() - 7.5).abs() < 1e-12);
    }
}
