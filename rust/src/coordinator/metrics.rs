//! Service metrics: request/batch counters, batch fill, latency.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Lock-free counters shared between the batcher loop and its clients.
#[derive(Debug, Default)]
pub struct ServiceMetrics {
    requests: AtomicU64,
    configs: AtomicU64,
    batches: AtomicU64,
    errors: AtomicU64,
    busy_micros: AtomicU64,
    max_batch_fill: AtomicU64,
}

impl ServiceMetrics {
    pub fn record_request(&self, n_configs: usize) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.configs.fetch_add(n_configs as u64, Ordering::Relaxed);
    }

    pub fn record_batch(&self, fill: usize, busy: Duration, ok: bool) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        if !ok {
            self.errors.fetch_add(1, Ordering::Relaxed);
        }
        self.busy_micros
            .fetch_add(busy.as_micros() as u64, Ordering::Relaxed);
        self.max_batch_fill.fetch_max(fill as u64, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            configs: self.configs.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            busy_micros: self.busy_micros.load(Ordering::Relaxed),
            max_batch_fill: self.max_batch_fill.load(Ordering::Relaxed),
        }
    }
}

/// A point-in-time copy of the counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub configs: u64,
    pub batches: u64,
    pub errors: u64,
    pub busy_micros: u64,
    pub max_batch_fill: u64,
}

impl MetricsSnapshot {
    /// Mean configurations per backend batch — the batching win.
    pub fn mean_batch_fill(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.configs as f64 / self.batches as f64
        }
    }

    /// Configurations per wall-clock second. Zero-duration (or zero-work)
    /// intervals report 0.0 rather than NaN/inf — an instant or
    /// zero-request run must print a finite throughput.
    pub fn configs_per_sec(&self, elapsed: std::time::Duration) -> f64 {
        let secs = elapsed.as_secs_f64();
        if secs > 0.0 && self.configs > 0 {
            self.configs as f64 / secs
        } else {
            0.0
        }
    }

    /// The snapshot as a JSON object (the `/metrics` wire shape; keys
    /// match the field names, plus the derived mean batch fill).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("requests", Json::Num(self.requests as f64)),
            ("configs", Json::Num(self.configs as f64)),
            ("batches", Json::Num(self.batches as f64)),
            ("errors", Json::Num(self.errors as f64)),
            ("busy_micros", Json::Num(self.busy_micros as f64)),
            ("max_batch_fill", Json::Num(self.max_batch_fill as f64)),
            ("mean_batch_fill", Json::Num(self.mean_batch_fill())),
        ])
    }

    /// Pool-aware aggregation: counters sum, `max_batch_fill` takes the
    /// max — so a fleet of per-operator services reports one snapshot.
    pub fn merged(&self, other: &MetricsSnapshot) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: self.requests + other.requests,
            configs: self.configs + other.configs,
            batches: self.batches + other.batches,
            errors: self.errors + other.errors,
            busy_micros: self.busy_micros + other.busy_micros,
            max_batch_fill: self.max_batch_fill.max(other.max_batch_fill),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = ServiceMetrics::default();
        m.record_request(10);
        m.record_request(5);
        m.record_batch(15, Duration::from_micros(100), true);
        m.record_batch(3, Duration::from_micros(50), false);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.configs, 15);
        assert_eq!(s.batches, 2);
        assert_eq!(s.errors, 1);
        assert_eq!(s.busy_micros, 150);
        assert_eq!(s.max_batch_fill, 15);
        assert!((s.mean_batch_fill() - 7.5).abs() < 1e-12);
    }

    #[test]
    fn throughput_is_finite_for_degenerate_intervals() {
        let m = ServiceMetrics::default();
        let empty = m.snapshot();
        // Zero requests and/or zero elapsed time: 0.0, never NaN or inf.
        assert_eq!(empty.configs_per_sec(Duration::ZERO), 0.0);
        assert_eq!(empty.configs_per_sec(Duration::from_secs(1)), 0.0);
        m.record_request(10);
        let s = m.snapshot();
        assert_eq!(s.configs_per_sec(Duration::ZERO), 0.0);
        assert!(s.configs_per_sec(Duration::ZERO).is_finite());
        assert!((s.configs_per_sec(Duration::from_secs(2)) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn to_json_carries_every_counter() {
        let m = ServiceMetrics::default();
        m.record_request(10);
        m.record_batch(10, Duration::from_micros(100), true);
        let v = m.snapshot().to_json();
        assert_eq!(v.get("requests").and_then(|x| x.as_u64()), Some(1));
        assert_eq!(v.get("configs").and_then(|x| x.as_u64()), Some(10));
        assert_eq!(v.get("batches").and_then(|x| x.as_u64()), Some(1));
        assert_eq!(v.get("errors").and_then(|x| x.as_u64()), Some(0));
        assert_eq!(v.get("busy_micros").and_then(|x| x.as_u64()), Some(100));
        assert_eq!(v.get("max_batch_fill").and_then(|x| x.as_u64()), Some(10));
        assert_eq!(v.get("mean_batch_fill").and_then(|x| x.as_f64()), Some(10.0));
    }

    #[test]
    fn merged_sums_counters_and_maxes_fill() {
        let a = ServiceMetrics::default();
        a.record_request(6);
        a.record_batch(6, Duration::from_micros(10), true);
        let b = ServiceMetrics::default();
        b.record_request(2);
        b.record_request(2);
        b.record_batch(4, Duration::from_micros(30), false);
        let m = a.snapshot().merged(&b.snapshot());
        assert_eq!(m.requests, 3);
        assert_eq!(m.configs, 10);
        assert_eq!(m.batches, 2);
        assert_eq!(m.errors, 1);
        assert_eq!(m.busy_micros, 40);
        assert_eq!(m.max_batch_fill, 6);
        // Identity under the default snapshot.
        let d = MetricsSnapshot::default().merged(&m);
        assert_eq!(d.requests, m.requests);
        assert_eq!(d.max_batch_fill, m.max_batch_fill);
    }
}
