//! Panic-isolated parallel validation (PPF → VPF).
//!
//! After the GA finishes, the pseudo Pareto-front's configurations are
//! re-characterized with the real substrate ("The PPF solutions ... are
//! then characterized to generate the Validated Pareto-front (VPF)
//! designs", Fig. 4). Validation is chunked so a poisoned configuration
//! cannot take down the run: each chunk is evaluated behind
//! `catch_unwind`, failures surface as [`Error::Coordinator`] for that
//! chunk only.

use crate::charac::{characterize, Backend, Dataset, InputSet};
use crate::error::Error;
#[cfg(test)]
use crate::error::Result;
use crate::operator::{AxoConfig, Operator};

/// Validate configurations in chunks; returns the merged dataset and the
/// list of (chunk start, error) failures.
pub fn validate_in_chunks(
    op: Operator,
    configs: &[AxoConfig],
    inputs: &InputSet,
    backend: &Backend<'_>,
    chunk_size: usize,
) -> (Option<Dataset>, Vec<(usize, Error)>) {
    let chunk_size = chunk_size.max(1);
    let mut merged: Option<Dataset> = None;
    let mut failures = Vec::new();
    for (ci, chunk) in configs.chunks(chunk_size).enumerate() {
        let start = ci * chunk_size;
        let attempt = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            characterize(op, chunk, inputs, backend)
        }));
        match attempt {
            Ok(Ok(ds)) => match &mut merged {
                None => merged = Some(ds),
                Some(m) => {
                    if let Err(e) = m.merge(&ds) {
                        failures.push((start, e));
                    }
                }
            },
            Ok(Err(e)) => failures.push((start, e)),
            Err(_) => failures.push((
                start,
                Error::Coordinator(format!("validation chunk at {start} panicked")),
            )),
        }
    }
    (merged, failures)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::charac::pipeline::BehavEvaluator;
    use crate::charac::BehavMetrics;

    #[test]
    fn validates_all_chunks_natively() {
        let op = Operator::ADD4;
        let inputs = InputSet::exhaustive(op);
        let cfgs: Vec<AxoConfig> = AxoConfig::enumerate(4).collect();
        let (ds, fails) =
            validate_in_chunks(op, &cfgs, &inputs, &Backend::Native, 4);
        assert!(fails.is_empty());
        assert_eq!(ds.unwrap().len(), 15);
    }

    /// Evaluator that panics on chunks containing the accurate config.
    struct PanickyEval;
    impl BehavEvaluator for PanickyEval {
        fn eval(
            &self,
            _op: Operator,
            configs: &[AxoConfig],
            _inputs: &InputSet,
        ) -> Result<Vec<BehavMetrics>> {
            if configs.iter().any(|c| c.is_accurate()) {
                panic!("poisoned config");
            }
            Ok(vec![BehavMetrics::ZERO; configs.len()])
        }
    }

    #[test]
    fn panicking_chunk_is_isolated() {
        let op = Operator::ADD4;
        let inputs = InputSet::exhaustive(op);
        let cfgs: Vec<AxoConfig> = AxoConfig::enumerate(4).collect(); // last is accurate
        let (ds, fails) = validate_in_chunks(
            op,
            &cfgs,
            &inputs,
            &Backend::Evaluator(&PanickyEval),
            4,
        );
        // 15 configs → chunks [0..4),[4..8),[8..12),[12..15); accurate
        // (uint 15) is in the last chunk.
        assert_eq!(fails.len(), 1);
        assert_eq!(fails[0].0, 12);
        assert!(matches!(fails[0].1, Error::Coordinator(_)));
        assert_eq!(ds.unwrap().len(), 12);
    }
}
