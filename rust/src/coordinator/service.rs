//! Batching estimator service.
//!
//! Compiled PJRT executables have *static* batch shapes; individual GA
//! fitness queries are small and bursty. The service decouples the two
//! with the classic dynamic-batching loop (cf. vLLM's router): requests
//! queue on an mpsc channel; the drainer thread packs them until either
//! `max_batch` configurations are pending or `max_wait` has elapsed since
//! the first queued request, then issues ONE backend call and scatters the
//! answers back through per-request channels. Requests are never dropped,
//! reordered within a request, or duplicated — the property-test suite in
//! `rust/tests/` pins this.
//!
//! Built on `std::thread` + `std::sync::mpsc` (this repo links no async
//! runtime); the blocking [`Fitness`] impl makes the service a drop-in GA
//! backend, and several concurrent searches (e.g. the four scaling factors
//! of Fig. 15) share one compiled executable through it.

use super::ServiceMetrics;
use crate::dse::{Fitness, Objectives};
use crate::error::{Error, Result};
use crate::operator::AxoConfig;
use crate::surrogate::Surrogate;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Batching knobs.
#[derive(Debug, Clone)]
pub struct BatchOptions {
    /// Flush when this many configurations are pending (align with the
    /// compiled executable's batch size).
    pub max_batch: usize,
    /// Flush this long after the first pending request.
    pub max_wait: Duration,
}

impl Default for BatchOptions {
    fn default() -> Self {
        BatchOptions { max_batch: 256, max_wait: Duration::from_millis(2) }
    }
}

struct Request {
    configs: Vec<AxoConfig>,
    resp: mpsc::Sender<Result<Vec<Objectives>>>,
}

/// Handle to a running estimator service (cheap to clone; the batcher
/// thread exits when the last handle is dropped).
#[derive(Clone)]
pub struct EstimatorService {
    tx: mpsc::Sender<Request>,
    metrics: Arc<ServiceMetrics>,
}

impl EstimatorService {
    /// Spawn the batcher thread.
    pub fn spawn(backend: Arc<dyn Surrogate>, options: BatchOptions) -> EstimatorService {
        let (tx, rx) = mpsc::channel::<Request>();
        let metrics = Arc::new(ServiceMetrics::default());
        let m = metrics.clone();
        std::thread::Builder::new()
            .name("axocs-estimator-batcher".into())
            .spawn(move || batcher_loop(rx, backend, options, m))
            .expect("failed to spawn batcher thread");
        EstimatorService { tx, metrics }
    }

    pub fn metrics(&self) -> &ServiceMetrics {
        &self.metrics
    }

    /// Submit one prediction request and wait for the batch result.
    pub fn predict(&self, configs: Vec<AxoConfig>) -> Result<Vec<Objectives>> {
        if configs.is_empty() {
            return Ok(Vec::new());
        }
        self.metrics.record_request(configs.len());
        let (resp, rx) = mpsc::channel();
        self.tx
            .send(Request { configs, resp })
            .map_err(|_| Error::Coordinator("estimator service is down".into()))?;
        rx.recv()
            .map_err(|_| Error::Coordinator("estimator service dropped request".into()))?
    }
}

impl Fitness for EstimatorService {
    fn evaluate(&self, configs: &[AxoConfig]) -> Result<Vec<Objectives>> {
        self.predict(configs.to_vec())
    }
}

fn batcher_loop(
    rx: mpsc::Receiver<Request>,
    backend: Arc<dyn Surrogate>,
    options: BatchOptions,
    metrics: Arc<ServiceMetrics>,
) {
    loop {
        // Block for the first request of a batch.
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // all handles dropped
        };
        let mut pending = vec![first];
        let mut pending_configs = pending[0].configs.len();

        // Accumulate until size or deadline.
        let deadline = Instant::now() + options.max_wait;
        while pending_configs < options.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => {
                    pending_configs += r.configs.len();
                    pending.push(r);
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }

        // One backend call for the whole batch, panic-isolated.
        let all: Vec<AxoConfig> =
            pending.iter().flat_map(|r| r.configs.iter().copied()).collect();
        let fill = all.len();
        let mut span = crate::obs::span(crate::obs::n::ESTIMATOR_BATCH);
        span.set_arg(fill as u64);
        let started = Instant::now();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            backend.predict(&all)
        }))
        .unwrap_or_else(|_| Err(Error::Coordinator("backend panicked".into())));
        let result = result.and_then(|objs| {
            if objs.len() == fill {
                Ok(objs)
            } else {
                Err(Error::Coordinator(format!(
                    "backend returned {} objectives for {fill} configs",
                    objs.len()
                )))
            }
        });
        let elapsed = started.elapsed();
        drop(span);
        metrics.record_batch(fill, elapsed, result.is_ok());
        crate::obs::metrics().batch_fill.record(fill as u64);
        crate::obs::metrics().batch_ns.record(elapsed.as_nanos() as u64);

        match result {
            Ok(objs) => {
                let mut off = 0;
                for req in pending {
                    let n = req.configs.len();
                    let slice = objs[off..off + n].to_vec();
                    off += n;
                    let _ = req.resp.send(Ok(slice));
                }
            }
            Err(e) => {
                let msg = e.to_string();
                for req in pending {
                    let _ = req
                        .resp
                        .send(Err(Error::Coordinator(format!("batch failed: {msg}"))));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Counts backend invocations; objective = (uint % 7, uint % 5).
    struct CountingBackend {
        calls: std::sync::atomic::AtomicUsize,
        delay: Duration,
    }

    impl Surrogate for CountingBackend {
        fn predict(&self, configs: &[AxoConfig]) -> Result<Vec<Objectives>> {
            self.calls.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            if !self.delay.is_zero() {
                std::thread::sleep(self.delay);
            }
            Ok(configs
                .iter()
                .map(|c| [(c.as_uint() % 7) as f64, (c.as_uint() % 5) as f64])
                .collect())
        }
    }

    fn counting(delay: Duration) -> Arc<CountingBackend> {
        Arc::new(CountingBackend { calls: Default::default(), delay })
    }

    fn cfgs(range: std::ops::Range<u64>) -> Vec<AxoConfig> {
        range.map(|v| AxoConfig::new(v, 16).unwrap()).collect()
    }

    #[test]
    fn responses_match_requests_across_threads() {
        let be = counting(Duration::ZERO);
        let svc = EstimatorService::spawn(be.clone(), BatchOptions::default());
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for start in 1..20u64 {
                let svc = svc.clone();
                handles.push(s.spawn(move || {
                    let c = cfgs(start..start + 5);
                    let r = svc.predict(c.clone()).unwrap();
                    (c, r)
                }));
            }
            for h in handles {
                let (c, r) = h.join().unwrap();
                assert_eq!(r.len(), c.len());
                for (cfg, obj) in c.iter().zip(&r) {
                    assert_eq!(obj[0], (cfg.as_uint() % 7) as f64);
                    assert_eq!(obj[1], (cfg.as_uint() % 5) as f64);
                }
            }
        });
        let snap = svc.metrics().snapshot();
        assert_eq!(snap.configs, 19 * 5);
        assert!(snap.batches as usize <= 19);
        assert_eq!(snap.errors, 0);
    }

    #[test]
    fn batching_coalesces_concurrent_requests() {
        // Slow backend so requests pile up behind the first batch.
        let be = counting(Duration::from_millis(10));
        let svc = EstimatorService::spawn(
            be.clone(),
            BatchOptions { max_batch: 512, max_wait: Duration::from_millis(30) },
        );
        std::thread::scope(|s| {
            for start in 1..=10u64 {
                let svc = svc.clone();
                s.spawn(move || svc.predict(cfgs(start * 100..start * 100 + 10)).unwrap());
            }
        });
        let calls = be.calls.load(std::sync::atomic::Ordering::SeqCst);
        assert!(calls < 10, "expected coalescing, saw {calls} backend calls");
        assert!(svc.metrics().snapshot().mean_batch_fill() > 10.0);
    }

    struct FailingBackend;
    impl Surrogate for FailingBackend {
        fn predict(&self, _c: &[AxoConfig]) -> Result<Vec<Objectives>> {
            Err(Error::Xla("backend exploded".into()))
        }
    }

    #[test]
    fn backend_failure_propagates_to_all_waiters() {
        let svc = EstimatorService::spawn(Arc::new(FailingBackend), BatchOptions::default());
        std::thread::scope(|s| {
            let s1 = svc.clone();
            let a = s.spawn(move || s1.predict(cfgs(1..4)));
            let s2 = svc.clone();
            let b = s.spawn(move || s2.predict(cfgs(4..8)));
            assert!(matches!(a.join().unwrap(), Err(Error::Coordinator(_))));
            assert!(matches!(b.join().unwrap(), Err(Error::Coordinator(_))));
        });
        assert!(svc.metrics().snapshot().errors >= 1);
    }

    struct PanickingBackend;
    impl Surrogate for PanickingBackend {
        fn predict(&self, _c: &[AxoConfig]) -> Result<Vec<Objectives>> {
            panic!("kaboom");
        }
    }

    #[test]
    fn backend_panic_is_isolated_and_service_survives() {
        let svc = EstimatorService::spawn(Arc::new(PanickingBackend), BatchOptions::default());
        let r1 = svc.predict(cfgs(1..3));
        assert!(matches!(r1, Err(Error::Coordinator(_))));
        // Service still alive for subsequent requests.
        let r2 = svc.predict(cfgs(3..5));
        assert!(matches!(r2, Err(Error::Coordinator(_))));
    }

    struct ShortBackend;
    impl Surrogate for ShortBackend {
        fn predict(&self, configs: &[AxoConfig]) -> Result<Vec<Objectives>> {
            Ok(vec![[0.0, 0.0]; configs.len().saturating_sub(1)])
        }
    }

    #[test]
    fn wrong_length_backend_detected() {
        let svc = EstimatorService::spawn(Arc::new(ShortBackend), BatchOptions::default());
        assert!(matches!(svc.predict(cfgs(1..5)), Err(Error::Coordinator(_))));
    }

    #[test]
    fn empty_request_is_noop() {
        let be = counting(Duration::ZERO);
        let svc = EstimatorService::spawn(be.clone(), BatchOptions::default());
        let r = svc.predict(Vec::new()).unwrap();
        assert!(r.is_empty());
        assert_eq!(be.calls.load(std::sync::atomic::Ordering::SeqCst), 0);
    }

    #[test]
    fn fitness_impl_works() {
        let be = counting(Duration::ZERO);
        let svc = EstimatorService::spawn(be, BatchOptions::default());
        let c = cfgs(1..9);
        let out = svc.evaluate(&c).unwrap();
        assert_eq!(out.len(), 8);
    }
}
