//! The coordinator — L3's request-path machinery.
//!
//! The GA's fitness queries are *requests*; this module provides the
//! vLLM-router-style service that batches them onto the compiled PJRT
//! executables (whose batch shapes are static):
//!
//! * [`service`] — [`EstimatorService`]: an mpsc request queue drained by a
//!   batching thread (size- and deadline-triggered), fronting any
//!   [`Surrogate`] backend; implements [`Fitness`] so the GA can use it
//!   directly. Multiple concurrent searches (e.g. the four constraint
//!   scaling factors of Fig. 15) share one backend through it.
//! * [`metrics`] — request/batch counters and latency accounting.
//! * [`worker`] — panic-isolated chunked validation (PPF → VPF).
//!
//! [`Surrogate`]: crate::surrogate::Surrogate
//! [`Fitness`]: crate::dse::Fitness

pub mod metrics;
pub mod service;
pub mod worker;

pub use metrics::ServiceMetrics;
pub use service::{BatchOptions, EstimatorService};
pub use worker::validate_in_chunks;
