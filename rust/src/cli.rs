//! Hand-rolled argument parser for the `repro` binary.
//!
//! No external CLI crate is linked (offline build); this covers exactly the
//! surface the binary needs: one subcommand, positional arguments,
//! `--flag value` / `--flag=value` options, boolean switches, `--help`.

use std::collections::HashMap;

/// Parsed command line: subcommand, positionals, options, switches.
#[derive(Debug, Clone, Default)]
pub struct ParsedArgs {
    pub command: String,
    pub positionals: Vec<String>,
    options: HashMap<String, String>,
    switches: Vec<String>,
}

/// Parse error (unknown option, missing value).
#[derive(Debug)]
pub struct ArgError(pub String);

impl std::fmt::Display for ArgError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "argument error: {}", self.0)
    }
}

impl std::error::Error for ArgError {}

impl ParsedArgs {
    /// Parse `args` (without argv[0]). `switch_names` lists the boolean
    /// flags; everything else starting with `--` expects a value.
    pub fn parse<I: IntoIterator<Item = String>>(
        args: I,
        switch_names: &[&str],
    ) -> Result<ParsedArgs, ArgError> {
        let mut out = ParsedArgs::default();
        let mut it = args.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if switch_names.contains(&name) {
                    out.switches.push(name.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| ArgError(format!("--{name} needs a value")))?;
                    out.options.insert(name.to_string(), v);
                }
            } else if out.command.is_empty() {
                out.command = arg;
            } else {
                out.positionals.push(arg);
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, ArgError> {
        match self.opt(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|_| ArgError(format!("--{name}: cannot parse `{v}`"))),
        }
    }

    /// Parse a comma-separated option value (e.g. `--factors 0.2,0.5,0.75`).
    /// Empty segments are ignored, so trailing commas are harmless.
    pub fn opt_parse_list<T: std::str::FromStr>(
        &self,
        name: &str,
    ) -> Result<Option<Vec<T>>, ArgError> {
        match self.opt(name) {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.parse::<T>()
                        .map_err(|_| ArgError(format!("--{name}: cannot parse `{s}`")))
                })
                .collect::<Result<Vec<T>, ArgError>>()
                .map(Some),
        }
    }

    pub fn positional(&self, idx: usize, what: &str) -> Result<&str, ArgError> {
        self.positionals
            .get(idx)
            .map(|s| s.as_str())
            .ok_or_else(|| ArgError(format!("missing {what}")))
    }

    /// Reject options that no subcommand consumed (typo protection).
    pub fn ensure_known(&self, known: &[&str]) -> Result<(), ArgError> {
        for k in self.options.keys() {
            if !known.contains(&k.as_str()) {
                return Err(ArgError(format!("unknown option --{k}")));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> ParsedArgs {
        ParsedArgs::parse(v.iter().map(|s| s.to_string()), &["quick", "pjrt"]).unwrap()
    }

    #[test]
    fn subcommand_positionals_options() {
        let a = parse(&["match", "add4", "add8", "--distance", "manhattan"]);
        assert_eq!(a.command, "match");
        assert_eq!(a.positionals, vec!["add4", "add8"]);
        assert_eq!(a.opt("distance"), Some("manhattan"));
    }

    #[test]
    fn equals_form_and_switches() {
        let a = parse(&["dse", "--factor=0.5", "--quick"]);
        assert_eq!(a.opt_parse::<f64>("factor").unwrap(), Some(0.5));
        assert!(a.flag("quick"));
        assert!(!a.flag("pjrt"));
    }

    #[test]
    fn missing_value_is_error() {
        let r = ParsedArgs::parse(
            ["x".to_string(), "--config".to_string()].into_iter(),
            &[],
        );
        assert!(r.is_err());
    }

    #[test]
    fn bad_parse_is_error() {
        let a = parse(&["dse", "--factor", "abc"]);
        assert!(a.opt_parse::<f64>("factor").is_err());
    }

    #[test]
    fn list_parsing() {
        let a = parse(&["dse", "--factors", "0.2, 0.5,0.75,"]);
        assert_eq!(
            a.opt_parse_list::<f64>("factors").unwrap(),
            Some(vec![0.2, 0.5, 0.75])
        );
        assert_eq!(a.opt_parse_list::<f64>("absent").unwrap(), None);
        let bad = parse(&["dse", "--factors", "0.2,x"]);
        assert!(bad.opt_parse_list::<f64>("factors").is_err());
        let empty = parse(&["dse", "--factors", ","]);
        assert_eq!(empty.opt_parse_list::<f64>("factors").unwrap(), Some(vec![]));
    }

    #[test]
    fn unknown_option_detection() {
        let a = parse(&["dse", "--fctor", "0.5"]);
        assert!(a.ensure_known(&["factor", "config"]).is_err());
        assert!(a.ensure_known(&["fctor"]).is_ok());
    }
}
