//! # AxOCS — Scaling FPGA-based Approximate Operators using Configuration Supersampling
//!
//! Production-grade reproduction of the IEEE TCAS-I paper (Sahoo, Ullah,
//! Bhattacharjee, Kumar; DOI 10.1109/TCSI.2024.3385333) as a three-layer
//! rust + JAX + Pallas stack. This crate is **Layer 3**: the DSE
//! coordinator that owns the entire request path. Python (Layers 1/2) runs
//! once at build time (`make artifacts`) to AOT-lower the Pallas
//! characterization kernels and surrogate MLPs to HLO text, which
//! [`runtime`] loads and executes through the PJRT CPU client.
//!
//! ## Build matrix
//!
//! * **default (hermetic)** — std-only, zero external crates, no network,
//!   no artifacts: native bit-exact characterization, exact-table and GBT
//!   surrogates, the full DSE/ConSS/report stack. This is the tier-1
//!   `cargo build --release && cargo test -q` configuration.
//! * **`--features pjrt`** — additionally compiles [`runtime`]'s PJRT
//!   client/executables and [`surrogate::pjrt`](surrogate) against the
//!   (vendored, stubbed) `xla` bindings; running compiled artifacts needs
//!   `make artifacts` plus a real `xla` package override (see
//!   `rust/xla-stub`). PJRT tests skip, not fail, when artifacts are
//!   absent — probe with `charac::Backend::pjrt_ready`.
//!
//! ## Pipeline (paper Fig. 4)
//!
//! ```text
//! operator model ──► characterization ──► statistical analysis
//!   (operator/)         (charac/ + synth/)     (stats/)
//!                                                 │
//!                       distance-based matching (matching/)
//!                                                 │
//!                       ML supersampling — ConSS (ml/ + conss/)
//!                                                 │
//!            augmented NSGA-II multi-objective DSE (dse/)
//!                                                 │
//!                    PPF ──validate──► VPF (charac/) ──► report/
//! ```
//!
//! The whole flow is orchestrated by the [`engine`] layer: an
//! [`engine::EngineContext`] caches characterized datasets (one
//! characterization per process) and shares one batching estimator
//! service, and [`engine::DseJob`]s for independent constraint scaling
//! factors run concurrently through it ([`engine::DsePrepared::run_many`]).
//!
//! ## Module map
//!
//! * [`operator`] — LUT-level approximate operator model (AppAxO-style):
//!   unsigned adders, signed Baugh-Wooley multipliers.
//! * [`synth`] — analytical Vivado-substitute synthesis estimator (PPA).
//! * [`charac`] — characterization pipeline: BEHAV × PPA datasets.
//! * [`stats`] — k-means, min-max scaling, distance measures, histograms.
//! * [`matching`] — distance-based matching → ConSS training datasets.
//! * [`ml`] — native random forest + gradient-boosted trees.
//! * [`surrogate`] — estimator backends (native GBT / exact table / PJRT MLP).
//! * [`dse`] — NSGA-II genetic search, Pareto tools, hypervolume.
//! * [`conss`] — configuration supersampling pipelines.
//! * [`baselines`] — AppAxO-like GA and EvoApprox-like library baselines.
//! * [`coordinator`] — std-thread estimator service: batching, workers,
//!   metrics (this repo links no async runtime).
//! * [`engine`] — job-oriented orchestration: per-key-guarded dataset
//!   cache, persistent on-disk dataset store, sharded characterization,
//!   keyed cross-operator estimator pool, concurrent multi-factor DSE
//!   jobs.
//! * [`serve`] — serve-mode DSE: a file-spool job queue
//!   (`pending/running/done/failed`), JSON job specs/results, and a
//!   bounded worker pool executing queued jobs against one resident
//!   engine (`repro serve-dse` / `repro submit`).
//! * [`runtime`] — artifact schemas (always) + PJRT client wrapper that
//!   loads `artifacts/*.hlo.txt` (`pjrt` feature).
//! * [`report`] — regenerates every paper figure/table (Figs 1–18, Tab II).
//! * [`expcfg`] — TOML experiment configuration system.
//! * [`obs`] — unified observability: spans (Chrome-trace exportable),
//!   log-bucketed latency histograms, Prometheus text exposition.
//! * [`fault`] — deterministic failpoints (`REPRO_FAULTS`) threaded
//!   through every durability-critical write path; zero-cost when
//!   disarmed, drives the crash-torture suite.

pub mod baselines;
pub mod charac;
pub mod cli;
pub mod conss;
pub mod coordinator;
pub mod dse;
pub mod engine;
pub mod error;
pub mod expcfg;
pub mod fault;
pub mod matching;
pub mod ml;
pub mod obs;
pub mod operator;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod stats;
pub mod surrogate;
pub mod synth;
pub mod util;

/// Convenience re-exports covering the public API surface used by the
/// examples and the CLI.
pub mod prelude {
    pub use crate::charac::{characterize, Backend, Dataset};
    pub use crate::conss::{ConssPipeline, SupersampleOptions};
    pub use crate::dse::{
        hypervolume2d, Constraints, GaOptions, NsgaRunner, Objectives, ParetoFront,
    };
    pub use crate::engine::{DseJob, EngineContext};
    pub use crate::error::{Error, Result};
    pub use crate::matching::{DistanceKind, Matcher};
    pub use crate::ml::{forest::RandomForest, gbt::GradientBoostedTrees};
    pub use crate::operator::{AxoConfig, Operator, OperatorKind};
    pub use crate::serve::{JobQueue, JobRunner, JobSpec, ServeOptions};
    pub use crate::stats::{kmeans::KMeans, scaling::MinMaxScaler};
    pub use crate::surrogate::{EstimatorBackend, Surrogate};
    pub use crate::synth::PpaMetrics;
}
