//! Micro-benchmark harness (the criterion substitute behind `cargo bench`).
//!
//! `harness = false` bench targets call [`Bench::new`] and register
//! closures; each gets a warmup phase, then timed iterations until both a
//! minimum iteration count and a minimum wall-clock budget are met.
//! Reported: mean, median, p99, and min per iteration.

use std::time::{Duration, Instant};

/// Runs and reports a set of named benchmarks.
pub struct Bench {
    pub warmup: Duration,
    pub budget: Duration,
    pub min_iters: usize,
    results: Vec<BenchResult>,
}

/// One benchmark's statistics (nanoseconds per iteration).
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

/// CI smoke mode (`REPRO_BENCH_SMOKE=1`): one iteration per benchmark, no
/// warmup, no statistics budget — catches bench bit-rot on every PR
/// without spending wall-clock on measurement quality.
pub fn smoke_mode() -> bool {
    std::env::var_os("REPRO_BENCH_SMOKE").is_some_and(|v| is_truthy(&v))
}

fn is_truthy(v: &std::ffi::OsStr) -> bool {
    !(v.is_empty()
        || v == "0"
        || v.eq_ignore_ascii_case("false")
        || v.eq_ignore_ascii_case("no")
        || v.eq_ignore_ascii_case("off"))
}

impl Bench {
    pub fn new() -> Bench {
        if smoke_mode() {
            return Bench {
                warmup: Duration::ZERO,
                budget: Duration::ZERO,
                min_iters: 1,
                results: Vec::new(),
            };
        }
        Bench {
            warmup: Duration::from_millis(200),
            budget: Duration::from_millis(1500),
            min_iters: 10,
            results: Vec::new(),
        }
    }

    /// Override warmup/measurement budgets (ignored in smoke mode, which
    /// always runs exactly one iteration).
    pub fn with_budget(mut self, warmup: Duration, budget: Duration) -> Bench {
        if !smoke_mode() {
            self.warmup = warmup;
            self.budget = budget;
        }
        self
    }

    /// Time `f`, which should perform ONE unit of work and return a value
    /// (kept alive to prevent dead-code elimination).
    pub fn bench<R>(&mut self, name: &str, mut f: impl FnMut() -> R) -> &BenchResult {
        // Warmup.
        let t0 = Instant::now();
        while t0.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Timed runs.
        let mut samples_ns: Vec<f64> = Vec::new();
        let t1 = Instant::now();
        while samples_ns.len() < self.min_iters || t1.elapsed() < self.budget {
            let s = Instant::now();
            std::hint::black_box(f());
            samples_ns.push(s.elapsed().as_nanos() as f64);
            if samples_ns.len() >= 1_000_000 {
                break;
            }
        }
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples_ns.len();
        let mean = samples_ns.iter().sum::<f64>() / n as f64;
        let result = BenchResult {
            name: name.to_string(),
            iters: n,
            mean_ns: mean,
            median_ns: crate::obs::percentile_sorted(&samples_ns, 50.0),
            p99_ns: crate::obs::percentile_sorted(&samples_ns, 99.0),
            min_ns: samples_ns[0],
        };
        println!(
            "{:<44} {:>10} iters  mean {:>12}  median {:>12}  p99 {:>12}",
            result.name,
            result.iters,
            fmt_ns(result.mean_ns),
            fmt_ns(result.median_ns),
            fmt_ns(result.p99_ns),
        );
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Render the standard footer (also parsed by EXPERIMENTS.md tooling).
    pub fn finish(&self) {
        println!("\n{} benchmarks completed", self.results.len());
    }

    /// Results as a JSON document (the `BENCH_*.json` perf-trajectory
    /// stamp format: mode + per-bench iteration statistics).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let benches: std::collections::BTreeMap<String, Json> = self
            .results
            .iter()
            .map(|r| {
                (
                    r.name.clone(),
                    Json::obj(vec![
                        ("iters", Json::Num(r.iters as f64)),
                        ("mean_ns", Json::Num(r.mean_ns)),
                        ("median_ns", Json::Num(r.median_ns)),
                        ("p99_ns", Json::Num(r.p99_ns)),
                        ("min_ns", Json::Num(r.min_ns)),
                    ]),
                )
            })
            .collect();
        Json::obj(vec![
            (
                "mode",
                Json::Str(if smoke_mode() { "smoke".into() } else { "full".into() }),
            ),
            ("benches", Json::Obj(benches)),
        ])
    }

    /// Persist [`Bench::to_json`] to `path` (e.g. `BENCH_charac.json`).
    pub fn write_json(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string())
    }
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

/// Human-readable nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench::new().with_budget(Duration::from_millis(5), Duration::from_millis(20));
        let r = b.bench("noop-sum", || (0..100u64).sum::<u64>());
        assert!(r.iters >= 10);
        assert!(r.mean_ns > 0.0);
        assert!(r.min_ns <= r.median_ns && r.median_ns <= r.p99_ns);
    }

    #[test]
    fn smoke_truthiness() {
        for off in ["", "0", "false", "FALSE", "no", "off"] {
            assert!(!is_truthy(std::ffi::OsStr::new(off)), "{off:?}");
        }
        for on in ["1", "true", "yes"] {
            assert!(is_truthy(std::ffi::OsStr::new(on)), "{on:?}");
        }
    }

    #[test]
    fn json_stamp_round_trips() {
        let mut b =
            Bench::new().with_budget(Duration::from_millis(1), Duration::from_millis(5));
        b.bench("a/x", || 1u32 + 1);
        let dir = crate::util::tempdir::TempDir::new().unwrap();
        let p = dir.join("BENCH_test.json");
        b.write_json(&p).unwrap();
        let v = crate::util::json::Json::parse(&std::fs::read_to_string(p).unwrap())
            .unwrap();
        assert!(v.get("mode").is_some());
        let bench = v.get("benches").and_then(|bs| bs.get("a/x")).unwrap();
        assert!(bench.get("mean_ns").and_then(|m| m.as_f64()).unwrap() >= 0.0);
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_ns(3.2e9), "3.200 s");
    }
}
