//! TOML-subset parser for experiment configuration files.
//!
//! Supports the grammar `configs/*.toml` actually uses: top-level and
//! `[section]` tables, `key = value` with string / integer / float /
//! boolean / homogeneous-array values, `#` comments, and quoted strings.
//! Values land in a flat `section.key → TomlValue` map.

use std::collections::BTreeMap;

/// A parsed scalar or array value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(v) => Some(*v),
            TomlValue::Int(v) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64_array(&self) -> Option<Vec<f64>> {
        match self {
            TomlValue::Array(a) => a.iter().map(|v| v.as_f64()).collect(),
            _ => None,
        }
    }
}

/// Parse error with line number.
#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub message: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml parse error on line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for TomlError {}

/// Parse into a flat `"section.key"` (or `"key"` at top level) map.
pub fn parse(text: &str) -> Result<BTreeMap<String, TomlValue>, TomlError> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = ln + 1;
        let mut s = raw.trim();
        if s.is_empty() || s.starts_with('#') {
            continue;
        }
        if s.starts_with('[') {
            let end = s
                .find(']')
                .ok_or(TomlError { line, message: "unterminated section".into() })?;
            section = s[1..end].trim().to_string();
            if section.is_empty() {
                return Err(TomlError { line, message: "empty section name".into() });
            }
            let rest = s[end + 1..].trim();
            if !rest.is_empty() && !rest.starts_with('#') {
                return Err(TomlError { line, message: "junk after section".into() });
            }
            continue;
        }
        let eq = s
            .find('=')
            .ok_or(TomlError { line, message: "expected `key = value`".into() })?;
        let key = s[..eq].trim();
        if key.is_empty() {
            return Err(TomlError { line, message: "empty key".into() });
        }
        s = s[eq + 1..].trim();
        let (value, rest) = parse_value(s, line)?;
        let rest = rest.trim();
        if !rest.is_empty() && !rest.starts_with('#') {
            return Err(TomlError { line, message: format!("junk after value: `{rest}`") });
        }
        let full_key = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        if out.insert(full_key.clone(), value).is_some() {
            return Err(TomlError { line, message: format!("duplicate key `{full_key}`") });
        }
    }
    Ok(out)
}

fn parse_value(s: &str, line: usize) -> Result<(TomlValue, &str), TomlError> {
    let s = s.trim_start();
    if s.is_empty() {
        return Err(TomlError { line, message: "missing value".into() });
    }
    let err = |m: &str| TomlError { line, message: m.into() };
    if let Some(rest) = s.strip_prefix('"') {
        let end = rest.find('"').ok_or_else(|| err("unterminated string"))?;
        return Ok((TomlValue::Str(rest[..end].to_string()), &rest[end + 1..]));
    }
    if let Some(mut rest) = s.strip_prefix('[') {
        let mut items = Vec::new();
        loop {
            rest = rest.trim_start();
            if let Some(r) = rest.strip_prefix(']') {
                return Ok((TomlValue::Array(items), r));
            }
            let (v, r) = parse_value(rest, line)?;
            items.push(v);
            rest = r.trim_start();
            if let Some(r) = rest.strip_prefix(',') {
                rest = r;
            } else if !rest.starts_with(']') {
                return Err(err("expected `,` or `]` in array"));
            }
        }
    }
    if let Some(r) = s.strip_prefix("true") {
        return Ok((TomlValue::Bool(true), r));
    }
    if let Some(r) = s.strip_prefix("false") {
        return Ok((TomlValue::Bool(false), r));
    }
    // Number: consume up to delimiter.
    let end = s
        .find(|c: char| c == ',' || c == ']' || c == '#' || c.is_whitespace())
        .unwrap_or(s.len());
    let tok = &s[..end];
    let rest = &s[end..];
    if tok.contains('.') || tok.contains('e') || tok.contains('E') {
        tok.parse::<f64>()
            .map(|v| (TomlValue::Float(v), rest))
            .map_err(|_| err(&format!("bad float `{tok}`")))
    } else {
        tok.parse::<i64>()
            .map(|v| (TomlValue::Int(v), rest))
            .map_err(|_| err(&format!("bad value `{tok}`")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_example() {
        let text = r#"
# experiment
name = "fig15"
operator = "mul8"
train_samples = 2000
scaling_factors = [0.2, 0.5, 0.75, 1.0]

[ga]
pop_size = 100
generations = 250   # paper max
crossover_prob = 0.9

[conss]
distance = "euclidean"
noise_bits = 4
enabled = true
"#;
        let m = parse(text).unwrap();
        assert_eq!(m["name"].as_str(), Some("fig15"));
        assert_eq!(m["train_samples"].as_usize(), Some(2000));
        assert_eq!(
            m["scaling_factors"].as_f64_array().unwrap(),
            vec![0.2, 0.5, 0.75, 1.0]
        );
        assert_eq!(m["ga.pop_size"].as_usize(), Some(100));
        assert_eq!(m["ga.crossover_prob"].as_f64(), Some(0.9));
        assert_eq!(m["conss.enabled"].as_bool(), Some(true));
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(parse("novalue").is_err());
        assert!(parse("[unterminated").is_err());
        assert!(parse("x = ").is_err());
        assert!(parse("x = \"oops").is_err());
        assert!(parse("x = [1, 2").is_err());
        assert!(parse("x = 1\nx = 2").is_err());
        assert!(parse("x = 1 junk").is_err());
    }

    #[test]
    fn comments_and_blanks_skipped() {
        let m = parse("# only comment\n\n  \nx = 3 # trailing\n").unwrap();
        assert_eq!(m["x"].as_i64(), Some(3));
    }

    #[test]
    fn nested_arrays() {
        let m = parse("x = [[1, 2], [3]]").unwrap();
        match &m["x"] {
            TomlValue::Array(outer) => {
                assert_eq!(outer.len(), 2);
                assert_eq!(outer[0], TomlValue::Array(vec![TomlValue::Int(1), TomlValue::Int(2)]));
            }
            _ => panic!(),
        }
    }
}
