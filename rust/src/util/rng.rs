//! Deterministic PRNG: splitmix64 seeding + xoshiro256** core.
//!
//! Every stochastic component (GA, forests, k-means++, sampling) threads an
//! explicit [`Rng`], so a fixed seed reproduces an entire experiment
//! bit-for-bit on any platform. The generator is Blackman/Vigna's
//! xoshiro256**, seeded via splitmix64 as its authors recommend.

/// Seedable xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Rng {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (for per-tree / per-thread RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::seed_from_u64(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` (Lemire-style rejection).
    #[inline]
    pub fn gen_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        // Rejection sampling over the top bits; bias is < 2^-64 per draw,
        // but we keep the loop for exactness.
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u64();
            let (hi, lo) = {
                let m = (r as u128) * (bound as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= threshold {
                return hi;
            }
        }
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    #[inline]
    pub fn gen_range_inclusive(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.gen_below(hi - lo + 1)
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn gen_index(&mut self, bound: usize) -> usize {
        self.gen_below(bound as u64) as usize
    }

    /// Bernoulli draw.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Weighted index over non-negative weights (k-means++ seeding).
    pub fn gen_weighted(&mut self, weights: &[f64]) -> Option<usize> {
        let total: f64 = weights.iter().sum();
        if !(total.is_finite() && total > 0.0) {
            return None;
        }
        let mut target = self.gen_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return Some(i);
            }
        }
        Some(weights.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(7);
        for _ in 0..10_000 {
            let v = r.gen_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn gen_below_bounds_and_coverage() {
        let mut r = Rng::seed_from_u64(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.gen_below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::seed_from_u64(9);
        for _ in 0..1000 {
            let v = r.gen_range_inclusive(5, 8);
            assert!((5..=8).contains(&v));
        }
        assert_eq!(r.gen_range_inclusive(4, 4), 4);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(11);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
        assert_ne!(xs, (0..50).collect::<Vec<u32>>()); // astronomically unlikely
    }

    #[test]
    fn mean_approximately_half() {
        let mut r = Rng::seed_from_u64(1);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.gen_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn weighted_respects_zero_weights() {
        let mut r = Rng::seed_from_u64(2);
        for _ in 0..100 {
            let i = r.gen_weighted(&[0.0, 1.0, 0.0]).unwrap();
            assert_eq!(i, 1);
        }
        assert!(r.gen_weighted(&[0.0, 0.0]).is_none());
    }

    #[test]
    fn fork_streams_differ() {
        let mut base = Rng::seed_from_u64(5);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
