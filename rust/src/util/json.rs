//! Minimal JSON: value model, recursive-descent parser, writer.
//!
//! Handles the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null). Numbers are kept as `f64` plus the original
//! token so integer round-trips are exact up to 2^53 (enough for every
//! UINT configuration ≤ 2^36 this project stores).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // -- accessors ---------------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|v| {
            (v >= 0.0 && v.fract() == 0.0 && v <= 2f64.powi(53)).then_some(v as u64)
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// `obj.get(key)` as f64 array.
    pub fn get_f64_array(&self, key: &str) -> Option<Vec<f64>> {
        self.get(key)?
            .as_arr()?
            .iter()
            .map(|v| v.as_f64())
            .collect::<Option<Vec<f64>>>()
    }

    // -- construction helpers ----------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(values: &[f64]) -> Json {
        Json::Arr(values.iter().map(|&v| Json::Num(v)).collect())
    }

    pub fn arr_str<S: AsRef<str>>(values: &[S]) -> Json {
        Json::Arr(values.iter().map(|v| Json::Str(v.as_ref().to_string())).collect())
    }

    // -- parse ---------------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- write (serialization lives in the `Display` impl) -------------------

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.fract() == 0.0 && v.abs() < 2f64.powi(53) {
                    write!(out, "{}", *v as i64).unwrap();
                } else {
                    write!(out, "{v}").unwrap();
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Compact (no-whitespace) JSON serialization; `Json::to_string()` comes
/// from the blanket `ToString` impl.
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => write!(out, "\\u{:04x}", c as u32).unwrap(),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, message: &str) -> JsonError {
        JsonError { offset: self.pos, message: message.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{lit}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are rare in our data; map
                            // lone surrogates to the replacement char.
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a run of plain UTF-8 bytes.
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let text = r#"{"a":[1,2.5,-3e2],"b":{"c":"x\"y\n","d":true,"e":null}}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_f64(), Some(-300.0));
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str(), Some("x\"y\n"));
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn integers_roundtrip_exactly() {
        let v = Json::parse("68719476735").unwrap(); // 2^36 - 1
        assert_eq!(v.as_u64(), Some((1u64 << 36) - 1));
        assert_eq!(v.to_string(), "68719476735");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn whitespace_tolerated() {
        let v = Json::parse(" { \"a\" : [ 1 , 2 ] } ").unwrap();
        assert_eq!(v.get_f64_array("a").unwrap(), vec![1.0, 2.0]);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn helpers() {
        let o = Json::obj(vec![
            ("xs", Json::arr_f64(&[1.0, 2.0])),
            ("name", Json::Str("t".into())),
        ]);
        assert_eq!(o.get_f64_array("xs").unwrap(), vec![1.0, 2.0]);
        assert!(o.get("missing").is_none());
        assert_eq!(o.get("name").unwrap().as_str(), Some("t"));
    }
}
