//! RAII temporary directories (test substrate).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A uniquely named directory under the system temp dir, removed on drop.
#[derive(Debug)]
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    pub fn new() -> std::io::Result<TempDir> {
        let id = COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "axocs-{}-{}-{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_nanos() as u64)
                .unwrap_or(0),
            id
        ));
        std::fs::create_dir_all(&path)?;
        Ok(TempDir { path })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn join(&self, name: &str) -> PathBuf {
        self.path.join(name)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_and_cleans_up() {
        let p;
        {
            let t = TempDir::new().unwrap();
            p = t.path().to_path_buf();
            std::fs::write(t.join("x.txt"), "hello").unwrap();
            assert!(p.exists());
        }
        assert!(!p.exists());
    }

    #[test]
    fn unique_paths() {
        let a = TempDir::new().unwrap();
        let b = TempDir::new().unwrap();
        assert_ne!(a.path(), b.path());
    }
}
