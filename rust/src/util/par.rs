//! Scoped-thread data parallelism (the rayon substitute).
//!
//! [`parallel_map`] fans a slice out over `std::thread::scope` workers in
//! contiguous chunks and reassembles results in order. Work items must be
//! `Sync` to share and results `Send`; the closure runs on borrowed data so
//! no `'static` bounds leak into callers.
//!
//! [`parallel_map_dynamic`] is the work-stealing variant: workers claim
//! `grain`-sized contiguous chunks off a shared atomic cursor, so uneven
//! per-item cost (multiplier configs vary widely in retained-term count)
//! no longer leaves workers idle behind a straggler's static chunk.
//! Results are reassembled order-stably, so both maps are bit-identical to
//! the serial loop.
//!
//! Nested parallelism policy: a [`parallel_map_dynamic`] call made from
//! inside a *dynamic* pool worker (or a [`serial_scope`]) runs serially
//! inline instead of spawning a second level of threads — the sharded
//! characterization fan-out keeps the machine busy without W² thread
//! explosions, and results are unchanged either way. The static
//! [`parallel_map`] deliberately keeps its original nested-spawn behavior
//! so coarse job fan-outs (e.g. 2 DSE jobs on a 16-core box) still reach
//! full width through their inner maps.

use std::sync::atomic::{AtomicUsize, Ordering};

thread_local! {
    /// Set while the current thread is a dynamic pool worker (or inside
    /// [`serial_scope`]); nested [`parallel_map_dynamic`] calls then run
    /// inline.
    static IN_POOL: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Whether the current thread is already inside a parallel region.
pub fn in_pool() -> bool {
    IN_POOL.with(|c| c.get())
}

/// Run `f` with every nested [`parallel_map_dynamic`] call executing
/// serially inline. Used by dynamic pool workers (automatically) and by
/// benchmarks that need a single-threaded baseline.
pub fn serial_scope<R>(f: impl FnOnce() -> R) -> R {
    IN_POOL.with(|c| {
        let prev = c.replace(true);
        let out = f();
        c.set(prev);
        out
    })
}

/// Worker-pool width: the `REPRO_THREADS` env knob when set to a positive
/// integer, else the machine's available parallelism. Cached after the
/// first read so every `parallel_map` call shares one decision — CI
/// runners pin it low (`REPRO_THREADS=2`) while laptops get every core.
pub fn configured_parallelism() -> usize {
    static CONFIGURED: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *CONFIGURED.get_or_init(|| {
        parse_thread_knob(std::env::var("REPRO_THREADS").ok().as_deref()).unwrap_or_else(
            || std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        )
    })
}

/// `REPRO_THREADS` parsing: positive integers pass through; unset, junk,
/// and zero all mean "auto".
fn parse_thread_knob(value: Option<&str>) -> Option<usize> {
    value.and_then(|s| s.trim().parse::<usize>().ok()).filter(|&n| n > 0)
}

/// Number of workers: configured parallelism, capped by items.
pub fn default_workers(items: usize) -> usize {
    configured_parallelism().min(items).max(1)
}

/// Parallel map preserving order. `f` receives `(index, item)`.
pub fn parallel_map<T: Sync, R: Send>(
    items: &[T],
    f: impl Fn(usize, &T) -> R + Sync,
) -> Vec<R> {
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = default_workers(n);
    if workers == 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let chunk = n.div_ceil(workers);
    let mut results: Vec<Option<Vec<R>>> = (0..workers).map(|_| None).collect();
    std::thread::scope(|scope| {
        let f = &f;
        let mut handles = Vec::new();
        for (w, slot) in results.iter_mut().enumerate() {
            let start = w * chunk;
            if start >= n {
                break;
            }
            let end = (start + chunk).min(n);
            let slice = &items[start..end];
            handles.push(scope.spawn(move || {
                let out: Vec<R> =
                    slice.iter().enumerate().map(|(i, t)| f(start + i, t)).collect();
                (slot, out)
            }));
        }
        for h in handles {
            let (slot, out) = h.join().expect("parallel_map worker panicked");
            *slot = Some(out);
        }
    });
    results.into_iter().flatten().flatten().collect()
}

/// Default grain for [`parallel_map_dynamic`]: roughly four chunks per
/// worker, so the cursor amortizes while stragglers still rebalance.
pub fn default_grain(items: usize) -> usize {
    (items / (configured_parallelism() * 4)).max(1)
}

/// Work-stealing parallel map preserving order. `f` receives
/// `(index, item)`. Workers claim `grain`-sized contiguous chunks off a
/// shared atomic cursor until the slice is drained, so uneven per-item
/// cost rebalances instead of idling workers behind static chunks.
/// Results are bit-identical to [`parallel_map`] and the serial loop.
pub fn parallel_map_dynamic<T: Sync, R: Send>(
    items: &[T],
    grain: usize,
    f: impl Fn(usize, &T) -> R + Sync,
) -> Vec<R> {
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let grain = grain.max(1);
    let workers = default_workers(n.div_ceil(grain));
    if workers == 1 || in_pool() {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let cursor = AtomicUsize::new(0);
    let mut parts: Vec<Vec<(usize, Vec<R>)>> = Vec::with_capacity(workers);
    std::thread::scope(|scope| {
        let f = &f;
        let cursor = &cursor;
        let mut handles = Vec::new();
        for _ in 0..workers {
            handles.push(scope.spawn(move || {
                serial_scope(|| {
                    let mut local: Vec<(usize, Vec<R>)> = Vec::new();
                    loop {
                        let start = cursor.fetch_add(grain, Ordering::Relaxed);
                        if start >= n {
                            break;
                        }
                        let end = (start + grain).min(n);
                        let out: Vec<R> = items[start..end]
                            .iter()
                            .enumerate()
                            .map(|(k, t)| f(start + k, t))
                            .collect();
                        local.push((start, out));
                    }
                    local
                })
            }));
        }
        for h in handles {
            parts.push(h.join().expect("parallel_map_dynamic worker panicked"));
        }
    });
    // Chunks are contiguous and disjoint: sorting by start index restores
    // the exact input order.
    let mut chunks: Vec<(usize, Vec<R>)> = parts.into_iter().flatten().collect();
    chunks.sort_by_key(|&(start, _)| start);
    chunks.into_iter().flat_map(|(_, out)| out).collect()
}

/// Parallel for over mutable chunks of an output buffer: each worker owns
/// `out[chunk]` rows and computes them from the shared context.
pub fn parallel_fill<R: Send, C: Sync>(
    out: &mut [R],
    chunk_size: usize,
    ctx: &C,
    f: impl Fn(&C, usize, &mut [R]) + Sync,
) {
    assert!(chunk_size > 0);
    std::thread::scope(|scope| {
        let f = &f;
        for (ci, chunk) in out.chunks_mut(chunk_size).enumerate() {
            scope.spawn(move || f(ctx, ci * chunk_size, chunk));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_knob_parsing() {
        assert_eq!(parse_thread_knob(None), None);
        assert_eq!(parse_thread_knob(Some("")), None);
        assert_eq!(parse_thread_knob(Some("abc")), None);
        assert_eq!(parse_thread_knob(Some("0")), None);
        assert_eq!(parse_thread_knob(Some("1")), Some(1));
        assert_eq!(parse_thread_knob(Some(" 8 ")), Some(8));
    }

    #[test]
    fn configured_parallelism_is_positive_and_stable() {
        let a = configured_parallelism();
        assert!(a >= 1);
        assert_eq!(a, configured_parallelism()); // cached
    }

    #[test]
    fn map_preserves_order() {
        let xs: Vec<u64> = (0..1000).collect();
        let ys = parallel_map(&xs, |i, &x| x * 2 + i as u64);
        for (i, y) in ys.iter().enumerate() {
            assert_eq!(*y, xs[i] * 2 + i as u64);
        }
    }

    #[test]
    fn map_empty_and_single() {
        let empty: Vec<u32> = vec![];
        assert!(parallel_map(&empty, |_, &x| x).is_empty());
        assert_eq!(parallel_map(&[7u32], |_, &x| x + 1), vec![8]);
    }

    #[test]
    fn fill_covers_all() {
        let mut out = vec![0usize; 103];
        parallel_fill(&mut out, 10, &5usize, |&c, start, chunk| {
            for (k, v) in chunk.iter_mut().enumerate() {
                *v = (start + k) * c;
            }
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * 5);
        }
    }

    #[test]
    fn dynamic_map_preserves_order_for_every_grain() {
        let xs: Vec<u64> = (0..997).collect();
        let want: Vec<u64> =
            xs.iter().enumerate().map(|(i, &x)| x * 3 + i as u64).collect();
        for grain in [1, 2, 7, 64, 997, 5000] {
            let got = parallel_map_dynamic(&xs, grain, |i, &x| x * 3 + i as u64);
            assert_eq!(got, want, "grain {grain}");
        }
    }

    #[test]
    fn dynamic_map_matches_static_on_skewed_work() {
        // Pathological skew: item cost grows quadratically, so the last
        // static chunk dominates; both maps must still agree bit-for-bit
        // with the serial loop.
        let xs: Vec<u64> = (0..257).map(|i| (i % 97) * (i % 89)).collect();
        let cost = |_i: usize, &x: &u64| -> u64 {
            let mut acc = 0u64;
            for k in 0..(x * 8 + 1) {
                acc = acc.wrapping_add(k.wrapping_mul(2654435761));
            }
            acc
        };
        let serial: Vec<u64> = xs.iter().enumerate().map(|(i, x)| cost(i, x)).collect();
        assert_eq!(parallel_map(&xs, cost), serial);
        assert_eq!(parallel_map_dynamic(&xs, 1, cost), serial);
        assert_eq!(parallel_map_dynamic(&xs, 16, cost), serial);
    }

    #[test]
    fn dynamic_map_empty_single_and_zero_grain() {
        let empty: Vec<u32> = vec![];
        assert!(parallel_map_dynamic(&empty, 4, |_, &x| x).is_empty());
        // A zero grain is clamped to 1 rather than spinning forever.
        assert_eq!(parallel_map_dynamic(&[7u32], 0, |_, &x| x + 1), vec![8]);
    }

    #[test]
    fn default_grain_is_positive() {
        assert_eq!(default_grain(0), 1);
        assert!(default_grain(1) >= 1);
        assert!(default_grain(1_000_000) >= 1);
    }

    #[test]
    fn serial_scope_inlines_nested_maps() {
        assert!(!in_pool());
        let out = serial_scope(|| {
            assert!(in_pool());
            // Nested maps run inline on this thread — observable as the
            // flag staying set inside the closure.
            parallel_map_dynamic(&[1u32, 2, 3], 1, |_, &x| {
                assert!(in_pool());
                x * 2
            })
        });
        assert_eq!(out, vec![2, 4, 6]);
        assert!(!in_pool());
    }

    #[test]
    #[should_panic(expected = "boom")] // child payload resumes on the caller
    fn worker_panic_propagates() {
        let xs = vec![1u32; 64];
        let _ = parallel_map(&xs, |i, _| {
            if i == 33 {
                panic!("boom");
            }
            i
        });
    }
}
