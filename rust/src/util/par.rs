//! Scoped-thread data parallelism (the rayon substitute).
//!
//! [`parallel_map`] fans a slice out over `std::thread::scope` workers in
//! contiguous chunks and reassembles results in order. Work items must be
//! `Sync` to share and results `Send`; the closure runs on borrowed data so
//! no `'static` bounds leak into callers.

/// Worker-pool width: the `REPRO_THREADS` env knob when set to a positive
/// integer, else the machine's available parallelism. Cached after the
/// first read so every `parallel_map` call shares one decision — CI
/// runners pin it low (`REPRO_THREADS=2`) while laptops get every core.
pub fn configured_parallelism() -> usize {
    static CONFIGURED: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *CONFIGURED.get_or_init(|| {
        parse_thread_knob(std::env::var("REPRO_THREADS").ok().as_deref()).unwrap_or_else(
            || std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        )
    })
}

/// `REPRO_THREADS` parsing: positive integers pass through; unset, junk,
/// and zero all mean "auto".
fn parse_thread_knob(value: Option<&str>) -> Option<usize> {
    value.and_then(|s| s.trim().parse::<usize>().ok()).filter(|&n| n > 0)
}

/// Number of workers: configured parallelism, capped by items.
pub fn default_workers(items: usize) -> usize {
    configured_parallelism().min(items).max(1)
}

/// Parallel map preserving order. `f` receives `(index, item)`.
pub fn parallel_map<T: Sync, R: Send>(
    items: &[T],
    f: impl Fn(usize, &T) -> R + Sync,
) -> Vec<R> {
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = default_workers(n);
    if workers == 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let chunk = n.div_ceil(workers);
    let mut results: Vec<Option<Vec<R>>> = (0..workers).map(|_| None).collect();
    std::thread::scope(|scope| {
        let f = &f;
        let mut handles = Vec::new();
        for (w, slot) in results.iter_mut().enumerate() {
            let start = w * chunk;
            if start >= n {
                break;
            }
            let end = (start + chunk).min(n);
            let slice = &items[start..end];
            handles.push(scope.spawn(move || {
                let out: Vec<R> =
                    slice.iter().enumerate().map(|(i, t)| f(start + i, t)).collect();
                (slot, out)
            }));
        }
        for h in handles {
            let (slot, out) = h.join().expect("parallel_map worker panicked");
            *slot = Some(out);
        }
    });
    results.into_iter().flatten().flatten().collect()
}

/// Parallel for over mutable chunks of an output buffer: each worker owns
/// `out[chunk]` rows and computes them from the shared context.
pub fn parallel_fill<R: Send, C: Sync>(
    out: &mut [R],
    chunk_size: usize,
    ctx: &C,
    f: impl Fn(&C, usize, &mut [R]) + Sync,
) {
    assert!(chunk_size > 0);
    std::thread::scope(|scope| {
        let f = &f;
        for (ci, chunk) in out.chunks_mut(chunk_size).enumerate() {
            scope.spawn(move || f(ctx, ci * chunk_size, chunk));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_knob_parsing() {
        assert_eq!(parse_thread_knob(None), None);
        assert_eq!(parse_thread_knob(Some("")), None);
        assert_eq!(parse_thread_knob(Some("abc")), None);
        assert_eq!(parse_thread_knob(Some("0")), None);
        assert_eq!(parse_thread_knob(Some("1")), Some(1));
        assert_eq!(parse_thread_knob(Some(" 8 ")), Some(8));
    }

    #[test]
    fn configured_parallelism_is_positive_and_stable() {
        let a = configured_parallelism();
        assert!(a >= 1);
        assert_eq!(a, configured_parallelism()); // cached
    }

    #[test]
    fn map_preserves_order() {
        let xs: Vec<u64> = (0..1000).collect();
        let ys = parallel_map(&xs, |i, &x| x * 2 + i as u64);
        for (i, y) in ys.iter().enumerate() {
            assert_eq!(*y, xs[i] * 2 + i as u64);
        }
    }

    #[test]
    fn map_empty_and_single() {
        let empty: Vec<u32> = vec![];
        assert!(parallel_map(&empty, |_, &x| x).is_empty());
        assert_eq!(parallel_map(&[7u32], |_, &x| x + 1), vec![8]);
    }

    #[test]
    fn fill_covers_all() {
        let mut out = vec![0usize; 103];
        parallel_fill(&mut out, 10, &5usize, |&c, start, chunk| {
            for (k, v) in chunk.iter_mut().enumerate() {
                *v = (start + k) * c;
            }
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * 5);
        }
    }

    #[test]
    #[should_panic(expected = "boom")] // child payload resumes on the caller
    fn worker_panic_propagates() {
        let xs = vec![1u32; 64];
        let _ = parallel_map(&xs, |i, _| {
            if i == 33 {
                panic!("boom");
            }
            i
        });
    }
}
