//! Self-contained infrastructure substrate.
//!
//! This repository builds **fully offline** with zero external
//! dependencies by default (the optional `pjrt` feature adds only the
//! vendored `xla` bindings), so the usual ecosystem crates are
//! re-implemented here at the scale this project needs:
//!
//! * [`json`] — JSON value model, parser and writer (datasets, manifest,
//!   golden fixtures).
//! * [`tomlkit`] — the TOML subset used by `configs/*.toml` experiment
//!   files (tables, scalars, homogeneous arrays).
//! * [`rng`] — seedable splitmix64/xoshiro256** PRNG with the sampling
//!   helpers the GA and forests need (deterministic across platforms).
//! * [`par`] — scoped-thread parallel map over index chunks (the rayon
//!   substitute used by characterization and forest training); pool width
//!   is tunable via the `REPRO_THREADS` env knob.
//! * [`bench`] — the micro-benchmark harness behind `cargo bench`
//!   (criterion substitute: warmup, timed iterations, mean/p50/p99).
//! * [`tempdir`] — RAII temporary directories for tests.

pub mod bench;
pub mod json;
pub mod par;
pub mod rng;
pub mod tempdir;
pub mod tomlkit;
