//! Span recording: a lock-free bounded ring of completed spans plus the
//! Chrome trace-event export.
//!
//! Writers are wait-free: a slot index comes from one `fetch_add` on the
//! head cursor, and the slot's fields are all atomics stamped between two
//! version words (a per-slot seqlock — no `unsafe`, no locks). Readers
//! accept a slot only when both version words agree, so a snapshot taken
//! mid-overwrite drops the torn slot instead of reporting garbage. The
//! ring is deliberately lossy under overflow: tracing must never make the
//! traced system wait, so old spans are overwritten and the count of
//! overwrites is reported instead.

use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};

/// One completed span, as recorded into the ring.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanEvent {
    /// Process-unique span id (never 0 for a recorded span).
    pub id: u64,
    /// Parent span id within the same trace; 0 for roots.
    pub parent: u64,
    /// Trace id shared by every span of one request/job.
    pub trace: u64,
    /// Interned span name — index into [`crate::obs::n::NAMES`].
    pub name: u16,
    /// Small per-process thread id (display only).
    pub tid: u16,
    /// One optional numeric payload (batch fill, shard size, status...).
    pub arg: u32,
    /// Start, nanoseconds since the process monotonic epoch.
    pub start_ns: u64,
    /// Duration in nanoseconds.
    pub dur_ns: u64,
}

/// `name | tid << 16 | arg << 32` — one atomic carries the three small
/// fields so a slot stays at eight words.
fn pack_meta(name: u16, tid: u16, arg: u32) -> u64 {
    (name as u64) | ((tid as u64) << 16) | ((arg as u64) << 32)
}

struct Slot {
    v0: AtomicU64,
    id: AtomicU64,
    parent: AtomicU64,
    trace: AtomicU64,
    start_ns: AtomicU64,
    dur_ns: AtomicU64,
    meta: AtomicU64,
    v1: AtomicU64,
}

impl Slot {
    fn new() -> Slot {
        Slot {
            v0: AtomicU64::new(0),
            id: AtomicU64::new(0),
            parent: AtomicU64::new(0),
            trace: AtomicU64::new(0),
            start_ns: AtomicU64::new(0),
            dur_ns: AtomicU64::new(0),
            meta: AtomicU64::new(0),
            v1: AtomicU64::new(0),
        }
    }
}

/// Lock-free bounded ring of [`SpanEvent`]s (overwrites oldest).
pub struct SpanRing {
    slots: Vec<Slot>,
    head: AtomicU64,
}

impl SpanRing {
    pub fn new(capacity: usize) -> SpanRing {
        let cap = capacity.max(1);
        SpanRing { slots: (0..cap).map(|_| Slot::new()).collect(), head: AtomicU64::new(0) }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Total spans ever recorded (including overwritten ones).
    pub fn recorded(&self) -> u64 {
        self.head.load(Ordering::Relaxed)
    }

    /// Spans lost to overwriting (recorded minus capacity, floored at 0).
    pub fn dropped(&self) -> u64 {
        self.recorded().saturating_sub(self.slots.len() as u64)
    }

    /// Record one completed span (wait-free; overwrites the oldest slot
    /// when full).
    pub fn record(&self, ev: &SpanEvent) {
        let pos = self.head.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(pos % self.slots.len() as u64) as usize];
        let ver = pos + 1; // never 0, distinct per write to this slot
        slot.v0.store(ver, Ordering::Release);
        slot.id.store(ev.id, Ordering::Relaxed);
        slot.parent.store(ev.parent, Ordering::Relaxed);
        slot.trace.store(ev.trace, Ordering::Relaxed);
        slot.start_ns.store(ev.start_ns, Ordering::Relaxed);
        slot.dur_ns.store(ev.dur_ns, Ordering::Relaxed);
        slot.meta.store(pack_meta(ev.name, ev.tid, ev.arg), Ordering::Relaxed);
        slot.v1.store(ver, Ordering::Release);
    }

    /// Best-effort copy of the current contents, oldest first (by start
    /// time). Slots caught mid-overwrite are skipped.
    pub fn snapshot(&self) -> Vec<SpanEvent> {
        let mut out = Vec::with_capacity(self.slots.len());
        for slot in &self.slots {
            let v1 = slot.v1.load(Ordering::Acquire);
            if v1 == 0 {
                continue; // never written
            }
            let meta = slot.meta.load(Ordering::Relaxed);
            let ev = SpanEvent {
                id: slot.id.load(Ordering::Relaxed),
                parent: slot.parent.load(Ordering::Relaxed),
                trace: slot.trace.load(Ordering::Relaxed),
                name: (meta & 0xffff) as u16,
                tid: ((meta >> 16) & 0xffff) as u16,
                arg: (meta >> 32) as u32,
                start_ns: slot.start_ns.load(Ordering::Relaxed),
                dur_ns: slot.dur_ns.load(Ordering::Relaxed),
            };
            if slot.v0.load(Ordering::Acquire) == v1 {
                out.push(ev);
            }
        }
        out.sort_by_key(|e| (e.start_ns, e.id));
        out
    }
}

/// Span id allocator + the ring they land in. One process-global instance
/// lives behind [`crate::obs::tracer`]; tests build their own.
pub struct Tracer {
    ring: SpanRing,
    next_id: AtomicU64,
}

impl Tracer {
    pub fn new(capacity: usize) -> Tracer {
        Tracer { ring: SpanRing::new(capacity), next_id: AtomicU64::new(1) }
    }

    /// Allocate a process-unique id (spans and traces share the space).
    pub fn next_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    pub fn ring(&self) -> &SpanRing {
        &self.ring
    }
}

/// Render completed spans as Chrome trace-event JSON (the `ph: "X"`
/// complete-event form) — loadable in Perfetto / `chrome://tracing`.
/// Ids are hex strings in `args` so 64-bit values survive the f64 JSON
/// number model.
pub fn chrome_trace(events: &[SpanEvent]) -> Json {
    let items = events
        .iter()
        .map(|e| {
            let name = super::name_str(e.name);
            let cat = name.split('.').next().unwrap_or(name);
            Json::obj(vec![
                ("ph", Json::Str("X".into())),
                ("name", Json::Str(name.into())),
                ("cat", Json::Str(cat.into())),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(e.tid as f64)),
                ("ts", Json::Num(e.start_ns as f64 / 1000.0)),
                ("dur", Json::Num(e.dur_ns as f64 / 1000.0)),
                (
                    "args",
                    Json::obj(vec![
                        ("span", Json::Str(format!("{:016x}", e.id))),
                        ("parent", Json::Str(format!("{:016x}", e.parent))),
                        ("trace", Json::Str(format!("{:016x}", e.trace))),
                        ("arg", Json::Num(e.arg as f64)),
                    ]),
                ),
            ])
        })
        .collect();
    Json::obj(vec![
        ("traceEvents", Json::Arr(items)),
        ("displayTimeUnit", Json::Str("ms".into())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(id: u64, start: u64) -> SpanEvent {
        SpanEvent {
            id,
            parent: 0,
            trace: id,
            name: 0,
            tid: 1,
            arg: 7,
            start_ns: start,
            dur_ns: 5,
        }
    }

    #[test]
    fn ring_keeps_the_newest_events_on_wraparound() {
        let ring = SpanRing::new(8);
        for i in 1..=20u64 {
            ring.record(&ev(i, i * 10));
        }
        assert_eq!(ring.recorded(), 20);
        assert_eq!(ring.dropped(), 12);
        let snap = ring.snapshot();
        assert_eq!(snap.len(), 8);
        let ids: Vec<u64> = snap.iter().map(|e| e.id).collect();
        assert_eq!(ids, (13..=20).collect::<Vec<u64>>());
    }

    #[test]
    fn meta_packing_round_trips() {
        let ring = SpanRing::new(2);
        let e = SpanEvent {
            id: 9,
            parent: 3,
            trace: 9,
            name: 300,
            tid: 65_535,
            arg: 4_000_000_000,
            start_ns: 123,
            dur_ns: 456,
        };
        ring.record(&e);
        assert_eq!(ring.snapshot(), vec![e]);
    }

    #[test]
    fn tracer_ids_are_unique_and_nonzero() {
        let t = Tracer::new(4);
        let a = t.next_id();
        let b = t.next_id();
        assert!(a != 0 && b != 0 && a != b);
    }

    #[test]
    fn chrome_trace_is_well_formed_json() {
        let events = [ev(1, 100), ev(2, 200)];
        let text = chrome_trace(&events).to_string();
        let parsed = Json::parse(&text).unwrap();
        let items = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(items.len(), 2);
        for item in items {
            assert_eq!(item.get("ph").and_then(Json::as_str), Some("X"));
            assert!(item.get("ts").and_then(Json::as_f64).is_some());
            assert!(item.get("dur").and_then(Json::as_f64).is_some());
            assert!(item.get("name").and_then(Json::as_str).is_some());
        }
    }
}
