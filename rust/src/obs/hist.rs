//! Log-bucketed atomic latency histogram.
//!
//! Fixed power-of-two bucket edges make every readout deterministic: two
//! histograms that saw the same multiset of values report bit-identical
//! percentiles, and snapshots merge by plain bucket addition (the property
//! `loadgen` and `/metrics` both lean on). Recording is three relaxed
//! `fetch_add`s — safe from any thread, never locked, never allocating —
//! so the hot paths (per HTTP request, per characterization shard, per
//! estimator batch) can record unconditionally.

use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};

/// Bucket count. Bucket 0 holds the value 0; bucket `i >= 1` holds values
/// in `[2^(i-1), 2^i)`; the last bucket additionally absorbs everything
/// larger (2^46 ns ≈ 19.5 hours — nothing we time gets there).
pub const BUCKETS: usize = 48;

/// Inclusive upper edge of bucket `i` (the value every percentile readout
/// reports for ranks landing in that bucket).
pub fn upper_edge(i: usize) -> u64 {
    if i == 0 {
        0
    } else {
        (1u64 << i.min(63)) - 1
    }
}

fn bucket_index(value: u64) -> usize {
    ((64 - value.leading_zeros()) as usize).min(BUCKETS - 1)
}

/// Mergeable log2-bucketed histogram over `u64` values (nanoseconds for
/// the latency instances, raw counts for batch fill).
#[derive(Debug)]
pub struct Histogram {
    counts: [AtomicU64; BUCKETS],
    sum: AtomicU64,
    count: AtomicU64,
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    /// Record one observation (relaxed atomics; never blocks).
    pub fn record(&self, value: u64) {
        self.counts[bucket_index(value)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(value, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    /// A point-in-time copy of the buckets.
    pub fn snapshot(&self) -> HistSnapshot {
        let mut counts = [0u64; BUCKETS];
        for (c, a) in counts.iter_mut().zip(&self.counts) {
            *c = a.load(Ordering::Relaxed);
        }
        HistSnapshot {
            counts,
            sum: self.sum.load(Ordering::Relaxed),
            count: self.count.load(Ordering::Relaxed),
        }
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

/// A point-in-time copy of a [`Histogram`]'s buckets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HistSnapshot {
    pub counts: [u64; BUCKETS],
    pub sum: u64,
    pub count: u64,
}

impl Default for HistSnapshot {
    fn default() -> Self {
        HistSnapshot { counts: [0; BUCKETS], sum: 0, count: 0 }
    }
}

impl HistSnapshot {
    /// Bucket-wise sum — merging N per-source snapshots reports exactly
    /// what one histogram fed all sources would have.
    pub fn merged(&self, other: &HistSnapshot) -> HistSnapshot {
        let mut counts = [0u64; BUCKETS];
        for (c, (a, b)) in counts.iter_mut().zip(self.counts.iter().zip(&other.counts)) {
            *c = a + b;
        }
        HistSnapshot {
            counts,
            sum: self.sum + other.sum,
            count: self.count + other.count,
        }
    }

    /// Deterministic percentile: the inclusive upper edge of the bucket
    /// the rank `ceil(count * p / 100)` lands in (0 when empty). Fixed
    /// edges mean the readout depends only on the observed multiset,
    /// never on arrival order or merge grouping.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((self.count as f64 * p / 100.0).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return upper_edge(i);
            }
        }
        upper_edge(BUCKETS - 1)
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Quantile summary in milliseconds (values recorded as nanoseconds)
    /// — the `/metrics` JSON `latency` shape.
    pub fn to_json_ms(&self) -> Json {
        Json::obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("p50_ms", Json::Num(self.percentile(50.0) as f64 / 1e6)),
            ("p90_ms", Json::Num(self.percentile(90.0) as f64 / 1e6)),
            ("p99_ms", Json::Num(self.percentile(99.0) as f64 / 1e6)),
            ("mean_ms", Json::Num(self.mean() / 1e6)),
        ])
    }

    /// Quantile summary in the recorded unit (for raw-count histograms
    /// like estimator batch fill).
    pub fn to_json_raw(&self) -> Json {
        Json::obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("p50", Json::Num(self.percentile(50.0) as f64)),
            ("p90", Json::Num(self.percentile(90.0) as f64)),
            ("p99", Json::Num(self.percentile(99.0) as f64)),
            ("mean", Json::Num(self.mean())),
        ])
    }

    /// The full bucket layout as JSON (`BENCH_http.json` stamps this so
    /// the bench artifact carries the whole distribution, not two
    /// points): parallel `upper_ns` / `counts` arrays, empty tail
    /// buckets trimmed.
    pub fn to_json_buckets(&self) -> Json {
        let last = self.counts.iter().rposition(|&c| c != 0).map_or(0, |i| i + 1);
        let edges: Vec<f64> = (0..last).map(|i| upper_edge(i) as f64).collect();
        let counts: Vec<f64> = self.counts[..last].iter().map(|&c| c as f64).collect();
        Json::obj(vec![
            ("upper_ns", Json::arr_f64(&edges)),
            ("counts", Json::arr_f64(&counts)),
            ("sum_ns", Json::Num(self.sum as f64)),
            ("count", Json::Num(self.count as f64)),
        ])
    }
}

/// Percentile of an already-sorted sample vector by the floor-index rule
/// the bench harness has always used (`sorted[floor(n*p/100)]`, clamped).
/// Shared so `util::bench` and ad-hoc callers agree on one definition.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 * p / 100.0) as usize).min(sorted.len() - 1);
    sorted[idx]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_edges_partition_the_line() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
        // Every bucket's values are <= its upper edge, > the previous one's.
        for i in 1..BUCKETS - 1 {
            assert_eq!(bucket_index(upper_edge(i)), i);
            assert_eq!(bucket_index(upper_edge(i) + 1), i + 1);
        }
    }

    #[test]
    fn percentiles_are_deterministic_and_merge_invariant() {
        let a = Histogram::new();
        let b = Histogram::new();
        let all = Histogram::new();
        for v in [3u64, 17, 90, 1500, 1501, 80_000, 1_000_000] {
            a.record(v);
            all.record(v);
        }
        for v in [5u64, 40, 4096, 70_000] {
            b.record(v);
            all.record(v);
        }
        let merged = a.snapshot().merged(&b.snapshot());
        let whole = all.snapshot();
        assert_eq!(merged, whole);
        for p in [0.0, 10.0, 50.0, 90.0, 99.0, 100.0] {
            assert_eq!(merged.percentile(p), whole.percentile(p), "p{p}");
        }
        // p50 of 11 values: rank 6 -> 1500 -> bucket upper edge 2047.
        assert_eq!(whole.percentile(50.0), 2047);
        assert_eq!(whole.count, 11);
        assert_eq!(whole.sum, 1_157_252);
    }

    #[test]
    fn empty_histogram_reads_zero() {
        let s = Histogram::new().snapshot();
        assert_eq!(s.percentile(50.0), 0);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.to_json_ms().get("count").and_then(Json::as_u64), Some(0));
        let b = s.to_json_buckets();
        assert_eq!(b.get("counts").unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn percentile_sorted_matches_legacy_bench_rule() {
        let s: Vec<f64> = (0..100).map(|i| i as f64).collect();
        assert_eq!(percentile_sorted(&s, 50.0), 50.0);
        assert_eq!(percentile_sorted(&s, 99.0), 99.0);
        let odd: Vec<f64> = (0..7).map(|i| i as f64).collect();
        assert_eq!(percentile_sorted(&odd, 50.0), odd[7 / 2]);
        assert_eq!(percentile_sorted(&odd, 99.0), odd[(7 * 99 / 100).min(6)]);
        assert_eq!(percentile_sorted(&[], 50.0), 0.0);
    }
}
