//! Unified observability: spans, latency histograms, Prometheus text.
//!
//! Three pillars, all std-only and shared by every layer:
//!
//! * [`trace`] — a hand-rolled tracer: process-unique span/trace ids with
//!   parent links and monotonic timestamps, recorded into a lock-free
//!   bounded [`SpanRing`], exported as Chrome trace-event JSON
//!   (`repro trace export`, `GET /trace` — Perfetto-loadable).
//! * [`hist`] — mergeable log-bucketed atomic [`Histogram`]s with fixed
//!   bucket edges, so percentile readouts are deterministic and
//!   `loadgen`, `/metrics`, and the Prometheus exposition all agree.
//! * [`prom`] — `GET /metrics?format=prometheus` text rendering.
//!
//! Tracing is **zero-cost when disabled**: [`span`] checks one relaxed
//! atomic load and returns an inert guard. The gate resolves as
//! `REPRO_TRACE` env > `[obs] trace` TOML > off (see [`apply`]).
//! Histogram recording is unconditional — three relaxed `fetch_add`s on
//! coarse-grained paths (per request, per shard, per batch).
//!
//! Span parentage crosses threads by value: capture [`Span::ctx`] (or
//! [`current`]) on the submitting thread, open children with
//! [`span_under`] on the worker.

pub mod hist;
pub mod prom;
pub mod trace;

pub use hist::{percentile_sorted, HistSnapshot, Histogram, BUCKETS};
pub use trace::{chrome_trace, SpanEvent, SpanRing, Tracer};

use crate::expcfg::ObsConfig;
use crate::util::json::Json;
use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Interned span names: spans carry a `u16` index instead of a string so
/// ring slots stay fixed-width atomics. The category shown in trace
/// viewers is the prefix before the `.`.
pub mod n {
    pub const HTTP_REQUEST: u16 = 0;
    pub const HTTP_HANDLE: u16 = 1;
    pub const JOB_SUBMIT: u16 = 2;
    pub const JOB_CLAIM: u16 = 3;
    pub const JOB_EXECUTE: u16 = 4;
    pub const JOB_COMPLETE: u16 = 5;
    pub const ENGINE_CHARACTERIZE: u16 = 6;
    pub const CHARAC_BEHAV: u16 = 7;
    pub const CHARAC_PPA: u16 = 8;
    pub const ESTIMATOR_PREDICT: u16 = 9;
    pub const ESTIMATOR_BATCH: u16 = 10;
    pub const NAMES: &[&str] = &[
        "http.request",
        "http.handle",
        "job.submit",
        "job.claim",
        "job.execute",
        "job.complete",
        "engine.characterize",
        "charac.behav",
        "charac.ppa",
        "estimator.predict",
        "estimator.batch",
    ];
}

/// The interned name's string form (`"unknown"` past the table).
pub fn name_str(id: u16) -> &'static str {
    n::NAMES.get(id as usize).copied().unwrap_or("unknown")
}

/// Ring capacity when no `[obs] trace_buffer` was configured before the
/// first span.
pub const DEFAULT_TRACE_BUFFER: usize = 16_384;

static TRACE_ON: AtomicBool = AtomicBool::new(false);
static TRACER: OnceLock<Tracer> = OnceLock::new();
static EPOCH: OnceLock<Instant> = OnceLock::new();
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static CURRENT: Cell<(u64, u64)> = const { Cell::new((0, 0)) };
    static TID: Cell<u16> = const { Cell::new(0) };
}

/// The tracing gate — one relaxed atomic load, the entire cost of every
/// instrumentation point while tracing is off.
#[inline]
pub fn trace_enabled() -> bool {
    TRACE_ON.load(Ordering::Relaxed)
}

/// The process-global tracer (sized by the first of [`apply`] or first
/// use).
pub fn tracer() -> &'static Tracer {
    TRACER.get_or_init(|| Tracer::new(DEFAULT_TRACE_BUFFER))
}

/// Nanoseconds since the process-wide monotonic epoch (first call).
pub fn monotonic_ns() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

fn thread_tid() -> u16 {
    TID.with(|t| {
        let v = t.get();
        if v != 0 {
            return v;
        }
        let fresh = (NEXT_TID.fetch_add(1, Ordering::Relaxed) & 0xffff).max(1) as u16;
        t.set(fresh);
        fresh
    })
}

/// Resolve the tracing gate — `REPRO_TRACE` env (`0`/`false`/`off`/empty
/// disable, anything else enables) over `[obs] trace` — and size the
/// span ring from `[obs] trace_buffer`. Called from config load; the
/// ring is sized by whichever call initializes it first.
pub fn apply(cfg: &ObsConfig) {
    TRACER.get_or_init(|| Tracer::new(cfg.trace_buffer));
    TRACE_ON.store(env_trace().unwrap_or(cfg.trace), Ordering::Relaxed);
}

/// Turn tracing on unconditionally (`loadgen --trace-out`, tests).
pub fn force_enable() {
    tracer();
    TRACE_ON.store(true, Ordering::Relaxed);
}

fn env_trace() -> Option<bool> {
    let v = std::env::var("REPRO_TRACE").ok()?;
    let s = v.trim();
    let off = s.is_empty()
        || s == "0"
        || s.eq_ignore_ascii_case("false")
        || s.eq_ignore_ascii_case("off");
    Some(!off)
}

/// Chrome trace-event JSON of everything currently in the ring.
pub fn export_chrome() -> Json {
    chrome_trace(&tracer().ring().snapshot())
}

/// A (trace, span) pair that parents cross-thread children — `Copy`, so
/// it moves into worker closures by value.
#[derive(Debug, Clone, Copy, Default)]
pub struct SpanCtx {
    trace: u64,
    span: u64,
}

/// The calling thread's innermost open span (zeroes when none).
pub fn current() -> SpanCtx {
    let (trace, span) = CURRENT.with(Cell::get);
    SpanCtx { trace, span }
}

/// RAII span guard: opened by [`span`]/[`span_under`], records one
/// completed [`SpanEvent`] on drop. Inert (and free beyond the gate
/// check) while tracing is disabled.
pub struct Span {
    inner: Option<SpanInner>,
}

struct SpanInner {
    id: u64,
    parent: u64,
    trace: u64,
    name: u16,
    arg: u32,
    start_ns: u64,
    prev: (u64, u64),
}

/// Open a span parented under the calling thread's current span (a new
/// root trace when there is none).
pub fn span(name: u16) -> Span {
    if !trace_enabled() {
        return Span { inner: None };
    }
    open_span(CURRENT.with(Cell::get), name)
}

/// Open a span under an explicit parent context — the cross-thread
/// handoff (capture [`current`]/[`Span::ctx`] on the submitting side).
pub fn span_under(parent: SpanCtx, name: u16) -> Span {
    if !trace_enabled() {
        return Span { inner: None };
    }
    open_span((parent.trace, parent.span), name)
}

fn open_span(parent: (u64, u64), name: u16) -> Span {
    let t = tracer();
    let id = t.next_id();
    let trace = if parent.0 != 0 { parent.0 } else { t.next_id() };
    let prev = CURRENT.with(|c| c.replace((trace, id)));
    Span {
        inner: Some(SpanInner {
            id,
            parent: parent.1,
            trace,
            name,
            arg: 0,
            start_ns: monotonic_ns(),
            prev,
        }),
    }
}

impl Span {
    /// Attach one numeric payload (batch fill, shard size, HTTP status).
    pub fn set_arg(&mut self, v: u64) {
        if let Some(inner) = &mut self.inner {
            inner.arg = v.min(u32::MAX as u64) as u32;
        }
    }

    /// This span's handoff context for [`span_under`] on worker threads.
    pub fn ctx(&self) -> SpanCtx {
        match &self.inner {
            Some(i) => SpanCtx { trace: i.trace, span: i.id },
            None => SpanCtx::default(),
        }
    }

    /// Close without recording — for speculative spans whose operation
    /// turned out to be a no-op (an empty claim poll, say), which would
    /// otherwise flood the ring.
    pub fn cancel(mut self) {
        if let Some(i) = self.inner.take() {
            CURRENT.with(|c| c.set(i.prev));
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(i) = self.inner.take() else { return };
        CURRENT.with(|c| c.set(i.prev));
        let end = monotonic_ns();
        tracer().ring().record(&SpanEvent {
            id: i.id,
            parent: i.parent,
            trace: i.trace,
            name: i.name,
            tid: thread_tid(),
            arg: i.arg,
            start_ns: i.start_ns,
            dur_ns: end.saturating_sub(i.start_ns),
        });
    }
}

/// Process-global histograms recorded from free functions deep in the
/// pipeline (characterization shards, the estimator batcher), where no
/// per-server instance is in scope.
pub struct GlobalMetrics {
    /// Per-shard BEHAV phase time, nanoseconds.
    pub behav_shard_ns: Histogram,
    /// Per-shard PPA phase time, nanoseconds.
    pub ppa_shard_ns: Histogram,
    /// Estimator batch fill — configurations per backend call.
    pub batch_fill: Histogram,
    /// Estimator backend call latency, nanoseconds.
    pub batch_ns: Histogram,
}

static METRICS: OnceLock<GlobalMetrics> = OnceLock::new();

pub fn metrics() -> &'static GlobalMetrics {
    METRICS.get_or_init(|| GlobalMetrics {
        behav_shard_ns: Histogram::new(),
        ppa_shard_ns: Histogram::new(),
        batch_fill: Histogram::new(),
        batch_ns: Histogram::new(),
    })
}

/// Route labels of the per-route HTTP latency histograms — a fixed set,
/// so the Prometheus families are stable across scrapes.
pub const HTTP_ROUTES: &[&str] = &[
    "jobs_submit",
    "job_status",
    "job_result",
    "job_timeline",
    "healthz",
    "metrics",
    "trace",
    "other",
];

/// Per-server-instance histograms: HTTP request latency by route plus the
/// job lifecycle split (queue wait vs execute). Owned by the HTTP
/// front-end and shared with its embedded runner, so tests with several
/// servers in one process read isolated numbers.
pub struct ServeObs {
    routes: Vec<(&'static str, Histogram)>,
    /// Submit → claim, nanoseconds.
    pub queue_wait_ns: Histogram,
    /// Claim → done, nanoseconds.
    pub execute_ns: Histogram,
}

impl ServeObs {
    pub fn new() -> ServeObs {
        ServeObs {
            routes: HTTP_ROUTES.iter().map(|r| (*r, Histogram::new())).collect(),
            queue_wait_ns: Histogram::new(),
            execute_ns: Histogram::new(),
        }
    }

    /// Record one request's latency under its route label (unknown
    /// labels land in `other`).
    pub fn record_route(&self, route: &str, ns: u64) {
        let hit = self
            .routes
            .iter()
            .find(|(r, _)| *r == route)
            .or_else(|| self.routes.iter().find(|(r, _)| *r == "other"));
        if let Some((_, h)) = hit {
            h.record(ns);
        }
    }

    pub fn route_snapshots(&self) -> Vec<(&'static str, HistSnapshot)> {
        self.routes.iter().map(|(r, h)| (*r, h.snapshot())).collect()
    }
}

impl Default for ServeObs {
    fn default() -> Self {
        ServeObs::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn name_table_is_dense_and_bounded() {
        assert_eq!(n::NAMES.len(), n::ESTIMATOR_BATCH as usize + 1);
        assert_eq!(name_str(n::HTTP_REQUEST), "http.request");
        assert_eq!(name_str(u16::MAX), "unknown");
    }

    #[test]
    fn serve_obs_buckets_unknown_routes_as_other() {
        let obs = ServeObs::new();
        obs.record_route("healthz", 100);
        obs.record_route("no-such-route", 200);
        let snaps = obs.route_snapshots();
        let count = |label: &str| {
            snaps.iter().find(|(r, _)| *r == label).map(|(_, s)| s.count).unwrap()
        };
        assert_eq!(count("healthz"), 1);
        assert_eq!(count("other"), 1);
        assert_eq!(count("metrics"), 0);
    }

    #[test]
    fn monotonic_clock_advances() {
        let a = monotonic_ns();
        let b = monotonic_ns();
        assert!(b >= a);
    }

    #[test]
    fn span_ctx_of_inert_span_is_zero() {
        // Regardless of the global gate, an inert guard hands out the
        // zero context and set_arg is a no-op.
        let mut s = Span { inner: None };
        s.set_arg(9);
        let ctx = s.ctx();
        assert_eq!(ctx.trace, 0);
        assert_eq!(ctx.span, 0);
    }
}
