//! Prometheus text exposition (format version 0.0.4).
//!
//! A tiny append-only renderer — the JSON/TOML idiom applied to the
//! exposition format: emit exactly the lines standard scrapers need
//! (`# TYPE` once per metric family, cumulative `le` buckets ending in
//! `+Inf`, `_sum`/`_count`) and nothing else. Output is deterministic
//! for deterministic inputs, which is what lets the integration suite
//! assert exact counter and bucket lines.

use super::hist::{upper_edge, HistSnapshot, BUCKETS};
use std::collections::BTreeSet;
use std::fmt::Write as _;

/// The scrape response content type.
pub const CONTENT_TYPE: &str = "text/plain; version=0.0.4; charset=utf-8";

/// Accumulates one exposition document.
pub struct PromText {
    out: String,
    typed: BTreeSet<String>,
}

fn fmt_labels(labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let inner: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}=\"{v}\"")).collect();
    format!("{{{}}}", inner.join(","))
}

impl PromText {
    pub fn new() -> PromText {
        PromText { out: String::new(), typed: BTreeSet::new() }
    }

    fn type_line(&mut self, name: &str, kind: &str) {
        if self.typed.insert(name.to_string()) {
            writeln!(self.out, "# TYPE {name} {kind}").unwrap();
        }
    }

    pub fn counter(&mut self, name: &str, labels: &[(&str, &str)], value: u64) {
        self.type_line(name, "counter");
        writeln!(self.out, "{name}{} {value}", fmt_labels(labels)).unwrap();
    }

    pub fn gauge(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.type_line(name, "gauge");
        writeln!(self.out, "{name}{} {value}", fmt_labels(labels)).unwrap();
    }

    /// Emit one histogram family member: cumulative buckets (`le` in the
    /// recorded unit scaled by `scale` — `1e-9` turns nanoseconds into
    /// seconds), then `_sum` and `_count`.
    pub fn histogram(
        &mut self,
        name: &str,
        labels: &[(&str, &str)],
        snap: &HistSnapshot,
        scale: f64,
    ) {
        self.type_line(name, "histogram");
        let base: String = labels.iter().map(|(k, v)| format!("{k}=\"{v}\",")).collect();
        let mut cum = 0u64;
        for (i, c) in snap.counts.iter().enumerate().take(BUCKETS - 1) {
            cum += c;
            let le = upper_edge(i) as f64 * scale;
            writeln!(self.out, "{name}_bucket{{{base}le=\"{le}\"}} {cum}").unwrap();
        }
        cum += snap.counts[BUCKETS - 1];
        writeln!(self.out, "{name}_bucket{{{base}le=\"+Inf\"}} {cum}").unwrap();
        let labels = fmt_labels(labels);
        writeln!(self.out, "{name}_sum{labels} {}", snap.sum as f64 * scale).unwrap();
        writeln!(self.out, "{name}_count{labels} {}", snap.count).unwrap();
    }

    pub fn finish(self) -> String {
        self.out
    }
}

impl Default for PromText {
    fn default() -> Self {
        PromText::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Histogram;

    #[test]
    fn counters_and_gauges_render_with_one_type_line() {
        let mut p = PromText::new();
        p.counter("jobs_total", &[("state", "done")], 3);
        p.counter("jobs_total", &[("state", "failed")], 0);
        p.gauge("uptime_seconds", &[], 1.5);
        let text = p.finish();
        assert_eq!(text.matches("# TYPE jobs_total counter").count(), 1);
        assert!(text.contains("jobs_total{state=\"done\"} 3\n"));
        assert!(text.contains("jobs_total{state=\"failed\"} 0\n"));
        assert!(text.contains("# TYPE uptime_seconds gauge\nuptime_seconds 1.5\n"));
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_end_at_inf() {
        let h = Histogram::new();
        for v in [1u64, 3, 3, 1_000_000] {
            h.record(v);
        }
        let mut p = PromText::new();
        p.histogram("req_seconds", &[("route", "healthz")], &h.snapshot(), 1e-9);
        let text = p.finish();
        let buckets: Vec<u64> = text
            .lines()
            .filter(|l| l.starts_with("req_seconds_bucket"))
            .map(|l| l.rsplit(' ').next().unwrap().parse().unwrap())
            .collect();
        assert_eq!(buckets.len(), BUCKETS);
        assert!(buckets.windows(2).all(|w| w[0] <= w[1]), "monotone: {buckets:?}");
        assert_eq!(*buckets.last().unwrap(), 4);
        assert!(text.contains("req_seconds_bucket{route=\"healthz\",le=\"+Inf\"} 4\n"));
        assert!(text.contains("req_seconds_count{route=\"healthz\"} 4\n"));
        assert!(text.contains("# TYPE req_seconds histogram\n"));
    }
}
