//! [`HttpServer`] — the std-only HTTP/1.1 front-end over the job spool.
//!
//! A hand-rolled `TcpListener` server (no hyper, no tokio — the repo
//! links nothing outside std) that parses *just enough* HTTP to run a job
//! API: the request line, `Content-Length`, `Connection`, and a hard
//! rejection of chunked transfer encoding. Connections are **keep-alive**
//! by default: each acceptor serves a per-connection request loop until
//! the client closes, sends `Connection: close`, goes idle past
//! [`KEEPALIVE_IDLE`], or triggers an error response (errors always
//! close — a client that sent garbage gets no second chance to desync
//! the framing). [`HttpClient`] is the matching persistent client;
//! [`http_call`] stays the one-shot `Connection: close` path.
//!
//! Routes:
//!
//! | route                  | behavior                                        |
//! |------------------------|-------------------------------------------------|
//! | `POST /jobs`           | spec JSON → dedup → spool; `201`/`200`/`400`/`429` |
//! | `GET /jobs/<id>`       | lifecycle state, `404` when unknown             |
//! | `GET /jobs/<id>/result`| `done/` bytes verbatim; `202` in flight, `500` failed |
//! | `GET /jobs/<id>/timeline` | lifecycle stamps + queue-wait/execute durations |
//! | `GET /healthz`         | liveness probe                                  |
//! | `GET /metrics`         | queue depths, counters, latency histograms — JSON, or Prometheus text via `?format=prometheus` / `Accept: text/plain` |
//! | `GET /trace`           | the span ring as Chrome trace-event JSON (Perfetto-loadable) |
//!
//! Two properties make the front-end safe under real traffic:
//!
//! * **Dedup** ([`dedup`](super::dedup)): submitted specs are renamed to
//!   their canonical-hash id, so identical concurrent requests collapse
//!   into one spooled job with many waiters — the first submitter gets
//!   `201 Created`, everyone else `200 OK` with the shared id. Client ids
//!   are rejected (`400`): job identity is content-addressed.
//! * **Backpressure**: once `pending/` reaches the configured high-water
//!   mark, *new* work is refused with `429` + `Retry-After`. Dedup is
//!   checked first, so duplicates of in-flight jobs still answer `200`
//!   under full load — a hit costs no queue space.
//!
//! And two that make it safe under failure:
//!
//! * **Graceful drain**: SIGTERM/SIGINT (see [`signal`](super::signal))
//!   stops the exec loop claiming new jobs, finishes in-flight work,
//!   retires the acceptors, and exits 0 — `/healthz` answers
//!   `"draining"` so load balancers route elsewhere first.
//! * **ENOSPC load-shedding**: a full spool disk answers `POST /jobs`
//!   with `503` + `Retry-After` and pauses the exec loop instead of
//!   crashing it; the flag clears on the first write that succeeds.
//!
//! With `workers > 0` the server also embeds an exec loop: a resident
//! [`JobRunner`] drains the spool in bounded bursts between shutdown
//! checks, sharing the engine's caches with every burst. `workers = 0`
//! runs a pure front-end against a spool drained by separate
//! `repro serve-dse` processes (the queue is multi-process-safe).

use super::dedup::{admit, canonical_hash, hash_id, Admission};
use super::eventlog::{EventLog, DEFAULT_LOG_MAX_BYTES};
use super::queue::{stamp_gap_ns, JobQueue, JobState};
use super::runner::{gc_event_fields, JobRunner, ServeOptions, StoreGc, LOG_FILE};
use super::spec::JobSpec;
use crate::engine::EngineContext;
use crate::error::{Error, Result};
use crate::obs::{self, prom::PromText, ServeObs};
use crate::util::json::Json;
use std::io::{Read as _, Write as _};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Largest accepted request head (request line + headers).
const MAX_HEAD_BYTES: usize = 8 * 1024;

/// Per-connection socket timeout: a stalled client must not pin an
/// acceptor thread forever.
const IO_TIMEOUT: Duration = Duration::from_secs(10);

/// How long a keep-alive connection may sit idle between requests before
/// the server closes it. Shorter than [`IO_TIMEOUT`]: waiting for a
/// request that may never come should release the acceptor sooner than a
/// read that is mid-request.
const KEEPALIVE_IDLE: Duration = Duration::from_secs(5);

/// HTTP front-end knobs (the `[http]` config section layered with the
/// serve-mode worker settings).
#[derive(Debug, Clone)]
pub struct HttpOptions {
    /// Concurrent acceptor threads (each serves one connection at a time).
    pub threads: usize,
    /// Embedded exec-loop workers; `0` = front-end only (no engine work
    /// in this process).
    pub workers: usize,
    /// Refuse new `POST /jobs` with `429` once `pending/` holds this many.
    pub high_water: usize,
    /// The `Retry-After` hint sent with a `429`, seconds.
    pub retry_after_secs: u64,
    /// Largest accepted request body, bytes.
    pub max_body_bytes: usize,
    /// Exec-loop idle poll interval.
    pub poll: Duration,
    /// Rotate `server.log.jsonl` to `.1` past this many bytes.
    pub log_max_bytes: u64,
}

impl Default for HttpOptions {
    fn default() -> Self {
        let http = crate::expcfg::HttpConfig::default();
        HttpOptions {
            threads: http.threads,
            workers: 2,
            high_water: http.high_water,
            retry_after_secs: http.retry_after_secs,
            max_body_bytes: http.max_body_bytes,
            poll: Duration::from_millis(200),
            log_max_bytes: DEFAULT_LOG_MAX_BYTES,
        }
    }
}

/// Lock-free front-end counters (the `http` object in `/metrics`).
#[derive(Debug, Default)]
struct HttpStats {
    requests: AtomicU64,
    created: AtomicU64,
    shared: AtomicU64,
    rejected: AtomicU64,
    bad_requests: AtomicU64,
    /// Submissions refused with `503` because the spool disk was full.
    shed: AtomicU64,
}

impl HttpStats {
    fn to_json(&self) -> Json {
        let created = self.created.load(Ordering::Relaxed);
        let shared = self.shared.load(Ordering::Relaxed);
        let admitted = created + shared;
        let hit_rate =
            if admitted == 0 { 0.0 } else { shared as f64 / admitted as f64 };
        Json::obj(vec![
            ("requests", Json::Num(self.requests.load(Ordering::Relaxed) as f64)),
            ("created", Json::Num(created as f64)),
            ("shared", Json::Num(shared as f64)),
            ("dedup_hit_rate", Json::Num(hit_rate)),
            ("rejected", Json::Num(self.rejected.load(Ordering::Relaxed) as f64)),
            (
                "bad_requests",
                Json::Num(self.bad_requests.load(Ordering::Relaxed) as f64),
            ),
            ("shed", Json::Num(self.shed.load(Ordering::Relaxed) as f64)),
        ])
    }
}

/// The bound front-end (see module docs). [`HttpServer::run`] blocks;
/// share the server in an [`Arc`] and call [`HttpServer::shutdown`] from
/// another thread (or a signal handler) to stop it.
pub struct HttpServer {
    ctx: Arc<EngineContext>,
    queue: Arc<JobQueue>,
    opts: HttpOptions,
    listener: TcpListener,
    local_addr: SocketAddr,
    started: Instant,
    stop: AtomicBool,
    /// The spool disk hit `ENOSPC`: shed new submissions with `503` and
    /// pause the exec loop; cleared by the next successful spool write.
    storage_full: AtomicBool,
    active_acceptors: AtomicUsize,
    stats: HttpStats,
    log: Arc<EventLog>,
    obs: Arc<ServeObs>,
}

impl HttpServer {
    /// Bind `addr` (port 0 = OS-assigned; read it back via
    /// [`HttpServer::local_addr`]).
    pub fn bind(
        ctx: Arc<EngineContext>,
        queue: Arc<JobQueue>,
        addr: &str,
        opts: HttpOptions,
    ) -> Result<HttpServer> {
        let listener = TcpListener::bind(addr).map_err(|e| {
            Error::Coordinator(format!("cannot bind http listener on {addr}: {e}"))
        })?;
        let local_addr = listener.local_addr()?;
        let log = Arc::new(EventLog::open(
            queue.dir().join(LOG_FILE),
            opts.log_max_bytes,
        )?);
        Ok(HttpServer {
            ctx,
            queue,
            opts,
            listener,
            local_addr,
            started: Instant::now(),
            stop: AtomicBool::new(false),
            storage_full: AtomicBool::new(false),
            active_acceptors: AtomicUsize::new(0),
            stats: HttpStats::default(),
            log,
            obs: Arc::new(ServeObs::new()),
        })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Serve until [`HttpServer::shutdown`]: `threads` acceptor loops,
    /// plus the embedded exec loop when `workers > 0`. Returns once every
    /// loop has retired.
    pub fn run(&self) -> Result<()> {
        self.log_event(
            "http-start",
            &[
                ("addr", Json::Str(self.local_addr.to_string())),
                ("threads", Json::Num(self.opts.threads.max(1) as f64)),
                ("workers", Json::Num(self.opts.workers as f64)),
            ],
        );
        std::thread::scope(|s| {
            for _ in 0..self.opts.threads.max(1) {
                let listener = self.listener.try_clone();
                s.spawn(move || match listener {
                    Ok(l) => self.accept_loop(&l),
                    Err(e) => eprintln!("warning: acceptor clone failed: {e}"),
                });
            }
            if self.opts.workers > 0 {
                s.spawn(|| self.exec_loop());
            }
            // Drain watcher: turns SIGTERM/SIGINT into an orderly
            // shutdown — the exec loop stops claiming (its workers check
            // the drain flag before every claim), in-flight jobs finish,
            // and the acceptors are woken to retire. Exits on its own
            // when `shutdown` is called directly.
            s.spawn(|| {
                while !self.stopping() {
                    if super::signal::draining() {
                        self.log_event("http-drain", &[]);
                        self.shutdown();
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(50));
                }
            });
        });
        self.log_event("http-stop", &[]);
        Ok(())
    }

    /// Ask every loop to stop, then wake blocked acceptors by connecting
    /// to our own listener until they have all retired. Safe to call more
    /// than once and from any thread.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        while self.active_acceptors.load(Ordering::SeqCst) > 0 {
            // Each wake-up connection unblocks at most one accept().
            let _ = TcpStream::connect_timeout(
                &self.local_addr,
                Duration::from_millis(100),
            );
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    fn stopping(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    /// One acceptor: blocking `accept()`, then a keep-alive request loop
    /// on the accepted connection. The stop flag is checked after every
    /// accept — [`HttpServer::shutdown`] wakes us with throwaway
    /// connections.
    fn accept_loop(&self, listener: &TcpListener) {
        self.active_acceptors.fetch_add(1, Ordering::SeqCst);
        loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    if self.stopping() {
                        break; // a shutdown wake-up, not a client
                    }
                    self.serve_connection(&stream);
                    let _ = stream.shutdown(std::net::Shutdown::Both);
                }
                Err(_) => {
                    if self.stopping() {
                        break;
                    }
                    // Transient accept fault (e.g. EMFILE); back off.
                    std::thread::sleep(Duration::from_millis(20));
                }
            }
        }
        self.active_acceptors.fetch_sub(1, Ordering::SeqCst);
    }

    /// The per-connection request loop: serve until the client closes or
    /// goes idle (a quiet break — no response, no `bad_requests` count),
    /// asks for `Connection: close`, desyncs the protocol (errors always
    /// close), or the server is stopping. Each served request — good or
    /// bad — counts toward `http.requests`.
    fn serve_connection(&self, mut stream: &TcpStream) {
        let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
        loop {
            let _ = stream.set_read_timeout(Some(KEEPALIVE_IDLE));
            match read_request(&mut stream, self.opts.max_body_bytes) {
                ReadOutcome::Idle => break,
                ReadOutcome::Bad(mut response) => {
                    self.stats.requests.fetch_add(1, Ordering::Relaxed);
                    self.stats.bad_requests.fetch_add(1, Ordering::Relaxed);
                    response.close = true;
                    let _ = response.write_to(stream);
                    break;
                }
                ReadOutcome::Request(request) => {
                    self.stats.requests.fetch_add(1, Ordering::Relaxed);
                    // The request span opens only once a request has been
                    // read — keep-alive idle waits are not request work.
                    let mut span = obs::span(obs::n::HTTP_REQUEST);
                    let started = Instant::now();
                    let mut response = {
                        let _handle = obs::span(obs::n::HTTP_HANDLE);
                        self.route(&request)
                    };
                    if response.status == 400 {
                        self.stats.bad_requests.fetch_add(1, Ordering::Relaxed);
                    }
                    response.close =
                        request.close || response.status >= 400 || self.stopping();
                    span.set_arg(response.status as u64);
                    drop(span);
                    // Recorded before the response is written so a client
                    // that reads its answer then scrapes `/metrics` sees
                    // this request already counted.
                    self.obs.record_route(
                        route_label(&request),
                        started.elapsed().as_nanos() as u64,
                    );
                    let close = response.close;
                    if response.write_to(stream).is_err() || close {
                        break;
                    }
                }
            }
        }
    }

    /// Embedded executor: drain the spool in bursts of at most `workers`
    /// jobs, re-checking the stop flag between bursts so a deep queue
    /// never blocks shutdown. One [`JobRunner`] lives for the whole loop,
    /// keeping its prepared-DSE pool warm across bursts.
    fn exec_loop(&self) {
        let opts = ServeOptions {
            workers: self.opts.workers,
            max_jobs: Some(self.opts.workers.max(1)),
            drain: true,
            poll: self.opts.poll,
            log_max_bytes: self.opts.log_max_bytes,
        };
        let gc = StoreGc::for_ctx(&self.ctx);
        // Share the event log and histogram set: requests and the jobs
        // they spawn land in one `/metrics` view and one rotated log.
        let runner = JobRunner::with_observer(
            &self.ctx,
            &self.queue,
            opts,
            Arc::clone(&self.log),
            Arc::clone(&self.obs),
        );
        while !self.stopping() && !super::signal::draining() {
            let busy = match self.queue.counts() {
                Ok(c) if c.pending > 0 => match runner.run() {
                    Ok(summary) => {
                        self.storage_full.store(false, Ordering::Relaxed);
                        summary.done + summary.failed > 0
                    }
                    // A full disk is a load condition, not a crash: flag
                    // it (submissions answer 503) and pause until the
                    // next burst finds space again.
                    Err(e) if e.is_disk_full() => {
                        self.storage_full.store(true, Ordering::Relaxed);
                        self.log_event(
                            "exec-pause",
                            &[("reason", Json::Str("disk-full".into()))],
                        );
                        false
                    }
                    Err(e) => {
                        eprintln!("warning: exec burst failed: {e}");
                        false
                    }
                },
                _ => false,
            };
            if !busy {
                // Idle lull: keep the persistent store inside its byte
                // budget before going back to sleep.
                if let Some(report) = gc.run_if_due(&self.ctx) {
                    self.log_event("store-gc", &gc_event_fields(&report));
                }
                std::thread::sleep(self.opts.poll);
            }
        }
    }

    /// Route one parsed request; never panics a connection — every
    /// outcome is a response.
    fn route(&self, request: &Request) -> Response {
        let path = request.path.split('?').next().unwrap_or("");
        let query = request.path.split_once('?').map_or("", |(_, q)| q);
        let segments: Vec<&str> =
            path.trim_matches('/').split('/').filter(|s| !s.is_empty()).collect();
        match (request.method.as_str(), segments.as_slice()) {
            ("POST", ["jobs"]) => self.handle_submit(&request.body),
            ("GET", ["jobs", id]) => self.handle_status(id),
            ("GET", ["jobs", id, "result"]) => self.handle_result(id),
            ("GET", ["jobs", id, "timeline"]) => self.handle_timeline(id),
            ("GET", ["healthz"]) => {
                let status =
                    if super::signal::draining() { "draining" } else { "ok" };
                Response::json(
                    200,
                    Json::obj(vec![("status", Json::Str(status.into()))]),
                )
            }
            ("GET", ["metrics"]) => self.handle_metrics(query, &request.accept),
            ("GET", ["trace"]) => Response::json(200, obs::export_chrome()),
            ("GET" | "POST", _) => Response::error(404, "no such route"),
            _ => Response::error(405, "method not allowed (GET and POST only)"),
        }
    }

    /// `POST /jobs`: parse → validate (`400`) → dedup (`200`) →
    /// backpressure (`429`) → spool (`201`). Dedup is checked before the
    /// high-water mark on purpose — a duplicate of an in-flight job costs
    /// no queue space, so it is answered even under full load.
    fn handle_submit(&self, body: &[u8]) -> Response {
        let _span = obs::span(obs::n::JOB_SUBMIT);
        let spec = match parse_spec(body) {
            Ok(spec) => spec,
            Err(message) => return Response::error(400, &message),
        };
        let id = hash_id(canonical_hash(&spec));
        if let Some(state) = self.queue.state_of(&id) {
            return self.respond_shared(&id, state);
        }
        let pending = match self.queue.counts() {
            Ok(c) => c.pending,
            Err(e) => return Response::error(500, &e.to_string()),
        };
        if pending >= self.opts.high_water {
            self.stats.rejected.fetch_add(1, Ordering::Relaxed);
            self.log_event("http-reject", &[("pending", Json::Num(pending as f64))]);
            let mut response = Response::json(
                429,
                Json::obj(vec![
                    (
                        "error",
                        Json::Str(format!(
                            "queue full: {pending} pending >= high-water {}",
                            self.opts.high_water
                        )),
                    ),
                    (
                        "retry_after_secs",
                        Json::Num(self.opts.retry_after_secs as f64),
                    ),
                ]),
            );
            response
                .headers
                .push(("Retry-After".into(), self.opts.retry_after_secs.to_string()));
            return response;
        }
        match admit(&self.queue, &spec) {
            Ok(Admission::Created { id }) => {
                self.storage_full.store(false, Ordering::Relaxed);
                self.stats.created.fetch_add(1, Ordering::Relaxed);
                self.log_event("http-created", &[("id", Json::Str(id.clone()))]);
                Response::json(
                    201,
                    Json::obj(vec![
                        ("id", Json::Str(id)),
                        ("state", Json::Str("pending".into())),
                        ("created", Json::Bool(true)),
                    ]),
                )
            }
            // Lost the spool race to an identical concurrent request.
            Ok(Admission::Shared { id, state }) => self.respond_shared(&id, state),
            // A full disk while spooling is load, not client error.
            Err(e) if e.is_disk_full() => self.shed_storage_full(),
            Err(e) => Response::error(400, &e.to_string()),
        }
    }

    /// The `ENOSPC` answer: `503` + `Retry-After`, the flag raised so the
    /// exec loop pauses too. The next submission that spools successfully
    /// clears it.
    fn shed_storage_full(&self) -> Response {
        self.storage_full.store(true, Ordering::Relaxed);
        self.stats.shed.fetch_add(1, Ordering::Relaxed);
        self.log_event("http-shed", &[("reason", Json::Str("disk-full".into()))]);
        let mut response = Response::json(
            503,
            Json::obj(vec![
                (
                    "error",
                    Json::Str("spool disk full; shedding new work".into()),
                ),
                (
                    "retry_after_secs",
                    Json::Num(self.opts.retry_after_secs as f64),
                ),
            ]),
        );
        response
            .headers
            .push(("Retry-After".into(), self.opts.retry_after_secs.to_string()));
        response
    }

    /// The dedup-hit response: `200 OK`, the shared content-addressed id,
    /// and where the job currently is in its lifecycle.
    fn respond_shared(&self, id: &str, state: JobState) -> Response {
        self.stats.shared.fetch_add(1, Ordering::Relaxed);
        self.log_event(
            "http-shared",
            &[
                ("id", Json::Str(id.to_string())),
                ("state", Json::Str(state.as_str().into())),
            ],
        );
        Response::json(
            200,
            Json::obj(vec![
                ("id", Json::Str(id.to_string())),
                ("state", Json::Str(state.as_str().into())),
                ("created", Json::Bool(false)),
            ]),
        )
    }

    fn handle_status(&self, id: &str) -> Response {
        match self.queue.state_of(id) {
            None => Response::error(404, "unknown job id"),
            Some(state) => {
                let mut pairs = vec![
                    ("id", Json::Str(id.to_string())),
                    ("state", Json::Str(state.as_str().into())),
                ];
                if state == JobState::Failed {
                    if let Ok(message) = self.queue.error(id) {
                        pairs.push(("error", Json::Str(message)));
                    }
                }
                Response::json(200, Json::obj(pairs))
            }
        }
    }

    /// `GET /jobs/<id>/result`: the `done/` record verbatim (the bytes a
    /// direct spool reader would see), `202` while in flight, `500` with
    /// the recorded error for failed jobs.
    fn handle_result(&self, id: &str) -> Response {
        match self.queue.state_of(id) {
            None => Response::error(404, "unknown job id"),
            Some(JobState::Done) => match self.queue.result_text(id) {
                Ok(text) => Response::raw_json(200, text.into_bytes()),
                Err(e) => Response::error(500, &e.to_string()),
            },
            Some(JobState::Failed) => {
                let message = self
                    .queue
                    .error(id)
                    .unwrap_or_else(|_| "job failed (no error record)".into());
                Response::json(
                    500,
                    Json::obj(vec![
                        ("id", Json::Str(id.to_string())),
                        ("error", Json::Str(message)),
                    ]),
                )
            }
            Some(state) => Response::json(
                202,
                Json::obj(vec![
                    ("id", Json::Str(id.to_string())),
                    ("state", Json::Str(state.as_str().into())),
                ]),
            ),
        }
    }

    /// `GET /jobs/<id>/timeline`: the job's lifecycle stamps plus the
    /// derived queue-wait and execute durations. Available at every
    /// lifecycle stage; dedup-shared submissions report the *original*
    /// submit stamp (identical specs are one job).
    fn handle_timeline(&self, id: &str) -> Response {
        let Some(state) = self.queue.state_of(id) else {
            return Response::error(404, "unknown job id");
        };
        let stamps = match self.queue.timeline(id) {
            Ok(s) => s,
            Err(e) => return Response::error(500, &e.to_string()),
        };
        let events: Vec<Json> = stamps.iter().map(|s| s.to_json()).collect();
        let mut pairs = vec![
            ("id", Json::Str(id.to_string())),
            ("state", Json::Str(state.as_str().into())),
            ("events", Json::Arr(events)),
        ];
        if let Some(ns) = stamp_gap_ns(&stamps, "submit", "claim") {
            pairs.push(("queue_wait_ms", Json::Num(ns as f64 / 1e6)));
        }
        let execute = stamp_gap_ns(&stamps, "start", "done")
            .or_else(|| stamp_gap_ns(&stamps, "start", "fail"));
        if let Some(ns) = execute {
            pairs.push(("execute_ms", Json::Num(ns as f64 / 1e6)));
        }
        Response::json(200, Json::obj(pairs))
    }

    /// `GET /metrics`: queue depths, front-end counters, latency
    /// histograms, and the engine's merged estimator/cache/pool
    /// statistics. JSON by default; the Prometheus text exposition via
    /// `?format=prometheus` or an `Accept` header naming `text/plain`.
    fn handle_metrics(&self, query: &str, accept: &str) -> Response {
        let prometheus = query.split('&').any(|kv| kv == "format=prometheus")
            || (!query.contains("format=") && accept.contains("text/plain"));
        if prometheus {
            return self.metrics_prometheus();
        }
        let counts = match self.queue.counts() {
            Ok(c) => c,
            Err(e) => return Response::error(500, &e.to_string()),
        };
        let uptime = self.started.elapsed();
        let metrics = self.ctx.pool_metrics();
        let mut estimator = metrics.to_json();
        if let Json::Obj(obj) = &mut estimator {
            obj.insert(
                "configs_per_sec".into(),
                Json::Num(metrics.configs_per_sec(uptime)),
            );
        }
        let cache = self.ctx.cache_stats();
        let pool = self.ctx.pool_stats();
        let route_lat: Vec<(&str, Json)> = self
            .obs
            .route_snapshots()
            .into_iter()
            .map(|(r, s)| (r, s.to_json_ms()))
            .collect();
        let g = obs::metrics();
        let latency = Json::obj(vec![
            ("http", Json::obj(route_lat)),
            ("queue_wait", self.obs.queue_wait_ns.snapshot().to_json_ms()),
            ("execute", self.obs.execute_ns.snapshot().to_json_ms()),
            ("behav_shard", g.behav_shard_ns.snapshot().to_json_ms()),
            ("ppa_shard", g.ppa_shard_ns.snapshot().to_json_ms()),
            ("estimator_batch", g.batch_ns.snapshot().to_json_ms()),
            ("estimator_batch_fill", g.batch_fill.snapshot().to_json_raw()),
        ]);
        let fault_hits = crate::fault::hits();
        let fault = Json::obj(
            fault_hits
                .iter()
                .map(|(site, n)| (site.as_str(), Json::Num(*n as f64)))
                .collect(),
        );
        let ring = obs::tracer().ring();
        let observability = Json::obj(vec![
            ("log_dropped", Json::Num(self.log.dropped() as f64)),
            ("log_rotations", Json::Num(self.log.rotations() as f64)),
            ("trace_enabled", Json::Bool(obs::trace_enabled())),
            ("spans_recorded", Json::Num(ring.recorded() as f64)),
            ("spans_dropped", Json::Num(ring.dropped() as f64)),
        ]);
        Response::json(
            200,
            Json::obj(vec![
                ("uptime_ms", Json::Num(uptime.as_millis() as f64)),
                (
                    "queue",
                    Json::obj(vec![
                        ("pending", Json::Num(counts.pending as f64)),
                        ("running", Json::Num(counts.running as f64)),
                        ("done", Json::Num(counts.done as f64)),
                        ("failed", Json::Num(counts.failed as f64)),
                    ]),
                ),
                ("http", self.stats.to_json()),
                ("estimator", estimator),
                (
                    "cache",
                    Json::obj(vec![
                        ("hits", Json::Num(cache.hits as f64)),
                        ("misses", Json::Num(cache.misses as f64)),
                        ("entries", Json::Num(cache.entries as f64)),
                        ("store_hits", Json::Num(cache.store_hits as f64)),
                        ("characterized", Json::Num(cache.characterized as f64)),
                        (
                            "behav_backend",
                            Json::Str(self.ctx.behav_backend().name().into()),
                        ),
                        (
                            "ppa_backend",
                            Json::Str(self.ctx.ppa_backend().name().into()),
                        ),
                        // Fused-pipeline phase clocks, aggregate ms summed
                        // across work-stealing tasks.
                        ("behav_ms", Json::Num(cache.behav_ns as f64 / 1e6)),
                        ("ppa_ms", Json::Num(cache.ppa_ns as f64 / 1e6)),
                    ]),
                ),
                (
                    "pool",
                    Json::obj(vec![
                        ("hits", Json::Num(pool.hits as f64)),
                        ("spawned", Json::Num(pool.spawned as f64)),
                        ("services", Json::Num(pool.services as f64)),
                    ]),
                ),
                ("latency", latency),
                ("obs", observability),
                // Armed failpoint hit counters — empty when faults are
                // disarmed (the production state).
                ("fault", fault),
            ]),
        )
    }

    /// The Prometheus text rendering of `/metrics` (exposition format
    /// v0.0.4): the same counters and histograms the JSON document
    /// carries, as fixed metric families standard scrapers ingest.
    /// Deterministic for deterministic traffic — the integration suite
    /// asserts exact counter and bucket lines.
    fn metrics_prometheus(&self) -> Response {
        let counts = match self.queue.counts() {
            Ok(c) => c,
            Err(e) => return Response::error(500, &e.to_string()),
        };
        let mut p = PromText::new();
        for (route, snap) in self.obs.route_snapshots() {
            p.histogram("http_request_seconds", &[("route", route)], &snap, 1e-9);
        }
        let load = |a: &AtomicU64| a.load(Ordering::Relaxed);
        p.counter("http_requests_total", &[], load(&self.stats.requests));
        p.counter("http_jobs_created_total", &[], load(&self.stats.created));
        p.counter("http_jobs_shared_total", &[], load(&self.stats.shared));
        p.counter("http_rejected_total", &[], load(&self.stats.rejected));
        p.counter("http_bad_requests_total", &[], load(&self.stats.bad_requests));
        p.counter("http_shed_total", &[], load(&self.stats.shed));
        p.gauge("queue_jobs", &[("state", "pending")], counts.pending as f64);
        p.gauge("queue_jobs", &[("state", "running")], counts.running as f64);
        p.gauge("queue_jobs", &[("state", "done")], counts.done as f64);
        p.gauge("queue_jobs", &[("state", "failed")], counts.failed as f64);
        let queue_wait = self.obs.queue_wait_ns.snapshot();
        p.histogram("job_queue_wait_seconds", &[], &queue_wait, 1e-9);
        let execute = self.obs.execute_ns.snapshot();
        p.histogram("job_execute_seconds", &[], &execute, 1e-9);
        let g = obs::metrics();
        let behav = g.behav_shard_ns.snapshot();
        p.histogram("charac_behav_shard_seconds", &[], &behav, 1e-9);
        let ppa = g.ppa_shard_ns.snapshot();
        p.histogram("charac_ppa_shard_seconds", &[], &ppa, 1e-9);
        p.histogram("estimator_batch_fill", &[], &g.batch_fill.snapshot(), 1.0);
        p.histogram("estimator_batch_seconds", &[], &g.batch_ns.snapshot(), 1e-9);
        p.counter("log_dropped_total", &[], self.log.dropped());
        p.counter("log_rotations_total", &[], self.log.rotations());
        for (site, n) in crate::fault::hits() {
            p.counter("fault_hits_total", &[("site", &site)], n);
        }
        let ring = obs::tracer().ring();
        p.gauge("trace_spans_recorded", &[], ring.recorded() as f64);
        p.gauge("trace_spans_dropped", &[], ring.dropped() as f64);
        p.gauge("uptime_seconds", &[], self.started.elapsed().as_secs_f64());
        Response::text(200, obs::prom::CONTENT_TYPE, p.finish().into_bytes())
    }

    /// Append one event line to `server.log.jsonl` (best-effort, like the
    /// runner's — observability must never fail a request; failures are
    /// counted and surfaced as `log_dropped` in `/metrics`).
    fn log_event(&self, event: &str, fields: &[(&str, Json)]) {
        let ts = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap_or(Duration::ZERO)
            .as_millis() as u64;
        let mut pairs =
            vec![("ts_ms", Json::Num(ts as f64)), ("event", Json::Str(event.into()))];
        for (k, v) in fields {
            pairs.push((*k, v.clone()));
        }
        let line = Json::obj(pairs).to_string();
        self.log.append(&line);
    }
}

/// Parse a `POST /jobs` body into a submittable spec: UTF-8 → JSON →
/// [`JobSpec`] (unknown keys rejected by `from_json`), with client ids
/// refused — identity is content-addressed on the server.
fn parse_spec(body: &[u8]) -> std::result::Result<JobSpec, String> {
    let text =
        std::str::from_utf8(body).map_err(|_| "body is not UTF-8".to_string())?;
    let value = Json::parse(text).map_err(|e| e.to_string())?;
    let spec = JobSpec::from_json(&value).map_err(|e| e.to_string())?;
    if !spec.id.is_empty() {
        return Err(
            "job ids are server-assigned (content-addressed); omit `id`".into()
        );
    }
    // Validation needs an id; the placeholder never reaches the spool.
    let mut candidate = spec.clone();
    candidate.id = "candidate".into();
    candidate.validate().map_err(|e| {
        e.to_string().replace("job `candidate`", "job spec")
    })?;
    Ok(spec)
}

/// One parsed request (the subset of HTTP/1.1 this server understands).
struct Request {
    method: String,
    path: String,
    body: Vec<u8>,
    /// The `Accept` header value, empty when absent (`/metrics` content
    /// negotiation).
    accept: String,
    /// The client asked for `Connection: close` — answer, then hang up.
    close: bool,
}

/// The fixed label a request's latency is recorded under — one of
/// [`obs::HTTP_ROUTES`], so the Prometheus families are stable however
/// clients misspell paths.
fn route_label(request: &Request) -> &'static str {
    let path = request.path.split('?').next().unwrap_or("");
    let segments: Vec<&str> =
        path.trim_matches('/').split('/').filter(|s| !s.is_empty()).collect();
    match (request.method.as_str(), segments.as_slice()) {
        ("POST", ["jobs"]) => "jobs_submit",
        ("GET", ["jobs", _]) => "job_status",
        ("GET", ["jobs", _, "result"]) => "job_result",
        ("GET", ["jobs", _, "timeline"]) => "job_timeline",
        ("GET", ["healthz"]) => "healthz",
        ("GET", ["metrics"]) => "metrics",
        ("GET", ["trace"]) => "trace",
        _ => "other",
    }
}

/// What reading one request off a keep-alive connection produced.
enum ReadOutcome {
    /// A well-formed request to route.
    Request(Request),
    /// The connection ended *between* requests — the client closed it or
    /// sat silent past the idle timeout. Not an error: close quietly.
    Idle,
    /// A protocol violation mid-request; send the `400` and close.
    Bad(Response),
}

/// Read one request from `stream`. Any protocol violation maps to the
/// error response the caller should send (`400` for everything malformed,
/// oversized, or chunked — this API has no patience for exotic clients);
/// EOF or a read timeout *before the first byte* is [`ReadOutcome::Idle`].
fn read_request(stream: &mut &TcpStream, max_body_bytes: usize) -> ReadOutcome {
    let bad = |message: &str| ReadOutcome::Bad(Response::error(400, message));

    // Head: everything up to the blank line, hard-capped.
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let head_len = loop {
        if let Some(pos) = find_blank_line(&buf) {
            break pos;
        }
        if buf.len() >= MAX_HEAD_BYTES {
            return bad("request head exceeds 8 KiB");
        }
        let mut chunk = [0u8; 1024];
        match stream.read(&mut chunk) {
            Ok(0) if buf.is_empty() => return ReadOutcome::Idle,
            Ok(0) => return bad("connection closed mid-request"),
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(_) if buf.is_empty() => return ReadOutcome::Idle,
            Err(_) => return bad("read failed or timed out"),
        }
    };
    let head = match std::str::from_utf8(&buf[..head_len]) {
        Ok(h) => h.to_string(),
        Err(_) => return bad("request head is not UTF-8"),
    };
    let mut lines = head.split("\r\n");

    // Request line: METHOD SP PATH SP HTTP/1.x
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split(' ');
    let (method, path, version) = match (parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v)) if parts.next().is_none() && !m.is_empty() => {
            (m.to_string(), p.to_string(), v)
        }
        _ => return bad("malformed request line"),
    };
    if !version.starts_with("HTTP/1.") {
        return bad("only HTTP/1.x is supported");
    }

    // Headers: only Content-Length, Connection, Accept, and
    // Transfer-Encoding matter.
    let mut content_length: Option<usize> = None;
    let mut close = false;
    let mut accept = String::new();
    for line in lines {
        let Some((name, value)) = line.split_once(':') else {
            return bad("malformed header line");
        };
        let value = value.trim();
        if name.eq_ignore_ascii_case("transfer-encoding") {
            return bad("chunked transfer encoding is not supported");
        }
        if name.eq_ignore_ascii_case("content-length") {
            match value.parse::<usize>() {
                Ok(n) => content_length = Some(n),
                Err(_) => return bad("unparseable Content-Length"),
            }
        }
        if name.eq_ignore_ascii_case("connection") {
            close = value.to_ascii_lowercase().contains("close");
        }
        if name.eq_ignore_ascii_case("accept") {
            accept = value.to_ascii_lowercase();
        }
    }

    // Body: exactly Content-Length bytes (some may sit in the head read).
    let body_len = match (method.as_str(), content_length) {
        ("POST", None) => return bad("POST requires Content-Length"),
        ("POST", Some(n)) if n > max_body_bytes => {
            return bad(&format!("body exceeds {max_body_bytes} bytes"));
        }
        (_, n) => n.unwrap_or(0),
    };
    let mut body = buf[head_len + 4..].to_vec();
    while body.len() < body_len {
        let mut chunk = vec![0u8; (body_len - body.len()).min(4096)];
        match stream.read(&mut chunk) {
            Ok(0) => return bad("connection closed mid-body"),
            Ok(n) => body.extend_from_slice(&chunk[..n]),
            Err(_) => return bad("body read failed or timed out"),
        }
    }
    body.truncate(body_len);
    ReadOutcome::Request(Request { method, path, body, accept, close })
}

/// The head/body boundary (`\r\n\r\n`) position, if fully buffered.
fn find_blank_line(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// An outgoing response. `close` decides the `Connection:` header (and
/// whether the per-connection loop hangs up after writing); the request
/// loop sets it from the client's wish, the response status, and the
/// server's stop flag.
pub struct Response {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    /// `content-type` value — the API is JSON everywhere except the
    /// Prometheus text exposition.
    content_type: &'static str,
    close: bool,
}

impl Response {
    /// A JSON document response.
    fn json(status: u16, value: Json) -> Response {
        Response::raw_json(status, value.to_string().into_bytes())
    }

    /// Pre-serialized JSON bytes (the verbatim result pass-through).
    fn raw_json(status: u16, body: Vec<u8>) -> Response {
        Response::text(status, "application/json", body)
    }

    /// A response with an explicit content type (the Prometheus text
    /// exposition).
    fn text(status: u16, content_type: &'static str, body: Vec<u8>) -> Response {
        Response { status, headers: Vec::new(), body, content_type, close: false }
    }

    /// The uniform error shape: `{"error": message}`.
    fn error(status: u16, message: &str) -> Response {
        Response::json(
            status,
            Json::obj(vec![("error", Json::Str(message.to_string()))]),
        )
    }

    fn reason(&self) -> &'static str {
        match self.status {
            200 => "OK",
            201 => "Created",
            202 => "Accepted",
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            429 => "Too Many Requests",
            503 => "Service Unavailable",
            _ => "Internal Server Error",
        }
    }

    fn write_to(&self, mut stream: &TcpStream) -> std::io::Result<()> {
        let connection = if self.close { "close" } else { "keep-alive" };
        let mut head = format!(
            "HTTP/1.1 {} {}\r\ncontent-type: {}\r\n\
             content-length: {}\r\nconnection: {connection}\r\n",
            self.status,
            self.reason(),
            self.content_type,
            self.body.len()
        );
        for (name, value) in &self.headers {
            head.push_str(&format!("{name}: {value}\r\n"));
        }
        head.push_str("\r\n");
        // `http.response.write` failpoint: `err` drops the response on
        // the floor, `partial` tears it mid-body — either way the client
        // sees a broken exchange it must treat as retryable.
        let quota = crate::fault::write_quota("http.response.write", self.body.len())?;
        stream.write_all(head.as_bytes())?;
        stream.write_all(&self.body[..quota])?;
        stream.flush()
    }
}

/// A client-side response (the test/loadgen counterpart of [`Response`]).
#[derive(Debug, Clone)]
pub struct HttpResponse {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: String,
}

impl HttpResponse {
    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(n, _)| n.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    /// The body parsed as JSON.
    pub fn json(&self) -> Result<Json> {
        Ok(Json::parse(&self.body)?)
    }
}

/// Minimal one-shot HTTP client over std sockets — what the integration
/// tests, the load generator, and the CI smoke scripts (via curl) all
/// exercise the server with. One request per connection, mirroring the
/// server's `Connection: close`.
pub fn http_call(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> Result<HttpResponse> {
    let fail = |what: &str, e: &dyn std::fmt::Display| {
        Error::Coordinator(format!("http {method} {path}: {what}: {e}"))
    };
    let mut stream = TcpStream::connect(addr)
        .map_err(|e| fail("connect", &e))?;
    let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
    let payload = body.unwrap_or("");
    let request = format!(
        "{method} {path} HTTP/1.1\r\nhost: {addr}\r\ncontent-length: {}\r\n\
         connection: close\r\n\r\n{payload}",
        payload.len()
    );
    stream
        .write_all(request.as_bytes())
        .map_err(|e| fail("write", &e))?;
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).map_err(|e| fail("read", &e))?;
    let text = String::from_utf8(raw)
        .map_err(|e| fail("decode", &e))?;
    let (head, body) = text
        .split_once("\r\n\r\n")
        .ok_or_else(|| fail("parse", &"no header/body boundary"))?;
    let (status, headers) = parse_response_head(head)
        .ok_or_else(|| fail("parse", &format!("bad response head `{head}`")))?;
    Ok(HttpResponse { status, headers, body: body.to_string() })
}

/// Parse a response head (status line + headers) the lenient way both
/// clients share.
fn parse_response_head(head: &str) -> Option<(u16, Vec<(String, String)>)> {
    let mut lines = head.split("\r\n");
    let status_line = lines.next().unwrap_or("");
    let status = status_line.split(' ').nth(1)?.parse::<u16>().ok()?;
    let headers = lines
        .filter_map(|line| {
            line.split_once(':')
                .map(|(n, v)| (n.trim().to_string(), v.trim().to_string()))
        })
        .collect();
    Some((status, headers))
}

/// A persistent keep-alive client: one TCP connection, many requests.
/// Responses are framed by their `content-length` (this server always
/// sends one), so the connection stays usable for the next call — the
/// client-side half of the server's per-connection request loop, used by
/// `loadgen --keep-alive` and the keep-alive tests.
pub struct HttpClient {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl HttpClient {
    pub fn connect(addr: &str) -> Result<HttpClient> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| Error::Coordinator(format!("http connect {addr}: {e}")))?;
        let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
        let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
        Ok(HttpClient { stream, buf: Vec::new() })
    }

    /// One request/response exchange on the persistent connection. Fails
    /// if the server closed it (e.g. after an error response or the idle
    /// timeout) — reconnect and retry at the caller's discretion.
    pub fn call(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> Result<HttpResponse> {
        let fail = |what: &str, e: &dyn std::fmt::Display| {
            Error::Coordinator(format!("http {method} {path}: {what}: {e}"))
        };
        let payload = body.unwrap_or("");
        let request = format!(
            "{method} {path} HTTP/1.1\r\nhost: keep-alive\r\ncontent-length: {}\r\n\
             connection: keep-alive\r\n\r\n{payload}",
            payload.len()
        );
        self.stream
            .write_all(request.as_bytes())
            .map_err(|e| fail("write", &e))?;

        // Head, framed by the blank line.
        let head_len = loop {
            if let Some(pos) = find_blank_line(&self.buf) {
                break pos;
            }
            let mut chunk = [0u8; 4096];
            match self.stream.read(&mut chunk) {
                Ok(0) => return Err(fail("read", &"connection closed mid-response")),
                Ok(n) => self.buf.extend_from_slice(&chunk[..n]),
                Err(e) => return Err(fail("read", &e)),
            }
        };
        let head = std::str::from_utf8(&self.buf[..head_len])
            .map_err(|e| fail("decode", &e))?;
        let (status, headers) = parse_response_head(head)
            .ok_or_else(|| fail("parse", &format!("bad response head `{head}`")))?;

        // Body, framed by content-length (keep-alive requires it).
        let length: usize = headers
            .iter()
            .find(|(n, _)| n.eq_ignore_ascii_case("content-length"))
            .and_then(|(_, v)| v.parse().ok())
            .ok_or_else(|| fail("parse", &"response has no content-length"))?;
        let body_start = head_len + 4;
        while self.buf.len() < body_start + length {
            let mut chunk = vec![0u8; (body_start + length - self.buf.len()).min(4096)];
            match self.stream.read(&mut chunk) {
                Ok(0) => return Err(fail("read", &"connection closed mid-body")),
                Ok(n) => self.buf.extend_from_slice(&chunk[..n]),
                Err(e) => return Err(fail("read", &e)),
            }
        }
        let body = String::from_utf8(self.buf[body_start..body_start + length].to_vec())
            .map_err(|e| fail("decode", &e))?;
        self.buf.drain(..body_start + length);
        Ok(HttpResponse { status, headers, body })
    }
}

/// Client retry policy: capped exponential backoff with *deterministic*
/// jitter (no RNG — the spread is keyed by `seed` and the attempt
/// number, so a run is reproducible and a fleet of seeded clients still
/// fans out). `429`/`503` responses are retried honoring `Retry-After`
/// when the server sends one; transport failures (connect, read, torn
/// response) are retried after our own backoff. Every request gets a
/// hard `deadline` across all of its attempts.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Retries per request beyond the first attempt.
    pub max_retries: u32,
    /// First backoff step; doubles per attempt.
    pub base: Duration,
    /// Backoff ceiling.
    pub cap: Duration,
    /// Per-request wall-clock budget across all attempts.
    pub deadline: Duration,
    /// Jitter key — give each client its own.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_retries: 5,
            base: Duration::from_millis(50),
            cap: Duration::from_secs(2),
            deadline: Duration::from_secs(30),
            seed: 0x5eed,
        }
    }
}

impl RetryPolicy {
    /// The wait before retry `attempt` (1-based): `base * 2^(attempt-1)`
    /// capped at `cap`, then full-jittered into `[capped/2, capped]` by
    /// an FNV hash of `(seed, attempt)`.
    pub fn backoff(&self, attempt: u32) -> Duration {
        let shift = attempt.saturating_sub(1).min(20);
        let capped = self
            .base
            .saturating_mul(1u32 << shift)
            .min(self.cap)
            .max(Duration::from_millis(1));
        let mut key = [0u8; 12];
        key[..8].copy_from_slice(&self.seed.to_le_bytes());
        key[8..].copy_from_slice(&attempt.to_le_bytes());
        let half = capped.as_millis() as u64 / 2;
        let jitter = if half == 0 {
            0
        } else {
            crate::engine::store::fnv1a64(&key) % (half + 1)
        };
        capped / 2 + Duration::from_millis(jitter)
    }
}

/// A server-directed pacing hint, when the response carries one.
fn retry_after(response: &HttpResponse) -> Option<Duration> {
    response
        .header("retry-after")
        .and_then(|v| v.parse::<u64>().ok())
        .map(Duration::from_secs)
}

/// [`http_call`] with retries under `policy`. Returns the final response
/// and how many retries it took; gives up with the last outcome once
/// retries or the deadline run out.
pub fn http_call_retry(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&str>,
    policy: &RetryPolicy,
) -> Result<(HttpResponse, u32)> {
    let started = Instant::now();
    let mut retries: u32 = 0;
    loop {
        let outcome = http_call(addr, method, path, body);
        let wait = match &outcome {
            Ok(r) if r.status == 429 || r.status == 503 => {
                retry_after(r).unwrap_or_else(|| policy.backoff(retries + 1))
            }
            Ok(_) => return outcome.map(|r| (r, retries)),
            Err(_) => policy.backoff(retries + 1),
        };
        if retries >= policy.max_retries
            || started.elapsed() + wait > policy.deadline
        {
            return outcome.map(|r| (r, retries));
        }
        std::thread::sleep(wait);
        retries += 1;
    }
}

/// [`HttpClient`] with a [`RetryPolicy`]: reconnects lazily, rebuilds the
/// connection after transport errors (and after responses the server
/// closed behind), and retries `429`/`503` honoring `Retry-After`. The
/// cumulative retry count is surfaced for benchmark reports
/// (`loadgen --retries` → `BENCH_http.json`).
pub struct RetryingClient {
    addr: String,
    policy: RetryPolicy,
    client: Option<HttpClient>,
    retries: u64,
}

impl RetryingClient {
    pub fn new(addr: &str, policy: RetryPolicy) -> RetryingClient {
        RetryingClient {
            addr: addr.to_string(),
            policy,
            client: None,
            retries: 0,
        }
    }

    /// Total retries performed across every call so far.
    pub fn retries(&self) -> u64 {
        self.retries
    }

    /// One request with retries; the final outcome after the policy is
    /// exhausted is returned as-is (a `429` after max retries is an
    /// `Ok(429)`, not an error — the caller sees what the server said).
    pub fn call(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> Result<HttpResponse> {
        let started = Instant::now();
        let mut attempt: u32 = 0;
        loop {
            let outcome = self.try_call(method, path, body);
            let wait = match &outcome {
                Ok(r) if r.status == 429 || r.status == 503 => {
                    retry_after(r)
                        .unwrap_or_else(|| self.policy.backoff(attempt + 1))
                }
                Ok(_) => return outcome,
                Err(_) => self.policy.backoff(attempt + 1),
            };
            if attempt >= self.policy.max_retries
                || started.elapsed() + wait > self.policy.deadline
            {
                return outcome;
            }
            std::thread::sleep(wait);
            attempt += 1;
            self.retries += 1;
        }
    }

    /// One attempt on the persistent connection, reconnecting first if
    /// needed and dropping the connection when it can no longer be
    /// trusted (transport error, or the server said `Connection: close`).
    fn try_call(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> Result<HttpResponse> {
        if self.client.is_none() {
            self.client = Some(HttpClient::connect(&self.addr)?);
        }
        let client = self.client.as_mut().expect("just connected");
        let result = client.call(method, path, body);
        match &result {
            Err(_) => self.client = None,
            Ok(r) if r.header("connection") == Some("close") => {
                self.client = None;
            }
            Ok(_) => {}
        }
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expcfg::{ConssConfig, ExperimentConfig, SurrogateConfig};
    use crate::surrogate::EstimatorBackend;
    use crate::util::tempdir::TempDir;

    fn tiny_cfg() -> ExperimentConfig {
        ExperimentConfig {
            operator: "add8".into(),
            surrogate: SurrogateConfig {
                backend: EstimatorBackend::Table,
                gbt_stages: None,
            },
            conss: ConssConfig {
                forest_trees: Some(4),
                noise_bits: 2,
                ..Default::default()
            },
            ga: crate::expcfg::GaConfig {
                pop_size: 10,
                generations: 3,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    /// A front-end-only server on an OS-assigned port, plus its serving
    /// thread. The heavyweight end-to-end suite lives in
    /// `rust/tests/http_serve.rs`; these unit tests only exercise the
    /// protocol layer, so no engine work runs.
    fn frontend(
        opts: HttpOptions,
    ) -> (TempDir, Arc<HttpServer>, std::thread::JoinHandle<()>) {
        let dir = TempDir::new().unwrap();
        let queue = Arc::new(JobQueue::open(dir.path().join("jobs")).unwrap());
        let ctx = Arc::new(EngineContext::new(tiny_cfg()));
        let server = Arc::new(
            HttpServer::bind(
                ctx,
                queue,
                "127.0.0.1:0",
                HttpOptions { workers: 0, ..opts },
            )
            .unwrap(),
        );
        let handle = {
            let server = Arc::clone(&server);
            std::thread::spawn(move || server.run().unwrap())
        };
        (dir, server, handle)
    }

    #[test]
    fn protocol_surface_without_engine_work() {
        let (_dir, server, handle) = frontend(HttpOptions::default());
        let addr = server.local_addr().to_string();

        let health = http_call(&addr, "GET", "/healthz", None).unwrap();
        assert_eq!(health.status, 200);
        assert_eq!(
            health.json().unwrap().get("status").and_then(Json::as_str),
            Some("ok")
        );
        assert_eq!(health.header("connection"), Some("close"));

        // Submit: created, then shared (dedup), each with the hash id.
        let spec = r#"{"factors":[0.5],"ga":{"pop_size":4,"generations":2}}"#;
        let created = http_call(&addr, "POST", "/jobs", Some(spec)).unwrap();
        assert_eq!(created.status, 201, "{}", created.body);
        let id = created
            .json()
            .unwrap()
            .get("id")
            .and_then(Json::as_str)
            .unwrap()
            .to_string();
        assert!(id.starts_with('h') && id.len() == 17);
        let shared = http_call(&addr, "POST", "/jobs", Some(spec)).unwrap();
        assert_eq!(shared.status, 200);
        assert_eq!(
            shared.json().unwrap().get("id").and_then(Json::as_str),
            Some(id.as_str())
        );

        // Status + result of the (unexecuted: workers = 0) job.
        let status =
            http_call(&addr, "GET", &format!("/jobs/{id}"), None).unwrap();
        assert_eq!(status.status, 200);
        assert_eq!(
            status.json().unwrap().get("state").and_then(Json::as_str),
            Some("pending")
        );
        let result =
            http_call(&addr, "GET", &format!("/jobs/{id}/result"), None).unwrap();
        assert_eq!(result.status, 202, "in flight, not an error");

        // Malformed bodies: 400, nothing spooled beyond our one job.
        for bad in [
            "not json",
            r#"{"factrs":[0.5]}"#,
            r#"{"factors":[2.5]}"#,
            r#"{"factors":[]}"#,
            r#"{"id":"mine","factors":[0.5]}"#,
        ] {
            let r = http_call(&addr, "POST", "/jobs", Some(bad)).unwrap();
            assert_eq!(r.status, 400, "body {bad:?} → {}", r.body);
        }

        // Unknown routes and methods.
        assert_eq!(http_call(&addr, "GET", "/nope", None).unwrap().status, 404);
        assert_eq!(
            http_call(&addr, "GET", "/jobs/unknown", None).unwrap().status,
            404
        );
        assert_eq!(http_call(&addr, "DELETE", "/jobs", None).unwrap().status, 405);

        // Metrics reflect what happened.
        let metrics = http_call(&addr, "GET", "/metrics", None).unwrap();
        assert_eq!(metrics.status, 200);
        let m = metrics.json().unwrap();
        let http = m.get("http").unwrap();
        assert_eq!(http.get("created").and_then(Json::as_u64), Some(1));
        assert_eq!(http.get("shared").and_then(Json::as_u64), Some(1));
        assert_eq!(http.get("dedup_hit_rate").and_then(Json::as_f64), Some(0.5));
        assert!(http.get("bad_requests").and_then(Json::as_u64).unwrap() >= 5);
        assert_eq!(
            m.get("queue").and_then(|q| q.get("pending")).and_then(Json::as_u64),
            Some(1)
        );

        server.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn keep_alive_serves_many_requests_per_connection() {
        let (_dir, server, handle) = frontend(HttpOptions::default());
        let addr = server.local_addr().to_string();

        let mut client = HttpClient::connect(&addr).unwrap();
        for _ in 0..3 {
            let r = client.call("GET", "/healthz", None).unwrap();
            assert_eq!(r.status, 200);
            assert_eq!(r.header("connection"), Some("keep-alive"));
        }
        // POSTs ride the same connection.
        let spec = r#"{"factors":[0.5]}"#;
        let created = client.call("POST", "/jobs", Some(spec)).unwrap();
        assert_eq!(created.status, 201, "{}", created.body);
        assert_eq!(created.header("connection"), Some("keep-alive"));
        // An error response closes the connection after answering.
        let bad = client.call("POST", "/jobs", Some("not json")).unwrap();
        assert_eq!(bad.status, 400);
        assert_eq!(bad.header("connection"), Some("close"));
        drop(client);

        // All five keep-alive requests counted individually; this metrics
        // probe is the sixth.
        let m = http_call(&addr, "GET", "/metrics", None).unwrap().json().unwrap();
        assert_eq!(
            m.get("http").and_then(|h| h.get("requests")).and_then(Json::as_u64),
            Some(6)
        );

        server.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn backpressure_rejects_new_work_but_answers_duplicates() {
        let (_dir, server, handle) =
            frontend(HttpOptions { high_water: 1, ..Default::default() });
        let addr = server.local_addr().to_string();

        let first = r#"{"factors":[0.4]}"#;
        assert_eq!(http_call(&addr, "POST", "/jobs", Some(first)).unwrap().status, 201);

        // The queue is now at the high-water mark: new work bounces...
        let second = http_call(&addr, "POST", "/jobs", Some(r#"{"factors":[0.9]}"#))
            .unwrap();
        assert_eq!(second.status, 429);
        assert_eq!(second.header("retry-after"), Some("1"));
        assert!(second
            .json()
            .unwrap()
            .get("retry_after_secs")
            .and_then(Json::as_u64)
            .is_some());

        // ...but a duplicate of the spooled job still shares (200), and
        // the rejected spec was never spooled.
        let dup = http_call(&addr, "POST", "/jobs", Some(first)).unwrap();
        assert_eq!(dup.status, 200);
        let m = http_call(&addr, "GET", "/metrics", None).unwrap().json().unwrap();
        assert_eq!(
            m.get("queue").and_then(|q| q.get("pending")).and_then(Json::as_u64),
            Some(1),
            "429 left the queue untouched"
        );
        assert_eq!(
            m.get("http").and_then(|h| h.get("rejected")).and_then(Json::as_u64),
            Some(1)
        );

        server.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn observability_routes_without_engine_work() {
        let (_dir, server, handle) = frontend(HttpOptions::default());
        let addr = server.local_addr().to_string();
        for _ in 0..3 {
            let r = http_call(&addr, "GET", "/healthz", None).unwrap();
            assert_eq!(r.status, 200);
        }

        // Prometheus exposition, selected by query parameter.
        let prom =
            http_call(&addr, "GET", "/metrics?format=prometheus", None).unwrap();
        assert_eq!(prom.status, 200);
        assert!(prom.header("content-type").unwrap().starts_with("text/plain"));
        assert!(prom.body.contains("# TYPE http_request_seconds histogram"));
        assert!(prom.body.contains("http_request_seconds_count{route=\"healthz\"} 3"));
        assert!(prom.body.contains("log_dropped_total 0"));
        assert!(prom.body.contains("queue_jobs{state=\"pending\"} 0"));

        // The JSON document carries the same story, additively.
        let m = http_call(&addr, "GET", "/metrics", None).unwrap().json().unwrap();
        let lat = m.get("latency").and_then(|l| l.get("http")).unwrap();
        assert_eq!(
            lat.get("healthz").and_then(|h| h.get("count")).and_then(Json::as_u64),
            Some(3)
        );
        assert_eq!(
            m.get("obs").and_then(|o| o.get("log_dropped")).and_then(Json::as_u64),
            Some(0)
        );

        // Chrome-trace export is well-formed JSON whatever the gate.
        let trace = http_call(&addr, "GET", "/trace", None).unwrap();
        assert_eq!(trace.status, 200);
        let t = trace.json().unwrap();
        assert!(t.get("traceEvents").unwrap().as_arr().is_some());

        // Timeline of a pending (workers = 0) job: just the submit stamp.
        let spec = r#"{"factors":[0.5]}"#;
        let created = http_call(&addr, "POST", "/jobs", Some(spec)).unwrap();
        assert_eq!(created.status, 201, "{}", created.body);
        let id = created
            .json()
            .unwrap()
            .get("id")
            .and_then(Json::as_str)
            .unwrap()
            .to_string();
        let tl = http_call(&addr, "GET", &format!("/jobs/{id}/timeline"), None)
            .unwrap();
        assert_eq!(tl.status, 200, "{}", tl.body);
        let t = tl.json().unwrap();
        let events = t.get("events").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].get("event").and_then(Json::as_str), Some("submit"));
        assert!(t.get("queue_wait_ms").is_none(), "not claimed yet");
        assert_eq!(
            http_call(&addr, "GET", "/jobs/nope/timeline", None).unwrap().status,
            404
        );

        server.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn retry_backoff_is_deterministic_capped_and_growing() {
        let policy = RetryPolicy {
            base: Duration::from_millis(10),
            cap: Duration::from_millis(80),
            ..Default::default()
        };
        for attempt in 1..10 {
            let wait = policy.backoff(attempt);
            assert_eq!(wait, policy.backoff(attempt), "deterministic");
            assert!(wait <= policy.cap, "attempt {attempt}: {wait:?} over cap");
            assert!(wait >= Duration::from_millis(5), "attempt {attempt}");
        }
        // Exponential growth until the cap dominates.
        assert!(policy.backoff(1) < policy.backoff(4));
        // Different seeds fan out to different schedules.
        let other = RetryPolicy { seed: 99, ..policy.clone() };
        assert!((1..10).any(|n| other.backoff(n) != policy.backoff(n)));
    }

    #[test]
    fn retrying_client_honors_retry_after_and_counts_retries() {
        // high_water 0: every fresh submission answers 429 + Retry-After.
        let (_dir, server, handle) = frontend(HttpOptions {
            high_water: 0,
            retry_after_secs: 0,
            ..Default::default()
        });
        let addr = server.local_addr().to_string();
        let policy = RetryPolicy {
            max_retries: 3,
            base: Duration::from_millis(1),
            cap: Duration::from_millis(2),
            deadline: Duration::from_secs(5),
            seed: 7,
        };
        let mut client = RetryingClient::new(&addr, policy);
        let r = client
            .call("POST", "/jobs", Some(r#"{"factors":[0.5]}"#))
            .unwrap();
        assert_eq!(r.status, 429, "{}", r.body);
        assert_eq!(client.retries(), 3, "policy exhausted, last answer kept");
        server.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn one_shot_retry_surfaces_the_final_transport_error() {
        // Port 1 is never listening here: every attempt fails to connect.
        let policy = RetryPolicy {
            max_retries: 2,
            base: Duration::from_millis(1),
            cap: Duration::from_millis(2),
            deadline: Duration::from_millis(500),
            seed: 1,
        };
        let err = http_call_retry("127.0.0.1:1", "GET", "/healthz", None, &policy);
        assert!(err.is_err());
    }

    #[test]
    fn wire_level_protocol_rejections() {
        let (_dir, server, handle) =
            frontend(HttpOptions { max_body_bytes: 64, ..Default::default() });
        let addr = server.local_addr().to_string();
        let raw = |request: &str| -> u16 {
            let mut stream = TcpStream::connect(&addr).unwrap();
            stream.write_all(request.as_bytes()).unwrap();
            let mut text = String::new();
            stream.read_to_string(&mut text).unwrap();
            text.split(' ').nth(1).unwrap().parse().unwrap()
        };

        // Chunked transfer encoding is refused outright.
        assert_eq!(
            raw("POST /jobs HTTP/1.1\r\ntransfer-encoding: chunked\r\n\r\n"),
            400
        );
        // POST without a Content-Length.
        assert_eq!(raw("POST /jobs HTTP/1.1\r\n\r\n"), 400);
        // Oversized body (declared 65 > cap 64): rejected before reading.
        assert_eq!(
            raw("POST /jobs HTTP/1.1\r\ncontent-length: 65\r\n\r\n"),
            400
        );
        // Garbage request line and unsupported version.
        assert_eq!(raw("ONE-FIELD\r\n\r\n"), 400);
        assert_eq!(raw("GET /healthz HTTP/2.0\r\n\r\n"), 400);

        server.shutdown();
        handle.join().unwrap();
    }
}
