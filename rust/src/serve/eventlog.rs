//! `server.log.jsonl` without silent loss: a shared append-only event log
//! that survives lock poisoning, counts write failures instead of
//! swallowing them, and rotates by size so `--watch` servers can't grow
//! the log unbounded.
//!
//! The old `log_event` helpers took a `Mutex<File>` and dropped the line
//! on *either* failure mode with no signal. Here a poisoned lock is
//! recovered (`PoisonError::into_inner` — appending a log line cannot
//! observe broken invariants), a failed write bumps an atomic surfaced as
//! `log_dropped` in `/metrics`, and when the current file would exceed
//! `max_bytes` it is renamed to `<name>.1` (one rotation generation —
//! the previous `.1` is replaced) and a fresh file is started.

use std::fs::{File, OpenOptions};
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Default `[serve] log_max_bytes`: 8 MiB per generation.
pub const DEFAULT_LOG_MAX_BYTES: u64 = 8 * 1024 * 1024;

struct Inner {
    file: File,
    bytes: u64,
}

/// Shared, size-rotated, drop-counting JSONL event log.
pub struct EventLog {
    path: PathBuf,
    max_bytes: u64,
    inner: Mutex<Inner>,
    dropped: AtomicU64,
    rotations: AtomicU64,
}

impl EventLog {
    /// Open (or continue) the log at `path`, rotating once the current
    /// file exceeds `max_bytes`.
    pub fn open(path: PathBuf, max_bytes: u64) -> std::io::Result<EventLog> {
        let file = OpenOptions::new().create(true).append(true).open(&path)?;
        let bytes = file.metadata().map(|m| m.len()).unwrap_or(0);
        Ok(EventLog {
            path,
            max_bytes: max_bytes.max(1),
            inner: Mutex::new(Inner { file, bytes }),
            dropped: AtomicU64::new(0),
            rotations: AtomicU64::new(0),
        })
    }

    /// Append one line (the trailing newline is added). Never panics and
    /// never poisons: failures count into [`EventLog::dropped`].
    pub fn append(&self, line: &str) {
        let mut inner = match self.inner.lock() {
            Ok(guard) => guard,
            Err(poisoned) => poisoned.into_inner(),
        };
        let len = line.len() as u64 + 1;
        if inner.bytes > 0 && inner.bytes + len > self.max_bytes {
            // A failed rotation is not fatal: keep appending to the old
            // file rather than dropping the line.
            if self.rotate(&mut inner).is_ok() {
                self.rotations.fetch_add(1, Ordering::Relaxed);
            }
        }
        // `log.append` failpoint: `err` exercises the drop counter,
        // `partial` leaves a torn final line for the tolerant readers.
        let full = format!("{line}\n");
        let wrote = match crate::fault::write_quota("log.append", full.len()) {
            Ok(quota) => inner.file.write_all(&full.as_bytes()[..quota]),
            Err(e) => Err(e),
        };
        match wrote {
            Ok(()) => inner.bytes += len,
            Err(_) => {
                self.dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    fn rotate(&self, inner: &mut Inner) -> std::io::Result<()> {
        let _ = inner.file.flush();
        let mut rotated = self.path.clone().into_os_string();
        rotated.push(".1");
        std::fs::rename(&self.path, PathBuf::from(rotated))?;
        inner.file = OpenOptions::new().create(true).append(true).open(&self.path)?;
        inner.bytes = 0;
        Ok(())
    }

    /// Lines lost to write errors since open.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Completed size-based rotations since open.
    pub fn rotations(&self) -> u64 {
        self.rotations.load(Ordering::Relaxed)
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tempdir::TempDir;

    #[test]
    fn appends_accumulate_and_survive_reopen() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("events.jsonl");
        let log = EventLog::open(path.clone(), DEFAULT_LOG_MAX_BYTES).unwrap();
        log.append(r#"{"event":"a"}"#);
        log.append(r#"{"event":"b"}"#);
        drop(log);
        let log = EventLog::open(path.clone(), DEFAULT_LOG_MAX_BYTES).unwrap();
        log.append(r#"{"event":"c"}"#);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert_eq!(log.dropped(), 0);
    }

    #[test]
    fn rotation_caps_the_live_file_and_keeps_one_generation() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("events.jsonl");
        // 40-byte budget: every line is 14 bytes, so the live file holds
        // at most two lines before the next append rotates it out.
        let log = EventLog::open(path.clone(), 40).unwrap();
        for i in 0..7 {
            log.append(&format!(r#"{{"event":"{i}"}}"#));
        }
        assert!(log.rotations() >= 2, "rotations: {}", log.rotations());
        assert_eq!(log.dropped(), 0);
        let live = std::fs::read_to_string(&path).unwrap();
        assert!(live.len() as u64 <= 40, "live file over budget: {live:?}");
        assert!(live.contains(r#"{"event":"6"}"#), "newest line in live file");
        let old = std::fs::read_to_string(dir.path().join("events.jsonl.1")).unwrap();
        assert!(!old.is_empty());
        for line in live.lines().chain(old.lines()) {
            crate::util::json::Json::parse(line).unwrap();
        }
    }

    #[test]
    fn write_errors_are_counted_not_swallowed() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("events.jsonl");
        std::fs::write(&path, "").unwrap();
        // A read-only handle makes every write fail deterministically.
        let file = File::open(&path).unwrap();
        let log = EventLog {
            path: path.clone(),
            max_bytes: u64::MAX,
            inner: Mutex::new(Inner { file, bytes: 0 }),
            dropped: AtomicU64::new(0),
            rotations: AtomicU64::new(0),
        };
        log.append(r#"{"event":"lost"}"#);
        log.append(r#"{"event":"lost"}"#);
        assert_eq!(log.dropped(), 2);
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "");
    }
}
