//! [`JobQueue`] — the file-spool job queue.
//!
//! Layout under the configured jobs directory (`artifacts/jobs` by
//! default):
//!
//! ```text
//! jobs/
//!   pending/<id>.json          submitted specs, claimed oldest-id first
//!   running/<id>.json          specs currently executing (crash evidence)
//!   done/<id>.json             JobResult per completed job
//!   failed/<id>.json           quarantined spec of a failed job
//!   failed/<id>.error.json     {"id", "error"} recorded next to it
//!   server.log.jsonl           append-only lifecycle event stream
//! ```
//!
//! Claiming is an atomic `rename(pending/x, running/x)`: the filesystem is
//! the arbiter, so any number of workers — across threads *and* processes
//! — can race on one queue and every spec is claimed exactly once (the
//! rename loser sees `NotFound` and moves to the next file). Submission is
//! the same temp-write + rename discipline the dataset store uses, so a
//! watcher never observes a half-written spec.

use super::spec::{JobResult, JobSpec};
use crate::error::{Error, Result};
use crate::util::json::Json;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// Process-local uniquifier for submit temp files: two threads racing on
/// one id must not share a temp path (the PID alone can't tell them
/// apart).
static SUBMIT_SEQ: AtomicU64 = AtomicU64::new(0);

/// Spool subdirectories, in lifecycle order.
pub const QUEUE_SUBDIRS: [&str; 4] = ["pending", "running", "done", "failed"];

/// A claimed job: its queue id and the spec's `running/` path.
#[derive(Debug, Clone)]
pub struct ClaimedJob {
    pub id: String,
    pub path: PathBuf,
}

/// Point-in-time spool census (`pending` excludes in-flight temp files,
/// `failed` excludes the `.error.json` records).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueueCounts {
    pub pending: usize,
    pub running: usize,
    pub done: usize,
    pub failed: usize,
}

/// File-spool queue rooted at one directory (see module docs).
pub struct JobQueue {
    dir: PathBuf,
}

impl JobQueue {
    /// Open (creating the spool layout if needed).
    pub fn open(dir: PathBuf) -> Result<JobQueue> {
        for sub in QUEUE_SUBDIRS {
            std::fs::create_dir_all(dir.join(sub))?;
        }
        Ok(JobQueue { dir })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn sub(&self, name: &str) -> PathBuf {
        self.dir.join(name)
    }

    fn spec_path(&self, state: &str, id: &str) -> PathBuf {
        self.sub(state).join(format!("{id}.json"))
    }

    /// Validate and enqueue `spec` into `pending/`. The id must be new to
    /// the whole spool — a duplicate in any lifecycle state is rejected so
    /// results are never silently overwritten. The spec is written to a
    /// submitter-unique temp file and *linked* (not renamed) into place:
    /// `hard_link` refuses an existing destination, so two processes
    /// racing on one id get exactly one winner — the loser errors instead
    /// of silently replacing the winner's spec.
    pub fn submit(&self, spec: &JobSpec) -> Result<PathBuf> {
        spec.validate()?;
        let duplicate = |state: &str| {
            Error::Config(format!(
                "job id `{}` already present in {state}/ — pick a fresh id",
                spec.id
            ))
        };
        for state in QUEUE_SUBDIRS {
            if self.spec_path(state, &spec.id).exists() {
                return Err(duplicate(state));
            }
        }
        let dest = self.spec_path("pending", &spec.id);
        let seq = SUBMIT_SEQ.fetch_add(1, Ordering::Relaxed);
        let tmp = self
            .sub("pending")
            .join(format!(".{}.{}-{seq}.tmp", spec.id, std::process::id()));
        std::fs::write(&tmp, spec.to_json().to_string())?;
        let linked = std::fs::hard_link(&tmp, &dest);
        let _ = std::fs::remove_file(&tmp);
        match linked {
            Ok(()) => Ok(dest),
            Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                Err(duplicate("pending"))
            }
            Err(e) => Err(e.into()),
        }
    }

    /// Sorted ids of the real spec files in one spool state (temp files
    /// and `.error.json` records excluded).
    fn ids_in(&self, state: &str) -> Result<Vec<String>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(self.sub(state))? {
            let name = entry?.file_name();
            let name = name.to_string_lossy().into_owned();
            if name.starts_with('.') || name.ends_with(".error.json") {
                continue;
            }
            if let Some(stem) = name.strip_suffix(".json") {
                out.push(stem.to_string());
            }
        }
        out.sort();
        Ok(out)
    }

    /// Claim the oldest pending job (lexicographic id order) by renaming
    /// its spec into `running/`. `Ok(None)` when the queue is empty; a
    /// concurrently-claimed file is skipped, not an error.
    pub fn claim(&self) -> Result<Option<ClaimedJob>> {
        for id in self.ids_in("pending")? {
            let from = self.spec_path("pending", &id);
            let to = self.spec_path("running", &id);
            match std::fs::rename(&from, &to) {
                Ok(()) => return Ok(Some(ClaimedJob { id, path: to })),
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => continue,
                Err(e) => return Err(e.into()),
            }
        }
        Ok(None)
    }

    /// Record a completed job: result written to `done/<id>.json` (temp +
    /// rename), the consumed spec removed from `running/`.
    pub fn complete(&self, id: &str, result: &JobResult) -> Result<PathBuf> {
        let dest = self.spec_path("done", id);
        let tmp = self.sub("done").join(format!(".{id}.tmp"));
        std::fs::write(&tmp, result.to_json().to_string())?;
        std::fs::rename(&tmp, &dest)?;
        // The consumed spec; a missing file (crash replay) is fine.
        let _ = std::fs::remove_file(self.spec_path("running", id));
        Ok(dest)
    }

    /// Quarantine a failed job: the spec moves `running/` → `failed/` and
    /// the error is recorded next to it as `failed/<id>.error.json`.
    pub fn fail(&self, id: &str, error: &str) -> Result<PathBuf> {
        let spec_dest = self.spec_path("failed", id);
        // The spec may be gone (e.g. it never parsed and was consumed by a
        // crash); the error record is the part that must land.
        let _ = std::fs::rename(self.spec_path("running", id), &spec_dest);
        let record = Json::obj(vec![
            ("id", Json::Str(id.to_string())),
            ("error", Json::Str(error.to_string())),
        ]);
        let dest = self.sub("failed").join(format!("{id}.error.json"));
        let tmp = self.sub("failed").join(format!(".{id}.error.tmp"));
        std::fs::write(&tmp, record.to_string())?;
        std::fs::rename(&tmp, &dest)?;
        Ok(dest)
    }

    /// Parse the recorded result of a completed job.
    pub fn result(&self, id: &str) -> Result<JobResult> {
        JobResult::parse(&std::fs::read_to_string(self.spec_path("done", id))?)
    }

    /// The recorded error message of a failed job.
    pub fn error(&self, id: &str) -> Result<String> {
        let path = self.sub("failed").join(format!("{id}.error.json"));
        let v = Json::parse(&std::fs::read_to_string(&path)?)?;
        v.get("error")
            .and_then(Json::as_str)
            .map(String::from)
            .ok_or_else(|| Error::Dataset(format!("{}: no error field", path.display())))
    }

    /// Sorted ids currently in `done/`.
    pub fn done_ids(&self) -> Result<Vec<String>> {
        self.ids_in("done")
    }

    /// Sorted ids currently in `failed/`.
    pub fn failed_ids(&self) -> Result<Vec<String>> {
        self.ids_in("failed")
    }

    pub fn counts(&self) -> Result<QueueCounts> {
        Ok(QueueCounts {
            pending: self.ids_in("pending")?.len(),
            running: self.ids_in("running")?.len(),
            done: self.ids_in("done")?.len(),
            failed: self.ids_in("failed")?.len(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tempdir::TempDir;

    fn queue() -> (TempDir, JobQueue) {
        let dir = TempDir::new().unwrap();
        let q = JobQueue::open(dir.path().join("jobs")).unwrap();
        (dir, q)
    }

    #[test]
    fn spool_layout_created_on_open() {
        let (_dir, q) = queue();
        for sub in QUEUE_SUBDIRS {
            assert!(q.dir().join(sub).is_dir());
        }
        assert_eq!(
            q.counts().unwrap(),
            QueueCounts { pending: 0, running: 0, done: 0, failed: 0 }
        );
        assert!(q.claim().unwrap().is_none());
    }

    #[test]
    fn submit_claim_order_and_duplicate_rejection() {
        let (_dir, q) = queue();
        q.submit(&JobSpec::new("b", vec![0.5])).unwrap();
        q.submit(&JobSpec::new("a", vec![0.7])).unwrap();
        assert_eq!(q.counts().unwrap().pending, 2);
        assert!(q.submit(&JobSpec::new("a", vec![0.5])).is_err(), "duplicate id");
        assert!(q.submit(&JobSpec::new("", vec![0.5])).is_err(), "invalid spec");

        let first = q.claim().unwrap().unwrap();
        assert_eq!(first.id, "a", "oldest id first");
        assert!(first.path.ends_with("running/a.json"));
        let parsed = JobSpec::parse(&std::fs::read_to_string(&first.path).unwrap());
        assert_eq!(parsed.unwrap().factors, vec![0.7]);
        // A claimed id still blocks resubmission (it lives in running/).
        assert!(q.submit(&JobSpec::new("a", vec![0.5])).is_err());

        let second = q.claim().unwrap().unwrap();
        assert_eq!(second.id, "b");
        assert!(q.claim().unwrap().is_none());
        assert_eq!(
            q.counts().unwrap(),
            QueueCounts { pending: 0, running: 2, done: 0, failed: 0 }
        );
        // No temp-file debris survives a submission.
        let stray: Vec<_> = std::fs::read_dir(q.sub("pending"))
            .unwrap()
            .map(|e| e.unwrap().file_name())
            .collect();
        assert!(stray.is_empty(), "leftover files: {stray:?}");
    }

    #[test]
    fn racing_submissions_of_one_id_get_exactly_one_winner() {
        let (_dir, q) = queue();
        let outcomes: Vec<bool> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|k| {
                    let q = &q;
                    s.spawn(move || {
                        q.submit(&JobSpec::new("sweep", vec![0.1 * (k + 1) as f64]))
                            .is_ok()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(
            outcomes.iter().filter(|&&ok| ok).count(),
            1,
            "exactly one submitter wins; the rest see a duplicate error"
        );
        assert_eq!(q.counts().unwrap().pending, 1);
        // The winner's spec is intact (not a torn interleaving).
        let spec = JobSpec::parse(
            &std::fs::read_to_string(q.spec_path("pending", "sweep")).unwrap(),
        )
        .unwrap();
        assert_eq!(spec.factors.len(), 1);
        spec.validate().unwrap();
    }

    #[test]
    fn concurrent_claims_hand_out_each_job_exactly_once() {
        let (_dir, q) = queue();
        for i in 0..12 {
            q.submit(&JobSpec::new(format!("j{i:02}"), vec![0.5])).unwrap();
        }
        let claimed = std::sync::Mutex::new(Vec::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    while let Some(job) = q.claim().unwrap() {
                        claimed.lock().unwrap().push(job.id);
                    }
                });
            }
        });
        let mut ids = claimed.into_inner().unwrap();
        ids.sort();
        let want: Vec<String> = (0..12).map(|i| format!("j{i:02}")).collect();
        assert_eq!(ids, want, "every job claimed exactly once");
    }

    #[test]
    fn complete_and_fail_move_specs_through_the_spool() {
        let (_dir, q) = queue();
        q.submit(&JobSpec::new("ok", vec![0.5])).unwrap();
        q.submit(&JobSpec::new("sad", vec![0.5])).unwrap();
        let ok = q.claim().unwrap().unwrap();
        let sad = q.claim().unwrap().unwrap();

        let result = JobResult {
            id: ok.id.clone(),
            operator: crate::operator::Operator::ADD8,
            factors: Vec::new(),
            wall_ms: 1,
        };
        q.complete(&ok.id, &result).unwrap();
        assert_eq!(q.result("ok").unwrap(), result);

        q.fail(&sad.id, "synthetic failure").unwrap();
        assert_eq!(q.error("sad").unwrap(), "synthetic failure");
        assert!(q.spec_path("failed", "sad").exists(), "spec quarantined");

        assert_eq!(
            q.counts().unwrap(),
            QueueCounts { pending: 0, running: 0, done: 1, failed: 1 }
        );
        assert_eq!(q.done_ids().unwrap(), vec!["ok"]);
        assert_eq!(q.failed_ids().unwrap(), vec!["sad"], "error record not counted");
    }
}
