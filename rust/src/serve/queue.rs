//! [`JobQueue`] — the file-spool job queue.
//!
//! Layout under the configured jobs directory (`artifacts/jobs` by
//! default):
//!
//! ```text
//! jobs/
//!   pending/<id>.json          submitted specs, claimed oldest-id first
//!   running/<id>.json          specs currently executing (crash evidence)
//!   running/.<id>.pid          claim sidecar: the holder's PID
//!   running/.<id>.revivals     retry ledger: times the id was revived
//!   done/<id>.json             JobResult per completed job
//!   failed/<id>.json           quarantined spec of a failed job
//!   failed/<id>.error.json     {"id", "error"} recorded next to it
//!   timeline/<id>.jsonl        per-job lifecycle stamps (see below)
//!   server.log.jsonl           append-only lifecycle event stream
//! ```
//!
//! Every lifecycle transition also appends a best-effort stamp to the
//! job's `timeline/<id>.jsonl` sidecar — `{"event", "unix_ms",
//! "mono_ns", "pid"}` — which `GET /jobs/<id>/timeline` reads back to
//! compute queue-wait and execute durations. Dedup-shared jobs keep the
//! original submit stamp: duplicates never re-stamp.
//!
//! Claiming is an atomic `rename(pending/x, running/x)`: the filesystem is
//! the arbiter, so any number of workers — across threads *and* processes
//! — can race on one queue and every spec is claimed exactly once (the
//! rename loser sees `NotFound` and moves to the next file). Submission is
//! the same temp-write + rename discipline the dataset store uses, so a
//! watcher never observes a half-written spec.

use super::spec::{JobResult, JobSpec};
use crate::error::{Error, Result};
use crate::util::json::Json;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// Process-local uniquifier for submit temp files: two threads racing on
/// one id must not share a temp path (the PID alone can't tell them
/// apart).
static SUBMIT_SEQ: AtomicU64 = AtomicU64::new(0);

/// Spool subdirectories, in lifecycle order.
pub const QUEUE_SUBDIRS: [&str; 4] = ["pending", "running", "done", "failed"];

/// Retry budget for crash revival: an orphaned `running/` spec is swept
/// back into `pending/` at most this many times before the sweep judges
/// it a crash loop (the job itself is what kills its claimers) and
/// quarantines it to `failed/` with a recorded error.
pub const MAX_REVIVALS: u32 = 3;

/// How long a sidecar-less `running/` entry must sit untouched before
/// [`JobQueue::requeue_stale`] treats it as abandoned. A claimer killed
/// between the claim rename and the PID-sidecar write leaves no liveness
/// evidence at all; age is the only signal left, and anything younger
/// than this may simply be a claim in progress.
pub const ORPHAN_GRACE: std::time::Duration = std::time::Duration::from_secs(10);

/// The resolved orphan grace: `REPRO_ORPHAN_GRACE_MS` (torture tests
/// shrink the window to milliseconds) over [`ORPHAN_GRACE`].
fn orphan_grace() -> std::time::Duration {
    std::env::var("REPRO_ORPHAN_GRACE_MS")
        .ok()
        .and_then(|v| v.trim().parse::<u64>().ok())
        .map(std::time::Duration::from_millis)
        .unwrap_or(ORPHAN_GRACE)
}

/// What one [`JobQueue::requeue_stale`] sweep did: ids revived into
/// `pending/`, ids that burned their [`MAX_REVIVALS`] budget and were
/// quarantined to `failed/` instead, finished ids whose `running/`
/// leftovers were cleaned up, and orphaned submit temp files removed.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RequeueReport {
    pub requeued: Vec<String>,
    pub quarantined: Vec<String>,
    /// Ids found in both `done/` and `running/` — a crash hit between
    /// `complete`'s publish rename and its cleanup. The result already
    /// exists, so the sweep finishes the cleanup instead of reviving
    /// (which would execute the job twice).
    pub cleaned: Vec<String>,
    /// `pending/` submit temps whose writing process is provably dead
    /// (file names; the PID embedded in the name no longer runs).
    pub swept_temps: Vec<String>,
}

impl RequeueReport {
    pub fn is_empty(&self) -> bool {
        self.requeued.is_empty()
            && self.quarantined.is_empty()
            && self.cleaned.is_empty()
            && self.swept_temps.is_empty()
    }
}

/// Parse the submitter PID out of a `.{id}.{pid}-{seq}.tmp` submit-temp
/// file name; `None` for anything that is not a submit temp.
fn submit_temp_pid(name: &str) -> Option<u32> {
    let rest = name.strip_prefix('.')?.strip_suffix(".tmp")?;
    let (_, tail) = rest.rsplit_once('.')?;
    let (pid, seq) = tail.split_once('-')?;
    seq.parse::<u64>().ok()?;
    pid.parse::<u32>().ok()
}

/// A claimed job: its queue id and the spec's `running/` path.
#[derive(Debug, Clone)]
pub struct ClaimedJob {
    pub id: String,
    pub path: PathBuf,
}

/// Lifecycle state of a spooled job (one per spool subdirectory).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    Pending,
    Running,
    Done,
    Failed,
}

impl JobState {
    /// The spool subdirectory this state lives in.
    pub fn as_str(self) -> &'static str {
        match self {
            JobState::Pending => "pending",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
        }
    }
}

/// Outcome of a [`JobQueue::try_submit`]: either the spec landed in
/// `pending/`, or an identical id already lives somewhere in the spool.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Submission {
    Submitted(PathBuf),
    Duplicate(JobState),
}

/// Point-in-time spool census (`pending` excludes in-flight temp files,
/// `failed` excludes the `.error.json` records).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueueCounts {
    pub pending: usize,
    pub running: usize,
    pub done: usize,
    pub failed: usize,
}

/// One line of a job's `timeline/<id>.jsonl` sidecar: which lifecycle
/// event happened, when on the wall clock (for display and cross-process
/// math), when on this process's monotonic clock (for exact same-process
/// durations), and which process stamped it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TimelineStamp {
    pub event: String,
    pub unix_ms: u64,
    pub mono_ns: u64,
    pub pid: u64,
}

impl TimelineStamp {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("event", Json::Str(self.event.clone())),
            ("unix_ms", Json::Num(self.unix_ms as f64)),
            ("mono_ns", Json::Num(self.mono_ns as f64)),
            ("pid", Json::Num(self.pid as f64)),
        ])
    }

    fn parse(v: &Json) -> Option<TimelineStamp> {
        Some(TimelineStamp {
            event: v.get("event")?.as_str()?.to_string(),
            unix_ms: v.get("unix_ms")?.as_u64()?,
            mono_ns: v.get("mono_ns")?.as_u64()?,
            pid: v.get("pid")?.as_u64()?,
        })
    }
}

/// Nanoseconds between the first `from` stamp and the first `to` stamp of
/// a timeline: the exact monotonic difference when one process stamped
/// both, the wall-clock difference (millisecond resolution) when the
/// stamps came from different processes.
pub fn stamp_gap_ns(stamps: &[TimelineStamp], from: &str, to: &str) -> Option<u64> {
    let a = stamps.iter().find(|s| s.event == from)?;
    let b = stamps.iter().find(|s| s.event == to)?;
    if a.pid == b.pid {
        Some(b.mono_ns.saturating_sub(a.mono_ns))
    } else {
        Some(b.unix_ms.saturating_sub(a.unix_ms) * 1_000_000)
    }
}

/// File-spool queue rooted at one directory (see module docs).
pub struct JobQueue {
    dir: PathBuf,
}

impl JobQueue {
    /// Open (creating the spool layout if needed).
    pub fn open(dir: PathBuf) -> Result<JobQueue> {
        for sub in QUEUE_SUBDIRS {
            std::fs::create_dir_all(dir.join(sub))?;
        }
        std::fs::create_dir_all(dir.join("timeline"))?;
        Ok(JobQueue { dir })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn sub(&self, name: &str) -> PathBuf {
        self.dir.join(name)
    }

    fn spec_path(&self, state: &str, id: &str) -> PathBuf {
        self.sub(state).join(format!("{id}.json"))
    }

    /// The most-advanced lifecycle state holding a spec (or result) for
    /// `id`, if any. Checked newest-state first so a job observed mid
    /// transition (briefly present in two directories) reports the state
    /// it is moving *into*.
    pub fn state_of(&self, id: &str) -> Option<JobState> {
        for state in
            [JobState::Done, JobState::Failed, JobState::Running, JobState::Pending]
        {
            if self.spec_path(state.as_str(), id).exists() {
                return Some(state);
            }
        }
        None
    }

    /// Validate and enqueue `spec` into `pending/`. The id must be new to
    /// the whole spool — a duplicate in any lifecycle state is rejected so
    /// results are never silently overwritten. The spec is written to a
    /// submitter-unique temp file and *linked* (not renamed) into place:
    /// `hard_link` refuses an existing destination, so two processes
    /// racing on one id get exactly one winner — the loser errors instead
    /// of silently replacing the winner's spec.
    pub fn submit(&self, spec: &JobSpec) -> Result<PathBuf> {
        match self.try_submit(spec)? {
            Submission::Submitted(path) => Ok(path),
            Submission::Duplicate(state) => Err(Error::Config(format!(
                "job id `{}` already present in {}/ — pick a fresh id",
                spec.id,
                state.as_str()
            ))),
        }
    }

    /// [`JobQueue::submit`] with the duplicate case reported as data
    /// instead of an error — the HTTP dedup path treats "already spooled"
    /// as a cache hit, not a failure. Same atomicity guarantee: when many
    /// submitters race on one id, exactly one sees `Submitted`.
    pub fn try_submit(&self, spec: &JobSpec) -> Result<Submission> {
        spec.validate()?;
        if let Some(state) = self.state_of(&spec.id) {
            return Ok(Submission::Duplicate(state));
        }
        let dest = self.spec_path("pending", &spec.id);
        let seq = SUBMIT_SEQ.fetch_add(1, Ordering::Relaxed);
        let tmp = self
            .sub("pending")
            .join(format!(".{}.{}-{seq}.tmp", spec.id, std::process::id()));
        // Durable write (fsync) before the link publishes the spec: the
        // rename/link is atomic against concurrent readers, but only the
        // fsync makes it atomic against power loss.
        crate::fault::write_file_durable(
            "queue.submit.write",
            &tmp,
            spec.to_json().to_string().as_bytes(),
        )?;
        if let Err(e) = crate::fault::point("queue.submit.link") {
            let _ = std::fs::remove_file(&tmp);
            return Err(e.into());
        }
        let linked = std::fs::hard_link(&tmp, &dest);
        let _ = std::fs::remove_file(&tmp);
        match linked {
            Ok(()) => {
                // Only the winning submitter stamps: dedup-shared jobs
                // keep the original submit time.
                self.stamp_timeline(&spec.id, "submit");
                Ok(Submission::Submitted(dest))
            }
            Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                // Lost the link race: the winner's spec may already be
                // claimed, so report wherever it landed.
                Ok(Submission::Duplicate(
                    self.state_of(&spec.id).unwrap_or(JobState::Pending),
                ))
            }
            Err(e) => Err(e.into()),
        }
    }

    /// Sorted ids of the real spec files in one spool state (temp files
    /// and `.error.json` records excluded).
    fn ids_in(&self, state: &str) -> Result<Vec<String>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(self.sub(state))? {
            let name = entry?.file_name();
            let name = name.to_string_lossy().into_owned();
            if name.starts_with('.') || name.ends_with(".error.json") {
                continue;
            }
            if let Some(stem) = name.strip_suffix(".json") {
                out.push(stem.to_string());
            }
        }
        out.sort();
        Ok(out)
    }

    /// Path of the claim sidecar recording which process holds a
    /// `running/` spec (dot-prefixed, so spool listings skip it).
    fn pid_path(&self, id: &str) -> PathBuf {
        self.sub("running").join(format!(".{id}.pid"))
    }

    /// Path of the dot-prefixed revival ledger for `id`. It lives in
    /// `running/` next to the claim sidecar but — unlike the PID file —
    /// survives re-queue and re-claim cycles, so the count accumulates
    /// across a crash loop. Removed on `complete`/`fail`.
    fn revivals_path(&self, id: &str) -> PathBuf {
        self.sub("running").join(format!(".{id}.revivals"))
    }

    /// Times `id` has been revived so far (missing or garbled ledger = 0).
    pub fn revivals_of(&self, id: &str) -> u32 {
        std::fs::read_to_string(self.revivals_path(id))
            .ok()
            .and_then(|text| text.trim().parse().ok())
            .unwrap_or(0)
    }

    /// Claim the oldest pending job (lexicographic id order) by renaming
    /// its spec into `running/`. `Ok(None)` when the queue is empty; a
    /// concurrently-claimed file is skipped, not an error. The winner
    /// records its PID in a sidecar so [`JobQueue::requeue_stale`] can
    /// prove a claim orphaned after a crash. The sidecar is written
    /// *after* the rename — a crash in between leaks a sidecar-less
    /// claim, which the sweep ages out after [`ORPHAN_GRACE`].
    pub fn claim(&self) -> Result<Option<ClaimedJob>> {
        for id in self.ids_in("pending")? {
            let from = self.spec_path("pending", &id);
            let to = self.spec_path("running", &id);
            crate::fault::point("queue.claim.rename")?;
            match std::fs::rename(&from, &to) {
                Ok(()) => {
                    // A death between the rename and the sidecar write
                    // leaves a sidecar-less claim; requeue_stale ages it
                    // out after the orphan grace.
                    if crate::fault::point("queue.claim.pid").is_ok() {
                        let _ = std::fs::write(
                            self.pid_path(&id),
                            std::process::id().to_string(),
                        );
                    }
                    self.stamp_timeline(&id, "claim");
                    return Ok(Some(ClaimedJob { id, path: to }));
                }
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => continue,
                Err(e) => return Err(e.into()),
            }
        }
        Ok(None)
    }

    /// Sweep `running/` for claims whose recorded holder PID provably no
    /// longer runs (the dataset store's stale-lock probe applied to job
    /// claims) and move those specs back into `pending/` for re-execution.
    /// Garbled sidecars are *not* provably stale and are left alone;
    /// *missing* sidecars (claimer died mid-claim) are reaped once the
    /// entry has aged past [`ORPHAN_GRACE`]. The sweep also finishes the
    /// cleanup of jobs stranded in both `done/` and `running/` and
    /// removes provably-orphaned submit temps from `pending/`.
    /// Each revival is tallied in a per-id ledger; once an id has
    /// burned [`MAX_REVIVALS`] revivals, the sweep quarantines it to
    /// `failed/` with a recorded crash-loop error instead of cycling it
    /// forever. Meant for server start, before any worker claims — jobs
    /// are deterministic, so re-running a half-done job yields the same
    /// result the dead claimer would have recorded.
    pub fn requeue_stale(&self) -> Result<RequeueReport> {
        let mut report = RequeueReport::default();
        self.sweep_orphan_temps(&mut report)?;
        for id in self.ids_in("running")? {
            // A crash between complete()'s publish rename and its cleanup
            // leaves the id in done/ AND running/. The result already
            // exists — reviving would execute the job twice — so finish
            // the interrupted cleanup instead.
            if self.spec_path("done", &id).exists() {
                let _ = std::fs::remove_file(self.spec_path("running", &id));
                let _ = std::fs::remove_file(self.pid_path(&id));
                let _ = std::fs::remove_file(self.revivals_path(&id));
                report.cleaned.push(id);
                continue;
            }
            let pid_path = self.pid_path(&id);
            let dead = match std::fs::read_to_string(&pid_path) {
                Ok(text) => text
                    .trim()
                    .parse::<u32>()
                    .ok()
                    .is_some_and(crate::engine::store::pid_is_dead),
                // No sidecar at all: a claimer died between the claim
                // rename and the sidecar write. Nothing proves the holder
                // is dead, so fall back to age — only entries untouched
                // for the whole orphan grace are treated as abandoned.
                Err(_) => self.older_than_orphan_grace(&self.spec_path("running", &id)),
            };
            if !dead {
                continue;
            }
            let revivals = self.revivals_of(&id);
            if revivals >= MAX_REVIVALS {
                self.fail(
                    &id,
                    &format!(
                        "crash loop: claiming process died again after \
                         {revivals} revivals (budget {MAX_REVIVALS})"
                    ),
                )?;
                report.quarantined.push(id);
                continue;
            }
            let from = self.spec_path("running", &id);
            let to = self.spec_path("pending", &id);
            match std::fs::rename(&from, &to) {
                Ok(()) => {
                    // A death here revives the job without tallying it —
                    // the window the torture suite pins with this site.
                    let _ = crate::fault::point("queue.revive.ledger");
                    let ledger = self.revivals_path(&id);
                    let _ = std::fs::write(ledger, (revivals + 1).to_string());
                    let _ = std::fs::remove_file(&pid_path);
                    report.requeued.push(id);
                }
                // Another sweeper (or the job finishing late) beat us.
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => continue,
                Err(e) => return Err(e.into()),
            }
        }
        Ok(report)
    }

    /// Whether `path` has sat untouched for longer than the orphan grace
    /// (unreadable metadata = no: never reap without evidence).
    fn older_than_orphan_grace(&self, path: &Path) -> bool {
        std::fs::metadata(path)
            .and_then(|m| m.modified())
            .ok()
            .and_then(|t| t.elapsed().ok())
            .is_some_and(|age| age >= orphan_grace())
    }

    /// Remove `pending/` submit temps whose writing process is provably
    /// dead. Temp names embed the submitter PID (`.{id}.{pid}-{seq}.tmp`),
    /// so once that PID no longer runs the temp can never be linked into
    /// place — it is debris from a submitter killed between its durable
    /// write and the publishing hard link, and would otherwise live
    /// forever.
    fn sweep_orphan_temps(&self, report: &mut RequeueReport) -> Result<()> {
        for entry in std::fs::read_dir(self.sub("pending"))? {
            let entry = entry?;
            let name = entry.file_name().to_string_lossy().into_owned();
            let Some(pid) = submit_temp_pid(&name) else { continue };
            if crate::engine::store::pid_is_dead(pid)
                && std::fs::remove_file(entry.path()).is_ok()
            {
                report.swept_temps.push(name);
            }
        }
        report.swept_temps.sort();
        Ok(())
    }

    /// Record a completed job: result written to `done/<id>.json` (temp +
    /// rename), the consumed spec removed from `running/`.
    pub fn complete(&self, id: &str, result: &JobResult) -> Result<PathBuf> {
        let dest = self.spec_path("done", id);
        let tmp = self.sub("done").join(format!(".{id}.tmp"));
        crate::fault::write_file_durable(
            "queue.complete.write",
            &tmp,
            result.to_json().to_string().as_bytes(),
        )?;
        crate::fault::point("queue.complete.rename")?;
        std::fs::rename(&tmp, &dest)?;
        // A death here strands the id in done/ AND running/;
        // requeue_stale finishes this cleanup instead of reviving.
        let _ = crate::fault::point("queue.complete.cleanup");
        // The consumed spec; a missing file (crash replay) is fine.
        let _ = std::fs::remove_file(self.spec_path("running", id));
        let _ = std::fs::remove_file(self.pid_path(id));
        let _ = std::fs::remove_file(self.revivals_path(id));
        self.stamp_timeline(id, "done");
        Ok(dest)
    }

    /// Quarantine a failed job: the spec moves `running/` → `failed/` and
    /// the error is recorded next to it as `failed/<id>.error.json`.
    pub fn fail(&self, id: &str, error: &str) -> Result<PathBuf> {
        let spec_dest = self.spec_path("failed", id);
        // The spec may be gone (e.g. it never parsed and was consumed by a
        // crash); the error record is the part that must land.
        let _ = std::fs::rename(self.spec_path("running", id), &spec_dest);
        let _ = std::fs::remove_file(self.pid_path(id));
        let _ = std::fs::remove_file(self.revivals_path(id));
        let record = Json::obj(vec![
            ("id", Json::Str(id.to_string())),
            ("error", Json::Str(error.to_string())),
        ]);
        let dest = self.sub("failed").join(format!("{id}.error.json"));
        let tmp = self.sub("failed").join(format!(".{id}.error.tmp"));
        crate::fault::write_file_durable("queue.fail.write", &tmp, record.to_string().as_bytes())?;
        std::fs::rename(&tmp, &dest)?;
        self.stamp_timeline(id, "fail");
        Ok(dest)
    }

    /// Parse the recorded result of a completed job.
    pub fn result(&self, id: &str) -> Result<JobResult> {
        JobResult::parse(&self.result_text(id)?)
    }

    /// The recorded result exactly as written to `done/<id>.json` — the
    /// HTTP result endpoint serves this pass-through, so a network client
    /// reads bit-identical bytes to a direct spool reader.
    pub fn result_text(&self, id: &str) -> Result<String> {
        Ok(std::fs::read_to_string(self.spec_path("done", id))?)
    }

    /// The recorded error message of a failed job.
    pub fn error(&self, id: &str) -> Result<String> {
        let path = self.sub("failed").join(format!("{id}.error.json"));
        let v = Json::parse(&std::fs::read_to_string(&path)?)?;
        v.get("error")
            .and_then(Json::as_str)
            .map(String::from)
            .ok_or_else(|| Error::Dataset(format!("{}: no error field", path.display())))
    }

    /// Sorted ids currently in `done/`.
    pub fn done_ids(&self) -> Result<Vec<String>> {
        self.ids_in("done")
    }

    /// Sorted ids currently in `failed/`.
    pub fn failed_ids(&self) -> Result<Vec<String>> {
        self.ids_in("failed")
    }

    pub fn counts(&self) -> Result<QueueCounts> {
        Ok(QueueCounts {
            pending: self.ids_in("pending")?.len(),
            running: self.ids_in("running")?.len(),
            done: self.ids_in("done")?.len(),
            failed: self.ids_in("failed")?.len(),
        })
    }

    fn timeline_path(&self, id: &str) -> PathBuf {
        self.sub("timeline").join(format!("{id}.jsonl"))
    }

    /// Best-effort append of one lifecycle stamp to the job's timeline
    /// sidecar. Never fails the transition it annotates: a job must not
    /// die because its timeline could not be written.
    pub fn stamp_timeline(&self, id: &str, event: &str) {
        use std::io::Write as _;
        let unix_ms = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_millis() as u64)
            .unwrap_or(0);
        let stamp = TimelineStamp {
            event: event.to_string(),
            unix_ms,
            mono_ns: crate::obs::monotonic_ns(),
            pid: std::process::id() as u64,
        };
        if let Ok(mut f) = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(self.timeline_path(id))
        {
            let line = format!("{}\n", stamp.to_json());
            if let Ok(quota) = crate::fault::write_quota("queue.timeline.append", line.len())
            {
                let _ = f.write_all(&line.as_bytes()[..quota]);
            }
        }
    }

    /// The recorded lifecycle stamps of `id`, in file (= stamp) order.
    /// Torn or garbled lines (a stamper killed mid-append) are skipped
    /// with a warning, a missing sidecar is an error.
    pub fn timeline(&self, id: &str) -> Result<Vec<TimelineStamp>> {
        let text = std::fs::read_to_string(self.timeline_path(id))?;
        let mut out = Vec::new();
        let mut skipped = 0usize;
        for line in text.lines() {
            match Json::parse(line).ok().as_ref().and_then(TimelineStamp::parse) {
                Some(stamp) => out.push(stamp),
                None => skipped += 1,
            }
        }
        if skipped > 0 {
            eprintln!(
                "warning: timeline {}: skipped {skipped} torn/garbled line(s)",
                self.timeline_path(id).display()
            );
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tempdir::TempDir;

    fn queue() -> (TempDir, JobQueue) {
        let dir = TempDir::new().unwrap();
        let q = JobQueue::open(dir.path().join("jobs")).unwrap();
        (dir, q)
    }

    #[test]
    fn spool_layout_created_on_open() {
        let (_dir, q) = queue();
        for sub in QUEUE_SUBDIRS {
            assert!(q.dir().join(sub).is_dir());
        }
        assert_eq!(
            q.counts().unwrap(),
            QueueCounts { pending: 0, running: 0, done: 0, failed: 0 }
        );
        assert!(q.claim().unwrap().is_none());
    }

    #[test]
    fn submit_claim_order_and_duplicate_rejection() {
        let (_dir, q) = queue();
        q.submit(&JobSpec::new("b", vec![0.5])).unwrap();
        q.submit(&JobSpec::new("a", vec![0.7])).unwrap();
        assert_eq!(q.counts().unwrap().pending, 2);
        assert!(q.submit(&JobSpec::new("a", vec![0.5])).is_err(), "duplicate id");
        assert!(q.submit(&JobSpec::new("", vec![0.5])).is_err(), "invalid spec");

        let first = q.claim().unwrap().unwrap();
        assert_eq!(first.id, "a", "oldest id first");
        assert!(first.path.ends_with("running/a.json"));
        let parsed = JobSpec::parse(&std::fs::read_to_string(&first.path).unwrap());
        assert_eq!(parsed.unwrap().factors, vec![0.7]);
        // A claimed id still blocks resubmission (it lives in running/).
        assert!(q.submit(&JobSpec::new("a", vec![0.5])).is_err());

        let second = q.claim().unwrap().unwrap();
        assert_eq!(second.id, "b");
        assert!(q.claim().unwrap().is_none());
        assert_eq!(
            q.counts().unwrap(),
            QueueCounts { pending: 0, running: 2, done: 0, failed: 0 }
        );
        // No temp-file debris survives a submission.
        let stray: Vec<_> = std::fs::read_dir(q.sub("pending"))
            .unwrap()
            .map(|e| e.unwrap().file_name())
            .collect();
        assert!(stray.is_empty(), "leftover files: {stray:?}");
    }

    #[test]
    fn racing_submissions_of_one_id_get_exactly_one_winner() {
        let (_dir, q) = queue();
        let outcomes: Vec<bool> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|k| {
                    let q = &q;
                    s.spawn(move || {
                        q.submit(&JobSpec::new("sweep", vec![0.1 * (k + 1) as f64]))
                            .is_ok()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(
            outcomes.iter().filter(|&&ok| ok).count(),
            1,
            "exactly one submitter wins; the rest see a duplicate error"
        );
        assert_eq!(q.counts().unwrap().pending, 1);
        // The winner's spec is intact (not a torn interleaving).
        let spec = JobSpec::parse(
            &std::fs::read_to_string(q.spec_path("pending", "sweep")).unwrap(),
        )
        .unwrap();
        assert_eq!(spec.factors.len(), 1);
        spec.validate().unwrap();
    }

    #[test]
    fn concurrent_claims_hand_out_each_job_exactly_once() {
        let (_dir, q) = queue();
        for i in 0..12 {
            q.submit(&JobSpec::new(format!("j{i:02}"), vec![0.5])).unwrap();
        }
        let claimed = std::sync::Mutex::new(Vec::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    while let Some(job) = q.claim().unwrap() {
                        claimed.lock().unwrap().push(job.id);
                    }
                });
            }
        });
        let mut ids = claimed.into_inner().unwrap();
        ids.sort();
        let want: Vec<String> = (0..12).map(|i| format!("j{i:02}")).collect();
        assert_eq!(ids, want, "every job claimed exactly once");
    }

    #[test]
    fn complete_and_fail_move_specs_through_the_spool() {
        let (_dir, q) = queue();
        q.submit(&JobSpec::new("ok", vec![0.5])).unwrap();
        q.submit(&JobSpec::new("sad", vec![0.5])).unwrap();
        let ok = q.claim().unwrap().unwrap();
        let sad = q.claim().unwrap().unwrap();

        let result = JobResult {
            id: ok.id.clone(),
            operator: crate::operator::Operator::ADD8,
            factors: Vec::new(),
            wall_ms: 1,
        };
        q.complete(&ok.id, &result).unwrap();
        assert_eq!(q.result("ok").unwrap(), result);

        q.fail(&sad.id, "synthetic failure").unwrap();
        assert_eq!(q.error("sad").unwrap(), "synthetic failure");
        assert!(q.spec_path("failed", "sad").exists(), "spec quarantined");

        assert_eq!(
            q.counts().unwrap(),
            QueueCounts { pending: 0, running: 0, done: 1, failed: 1 }
        );
        assert_eq!(q.done_ids().unwrap(), vec!["ok"]);
        assert_eq!(q.failed_ids().unwrap(), vec!["sad"], "error record not counted");
    }

    #[test]
    fn state_of_tracks_the_lifecycle() {
        let (_dir, q) = queue();
        assert_eq!(q.state_of("j"), None);
        q.submit(&JobSpec::new("j", vec![0.5])).unwrap();
        assert_eq!(q.state_of("j"), Some(JobState::Pending));
        let job = q.claim().unwrap().unwrap();
        assert_eq!(q.state_of("j"), Some(JobState::Running));
        let result = JobResult {
            id: job.id.clone(),
            operator: crate::operator::Operator::ADD8,
            factors: Vec::new(),
            wall_ms: 1,
        };
        q.complete(&job.id, &result).unwrap();
        assert_eq!(q.state_of("j"), Some(JobState::Done));
    }

    #[test]
    fn try_submit_reports_duplicates_as_data() {
        let (_dir, q) = queue();
        let spec = JobSpec::new("dup", vec![0.5]);
        match q.try_submit(&spec).unwrap() {
            Submission::Submitted(path) => assert!(path.ends_with("pending/dup.json")),
            other => panic!("expected Submitted, got {other:?}"),
        }
        assert_eq!(
            q.try_submit(&spec).unwrap(),
            Submission::Duplicate(JobState::Pending)
        );
        q.claim().unwrap().unwrap();
        assert_eq!(
            q.try_submit(&spec).unwrap(),
            Submission::Duplicate(JobState::Running)
        );
        // An invalid spec is still an error, not a Duplicate.
        assert!(q.try_submit(&JobSpec::new("", vec![0.5])).is_err());
    }

    #[test]
    fn requeue_stale_revives_only_provably_dead_claims() {
        let (_dir, q) = queue();
        for id in ["dead", "live", "bare"] {
            q.submit(&JobSpec::new(id, vec![0.5])).unwrap();
        }
        while q.claim().unwrap().is_some() {}
        assert_eq!(q.counts().unwrap().running, 3);
        // Fake a crashed claimer: PID u32::MAX can't exist (PID_MAX_LIMIT
        // is 2^22 on Linux). "live" keeps our real PID; "bare" loses its
        // sidecar, as a claimer crashing mid-claim would leave it.
        std::fs::write(q.pid_path("dead"), u32::MAX.to_string()).unwrap();
        std::fs::remove_file(q.pid_path("bare")).unwrap();

        let report = q.requeue_stale().unwrap();
        if cfg!(target_os = "linux") {
            assert_eq!(report.requeued, vec!["dead"]);
            assert!(report.quarantined.is_empty());
            assert_eq!(q.state_of("dead"), Some(JobState::Pending));
            assert!(!q.pid_path("dead").exists(), "sidecar cleaned up");
            assert_eq!(q.revivals_of("dead"), 1, "revival tallied in the ledger");
        } else {
            assert!(report.is_empty(), "no liveness probe off-linux");
        }
        assert_eq!(q.state_of("live"), Some(JobState::Running));
        assert_eq!(q.state_of("bare"), Some(JobState::Running));

        // The revived spec is claimable again and completes normally.
        if cfg!(target_os = "linux") {
            let job = q.claim().unwrap().unwrap();
            assert_eq!(job.id, "dead");
            let result = JobResult {
                id: job.id.clone(),
                operator: crate::operator::Operator::ADD8,
                factors: Vec::new(),
                wall_ms: 1,
            };
            q.complete(&job.id, &result).unwrap();
            assert_eq!(q.state_of("dead"), Some(JobState::Done));
        }
    }

    #[test]
    fn crash_looping_job_is_quarantined_after_revival_budget() {
        if !cfg!(target_os = "linux") {
            return; // revival needs the PID liveness probe
        }
        let (_dir, q) = queue();
        q.submit(&JobSpec::new("loopy", vec![0.5])).unwrap();
        for round in 0..MAX_REVIVALS {
            let job = q.claim().unwrap().unwrap();
            assert_eq!(job.id, "loopy");
            // The claimer "crashes": its recorded PID can never exist
            // (PID_MAX_LIMIT is 2^22 on Linux).
            std::fs::write(q.pid_path("loopy"), u32::MAX.to_string()).unwrap();
            let report = q.requeue_stale().unwrap();
            assert_eq!(report.requeued, vec!["loopy"], "round {round}");
            assert_eq!(q.revivals_of("loopy"), round + 1);
            assert_eq!(q.state_of("loopy"), Some(JobState::Pending));
        }
        // Budget burned: the next crash quarantines instead of reviving.
        q.claim().unwrap().unwrap();
        std::fs::write(q.pid_path("loopy"), u32::MAX.to_string()).unwrap();
        let report = q.requeue_stale().unwrap();
        assert!(report.requeued.is_empty());
        assert_eq!(report.quarantined, vec!["loopy"]);
        assert_eq!(q.state_of("loopy"), Some(JobState::Failed));
        assert!(q.error("loopy").unwrap().contains("crash loop"));
        assert!(!q.pid_path("loopy").exists());
        assert!(!q.revivals_path("loopy").exists(), "ledger cleaned up");
        // A quarantined id stays quarantined across further sweeps.
        assert!(q.requeue_stale().unwrap().is_empty());
    }

    #[test]
    fn timeline_records_the_lifecycle_and_keeps_the_original_submit() {
        let (_dir, q) = queue();
        let spec = JobSpec::new("t", vec![0.5]);
        q.submit(&spec).unwrap();
        // A dedup duplicate must not re-stamp "submit".
        assert_eq!(
            q.try_submit(&spec).unwrap(),
            Submission::Duplicate(JobState::Pending)
        );
        let job = q.claim().unwrap().unwrap();
        let result = JobResult {
            id: job.id.clone(),
            operator: crate::operator::Operator::ADD8,
            factors: Vec::new(),
            wall_ms: 1,
        };
        q.complete(&job.id, &result).unwrap();
        let stamps = q.timeline("t").unwrap();
        let events: Vec<&str> = stamps.iter().map(|s| s.event.as_str()).collect();
        assert_eq!(events, vec!["submit", "claim", "done"]);
        assert!(stamps.windows(2).all(|w| w[0].mono_ns <= w[1].mono_ns));
        assert!(stamps.iter().all(|s| s.pid == std::process::id() as u64));
        assert!(q.timeline("nope").is_err(), "missing sidecar is an error");

        q.submit(&JobSpec::new("sad", vec![0.5])).unwrap();
        let sad = q.claim().unwrap().unwrap();
        q.fail(&sad.id, "synthetic").unwrap();
        let events: Vec<String> =
            q.timeline("sad").unwrap().into_iter().map(|s| s.event).collect();
        assert_eq!(events, vec!["submit", "claim", "fail"]);
    }

    #[test]
    fn submit_temp_pid_parses_only_submit_temps() {
        assert_eq!(submit_temp_pid(".job1.4321-7.tmp"), Some(4321));
        assert_eq!(submit_temp_pid(".dotted.id.99-0.tmp"), Some(99));
        assert_eq!(submit_temp_pid(".job1.tmp"), None, "complete()-style temp");
        assert_eq!(submit_temp_pid(".job1.error.tmp"), None, "fail()-style temp");
        assert_eq!(submit_temp_pid("job1.json"), None);
        assert_eq!(submit_temp_pid(".job1.x-1.tmp"), None, "non-numeric pid");
        assert_eq!(submit_temp_pid(".job1.1-x.tmp"), None, "non-numeric seq");
    }

    #[test]
    fn requeue_sweeps_orphan_temps_of_dead_submitters_only() {
        let (_dir, q) = queue();
        // Debris from a submitter killed between write and link: the PID
        // embedded in the name can never exist.
        let dead_temp = format!(".ghost.{}-0.tmp", u32::MAX);
        std::fs::write(q.sub("pending").join(&dead_temp), "{}").unwrap();
        // An in-flight temp of a live submitter (our own PID) must stay.
        let live_temp = format!(".inflight.{}-1.tmp", std::process::id());
        std::fs::write(q.sub("pending").join(&live_temp), "{}").unwrap();
        // Unrelated dot-files are not submit temps and are never touched.
        std::fs::write(q.sub("pending").join(".keepme"), "x").unwrap();

        let report = q.requeue_stale().unwrap();
        if cfg!(target_os = "linux") {
            assert_eq!(report.swept_temps, vec![dead_temp.clone()]);
            assert!(!q.sub("pending").join(&dead_temp).exists());
        } else {
            assert!(report.swept_temps.is_empty(), "no liveness probe off-linux");
        }
        assert!(q.sub("pending").join(&live_temp).exists());
        assert!(q.sub("pending").join(".keepme").exists());
        assert!(report.requeued.is_empty() && report.quarantined.is_empty());
    }

    #[test]
    fn finished_job_stranded_in_running_is_cleaned_not_revived() {
        let (_dir, q) = queue();
        q.submit(&JobSpec::new("twice", vec![0.5])).unwrap();
        let job = q.claim().unwrap().unwrap();
        let spec_bytes = std::fs::read(&job.path).unwrap();
        let result = JobResult {
            id: job.id.clone(),
            operator: crate::operator::Operator::ADD8,
            factors: Vec::new(),
            wall_ms: 1,
        };
        q.complete(&job.id, &result).unwrap();
        // Recreate the state a crash between complete()'s rename and its
        // cleanup leaves behind: the id in done/ AND running/, sidecars
        // intact, the holder dead.
        std::fs::write(q.spec_path("running", "twice"), &spec_bytes).unwrap();
        std::fs::write(q.pid_path("twice"), u32::MAX.to_string()).unwrap();
        std::fs::write(q.revivals_path("twice"), "1").unwrap();

        let report = q.requeue_stale().unwrap();
        assert_eq!(report.cleaned, vec!["twice"]);
        assert!(report.requeued.is_empty(), "a finished job must never requeue");
        assert_eq!(q.state_of("twice"), Some(JobState::Done));
        assert!(!q.spec_path("running", "twice").exists());
        assert!(!q.pid_path("twice").exists());
        assert!(!q.revivals_path("twice").exists());
        assert_eq!(q.result("twice").unwrap(), result, "result untouched");
        // The cleanup is idempotent: a second sweep finds nothing.
        assert!(q.requeue_stale().unwrap().is_empty());
    }

    #[test]
    fn completed_jobs_leave_no_pid_sidecars() {
        let (_dir, q) = queue();
        q.submit(&JobSpec::new("a", vec![0.5])).unwrap();
        let job = q.claim().unwrap().unwrap();
        assert!(q.pid_path("a").exists(), "claim records its holder");
        let result = JobResult {
            id: job.id.clone(),
            operator: crate::operator::Operator::ADD8,
            factors: Vec::new(),
            wall_ms: 1,
        };
        q.complete(&job.id, &result).unwrap();
        assert!(!q.pid_path("a").exists());

        q.submit(&JobSpec::new("b", vec![0.5])).unwrap();
        let job = q.claim().unwrap().unwrap();
        q.fail(&job.id, "synthetic").unwrap();
        assert!(!q.pid_path("b").exists());
    }
}
