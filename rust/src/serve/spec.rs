//! [`JobSpec`] / [`JobResult`] — the serve-mode wire schema.
//!
//! Hand-rolled JSON over [`util::json`](crate::util::json), matching the
//! rest of the repo (no serde in the hermetic build). A spec describes one
//! queued job: which operator to search, which constraint scaling factors,
//! how ConSS seeds are selected, and optional GA overrides — exactly the
//! knobs of [`DseJob`], so a spec resolves losslessly to the jobs a direct
//! library caller would run. Unknown keys are rejected (the same typo
//! protection as `expcfg`).

use crate::conss::SeedSelection;
use crate::engine::{DseJob, DseOutcome};
use crate::error::{Error, Result};
use crate::expcfg::GaConfig;
use crate::operator::Operator;
use crate::util::json::Json;

/// One queued DSE job: a factor sweep (one [`DseJob`] per factor) over one
/// operator, with optional seed-selection / GA overrides.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Queue identity; becomes the spool filename (`<id>.json`), so it is
    /// restricted to filesystem-safe characters.
    pub id: String,
    /// Operator under DSE; `None` = the server configuration's operator.
    pub operator: Option<Operator>,
    /// Constraint scaling factors, one sub-search each (paper §V-D).
    pub factors: Vec<f64>,
    /// Which L designs seed the supersampler (ablation knob).
    pub seed_selection: SeedSelection,
    /// GA overrides; `None` = the server configuration's `[ga]` section.
    pub ga: Option<GaConfig>,
    /// GA RNG seed override; `None` = the server configuration's seed.
    pub ga_seed: Option<u64>,
}

impl JobSpec {
    pub fn new(id: impl Into<String>, factors: Vec<f64>) -> JobSpec {
        JobSpec {
            id: id.into(),
            operator: None,
            factors,
            seed_selection: SeedSelection::All,
            ga: None,
            ga_seed: None,
        }
    }

    /// Spool-filename and search validity: a usable id, at least one
    /// factor, every factor in (0, 1] (the same constraint scaling domain
    /// `expcfg` enforces), a sane GA override.
    pub fn validate(&self) -> Result<()> {
        if self.id.is_empty() {
            return Err(Error::Config("job spec needs a non-empty id".into()));
        }
        if !self
            .id
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.'))
        {
            return Err(Error::Config(format!(
                "job id `{}` has characters outside [A-Za-z0-9._-]",
                self.id
            )));
        }
        // Ids whose spool filename the queue itself hides (dot-prefixed
        // temp files) or claims ("<id>.error.json" records) would submit
        // fine and then never be claimable — reject them up front.
        if self.id.starts_with('.') || self.id.ends_with(".error") {
            return Err(Error::Config(format!(
                "job id `{}` collides with spool-internal names \
                 (no leading `.`, no trailing `.error`)",
                self.id
            )));
        }
        if self.factors.is_empty() {
            return Err(Error::Config(format!(
                "job `{}` needs at least one scaling factor",
                self.id
            )));
        }
        for &f in &self.factors {
            if !(0.0 < f && f <= 1.0) {
                return Err(Error::Config(format!(
                    "job `{}`: scaling factor {f} outside (0, 1]",
                    self.id
                )));
            }
        }
        if let Some(ga) = &self.ga {
            if ga.pop_size < 2 {
                return Err(Error::Config(format!(
                    "job `{}`: ga.pop_size must be >= 2",
                    self.id
                )));
            }
        }
        Ok(())
    }

    /// The [`DseJob`]s this spec resolves to, one per factor in order.
    pub fn to_jobs(&self) -> Vec<DseJob> {
        self.factors
            .iter()
            .map(|&f| {
                let mut job = DseJob::new(f).seed_selection(self.seed_selection);
                if let Some(ga) = &self.ga {
                    job = job.ga(ga.clone());
                }
                if let Some(seed) = self.ga_seed {
                    job = job.ga_seed(seed);
                }
                job
            })
            .collect()
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("id", Json::Str(self.id.clone())),
            ("factors", Json::arr_f64(&self.factors)),
            ("seed_selection", Json::Str(self.seed_selection.name().into())),
        ];
        if let Some(op) = self.operator {
            pairs.push(("operator", Json::Str(op.name())));
        }
        if let Some(ga) = &self.ga {
            let mut g = vec![
                ("pop_size", Json::Num(ga.pop_size as f64)),
                ("generations", Json::Num(ga.generations as f64)),
                ("crossover_prob", Json::Num(ga.crossover_prob)),
                ("tournament_size", Json::Num(ga.tournament_size as f64)),
            ];
            if let Some(m) = ga.mutation_prob {
                g.push(("mutation_prob", Json::Num(m)));
            }
            pairs.push(("ga", Json::obj(g)));
        }
        if let Some(seed) = self.ga_seed {
            pairs.push(("ga_seed", Json::Num(seed as f64)));
        }
        Json::obj(pairs)
    }

    /// Parse and validate a spec. `id` may be omitted in the JSON (the
    /// submit path fills it from the spool filename before validation).
    pub fn from_json(v: &Json) -> Result<JobSpec> {
        let obj = v
            .as_obj()
            .ok_or_else(|| Error::Config("job spec must be a JSON object".into()))?;
        let bad = |key: &str, want: &str| {
            Error::Config(format!("job spec key `{key}` must be {want}"))
        };
        let mut spec = JobSpec::new("", Vec::new());
        for (key, value) in obj {
            match key.as_str() {
                "id" => {
                    spec.id =
                        value.as_str().ok_or_else(|| bad(key, "a string"))?.to_string()
                }
                "operator" => {
                    let name = value.as_str().ok_or_else(|| bad(key, "a string"))?;
                    spec.operator = Some(Operator::from_name(name)?);
                }
                "factors" => {
                    spec.factors = value
                        .as_arr()
                        .and_then(|a| {
                            a.iter().map(Json::as_f64).collect::<Option<Vec<f64>>>()
                        })
                        .ok_or_else(|| bad(key, "a number array"))?;
                }
                "seed_selection" => {
                    let name = value.as_str().ok_or_else(|| bad(key, "a string"))?;
                    spec.seed_selection = SeedSelection::from_name(name).ok_or_else(
                        || bad(key, "all|pareto-only|constraint-filtered"),
                    )?;
                }
                "ga" => spec.ga = Some(parse_ga(value)?),
                "ga_seed" => {
                    spec.ga_seed =
                        Some(value.as_u64().ok_or_else(|| {
                            bad(key, "a non-negative integer")
                        })?)
                }
                other => {
                    return Err(Error::Config(format!("unknown job spec key `{other}`")))
                }
            }
        }
        Ok(spec)
    }

    /// [`JobSpec::from_json`] over raw text.
    pub fn parse(text: &str) -> Result<JobSpec> {
        JobSpec::from_json(&Json::parse(text)?)
    }
}

/// Parse a spec's `ga` override: the crate-default [`GaConfig`] with the
/// given fields replaced (a spec overrides knobs relative to the paper
/// defaults, not the server's — the server config is reachable by simply
/// omitting the section).
fn parse_ga(v: &Json) -> Result<GaConfig> {
    let obj = v
        .as_obj()
        .ok_or_else(|| Error::Config("job spec key `ga` must be an object".into()))?;
    let bad = |key: &str, want: &str| {
        Error::Config(format!("job spec key `ga.{key}` must be {want}"))
    };
    let mut ga = GaConfig::default();
    for (key, value) in obj {
        match key.as_str() {
            "pop_size" => {
                ga.pop_size = value.as_usize().ok_or_else(|| bad(key, "an integer"))?
            }
            "generations" => {
                ga.generations =
                    value.as_usize().ok_or_else(|| bad(key, "an integer"))? as u32
            }
            "crossover_prob" => {
                ga.crossover_prob = value.as_f64().ok_or_else(|| bad(key, "a number"))?
            }
            "mutation_prob" => {
                ga.mutation_prob =
                    Some(value.as_f64().ok_or_else(|| bad(key, "a number"))?)
            }
            "tournament_size" => {
                ga.tournament_size =
                    value.as_usize().ok_or_else(|| bad(key, "an integer"))?
            }
            other => {
                return Err(Error::Config(format!("unknown job spec key `ga.{other}`")))
            }
        }
    }
    Ok(ga)
}

/// One factor's outcome inside a [`JobResult`] — the paper's four-method
/// comparison (TRAIN / GA / ConSS / ConSS+GA) reduced to hypervolumes.
#[derive(Debug, Clone, PartialEq)]
pub struct FactorResult {
    pub factor: f64,
    pub hv_train: f64,
    pub hv_ga: f64,
    pub hv_conss: f64,
    pub hv_conss_ga: f64,
    pub evaluations_ga: usize,
    pub evaluations_conss_ga: usize,
    pub pool_size: usize,
    pub n_seeds: usize,
}

/// What `done/<id>.json` records for a completed job.
#[derive(Debug, Clone, PartialEq)]
pub struct JobResult {
    pub id: String,
    pub operator: Operator,
    pub factors: Vec<FactorResult>,
    pub wall_ms: u64,
}

impl JobResult {
    pub fn from_outcomes(
        id: &str,
        operator: Operator,
        outcomes: &[DseOutcome],
        wall: std::time::Duration,
    ) -> JobResult {
        JobResult {
            id: id.to_string(),
            operator,
            factors: outcomes
                .iter()
                .map(|o| FactorResult {
                    factor: o.factor,
                    hv_train: o.hv_train,
                    hv_ga: o.ga.final_hypervolume(),
                    hv_conss: o.hv_conss,
                    hv_conss_ga: o.conss_ga.final_hypervolume(),
                    evaluations_ga: o.ga.evaluations,
                    evaluations_conss_ga: o.conss_ga.evaluations,
                    pool_size: o.conss_pool.configs.len(),
                    n_seeds: o.conss_pool.n_seeds,
                })
                .collect(),
            wall_ms: wall.as_millis() as u64,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::Str(self.id.clone())),
            ("operator", Json::Str(self.operator.name())),
            ("wall_ms", Json::Num(self.wall_ms as f64)),
            (
                "factors",
                Json::Arr(
                    self.factors
                        .iter()
                        .map(|f| {
                            Json::obj(vec![
                                ("factor", Json::Num(f.factor)),
                                ("hv_train", Json::Num(f.hv_train)),
                                ("hv_ga", Json::Num(f.hv_ga)),
                                ("hv_conss", Json::Num(f.hv_conss)),
                                ("hv_conss_ga", Json::Num(f.hv_conss_ga)),
                                (
                                    "evaluations_ga",
                                    Json::Num(f.evaluations_ga as f64),
                                ),
                                (
                                    "evaluations_conss_ga",
                                    Json::Num(f.evaluations_conss_ga as f64),
                                ),
                                ("pool_size", Json::Num(f.pool_size as f64)),
                                ("n_seeds", Json::Num(f.n_seeds as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<JobResult> {
        let corrupt = |what: &str| Error::Dataset(format!("job result: {what}"));
        let id = v
            .get("id")
            .and_then(Json::as_str)
            .ok_or_else(|| corrupt("missing id"))?
            .to_string();
        let operator = Operator::from_name(
            v.get("operator")
                .and_then(Json::as_str)
                .ok_or_else(|| corrupt("missing operator"))?,
        )?;
        let wall_ms = v
            .get("wall_ms")
            .and_then(Json::as_u64)
            .ok_or_else(|| corrupt("missing wall_ms"))?;
        let arr = v
            .get("factors")
            .and_then(Json::as_arr)
            .ok_or_else(|| corrupt("missing factors array"))?;
        let mut factors = Vec::with_capacity(arr.len());
        for f in arr {
            let num = |key: &str| {
                f.get(key)
                    .and_then(Json::as_f64)
                    .ok_or_else(|| corrupt(&format!("factor entry missing `{key}`")))
            };
            let count = |key: &str| {
                f.get(key)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| corrupt(&format!("factor entry missing `{key}`")))
            };
            factors.push(FactorResult {
                factor: num("factor")?,
                hv_train: num("hv_train")?,
                hv_ga: num("hv_ga")?,
                hv_conss: num("hv_conss")?,
                hv_conss_ga: num("hv_conss_ga")?,
                evaluations_ga: count("evaluations_ga")?,
                evaluations_conss_ga: count("evaluations_conss_ga")?,
                pool_size: count("pool_size")?,
                n_seeds: count("n_seeds")?,
            });
        }
        Ok(JobResult { id, operator, factors, wall_ms })
    }

    /// [`JobResult::from_json`] over raw text.
    pub fn parse(text: &str) -> Result<JobResult> {
        JobResult::from_json(&Json::parse(text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_roundtrip_with_all_fields() {
        let spec = JobSpec {
            id: "sweep-1".into(),
            operator: Some(Operator::MUL8),
            factors: vec![0.2, 0.5],
            seed_selection: SeedSelection::ParetoOnly,
            ga: Some(GaConfig { pop_size: 8, generations: 3, ..Default::default() }),
            ga_seed: Some(11),
        };
        spec.validate().unwrap();
        let back = JobSpec::parse(&spec.to_json().to_string()).unwrap();
        assert_eq!(back.id, "sweep-1");
        assert_eq!(back.operator, Some(Operator::MUL8));
        assert_eq!(back.factors, vec![0.2, 0.5]);
        assert_eq!(back.seed_selection, SeedSelection::ParetoOnly);
        assert_eq!(back.ga.as_ref().unwrap().pop_size, 8);
        assert_eq!(back.ga.as_ref().unwrap().generations, 3);
        assert_eq!(back.ga_seed, Some(11));
        let jobs = back.to_jobs();
        assert_eq!(jobs.len(), 2);
        assert_eq!(jobs[0].factor, 0.2);
        assert_eq!(jobs[1].seed_selection, SeedSelection::ParetoOnly);
        assert_eq!(jobs[1].ga_seed, Some(11));
    }

    #[test]
    fn minimal_spec_defaults() {
        let spec = JobSpec::parse(r#"{"factors":[0.5]}"#).unwrap();
        assert!(spec.id.is_empty(), "id comes from the spool filename");
        assert_eq!(spec.operator, None);
        assert_eq!(spec.seed_selection, SeedSelection::All);
        assert!(spec.ga.is_none());
        // ...but an id-less spec is not submittable as-is.
        assert!(spec.validate().is_err());
    }

    #[test]
    fn spec_validation_rejects_bad_inputs() {
        assert!(JobSpec::new("a/b", vec![0.5]).validate().is_err(), "unsafe id");
        assert!(JobSpec::new("j", vec![]).validate().is_err(), "no factors");
        assert!(JobSpec::new("j", vec![1.5]).validate().is_err(), "factor > 1");
        assert!(JobSpec::new("j", vec![0.0]).validate().is_err(), "factor = 0");
        let mut bad_ga = JobSpec::new("j", vec![0.5]);
        bad_ga.ga = Some(GaConfig { pop_size: 1, ..Default::default() });
        assert!(bad_ga.validate().is_err(), "degenerate ga");
        // Spool-internal shapes: hidden by ids_in (leading dot) or
        // claimed by the error records (trailing `.error`).
        assert!(JobSpec::new(".hidden", vec![0.5]).validate().is_err());
        assert!(JobSpec::new("x.error", vec![0.5]).validate().is_err());
        JobSpec::new("ok-1_2.x", vec![0.5, 1.0]).validate().unwrap();
    }

    #[test]
    fn spec_rejects_unknown_keys_and_operators() {
        assert!(JobSpec::parse(r#"{"factrs":[0.5]}"#).is_err());
        assert!(JobSpec::parse(r#"{"factors":[0.5],"ga":{"popsize":4}}"#).is_err());
        assert!(JobSpec::parse(r#"{"factors":[0.5],"operator":"div9"}"#).is_err());
        assert!(JobSpec::parse(r#"{"factors":[0.5],"seed_selection":"best"}"#).is_err());
        assert!(JobSpec::parse("[1,2]").is_err(), "spec must be an object");
    }

    #[test]
    fn result_roundtrip() {
        let r = JobResult {
            id: "j1".into(),
            operator: Operator::ADD12,
            factors: vec![FactorResult {
                factor: 0.75,
                hv_train: 0.123456789,
                hv_ga: 0.2,
                hv_conss: 0.3,
                hv_conss_ga: 0.4000000001,
                evaluations_ga: 120,
                evaluations_conss_ga: 130,
                pool_size: 512,
                n_seeds: 40,
            }],
            wall_ms: 42,
        };
        let back = JobResult::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(back, r, "floats round-trip exactly (shortest-repr writer)");
        assert!(JobResult::parse(r#"{"id":"x"}"#).is_err());
    }
}
