//! Serve-mode DSE: queued jobs against one resident engine.
//!
//! Every `repro dse` invocation used to be a one-shot process — pay
//! characterization, forest training, and estimator spawning, answer one
//! question, exit. This subsystem turns the binary into the serving-shaped
//! system the north star asks for (and autoAx/AxOSyn frame operator DSE
//! as): a long-running `repro serve-dse` drains a queue of job specs
//! against one resident [`EngineContext`](crate::engine::EngineContext),
//! so characterized datasets, trained ConSS pipelines, and spawned
//! estimator services amortize across every request — heterogeneous ones
//! included, via the engine's keyed estimator pool.
//!
//! Three pieces:
//!
//! * [`spec`] — the [`JobSpec`]/[`JobResult`] schema: hand-rolled JSON
//!   (the `util::json` idiom; no serde in the hermetic build) describing
//!   one job (operator, constraint factors, ConSS seed selection, GA
//!   overrides) and its per-factor hypervolume outcomes.
//! * [`queue`] — the file-spool [`JobQueue`] under
//!   `<jobs_dir>/{pending,running,done,failed}/`: `repro submit` drops
//!   specs into `pending/`, workers *claim* by atomic rename into
//!   `running/` (the portable cross-process test-and-set), results land
//!   in `done/`, broken specs are quarantined in `failed/` with the error
//!   recorded next to them.
//! * [`runner`] — the [`JobRunner`]: a bounded pool of scoped worker
//!   threads executing claimed jobs concurrently, sharing one per-operator
//!   [`DsePrepared`](crate::engine::DsePrepared) pool on top of the
//!   engine's dataset cache and estimator pool, and appending every
//!   lifecycle event to `server.log.jsonl`. `--drain` runs the queue to
//!   empty and exits (the CI-testable mode); watch mode polls `pending/`
//!   forever.
//! * [`http`] — the [`HttpServer`]: a std-only `TcpListener` HTTP/1.1
//!   front-end (`repro serve-http`) exposing the spool as a job API —
//!   `POST /jobs`, `GET /jobs/<id>[/result]`, `/healthz`, `/metrics` —
//!   with high-water-mark backpressure (`429` + `Retry-After`) and an
//!   optional embedded exec loop.
//! * [`eventlog`] — the rotating, drop-counting `server.log.jsonl`
//!   writer shared by the runner and the HTTP front-end: write failures
//!   are counted (surfaced as `log_dropped` in `/metrics`) instead of
//!   silently discarded, and the file rotates to `.1` past
//!   `[serve] log_max_bytes`.
//! * [`signal`] — graceful drain on SIGTERM/SIGINT: one flag the worker
//!   and exec loops poll so a kill stops *claiming* but finishes in-flight
//!   jobs and exits 0 with the spool consistent.
//! * [`dedup`] — content-addressed job identity: specs hash to
//!   `h<fnv1a64>` ids (client ids stripped), so identical concurrent
//!   requests collapse into one spooled job with many waiters and the
//!   queue itself arbitrates the dedup race.
//!
//! Results are bit-identical to direct [`DseJob`](crate::engine::DseJob)
//! runs: a job spec resolves to the same prepared state and the same
//! deterministic searches, so queueing changes *when* work happens, never
//! *what* it computes — and a deduped HTTP result is byte-for-byte the
//! record any direct spool reader sees.

pub mod dedup;
pub mod eventlog;
pub mod http;
pub mod queue;
pub mod runner;
pub mod signal;
pub mod spec;

pub use dedup::{canonical_hash, hash_id, Admission};
pub use eventlog::{EventLog, DEFAULT_LOG_MAX_BYTES};
pub use http::{
    http_call, http_call_retry, HttpClient, HttpOptions, HttpResponse,
    HttpServer, RetryPolicy, RetryingClient,
};
pub use queue::{
    stamp_gap_ns, ClaimedJob, JobQueue, JobState, QueueCounts, RequeueReport,
    Submission, TimelineStamp, MAX_REVIVALS,
};
pub use runner::{JobRunner, ServeOptions, ServeSummary, LOG_FILE};
pub use spec::{FactorResult, JobResult, JobSpec};
