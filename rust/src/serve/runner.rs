//! [`JobRunner`] — bounded worker pool draining the spool against one
//! resident engine.
//!
//! Workers are scoped threads; each loops claim → execute → record.
//! Execution funnels through shared state three layers deep, so a queue of
//! heterogeneous jobs pays every expensive resource at most once per
//! process:
//!
//! * a per-operator [`DsePrepared`] pool (this runner, `KeyedOnce`-guarded
//!   like the dataset cache) — ConSS matching/forest training once per
//!   operator, even when two workers race on the same operator's first
//!   job;
//! * the engine's dataset cache + persistent store — L_CHAR/H_CHAR
//!   characterized at most once per process (at most once *ever* with the
//!   store);
//! * the engine's keyed estimator pool — one resident
//!   [`EstimatorService`](crate::coordinator::EstimatorService) per
//!   operator × backend, so concurrent same-operator jobs coalesce their
//!   fitness batches and mixed-operator queues never evict each other.
//!
//! Every lifecycle event (`start`/`claim`/`done`/`fail`/`stop`) is
//! appended to `server.log.jsonl` in the queue directory — one JSON object
//! per line, the observable record CI uploads.

use super::eventlog::{EventLog, DEFAULT_LOG_MAX_BYTES};
use super::queue::{stamp_gap_ns, ClaimedJob, JobQueue};
use super::spec::{JobResult, JobSpec};
use crate::engine::{DsePrepared, EngineContext, KeyedOnce};
use crate::error::Result;
use crate::obs::{self, ServeObs};
use crate::operator::Operator;
use crate::util::json::Json;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

/// The append-only event stream's filename, inside the queue directory.
pub const LOG_FILE: &str = "server.log.jsonl";

/// Minimum spacing between store-GC sweeps on a serve loop. Sweeps run
/// from idle branches only, so a busy server defers GC to its next lull.
pub(crate) const STORE_GC_INTERVAL: Duration = Duration::from_secs(60);

/// Periodic [`DatasetStore::gc`](crate::engine::DatasetStore::gc) driver
/// for long-lived serve loops: armed only when the config both enables
/// the store and sets a `[store] max_bytes` budget, and rate-limited to
/// one sweep per [`STORE_GC_INTERVAL`] across however many workers poll
/// it. Shared by the spool runner's watch loop and the HTTP exec loop.
pub(crate) struct StoreGc {
    budget: Option<u64>,
    last: Mutex<Option<Instant>>,
}

impl StoreGc {
    /// Arm from a context: the budget is `[store] max_bytes`, and only
    /// matters when the context actually has a store open.
    pub(crate) fn for_ctx(ctx: &EngineContext) -> StoreGc {
        let budget =
            ctx.store().is_some().then_some(ctx.cfg().store.max_bytes).flatten();
        StoreGc { budget, last: Mutex::new(None) }
    }

    /// Run one sweep when armed and due; `None` when disarmed, not yet
    /// due, or the sweep failed (reported to stderr — GC must never take
    /// down a server).
    pub(crate) fn run_if_due(
        &self,
        ctx: &EngineContext,
    ) -> Option<crate::engine::GcReport> {
        let budget = self.budget?;
        {
            let mut last = self.last.lock().ok()?;
            if last.is_some_and(|t| t.elapsed() < STORE_GC_INTERVAL) {
                return None;
            }
            *last = Some(Instant::now());
        }
        match ctx.store()?.gc(budget) {
            Ok(report) => Some(report),
            Err(e) => {
                eprintln!("warning: store gc failed: {e}");
                None
            }
        }
    }
}

/// Serve-mode knobs (CLI flags layered over the `[serve]` config section).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Concurrent worker threads.
    pub workers: usize,
    /// Stop after this many jobs have been claimed across all workers
    /// (per [`JobRunner::run`] call — a re-run gets a fresh budget).
    pub max_jobs: Option<usize>,
    /// `true`: run the queue to empty, then exit (the CI-testable mode).
    /// `false`: watch mode — poll `pending/` forever (or until
    /// `max_jobs`).
    pub drain: bool,
    /// Watch-mode poll interval.
    pub poll: Duration,
    /// Rotate `server.log.jsonl` to `.1` past this many bytes.
    pub log_max_bytes: u64,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            workers: 2,
            max_jobs: None,
            drain: true,
            poll: Duration::from_millis(200),
            log_max_bytes: DEFAULT_LOG_MAX_BYTES,
        }
    }
}

/// What one [`JobRunner::run`] call processed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServeSummary {
    pub done: usize,
    pub failed: usize,
}

/// Event-log fields for one GC sweep (shared with the HTTP exec loop).
pub(crate) fn gc_event_fields(
    report: &crate::engine::GcReport,
) -> Vec<(&'static str, Json)> {
    vec![
        ("evicted", Json::Num(report.evicted.len() as f64)),
        ("kept", Json::Num(report.kept as f64)),
        ("bytes_before", Json::Num(report.bytes_before as f64)),
        ("bytes_after", Json::Num(report.bytes_after as f64)),
    ]
}

/// The serve-mode executor (see module docs).
pub struct JobRunner<'a> {
    ctx: &'a EngineContext,
    queue: &'a JobQueue,
    opts: ServeOptions,
    prepared: KeyedOnce<Operator, DsePrepared>,
    log: Arc<EventLog>,
    obs: Arc<ServeObs>,
    gc: StoreGc,
    claimed: AtomicUsize,
    done: AtomicUsize,
    failed: AtomicUsize,
}

impl<'a> JobRunner<'a> {
    pub fn new(
        ctx: &'a EngineContext,
        queue: &'a JobQueue,
        opts: ServeOptions,
    ) -> Result<JobRunner<'a>> {
        let log =
            Arc::new(EventLog::open(queue.dir().join(LOG_FILE), opts.log_max_bytes)?);
        Ok(Self::with_observer(ctx, queue, opts, log, Arc::new(ServeObs::new())))
    }

    /// Build on a shared event log and histogram set — the HTTP front-end
    /// hands its own in so requests and the jobs they spawn land in one
    /// `/metrics` view (and one rotated log).
    pub fn with_observer(
        ctx: &'a EngineContext,
        queue: &'a JobQueue,
        opts: ServeOptions,
        log: Arc<EventLog>,
        obs: Arc<ServeObs>,
    ) -> JobRunner<'a> {
        JobRunner {
            ctx,
            queue,
            opts,
            prepared: KeyedOnce::new(),
            log,
            obs,
            gc: StoreGc::for_ctx(ctx),
            claimed: AtomicUsize::new(0),
            done: AtomicUsize::new(0),
            failed: AtomicUsize::new(0),
        }
    }

    /// The shared event log (drop/rotation counters feed `/metrics`).
    pub fn event_log(&self) -> &Arc<EventLog> {
        &self.log
    }

    /// The shared latency histograms this runner records into.
    pub fn observer(&self) -> &Arc<ServeObs> {
        &self.obs
    }

    /// Run the worker pool until the stop condition (`drain` exhaustion or
    /// `max_jobs`) and report what this call processed. The runner (and
    /// its prepared pool) survives across calls, so a drain → submit →
    /// drain sequence re-prepares nothing.
    pub fn run(&self) -> Result<ServeSummary> {
        let done0 = self.done.load(Ordering::SeqCst);
        let failed0 = self.failed.load(Ordering::SeqCst);
        // The max_jobs budget is per run() call, like the summary (no
        // workers are live between calls, so a plain reset is safe).
        self.claimed.store(0, Ordering::SeqCst);
        let workers = self.opts.workers.max(1);
        self.log_event("start", &[("workers", Json::Num(workers as f64))]);
        std::thread::scope(|s| {
            for w in 0..workers {
                s.spawn(move || self.worker_loop(w));
            }
        });
        let summary = ServeSummary {
            done: self.done.load(Ordering::SeqCst) - done0,
            failed: self.failed.load(Ordering::SeqCst) - failed0,
        };
        self.log_event(
            "stop",
            &[
                ("done", Json::Num(summary.done as f64)),
                ("failed", Json::Num(summary.failed as f64)),
            ],
        );
        Ok(summary)
    }

    /// One `max_jobs` slot, or `false` when the budget is spent.
    fn try_reserve_slot(&self) -> bool {
        match self.opts.max_jobs {
            None => true,
            Some(max) => self
                .claimed
                .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| {
                    (n < max).then_some(n + 1)
                })
                .is_ok(),
        }
    }

    fn release_slot(&self) {
        if self.opts.max_jobs.is_some() {
            self.claimed.fetch_sub(1, Ordering::SeqCst);
        }
    }

    fn worker_loop(&self, worker: usize) {
        loop {
            // Graceful drain: stop claiming, let the in-flight jobs (on
            // the other workers) finish, exit with the spool consistent.
            if super::signal::draining() {
                self.log_event("drain", &[("worker", Json::Num(worker as f64))]);
                return;
            }
            if !self.try_reserve_slot() {
                return; // max_jobs budget spent
            }
            let claim_span = obs::span(obs::n::JOB_CLAIM);
            match self.queue.claim() {
                Ok(Some(job)) => {
                    drop(claim_span);
                    self.process(worker, job)
                }
                Ok(None) => {
                    claim_span.cancel(); // an empty poll is not a span
                    self.release_slot();
                    if self.opts.drain {
                        return;
                    }
                    // Watch-mode lull: a good moment to keep the
                    // persistent store inside its byte budget.
                    if let Some(report) = self.gc.run_if_due(self.ctx) {
                        self.log_event("store-gc", &gc_event_fields(&report));
                    }
                    std::thread::sleep(self.opts.poll);
                }
                Err(e) => {
                    // A queue I/O fault is not attributable to any one
                    // job; record it and retire the worker — except a
                    // full disk in watch mode, which is a load condition
                    // to ride out, not a crash: pause and re-poll.
                    claim_span.cancel();
                    self.release_slot();
                    self.log_event(
                        "claim-error",
                        &[
                            ("worker", Json::Num(worker as f64)),
                            ("error", Json::Str(e.to_string())),
                        ],
                    );
                    if !self.opts.drain && e.is_disk_full() {
                        std::thread::sleep(
                            self.opts.poll.max(Duration::from_millis(500)),
                        );
                        continue;
                    }
                    return;
                }
            }
        }
    }

    fn process(&self, worker: usize, job: ClaimedJob) {
        self.log_event(
            "claim",
            &[
                ("id", Json::Str(job.id.clone())),
                ("worker", Json::Num(worker as f64)),
            ],
        );
        self.queue.stamp_timeline(&job.id, "start");
        if let Ok(stamps) = self.queue.timeline(&job.id) {
            if let Some(ns) = stamp_gap_ns(&stamps, "submit", "claim") {
                self.obs.queue_wait_ns.record(ns);
            }
        }
        let exec_span = obs::span(obs::n::JOB_EXECUTE);
        let started = Instant::now();
        let outcome = self.execute(&job);
        drop(exec_span);
        self.obs.execute_ns.record(started.elapsed().as_nanos() as u64);
        match outcome {
            Ok(result) => {
                let completed = {
                    let _span = obs::span(obs::n::JOB_COMPLETE);
                    self.queue.complete(&job.id, &result)
                };
                match completed {
                    Ok(_) => {
                        self.done.fetch_add(1, Ordering::SeqCst);
                        self.log_event(
                            "done",
                            &[
                                ("id", Json::Str(job.id.clone())),
                                ("worker", Json::Num(worker as f64)),
                                ("wall_ms", Json::Num(result.wall_ms as f64)),
                                ("operator", Json::Str(result.operator.name())),
                            ],
                        );
                    }
                    Err(e) => {
                        self.record_failure(worker, &job.id, &e.to_string())
                    }
                }
            }
            Err(e) => self.record_failure(worker, &job.id, &e.to_string()),
        }
    }

    fn record_failure(&self, worker: usize, id: &str, error: &str) {
        if let Err(e) = self.queue.fail(id, error) {
            eprintln!("warning: could not quarantine job {id}: {e}");
        }
        self.failed.fetch_add(1, Ordering::SeqCst);
        self.log_event(
            "fail",
            &[
                ("id", Json::Str(id.to_string())),
                ("worker", Json::Num(worker as f64)),
                ("error", Json::Str(error.to_string())),
            ],
        );
    }

    /// Parse and run one claimed spec: resolve the operator, fetch (or
    /// build) its prepared DSE state, run the factor jobs in order.
    fn execute(&self, job: &ClaimedJob) -> Result<JobResult> {
        let mut spec = JobSpec::parse(&std::fs::read_to_string(&job.path)?)?;
        if spec.id.is_empty() {
            spec.id = job.id.clone();
        }
        spec.validate()?;
        let op = match spec.operator {
            Some(op) => op,
            None => Operator::from_name(&self.ctx.cfg().operator)?,
        };
        let prep = self.prepared(op)?;
        let started = Instant::now();
        let mut outcomes = Vec::with_capacity(spec.factors.len());
        for dse_job in spec.to_jobs() {
            outcomes.push(prep.run_job(&dse_job)?);
        }
        Ok(JobResult::from_outcomes(&job.id, op, &outcomes, started.elapsed()))
    }

    /// The shared prepared-DSE state for `op`, built at most once per
    /// runner (per-key in-flight guard: two workers racing on one
    /// operator's first job train one pipeline; first jobs of *different*
    /// operators prepare in parallel).
    fn prepared(&self, op: Operator) -> Result<Arc<DsePrepared>> {
        let (prep, _) = self
            .prepared
            .get_or_try_compute(op, || Ok(Arc::new(self.ctx.prepare_dse_for(op)?)))?;
        Ok(prep)
    }

    /// Append one event line to `server.log.jsonl` (best-effort: logging
    /// must never fail a job).
    fn log_event(&self, event: &str, fields: &[(&str, Json)]) {
        let ts = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .unwrap_or(Duration::ZERO)
            .as_millis() as u64;
        let mut pairs =
            vec![("ts_ms", Json::Num(ts as f64)), ("event", Json::Str(event.into()))];
        for (k, v) in fields {
            pairs.push((*k, v.clone()));
        }
        let line = Json::obj(pairs).to_string();
        self.log.append(&line);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expcfg::{
        ConssConfig, ExperimentConfig, GaConfig, StoreConfig, SurrogateConfig,
    };
    use crate::surrogate::EstimatorBackend;
    use crate::util::tempdir::TempDir;

    /// Small add4 → add8 serve configuration (exhaustive spaces, exact
    /// table surrogate, tiny GA) — fast enough for unit-level lifecycle
    /// tests; the mixed-operator integration story lives in
    /// `rust/tests/serve_jobs.rs`.
    fn tiny_cfg() -> ExperimentConfig {
        ExperimentConfig {
            operator: "add8".into(),
            surrogate: SurrogateConfig {
                backend: EstimatorBackend::Table,
                gbt_stages: None,
            },
            conss: ConssConfig {
                forest_trees: Some(4),
                noise_bits: 2,
                ..Default::default()
            },
            ga: GaConfig { pop_size: 10, generations: 3, ..Default::default() },
            ..Default::default()
        }
    }

    #[test]
    fn drain_mode_processes_the_queue_and_exits() {
        let dir = TempDir::new().unwrap();
        let queue = JobQueue::open(dir.path().join("jobs")).unwrap();
        queue.submit(&JobSpec::new("a", vec![0.6])).unwrap();
        queue.submit(&JobSpec::new("b", vec![0.9])).unwrap();
        let ctx = EngineContext::new(tiny_cfg());
        let runner =
            JobRunner::new(&ctx, &queue, ServeOptions::default()).unwrap();
        let summary = runner.run().unwrap();
        assert_eq!(summary, ServeSummary { done: 2, failed: 0 });
        assert_eq!(queue.done_ids().unwrap(), vec!["a", "b"]);
        assert_eq!(queue.counts().unwrap().pending, 0);
        assert_eq!(queue.counts().unwrap().running, 0);
        let log = std::fs::read_to_string(queue.dir().join(LOG_FILE)).unwrap();
        let events: Vec<Json> =
            log.lines().map(|l| Json::parse(l).unwrap()).collect();
        assert!(events.iter().any(|e| e.get("event").and_then(Json::as_str)
            == Some("start")));
        assert_eq!(
            events
                .iter()
                .filter(|e| e.get("event").and_then(Json::as_str) == Some("done"))
                .count(),
            2
        );

        // Draining again is a no-op but keeps the prepared pool warm.
        queue.submit(&JobSpec::new("c", vec![0.4])).unwrap();
        let again = runner.run().unwrap();
        assert_eq!(again, ServeSummary { done: 1, failed: 0 });
        let s = ctx.cache_stats();
        assert_eq!(s.characterized, 2, "datasets characterized once across runs");
        assert_eq!(ctx.pool_stats().spawned, 1, "one estimator across runs");
    }

    #[test]
    fn unparseable_spec_is_quarantined_with_the_error() {
        let dir = TempDir::new().unwrap();
        let queue = JobQueue::open(dir.path().join("jobs")).unwrap();
        // Bypass submit() validation by dropping a raw file in pending/,
        // as a foreign producer might.
        std::fs::write(
            queue.dir().join("pending").join("broken.json"),
            r#"{"factors":[2.5]}"#,
        )
        .unwrap();
        let ctx = EngineContext::new(tiny_cfg());
        let runner =
            JobRunner::new(&ctx, &queue, ServeOptions::default()).unwrap();
        let summary = runner.run().unwrap();
        assert_eq!(summary, ServeSummary { done: 0, failed: 1 });
        assert_eq!(queue.failed_ids().unwrap(), vec!["broken"]);
        let err = queue.error("broken").unwrap();
        assert!(err.contains("outside (0, 1]"), "recorded error: {err}");
        // The engine never paid for anything.
        assert_eq!(ctx.cache_stats().characterized, 0);
        assert_eq!(ctx.pool_stats().spawned, 0);
    }

    #[test]
    fn store_gc_sweeps_when_armed_and_rate_limits() {
        let dir = TempDir::new().unwrap();
        let cfg = ExperimentConfig {
            store: StoreConfig {
                enabled: Some(true),
                dir: Some(dir.path().join("ds")),
                max_bytes: Some(1),
            },
            ..tiny_cfg()
        };
        let ctx = EngineContext::new(cfg);
        ctx.dataset(Operator::ADD4).unwrap(); // populate the store
        assert!(ctx.store().unwrap().total_bytes().unwrap() > 1);

        let gc = StoreGc::for_ctx(&ctx);
        let report = gc.run_if_due(&ctx).expect("armed GC sweeps on first poll");
        assert_eq!(report.evicted.len(), 1);
        assert_eq!(ctx.store().unwrap().total_bytes().unwrap(), 0);
        assert!(gc.run_if_due(&ctx).is_none(), "one sweep per interval");

        // No store → disarmed, whatever the budget says.
        let ctx = EngineContext::new(tiny_cfg());
        assert!(ctx.store().is_none());
        assert!(StoreGc::for_ctx(&ctx).run_if_due(&ctx).is_none());

        // Store without a byte budget → disarmed.
        let cfg = ExperimentConfig {
            store: StoreConfig {
                enabled: Some(true),
                dir: Some(dir.path().join("ds2")),
                max_bytes: None,
            },
            ..tiny_cfg()
        };
        let ctx = EngineContext::new(cfg);
        assert!(StoreGc::for_ctx(&ctx).run_if_due(&ctx).is_none());
    }

    #[test]
    fn max_jobs_caps_a_watch_mode_run() {
        let dir = TempDir::new().unwrap();
        let queue = JobQueue::open(dir.path().join("jobs")).unwrap();
        for i in 0..3 {
            queue.submit(&JobSpec::new(format!("j{i}"), vec![0.5])).unwrap();
        }
        let ctx = EngineContext::new(tiny_cfg());
        let opts = ServeOptions {
            drain: false,
            max_jobs: Some(2),
            workers: 2,
            poll: Duration::from_millis(10),
            ..Default::default()
        };
        let runner = JobRunner::new(&ctx, &queue, opts).unwrap();
        let summary = runner.run().unwrap();
        assert_eq!(summary.done, 2, "watch mode stops at max_jobs");
        assert_eq!(queue.counts().unwrap().pending, 1);

        // The budget is per run() call: topping the queue back up to the
        // budget size, a second run on the same runner claims a fresh
        // allowance (a stale counter would return done: 0 immediately).
        queue.submit(&JobSpec::new("j3", vec![0.5])).unwrap();
        let second = runner.run().unwrap();
        assert_eq!(second.done, 2, "fresh max_jobs budget per run");
        assert_eq!(queue.counts().unwrap().pending, 0);
    }
}
