//! Canonical-hash request dedup — content-addressed job identity.
//!
//! The HTTP front-end names every submitted job after *what it computes*,
//! not who asked: the spec's canonical JSON (sorted keys, shortest-repr
//! floats — `util::json`'s writer is already canonical), with the client
//! `id` field stripped, hashed with the dataset store's FNV-1a 64. Two
//! clients posting byte-different JSON for the same work ("0.50" vs
//! "0.5", shuffled keys, a cosmetic id) collapse onto one spool id
//! `h<hash:016x>`, and the [`JobQueue`]'s exactly-one-winner submission
//! makes the queue itself the dedup arbiter — no in-memory table to race
//! on or lose across restarts. Jobs are deterministic, so a hit in *any*
//! lifecycle state is shareable: a `done/` hit is a fully-amortized cache
//! hit, a `pending/`/`running/` hit is one spooled job with many waiters.

use super::queue::{JobQueue, JobState, Submission};
use super::spec::JobSpec;
use crate::engine::store::fnv1a64;
use crate::error::Result;
use crate::util::json::Json;

/// FNV-1a 64 of the spec's canonical JSON with the `id` key stripped —
/// equal exactly when two specs resolve to the same work.
pub fn canonical_hash(spec: &JobSpec) -> u64 {
    let mut v = spec.to_json();
    if let Json::Obj(map) = &mut v {
        map.remove("id");
    }
    fnv1a64(v.to_string().as_bytes())
}

/// The content-addressed spool id for a canonical hash (`h` + 16 hex
/// digits — always a valid queue id).
pub fn hash_id(hash: u64) -> String {
    format!("h{hash:016x}")
}

/// What admitting one request did (maps to `201 Created` / `200 OK`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Admission {
    /// This request spooled a new job.
    Created { id: String },
    /// An identical job is already in the spool (in `state`); the caller
    /// shares its id and, eventually, its result.
    Shared { id: String, state: JobState },
}

impl Admission {
    pub fn id(&self) -> &str {
        match self {
            Admission::Created { id } | Admission::Shared { id, .. } => id,
        }
    }
}

/// Admit one deduped request: rewrite the spec onto its content-addressed
/// id and submit, reporting a spool hit as [`Admission::Shared`]. Races
/// between identical concurrent requests are settled by the queue's
/// hard-link submission — exactly one caller sees `Created`.
pub fn admit(queue: &JobQueue, spec: &JobSpec) -> Result<Admission> {
    let id = hash_id(canonical_hash(spec));
    let mut spooled = spec.clone();
    spooled.id = id.clone();
    match queue.try_submit(&spooled)? {
        Submission::Submitted(_) => Ok(Admission::Created { id }),
        Submission::Duplicate(state) => Ok(Admission::Shared { id, state }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conss::SeedSelection;
    use crate::operator::Operator;
    use crate::util::tempdir::TempDir;

    #[test]
    fn hash_ignores_client_ids_but_not_work() {
        let a = JobSpec::new("client-a", vec![0.5]);
        let b = JobSpec::new("client-b", vec![0.5]);
        assert_eq!(canonical_hash(&a), canonical_hash(&b), "id is cosmetic");

        let mut c = JobSpec::new("client-a", vec![0.5]);
        c.factors = vec![0.6];
        assert_ne!(canonical_hash(&a), canonical_hash(&c), "factors matter");

        let mut d = JobSpec::new("", vec![0.5]);
        d.operator = Some(Operator::MUL8);
        assert_ne!(canonical_hash(&a), canonical_hash(&d), "operator matters");

        let mut e = JobSpec::new("", vec![0.5]);
        e.seed_selection = SeedSelection::ParetoOnly;
        assert_ne!(canonical_hash(&a), canonical_hash(&e));

        let mut f = JobSpec::new("", vec![0.5]);
        f.ga_seed = Some(7);
        assert_ne!(canonical_hash(&a), canonical_hash(&f));
    }

    #[test]
    fn hash_is_stable_across_textual_variants() {
        // Two textual spellings of one spec (key order, float formatting,
        // client id) must meet at one spool id.
        let v1 = JobSpec::parse(r#"{"id":"x","factors":[0.5],"ga_seed":3}"#).unwrap();
        let v2 = JobSpec::parse(r#"{"ga_seed":3,"factors":[0.50],"id":"y"}"#).unwrap();
        assert_eq!(canonical_hash(&v1), canonical_hash(&v2));
    }

    #[test]
    fn hash_id_is_a_valid_queue_id() {
        let id = hash_id(canonical_hash(&JobSpec::new("", vec![0.5])));
        assert_eq!(id.len(), 17);
        assert!(id.starts_with('h'));
        let mut spec = JobSpec::new(id, vec![0.5]);
        spec.validate().unwrap();
        spec.id = hash_id(0);
        assert_eq!(spec.id, "h0000000000000000", "zero-padded");
        spec.validate().unwrap();
    }

    #[test]
    fn admit_creates_once_then_shares() {
        let dir = TempDir::new().unwrap();
        let queue = JobQueue::open(dir.path().join("jobs")).unwrap();
        let spec = JobSpec::new("mine", vec![0.5]);
        let first = admit(&queue, &spec).unwrap();
        let id = match &first {
            Admission::Created { id } => id.clone(),
            other => panic!("expected Created, got {other:?}"),
        };
        // A different client, different cosmetic id, same work.
        let again = admit(&queue, &JobSpec::new("yours", vec![0.5])).unwrap();
        assert_eq!(
            again,
            Admission::Shared { id: id.clone(), state: JobState::Pending }
        );
        assert_eq!(queue.counts().unwrap().pending, 1, "one spooled job");

        // The hit follows the job through its lifecycle.
        queue.claim().unwrap().unwrap();
        let running = admit(&queue, &spec).unwrap();
        assert_eq!(running, Admission::Shared { id, state: JobState::Running });
    }

    #[test]
    fn concurrent_identical_admissions_create_exactly_once() {
        let dir = TempDir::new().unwrap();
        let queue = JobQueue::open(dir.path().join("jobs")).unwrap();
        let outcomes: Vec<Admission> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|k| {
                    let queue = &queue;
                    s.spawn(move || {
                        admit(queue, &JobSpec::new(format!("c{k}"), vec![0.5]))
                            .unwrap()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let created = outcomes
            .iter()
            .filter(|a| matches!(a, Admission::Created { .. }))
            .count();
        assert_eq!(created, 1, "exactly one creator; the rest share");
        let id = outcomes[0].id();
        assert!(outcomes.iter().all(|a| a.id() == id));
        assert_eq!(queue.counts().unwrap().pending, 1);
    }
}
