//! Graceful drain on SIGTERM/SIGINT.
//!
//! One process-wide flag, set from an async-signal-safe handler (a single
//! relaxed atomic store — the only thing a signal handler may safely do
//! here). The serve loops poll [`draining`]:
//!
//! * `repro serve-dse --watch` workers stop claiming new jobs, finish
//!   their in-flight job, and return — the process exits 0 with the spool
//!   consistent (no orphaned claims to sweep on the next start).
//! * `repro serve-http` additionally reports `{"status":"draining"}` on
//!   `/healthz` so load balancers stop routing new work, then shuts the
//!   acceptors down once the embedded exec loop has drained.
//!
//! No `signal-hook`/`libc` crate: the handler is registered through the
//! C library's `signal` symbol, which std already links. Off-linux,
//! [`install`] is a no-op and Ctrl-C keeps its default kill behavior.

use std::sync::atomic::{AtomicBool, Ordering};

static DRAIN: AtomicBool = AtomicBool::new(false);

/// Whether a drain has been requested (signal received or
/// [`request_drain`] called).
#[inline]
pub fn draining() -> bool {
    DRAIN.load(Ordering::Relaxed)
}

/// Request a drain programmatically (tests, embedders).
pub fn request_drain() {
    DRAIN.store(true, Ordering::Relaxed);
}

#[cfg(target_os = "linux")]
extern "C" fn on_signal(_signum: i32) {
    // Async-signal-safe: one relaxed atomic store, nothing else.
    DRAIN.store(true, Ordering::Relaxed);
}

/// Install the SIGTERM/SIGINT drain handler. Safe to call more than once.
#[cfg(target_os = "linux")]
pub fn install() {
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        let _ = signal(SIGINT, on_signal);
        let _ = signal(SIGTERM, on_signal);
    }
}

/// Off-linux no-op: the raw `signal` ABI contract is only asserted for
/// the platform CI exercises; elsewhere Ctrl-C keeps its default
/// terminate behavior.
#[cfg(not(target_os = "linux"))]
pub fn install() {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn installs_without_crashing_and_starts_undrained() {
        // Flag-setting semantics are exercised end-to-end by the torture
        // suite (real SIGTERM to a serve subprocess); in-process we only
        // assert installation is safe and the flag starts clear — other
        // suites in this binary poll `draining()` from their serve loops,
        // so no lib test may ever set it.
        install();
        install();
        assert!(!draining());
    }
}
