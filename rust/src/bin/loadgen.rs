//! `loadgen` — closed-loop HTTP load generator for `repro serve-http`.
//!
//! Drives N connections of mixed add12/mul8 `POST /jobs` specs with a
//! configurable duplicate ratio against either an in-process front-end
//! (the default: hermetic, port 0, workers 0 — measures the submit path
//! without paying DSE wall-clock) or an external `--addr`. Stamps
//! `BENCH_http.json` with requests/s, p50/p99 submit latency, and the
//! observed dedup hit rate — the HTTP leg of the CI perf trajectory,
//! `REPRO_BENCH_SMOKE=1` shrinking it to a bit-rot probe like every other
//! bench.
//!
//! ```text
//! loadgen [--addr HOST:PORT] [--connections N] [--requests N]
//!         [--dup-ratio F] [--out PATH]
//! ```

use repro::cli::ParsedArgs;
use repro::engine::EngineContext;
use repro::error::{Error, Result};
use repro::expcfg::{ConssConfig, ExperimentConfig, GaConfig, SurrogateConfig};
use repro::serve::{http_call, HttpOptions, HttpServer, JobQueue};
use repro::surrogate::EstimatorBackend;
use repro::util::bench::smoke_mode;
use repro::util::json::Json;
use repro::util::rng::Rng;
use repro::util::tempdir::TempDir;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Globally-unique spec uniquifier: each fresh (non-duplicate) request
/// gets its own `ga_seed`, so distinct requests never collide by accident.
static NEXT_SEED: AtomicU64 = AtomicU64::new(0);

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--help" || a == "-h") {
        println!(
            "loadgen — closed-loop HTTP load for `repro serve-http`\n\n\
             USAGE: loadgen [--addr HOST:PORT] [--connections N] [--requests N]\n\
             \x20                [--dup-ratio F] [--out PATH]\n\n\
             Without --addr an in-process front-end is spawned on 127.0.0.1:0\n\
             (hermetic; no engine work). REPRO_BENCH_SMOKE=1 shrinks the run\n\
             to a bit-rot probe. Stamps BENCH_http.json."
        );
        return;
    }
    if let Err(e) = run(args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

/// One request's outcome, as the client saw it.
struct Sample {
    status: u16,
    latency_ns: u64,
}

fn run(args: Vec<String>) -> Result<()> {
    let parsed = ParsedArgs::parse(args, &[])
        .map_err(|e| Error::Config(e.to_string()))?;
    parsed
        .ensure_known(&["addr", "connections", "requests", "dup-ratio", "out"])
        .map_err(|e| Error::Config(e.to_string()))?;
    let smoke = smoke_mode();
    let connections: usize = parsed
        .opt_parse("connections")
        .map_err(|e| Error::Config(e.to_string()))?
        .unwrap_or(if smoke { 2 } else { 8 });
    let requests: usize = parsed
        .opt_parse("requests")
        .map_err(|e| Error::Config(e.to_string()))?
        .unwrap_or(if smoke { 8 } else { 48 });
    let dup_ratio: f64 = parsed
        .opt_parse("dup-ratio")
        .map_err(|e| Error::Config(e.to_string()))?
        .unwrap_or(0.5);
    if !(0.0..=1.0).contains(&dup_ratio) {
        return Err(Error::Config("--dup-ratio must be within [0, 1]".into()));
    }
    let out = parsed
        .opt("out")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("BENCH_http.json"));

    // Target: external server, or a hermetic in-process front-end.
    let embedded = if parsed.opt("addr").is_none() {
        Some(EmbeddedServer::start()?)
    } else {
        None
    };
    let addr = match (&embedded, parsed.opt("addr")) {
        (Some(server), _) => server.addr.clone(),
        (None, Some(addr)) => addr.to_string(),
        (None, None) => unreachable!(),
    };
    println!(
        "loadgen: {connections} connection(s) x {requests} request(s), \
         dup ratio {dup_ratio}, target http://{addr}{}",
        if embedded.is_some() { " (in-process)" } else { "" }
    );

    let started = Instant::now();
    let samples: Vec<Sample> = {
        let collected = Mutex::new(Vec::with_capacity(connections * requests));
        std::thread::scope(|s| {
            for conn in 0..connections {
                let collected = &collected;
                let addr = addr.as_str();
                s.spawn(move || {
                    let mine = drive_connection(addr, conn, requests, dup_ratio);
                    collected.lock().unwrap().extend(mine);
                });
            }
        });
        collected.into_inner().unwrap()
    };
    let elapsed = started.elapsed();

    if let Some(server) = embedded {
        server.stop();
    }

    // Aggregate: throughput, latency percentiles, dedup split.
    let total = samples.len();
    let created = samples.iter().filter(|s| s.status == 201).count();
    let shared = samples.iter().filter(|s| s.status == 200).count();
    let errors = total - created - shared;
    if errors > 0 {
        return Err(Error::Coordinator(format!(
            "{errors}/{total} requests failed (non-200/201 status)"
        )));
    }
    let hit_rate = if created + shared == 0 {
        0.0
    } else {
        shared as f64 / (created + shared) as f64
    };
    let mut lat: Vec<u64> = samples.iter().map(|s| s.latency_ns).collect();
    lat.sort_unstable();
    let pct = |p: usize| -> f64 {
        if lat.is_empty() {
            0.0
        } else {
            lat[(lat.len() * p / 100).min(lat.len() - 1)] as f64
        }
    };
    let secs = elapsed.as_secs_f64();
    let rps = if secs > 0.0 { total as f64 / secs } else { 0.0 };
    println!(
        "{total} request(s) in {elapsed:.2?} — {rps:.0} req/s; p50 {:.2} ms, \
         p99 {:.2} ms; {created} created / {shared} shared (hit rate {:.2})",
        pct(50) / 1e6,
        pct(99) / 1e6,
        hit_rate
    );

    // The BENCH_*.json stamp (same mode discipline as util::bench).
    let stamp = Json::obj(vec![
        (
            "mode",
            Json::Str(if smoke { "smoke".into() } else { "full".into() }),
        ),
        ("connections", Json::Num(connections as f64)),
        ("requests", Json::Num(total as f64)),
        ("duration_ms", Json::Num(elapsed.as_millis() as f64)),
        ("requests_per_sec", Json::Num(rps)),
        (
            "latency_ms",
            Json::obj(vec![
                ("p50", Json::Num(pct(50) / 1e6)),
                ("p99", Json::Num(pct(99) / 1e6)),
            ]),
        ),
        (
            "dedup",
            Json::obj(vec![
                ("created", Json::Num(created as f64)),
                ("shared", Json::Num(shared as f64)),
                ("hit_rate", Json::Num(hit_rate)),
            ]),
        ),
    ]);
    std::fs::write(&out, stamp.to_string())?;
    println!("wrote {}", out.display());
    Ok(())
}

/// One closed-loop connection: `requests` sequential submits, duplicating
/// an earlier spec of this connection with probability `dup_ratio`.
/// Deterministic per (connection, request) — only the wall-clock varies
/// between runs.
fn drive_connection(
    addr: &str,
    conn: usize,
    requests: usize,
    dup_ratio: f64,
) -> Vec<Sample> {
    let mut rng = Rng::seed_from_u64(0x10ad_6e4e + conn as u64);
    let mut issued: Vec<String> = Vec::new();
    let mut samples = Vec::with_capacity(requests);
    for _ in 0..requests {
        let body = if !issued.is_empty() && rng.gen_bool(dup_ratio) {
            issued[rng.gen_index(issued.len())].clone()
        } else {
            let seed = NEXT_SEED.fetch_add(1, Ordering::Relaxed);
            let op = if seed % 2 == 0 { "add12" } else { "mul8" };
            let body = format!(
                r#"{{"factors":[0.5],"operator":"{op}","ga_seed":{seed}}}"#
            );
            issued.push(body.clone());
            body
        };
        let t0 = Instant::now();
        let sample = match http_call(addr, "POST", "/jobs", Some(&body)) {
            Ok(response) => Sample {
                status: response.status,
                latency_ns: t0.elapsed().as_nanos() as u64,
            },
            Err(_) => Sample { status: 0, latency_ns: t0.elapsed().as_nanos() as u64 },
        };
        samples.push(sample);
    }
    samples
}

/// The hermetic in-process target: a front-end-only server (workers 0 —
/// specs spool but never execute, so the bench measures the HTTP + dedup
/// + spool path, not DSE) over a temp queue, torn down on stop.
struct EmbeddedServer {
    addr: String,
    server: Arc<HttpServer>,
    handle: std::thread::JoinHandle<()>,
    _dir: TempDir,
}

impl EmbeddedServer {
    fn start() -> Result<EmbeddedServer> {
        let dir = TempDir::new()?;
        let cfg = ExperimentConfig {
            operator: "add8".into(),
            artifacts_dir: dir.path().join("artifacts"),
            surrogate: SurrogateConfig {
                backend: EstimatorBackend::Table,
                gbt_stages: None,
            },
            conss: ConssConfig {
                forest_trees: Some(4),
                noise_bits: 2,
                ..Default::default()
            },
            ga: GaConfig { pop_size: 10, generations: 3, ..Default::default() },
            ..Default::default()
        };
        let queue = Arc::new(JobQueue::open(dir.path().join("jobs"))?);
        let ctx = Arc::new(EngineContext::new(cfg));
        let opts = HttpOptions {
            workers: 0,
            high_water: usize::MAX,
            ..Default::default()
        };
        let server =
            Arc::new(HttpServer::bind(ctx, queue, "127.0.0.1:0", opts)?);
        let addr = server.local_addr().to_string();
        let handle = {
            let server = Arc::clone(&server);
            std::thread::spawn(move || {
                if let Err(e) = server.run() {
                    eprintln!("warning: embedded server: {e}");
                }
            })
        };
        Ok(EmbeddedServer { addr, server, handle, _dir: dir })
    }

    fn stop(self) {
        self.server.shutdown();
        let _ = self.handle.join();
    }
}
