//! `loadgen` — closed-loop HTTP load generator for `repro serve-http`.
//!
//! Drives N connections of mixed add12/mul8 `POST /jobs` specs with a
//! configurable duplicate ratio against either an in-process front-end
//! (the default: hermetic, port 0, workers 0 — measures the submit path
//! without paying DSE wall-clock) or an external `--addr`. Latencies
//! aggregate through the shared [`obs::Histogram`](repro::obs::Histogram)
//! — the same fixed log-bucketed edges `/metrics` reports — so stamped
//! percentiles are deterministic for a given latency multiset. Stamps
//! `BENCH_http.json` with requests/s, p50/p99 submit latency, the full
//! bucket layout, and the observed dedup hit rate — the HTTP leg of the
//! CI perf trajectory, `REPRO_BENCH_SMOKE=1` shrinking it to a bit-rot
//! probe like every other bench.
//!
//! `--keep-alive` runs a second pass where every connection reuses one
//! persistent socket ([`HttpClient`]) instead of a fresh
//! connect-per-request, and stamps the p50/p99 latency deltas
//! (close − keep-alive, ms) alongside the close-mode numbers.
//!
//! `--trace-out PATH` force-enables span tracing and writes the run's
//! Chrome trace-event JSON — with the default in-process target that
//! captures the server's request spans (Perfetto-loadable).
//!
//! `--retries N` drives every request through the retry policy
//! ([`RetryPolicy`]: capped exponential backoff, deterministic
//! per-connection jitter, `Retry-After` honored on `429`/`503`) and
//! stamps the observed retry counts — `0` (the default) keeps the
//! historical no-retry path for cross-PR comparability.
//!
//! ```text
//! loadgen [--addr HOST:PORT] [--connections N] [--requests N]
//!         [--dup-ratio F] [--keep-alive] [--retries N] [--out PATH]
//!         [--trace-out PATH]
//! ```

use repro::cli::ParsedArgs;
use repro::engine::EngineContext;
use repro::error::{Error, Result};
use repro::expcfg::{ConssConfig, ExperimentConfig, GaConfig, SurrogateConfig};
use repro::obs::{HistSnapshot, Histogram};
use repro::serve::{
    http_call, http_call_retry, HttpClient, HttpOptions, HttpServer, JobQueue,
    RetryPolicy, RetryingClient,
};
use repro::surrogate::EstimatorBackend;
use repro::util::bench::smoke_mode;
use repro::util::json::Json;
use repro::util::rng::Rng;
use repro::util::tempdir::TempDir;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Globally-unique spec uniquifier: each fresh (non-duplicate) request
/// gets its own `ga_seed`, so distinct requests never collide by accident.
static NEXT_SEED: AtomicU64 = AtomicU64::new(0);

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--help" || a == "-h") {
        println!(
            "loadgen — closed-loop HTTP load for `repro serve-http`\n\n\
             USAGE: loadgen [--addr HOST:PORT] [--connections N] [--requests N]\n\
             \x20                [--dup-ratio F] [--keep-alive] [--retries N]\n\
             \x20                [--out PATH] [--trace-out PATH]\n\n\
             Without --addr an in-process front-end is spawned on 127.0.0.1:0\n\
             (hermetic; no engine work). --keep-alive adds a second pass on\n\
             persistent connections and stamps the latency delta. --trace-out\n\
             force-enables span tracing and writes Chrome trace-event JSON.\n\
             REPRO_BENCH_SMOKE=1 shrinks the run to a bit-rot probe.\n\
             Stamps BENCH_http.json."
        );
        return;
    }
    if let Err(e) = run(args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

/// One request's outcome, as the client saw it.
struct Sample {
    status: u16,
    latency_ns: u64,
}

fn run(args: Vec<String>) -> Result<()> {
    let parsed = ParsedArgs::parse(args, &["keep-alive"])
        .map_err(|e| Error::Config(e.to_string()))?;
    parsed
        .ensure_known(&[
            "addr", "connections", "requests", "dup-ratio", "out", "trace-out",
            "retries",
        ])
        .map_err(|e| Error::Config(e.to_string()))?;
    let keep_alive = parsed.flag("keep-alive");
    let smoke = smoke_mode();
    let connections: usize = parsed
        .opt_parse("connections")
        .map_err(|e| Error::Config(e.to_string()))?
        .unwrap_or(if smoke { 2 } else { 8 });
    let requests: usize = parsed
        .opt_parse("requests")
        .map_err(|e| Error::Config(e.to_string()))?
        .unwrap_or(if smoke { 8 } else { 48 });
    let dup_ratio: f64 = parsed
        .opt_parse("dup-ratio")
        .map_err(|e| Error::Config(e.to_string()))?
        .unwrap_or(0.5);
    if !(0.0..=1.0).contains(&dup_ratio) {
        return Err(Error::Config("--dup-ratio must be within [0, 1]".into()));
    }
    let retries: u32 = parsed
        .opt_parse("retries")
        .map_err(|e| Error::Config(e.to_string()))?
        .unwrap_or(0);
    let out = parsed
        .opt("out")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("BENCH_http.json"));
    let trace_out = parsed.opt("trace-out").map(PathBuf::from);
    if trace_out.is_some() {
        repro::obs::force_enable();
    }

    // Target: external server, or a hermetic in-process front-end.
    let embedded = if parsed.opt("addr").is_none() {
        Some(EmbeddedServer::start()?)
    } else {
        None
    };
    let addr = match (&embedded, parsed.opt("addr")) {
        (Some(server), _) => server.addr.clone(),
        (None, Some(addr)) => addr.to_string(),
        (None, None) => unreachable!(),
    };
    println!(
        "loadgen: {connections} connection(s) x {requests} request(s), \
         dup ratio {dup_ratio}, target http://{addr}{}",
        if embedded.is_some() { " (in-process)" } else { "" }
    );

    let close = PassStats::aggregate(
        "close",
        &drive(&addr, connections, requests, dup_ratio, false, retries),
    )?;
    close.print();
    let reused = if keep_alive {
        let stats = PassStats::aggregate(
            "keep-alive",
            &drive(&addr, connections, requests, dup_ratio, true, retries),
        )?;
        stats.print();
        Some(stats)
    } else {
        None
    };

    if let Some(server) = embedded {
        server.stop();
    }

    // The BENCH_*.json stamp (same mode discipline as util::bench). The
    // top-level numbers stay the close-mode pass for cross-PR
    // comparability; `keep_alive` carries the reuse pass and the deltas.
    let mut pairs = vec![
        (
            "mode",
            Json::Str(if smoke { "smoke".into() } else { "full".into() }),
        ),
        ("connections", Json::Num(connections as f64)),
        ("requests", Json::Num(close.total as f64)),
        ("duration_ms", Json::Num(close.duration_ms)),
        ("requests_per_sec", Json::Num(close.rps)),
        (
            "latency_ms",
            Json::obj(vec![
                ("p50", Json::Num(close.p50_ms)),
                ("p99", Json::Num(close.p99_ms)),
            ]),
        ),
        ("latency_buckets", close.snap.to_json_buckets()),
        (
            "dedup",
            Json::obj(vec![
                ("created", Json::Num(close.created as f64)),
                ("shared", Json::Num(close.shared as f64)),
                ("hit_rate", Json::Num(close.hit_rate)),
            ]),
        ),
        (
            "retry",
            Json::obj(vec![
                ("budget_per_request", Json::Num(retries as f64)),
                ("performed", Json::Num(close.retries as f64)),
            ]),
        ),
    ];
    if let Some(ka) = &reused {
        pairs.push((
            "keep_alive",
            Json::obj(vec![
                ("requests_per_sec", Json::Num(ka.rps)),
                (
                    "latency_ms",
                    Json::obj(vec![
                        ("p50", Json::Num(ka.p50_ms)),
                        ("p99", Json::Num(ka.p99_ms)),
                    ]),
                ),
                ("latency_buckets", ka.snap.to_json_buckets()),
                // close − keep-alive: positive = connection reuse saved.
                ("p50_delta_ms", Json::Num(close.p50_ms - ka.p50_ms)),
                ("p99_delta_ms", Json::Num(close.p99_ms - ka.p99_ms)),
                ("retries_performed", Json::Num(ka.retries as f64)),
            ]),
        ));
    }
    std::fs::write(&out, Json::obj(pairs).to_string())?;
    println!("wrote {}", out.display());
    if let Some(path) = &trace_out {
        std::fs::write(path, repro::obs::export_chrome().to_string())?;
        println!("wrote trace to {}", path.display());
    }
    Ok(())
}

/// One pass's aggregates: throughput, latency percentiles, dedup split.
struct PassStats {
    label: &'static str,
    total: usize,
    created: usize,
    shared: usize,
    hit_rate: f64,
    duration_ms: f64,
    rps: f64,
    p50_ms: f64,
    p99_ms: f64,
    retries: u64,
    snap: HistSnapshot,
}

impl PassStats {
    fn aggregate(label: &'static str, pass: &Pass) -> Result<PassStats> {
        let (samples, elapsed, retries) = pass;
        let total = samples.len();
        let created = samples.iter().filter(|s| s.status == 201).count();
        let shared = samples.iter().filter(|s| s.status == 200).count();
        let errors = total - created - shared;
        if errors > 0 {
            return Err(Error::Coordinator(format!(
                "{label}: {errors}/{total} requests failed (non-200/201 status)"
            )));
        }
        let hit_rate = if created + shared == 0 {
            0.0
        } else {
            shared as f64 / (created + shared) as f64
        };
        // Same log-bucketed histogram `/metrics` exposes: percentiles are
        // bucket upper edges, deterministic for a given latency multiset.
        let hist = Histogram::new();
        for s in samples {
            hist.record(s.latency_ns);
        }
        let snap = hist.snapshot();
        let secs = elapsed.as_secs_f64();
        Ok(PassStats {
            label,
            total,
            created,
            shared,
            hit_rate,
            duration_ms: elapsed.as_millis() as f64,
            rps: if secs > 0.0 { total as f64 / secs } else { 0.0 },
            p50_ms: snap.percentile(50.0) as f64 / 1e6,
            p99_ms: snap.percentile(99.0) as f64 / 1e6,
            retries: *retries,
            snap,
        })
    }

    fn print(&self) {
        println!(
            "{}: {} request(s) in {:.0} ms — {:.0} req/s; p50 {:.2} ms, \
             p99 {:.2} ms; {} created / {} shared (hit rate {:.2}); \
             {} retry(ies)",
            self.label,
            self.total,
            self.duration_ms,
            self.rps,
            self.p50_ms,
            self.p99_ms,
            self.created,
            self.shared,
            self.hit_rate,
            self.retries
        );
    }
}

type Pass = (Vec<Sample>, std::time::Duration, u64);

/// One full pass: every connection drives its requests concurrently, in
/// close (connect-per-request) or keep-alive (persistent socket) mode.
fn drive(
    addr: &str,
    connections: usize,
    requests: usize,
    dup_ratio: f64,
    keep_alive: bool,
    retries: u32,
) -> Pass {
    let started = Instant::now();
    let collected = Mutex::new(Vec::with_capacity(connections * requests));
    let retries_performed = AtomicU64::new(0);
    std::thread::scope(|s| {
        for conn in 0..connections {
            let collected = &collected;
            let retries_performed = &retries_performed;
            s.spawn(move || {
                let (mine, performed) = drive_connection(
                    addr, conn, requests, dup_ratio, keep_alive, retries,
                );
                retries_performed.fetch_add(performed, Ordering::Relaxed);
                collected.lock().unwrap().extend(mine);
            });
        }
    });
    (
        collected.into_inner().unwrap(),
        started.elapsed(),
        retries_performed.load(Ordering::Relaxed),
    )
}

/// One closed-loop connection: `requests` sequential submits, duplicating
/// an earlier spec of this connection with probability `dup_ratio`.
/// Deterministic per (connection, request) — only the wall-clock varies
/// between runs. In keep-alive mode every submit reuses one persistent
/// socket, reconnecting once per request at most (the server may idle a
/// quiet connection out).
fn drive_connection(
    addr: &str,
    conn: usize,
    requests: usize,
    dup_ratio: f64,
    keep_alive: bool,
    retries: u32,
) -> (Vec<Sample>, u64) {
    let mut rng = Rng::seed_from_u64(0x10ad_6e4e + conn as u64);
    let policy = RetryPolicy {
        max_retries: retries,
        seed: 0x10ad_6e4e + conn as u64,
        ..Default::default()
    };
    // `--retries 0` keeps the historical no-retry paths byte-for-byte
    // (cross-PR bench comparability); a budget switches to the retrying
    // client / one-shot-with-retries call.
    let mut retry_client = if keep_alive && retries > 0 {
        Some(RetryingClient::new(addr, policy.clone()))
    } else {
        None
    };
    let mut plain_client = if keep_alive && retries == 0 {
        HttpClient::connect(addr).ok()
    } else {
        None
    };
    let mut one_shot_retries: u64 = 0;
    let mut issued: Vec<String> = Vec::new();
    let mut samples = Vec::with_capacity(requests);
    for _ in 0..requests {
        let body = if !issued.is_empty() && rng.gen_bool(dup_ratio) {
            issued[rng.gen_index(issued.len())].clone()
        } else {
            let seed = NEXT_SEED.fetch_add(1, Ordering::Relaxed);
            let op = if seed % 2 == 0 { "add12" } else { "mul8" };
            let body = format!(
                r#"{{"factors":[0.5],"operator":"{op}","ga_seed":{seed}}}"#
            );
            issued.push(body.clone());
            body
        };
        let t0 = Instant::now();
        let status = if let Some(rc) = retry_client.as_mut() {
            rc.call("POST", "/jobs", Some(&body)).map_or(0, |r| r.status)
        } else if keep_alive {
            match plain_client
                .as_mut()
                .and_then(|c| c.call("POST", "/jobs", Some(&body)).ok())
            {
                Some(r) => r.status,
                None => {
                    plain_client = HttpClient::connect(addr).ok();
                    plain_client
                        .as_mut()
                        .and_then(|c| c.call("POST", "/jobs", Some(&body)).ok())
                        .map_or(0, |r| r.status)
                }
            }
        } else if retries > 0 {
            match http_call_retry(addr, "POST", "/jobs", Some(&body), &policy) {
                Ok((r, n)) => {
                    one_shot_retries += n as u64;
                    r.status
                }
                Err(_) => 0,
            }
        } else {
            http_call(addr, "POST", "/jobs", Some(&body)).map_or(0, |r| r.status)
        };
        samples.push(Sample { status, latency_ns: t0.elapsed().as_nanos() as u64 });
    }
    let performed =
        one_shot_retries + retry_client.map_or(0, |c| c.retries());
    (samples, performed)
}

/// The hermetic in-process target: a front-end-only server (workers 0 —
/// specs spool but never execute, so the bench measures the HTTP + dedup
/// + spool path, not DSE) over a temp queue, torn down on stop.
struct EmbeddedServer {
    addr: String,
    server: Arc<HttpServer>,
    handle: std::thread::JoinHandle<()>,
    _dir: TempDir,
}

impl EmbeddedServer {
    fn start() -> Result<EmbeddedServer> {
        let dir = TempDir::new()?;
        let cfg = ExperimentConfig {
            operator: "add8".into(),
            artifacts_dir: dir.path().join("artifacts"),
            surrogate: SurrogateConfig {
                backend: EstimatorBackend::Table,
                gbt_stages: None,
            },
            conss: ConssConfig {
                forest_trees: Some(4),
                noise_bits: 2,
                ..Default::default()
            },
            ga: GaConfig { pop_size: 10, generations: 3, ..Default::default() },
            ..Default::default()
        };
        let queue = Arc::new(JobQueue::open(dir.path().join("jobs"))?);
        let ctx = Arc::new(EngineContext::new(cfg));
        let opts = HttpOptions {
            workers: 0,
            high_water: usize::MAX,
            ..Default::default()
        };
        let server =
            Arc::new(HttpServer::bind(ctx, queue, "127.0.0.1:0", opts)?);
        let addr = server.local_addr().to_string();
        let handle = {
            let server = Arc::clone(&server);
            std::thread::spawn(move || {
                if let Err(e) = server.run() {
                    eprintln!("warning: embedded server: {e}");
                }
            })
        };
        Ok(EmbeddedServer { addr, server, handle, _dir: dir })
    }

    fn stop(self) {
        self.server.shutdown();
        let _ = self.handle.join();
    }
}
