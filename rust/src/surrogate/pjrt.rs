//! PJRT-backed surrogate: the AOT-compiled Pallas MLP on the GA hot path.
//!
//! Wraps [`MlpExec`] (the `estimator_mul8` artifact) behind the
//! [`Surrogate`] trait so the coordinator service can batch GA fitness
//! queries onto one compiled executable.

use super::Surrogate;
use crate::dse::Objectives;
use crate::error::{Error, Result};
use crate::operator::AxoConfig;
use crate::runtime::MlpExec;
use std::sync::Mutex;

/// Thread-safe wrapper over the compiled estimator MLP.
///
/// # Safety of `Send`/`Sync`
/// The `xla` crate's handles are raw FFI pointers and therefore `!Send`.
/// The PJRT CPU client is thread-safe for execution, input literals are
/// immutable host buffers after construction, and the `Mutex` serializes
/// every `execute` call, so moving the wrapper across threads is sound.
pub struct PjrtSurrogate {
    inner: Mutex<MlpExec>,
    config_len: u32,
}

unsafe impl Send for PjrtSurrogate {}
unsafe impl Sync for PjrtSurrogate {}

impl PjrtSurrogate {
    pub fn new(exec: MlpExec) -> Result<PjrtSurrogate> {
        if exec.target_min.len() != 2 {
            return Err(Error::Ml(
                "estimator executable must predict [pdplut, avg_abs_rel_err]".into(),
            ));
        }
        let config_len = exec.in_features as u32;
        Ok(PjrtSurrogate { inner: Mutex::new(exec), config_len })
    }

    pub fn config_len(&self) -> u32 {
        self.config_len
    }
}

impl Surrogate for PjrtSurrogate {
    fn predict(&self, configs: &[AxoConfig]) -> Result<Vec<Objectives>> {
        if configs.is_empty() {
            return Ok(Vec::new());
        }
        let mut rows = Vec::with_capacity(configs.len() * self.config_len as usize);
        for c in configs {
            if c.len() != self.config_len {
                return Err(Error::Shape(format!(
                    "config length {} != estimator features {}",
                    c.len(),
                    self.config_len
                )));
            }
            rows.extend(c.to_bits_f32());
        }
        let exec = self
            .inner
            .lock()
            .map_err(|_| Error::Coordinator("estimator mutex poisoned".into()))?;
        let preds = exec.predict_unscaled(&rows)?;
        // Manifest target order is [pdplut, avg_abs_rel_err]; objectives
        // are [behav, ppa]. Metrics are non-negative; clamp MLP output.
        Ok(preds
            .iter()
            .map(|p| [p[1].max(0.0), p[0].max(0.0)])
            .collect())
    }
}
