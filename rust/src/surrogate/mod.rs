//! Surrogate estimators — the GA's fitness backends (paper §IV-A-1, §V-B).
//!
//! During GA evolution the PPF is ranked on *predicted* PPA and BEHAV
//! metrics; validation (PPF → VPF) re-characterizes the survivors. Three
//! interchangeable backends implement [`Surrogate`]:
//!
//! * [`TableSurrogate`] — exact lookup from a characterized dataset; the
//!   paper uses actual characterization for every operator except the 8×8
//!   multiplier ("we used ML-based estimators only for the signed 8-bit
//!   multiplier AxOs").
//! * [`GbtSurrogate`] — native gradient-boosted trees per metric, the
//!   CatBoost/LightGBM stand-in.
//! * `MlpExec` (via [`PjrtSurrogate`] in the coordinator) — the
//!   AOT-compiled Pallas MLP forward executed through PJRT; the hot path
//!   of the three-layer story.
//!
//! All backends emit the minimization pair `[avg_abs_rel_err, pdplut]`.

#[cfg(feature = "pjrt")]
pub mod pjrt;

#[cfg(feature = "pjrt")]
pub use pjrt::PjrtSurrogate;

use crate::charac::Dataset;
use crate::dse::Objectives;
use crate::error::{Error, Result};
use crate::ml::gbt::{GbtParams, GradientBoostedTrees};
use crate::operator::{AxoConfig, Operator};
use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

/// Backend selector used by experiment configs / CLI. `Hash` because the
/// engine's estimator pool keys resident services by operator × backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EstimatorBackend {
    Table,
    Gbt,
    PjrtMlp,
}

impl EstimatorBackend {
    pub fn name(&self) -> &'static str {
        match self {
            EstimatorBackend::Table => "table",
            EstimatorBackend::Gbt => "gbt",
            EstimatorBackend::PjrtMlp => "pjrt-mlp",
        }
    }

    pub fn from_name(name: &str) -> Option<EstimatorBackend> {
        [Self::Table, Self::Gbt, Self::PjrtMlp]
            .into_iter()
            .find(|b| b.name() == name)
    }

    /// Whether this backend can be constructed by the current binary —
    /// `pjrt-mlp` needs the `pjrt` cargo feature compiled in. (Artifacts
    /// are probed separately at construction time.)
    pub fn compiled_in(&self) -> bool {
        !matches!(self, EstimatorBackend::PjrtMlp) || cfg!(feature = "pjrt")
    }
}

// ---------------------------------------------------------------------------
// Backend registry
// ---------------------------------------------------------------------------

/// Construct the configured estimator backend — the one registry the CLI,
/// the figure harness, and the examples all go through.
///
/// `dataset` is pulled lazily: the table/GBT backends train on it, while
/// the PJRT MLP loads compiled weights instead and never touches it. The
/// `pjrt-mlp` selection fails with a clear [`Error::Config`] when the
/// binary was built without the `pjrt` feature, so hermetic builds degrade
/// with an actionable message instead of a link error.
pub fn build_backend(
    kind: EstimatorBackend,
    gbt_stages: Option<usize>,
    artifacts_dir: &Path,
    op: Operator,
    dataset: impl FnOnce() -> Result<Arc<Dataset>>,
) -> Result<Arc<dyn Surrogate>> {
    match kind {
        EstimatorBackend::Table => {
            Ok(Arc::new(TableSurrogate::from_dataset(&dataset()?)))
        }
        EstimatorBackend::Gbt => {
            let mut params = GbtParams::default();
            if let Some(stages) = gbt_stages {
                params.n_stages = stages;
            }
            Ok(Arc::new(GbtSurrogate::train(&dataset()?, params)?))
        }
        EstimatorBackend::PjrtMlp => pjrt_backend(artifacts_dir, op),
    }
}

#[cfg(feature = "pjrt")]
fn pjrt_backend(artifacts_dir: &Path, op: Operator) -> Result<Arc<dyn Surrogate>> {
    let rt = crate::runtime::Runtime::cpu(artifacts_dir)?;
    let exec =
        crate::runtime::MlpExec::new(&rt, &format!("estimator_{}", op.name()))?;
    Ok(Arc::new(PjrtSurrogate::new(exec)?))
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_backend(_artifacts_dir: &Path, op: Operator) -> Result<Arc<dyn Surrogate>> {
    Err(Error::Config(format!(
        "estimator backend `pjrt-mlp` for {op} needs a build with `--features pjrt` \
         (and `make artifacts`); use `table` or `gbt` in hermetic builds"
    )))
}

/// Batched metric prediction: configs → `[behav, ppa]`.
///
/// Adapting a surrogate to the GA's [`Fitness`] trait is a one-liner
/// closure (`|c: &[AxoConfig]| surrogate.predict(c)`): the `Fn` blanket
/// impl on [`Fitness`] picks it up. A blanket `Surrogate → Fitness` impl
/// would conflict with that closure impl, so none is provided.
pub trait Surrogate: Send + Sync {
    fn predict(&self, configs: &[AxoConfig]) -> Result<Vec<Objectives>>;
}

// ---------------------------------------------------------------------------
// Exact table lookup
// ---------------------------------------------------------------------------

/// Exact characterization lookup (small, exhaustively characterized spaces).
pub struct TableSurrogate {
    map: HashMap<u64, Objectives>,
}

impl TableSurrogate {
    pub fn from_dataset(ds: &Dataset) -> TableSurrogate {
        let map = ds
            .configs
            .iter()
            .zip(ds.headline_points())
            .map(|(c, p)| (c.as_uint(), [p[1], p[0]])) // [behav, ppa]
            .collect();
        TableSurrogate { map }
    }
}

impl Surrogate for TableSurrogate {
    fn predict(&self, configs: &[AxoConfig]) -> Result<Vec<Objectives>> {
        configs
            .iter()
            .map(|c| {
                self.map.get(&c.as_uint()).copied().ok_or_else(|| {
                    Error::Ml(format!("config {c} not in characterization table"))
                })
            })
            .collect()
    }
}

// ---------------------------------------------------------------------------
// Native GBT
// ---------------------------------------------------------------------------

/// Per-metric gradient-boosted-tree regressors over configuration bits.
pub struct GbtSurrogate {
    behav_model: GradientBoostedTrees,
    ppa_model: GradientBoostedTrees,
    config_len: u32,
}

impl GbtSurrogate {
    /// Train on a characterized dataset (paper: the 10,650-point sample).
    pub fn train(ds: &Dataset, params: GbtParams) -> Result<GbtSurrogate> {
        if ds.is_empty() {
            return Err(Error::Ml("cannot train surrogate on empty dataset".into()));
        }
        let l = ds.operator.config_len();
        let x: Vec<f64> = ds
            .configs
            .iter()
            .flat_map(|c| c.to_bits_f32().into_iter().map(|v| v as f64))
            .collect();
        let behav: Vec<f64> = ds.behav.iter().map(|b| b.avg_abs_rel_err).collect();
        let ppa: Vec<f64> = ds.ppa.iter().map(|p| p.pdplut).collect();
        let behav_model =
            GradientBoostedTrees::fit(&x, l as usize, &behav, params.clone())?;
        let ppa_model = GradientBoostedTrees::fit(&x, l as usize, &ppa, params)?;
        Ok(GbtSurrogate { behav_model, ppa_model, config_len: l })
    }

    /// Held-out quality report: (behav_rmse, behav_r2, ppa_rmse, ppa_r2).
    pub fn evaluate_on(&self, ds: &Dataset) -> Result<[f64; 4]> {
        let preds = self.predict(&ds.configs)?;
        let bt: Vec<f64> = ds.behav.iter().map(|b| b.avg_abs_rel_err).collect();
        let pt: Vec<f64> = ds.ppa.iter().map(|p| p.pdplut).collect();
        let bp: Vec<f64> = preds.iter().map(|o| o[0]).collect();
        let pp: Vec<f64> = preds.iter().map(|o| o[1]).collect();
        use crate::ml::metrics::{r2, rmse};
        Ok([rmse(&bt, &bp), r2(&bt, &bp), rmse(&pt, &pp), r2(&pt, &pp)])
    }
}

impl Surrogate for GbtSurrogate {
    fn predict(&self, configs: &[AxoConfig]) -> Result<Vec<Objectives>> {
        let mut out = Vec::with_capacity(configs.len());
        for c in configs {
            if c.len() != self.config_len {
                return Err(Error::Shape(format!(
                    "config length {} != trained {}",
                    c.len(),
                    self.config_len
                )));
            }
            let row: Vec<f64> =
                c.to_bits_f32().into_iter().map(|v| v as f64).collect();
            // Metrics are non-negative by construction; clamp tree output.
            let b = self.behav_model.predict_row(&row).max(0.0);
            let p = self.ppa_model.predict_row(&row).max(0.0);
            out.push([b, p]);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::charac::{characterize_all, Backend, InputSet};
    use crate::operator::Operator;

    fn add4_dataset() -> Dataset {
        let inputs = InputSet::exhaustive(Operator::ADD4);
        characterize_all(Operator::ADD4, &inputs, &Backend::Native).unwrap()
    }

    #[test]
    fn table_surrogate_exact() {
        let ds = add4_dataset();
        let t = TableSurrogate::from_dataset(&ds);
        let preds = t.predict(&ds.configs).unwrap();
        for (i, p) in preds.iter().enumerate() {
            assert_eq!(p[0], ds.behav[i].avg_abs_rel_err);
            assert_eq!(p[1], ds.ppa[i].pdplut);
        }
    }

    #[test]
    fn table_surrogate_unknown_config_errors() {
        let ds = add4_dataset();
        let sub = ds.subset(&[0, 1, 2]);
        let t = TableSurrogate::from_dataset(&sub);
        assert!(t.predict(&[AxoConfig::accurate(4)]).is_err() || sub.configs.contains(&AxoConfig::accurate(4)));
    }

    #[test]
    fn gbt_surrogate_fits_small_space_well() {
        let ds = add4_dataset();
        let g = GbtSurrogate::train(&ds, GbtParams::default()).unwrap();
        let [b_rmse, b_r2, p_rmse, p_r2] = g.evaluate_on(&ds).unwrap();
        assert!(b_r2 > 0.9, "behav r2 {b_r2} (rmse {b_rmse})");
        assert!(p_r2 > 0.9, "ppa r2 {p_r2} (rmse {p_rmse})");
    }

    #[test]
    fn gbt_rejects_wrong_length() {
        let ds = add4_dataset();
        let g = GbtSurrogate::train(&ds, GbtParams::default()).unwrap();
        assert!(g.predict(&[AxoConfig::accurate(8)]).is_err());
    }

    #[test]
    fn registry_builds_native_backends() {
        let ds = Arc::new(add4_dataset());
        for kind in [EstimatorBackend::Table, EstimatorBackend::Gbt] {
            assert!(kind.compiled_in());
            let ds2 = ds.clone();
            let backend =
                build_backend(kind, Some(10), Path::new("artifacts"), Operator::ADD4, move || {
                    Ok(ds2)
                })
                .unwrap();
            let preds = backend.predict(&ds.configs).unwrap();
            assert_eq!(preds.len(), ds.len());
        }
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn registry_rejects_pjrt_when_not_compiled() {
        assert!(!EstimatorBackend::PjrtMlp.compiled_in());
        let r = build_backend(
            EstimatorBackend::PjrtMlp,
            None,
            Path::new("artifacts"),
            Operator::MUL8,
            || unreachable!("pjrt backend must not touch the dataset"),
        );
        assert!(matches!(r, Err(Error::Config(_))));
    }

    #[test]
    fn predictions_nonnegative() {
        let ds = add4_dataset();
        let g = GbtSurrogate::train(&ds, GbtParams::default()).unwrap();
        for p in g.predict(&ds.configs).unwrap() {
            assert!(p[0] >= 0.0 && p[1] >= 0.0);
        }
    }
}
