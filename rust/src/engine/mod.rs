//! Job-oriented orchestration layer — the engine that owns the full
//! Fig. 4 pipeline end to end.
//!
//! Before this layer existed, the charac → match → ConSS → augmented
//! NSGA-II → VPF flow was wired by hand in the CLI, the figure harness,
//! and every example, each re-characterizing datasets and training its own
//! surrogate, and constraint scaling factors always ran sequentially. The
//! engine centralizes that wiring behind two types:
//!
//! * [`EngineContext`] — process-wide shared state: a thread-safe dataset
//!   cache (keyed operator × substrate × sample spec, so L_CHAR/H_CHAR are
//!   characterized exactly once per process) and a lazily-spawned shared
//!   [`EstimatorService`](crate::coordinator::EstimatorService).
//! * [`DseJob`] / [`DsePrepared`] — a job describes one constraint-scaled
//!   search; `prepare_dse` builds the shared pipeline once; `run_many`
//!   executes independent factor jobs concurrently on scoped threads, all
//!   funneling fitness through the one batching service so batches
//!   coalesce across searches.
//!
//! This is the seam future sharding/serving work builds on: a DSE job is
//! already a self-contained description that could be queued, sharded, or
//! served remotely (see ROADMAP "Open items").

pub mod context;
pub mod job;

pub use context::{
    l_operator, CacheStats, CharacSubstrate, DatasetKey, EngineContext, SampleSpec,
};
pub use job::{vpf_candidates, DseJob, DseOutcome, DsePrepared};
