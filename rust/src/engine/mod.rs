//! Job-oriented orchestration layer — the engine that owns the full
//! Fig. 4 pipeline end to end.
//!
//! Before this layer existed, the charac → match → ConSS → augmented
//! NSGA-II → VPF flow was wired by hand in the CLI, the figure harness,
//! and every example, each re-characterizing datasets and training its own
//! surrogate, and constraint scaling factors always ran sequentially. The
//! engine centralizes that wiring behind two types:
//!
//! * [`EngineContext`] — process-wide shared state: a thread-safe dataset
//!   cache (keyed operator × substrate × sample spec, per-key in-flight
//!   guard so concurrent misses on distinct keys characterize in
//!   parallel), an optional persistent [`DatasetStore`] under
//!   `artifacts_dir/datasets/` that makes characterization once-*ever*
//!   across processes, and a keyed **estimator pool** (operator ×
//!   surrogate backend → lazily-spawned
//!   [`EstimatorService`](crate::coordinator::EstimatorService)), so
//!   heterogeneous jobs — add12 next to mul8 in the serve-mode queue —
//!   coexist in one process without evicting each other. `Seeded`
//!   characterizations run as deterministic sub-range shards on the
//!   work-stealing pool, bit-identical to the sequential path.
//! * [`DseJob`] / [`DsePrepared`] — a job describes one constraint-scaled
//!   search; `prepare_dse` builds the shared pipeline once; `run_many`
//!   executes independent factor jobs concurrently on scoped threads, all
//!   funneling fitness through the one batching service so batches
//!   coalesce across searches.
//!
//! This is the seam the [`serve`](crate::serve) subsystem builds on: a DSE
//! job is a self-contained description, so the serve-mode queue executes
//! specs against one resident context — datasets characterized at most
//! once per process, estimators spawned at most once per key.

pub mod context;
pub mod job;
pub mod store;

pub use context::{
    l_operator, CacheStats, CharacSubstrate, DatasetKey, EngineContext, EstimatorKey,
    PoolStats, SampleSpec,
};
pub(crate) use context::KeyedOnce;
pub use job::{vpf_candidates, DseJob, DseOutcome, DsePrepared};
pub use store::{
    inputs_fingerprint, key_slug, DatasetStore, GcReport, StoreEntry, VerifyStatus,
    STORE_FORMAT_VERSION,
};
