//! [`DseJob`] — one constraint-scaled DSE experiment, and the drivers that
//! run many of them concurrently against shared state.
//!
//! A job describes *what* to search (constraint scaling factor, ConSS seed
//! selection, GA knobs); [`EngineContext::prepare_dse`] builds the shared
//! *how* once — cached L_CHAR/H_CHAR datasets, the trained ConSS pipeline,
//! and the batching estimator service — and [`DsePrepared::run_many`] fans
//! independent jobs out over scoped threads. Every job funnels its fitness
//! queries through the one [`EstimatorService`], so batches coalesce across
//! searches (the Fig. 15 scenario the coordinator was built for), while
//! results stay bit-identical to sequential runs: each search is seeded
//! deterministically and the surrogate is a pure function of the
//! configuration, so batching order cannot change any objective value.

use super::context::{l_operator, EngineContext};
use crate::baselines::appaxo_search;
use crate::charac::Dataset;
use crate::conss::pipeline::SeedSelection;
use crate::conss::{ConssPipeline, ConssPool, SupersampleOptions};
use crate::coordinator::EstimatorService;
use crate::dse::{
    hypervolume2d, Constraints, GaOptions, GaResult, NsgaRunner, Objectives, ParetoFront,
};
use crate::error::Result;
use crate::expcfg::GaConfig;
use crate::ml::forest::ForestParams;
use crate::operator::{AxoConfig, Operator};
use crate::util::par::parallel_map;
use std::collections::{HashMap, HashSet};
use std::sync::Arc;

/// One DSE experiment: a constraint scaling factor plus optional overrides
/// of the prepared defaults.
#[derive(Debug, Clone)]
pub struct DseJob {
    /// Constraint scaling factor (paper §V-D, Eq. 3).
    pub factor: f64,
    /// Which L designs seed the supersampler for this job.
    pub seed_selection: SeedSelection,
    /// GA knobs; `None` = the experiment config's `[ga]` section.
    pub ga: Option<GaConfig>,
    /// GA RNG seed; `None` = the experiment config's seed.
    pub ga_seed: Option<u64>,
}

impl DseJob {
    pub fn new(factor: f64) -> DseJob {
        DseJob { factor, seed_selection: SeedSelection::All, ga: None, ga_seed: None }
    }

    pub fn seed_selection(mut self, selection: SeedSelection) -> DseJob {
        self.seed_selection = selection;
        self
    }

    pub fn ga(mut self, ga: GaConfig) -> DseJob {
        self.ga = Some(ga);
        self
    }

    pub fn ga_seed(mut self, seed: u64) -> DseJob {
        self.ga_seed = Some(seed);
        self
    }
}

/// Everything DSE jobs share, built once per context by
/// [`EngineContext::prepare_dse`]: cached datasets, the trained ConSS
/// pipeline, and a handle to the shared estimator service.
pub struct DsePrepared {
    pub op: Operator,
    pub l_op: Operator,
    pub l_ds: Arc<Dataset>,
    pub h_ds: Arc<Dataset>,
    pub service: EstimatorService,
    pub pipeline: ConssPipeline,
    /// H_CHAR objectives `[behav, ppa]` (the TRAIN method's points).
    pub h_objectives: Vec<Objectives>,
    ga_defaults: GaConfig,
    default_seed: u64,
}

/// One job's outcome: the four methods the paper compares per factor
/// (TRAIN / GA / ConSS / ConSS+GA) plus the artifacts figures need.
pub struct DseOutcome {
    pub factor: f64,
    pub constraints: Constraints,
    pub hv_train: f64,
    pub hv_conss: f64,
    pub conss_pool: ConssPool,
    pub conss_objs: Vec<Objectives>,
    pub ga: GaResult,
    pub conss_ga: GaResult,
}

impl EngineContext {
    /// Build the shared DSE state for the configured operator pair
    /// (see [`EngineContext::prepare_dse_for`]).
    pub fn prepare_dse(&self) -> Result<DsePrepared> {
        self.prepare_dse_for(Operator::from_name(&self.cfg().operator)?)
    }

    /// Build the shared DSE state for `op`'s operator pair: characterize
    /// (or fetch cached) L/H datasets, train the ConSS pipeline, and
    /// spawn/fetch `op`'s pooled estimator service. Heterogeneous serve
    /// jobs prepare each operator independently while still sharing the
    /// process-wide dataset cache and estimator pool.
    pub fn prepare_dse_for(&self, op: Operator) -> Result<DsePrepared> {
        let l_op = l_operator(op)?;
        let l_ds = self.dataset(l_op)?;
        let h_ds = self.dataset(op)?;
        let service = self.estimator_for(op)?;
        let opts = SupersampleOptions {
            distance: self.cfg().conss.distance,
            noise_bits: self.cfg().conss.noise_bits,
            seeds: SeedSelection::All,
            forest: ForestParams {
                n_trees: self.cfg().conss.forest_trees.unwrap_or(25),
                ..Default::default()
            },
        };
        let pipeline = ConssPipeline::train(&l_ds, &h_ds, opts)?;
        let h_objectives: Vec<Objectives> =
            h_ds.headline_points().iter().map(|p| [p[1], p[0]]).collect();
        Ok(DsePrepared {
            op,
            l_op,
            l_ds,
            h_ds,
            service,
            pipeline,
            h_objectives,
            ga_defaults: self.cfg().ga.clone(),
            default_seed: self.cfg().seed,
        })
    }

    /// VPF: validate front configs with the real substrate; returns the
    /// validated front and the number of *additional* characterizations
    /// (the paper reports 31/282/365/390 for the four factors). Configs
    /// already in H_CHAR reuse their characterized metrics.
    pub fn validate_front(
        &self,
        prep: &DsePrepared,
        configs: &[AxoConfig],
        constraints: &Constraints,
    ) -> Result<(ParetoFront, usize)> {
        let known: HashMap<u64, usize> = prep
            .h_ds
            .configs
            .iter()
            .enumerate()
            .map(|(i, c)| (c.as_uint(), i))
            .collect();
        let fresh: Vec<AxoConfig> = configs
            .iter()
            .filter(|c| !known.contains_key(&c.as_uint()))
            .copied()
            .collect();
        let mut objs: Vec<Objectives> = Vec::new();
        if !fresh.is_empty() {
            let ds = self.validate(prep.op, &fresh)?;
            objs.extend(ds.headline_points().iter().map(|p| [p[1], p[0]] as Objectives));
        }
        let h_points = prep.h_ds.headline_points();
        for c in configs {
            if let Some(&i) = known.get(&c.as_uint()) {
                let p = h_points[i];
                objs.push([p[1], p[0]]);
            }
        }
        let feasible: Vec<Objectives> =
            objs.into_iter().filter(|o| constraints.feasible(*o)).collect();
        Ok((ParetoFront::from_points(&feasible), fresh.len()))
    }
}

impl DsePrepared {
    /// The GA options a job resolves to (overrides applied over defaults).
    pub fn ga_options(&self, job: &DseJob) -> GaOptions {
        job.ga
            .as_ref()
            .unwrap_or(&self.ga_defaults)
            .to_options(job.ga_seed.unwrap_or(self.default_seed))
    }

    /// Run one job: constraints → ConSS pool → GA (AppAxO baseline) and
    /// ConSS+GA (augmented AxOCS), all fitness through the shared service.
    pub fn run_job(&self, job: &DseJob) -> Result<DseOutcome> {
        let constraints =
            Constraints::from_scaling_factor(job.factor, &self.h_objectives)?;
        let reference = constraints.reference();

        // TRAIN: hypervolume of the characterized sample itself.
        let hv_train = hypervolume2d(&self.h_objectives, reference);

        // Standalone ConSS: supersample → predicted objectives → HV.
        let pool = self.pipeline.supersample_as(
            job.seed_selection,
            Some(&constraints),
            &self.h_objectives,
        )?;
        let conss_objs = {
            let mut span = crate::obs::span(crate::obs::n::ESTIMATOR_PREDICT);
            span.set_arg(pool.configs.len() as u64);
            self.service.predict(pool.configs.clone())?
        };
        let hv_conss = hypervolume2d(&conss_objs, reference);

        // GA (AppAxO-style, random init) and ConSS+GA (augmented), both
        // driving the shared batching service as their Fitness backend.
        let opts = self.ga_options(job);
        let ga = appaxo_search(
            self.op.config_len(),
            &self.service,
            constraints,
            opts.clone(),
        )?;
        let conss_ga = NsgaRunner::new(opts, constraints).run(
            self.op.config_len(),
            &self.service,
            &pool.configs,
        )?;

        Ok(DseOutcome {
            factor: job.factor,
            constraints,
            hv_train,
            hv_conss,
            conss_pool: pool,
            conss_objs,
            ga,
            conss_ga,
        })
    }

    /// Run independent jobs concurrently on scoped worker threads
    /// (`REPRO_THREADS` wide), results in job order. All searches share
    /// the one estimator service, so their fitness batches coalesce.
    pub fn run_many(&self, jobs: &[DseJob]) -> Result<Vec<DseOutcome>> {
        parallel_map(jobs, |_, job| self.run_job(job)).into_iter().collect()
    }
}

/// Candidate set for VPF validation: the predicted front plus the final
/// population (the paper re-characterizes 31-390 designs per factor, far
/// more than the front alone).
pub fn vpf_candidates(result: &GaResult) -> Vec<AxoConfig> {
    let mut seen = HashSet::new();
    let mut out = Vec::new();
    for c in result.front_configs.iter().chain(&result.population) {
        if seen.insert(c.as_uint()) {
            out.push(*c);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expcfg::{ConssConfig, ExperimentConfig, SurrogateConfig};
    use crate::surrogate::EstimatorBackend;

    fn tiny_cfg() -> ExperimentConfig {
        ExperimentConfig {
            operator: "add8".into(),
            surrogate: SurrogateConfig {
                backend: EstimatorBackend::Table,
                gbt_stages: None,
            },
            conss: ConssConfig {
                forest_trees: Some(4),
                noise_bits: 2,
                ..Default::default()
            },
            ga: GaConfig { pop_size: 10, generations: 4, ..Default::default() },
            ..Default::default()
        }
    }

    #[test]
    fn prepare_and_run_single_job() {
        let ctx = EngineContext::new(tiny_cfg());
        let prep = ctx.prepare_dse().unwrap();
        assert_eq!(prep.op, Operator::ADD8);
        assert_eq!(prep.l_op, Operator::ADD4);
        let out = prep.run_job(&DseJob::new(0.8)).unwrap();
        assert!(out.hv_train > 0.0);
        assert_eq!(out.conss_objs.len(), out.conss_pool.configs.len());
        assert!(out.conss_ga.final_hypervolume() >= 0.0);
        // Datasets came from the cache exactly once each.
        assert_eq!(ctx.cache_stats().entries, 2);
    }

    #[test]
    fn job_builder_overrides() {
        let job = DseJob::new(0.5)
            .seed_selection(SeedSelection::ParetoOnly)
            .ga(GaConfig { pop_size: 8, generations: 2, ..Default::default() })
            .ga_seed(7);
        assert_eq!(job.seed_selection, SeedSelection::ParetoOnly);
        assert_eq!(job.ga.as_ref().unwrap().pop_size, 8);
        assert_eq!(job.ga_seed, Some(7));
    }

    #[test]
    fn vpf_candidates_dedup() {
        let c1 = AxoConfig::new(3, 8).unwrap();
        let c2 = AxoConfig::new(5, 8).unwrap();
        let r = GaResult {
            population: vec![c1, c2],
            objectives: vec![[0.0, 0.0]; 2],
            front_configs: vec![c1],
            front_points: vec![[0.0, 0.0]],
            hv_history: vec![0.0],
            evaluations: 2,
        };
        let cands = vpf_candidates(&r);
        assert_eq!(cands, vec![c1, c2]);
    }
}
