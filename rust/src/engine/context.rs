//! [`EngineContext`] — process-wide shared state for DSE jobs.
//!
//! Two resources dominate the cost of every AxOCS flow and were previously
//! re-created by each caller: characterized datasets (L_CHAR/H_CHAR, minutes
//! at paper scale) and the trained estimator backend behind the batching
//! service. The context owns both:
//!
//! * a **thread-safe dataset cache** keyed by operator × characterization
//!   backend × sample spec, so each dataset is characterized exactly once
//!   per process no matter how many jobs, figures, or examples ask for it;
//! * a **lazily-spawned shared [`EstimatorService`]** fronting the
//!   configured surrogate backend, so concurrent searches funnel fitness
//!   queries through one batcher and their batches coalesce.
//!
//! The cache lock is held across characterization on purpose: the invariant
//! is "exactly once per process", and the expensive datasets are pre-warmed
//! by [`EngineContext::prepare_dse`] before any job fan-out, so the lock is
//! uncontended on the hot path.

use crate::charac::{characterize, characterize_all, Backend, Dataset, InputSet};
use crate::coordinator::EstimatorService;
use crate::error::{Error, Result};
use crate::expcfg::ExperimentConfig;
use crate::operator::{AxoConfig, Operator};
use crate::surrogate::build_backend;
use crate::util::rng::Rng;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Which substrate characterized a cached dataset. Only the native
/// bit-exact substrate is routed through the cache today; the variant
/// exists so PJRT-characterized datasets get distinct keys when the
/// runtime path starts feeding the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CharacSubstrate {
    Native,
}

/// How a dataset's configurations were selected.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SampleSpec {
    /// Every usable configuration of the space.
    Exhaustive,
    /// `n` unique configurations drawn from the seeded sampler.
    Seeded { seed: u64, n: usize },
}

/// Cache key: operator × substrate × sample spec.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct DatasetKey {
    pub op: Operator,
    pub substrate: CharacSubstrate,
    pub spec: SampleSpec,
}

/// Point-in-time dataset-cache counters.
#[derive(Debug, Clone, Copy)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
}

/// The low-bit-width ConSS partner of an operator (paper Table II arrows).
pub fn l_operator(h: Operator) -> Result<Operator> {
    Ok(match h {
        Operator::ADD8 => Operator::ADD4,
        Operator::ADD12 => Operator::ADD8,
        Operator::MUL8 => Operator::MUL4,
        other => {
            return Err(Error::Config(format!("no smaller ConSS partner for {other}")))
        }
    })
}

/// Shared engine state: configuration, dataset cache, estimator service.
pub struct EngineContext {
    cfg: ExperimentConfig,
    datasets: Mutex<HashMap<DatasetKey, Arc<Dataset>>>,
    estimator: Mutex<Option<EstimatorService>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl EngineContext {
    pub fn new(cfg: ExperimentConfig) -> EngineContext {
        EngineContext {
            cfg,
            datasets: Mutex::new(HashMap::new()),
            estimator: Mutex::new(None),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    pub fn cfg(&self) -> &ExperimentConfig {
        &self.cfg
    }

    /// The default sample spec for `op` under this configuration:
    /// exhaustive where enumerable, else the seeded `train_samples` draw
    /// (paper §V-B — only the 8×8 multiplier space needs sampling).
    pub fn default_spec(&self, op: Operator) -> SampleSpec {
        if op.exhaustive() {
            SampleSpec::Exhaustive
        } else {
            SampleSpec::Seeded { seed: self.cfg.seed, n: self.cfg.train_samples }
        }
    }

    /// Characterized dataset for `op` under the default spec, cached.
    pub fn dataset(&self, op: Operator) -> Result<Arc<Dataset>> {
        self.dataset_with(op, self.default_spec(op))
    }

    /// Characterized dataset for `op` under an explicit spec, cached.
    pub fn dataset_with(&self, op: Operator, spec: SampleSpec) -> Result<Arc<Dataset>> {
        let key = DatasetKey { op, substrate: CharacSubstrate::Native, spec };
        let mut cache = self.datasets.lock().expect("engine dataset cache poisoned");
        if let Some(ds) = cache.get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(ds.clone());
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        if spec == SampleSpec::Exhaustive && !op.exhaustive() {
            return Err(Error::Config(format!(
                "{op} is not exhaustively characterizable (2^{} designs)",
                op.config_len()
            )));
        }
        let inputs = InputSet::for_operator(op, &self.cfg.artifacts_dir)?;
        let ds = match spec {
            SampleSpec::Exhaustive => characterize_all(op, &inputs, &Backend::Native)?,
            SampleSpec::Seeded { seed, n } => {
                let mut rng = Rng::seed_from_u64(seed);
                let cfgs = AxoConfig::sample_unique(op.config_len(), n, &mut rng);
                characterize(op, &cfgs, &inputs, &Backend::Native)?
            }
        };
        let arc = Arc::new(ds);
        cache.insert(key, arc.clone());
        Ok(arc)
    }

    /// Characterize arbitrary configs of `op` natively (PPF → VPF
    /// validation). Deliberately uncached: validation sets are one-shot.
    pub fn validate(&self, op: Operator, configs: &[AxoConfig]) -> Result<Dataset> {
        let inputs = InputSet::for_operator(op, &self.cfg.artifacts_dir)?;
        characterize(op, configs, &inputs, &Backend::Native)
    }

    /// The shared estimator service for the configured operator, spawned on
    /// first use. Every caller gets a clone of the same handle, so fitness
    /// batches coalesce across concurrent searches; the batcher thread
    /// exits when the context (and all clones) drop.
    pub fn estimator(&self) -> Result<EstimatorService> {
        let mut slot = self.estimator.lock().expect("engine estimator slot poisoned");
        if let Some(svc) = slot.as_ref() {
            return Ok(svc.clone());
        }
        let op = Operator::from_name(&self.cfg.operator)?;
        let backend = build_backend(
            self.cfg.surrogate.backend,
            self.cfg.surrogate.gbt_stages,
            &self.cfg.artifacts_dir,
            op,
            || self.dataset(op),
        )?;
        let svc = EstimatorService::spawn(backend, self.cfg.service.to_batch_options());
        *slot = Some(svc.clone());
        Ok(svc)
    }

    pub fn cache_stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: self.datasets.lock().expect("engine dataset cache poisoned").len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ExperimentConfig {
        ExperimentConfig {
            operator: "add8".into(),
            train_samples: 100,
            ..Default::default()
        }
    }

    #[test]
    fn dataset_is_characterized_once_and_shared() {
        let ctx = EngineContext::new(tiny_cfg());
        let a = ctx.dataset(Operator::ADD4).unwrap();
        let b = ctx.dataset(Operator::ADD4).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(a.len(), 15);
        let s = ctx.cache_stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
    }

    #[test]
    fn distinct_specs_get_distinct_entries() {
        let ctx = EngineContext::new(tiny_cfg());
        let full = ctx.dataset_with(Operator::ADD8, SampleSpec::Exhaustive).unwrap();
        let sampled = ctx
            .dataset_with(Operator::ADD8, SampleSpec::Seeded { seed: 1, n: 40 })
            .unwrap();
        assert_eq!(full.len(), 255);
        assert_eq!(sampled.len(), 40);
        assert_eq!(ctx.cache_stats().entries, 2);
    }

    #[test]
    fn exhaustive_spec_rejected_for_huge_spaces() {
        let ctx = EngineContext::new(tiny_cfg());
        assert!(ctx.dataset_with(Operator::MUL8, SampleSpec::Exhaustive).is_err());
        // The default spec for mul8 is a seeded sample, not exhaustive.
        assert_eq!(
            ctx.default_spec(Operator::MUL8),
            SampleSpec::Seeded { seed: 2023, n: 100 }
        );
    }

    #[test]
    fn l_operator_pairs() {
        assert_eq!(l_operator(Operator::MUL8).unwrap(), Operator::MUL4);
        assert_eq!(l_operator(Operator::ADD8).unwrap(), Operator::ADD4);
        assert_eq!(l_operator(Operator::ADD12).unwrap(), Operator::ADD8);
        assert!(l_operator(Operator::ADD4).is_err());
    }

    #[test]
    fn estimator_is_spawned_once() {
        let ctx = EngineContext::new(tiny_cfg());
        let a = ctx.estimator().unwrap();
        let b = ctx.estimator().unwrap();
        // Both handles point at the same metrics allocation → one service.
        assert!(std::ptr::eq(a.metrics(), b.metrics()));
        a.predict(vec![AxoConfig::new(3, 8).unwrap()]).unwrap();
        assert_eq!(b.metrics().snapshot().requests, 1);
    }
}
