//! [`EngineContext`] — process-wide shared state for DSE jobs.
//!
//! Two resources dominate the cost of every AxOCS flow and were previously
//! re-created by each caller: characterized datasets (L_CHAR/H_CHAR, minutes
//! at paper scale) and the trained estimator backend behind the batching
//! service. The context owns both:
//!
//! * a **thread-safe dataset cache** keyed by operator × characterization
//!   backend × sample spec, with a *per-key* in-flight guard: the map lock
//!   is only held to find a key's cell, never across characterization, so
//!   concurrent misses on different keys characterize in parallel while a
//!   second miss on the *same* key blocks and then observes the result;
//! * an optional **persistent [`DatasetStore`]** consulted on cache miss
//!   and written on characterize, so repeated processes warm-start from
//!   disk instead of re-paying H_CHAR;
//! * a keyed **estimator pool** ([`EstimatorKey`] = operator × surrogate
//!   backend → lazily-spawned [`EstimatorService`]), so heterogeneous
//!   workloads (an add12 job next to a mul8 job, as the serve-mode queue
//!   produces) coexist in one process without evicting each other, while
//!   every caller for the same key funnels fitness queries through one
//!   batcher and their batches coalesce.
//!
//! `Seeded` characterizations are split into deterministic sub-range
//! shards on the work-stealing pool
//! ([`characterize_sharded`](crate::charac::characterize_sharded)), merged
//! order-stably — bit-identical to the sequential path.

use super::store::DatasetStore;
use crate::charac::{
    characterize_sharded_timed, characterize_timed, BehavBackend, Dataset, InputSet,
    PhaseTiming, PpaBackend,
};
use crate::coordinator::{EstimatorService, MetricsSnapshot};
use crate::error::{Error, Result};
use crate::expcfg::ExperimentConfig;
use crate::operator::{AxoConfig, Operator};
use crate::surrogate::{build_backend, EstimatorBackend};
use crate::util::rng::Rng;
use std::collections::HashMap;
use std::hash::Hash;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Which substrate characterized a cached dataset. Only the native
/// bit-exact substrate is routed through the cache today; the variant
/// exists so PJRT-characterized datasets get distinct keys when the
/// runtime path starts feeding the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CharacSubstrate {
    Native,
}

/// How a dataset's configurations were selected.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SampleSpec {
    /// Every usable configuration of the space.
    Exhaustive,
    /// `n` unique configurations drawn from the seeded sampler.
    Seeded { seed: u64, n: usize },
}

/// Cache key: operator × substrate × sample spec.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct DatasetKey {
    pub op: Operator,
    pub substrate: CharacSubstrate,
    pub spec: SampleSpec,
}

/// Point-in-time dataset-cache counters.
#[derive(Debug, Clone, Copy)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
    /// Cache misses served from the persistent on-disk store.
    pub store_hits: u64,
    /// Cache misses that ran an actual characterization.
    pub characterized: u64,
    /// Aggregate nanoseconds the fused pipeline spent on BEHAV metrics
    /// (summed across work-stealing tasks, so concurrent shards each
    /// contribute their own clock).
    pub behav_ns: u64,
    /// Aggregate nanoseconds spent on PPA metrics (same accounting).
    pub ppa_ns: u64,
}

/// Estimator-pool key: which operator the service predicts for, under
/// which surrogate backend. Distinct operators (add12 next to mul8 in a
/// serve-mode queue) get distinct resident services; a second request for
/// the same key reuses the already-spawned one.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct EstimatorKey {
    pub op: Operator,
    pub backend: EstimatorBackend,
}

/// Point-in-time estimator-pool counters.
#[derive(Debug, Clone, Copy)]
pub struct PoolStats {
    /// Requests served by an already-resident service.
    pub hits: u64,
    /// Services (backend build + batcher spawn) actually created.
    pub spawned: u64,
    /// Resident services right now.
    pub services: usize,
}

/// The low-bit-width ConSS partner of an operator (paper Table II arrows).
pub fn l_operator(h: Operator) -> Result<Operator> {
    Ok(match h {
        Operator::ADD8 => Operator::ADD4,
        Operator::ADD12 => Operator::ADD8,
        Operator::MUL8 => Operator::MUL4,
        other => {
            return Err(Error::Config(format!("no smaller ConSS partner for {other}")))
        }
    })
}

/// Per-key once-map: each key owns a cell whose lock is held across that
/// key's (single) computation, while the outer map lock is only held to
/// find or create the cell. Concurrent computes on distinct keys therefore
/// run in parallel; a second request for an in-flight key blocks on the
/// cell and then observes the first result. A failed compute leaves the
/// cell empty, so the next request retries.
///
/// Shared (crate-wide) by the dataset cache, the estimator pool, and the
/// serve-mode per-operator `DsePrepared` pool — every "expensive resource,
/// build at most once per key, misses on distinct keys proceed in
/// parallel" need uses this one primitive.
type Cell<V> = Arc<Mutex<Option<Arc<V>>>>;

pub(crate) struct KeyedOnce<K, V> {
    cells: Mutex<HashMap<K, Cell<V>>>,
}

impl<K: Eq + Hash + Copy, V> KeyedOnce<K, V> {
    pub(crate) fn new() -> KeyedOnce<K, V> {
        KeyedOnce { cells: Mutex::new(HashMap::new()) }
    }

    /// Fetch `key`, running `compute` under the key's cell lock if absent.
    /// Returns the value and whether it was already present.
    pub(crate) fn get_or_try_compute(
        &self,
        key: K,
        compute: impl FnOnce() -> Result<Arc<V>>,
    ) -> Result<(Arc<V>, bool)> {
        let cell = {
            let mut map = self.cells.lock().expect("keyed cache map poisoned");
            map.entry(key).or_default().clone()
        };
        let mut slot = cell.lock().expect("keyed cache cell poisoned");
        if let Some(v) = slot.as_ref() {
            return Ok((v.clone(), true));
        }
        let v = compute()?;
        *slot = Some(v.clone());
        Ok((v, false))
    }

    /// Number of keys whose computation has completed. Snapshots the cell
    /// list first (the map lock must never be held while touching cell
    /// locks), then counts via `try_lock`: a cell whose lock is contended
    /// is mid-compute, i.e. not yet filled — so a stats probe never blocks
    /// behind an in-flight characterization.
    pub(crate) fn filled(&self) -> usize {
        self.values().len()
    }

    /// Snapshot of every completed value, skipping in-flight cells by the
    /// same non-blocking `try_lock` discipline as [`KeyedOnce::filled`].
    pub(crate) fn values(&self) -> Vec<Arc<V>> {
        let cells: Vec<Cell<V>> = {
            let map = self.cells.lock().expect("keyed cache map poisoned");
            map.values().cloned().collect()
        };
        cells
            .iter()
            .filter_map(|cell| match cell.try_lock().as_deref() {
                Ok(Some(v)) => Some(v.clone()),
                _ => None,
            })
            .collect()
    }
}

/// Shared engine state: configuration, dataset cache, optional persistent
/// store, keyed estimator pool.
pub struct EngineContext {
    cfg: ExperimentConfig,
    datasets: KeyedOnce<DatasetKey, Dataset>,
    inputs: KeyedOnce<Operator, InputSet>,
    store: Option<DatasetStore>,
    estimators: KeyedOnce<EstimatorKey, EstimatorService>,
    hits: AtomicU64,
    misses: AtomicU64,
    store_hits: AtomicU64,
    characterized: AtomicU64,
    behav_ns: AtomicU64,
    ppa_ns: AtomicU64,
    pool_hits: AtomicU64,
    pool_spawned: AtomicU64,
}

impl EngineContext {
    pub fn new(cfg: ExperimentConfig) -> EngineContext {
        let store = cfg
            .store
            .is_enabled()
            .then(|| DatasetStore::open(cfg.store.dir_under(&cfg.artifacts_dir)));
        EngineContext {
            cfg,
            datasets: KeyedOnce::new(),
            inputs: KeyedOnce::new(),
            store,
            estimators: KeyedOnce::new(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            store_hits: AtomicU64::new(0),
            characterized: AtomicU64::new(0),
            behav_ns: AtomicU64::new(0),
            ppa_ns: AtomicU64::new(0),
            pool_hits: AtomicU64::new(0),
            pool_spawned: AtomicU64::new(0),
        }
    }

    pub fn cfg(&self) -> &ExperimentConfig {
        &self.cfg
    }

    /// The persistent dataset store, when enabled by the configuration.
    pub fn store(&self) -> Option<&DatasetStore> {
        self.store.as_ref()
    }

    /// The resolved native BEHAV implementation this context characterizes
    /// with (`REPRO_BEHAV` env > `[charac] behav` > bit-sliced default).
    /// Both implementations are bit-identical, so the choice never keys
    /// the dataset cache or the persistent store.
    pub fn behav_backend(&self) -> BehavBackend {
        BehavBackend::resolve(self.cfg.charac.behav)
    }

    /// The resolved PPA implementation this context characterizes with
    /// (`REPRO_PPA` env > `[charac] ppa` > plane default). Like the BEHAV
    /// choice, both implementations are bit-identical, so the backend
    /// never keys the dataset cache or the persistent store.
    pub fn ppa_backend(&self) -> PpaBackend {
        PpaBackend::resolve(self.cfg.charac.ppa)
    }

    /// Fold one characterization's phase clocks into the running totals
    /// surfaced by [`EngineContext::cache_stats`] and `/metrics`.
    fn record_timing(&self, timing: PhaseTiming) {
        self.behav_ns.fetch_add(timing.behav_ns, Ordering::Relaxed);
        self.ppa_ns.fetch_add(timing.ppa_ns, Ordering::Relaxed);
    }

    /// The default sample spec for `op` under this configuration:
    /// exhaustive where enumerable, else the seeded `train_samples` draw
    /// (paper §V-B — only the 8×8 multiplier space needs sampling).
    pub fn default_spec(&self, op: Operator) -> SampleSpec {
        if op.exhaustive() {
            SampleSpec::Exhaustive
        } else {
            SampleSpec::Seeded { seed: self.cfg.seed, n: self.cfg.train_samples }
        }
    }

    /// Characterization inputs for `op`, loaded once per context and
    /// shared by every dataset build and VPF validation batch (previously
    /// re-read from disk on each `validate` call).
    pub fn inputs(&self, op: Operator) -> Result<Arc<InputSet>> {
        let (inputs, _) = self.inputs.get_or_try_compute(op, || {
            Ok(Arc::new(InputSet::for_operator(op, &self.cfg.artifacts_dir)?))
        })?;
        Ok(inputs)
    }

    /// Characterized dataset for `op` under the default spec, cached.
    pub fn dataset(&self, op: Operator) -> Result<Arc<Dataset>> {
        self.dataset_with(op, self.default_spec(op))
    }

    /// Characterized dataset for `op` under an explicit spec: in-memory
    /// cache first, then the persistent store (entries are only served
    /// when their recorded input-set fingerprint matches the inputs this
    /// context characterizes against), then a (sharded) characterization
    /// whose result is written back to the store.
    pub fn dataset_with(&self, op: Operator, spec: SampleSpec) -> Result<Arc<Dataset>> {
        let key = DatasetKey { op, substrate: CharacSubstrate::Native, spec };
        let (ds, was_hit) = self.datasets.get_or_try_compute(key, || {
            if spec == SampleSpec::Exhaustive && !op.exhaustive() {
                return Err(Error::Config(format!(
                    "{op} is not exhaustively characterizable (2^{} designs)",
                    op.config_len()
                )));
            }
            let inputs = self.inputs(op)?;
            let inputs_fp = super::store::inputs_fingerprint(&inputs);
            if let Some(store) = &self.store {
                if let Some(ds) = store.load(&key, inputs_fp)? {
                    self.store_hits.fetch_add(1, Ordering::Relaxed);
                    return Ok(Arc::new(ds));
                }
            }
            let ds = self.characterize_spec(op, spec, &inputs)?;
            self.characterized.fetch_add(1, Ordering::Relaxed);
            if let Some(store) = &self.store {
                if let Err(e) = store.save(&key, &ds, inputs_fp) {
                    eprintln!(
                        "warning: failed to persist dataset {}: {e}",
                        super::store::key_slug(&key)
                    );
                }
            }
            Ok(Arc::new(ds))
        })?;
        if was_hit {
            self.hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }
        Ok(ds)
    }

    /// Run the actual characterization for a cache miss: exhaustive spaces
    /// in one call, seeded samples as deterministic sub-range shards on
    /// the work-stealing pool.
    fn characterize_spec(
        &self,
        op: Operator,
        spec: SampleSpec,
        inputs: &InputSet,
    ) -> Result<Dataset> {
        let mut span = crate::obs::span(crate::obs::n::ENGINE_CHARACTERIZE);
        let behav = self.behav_backend();
        let ppa = self.ppa_backend();
        let (ds, timing) = match spec {
            SampleSpec::Exhaustive => {
                assert!(
                    op.exhaustive(),
                    "{op} design space must be sampled, not enumerated"
                );
                let cfgs: Vec<AxoConfig> =
                    AxoConfig::enumerate(op.config_len()).collect();
                characterize_timed(op, &cfgs, inputs, behav, ppa)?
            }
            SampleSpec::Seeded { seed, n } => {
                let mut rng = Rng::seed_from_u64(seed);
                let cfgs = AxoConfig::sample_unique(op.config_len(), n, &mut rng);
                characterize_sharded_timed(
                    op,
                    &cfgs,
                    inputs,
                    self.cfg.charac.shard_size,
                    behav,
                    ppa,
                )?
            }
        };
        span.set_arg(ds.len() as u64);
        self.record_timing(timing);
        Ok(ds)
    }

    /// Characterize arbitrary configs of `op` natively (PPF → VPF
    /// validation). Deliberately uncached: validation sets are one-shot
    /// (the inputs they share *are* cached per operator).
    pub fn validate(&self, op: Operator, configs: &[AxoConfig]) -> Result<Dataset> {
        let inputs = self.inputs(op)?;
        let (ds, timing) = characterize_timed(
            op,
            configs,
            &inputs,
            self.behav_backend(),
            self.ppa_backend(),
        )?;
        self.record_timing(timing);
        Ok(ds)
    }

    /// The shared estimator service for the configured operator, spawned on
    /// first use (see [`EngineContext::estimator_for`]).
    pub fn estimator(&self) -> Result<EstimatorService> {
        self.estimator_for(Operator::from_name(&self.cfg.operator)?)
    }

    /// The pooled estimator service for `op` under the configured surrogate
    /// backend, spawned on first use per [`EstimatorKey`]. Every caller for
    /// the same key gets a clone of the same handle, so fitness batches
    /// coalesce across concurrent searches; heterogeneous operators get
    /// distinct resident services (nothing is evicted). The same per-key
    /// in-flight guard as the dataset cache applies: two concurrent first
    /// requests for one key build one backend, while first requests for
    /// *different* keys build in parallel. Batcher threads exit when the
    /// context (and all handle clones) drop.
    pub fn estimator_for(&self, op: Operator) -> Result<EstimatorService> {
        let key = EstimatorKey { op, backend: self.cfg.surrogate.backend };
        let (svc, was_hit) = self.estimators.get_or_try_compute(key, || {
            let backend = build_backend(
                key.backend,
                self.cfg.surrogate.gbt_stages,
                &self.cfg.artifacts_dir,
                op,
                || self.dataset(op),
            )?;
            self.pool_spawned.fetch_add(1, Ordering::Relaxed);
            Ok(Arc::new(EstimatorService::spawn(
                backend,
                self.cfg.service.to_batch_options(),
            )))
        })?;
        if was_hit {
            self.pool_hits.fetch_add(1, Ordering::Relaxed);
        }
        Ok((*svc).clone())
    }

    pub fn cache_stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: self.datasets.filled(),
            store_hits: self.store_hits.load(Ordering::Relaxed),
            characterized: self.characterized.load(Ordering::Relaxed),
            behav_ns: self.behav_ns.load(Ordering::Relaxed),
            ppa_ns: self.ppa_ns.load(Ordering::Relaxed),
        }
    }

    pub fn pool_stats(&self) -> PoolStats {
        PoolStats {
            hits: self.pool_hits.load(Ordering::Relaxed),
            spawned: self.pool_spawned.load(Ordering::Relaxed),
            services: self.estimators.filled(),
        }
    }

    /// Pool-aware service metrics: one [`MetricsSnapshot`] aggregated over
    /// every resident estimator service, so serve-mode reporting sees the
    /// whole process's request path no matter how many operators are live.
    pub fn pool_metrics(&self) -> MetricsSnapshot {
        self.estimators
            .values()
            .iter()
            .map(|svc| svc.metrics().snapshot())
            .fold(MetricsSnapshot::default(), |acc, s| acc.merged(&s))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::time::Duration;

    fn tiny_cfg() -> ExperimentConfig {
        ExperimentConfig {
            operator: "add8".into(),
            train_samples: 100,
            ..Default::default()
        }
    }

    #[test]
    fn dataset_is_characterized_once_and_shared() {
        let ctx = EngineContext::new(tiny_cfg());
        let a = ctx.dataset(Operator::ADD4).unwrap();
        let b = ctx.dataset(Operator::ADD4).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(a.len(), 15);
        let s = ctx.cache_stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert_eq!(s.characterized, 1);
        assert_eq!(s.store_hits, 0, "store is off by default in library use");
        assert!(ctx.store().is_none());
    }

    #[test]
    fn distinct_specs_get_distinct_entries() {
        let ctx = EngineContext::new(tiny_cfg());
        let full = ctx.dataset_with(Operator::ADD8, SampleSpec::Exhaustive).unwrap();
        let sampled = ctx
            .dataset_with(Operator::ADD8, SampleSpec::Seeded { seed: 1, n: 40 })
            .unwrap();
        assert_eq!(full.len(), 255);
        assert_eq!(sampled.len(), 40);
        assert_eq!(ctx.cache_stats().entries, 2);
    }

    #[test]
    fn exhaustive_spec_rejected_for_huge_spaces() {
        let ctx = EngineContext::new(tiny_cfg());
        assert!(ctx.dataset_with(Operator::MUL8, SampleSpec::Exhaustive).is_err());
        // The default spec for mul8 is a seeded sample, not exhaustive.
        assert_eq!(
            ctx.default_spec(Operator::MUL8),
            SampleSpec::Seeded { seed: 2023, n: 100 }
        );
        // A failed compute leaves no entry behind (and no characterization
        // was counted).
        let s = ctx.cache_stats();
        assert_eq!(s.entries, 0);
        assert_eq!(s.characterized, 0);
    }

    #[test]
    fn l_operator_pairs() {
        assert_eq!(l_operator(Operator::MUL8).unwrap(), Operator::MUL4);
        assert_eq!(l_operator(Operator::ADD8).unwrap(), Operator::ADD4);
        assert_eq!(l_operator(Operator::ADD12).unwrap(), Operator::ADD8);
        assert!(l_operator(Operator::ADD4).is_err());
    }

    #[test]
    fn estimator_is_spawned_once() {
        let ctx = EngineContext::new(tiny_cfg());
        let a = ctx.estimator().unwrap();
        let b = ctx.estimator().unwrap();
        // Both handles point at the same metrics allocation → one service.
        assert!(std::ptr::eq(a.metrics(), b.metrics()));
        a.predict(vec![AxoConfig::new(3, 8).unwrap()]).unwrap();
        assert_eq!(b.metrics().snapshot().requests, 1);
        let p = ctx.pool_stats();
        assert_eq!((p.spawned, p.hits, p.services), (1, 1, 1));
    }

    #[test]
    fn estimator_pool_keys_by_operator_without_eviction() {
        let ctx = EngineContext::new(tiny_cfg());
        let a = ctx.estimator_for(Operator::ADD4).unwrap();
        let b = ctx.estimator_for(Operator::ADD8).unwrap();
        let a2 = ctx.estimator_for(Operator::ADD4).unwrap();
        // Same key → same resident service; distinct keys coexist.
        assert!(std::ptr::eq(a.metrics(), a2.metrics()));
        assert!(!std::ptr::eq(a.metrics(), b.metrics()));
        let p = ctx.pool_stats();
        assert_eq!((p.spawned, p.hits, p.services), (2, 1, 2));

        // Pool-aware metrics aggregate every resident service.
        a.predict(vec![AxoConfig::new(3, 4).unwrap()]).unwrap();
        b.predict(vec![AxoConfig::new(3, 8).unwrap(), AxoConfig::new(5, 8).unwrap()])
            .unwrap();
        let merged = ctx.pool_metrics();
        assert_eq!(merged.requests, 2);
        assert_eq!(merged.configs, 3);
    }

    // -- KeyedOnce semantics -------------------------------------------------

    #[test]
    fn keyed_once_distinct_keys_compute_concurrently() {
        // Each compute closure announces itself, then waits for the *other*
        // closure's announcement: this only completes if both keys are in
        // flight simultaneously. A serialized cache (one lock across the
        // compute) would time out here.
        let m: KeyedOnce<u32, u32> = KeyedOnce::new();
        let (tx1, rx1) = mpsc::channel::<()>();
        let (tx2, rx2) = mpsc::channel::<()>();
        let wait = Duration::from_secs(30);
        let mref = &m;
        std::thread::scope(|s| {
            let ha = s.spawn(move || {
                mref.get_or_try_compute(1, move || {
                    tx1.send(()).unwrap();
                    rx2.recv_timeout(wait).expect(
                        "key 2 never started computing while key 1 was in flight \
                         — distinct-key misses are serializing",
                    );
                    Ok(Arc::new(10))
                })
            });
            let hb = s.spawn(move || {
                mref.get_or_try_compute(2, move || {
                    tx2.send(()).unwrap();
                    rx1.recv_timeout(wait).expect(
                        "key 1 never started computing while key 2 was in flight \
                         — distinct-key misses are serializing",
                    );
                    Ok(Arc::new(20))
                })
            });
            assert_eq!(*ha.join().unwrap().unwrap().0, 10);
            assert_eq!(*hb.join().unwrap().unwrap().0, 20);
        });
        assert_eq!(m.filled(), 2);
    }

    #[test]
    fn keyed_once_same_key_computes_exactly_once() {
        let m: KeyedOnce<u32, u32> = KeyedOnce::new();
        let computes = AtomicU64::new(0);
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|_| {
                    s.spawn(|| {
                        m.get_or_try_compute(7, || {
                            computes.fetch_add(1, Ordering::Relaxed);
                            // Widen the race window.
                            std::thread::sleep(Duration::from_millis(5));
                            Ok(Arc::new(42))
                        })
                        .unwrap()
                    })
                })
                .collect();
            for h in handles {
                assert_eq!(*h.join().unwrap().0, 42);
            }
        });
        assert_eq!(computes.load(Ordering::Relaxed), 1);
        assert_eq!(m.filled(), 1);
    }

    #[test]
    fn keyed_once_failed_compute_retries() {
        let m: KeyedOnce<u32, u32> = KeyedOnce::new();
        let r = m.get_or_try_compute(1, || Err(Error::Config("transient".into())));
        assert!(r.is_err());
        assert_eq!(m.filled(), 0);
        let (v, hit) = m.get_or_try_compute(1, || Ok(Arc::new(5))).unwrap();
        assert_eq!((*v, hit), (5, false));
        let (v, hit) = m.get_or_try_compute(1, || unreachable!()).unwrap();
        assert_eq!((*v, hit), (5, true));
    }
}
