//! [`DatasetStore`] — the persistent on-disk dataset store.
//!
//! Spills characterized datasets to `artifacts_dir/datasets/` keyed by the
//! engine's [`DatasetKey`] (operator × substrate × sample spec), so
//! repeated CLI invocations, CI jobs, and the figure harness warm-start
//! from disk instead of re-paying H_CHAR. Layout:
//!
//! ```text
//! datasets/
//!   manifest.json            {"version": 1, "entries": {"<slug>": {...}}}
//!   <slug>.json              Dataset::save_json payload per entry
//! ```
//!
//! Every entry records an FNV-1a 64 content hash in the manifest; loads
//! re-hash the file bytes before parsing. A failed integrity check (hash
//! mismatch, truncated/garbled payload, stale format version) is a *miss*
//! — the caller re-characterizes and overwrites — while genuine I/O
//! faults (permissions, short reads) surface as errors so a real fault is
//! never papered over by silent re-characterization.
//!
//! Manifest read-modify-write is serialized twice over: one process-wide
//! mutex (covering every store instance, whatever directory it points at)
//! and an advisory cross-process lock file (`manifest.lock`, created with
//! `create_new`, holder PID recorded, stale holders taken over) — so a
//! `repro serve-dse` server and ad-hoc `repro dse` runs sharing one
//! `artifacts/datasets/` never interleave manifest updates. Eviction is
//! [`DatasetStore::gc`] (LRU by payload mtime, size-capped).

use super::context::{CharacSubstrate, DatasetKey, SampleSpec};
use crate::charac::Dataset;
use crate::error::{Error, Result};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::{Duration, Instant, SystemTime};

/// Bump when the on-disk layout or the dataset JSON schema changes; a
/// mismatching store is ignored (treated as empty) rather than misread.
pub const STORE_FORMAT_VERSION: u64 = 1;

/// Deterministic filename stem for a dataset key, e.g.
/// `mul8-native-seeded-s2023-n10650` or `add8-native-exhaustive`.
pub fn key_slug(key: &DatasetKey) -> String {
    let substrate = match key.substrate {
        CharacSubstrate::Native => "native",
    };
    match key.spec {
        SampleSpec::Exhaustive => format!("{}-{substrate}-exhaustive", key.op.name()),
        SampleSpec::Seeded { seed, n } => {
            format!("{}-{substrate}-seeded-s{seed}-n{n}", key.op.name())
        }
    }
}

/// FNV-1a 64-bit content hash (std-only; collision resistance is ample
/// for corruption detection, which is all the manifest needs).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

fn parse_hash(s: &str) -> Option<u64> {
    u64::from_str_radix(s, 16).ok()
}

/// Content fingerprint of the input set a dataset was characterized
/// against, recorded in the manifest and checked on load. The cache key
/// alone cannot capture this: the 12-bit adder characterizes against the
/// persisted `inputs_add12.bin` sample when present but a seeded native
/// fallback otherwise, so the same `DatasetKey` can legitimately mean two
/// different input sets across processes — a store hit must only be
/// served when the inputs match.
pub fn inputs_fingerprint(inputs: &crate::charac::InputSet) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut push = |v: i64| {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    };
    push(inputs.a.len() as i64);
    for &v in &inputs.a {
        push(v);
    }
    for &v in &inputs.b {
        push(v);
    }
    h
}

/// One manifest entry as seen by `repro store ls`.
#[derive(Debug, Clone)]
pub struct StoreEntry {
    pub slug: String,
    pub hash: u64,
    pub len: usize,
    pub path: PathBuf,
    /// Payload size on disk (0 when the payload is missing).
    pub bytes: u64,
    /// Payload mtime — the GC's LRU clock (`UNIX_EPOCH` when missing).
    pub modified: SystemTime,
}

/// Outcome of one [`DatasetStore::gc`] sweep.
#[derive(Debug, Clone)]
pub struct GcReport {
    /// Slugs evicted, oldest payload first.
    pub evicted: Vec<String>,
    /// Entries still resident after the sweep.
    pub kept: usize,
    pub bytes_before: u64,
    pub bytes_after: u64,
}

/// Integrity state of one entry, as reported by `repro store verify`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum VerifyStatus {
    Ok,
    MissingFile,
    HashMismatch,
    Corrupt(String),
}

impl std::fmt::Display for VerifyStatus {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            VerifyStatus::Ok => write!(f, "ok"),
            VerifyStatus::MissingFile => write!(f, "missing file"),
            VerifyStatus::HashMismatch => write!(f, "hash mismatch"),
            VerifyStatus::Corrupt(reason) => write!(f, "corrupt: {reason}"),
        }
    }
}

/// Whether a filename is one the store itself writes: the manifest (and
/// its rename temp), or a key-slug payload / payload temp — every slug
/// embeds a `-<substrate>-` marker (see [`key_slug`]), which is what
/// keeps [`DatasetStore::clear`] from touching unrelated files when the
/// configured store directory is shared with other artifacts.
/// `manifest.lock` is deliberately *not* a store file: `clear` runs while
/// holding it, and [`ManifestLock`]'s drop releases it.
fn is_store_file(name: &str) -> bool {
    const SUBSTRATE_TAGS: [&str; 1] = ["native"];
    if name == "manifest.json" || name == ".manifest.tmp" {
        return true;
    }
    let stem = name.strip_prefix('.').unwrap_or(name);
    let Some(stem) = stem.strip_suffix(".json").or_else(|| stem.strip_suffix(".tmp"))
    else {
        return false;
    };
    SUBSTRATE_TAGS.iter().any(|tag| stem.contains(&format!("-{tag}-")))
}

/// Serializes manifest read-modify-write for every store instance in the
/// process — two `DatasetStore`s opened on the same directory (e.g. a DSE
/// engine plus a figure harness) must not interleave manifest updates.
/// Cross-process writers are serialized by [`ManifestLock`] on top.
static WRITE_LOCK: Mutex<()> = Mutex::new(());

/// How long to wait behind a live lock holder before forcibly taking the
/// lock over. A manifest read-modify-write is milliseconds of work, so a
/// holder this old is stuck (or its PID was recycled); takeover is safe
/// because manifest/payload writes are atomic renames and hash-verified —
/// the worst interleaving loses a manifest entry, which the next miss
/// re-characterizes.
const LOCK_WAIT_MAX: Duration = Duration::from_secs(10);
const LOCK_POLL: Duration = Duration::from_millis(5);

/// Advisory cross-process lock on the store's manifest read-modify-write:
/// `manifest.lock` created with `create_new` (the portable atomic
/// test-and-set), holder PID recorded inside, removed on drop. A holder
/// whose PID no longer runs is taken over immediately; a live-but-stuck
/// holder is taken over after [`LOCK_WAIT_MAX`] with a warning.
struct ManifestLock {
    path: PathBuf,
}

impl ManifestLock {
    fn acquire(dir: &Path) -> Result<ManifestLock> {
        let path = dir.join("manifest.lock");
        let deadline = Instant::now() + LOCK_WAIT_MAX;
        loop {
            match std::fs::OpenOptions::new().write(true).create_new(true).open(&path) {
                Ok(mut f) => {
                    use std::io::Write as _;
                    let _ = write!(f, "{}", std::process::id());
                    return Ok(ManifestLock { path });
                }
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    if holder_is_stale(&path) {
                        eprintln!(
                            "warning: taking over stale dataset store lock {} \
                             (holder no longer running)",
                            path.display()
                        );
                        crate::fault::point("store.lock.takeover")?;
                        let _ = std::fs::remove_file(&path);
                        continue;
                    }
                    if Instant::now() >= deadline {
                        eprintln!(
                            "warning: dataset store lock {} held for over {:?} — \
                             taking it over",
                            path.display(),
                            LOCK_WAIT_MAX
                        );
                        crate::fault::point("store.lock.takeover")?;
                        let _ = std::fs::remove_file(&path);
                        continue;
                    }
                    std::thread::sleep(LOCK_POLL);
                }
                Err(e) => return Err(e.into()),
            }
        }
    }
}

impl Drop for ManifestLock {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

/// Whether the lock file records a PID that provably no longer runs. An
/// empty or garbled record (holder crashed between create and write, or
/// mid-write) is *not* provably stale — the wait-timeout takeover covers
/// those.
fn holder_is_stale(path: &Path) -> bool {
    match std::fs::read_to_string(path) {
        Ok(text) => match text.trim().parse::<u32>() {
            Ok(pid) => pid_is_dead(pid),
            Err(_) => false,
        },
        Err(_) => false, // already released, or unreadable: retry the create
    }
}

/// Whether `pid` provably no longer runs (the stale-holder probe shared
/// with the serve queue's orphaned-claim sweep).
#[cfg(target_os = "linux")]
pub(crate) fn pid_is_dead(pid: u32) -> bool {
    !Path::new(&format!("/proc/{pid}")).exists()
}

#[cfg(not(target_os = "linux"))]
pub(crate) fn pid_is_dead(_pid: u32) -> bool {
    false // no portable liveness probe; the wait-timeout takeover covers it
}

/// Disk-backed dataset store. Cheap to construct: the directory is only
/// created on the first write.
pub struct DatasetStore {
    dir: PathBuf,
}

impl DatasetStore {
    pub fn open(dir: PathBuf) -> DatasetStore {
        DatasetStore { dir }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn manifest_path(&self) -> PathBuf {
        self.dir.join("manifest.json")
    }

    fn entry_path(&self, slug: &str) -> PathBuf {
        self.dir.join(format!("{slug}.json"))
    }

    /// The parsed manifest, or `None` for absent / stale-version /
    /// unparseable (the latter with a warning — its entries are
    /// unrecoverable metadata, the datasets get rewritten on demand).
    fn read_manifest(&self) -> Result<Option<Json>> {
        let path = self.manifest_path();
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => {
                return Err(Error::ArtifactCorrupt { path, reason: e.to_string() })
            }
        };
        match Json::parse(&text) {
            Ok(m) if m.get("version").and_then(Json::as_u64)
                == Some(STORE_FORMAT_VERSION) =>
            {
                Ok(Some(m))
            }
            Ok(_) => Ok(None), // older/newer format: treat the store as empty
            Err(e) => {
                eprintln!(
                    "warning: dataset store manifest {} is unparseable ({e}) — \
                     treating the store as empty",
                    path.display()
                );
                Ok(None)
            }
        }
    }

    /// Look up `key` for a dataset characterized against inputs matching
    /// `inputs_fp` (see [`inputs_fingerprint`]). `Ok(None)` is a miss —
    /// absent, stale format, different inputs, or a failed integrity
    /// check (the caller re-characterizes and the next save overwrites
    /// the bad entry). Genuine I/O faults are errors.
    pub fn load(&self, key: &DatasetKey, inputs_fp: u64) -> Result<Option<Dataset>> {
        let slug = key_slug(key);
        let Some(manifest) = self.read_manifest()? else { return Ok(None) };
        let Some(entry) = manifest.get("entries").and_then(|e| e.get(&slug)) else {
            return Ok(None);
        };
        if entry.get("inputs").and_then(Json::as_str).and_then(parse_hash)
            != Some(inputs_fp)
        {
            eprintln!(
                "warning: dataset store entry {slug} was characterized against a \
                 different input set — re-characterizing"
            );
            return Ok(None);
        }
        let want = entry.get("hash").and_then(Json::as_str).and_then(parse_hash);
        let path = self.entry_path(&slug);
        let bytes = match std::fs::read(&path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                eprintln!(
                    "warning: dataset store entry {slug} is in the manifest but \
                     missing on disk — re-characterizing"
                );
                return Ok(None);
            }
            Err(e) => {
                return Err(Error::ArtifactCorrupt { path, reason: e.to_string() })
            }
        };
        if want != Some(fnv1a64(&bytes)) {
            eprintln!(
                "warning: dataset store entry {slug} failed its integrity check — \
                 re-characterizing"
            );
            return Ok(None);
        }
        let parsed = String::from_utf8(bytes)
            .ok()
            .and_then(|t| Json::parse(&t).ok())
            .and_then(|v| Dataset::from_json(&v).ok());
        match parsed {
            Some(ds) if ds.operator == key.op => Ok(Some(ds)),
            _ => {
                eprintln!(
                    "warning: dataset store entry {slug} hash-matches but does not \
                     parse as a {} dataset — re-characterizing",
                    key.op.name()
                );
                Ok(None)
            }
        }
    }

    /// Persist `ds` under `key`: payload written to a temp file and
    /// renamed into place, then the manifest entry (content hash, input
    /// fingerprint, length) updated the same way — all under the
    /// in-process write mutex *and* the cross-process [`ManifestLock`].
    pub fn save(&self, key: &DatasetKey, ds: &Dataset, inputs_fp: u64) -> Result<()> {
        let _guard = WRITE_LOCK.lock().expect("dataset store write lock poisoned");
        std::fs::create_dir_all(&self.dir)?;
        let _lock = ManifestLock::acquire(&self.dir)?;
        let slug = key_slug(key);
        let text = ds.to_json().to_string();
        let hash = fnv1a64(text.as_bytes());
        let tmp = self.dir.join(format!(".{slug}.tmp"));
        // Durable write (fsync) before the rename publishes the payload:
        // atomic against readers either way, but only durable against
        // power loss with the fsync. A `partial` failpoint here models
        // exactly that torn no-fsync write.
        crate::fault::write_file_durable("store.payload.write", &tmp, text.as_bytes())?;
        crate::fault::point("store.payload.rename")?;
        std::fs::rename(&tmp, self.entry_path(&slug))?;
        let mut entries: BTreeMap<String, Json> = self
            .read_manifest()?
            .and_then(|m| m.get("entries").and_then(Json::as_obj).cloned())
            .unwrap_or_default();
        entries.insert(
            slug.clone(),
            Json::obj(vec![
                ("hash", Json::Str(format!("{hash:016x}"))),
                ("inputs", Json::Str(format!("{inputs_fp:016x}"))),
                ("len", Json::Num(ds.len() as f64)),
                ("operator", Json::Str(ds.operator.name())),
                ("file", Json::Str(format!("{slug}.json"))),
            ]),
        );
        self.write_manifest(entries)
    }

    /// Atomically replace the manifest with `entries` (temp + rename).
    /// Callers must hold both write locks.
    fn write_manifest(&self, entries: BTreeMap<String, Json>) -> Result<()> {
        let manifest = Json::obj(vec![
            ("version", Json::Num(STORE_FORMAT_VERSION as f64)),
            ("entries", Json::Obj(entries)),
        ]);
        let mtmp = self.dir.join(".manifest.tmp");
        crate::fault::write_file_durable(
            "store.manifest.write",
            &mtmp,
            manifest.to_string().as_bytes(),
        )?;
        std::fs::rename(&mtmp, self.manifest_path())?;
        Ok(())
    }

    /// Every manifest entry (`repro store ls`), with on-disk payload size
    /// and mtime (the GC's LRU clock).
    pub fn entries(&self) -> Result<Vec<StoreEntry>> {
        let Some(manifest) = self.read_manifest()? else { return Ok(Vec::new()) };
        let mut out = Vec::new();
        if let Some(map) = manifest.get("entries").and_then(Json::as_obj) {
            for (slug, e) in map {
                let path = self.entry_path(slug);
                let (bytes, modified) = match std::fs::metadata(&path) {
                    Ok(md) => {
                        (md.len(), md.modified().unwrap_or(SystemTime::UNIX_EPOCH))
                    }
                    Err(_) => (0, SystemTime::UNIX_EPOCH),
                };
                out.push(StoreEntry {
                    slug: slug.clone(),
                    hash: e
                        .get("hash")
                        .and_then(Json::as_str)
                        .and_then(parse_hash)
                        .unwrap_or(0),
                    len: e.get("len").and_then(Json::as_usize).unwrap_or(0),
                    path,
                    bytes,
                    modified,
                });
            }
        }
        Ok(out)
    }

    /// Total payload bytes across every manifest entry (`repro store ls`
    /// footer and the GC budget).
    pub fn total_bytes(&self) -> Result<u64> {
        Ok(self.entries()?.iter().map(|e| e.bytes).sum())
    }

    /// Size-capped eviction: while total payload bytes exceed `max_bytes`,
    /// evict the least-recently-written entry (LRU by payload mtime,
    /// slug-tiebroken for determinism) — payload deleted, manifest entry
    /// dropped, both under the write locks. `repro store gc --max-bytes N`
    /// drives this.
    pub fn gc(&self, max_bytes: u64) -> Result<GcReport> {
        let _guard = WRITE_LOCK.lock().expect("dataset store write lock poisoned");
        let empty =
            GcReport { evicted: Vec::new(), kept: 0, bytes_before: 0, bytes_after: 0 };
        if !self.dir.exists() {
            return Ok(empty);
        }
        let _lock = ManifestLock::acquire(&self.dir)?;
        let mut entries = self.entries()?;
        if entries.is_empty() {
            return Ok(empty);
        }
        entries.sort_by(|a, b| {
            a.modified.cmp(&b.modified).then_with(|| a.slug.cmp(&b.slug))
        });
        let bytes_before: u64 = entries.iter().map(|e| e.bytes).sum();
        let mut remaining = bytes_before;
        let mut evicted = Vec::new();
        for e in &entries {
            if remaining <= max_bytes {
                break;
            }
            match std::fs::remove_file(&e.path) {
                Ok(()) => {}
                Err(err) if err.kind() == std::io::ErrorKind::NotFound => {}
                Err(err) => return Err(err.into()),
            }
            remaining -= e.bytes;
            evicted.push(e.slug.clone());
        }
        if !evicted.is_empty() {
            let kept: BTreeMap<String, Json> = self
                .read_manifest()?
                .and_then(|m| m.get("entries").and_then(Json::as_obj).cloned())
                .unwrap_or_default()
                .into_iter()
                .filter(|(slug, _)| !evicted.contains(slug))
                .collect();
            self.write_manifest(kept)?;
        }
        Ok(GcReport {
            kept: entries.len() - evicted.len(),
            evicted,
            bytes_before,
            bytes_after: remaining,
        })
    }

    /// Delete the manifest and every store-owned file in the directory —
    /// a directory sweep, not a manifest walk, so payloads orphaned by a
    /// format-version bump, an unparseable manifest, or a crashed save's
    /// `.tmp` files are reclaimed too. Only filenames the store itself
    /// writes are touched (see [`is_store_file`]): pointing `store.dir`
    /// at a shared directory must never delete unrelated files. Returns
    /// how many dataset payloads were removed.
    pub fn clear(&self) -> Result<usize> {
        let _guard = WRITE_LOCK.lock().expect("dataset store write lock poisoned");
        let read_dir = match std::fs::read_dir(&self.dir) {
            Ok(rd) => rd,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(0),
            Err(e) => return Err(e.into()),
        };
        // `manifest.lock` is deliberately not a store file for the sweep
        // below: the guard we hold IS that file, and Drop releases it.
        let _lock = ManifestLock::acquire(&self.dir)?;
        let mut removed = 0usize;
        for entry in read_dir {
            let entry = entry?;
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if !is_store_file(&name) {
                continue;
            }
            std::fs::remove_file(entry.path())?;
            removed += (name.ends_with(".json") && name != "manifest.json") as usize;
        }
        Ok(removed)
    }

    /// Re-hash and re-parse every manifest entry (`repro store verify`).
    pub fn verify(&self) -> Result<Vec<(String, VerifyStatus)>> {
        let mut out = Vec::new();
        for e in self.entries()? {
            let status = match std::fs::read(&e.path) {
                Err(err) if err.kind() == std::io::ErrorKind::NotFound => {
                    VerifyStatus::MissingFile
                }
                Err(err) => VerifyStatus::Corrupt(err.to_string()),
                Ok(bytes) if fnv1a64(&bytes) != e.hash => VerifyStatus::HashMismatch,
                Ok(bytes) => {
                    let parsed = String::from_utf8(bytes)
                        .ok()
                        .and_then(|t| Json::parse(&t).ok())
                        .map(|v| Dataset::from_json(&v));
                    match parsed {
                        Some(Ok(_)) => VerifyStatus::Ok,
                        Some(Err(err)) => VerifyStatus::Corrupt(err.to_string()),
                        None => VerifyStatus::Corrupt("not valid JSON".into()),
                    }
                }
            };
            out.push((e.slug, status));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::charac::BehavMetrics;
    use crate::operator::{AxoConfig, Operator};
    use crate::synth::PpaMetrics;
    use crate::util::tempdir::TempDir;

    fn tiny_ds() -> Dataset {
        let cfgs = vec![AxoConfig::accurate(4), AxoConfig::new(0b0111, 4).unwrap()];
        let behav = vec![
            BehavMetrics::ZERO,
            BehavMetrics {
                avg_abs_err: 1.0,
                avg_abs_rel_err: 0.1,
                max_abs_err: 8.0,
                err_prob: 0.5,
            },
        ];
        let ppa = vec![
            PpaMetrics { luts: 4.0, cpd_ns: 0.75, power_mw: 0.8, pdp: 0.6, pdplut: 2.4 },
            PpaMetrics { luts: 3.0, cpd_ns: 0.7, power_mw: 0.7, pdp: 0.49, pdplut: 1.47 },
        ];
        Dataset::new(Operator::ADD4, cfgs, behav, ppa).unwrap()
    }

    fn key() -> DatasetKey {
        DatasetKey {
            op: Operator::ADD4,
            substrate: CharacSubstrate::Native,
            spec: SampleSpec::Seeded { seed: 7, n: 2 },
        }
    }

    #[test]
    fn slug_is_deterministic_and_distinct() {
        assert_eq!(key_slug(&key()), "add4-native-seeded-s7-n2");
        let ex = DatasetKey {
            op: Operator::MUL8,
            substrate: CharacSubstrate::Native,
            spec: SampleSpec::Exhaustive,
        };
        assert_eq!(key_slug(&ex), "mul8-native-exhaustive");
    }

    #[test]
    fn fnv_known_vectors() {
        // Standard FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
    }

    /// Fixed input fingerprint used by tests that don't vary the inputs.
    const FP: u64 = 0x1234_5678_9abc_def0;

    #[test]
    fn round_trip_and_ls() {
        let dir = TempDir::new().unwrap();
        let store = DatasetStore::open(dir.path().join("ds"));
        assert!(store.load(&key(), FP).unwrap().is_none());
        assert!(store.entries().unwrap().is_empty());
        store.save(&key(), &tiny_ds(), FP).unwrap();
        let loaded = store.load(&key(), FP).unwrap().expect("stored entry loads");
        assert_eq!(loaded.configs, tiny_ds().configs);
        assert_eq!(loaded.len(), 2);
        let ls = store.entries().unwrap();
        assert_eq!(ls.len(), 1);
        assert_eq!(ls[0].slug, "add4-native-seeded-s7-n2");
        assert_eq!(ls[0].len, 2);
        assert_eq!(
            store.verify().unwrap(),
            vec![("add4-native-seeded-s7-n2".into(), VerifyStatus::Ok)]
        );
        assert_eq!(store.clear().unwrap(), 1);
        assert!(store.load(&key(), FP).unwrap().is_none());
    }

    #[test]
    fn clear_never_touches_foreign_files_in_a_shared_dir() {
        // `store.dir` may point at a shared directory (even `artifacts/`
        // itself): clear must only remove store-owned filenames.
        let dir = TempDir::new().unwrap();
        let store = DatasetStore::open(dir.path().to_path_buf());
        store.save(&key(), &tiny_ds(), FP).unwrap();
        let foreign_json = dir.path().join("golden_behav.json");
        let foreign_txt = dir.path().join("notes.txt");
        std::fs::write(&foreign_json, "{}").unwrap();
        std::fs::write(&foreign_txt, "keep me").unwrap();
        assert!(is_store_file("add4-native-seeded-s7-n2.json"));
        assert!(is_store_file(".add4-native-seeded-s7-n2.tmp"));
        assert!(is_store_file("manifest.json"));
        assert!(!is_store_file("golden_behav.json"));
        assert!(!is_store_file("inputs_add12.bin"));
        assert_eq!(store.clear().unwrap(), 1);
        assert!(foreign_json.exists());
        assert!(foreign_txt.exists());
        assert!(!store.manifest_path().exists());
    }

    fn key_for(op: Operator, seed: u64) -> DatasetKey {
        DatasetKey {
            op,
            substrate: CharacSubstrate::Native,
            spec: SampleSpec::Seeded { seed, n: 2 },
        }
    }

    #[test]
    fn gc_evicts_lru_by_mtime_until_under_cap() {
        let dir = TempDir::new().unwrap();
        let store = DatasetStore::open(dir.path().join("ds"));
        // Three equally-sized entries, oldest payload first; the sleeps
        // order the mtimes the GC sorts by.
        for seed in [1u64, 2, 3] {
            store.save(&key_for(Operator::ADD4, seed), &tiny_ds(), FP).unwrap();
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
        let total = store.total_bytes().unwrap();
        assert!(total > 0);
        let per_entry = total / 3;
        assert_eq!(store.entries().unwrap().len(), 3);

        // Budget for two entries: exactly the oldest is evicted.
        let report = store.gc(total - 1).unwrap();
        assert_eq!(report.evicted, vec!["add4-native-seeded-s1-n2".to_string()]);
        assert_eq!(report.kept, 2);
        assert_eq!(report.bytes_before, total);
        assert_eq!(report.bytes_after, total - per_entry);
        assert_eq!(store.total_bytes().unwrap(), total - per_entry);
        let slugs: Vec<String> =
            store.entries().unwrap().into_iter().map(|e| e.slug).collect();
        assert_eq!(slugs, vec!["add4-native-seeded-s2-n2", "add4-native-seeded-s3-n2"]);
        assert!(store.load(&key_for(Operator::ADD4, 1), FP).unwrap().is_none());
        assert!(store.load(&key_for(Operator::ADD4, 3), FP).unwrap().is_some());

        // Zero budget sweeps everything; an idempotent re-run is a no-op.
        let report = store.gc(0).unwrap();
        assert_eq!(report.evicted.len(), 2);
        assert_eq!(report.kept, 0);
        assert_eq!(report.bytes_after, 0);
        assert!(store.entries().unwrap().is_empty());
        let report = store.gc(0).unwrap();
        assert!(report.evicted.is_empty());

        // A directory that never existed reports an empty sweep.
        let ghost = DatasetStore::open(dir.path().join("never-created"));
        assert!(ghost.gc(0).unwrap().evicted.is_empty());
    }

    #[test]
    fn gc_under_budget_keeps_everything() {
        let dir = TempDir::new().unwrap();
        let store = DatasetStore::open(dir.path().join("ds"));
        store.save(&key(), &tiny_ds(), FP).unwrap();
        let total = store.total_bytes().unwrap();
        let report = store.gc(total).unwrap();
        assert!(report.evicted.is_empty());
        assert_eq!(report.kept, 1);
        assert_eq!(report.bytes_after, total);
    }

    #[test]
    fn stale_pid_lock_is_taken_over_and_released() {
        let dir = TempDir::new().unwrap();
        let store = DatasetStore::open(dir.path().join("ds"));
        std::fs::create_dir_all(store.dir()).unwrap();
        let lock_path = store.dir().join("manifest.lock");
        // u32::MAX is never a live PID (Linux caps pids well below it).
        std::fs::write(&lock_path, format!("{}", u32::MAX)).unwrap();
        store.save(&key(), &tiny_ds(), FP).unwrap();
        assert!(
            !lock_path.exists(),
            "save must take over the stale lock and release it afterwards"
        );
        assert!(store.load(&key(), FP).unwrap().is_some());
        // The lock file is transient, never part of the store sweep.
        assert!(!is_store_file("manifest.lock"));
    }

    #[test]
    fn lock_file_is_held_during_writes_and_dropped_after() {
        let dir = TempDir::new().unwrap();
        std::fs::create_dir_all(dir.path()).unwrap();
        let lock = ManifestLock::acquire(dir.path()).unwrap();
        let lock_path = dir.path().join("manifest.lock");
        assert!(lock_path.exists());
        let recorded = std::fs::read_to_string(&lock_path).unwrap();
        assert_eq!(recorded.trim(), format!("{}", std::process::id()));
        // Our own live PID is not stale.
        assert!(!holder_is_stale(&lock_path));
        drop(lock);
        assert!(!lock_path.exists());
    }

    #[test]
    fn mismatched_input_fingerprint_is_a_miss() {
        let dir = TempDir::new().unwrap();
        let store = DatasetStore::open(dir.path().join("ds"));
        store.save(&key(), &tiny_ds(), FP).unwrap();
        assert!(store.load(&key(), FP).unwrap().is_some());
        // Same key, different input set: never served.
        assert!(store.load(&key(), FP ^ 1).unwrap().is_none());
    }

    #[test]
    fn inputs_fingerprint_tracks_content() {
        use crate::charac::InputSet;
        let a = InputSet { a: vec![1, 2, 3], b: vec![4, 5, 6] };
        let same = InputSet { a: vec![1, 2, 3], b: vec![4, 5, 6] };
        let diff = InputSet { a: vec![1, 2, 3], b: vec![4, 5, 7] };
        assert_eq!(inputs_fingerprint(&a), inputs_fingerprint(&same));
        assert_ne!(inputs_fingerprint(&a), inputs_fingerprint(&diff));
    }

    #[test]
    fn corrupted_payload_is_a_miss_not_an_error() {
        let dir = TempDir::new().unwrap();
        let store = DatasetStore::open(dir.path().join("ds"));
        store.save(&key(), &tiny_ds(), FP).unwrap();
        let entry = store.entries().unwrap().remove(0);
        std::fs::write(&entry.path, "garbage").unwrap();
        assert_eq!(
            store.verify().unwrap()[0].1,
            VerifyStatus::HashMismatch,
            "verify flags the tampered entry"
        );
        assert!(store.load(&key(), FP).unwrap().is_none(), "load falls back to a miss");
        // Re-saving heals the entry.
        store.save(&key(), &tiny_ds(), FP).unwrap();
        assert!(store.load(&key(), FP).unwrap().is_some());
    }

    #[test]
    fn missing_payload_and_stale_version_are_misses() {
        let dir = TempDir::new().unwrap();
        let store = DatasetStore::open(dir.path().join("ds"));
        store.save(&key(), &tiny_ds(), FP).unwrap();
        let entry = store.entries().unwrap().remove(0);
        std::fs::remove_file(&entry.path).unwrap();
        assert_eq!(store.verify().unwrap()[0].1, VerifyStatus::MissingFile);
        assert!(store.load(&key(), FP).unwrap().is_none());

        // A manifest from a different format version empties the store.
        store.save(&key(), &tiny_ds(), FP).unwrap();
        let manifest = store.manifest_path();
        let text = std::fs::read_to_string(&manifest).unwrap();
        assert!(text.contains("\"version\":1"), "compact manifest layout changed?");
        std::fs::write(&manifest, text.replace("\"version\":1", "\"version\":999"))
            .unwrap();
        assert!(store.load(&key(), FP).unwrap().is_none());
        assert!(store.entries().unwrap().is_empty());
        // ...but clear() sweeps the directory, so the now-orphaned payload
        // is still reclaimed rather than leaking forever.
        assert_eq!(store.clear().unwrap(), 1);
        assert!(!entry.path.exists());
        assert!(!store.manifest_path().exists());
    }
}
