//! Model-quality metrics: RMSE, R², Hamming accuracy (paper Fig. 13 /
//! §V-B estimator table).

/// Root mean squared error.
pub fn rmse(y_true: &[f64], y_pred: &[f64]) -> f64 {
    assert_eq!(y_true.len(), y_pred.len());
    if y_true.is_empty() {
        return 0.0;
    }
    let sse: f64 = y_true.iter().zip(y_pred).map(|(t, p)| (t - p) * (t - p)).sum();
    (sse / y_true.len() as f64).sqrt()
}

/// Coefficient of determination.
pub fn r2(y_true: &[f64], y_pred: &[f64]) -> f64 {
    assert_eq!(y_true.len(), y_pred.len());
    let n = y_true.len() as f64;
    if y_true.is_empty() {
        return 0.0;
    }
    let mean = y_true.iter().sum::<f64>() / n;
    let ss_tot: f64 = y_true.iter().map(|t| (t - mean) * (t - mean)).sum();
    let ss_res: f64 = y_true.iter().zip(y_pred).map(|(t, p)| (t - p) * (t - p)).sum();
    if ss_tot <= 0.0 {
        if ss_res <= 0.0 {
            1.0
        } else {
            0.0
        }
    } else {
        1.0 - ss_res / ss_tot
    }
}

/// Mean per-bit accuracy between two bit matrices (Fig. 13's metric:
/// `1 - hamming_distance / n_bits`, averaged over rows).
pub fn hamming_accuracy(y_true: &[u8], y_pred: &[u8]) -> f64 {
    assert_eq!(y_true.len(), y_pred.len());
    if y_true.is_empty() {
        return 1.0;
    }
    let correct = y_true.iter().zip(y_pred).filter(|(a, b)| a == b).count();
    correct as f64 / y_true.len() as f64
}

/// Fraction of rows predicted exactly (all bits correct).
pub fn exact_match_rate(y_true: &[u8], y_pred: &[u8], row_len: usize) -> f64 {
    assert_eq!(y_true.len(), y_pred.len());
    assert!(row_len > 0 && y_true.len() % row_len == 0);
    let rows = y_true.len() / row_len;
    if rows == 0 {
        return 1.0;
    }
    let mut ok = 0;
    for r in 0..rows {
        if y_true[r * row_len..(r + 1) * row_len] == y_pred[r * row_len..(r + 1) * row_len] {
            ok += 1;
        }
    }
    ok as f64 / rows as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmse_known() {
        assert_eq!(rmse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((rmse(&[0.0, 0.0], &[3.0, 4.0]) - (12.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn r2_perfect_and_mean() {
        let y = [1.0, 2.0, 3.0];
        assert_eq!(r2(&y, &y), 1.0);
        assert_eq!(r2(&y, &[2.0, 2.0, 2.0]), 0.0);
        assert!(r2(&y, &[3.0, 1.0, 2.0]) < 0.0); // worse than mean
    }

    #[test]
    fn hamming_and_exact_match() {
        let t = [1u8, 0, 1, 1, 0, 0];
        let p = [1u8, 0, 0, 1, 0, 0];
        assert!((hamming_accuracy(&t, &p) - 5.0 / 6.0).abs() < 1e-12);
        assert_eq!(exact_match_rate(&t, &p, 3), 0.5);
        assert_eq!(exact_match_rate(&t, &t, 3), 1.0);
    }
}
