//! Multi-output regression CART.
//!
//! Split criterion is total variance reduction summed over outputs, found
//! by a sorted prefix-sum scan per candidate feature. For 0/1 targets this
//! ranks splits identically to Gini impurity (`var = p(1-p)` =
//! `gini / 2`), so the tree doubles as the classification CART the paper's
//! RandomForest uses. Feature subsampling per split (`max_features`)
//! provides the randomness the forest needs beyond bagging.

use crate::util::rng::Rng;

/// Hyper-parameters for one tree.
#[derive(Debug, Clone)]
pub struct TreeParams {
    pub max_depth: u32,
    pub min_samples_leaf: usize,
    /// Number of features considered per split (None = all).
    pub max_features: Option<usize>,
}

impl Default for TreeParams {
    fn default() -> Self {
        TreeParams { max_depth: 12, min_samples_leaf: 2, max_features: None }
    }
}

#[derive(Debug, Clone)]
enum Node {
    Leaf { value: Vec<f64> },
    Split { feature: usize, threshold: f64, left: usize, right: usize },
}

/// A fitted multi-output regression tree.
#[derive(Debug, Clone)]
pub struct DecisionTree {
    nodes: Vec<Node>,
    pub n_features: usize,
    pub n_outputs: usize,
}

struct FitCtx<'a> {
    x: &'a [f64],
    y: &'a [f64],
    nf: usize,
    no: usize,
    params: &'a TreeParams,
}

impl DecisionTree {
    /// Fit on row-major `x` (n × n_features) and `y` (n × n_outputs),
    /// restricted to `sample` row indices (bootstrap support).
    pub fn fit(
        x: &[f64],
        n_features: usize,
        y: &[f64],
        n_outputs: usize,
        sample: &[usize],
        params: &TreeParams,
        rng: &mut Rng,
    ) -> DecisionTree {
        assert!(n_features > 0 && n_outputs > 0);
        assert_eq!(x.len() % n_features, 0);
        assert_eq!(y.len() % n_outputs, 0);
        assert!(!sample.is_empty());
        let ctx = FitCtx { x, y, nf: n_features, no: n_outputs, params };
        let mut tree =
            DecisionTree { nodes: Vec::new(), n_features, n_outputs };
        let mut idx = sample.to_vec();
        tree.build(&ctx, &mut idx, 0, rng);
        tree
    }

    fn leaf_value(ctx: &FitCtx, idx: &[usize]) -> Vec<f64> {
        let mut v = vec![0.0; ctx.no];
        for &i in idx {
            for k in 0..ctx.no {
                v[k] += ctx.y[i * ctx.no + k];
            }
        }
        let n = idx.len() as f64;
        v.iter_mut().for_each(|a| *a /= n);
        v
    }

    fn build(
        &mut self,
        ctx: &FitCtx,
        idx: &mut [usize],
        depth: u32,
        rng: &mut Rng,
    ) -> usize {
        let node_id = self.nodes.len();
        self.nodes.push(Node::Leaf { value: Vec::new() }); // placeholder

        let stop = depth >= ctx.params.max_depth
            || idx.len() < 2 * ctx.params.min_samples_leaf;
        let split = if stop { None } else { Self::best_split(ctx, idx, rng) };

        match split {
            None => {
                self.nodes[node_id] = Node::Leaf { value: Self::leaf_value(ctx, idx) };
            }
            Some((feature, threshold)) => {
                // Partition in place.
                let mut lo = 0;
                let mut hi = idx.len();
                while lo < hi {
                    if ctx.x[idx[lo] * ctx.nf + feature] <= threshold {
                        lo += 1;
                    } else {
                        hi -= 1;
                        idx.swap(lo, hi);
                    }
                }
                if lo == 0 || lo == idx.len() {
                    self.nodes[node_id] =
                        Node::Leaf { value: Self::leaf_value(ctx, idx) };
                    return node_id;
                }
                let (li, ri) = idx.split_at_mut(lo);
                let left = self.build(ctx, li, depth + 1, rng);
                let right = self.build(ctx, ri, depth + 1, rng);
                self.nodes[node_id] = Node::Split { feature, threshold, left, right };
            }
        }
        node_id
    }

    /// Best (feature, threshold) by total variance reduction, or None when
    /// no split improves.
    fn best_split(
        ctx: &FitCtx,
        idx: &[usize],
        rng: &mut Rng,
    ) -> Option<(usize, f64)> {
        let mut features: Vec<usize> = (0..ctx.nf).collect();
        if let Some(mf) = ctx.params.max_features {
            rng.shuffle(&mut features);
            features.truncate(mf.max(1));
        }

        let n = idx.len() as f64;
        // Parent sum of squared deviations = sum(y²) - n·mean² per output.
        let mut tot_sum = vec![0.0; ctx.no];
        let mut tot_sq = vec![0.0; ctx.no];
        for &i in idx {
            for k in 0..ctx.no {
                let v = ctx.y[i * ctx.no + k];
                tot_sum[k] += v;
                tot_sq[k] += v * v;
            }
        }
        let parent_sse: f64 = (0..ctx.no)
            .map(|k| tot_sq[k] - tot_sum[k] * tot_sum[k] / n)
            .sum();
        if parent_sse <= 1e-12 {
            return None; // pure node
        }

        let min_leaf = ctx.params.min_samples_leaf;
        let mut best: Option<(usize, f64, f64)> = None; // (feat, thr, gain)

        let mut order: Vec<usize> = idx.to_vec();
        for &f in &features {
            order.sort_by(|&a, &b| {
                ctx.x[a * ctx.nf + f]
                    .partial_cmp(&ctx.x[b * ctx.nf + f])
                    .unwrap()
            });
            let mut left_sum = vec![0.0; ctx.no];
            let mut left_sq = vec![0.0; ctx.no];
            for (pos, &i) in order.iter().enumerate().take(order.len() - 1) {
                for k in 0..ctx.no {
                    let v = ctx.y[i * ctx.no + k];
                    left_sum[k] += v;
                    left_sq[k] += v * v;
                }
                let xl = ctx.x[i * ctx.nf + f];
                let xr = ctx.x[order[pos + 1] * ctx.nf + f];
                if xl == xr {
                    continue; // no boundary between equal values
                }
                let nl = (pos + 1) as f64;
                let nr = n - nl;
                if (pos + 1) < min_leaf || (order.len() - pos - 1) < min_leaf {
                    continue;
                }
                let mut child_sse = 0.0;
                for k in 0..ctx.no {
                    let rs = tot_sum[k] - left_sum[k];
                    let rq = tot_sq[k] - left_sq[k];
                    child_sse += left_sq[k] - left_sum[k] * left_sum[k] / nl;
                    child_sse += rq - rs * rs / nr;
                }
                // Impure nodes may split even at zero gain (XOR-style
                // targets need a pass-through split before any gain shows;
                // scikit's CART behaves the same way).
                let gain = parent_sse - child_sse;
                if gain > -1e-12 && best.map_or(true, |(_, _, g)| gain > g) {
                    best = Some((f, (xl + xr) / 2.0, gain));
                }
            }
        }
        best.map(|(f, t, _)| (f, t))
    }

    /// Predict one row-major feature row.
    pub fn predict_row(&self, row: &[f64]) -> &[f64] {
        debug_assert_eq!(row.len(), self.n_features);
        let mut id = 0;
        loop {
            match &self.nodes[id] {
                Node::Leaf { value } => return value,
                Node::Split { feature, threshold, left, right } => {
                    id = if row[*feature] <= *threshold { *left } else { *right };
                }
            }
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn depth(&self) -> u32 {
        fn rec(nodes: &[Node], id: usize) -> u32 {
            match &nodes[id] {
                Node::Leaf { .. } => 0,
                Node::Split { left, right, .. } => {
                    1 + rec(nodes, *left).max(rec(nodes, *right))
                }
            }
        }
        rec(&self.nodes, 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fit_simple(x: &[f64], nf: usize, y: &[f64], no: usize, p: &TreeParams) -> DecisionTree {
        let sample: Vec<usize> = (0..x.len() / nf).collect();
        let mut rng = Rng::seed_from_u64(0);
        DecisionTree::fit(x, nf, y, no, &sample, p, &mut rng)
    }

    #[test]
    fn learns_single_feature_step() {
        // y = [x > 0.5]
        let x: Vec<f64> = (0..20).map(|i| i as f64 / 19.0).collect();
        let y: Vec<f64> = x.iter().map(|&v| (v > 0.5) as u8 as f64).collect();
        let t = fit_simple(&x, 1, &y, 1, &TreeParams::default());
        for (xi, yi) in x.iter().zip(&y) {
            assert_eq!(t.predict_row(&[*xi])[0], *yi);
        }
    }

    #[test]
    fn learns_xor_with_depth2() {
        let x = vec![0.0, 0.0, 0.0, 1.0, 1.0, 0.0, 1.0, 1.0];
        let y = vec![0.0, 1.0, 1.0, 0.0];
        let p = TreeParams { max_depth: 3, min_samples_leaf: 1, max_features: None };
        let t = fit_simple(&x, 2, &y, 1, &p);
        for i in 0..4 {
            let row = &x[2 * i..2 * i + 2];
            assert_eq!(t.predict_row(row)[0], y[i], "row {row:?}");
        }
        assert!(t.depth() >= 2);
    }

    #[test]
    fn multi_output_leaf_means() {
        // One constant feature -> single leaf = column means.
        let x = vec![1.0, 1.0, 1.0];
        let y = vec![0.0, 2.0, 1.0, 4.0, 2.0, 6.0];
        let t = fit_simple(&x, 1, &y, 2, &TreeParams::default());
        assert_eq!(t.predict_row(&[1.0]), &[1.0, 4.0]);
        assert_eq!(t.n_nodes(), 1);
    }

    #[test]
    fn respects_max_depth() {
        let x: Vec<f64> = (0..64).map(|i| i as f64).collect();
        let y: Vec<f64> = (0..64).map(|i| (i % 2) as f64).collect();
        let p = TreeParams { max_depth: 2, min_samples_leaf: 1, max_features: None };
        let t = fit_simple(&x, 1, &y, 1, &p);
        assert!(t.depth() <= 2);
    }

    #[test]
    fn min_samples_leaf_respected() {
        let x: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let y: Vec<f64> = (0..10).map(|i| (i >= 1) as u8 as f64).collect();
        // min leaf 3 forbids the pure split at 0|1..9.
        let p = TreeParams { max_depth: 8, min_samples_leaf: 3, max_features: None };
        let t = fit_simple(&x, 1, &y, 1, &p);
        // first split must leave >= 3 on the left.
        let pred0 = t.predict_row(&[0.0])[0];
        assert!(pred0 > 0.0, "leaf mixes labels under min_samples_leaf");
    }

    #[test]
    fn deterministic_given_seed_with_feature_subsampling() {
        let x: Vec<f64> = (0..200).map(|i| ((i * 37) % 19) as f64).collect();
        let y: Vec<f64> = (0..100).map(|i| ((i * 13) % 7) as f64).collect();
        let p = TreeParams { max_depth: 6, min_samples_leaf: 1, max_features: Some(1) };
        let sample: Vec<usize> = (0..100).collect();
        let t1 = DecisionTree::fit(&x, 2, &y, 1, &sample, &p, &mut Rng::seed_from_u64(9));
        let t2 = DecisionTree::fit(&x, 2, &y, 1, &sample, &p, &mut Rng::seed_from_u64(9));
        assert_eq!(t1.n_nodes(), t2.n_nodes());
        for i in 0..100 {
            let row = &x[2 * i..2 * i + 2];
            assert_eq!(t1.predict_row(row), t2.predict_row(row));
        }
    }
}
