//! Random forest — the ConSS supersampling model (paper Fig. 13).
//!
//! Bagged multi-output CART ensemble with per-split feature subsampling
//! (`sqrt(n_features)` by default, scikit's classifier default). The
//! forest predicts all H-configuration bits jointly; classification output
//! thresholds the averaged leaf means at 0.5 — for 0/1 targets this is
//! exactly majority voting over per-tree probability estimates.

use super::tree::{DecisionTree, TreeParams};
use crate::error::{Error, Result};
use crate::util::par::parallel_map;
use crate::util::rng::Rng;

/// Random forest hyper-parameters.
#[derive(Debug, Clone)]
pub struct ForestParams {
    pub n_trees: usize,
    pub tree: TreeParams,
    /// Bootstrap sample fraction (1.0 = classic bagging with replacement).
    pub bootstrap_fraction: f64,
    pub seed: u64,
}

impl Default for ForestParams {
    fn default() -> Self {
        ForestParams {
            n_trees: 25,
            tree: TreeParams { max_depth: 14, min_samples_leaf: 2, max_features: None },
            bootstrap_fraction: 1.0,
            seed: 2023,
        }
    }
}

/// A fitted random forest.
#[derive(Debug, Clone)]
pub struct RandomForest {
    trees: Vec<DecisionTree>,
    pub n_features: usize,
    pub n_outputs: usize,
    pub params: ForestParams,
}

impl RandomForest {
    /// Fit on row-major `x` (n × n_features) / `y` (n × n_outputs).
    ///
    /// `max_features` defaults to `ceil(sqrt(n_features))` when unset.
    pub fn fit(
        x: &[f64],
        n_features: usize,
        y: &[f64],
        n_outputs: usize,
        mut params: ForestParams,
    ) -> Result<RandomForest> {
        if n_features == 0 || x.len() % n_features != 0 {
            return Err(Error::Ml(format!("bad x shape: len {} nf {n_features}", x.len())));
        }
        let n = x.len() / n_features;
        if n == 0 || y.len() != n * n_outputs {
            return Err(Error::Ml(format!(
                "bad y shape: len {} expected {}",
                y.len(),
                n * n_outputs
            )));
        }
        if params.tree.max_features.is_none() {
            params.tree.max_features =
                Some((n_features as f64).sqrt().ceil() as usize);
        }
        let boot = ((n as f64) * params.bootstrap_fraction).ceil().max(1.0) as usize;
        let seeds: Vec<u64> = (0..params.n_trees)
            .map(|t| params.seed.wrapping_add(t as u64 * 0x9E37_79B9))
            .collect();
        let tp = params.tree.clone();
        let trees: Vec<DecisionTree> = parallel_map(&seeds, |_, &s| {
            let mut rng = Rng::seed_from_u64(s);
            let sample: Vec<usize> =
                (0..boot).map(|_| rng.gen_index(n)).collect();
            DecisionTree::fit(x, n_features, y, n_outputs, &sample, &tp, &mut rng)
        });
        Ok(RandomForest { trees, n_features, n_outputs, params })
    }

    /// Averaged leaf means (per-output probabilities for 0/1 targets).
    pub fn predict_proba_row(&self, row: &[f64]) -> Vec<f64> {
        let mut acc = vec![0.0; self.n_outputs];
        for t in &self.trees {
            for (a, v) in acc.iter_mut().zip(t.predict_row(row)) {
                *a += v;
            }
        }
        let nt = self.trees.len() as f64;
        acc.iter_mut().for_each(|a| *a /= nt);
        acc
    }

    /// Hard 0/1 predictions (threshold 0.5 == majority vote).
    pub fn predict_bits_row(&self, row: &[f64]) -> Vec<u8> {
        self.predict_proba_row(row).iter().map(|&p| (p >= 0.5) as u8).collect()
    }

    /// Batch prediction over row-major features.
    pub fn predict_proba(&self, x: &[f64]) -> Vec<f64> {
        let rows: Vec<&[f64]> = x.chunks_exact(self.n_features).collect();
        parallel_map(&rows, |_, row| self.predict_proba_row(row))
            .into_iter()
            .flatten()
            .collect()
    }

    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// y = identity mapping of 4 input bits to 4 output bits + 2 constant.
    fn bit_dataset(n: usize) -> (Vec<f64>, Vec<f64>) {
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..n {
            let bits: Vec<f64> = (0..4).map(|k| ((i >> k) & 1) as f64).collect();
            x.extend(&bits);
            y.extend(&bits);
            y.push(1.0);
            y.push(0.0);
        }
        (x, y)
    }

    #[test]
    fn learns_bit_identity() {
        let (x, y) = bit_dataset(64);
        let f = RandomForest::fit(&x, 4, &y, 6, ForestParams::default()).unwrap();
        for i in 0..16 {
            let row: Vec<f64> = (0..4).map(|k| ((i >> k) & 1) as f64).collect();
            let bits = f.predict_bits_row(&row);
            let want: Vec<u8> = (0..4)
                .map(|k| ((i >> k) & 1) as u8)
                .chain([1, 0])
                .collect();
            assert_eq!(bits, want, "input {i}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let (x, y) = bit_dataset(64);
        let f1 = RandomForest::fit(&x, 4, &y, 6, ForestParams::default()).unwrap();
        let f2 = RandomForest::fit(&x, 4, &y, 6, ForestParams::default()).unwrap();
        for i in 0..16 {
            let row: Vec<f64> = (0..4).map(|k| ((i >> k) & 1) as f64).collect();
            assert_eq!(f1.predict_proba_row(&row), f2.predict_proba_row(&row));
        }
    }

    #[test]
    fn probabilities_bounded() {
        let (x, y) = bit_dataset(32);
        let f = RandomForest::fit(&x, 4, &y, 6, ForestParams::default()).unwrap();
        let p = f.predict_proba(&x);
        assert_eq!(p.len(), 32 * 6);
        assert!(p.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn rejects_bad_shapes() {
        assert!(RandomForest::fit(&[1.0, 2.0], 3, &[1.0], 1, ForestParams::default()).is_err());
        assert!(RandomForest::fit(&[1.0, 2.0], 2, &[1.0], 2, ForestParams::default()).is_err());
    }
}
