//! Gradient-boosted regression trees — the CatBoost/LightGBM substitute.
//!
//! Squared-loss boosting: each stage fits a shallow CART to the current
//! residuals and is added with shrinkage. Used by the surrogate estimator
//! to predict scaled PDPLUT / AVG_ABS_REL_ERR from 0/1 configuration bits
//! (paper §V-B: tree ensembles win on categorical features; products like
//! PDP/PDPLUT regress worse than raw metrics — reproduced in the §V-B
//! harness).

use super::tree::{DecisionTree, TreeParams};
use crate::error::{Error, Result};
use crate::util::rng::Rng;

/// GBT hyper-parameters.
#[derive(Debug, Clone)]
pub struct GbtParams {
    pub n_stages: usize,
    pub learning_rate: f64,
    pub tree: TreeParams,
    pub seed: u64,
}

impl Default for GbtParams {
    fn default() -> Self {
        GbtParams {
            n_stages: 120,
            learning_rate: 0.15,
            tree: TreeParams { max_depth: 4, min_samples_leaf: 4, max_features: None },
            seed: 2023,
        }
    }
}

/// A fitted single-output GBT regressor.
#[derive(Debug, Clone)]
pub struct GradientBoostedTrees {
    base: f64,
    stages: Vec<DecisionTree>,
    pub params: GbtParams,
    pub n_features: usize,
    /// Training RMSE per stage (the §V-B convergence trace).
    pub train_rmse: Vec<f64>,
}

impl GradientBoostedTrees {
    /// Fit on row-major `x` (n × n_features) and targets `y` (n).
    pub fn fit(
        x: &[f64],
        n_features: usize,
        y: &[f64],
        params: GbtParams,
    ) -> Result<GradientBoostedTrees> {
        if n_features == 0 || x.len() % n_features != 0 {
            return Err(Error::Ml(format!("bad x shape: len {}", x.len())));
        }
        let n = x.len() / n_features;
        if n == 0 || y.len() != n {
            return Err(Error::Ml(format!("bad y len {} (n = {n})", y.len())));
        }
        let base = y.iter().sum::<f64>() / n as f64;
        let mut pred = vec![base; n];
        let mut stages = Vec::with_capacity(params.n_stages);
        let mut train_rmse = Vec::with_capacity(params.n_stages);
        let sample: Vec<usize> = (0..n).collect();
        let mut rng = Rng::seed_from_u64(params.seed);
        for _ in 0..params.n_stages {
            let resid: Vec<f64> = y.iter().zip(&pred).map(|(t, p)| t - p).collect();
            let tree =
                DecisionTree::fit(x, n_features, &resid, 1, &sample, &params.tree, &mut rng);
            for (i, p) in pred.iter_mut().enumerate() {
                *p += params.learning_rate * tree.predict_row(&x[i * n_features..(i + 1) * n_features])[0];
            }
            let rmse = (y
                .iter()
                .zip(&pred)
                .map(|(t, p)| (t - p) * (t - p))
                .sum::<f64>()
                / n as f64)
                .sqrt();
            stages.push(tree);
            train_rmse.push(rmse);
        }
        Ok(GradientBoostedTrees { base, stages, params, n_features, train_rmse })
    }

    pub fn predict_row(&self, row: &[f64]) -> f64 {
        let mut v = self.base;
        for t in &self.stages {
            v += self.params.learning_rate * t.predict_row(row)[0];
        }
        v
    }

    pub fn predict(&self, x: &[f64]) -> Vec<f64> {
        x.chunks_exact(self.n_features).map(|r| self.predict_row(r)).collect()
    }

    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fits_linear_function_of_bits() {
        // y = 3·b0 + 2·b1 - b2 over all 3-bit inputs, replicated.
        let mut x = Vec::new();
        let mut y = Vec::new();
        for rep in 0..8 {
            for i in 0..8 {
                let bits: Vec<f64> = (0..3).map(|k| ((i >> k) & 1) as f64).collect();
                x.extend(&bits);
                y.push(3.0 * bits[0] + 2.0 * bits[1] - bits[2] + (rep as f64) * 0.0);
            }
        }
        let g = GradientBoostedTrees::fit(&x, 3, &y, GbtParams::default()).unwrap();
        for i in 0..8 {
            let bits: Vec<f64> = (0..3).map(|k| ((i >> k) & 1) as f64).collect();
            let want = 3.0 * bits[0] + 2.0 * bits[1] - bits[2];
            assert!((g.predict_row(&bits) - want).abs() < 0.05);
        }
    }

    #[test]
    fn train_rmse_decreases() {
        let x: Vec<f64> = (0..128).map(|i| (i % 17) as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| v * v).collect();
        let g = GradientBoostedTrees::fit(&x, 1, &y, GbtParams::default()).unwrap();
        assert!(g.train_rmse.last().unwrap() < &g.train_rmse[0]);
    }

    #[test]
    fn constant_target_predicts_base() {
        let x: Vec<f64> = (0..32).map(|i| i as f64).collect();
        let y = vec![5.0; 32];
        let g = GradientBoostedTrees::fit(&x, 1, &y, GbtParams::default()).unwrap();
        assert!((g.predict_row(&[100.0]) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn rejects_bad_shapes() {
        assert!(GradientBoostedTrees::fit(&[1.0], 2, &[1.0], GbtParams::default()).is_err());
        assert!(GradientBoostedTrees::fit(&[1.0, 2.0], 1, &[1.0], GbtParams::default()).is_err());
    }
}
