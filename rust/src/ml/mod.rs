//! Native ML substrate: CART trees, random forests, gradient boosting.
//!
//! The paper uses scikit's RandomForest (multi-output classification) for
//! ConSS and AutoML-selected CatBoost/LightGBM regressors for PPA/BEHAV
//! estimation (§V-B). Both roles are implemented natively here so the
//! entire request path stays in rust:
//!
//! * [`tree`] — multi-output regression CART. For 0/1 targets, variance
//!   reduction ranks splits identically to Gini impurity, so the same tree
//!   serves classification (threshold at 0.5) and regression.
//! * [`forest`] — bagged ensemble with per-split feature subsampling;
//!   multi-output (predicts all 36 H-configuration bits jointly).
//! * [`gbt`] — gradient-boosted regression trees (squared loss), the
//!   CatBoost/LightGBM substitute for metric estimation.
//! * [`metrics`] — RMSE, R², Hamming accuracy, exact-match rate.
//!
//! The MLP alternatives (AOT-compiled Pallas forwards executed via PJRT)
//! live behind [`crate::surrogate`]; §V-B's model-quality table compares
//! both backends.

pub mod forest;
pub mod gbt;
pub mod metrics;
pub mod tree;

pub use forest::RandomForest;
pub use gbt::GradientBoostedTrees;
pub use tree::{DecisionTree, TreeParams};
