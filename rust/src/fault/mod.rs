//! Deterministic failpoint subsystem for crash-consistency testing.
//!
//! Every durability-critical write path in the crate passes through a
//! *named injection site* (`queue.complete.rename`, `store.payload.write`,
//! …). In production the sites are inert: the entire cost is one relaxed
//! atomic load ([`faults_enabled`], same pattern as the `REPRO_TRACE`
//! gate). A torture harness arms sites via the `REPRO_FAULTS` environment
//! variable (or `[fault] spec` in the experiment TOML) to deterministically
//! reproduce any crash interleaving:
//!
//! ```text
//! REPRO_FAULTS=site=action[:count],site=action,...
//! ```
//!
//! Actions:
//!
//! * `err` — the operation fails with an injected [`std::io::Error`].
//! * `enospc` — the operation fails with `ENOSPC` (disk full), so the
//!   load-shedding path can be exercised without filling a disk.
//! * `partial` — a *torn write*: half the bytes land, fsync is skipped,
//!   and the call reports success — the power-loss model.
//! * `abort` — the process dies on the spot (`std::process::abort`),
//!   simulating a `kill -9` at exactly this site.
//! * `delay:ms` — sleep before proceeding (widens race windows).
//!
//! An optional `:count` suffix (for `delay`: `delay:ms:count`) limits how
//! many times the site fires; afterwards it passes through normally but
//! keeps counting hits. Hit counters for all armed sites are exported via
//! [`hits`] and surface in `/metrics` as `fault_hits_total{site=...}`.
//!
//! The entry points mirror the write shapes they guard:
//!
//! * [`point`] — a marker between two operations (after a rename, before
//!   cleanup); fails/aborts/delays but never writes.
//! * [`write_file`] — guarded `std::fs::write`.
//! * [`write_file_durable`] — guarded write **plus `sync_all`** — the
//!   fsync-before-rename half of a crash-safe temp+rename pair.
//! * [`write_quota`] — for streaming writers that need to know how many
//!   bytes to emit (the event log): returns the allowed byte count.

use crate::error::{Error, Result};
use crate::expcfg::FaultConfig;
use std::collections::BTreeMap;
use std::fs::File;
use std::io::{self, Write};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

/// Environment variable holding the failpoint spec (outranks the TOML).
pub const ENV_SPEC: &str = "REPRO_FAULTS";

static FAULTS_ON: AtomicBool = AtomicBool::new(false);
static SITES: Mutex<BTreeMap<String, SiteState>> = Mutex::new(BTreeMap::new());

/// The failpoint gate — one relaxed atomic load, the entire cost of every
/// injection site while no fault is armed.
#[inline]
pub fn faults_enabled() -> bool {
    FAULTS_ON.load(Ordering::Relaxed)
}

/// What an armed site does when hit.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Action {
    /// Fail with an injected I/O error.
    Err,
    /// Fail with `ENOSPC` (raw OS error 28).
    Enospc,
    /// Torn write: truncate the payload, skip fsync, report success.
    Partial,
    /// Kill the process at this site (`std::process::abort`).
    Abort,
    /// Sleep this many milliseconds, then proceed normally.
    Delay(u64),
}

#[derive(Debug)]
struct SiteState {
    action: Action,
    /// `None` = unlimited; `Some(0)` = exhausted (site passes through but
    /// keeps counting hits so the metrics stay visible).
    remaining: Option<u64>,
    hits: u64,
}

/// Parse a spec string into `(site, action, count)` triples.
fn parse_spec(spec: &str) -> Result<Vec<(String, Action, Option<u64>)>> {
    let mut out = Vec::new();
    for entry in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let (site, rest) = entry.split_once('=').ok_or_else(|| {
            Error::Config(format!("fault spec `{entry}`: expected site=action[:count]"))
        })?;
        let site = site.trim();
        if site.is_empty() {
            return Err(Error::Config(format!("fault spec `{entry}`: empty site name")));
        }
        let parts: Vec<&str> = rest.split(':').collect();
        let count_at = |idx: usize| -> Result<Option<u64>> {
            match parts.get(idx) {
                None => Ok(None),
                Some(s) => s.parse::<u64>().map(Some).map_err(|_| {
                    Error::Config(format!("fault spec `{entry}`: bad count `{s}`"))
                }),
            }
        };
        let (action, count) = match parts[0] {
            "err" => (Action::Err, count_at(1)?),
            "enospc" => (Action::Enospc, count_at(1)?),
            "partial" => (Action::Partial, count_at(1)?),
            "abort" => (Action::Abort, count_at(1)?),
            "delay" => {
                let ms = parts
                    .get(1)
                    .and_then(|s| s.parse::<u64>().ok())
                    .ok_or_else(|| {
                        Error::Config(format!("fault spec `{entry}`: delay needs `delay:ms`"))
                    })?;
                (Action::Delay(ms), count_at(2)?)
            }
            other => {
                return Err(Error::Config(format!(
                    "fault spec `{entry}`: unknown action `{other}` \
                     (err|enospc|partial|abort|delay:ms)"
                )))
            }
        };
        if parts.len() > if matches!(action, Action::Delay(_)) { 3 } else { 2 } {
            return Err(Error::Config(format!("fault spec `{entry}`: trailing garbage")));
        }
        out.push((site.to_string(), action, count));
    }
    Ok(out)
}

/// Check a spec string for grammar errors without arming anything
/// (config validation).
pub fn validate_spec(spec: &str) -> Result<()> {
    parse_spec(spec).map(|_| ())
}

/// Arm the sites named in `spec`, replacing whatever was armed before.
/// An empty spec disarms everything.
pub fn arm_from_spec(spec: &str) -> Result<()> {
    let parsed = parse_spec(spec)?;
    let mut sites = SITES.lock().unwrap();
    sites.clear();
    for (site, action, count) in parsed {
        sites.insert(site, SiteState { action, remaining: count, hits: 0 });
    }
    FAULTS_ON.store(!sites.is_empty(), Ordering::Relaxed);
    Ok(())
}

/// Disarm every site and clear hit counters.
pub fn disarm_all() {
    SITES.lock().unwrap().clear();
    FAULTS_ON.store(false, Ordering::Relaxed);
}

/// Resolve the failpoint configuration: `REPRO_FAULTS` env (if set, even
/// to the empty string) over `[fault] spec`. Called from config load.
pub fn apply(cfg: &FaultConfig) -> Result<()> {
    match std::env::var(ENV_SPEC) {
        Ok(env_spec) => arm_from_spec(&env_spec),
        Err(_) => arm_from_spec(&cfg.spec),
    }
}

/// Arm from `REPRO_FAULTS` alone (torture workers, `loadgen` — processes
/// that never load an experiment TOML). No-op when the variable is unset.
pub fn apply_env() -> Result<()> {
    if let Ok(spec) = std::env::var(ENV_SPEC) {
        arm_from_spec(&spec)?;
    }
    Ok(())
}

/// Hit counters for every armed site (site name → times hit), in
/// deterministic (sorted) order. Sites stay listed after their count is
/// exhausted so scrapes see the final tallies.
pub fn hits() -> Vec<(String, u64)> {
    SITES
        .lock()
        .unwrap()
        .iter()
        .map(|(site, st)| (site.clone(), st.hits))
        .collect()
}

/// Consume one firing of `site`: bump the hit counter and return the
/// action to perform, or `None` when the site is unarmed/exhausted.
fn fire(site: &str) -> Option<Action> {
    let mut sites = SITES.lock().unwrap();
    let st = sites.get_mut(site)?;
    st.hits += 1;
    match &mut st.remaining {
        Some(0) => return None,
        Some(n) => *n -= 1,
        None => {}
    }
    Some(st.action.clone())
}

fn injected_err(site: &str) -> io::Error {
    io::Error::other(format!("fault injected at {site}"))
}

fn enospc_err() -> io::Error {
    io::Error::from_raw_os_error(28)
}

fn do_abort(site: &str) -> ! {
    eprintln!("fault: aborting process at site {site}");
    std::process::abort()
}

/// A pure marker site (between a rename and its cleanup, before a lock
/// takeover). `partial` is meaningless here and passes through.
#[inline]
pub fn point(site: &str) -> io::Result<()> {
    if !faults_enabled() {
        return Ok(());
    }
    match fire(site) {
        None | Some(Action::Partial) => Ok(()),
        Some(Action::Err) => Err(injected_err(site)),
        Some(Action::Enospc) => Err(enospc_err()),
        Some(Action::Abort) => do_abort(site),
        Some(Action::Delay(ms)) => {
            std::thread::sleep(std::time::Duration::from_millis(ms));
            Ok(())
        }
    }
}

/// How many of `len` bytes a streaming writer may emit through `site`.
/// Normal operation returns `len`; `partial` halves it (the torn-tail
/// model for append-only logs).
#[inline]
pub fn write_quota(site: &str, len: usize) -> io::Result<usize> {
    if !faults_enabled() {
        return Ok(len);
    }
    match fire(site) {
        None => Ok(len),
        Some(Action::Partial) => Ok(len / 2),
        Some(Action::Err) => Err(injected_err(site)),
        Some(Action::Enospc) => Err(enospc_err()),
        Some(Action::Abort) => do_abort(site),
        Some(Action::Delay(ms)) => {
            std::thread::sleep(std::time::Duration::from_millis(ms));
            Ok(len)
        }
    }
}

/// Guarded `std::fs::write`. A `partial` firing writes the front half of
/// `bytes` and reports success — the caller believes the write landed.
pub fn write_file(site: &str, path: &Path, bytes: &[u8]) -> io::Result<()> {
    if !faults_enabled() {
        return std::fs::write(path, bytes);
    }
    let quota = write_quota(site, bytes.len())?;
    std::fs::write(path, &bytes[..quota])
}

/// Guarded durable write: write all of `bytes`, then `sync_all`, so the
/// subsequent rename publishes a record that survives power loss. A
/// `partial` firing writes a truncated payload, **skips the fsync**, and
/// reports success — exactly the torn state a real power cut leaves.
pub fn write_file_durable(site: &str, path: &Path, bytes: &[u8]) -> io::Result<()> {
    let quota = if faults_enabled() { write_quota(site, bytes.len())? } else { bytes.len() };
    let mut f = File::create(path)?;
    f.write_all(&bytes[..quota])?;
    if quota == bytes.len() {
        f.sync_all()?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    // The registry is process-global; serialize tests that arm it.
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    fn guard() -> std::sync::MutexGuard<'static, ()> {
        TEST_LOCK.lock().unwrap_or_else(|p| p.into_inner())
    }

    #[test]
    fn disarmed_sites_are_transparent() {
        let _g = guard();
        disarm_all();
        assert!(!faults_enabled());
        assert!(point("any.site").is_ok());
        assert_eq!(write_quota("any.site", 100).unwrap(), 100);
    }

    #[test]
    fn spec_parsing_accepts_the_grammar() {
        let parsed = parse_spec("a.b=err,c=partial:2, d=delay:50:1 ,e=abort,f=enospc").unwrap();
        assert_eq!(parsed.len(), 5);
        assert_eq!(parsed[0], ("a.b".into(), Action::Err, None));
        assert_eq!(parsed[1], ("c".into(), Action::Partial, Some(2)));
        assert_eq!(parsed[2], ("d".into(), Action::Delay(50), Some(1)));
        assert_eq!(parsed[3], ("e".into(), Action::Abort, None));
        assert_eq!(parsed[4], ("f".into(), Action::Enospc, None));
        assert_eq!(parse_spec("").unwrap().len(), 0);
        assert_eq!(parse_spec(" , ").unwrap().len(), 0);
    }

    #[test]
    fn spec_parsing_rejects_garbage() {
        assert!(parse_spec("noequals").is_err());
        assert!(parse_spec("a=explode").is_err());
        assert!(parse_spec("a=err:x").is_err());
        assert!(parse_spec("a=delay").is_err());
        assert!(parse_spec("a=delay:10:2:3").is_err());
        assert!(parse_spec("=err").is_err());
    }

    #[test]
    fn err_fires_counted_then_passes_through_but_keeps_counting() {
        let _g = guard();
        arm_from_spec("t.err=err:2").unwrap();
        assert!(point("t.err").is_err());
        assert!(point("t.err").is_err());
        assert!(point("t.err").is_ok());
        assert!(point("other.site").is_ok());
        assert_eq!(hits(), vec![("t.err".to_string(), 3)]);
        disarm_all();
        assert_eq!(hits(), vec![]);
    }

    #[test]
    fn enospc_action_has_raw_os_error_28() {
        let _g = guard();
        arm_from_spec("t.full=enospc").unwrap();
        let e = point("t.full").unwrap_err();
        assert_eq!(e.raw_os_error(), Some(28));
        disarm_all();
    }

    #[test]
    fn partial_write_truncates_and_reports_success() {
        let _g = guard();
        let dir = std::env::temp_dir().join(format!("fault-partial-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("torn.json");
        arm_from_spec("t.torn=partial:1").unwrap();
        write_file("t.torn", &path, b"0123456789").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"01234");
        // Count exhausted: the next write is whole.
        write_file("t.torn", &path, b"0123456789").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"0123456789");
        disarm_all();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn durable_write_skips_fsync_only_when_torn() {
        let _g = guard();
        let dir = std::env::temp_dir().join(format!("fault-durable-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rec.json");
        disarm_all();
        write_file_durable("t.none", &path, b"full record").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"full record");
        arm_from_spec("t.dur=partial").unwrap();
        write_file_durable("t.dur", &path, b"full record").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"full ");
        disarm_all();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn env_outranks_toml_spec() {
        let _g = guard();
        // No env set in the test runner: the TOML spec applies.
        std::env::remove_var(ENV_SPEC);
        let cfg = FaultConfig { spec: "t.toml=err".into() };
        apply(&cfg).unwrap();
        assert!(faults_enabled());
        assert!(point("t.toml").is_err());
        std::env::set_var(ENV_SPEC, "t.env=err");
        apply(&cfg).unwrap();
        assert!(point("t.toml").is_ok());
        assert!(point("t.env").is_err());
        // Env set to empty disarms even with a TOML spec present.
        std::env::set_var(ENV_SPEC, "");
        apply(&cfg).unwrap();
        assert!(!faults_enabled());
        std::env::remove_var(ENV_SPEC);
        disarm_all();
    }

    #[test]
    fn delay_action_sleeps_then_succeeds() {
        let _g = guard();
        arm_from_spec("t.slow=delay:10:1").unwrap();
        let t0 = std::time::Instant::now();
        assert!(point("t.slow").is_ok());
        assert!(t0.elapsed() >= std::time::Duration::from_millis(10));
        disarm_all();
    }
}
