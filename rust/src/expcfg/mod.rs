//! TOML experiment configuration system.
//!
//! Every CLI subcommand and example is driven by an [`ExperimentConfig`]
//! (file via `--config`, overridable by flags). `configs/` in the repo root
//! ships the paper-scale configurations; tests and the quickstart use the
//! scaled-down defaults to stay fast.

use crate::error::{Error, Result};
use crate::matching::DistanceKind;
use crate::surrogate::EstimatorBackend;
use std::path::{Path, PathBuf};

fn default_artifacts() -> PathBuf {
    PathBuf::from("artifacts")
}
fn default_out() -> PathBuf {
    PathBuf::from("results")
}
fn default_seed() -> u64 {
    2023
}
fn default_samples() -> usize {
    10_650 // paper §V-B
}
fn default_pop() -> usize {
    100
}
fn default_gens() -> u32 {
    250 // paper §IV-C-2
}
fn default_cx() -> f64 {
    0.9
}
fn default_tourn() -> usize {
    2
}
fn default_noise() -> u32 {
    4
}
fn default_factors() -> Vec<f64> {
    vec![0.2, 0.5, 0.75, 1.0] // paper §V-D
}
fn default_distance() -> DistanceKind {
    DistanceKind::Euclidean
}
fn default_backend() -> EstimatorBackend {
    EstimatorBackend::Gbt
}

/// Top-level experiment description.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub name: String,
    /// Operator under DSE (the paper's headline target is `mul8`).
    pub operator: String,
    pub artifacts_dir: PathBuf,
    pub out_dir: PathBuf,
    pub seed: u64,
    /// H_CHAR sample size for non-exhaustive spaces.
    pub train_samples: usize,
    pub surrogate: SurrogateConfig,
    pub conss: ConssConfig,
    pub ga: GaConfig,
    pub service: ServiceConfig,
    pub charac: CharacConfig,
    pub store: StoreConfig,
    pub serve: ServeConfig,
    pub http: HttpConfig,
    pub obs: ObsConfig,
    pub fault: FaultConfig,
    pub scaling_factors: Vec<f64>,
}

impl ExperimentConfig {
    fn default_operator() -> String {
        "mul8".into()
    }

    pub fn load(path: &Path) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|_| Error::ArtifactMissing { path: path.to_path_buf() })?;
        Self::from_toml_str(&text)
            .map_err(|e| Error::Config(format!("{}: {e}", path.display())))
    }

    /// Parse the TOML subset `configs/*.toml` uses. Unknown keys are
    /// rejected (typo protection).
    pub fn from_toml_str(text: &str) -> Result<ExperimentConfig> {
        use crate::util::tomlkit::{parse, TomlValue};
        let map = parse(text)?;
        let mut cfg = ExperimentConfig::default();
        let bad =
            |key: &str, want: &str| Error::Config(format!("key `{key}` must be {want}"));
        let get_str = |key: &str, v: &TomlValue| -> Result<String> {
            v.as_str().map(String::from).ok_or_else(|| bad(key, "a string"))
        };
        for (key, value) in &map {
            match key.as_str() {
                "name" => cfg.name = get_str(key, value)?,
                "operator" => cfg.operator = get_str(key, value)?,
                "artifacts_dir" => cfg.artifacts_dir = PathBuf::from(get_str(key, value)?),
                "out_dir" => cfg.out_dir = PathBuf::from(get_str(key, value)?),
                "seed" => {
                    cfg.seed = value
                        .as_i64()
                        .and_then(|v| u64::try_from(v).ok())
                        .ok_or_else(|| bad(key, "a non-negative integer"))?
                }
                "train_samples" => {
                    cfg.train_samples =
                        value.as_usize().ok_or_else(|| bad(key, "an integer"))?
                }
                "scaling_factors" => {
                    cfg.scaling_factors =
                        value.as_f64_array().ok_or_else(|| bad(key, "a number array"))?
                }
                "surrogate.backend" => {
                    let s = get_str(key, value)?;
                    cfg.surrogate.backend = EstimatorBackend::from_name(&s)
                        .ok_or_else(|| bad(key, "table|gbt|pjrt-mlp"))?;
                }
                "surrogate.gbt_stages" => {
                    cfg.surrogate.gbt_stages =
                        Some(value.as_usize().ok_or_else(|| bad(key, "an integer"))?)
                }
                "conss.distance" => {
                    let s = get_str(key, value)?;
                    cfg.conss.distance = DistanceKind::from_name(&s)
                        .ok_or_else(|| bad(key, "euclidean|manhattan|pareto"))?;
                }
                "conss.noise_bits" => {
                    cfg.conss.noise_bits =
                        value.as_usize().ok_or_else(|| bad(key, "an integer"))? as u32
                }
                "conss.forest_trees" => {
                    cfg.conss.forest_trees =
                        Some(value.as_usize().ok_or_else(|| bad(key, "an integer"))?)
                }
                "ga.pop_size" => {
                    cfg.ga.pop_size =
                        value.as_usize().ok_or_else(|| bad(key, "an integer"))?
                }
                "ga.generations" => {
                    cfg.ga.generations =
                        value.as_usize().ok_or_else(|| bad(key, "an integer"))? as u32
                }
                "ga.crossover_prob" => {
                    cfg.ga.crossover_prob =
                        value.as_f64().ok_or_else(|| bad(key, "a number"))?
                }
                "ga.mutation_prob" => {
                    cfg.ga.mutation_prob =
                        Some(value.as_f64().ok_or_else(|| bad(key, "a number"))?)
                }
                "ga.tournament_size" => {
                    cfg.ga.tournament_size =
                        value.as_usize().ok_or_else(|| bad(key, "an integer"))?
                }
                "service.max_batch" => {
                    cfg.service.max_batch =
                        value.as_usize().ok_or_else(|| bad(key, "an integer"))?
                }
                "service.max_wait_us" => {
                    cfg.service.max_wait_us = value
                        .as_i64()
                        .and_then(|v| u64::try_from(v).ok())
                        .ok_or_else(|| bad(key, "a non-negative integer"))?
                }
                "charac.shard_size" => {
                    cfg.charac.shard_size =
                        value.as_usize().ok_or_else(|| bad(key, "an integer"))?
                }
                "charac.behav" => {
                    let s = get_str(key, value)?;
                    cfg.charac.behav = Some(
                        crate::charac::BehavBackend::from_name(&s)
                            .ok_or_else(|| bad(key, "scalar|bitslice"))?,
                    );
                }
                "charac.ppa" => {
                    let s = get_str(key, value)?;
                    cfg.charac.ppa = Some(
                        crate::charac::PpaBackend::from_name(&s)
                            .ok_or_else(|| bad(key, "scalar|plane"))?,
                    );
                }
                "store.enabled" => {
                    cfg.store.enabled =
                        Some(value.as_bool().ok_or_else(|| bad(key, "a boolean"))?)
                }
                "store.dir" => cfg.store.dir = Some(PathBuf::from(get_str(key, value)?)),
                "store.max_bytes" => {
                    cfg.store.max_bytes = Some(
                        value
                            .as_i64()
                            .and_then(|v| u64::try_from(v).ok())
                            .ok_or_else(|| bad(key, "a non-negative integer"))?,
                    )
                }
                "serve.workers" => {
                    cfg.serve.workers =
                        value.as_usize().ok_or_else(|| bad(key, "an integer"))?
                }
                "serve.poll_ms" => {
                    cfg.serve.poll_ms = value
                        .as_i64()
                        .and_then(|v| u64::try_from(v).ok())
                        .ok_or_else(|| bad(key, "a non-negative integer"))?
                }
                "serve.jobs_dir" => {
                    cfg.serve.jobs_dir = Some(PathBuf::from(get_str(key, value)?))
                }
                "serve.log_max_bytes" => {
                    cfg.serve.log_max_bytes = value
                        .as_i64()
                        .and_then(|v| u64::try_from(v).ok())
                        .ok_or_else(|| bad(key, "a non-negative integer"))?
                }
                "http.addr" => cfg.http.addr = get_str(key, value)?,
                "http.threads" => {
                    cfg.http.threads =
                        value.as_usize().ok_or_else(|| bad(key, "an integer"))?
                }
                "http.high_water" => {
                    cfg.http.high_water =
                        value.as_usize().ok_or_else(|| bad(key, "an integer"))?
                }
                "http.retry_after_secs" => {
                    cfg.http.retry_after_secs = value
                        .as_i64()
                        .and_then(|v| u64::try_from(v).ok())
                        .ok_or_else(|| bad(key, "a non-negative integer"))?
                }
                "http.max_body_bytes" => {
                    cfg.http.max_body_bytes =
                        value.as_usize().ok_or_else(|| bad(key, "an integer"))?
                }
                "obs.trace" => {
                    cfg.obs.trace =
                        value.as_bool().ok_or_else(|| bad(key, "a boolean"))?
                }
                "obs.trace_buffer" => {
                    cfg.obs.trace_buffer =
                        value.as_usize().ok_or_else(|| bad(key, "an integer"))?
                }
                "fault.spec" => cfg.fault.spec = get_str(key, value)?,
                other => {
                    return Err(Error::Config(format!("unknown config key `{other}`")))
                }
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        crate::operator::Operator::from_name(&self.operator)?;
        if self.train_samples == 0 {
            return Err(Error::Config("train_samples must be > 0".into()));
        }
        if self.ga.pop_size < 2 {
            return Err(Error::Config("ga.pop_size must be >= 2".into()));
        }
        for &f in &self.scaling_factors {
            if !(0.0 < f && f <= 1.0) {
                return Err(Error::Config(format!(
                    "scaling factor {f} outside (0, 1]"
                )));
            }
        }
        if self.conss.noise_bits > 8 {
            return Err(Error::Config("conss.noise_bits > 8 is unreasonable".into()));
        }
        if self.service.max_batch == 0 {
            return Err(Error::Config("service.max_batch must be > 0".into()));
        }
        if self.charac.shard_size == 0 {
            return Err(Error::Config("charac.shard_size must be > 0".into()));
        }
        if self.store.max_bytes == Some(0) {
            return Err(Error::Config("store.max_bytes must be > 0".into()));
        }
        if self.serve.workers == 0 {
            return Err(Error::Config("serve.workers must be > 0".into()));
        }
        if self.http.threads == 0 {
            return Err(Error::Config("http.threads must be > 0".into()));
        }
        if self.http.high_water == 0 {
            return Err(Error::Config("http.high_water must be > 0".into()));
        }
        if self.http.max_body_bytes == 0 {
            return Err(Error::Config("http.max_body_bytes must be > 0".into()));
        }
        if self.serve.log_max_bytes == 0 {
            return Err(Error::Config("serve.log_max_bytes must be > 0".into()));
        }
        if self.obs.trace_buffer == 0 {
            return Err(Error::Config("obs.trace_buffer must be > 0".into()));
        }
        crate::fault::validate_spec(&self.fault.spec)?;
        Ok(())
    }
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: String::new(),
            operator: Self::default_operator(),
            artifacts_dir: default_artifacts(),
            out_dir: default_out(),
            seed: default_seed(),
            train_samples: default_samples(),
            surrogate: SurrogateConfig::default(),
            conss: ConssConfig::default(),
            ga: GaConfig::default(),
            service: ServiceConfig::default(),
            charac: CharacConfig::default(),
            store: StoreConfig::default(),
            serve: ServeConfig::default(),
            http: HttpConfig::default(),
            obs: ObsConfig::default(),
            fault: FaultConfig::default(),
            scaling_factors: default_factors(),
        }
    }
}

/// Failpoint knobs (`[fault]`): the armed-site spec, same grammar as the
/// `REPRO_FAULTS` environment variable (which outranks it). Empty = all
/// sites disarmed — the production default; every injection site then
/// costs one relaxed atomic load.
#[derive(Debug, Clone, Default)]
pub struct FaultConfig {
    /// `site=action[:count],...` — see [`crate::fault`] for the grammar.
    pub spec: String,
}

/// HTTP front-end knobs (`repro serve-http`).
#[derive(Debug, Clone)]
pub struct HttpConfig {
    /// Bind address (`host:port`; port 0 = OS-assigned).
    pub addr: String,
    /// Concurrent acceptor threads.
    pub threads: usize,
    /// Admission control: reject `POST /jobs` with `429` once `pending/`
    /// holds this many specs (dedup hits still answer `200`).
    pub high_water: usize,
    /// The `Retry-After` hint sent with a `429`, seconds.
    pub retry_after_secs: u64,
    /// Largest accepted request body, bytes.
    pub max_body_bytes: usize,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig {
            addr: "127.0.0.1:7878".into(),
            threads: 4,
            high_water: 256,
            retry_after_secs: 1,
            max_body_bytes: 64 * 1024,
        }
    }
}

/// Observability knobs (`[obs]`): span tracing gate and ring size. The
/// `REPRO_TRACE` environment variable outranks `trace` either way;
/// latency histograms and drop counters are always on (their cost is a
/// few relaxed atomics per event).
#[derive(Debug, Clone)]
pub struct ObsConfig {
    /// Record spans into the in-process ring (default off — the disabled
    /// path is one relaxed atomic load per would-be span).
    pub trace: bool,
    /// Span ring capacity; oldest spans are overwritten past this.
    pub trace_buffer: usize,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig { trace: false, trace_buffer: crate::obs::DEFAULT_TRACE_BUFFER }
    }
}

/// Serve-mode job-server knobs (`repro serve-dse` / `repro submit`).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Concurrent job-runner workers.
    pub workers: usize,
    /// Watch-mode `pending/` poll interval, milliseconds.
    pub poll_ms: u64,
    /// Spool directory; `None` = `artifacts_dir/jobs`.
    pub jobs_dir: Option<PathBuf>,
    /// Rotate `server.log.jsonl` to `.1` past this many bytes.
    pub log_max_bytes: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 2,
            poll_ms: 200,
            jobs_dir: None,
            log_max_bytes: crate::serve::eventlog::DEFAULT_LOG_MAX_BYTES,
        }
    }
}

impl ServeConfig {
    /// The resolved spool directory under `artifacts_dir`.
    pub fn dir_under(&self, artifacts_dir: &Path) -> PathBuf {
        self.jobs_dir.clone().unwrap_or_else(|| artifacts_dir.join("jobs"))
    }

    pub fn poll(&self) -> std::time::Duration {
        std::time::Duration::from_millis(self.poll_ms)
    }
}

/// Characterization execution knobs.
#[derive(Debug, Clone)]
pub struct CharacConfig {
    /// Configurations per shard when a `Seeded` characterization job is
    /// split across the worker pool. The shard plan is a pure function of
    /// `(n, shard_size)`, so results are bit-identical for any value.
    pub shard_size: usize,
    /// Native BEHAV implementation preference (`scalar` | `bitslice`).
    /// `None` = the resolved default (bit-sliced); the `REPRO_BEHAV` env
    /// escape hatch outranks this either way. Both produce bit-identical
    /// metrics, so this is a perf/debug knob, not a semantic one.
    pub behav: Option<crate::charac::BehavBackend>,
    /// PPA implementation preference (`scalar` | `plane`). `None` = the
    /// resolved default (config-parallel plane); the `REPRO_PPA` env
    /// escape hatch outranks this either way. Bit-identical like the
    /// BEHAV pair — a perf/debug knob, not a semantic one.
    pub ppa: Option<crate::charac::PpaBackend>,
}

impl Default for CharacConfig {
    fn default() -> Self {
        CharacConfig { shard_size: 512, behav: None, ppa: None }
    }
}

/// Persistent on-disk dataset store knobs (`artifacts_dir/datasets/`).
#[derive(Debug, Clone, Default)]
pub struct StoreConfig {
    /// Tri-state: `None` leaves the decision to the embedding — the
    /// `repro` CLI turns the store on (opt out with `--no-store`), while
    /// library/test embedding defaults to off so hermetic runs never
    /// touch the filesystem. `Some(_)` is an explicit choice (TOML
    /// `store.enabled` or CLI flag).
    pub enabled: Option<bool>,
    /// Store directory; `None` = `artifacts_dir/datasets`.
    pub dir: Option<PathBuf>,
    /// Byte budget for LRU eviction: `repro store gc` falls back to it,
    /// and the serve loops (`serve-dse --watch`, `serve-http`) garbage
    /// collect against it periodically while idle. `None` = unbounded.
    pub max_bytes: Option<u64>,
}

impl StoreConfig {
    /// Whether the store is active for this configuration (`None` = off:
    /// the hermetic library default).
    pub fn is_enabled(&self) -> bool {
        self.enabled.unwrap_or(false)
    }

    /// The resolved store directory under `artifacts_dir`.
    pub fn dir_under(&self, artifacts_dir: &Path) -> PathBuf {
        self.dir.clone().unwrap_or_else(|| artifacts_dir.join("datasets"))
    }
}

/// Estimator-service batching knobs (the engine's shared
/// [`EstimatorService`](crate::coordinator::EstimatorService)).
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Flush when this many configurations are pending.
    pub max_batch: usize,
    /// Flush this long after the first pending request (microseconds).
    pub max_wait_us: u64,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        let opts = crate::coordinator::BatchOptions::default();
        ServiceConfig {
            max_batch: opts.max_batch,
            max_wait_us: opts.max_wait.as_micros() as u64,
        }
    }
}

impl ServiceConfig {
    pub fn to_batch_options(&self) -> crate::coordinator::BatchOptions {
        crate::coordinator::BatchOptions {
            max_batch: self.max_batch,
            max_wait: std::time::Duration::from_micros(self.max_wait_us),
        }
    }
}

/// Surrogate backend selection.
#[derive(Debug, Clone)]
pub struct SurrogateConfig {
    pub backend: EstimatorBackend,
    pub gbt_stages: Option<usize>,
}

impl Default for SurrogateConfig {
    fn default() -> Self {
        SurrogateConfig { backend: default_backend(), gbt_stages: None }
    }
}

/// ConSS knobs.
#[derive(Debug, Clone)]
pub struct ConssConfig {
    pub distance: DistanceKind,
    pub noise_bits: u32,
    pub forest_trees: Option<usize>,
}

impl Default for ConssConfig {
    fn default() -> Self {
        ConssConfig {
            distance: default_distance(),
            noise_bits: default_noise(),
            forest_trees: None,
        }
    }
}

/// GA knobs (defaults = paper's DEAP setup).
#[derive(Debug, Clone)]
pub struct GaConfig {
    pub pop_size: usize,
    pub generations: u32,
    pub crossover_prob: f64,
    pub mutation_prob: Option<f64>,
    pub tournament_size: usize,
}

impl Default for GaConfig {
    fn default() -> Self {
        GaConfig {
            pop_size: default_pop(),
            generations: default_gens(),
            crossover_prob: default_cx(),
            mutation_prob: None,
            tournament_size: default_tourn(),
        }
    }
}

impl GaConfig {
    pub fn to_options(&self, seed: u64) -> crate::dse::GaOptions {
        crate::dse::GaOptions {
            pop_size: self.pop_size,
            generations: self.generations,
            crossover_prob: self.crossover_prob,
            mutation_prob: self.mutation_prob,
            tournament_size: self.tournament_size,
            seed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_paper_scale() {
        let c = ExperimentConfig::default();
        assert_eq!(c.train_samples, 10_650);
        assert_eq!(c.ga.generations, 250);
        assert_eq!(c.scaling_factors, vec![0.2, 0.5, 0.75, 1.0]);
        c.validate().unwrap();
    }

    #[test]
    fn toml_roundtrip() {
        let dir = crate::util::tempdir::TempDir::new().unwrap();
        let p = dir.path().join("e.toml");
        std::fs::write(
            &p,
            r#"
name = "fig15"
operator = "mul8"
train_samples = 2000
scaling_factors = [0.5]

[ga]
pop_size = 40
generations = 30

[conss]
distance = "manhattan"
noise_bits = 2

[surrogate]
backend = "pjrt-mlp"

[service]
max_batch = 128
max_wait_us = 500

[charac]
shard_size = 64
behav = "scalar"
ppa = "scalar"

[store]
enabled = true
dir = "/tmp/ds"
max_bytes = 1000000

[serve]
workers = 4
poll_ms = 50
jobs_dir = "/tmp/jobs"
log_max_bytes = 4096

[obs]
trace = true
trace_buffer = 1024

[http]
addr = "0.0.0.0:8080"
threads = 8
high_water = 32
retry_after_secs = 2
max_body_bytes = 4096

[fault]
spec = "queue.complete.rename=abort:1"
"#,
        )
        .unwrap();
        let c = ExperimentConfig::load(&p).unwrap();
        assert_eq!(c.ga.pop_size, 40);
        assert_eq!(c.conss.distance, DistanceKind::Manhattan);
        assert_eq!(c.surrogate.backend, EstimatorBackend::PjrtMlp);
        assert_eq!(c.service.max_batch, 128);
        assert_eq!(c.service.to_batch_options().max_wait.as_micros(), 500);
        assert_eq!(c.charac.shard_size, 64);
        assert_eq!(c.charac.behav, Some(crate::charac::BehavBackend::Scalar));
        assert_eq!(c.charac.ppa, Some(crate::charac::PpaBackend::Scalar));
        assert_eq!(c.store.enabled, Some(true));
        assert!(c.store.is_enabled());
        assert_eq!(c.store.dir_under(Path::new("a")), PathBuf::from("/tmp/ds"));
        assert_eq!(c.store.max_bytes, Some(1_000_000));
        assert_eq!(c.serve.workers, 4);
        assert_eq!(c.serve.poll().as_millis(), 50);
        assert_eq!(c.serve.dir_under(Path::new("a")), PathBuf::from("/tmp/jobs"));
        assert_eq!(c.serve.log_max_bytes, 4096);
        assert!(c.obs.trace);
        assert_eq!(c.obs.trace_buffer, 1024);
        assert_eq!(c.http.addr, "0.0.0.0:8080");
        assert_eq!(c.http.threads, 8);
        assert_eq!(c.http.high_water, 32);
        assert_eq!(c.http.retry_after_secs, 2);
        assert_eq!(c.http.max_body_bytes, 4096);
        assert_eq!(c.fault.spec, "queue.complete.rename=abort:1");
    }

    #[test]
    fn fault_spec_is_validated() {
        let c = ExperimentConfig::default();
        assert_eq!(c.fault.spec, "", "failpoints must default to disarmed");
        c.validate().unwrap();
        let c = ExperimentConfig {
            fault: FaultConfig { spec: "site=explode".into() },
            ..Default::default()
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn http_defaults_and_validation() {
        let c = ExperimentConfig::default();
        assert_eq!(c.http.addr, "127.0.0.1:7878");
        assert_eq!(c.http.threads, 4);
        assert_eq!(c.http.high_water, 256);
        assert_eq!(c.http.retry_after_secs, 1);
        assert_eq!(c.http.max_body_bytes, 64 * 1024);
        for broken in [
            ExperimentConfig {
                http: HttpConfig { threads: 0, ..Default::default() },
                ..Default::default()
            },
            ExperimentConfig {
                http: HttpConfig { high_water: 0, ..Default::default() },
                ..Default::default()
            },
            ExperimentConfig {
                http: HttpConfig { max_body_bytes: 0, ..Default::default() },
                ..Default::default()
            },
        ] {
            assert!(broken.validate().is_err());
        }
    }

    #[test]
    fn obs_defaults_are_off_and_validated() {
        let c = ExperimentConfig::default();
        assert!(!c.obs.trace, "tracing must be opt-in");
        assert_eq!(c.obs.trace_buffer, crate::obs::DEFAULT_TRACE_BUFFER);
        let c = ExperimentConfig {
            obs: ObsConfig { trace_buffer: 0, ..Default::default() },
            ..Default::default()
        };
        assert!(c.validate().is_err());
        let c = ExperimentConfig {
            serve: ServeConfig { log_max_bytes: 0, ..Default::default() },
            ..Default::default()
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn serve_defaults_and_validation() {
        let c = ExperimentConfig::default();
        assert_eq!(c.serve.workers, 2);
        assert_eq!(c.serve.poll_ms, 200);
        assert_eq!(c.serve.log_max_bytes, 8 * 1024 * 1024);
        assert_eq!(
            c.serve.dir_under(Path::new("artifacts")),
            PathBuf::from("artifacts").join("jobs")
        );
        let c = ExperimentConfig {
            serve: ServeConfig { workers: 0, ..Default::default() },
            ..Default::default()
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn store_defaults_are_hermetic() {
        let c = ExperimentConfig::default();
        assert_eq!(c.store.enabled, None);
        assert!(!c.store.is_enabled(), "library default must not touch disk");
        assert_eq!(
            c.store.dir_under(Path::new("artifacts")),
            PathBuf::from("artifacts").join("datasets")
        );
        assert_eq!(c.charac.shard_size, 512);
        assert_eq!(c.charac.behav, None, "backend choice is resolved, not baked in");
        assert_eq!(c.charac.ppa, None, "PPA backend choice is resolved, not baked in");
        assert_eq!(c.store.max_bytes, None, "store is unbounded unless budgeted");
        let c = ExperimentConfig {
            charac: CharacConfig { shard_size: 0, ..Default::default() },
            ..Default::default()
        };
        assert!(c.validate().is_err());
        let c = ExperimentConfig {
            store: StoreConfig { max_bytes: Some(0), ..Default::default() },
            ..Default::default()
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn rejects_invalid() {
        let c = ExperimentConfig { operator: "div9".into(), ..Default::default() };
        assert!(c.validate().is_err());
        let c = ExperimentConfig { scaling_factors: vec![1.5], ..Default::default() };
        assert!(c.validate().is_err());
        let c = ExperimentConfig {
            ga: GaConfig { pop_size: 1, ..Default::default() },
            ..Default::default()
        };
        assert!(c.validate().is_err());
        let c = ExperimentConfig {
            service: ServiceConfig { max_batch: 0, ..Default::default() },
            ..Default::default()
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn unknown_fields_rejected() {
        let dir = crate::util::tempdir::TempDir::new().unwrap();
        let p = dir.path().join("bad.toml");
        std::fs::write(&p, "operatorr = \"mul8\"\n").unwrap();
        assert!(matches!(ExperimentConfig::load(&p), Err(Error::Config(_))));
    }
}
