//! Noise-bit augmentation (paper Fig. 8).
//!
//! Each `INP_SEQ → OUT_SEQ` pair is replicated once per value of an
//! `n`-bit noise suffix appended to the input sequence. At supersampling
//! time the trained model is queried with every noise value, so one L
//! configuration can fan out into up to `2^n` distinct H candidates.

/// Expand `(l_bits, h_bits)` pairs into row-major (x, y) training matrices
/// with all `2^noise_bits` noise suffixes.
pub fn augment_with_noise(
    pairs: &[(Vec<f64>, Vec<f64>)],
    noise_bits: u32,
) -> (Vec<f64>, Vec<f64>) {
    let reps = 1usize << noise_bits;
    let lf = pairs.first().map_or(0, |(l, _)| l.len());
    let hf = pairs.first().map_or(0, |(_, h)| h.len());
    let mut x = Vec::with_capacity(pairs.len() * reps * (lf + noise_bits as usize));
    let mut y = Vec::with_capacity(pairs.len() * reps * hf);
    for (l, h) in pairs {
        for noise in 0..reps {
            x.extend_from_slice(l);
            for k in 0..noise_bits {
                x.push(((noise >> k) & 1) as f64);
            }
            y.extend_from_slice(h);
        }
    }
    (x, y)
}

/// The noise suffix row for one noise value (query-time helper).
pub fn noise_row(noise: usize, noise_bits: u32) -> Vec<f64> {
    (0..noise_bits).map(|k| ((noise >> k) & 1) as f64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replication_counts() {
        let pairs = vec![(vec![1.0, 0.0], vec![1.0, 1.0, 0.0])];
        let (x, y) = augment_with_noise(&pairs, 2);
        assert_eq!(x.len(), 4 * 4); // 4 reps × (2 + 2) features
        assert_eq!(y.len(), 4 * 3);
        // Noise suffixes enumerate 00, 10, 01, 11 (LSB first).
        let suffixes: Vec<(f64, f64)> =
            (0..4).map(|r| (x[r * 4 + 2], x[r * 4 + 3])).collect();
        assert_eq!(suffixes, vec![(0.0, 0.0), (1.0, 0.0), (0.0, 1.0), (1.0, 1.0)]);
    }

    #[test]
    fn zero_noise_bits_is_identity() {
        let pairs = vec![(vec![1.0], vec![0.0]), (vec![0.0], vec![1.0])];
        let (x, y) = augment_with_noise(&pairs, 0);
        assert_eq!(x, vec![1.0, 0.0]);
        assert_eq!(y, vec![0.0, 1.0]);
    }

    #[test]
    fn noise_row_lsb_first() {
        assert_eq!(noise_row(0b10, 2), vec![0.0, 1.0]);
        assert_eq!(noise_row(0b01, 3), vec![1.0, 0.0, 0.0]);
    }
}
