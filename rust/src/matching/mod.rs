//! Distance-based matching (paper §IV-B, Figs. 7/8/12).
//!
//! Builds the `INP_SEQ → OUT_SEQ` datasets that train ConSS models: every
//! configuration in the high-bit-width dataset (`H_CHAR`) is matched to its
//! nearest neighbour in the low-bit-width dataset (`L_CHAR`) in the
//! *scaled* (PPA, BEHAV) metric plane. Multiple H configurations may share
//! one L configuration — the one-to-many mapping of Fig. 7 — and noise-bit
//! augmentation (Fig. 8) replicates each pair `2^n` times so the trained
//! model can emit a diverse set of H candidates per L seed.

pub mod noise;

use crate::charac::Dataset;
use crate::error::{Error, Result};
use crate::stats::{distance::distance_matrix, MinMaxScaler};

pub use crate::stats::DistanceKind;

pub use noise::augment_with_noise;

/// Result of matching every H configuration to its nearest L configuration.
#[derive(Debug, Clone)]
pub struct MatchResult {
    pub kind: DistanceKind,
    /// For each H row, the index of the matched L row.
    pub h_to_l: Vec<usize>,
    /// For each H row, the achieved (scaled) distance.
    pub distances: Vec<f64>,
}

impl MatchResult {
    /// Matches per L row — the Fig. 12(b) one-to-many counts.
    pub fn counts_per_l(&self, n_l: usize) -> Vec<usize> {
        let mut c = vec![0usize; n_l];
        for &l in &self.h_to_l {
            c[l] += 1;
        }
        c
    }
}

/// Distance-based matcher over headline (PDPLUT, AVG_ABS_REL_ERR) planes.
#[derive(Debug, Clone)]
pub struct Matcher {
    pub kind: DistanceKind,
}

impl Matcher {
    pub fn new(kind: DistanceKind) -> Matcher {
        Matcher { kind }
    }

    /// Scaled headline points of a dataset (each dataset scaled
    /// independently, as in the paper's Fig. 1b comparison).
    pub fn scaled_points(ds: &Dataset) -> Result<Vec<[f64; 2]>> {
        let pts = ds.headline_points();
        let scaler = MinMaxScaler::fit_points2(&pts)?;
        Ok(scaler.transform_points2(&pts))
    }

    /// Match every H design to its nearest L design.
    pub fn match_datasets(&self, l: &Dataset, h: &Dataset) -> Result<MatchResult> {
        if l.is_empty() || h.is_empty() {
            return Err(Error::Dataset("cannot match empty datasets".into()));
        }
        let lp = Self::scaled_points(l)?;
        let hp = Self::scaled_points(h)?;
        let mut h_to_l = Vec::with_capacity(hp.len());
        let mut distances = Vec::with_capacity(hp.len());
        for hpt in &hp {
            let (mut best, mut best_i) = (f64::INFINITY, 0);
            for (i, lpt) in lp.iter().enumerate() {
                let d = self.kind.distance(*hpt, *lpt);
                if d < best {
                    best = d;
                    best_i = i;
                }
            }
            h_to_l.push(best_i);
            distances.push(best);
        }
        Ok(MatchResult { kind: self.kind, h_to_l, distances })
    }

    /// All pairwise scaled distances (flattened H×L) — the Fig. 11
    /// distribution input and Fig. 12(a) heat-map.
    pub fn all_distances(&self, l: &Dataset, h: &Dataset) -> Result<Vec<f64>> {
        let lp = Self::scaled_points(l)?;
        let hp = Self::scaled_points(h)?;
        Ok(distance_matrix(self.kind, &hp, &lp))
    }
}

/// Assemble the ConSS training matrices from a match result: row-major
/// `x = [l_config_bits | noise]`, `y = h_config_bits`, with `2^noise_bits`
/// replicas per pair (Fig. 8).
pub fn conss_training_set(
    l: &Dataset,
    h: &Dataset,
    m: &MatchResult,
    noise_bits: u32,
) -> Result<(Vec<f64>, usize, Vec<f64>, usize)> {
    if m.h_to_l.len() != h.len() {
        return Err(Error::Dataset("match result does not cover H dataset".into()));
    }
    let lf = l.operator.config_len() as usize;
    let hf = h.operator.config_len() as usize;
    let pairs: Vec<(Vec<f64>, Vec<f64>)> = m
        .h_to_l
        .iter()
        .enumerate()
        .map(|(hi, &li)| {
            let lx: Vec<f64> =
                l.configs[li].to_bits_f32().iter().map(|&v| v as f64).collect();
            let hy: Vec<f64> =
                h.configs[hi].to_bits_f32().iter().map(|&v| v as f64).collect();
            (lx, hy)
        })
        .collect();
    let (x, y) = augment_with_noise(&pairs, noise_bits);
    Ok((x, lf + noise_bits as usize, y, hf))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::charac::{characterize_all, Backend, InputSet};
    use crate::operator::Operator;

    fn adder_datasets() -> (Dataset, Dataset) {
        let li = InputSet::exhaustive(Operator::ADD4);
        let hi = InputSet::exhaustive(Operator::ADD8);
        let l = characterize_all(Operator::ADD4, &li, &Backend::Native).unwrap();
        let h = characterize_all(Operator::ADD8, &hi, &Backend::Native).unwrap();
        (l, h)
    }

    #[test]
    fn matching_covers_all_h_and_is_one_to_many() {
        let (l, h) = adder_datasets();
        let m = Matcher::new(DistanceKind::Euclidean).match_datasets(&l, &h).unwrap();
        assert_eq!(m.h_to_l.len(), 255);
        let counts = m.counts_per_l(l.len());
        assert_eq!(counts.iter().sum::<usize>(), 255);
        // 255 H into 15 L: pigeonhole forces one-to-many.
        assert!(counts.iter().any(|&c| c > 1));
    }

    #[test]
    fn matched_distance_is_minimal() {
        let (l, h) = adder_datasets();
        let m = Matcher::new(DistanceKind::Manhattan).match_datasets(&l, &h).unwrap();
        let lp = Matcher::scaled_points(&l).unwrap();
        let hp = Matcher::scaled_points(&h).unwrap();
        for (hi, &li) in m.h_to_l.iter().enumerate() {
            let got = DistanceKind::Manhattan.distance(hp[hi], lp[li]);
            for lpt in &lp {
                assert!(got <= DistanceKind::Manhattan.distance(hp[hi], *lpt) + 1e-12);
            }
        }
    }

    #[test]
    fn self_match_is_identity_with_zero_distance() {
        let (l, _) = adder_datasets();
        let m = Matcher::new(DistanceKind::Euclidean).match_datasets(&l, &l).unwrap();
        for (hi, &li) in m.h_to_l.iter().enumerate() {
            // Distances are zero (a point is its own nearest neighbour) —
            // ties may pick another coincident point, so check distance.
            assert!(m.distances[hi] <= 1e-12, "h {hi} -> l {li}");
        }
    }

    #[test]
    fn training_set_shapes() {
        let (l, h) = adder_datasets();
        let m = Matcher::new(DistanceKind::Euclidean).match_datasets(&l, &h).unwrap();
        let (x, xf, y, yf) = conss_training_set(&l, &h, &m, 2).unwrap();
        assert_eq!(xf, 4 + 2);
        assert_eq!(yf, 8);
        assert_eq!(x.len(), 255 * 4 * 6);
        assert_eq!(y.len(), 255 * 4 * 8);
        assert!(x.iter().all(|&v| v == 0.0 || v == 1.0));
    }

    #[test]
    fn all_distances_size() {
        let (l, h) = adder_datasets();
        let d = Matcher::new(DistanceKind::Pareto).all_distances(&l, &h).unwrap();
        assert_eq!(d.len(), 255 * 15);
        assert!(d.iter().all(|&v| v >= 0.0));
    }
}
