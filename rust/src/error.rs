//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls keep the default build std-only (no
//! `thiserror`); the CLI prints the same [`Error`] at its boundary.
//! Variants are grouped by subsystem so failure injection tests can assert
//! on the class of failure.

use std::fmt;
use std::path::PathBuf;

/// Unified error type for the AxOCS library.
#[derive(Debug)]
pub enum Error {
    /// Artifact file (HLO text, weights, manifest, input set) missing.
    ArtifactMissing { path: PathBuf },

    /// Artifact exists but failed to parse/validate.
    ArtifactCorrupt { path: PathBuf, reason: String },

    /// PJRT / XLA runtime failure.
    Xla(String),

    /// Shape or batch-size mismatch between caller and compiled executable.
    Shape(String),

    /// Invalid operator configuration (e.g. all-zeros, wrong length).
    InvalidConfig(String),

    /// Dataset consistency problem (length mismatch, empty, bad columns).
    Dataset(String),

    /// ML model error (untrained model queried, bad hyperparameters).
    Ml(String),

    /// DSE setup error (bad constraints, empty population).
    Dse(String),

    /// Coordinator/service failure (channel closed, worker panicked).
    Coordinator(String),

    /// Experiment configuration / CLI argument problem.
    Config(String),

    Io(std::io::Error),

    Json(crate::util::json::JsonError),

    Toml(crate::util::tomlkit::TomlError),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::ArtifactMissing { path } => write!(
                f,
                "artifact not found: {} (run `make artifacts` first)",
                path.display()
            ),
            Error::ArtifactCorrupt { path, reason } => {
                write!(f, "corrupt artifact {}: {reason}", path.display())
            }
            Error::Xla(m) => write!(f, "xla runtime error: {m}"),
            Error::Shape(m) => write!(f, "shape mismatch: {m}"),
            Error::InvalidConfig(m) => write!(f, "invalid configuration: {m}"),
            Error::Dataset(m) => write!(f, "dataset error: {m}"),
            Error::Ml(m) => write!(f, "ml error: {m}"),
            Error::Dse(m) => write!(f, "dse error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            // Transparent wrappers: display the source verbatim.
            Error::Io(e) => write!(f, "{e}"),
            Error::Json(e) => write!(f, "{e}"),
            Error::Toml(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            Error::Json(e) => Some(e),
            Error::Toml(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<crate::util::json::JsonError> for Error {
    fn from(e: crate::util::json::JsonError) -> Self {
        Error::Json(e)
    }
}

impl From<crate::util::tomlkit::TomlError> for Error {
    fn from(e: crate::util::tomlkit::TomlError) -> Self {
        Error::Toml(e)
    }
}

impl From<crate::cli::ArgError> for Error {
    fn from(e: crate::cli::ArgError) -> Self {
        Error::Config(e.to_string())
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl Error {
    /// Whether this is a full-disk (`ENOSPC`) I/O failure — the one fault
    /// class the serve loops downgrade to load-shedding (`503` + pause)
    /// instead of crashing or retiring workers.
    pub fn is_disk_full(&self) -> bool {
        matches!(self, Error::Io(e) if e.raw_os_error() == Some(28))
    }
}

pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_subsystem_prefix() {
        assert_eq!(Error::Shape("x".into()).to_string(), "shape mismatch: x");
        assert_eq!(Error::Dse("y".into()).to_string(), "dse error: y");
        let e = Error::ArtifactMissing { path: PathBuf::from("a/b.bin") };
        assert!(e.to_string().contains("a/b.bin"));
        assert!(e.to_string().contains("make artifacts"));
    }

    #[test]
    fn transparent_wrappers_expose_source() {
        use std::error::Error as _;
        let io = Error::from(std::io::Error::other("disk"));
        assert!(io.source().is_some());
        assert!(io.to_string().contains("disk"));
        assert!(Error::Config("c".into()).source().is_none());
    }

    #[test]
    fn disk_full_is_detected_through_the_io_wrapper() {
        let full = Error::from(std::io::Error::from_raw_os_error(28));
        assert!(full.is_disk_full());
        assert!(!Error::from(std::io::Error::other("x")).is_disk_full());
        assert!(!Error::Config("c".into()).is_disk_full());
    }
}
