//! Crate-wide error type.
//!
//! Library code returns [`Result`]; the CLI converts into `eyre` at the
//! boundary. Variants are grouped by subsystem so failure injection tests
//! can assert on the class of failure.

use std::path::PathBuf;

/// Unified error type for the AxOCS library.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Artifact file (HLO text, weights, manifest, input set) missing.
    #[error("artifact not found: {path} (run `make artifacts` first)")]
    ArtifactMissing { path: PathBuf },

    /// Artifact exists but failed to parse/validate.
    #[error("corrupt artifact {path}: {reason}")]
    ArtifactCorrupt { path: PathBuf, reason: String },

    /// PJRT / XLA runtime failure.
    #[error("xla runtime error: {0}")]
    Xla(String),

    /// Shape or batch-size mismatch between caller and compiled executable.
    #[error("shape mismatch: {0}")]
    Shape(String),

    /// Invalid operator configuration (e.g. all-zeros, wrong length).
    #[error("invalid configuration: {0}")]
    InvalidConfig(String),

    /// Dataset consistency problem (length mismatch, empty, bad columns).
    #[error("dataset error: {0}")]
    Dataset(String),

    /// ML model error (untrained model queried, bad hyperparameters).
    #[error("ml error: {0}")]
    Ml(String),

    /// DSE setup error (bad constraints, empty population).
    #[error("dse error: {0}")]
    Dse(String),

    /// Coordinator/service failure (channel closed, worker panicked).
    #[error("coordinator error: {0}")]
    Coordinator(String),

    /// Experiment configuration file problem.
    #[error("config error: {0}")]
    Config(String),

    #[error(transparent)]
    Io(#[from] std::io::Error),

    #[error(transparent)]
    Json(#[from] crate::util::json::JsonError),

    #[error(transparent)]
    Toml(#[from] crate::util::tomlkit::TomlError),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
