//! Engine-layer benches: dataset cache (cold vs cached), the concurrent
//! multi-factor DSE driver, the characterization scaling story —
//! cold-serial vs cold-sharded vs warm-from-disk on the paper's mul8
//! `Seeded` spec (scaled down) — and the serve-mode overhead case (the
//! same jobs direct vs spooled through the file queue + JobRunner). CI's
//! bench-smoke job runs this suite with `REPRO_BENCH_SMOKE=1` and uploads
//! the stamps; the suite itself writes `BENCH_store.json` and
//! `BENCH_serve.json` so the store-path timings and the queueing
//! overhead are recorded in the perf trajectory alongside
//! BENCH_engine.json (the scalar-vs-bitslice characterization speedups
//! land in `BENCH_charac.json`, stamped by `charac_benches`).
//!
//! Run: `cargo bench --bench engine_benches`

use repro::charac::{characterize, characterize_sharded, Backend, InputSet};
use repro::engine::{DseJob, EngineContext};
use repro::expcfg::{
    CharacConfig, ConssConfig, ExperimentConfig, GaConfig, StoreConfig, SurrogateConfig,
};
use repro::operator::{AxoConfig, Operator};
use repro::serve::{JobQueue, JobRunner, JobSpec, ServeOptions};
use repro::surrogate::EstimatorBackend;
use repro::util::bench::Bench;
use repro::util::par;
use repro::util::rng::Rng;
use repro::util::tempdir::TempDir;
use std::time::Duration;

/// Small add4 → add8 pipeline: exhaustive spaces, exact-table surrogate,
/// tiny GA — isolates engine overhead from substrate cost.
fn cfg() -> ExperimentConfig {
    ExperimentConfig {
        operator: "add8".into(),
        surrogate: SurrogateConfig { backend: EstimatorBackend::Table, gbt_stages: None },
        conss: ConssConfig { forest_trees: Some(4), noise_bits: 2, ..Default::default() },
        ga: GaConfig { pop_size: 16, generations: 8, ..Default::default() },
        ..Default::default()
    }
}

fn main() {
    let mut b =
        Bench::new().with_budget(Duration::from_millis(100), Duration::from_millis(800));

    // Dataset path: cold characterization vs cache hit.
    b.bench("engine/dataset_add8_cold", || {
        EngineContext::new(cfg()).dataset(Operator::ADD8).unwrap()
    });
    let ctx = EngineContext::new(cfg());
    ctx.dataset(Operator::ADD8).unwrap();
    b.bench("engine/dataset_add8_cached", || ctx.dataset(Operator::ADD8).unwrap());

    // Multi-factor DSE: four concurrent jobs over a warm context vs the
    // full cold path (characterize + train + spawn + run).
    let jobs: Vec<DseJob> =
        [0.35, 0.5, 0.65, 0.8].iter().map(|&f| DseJob::new(f)).collect();
    let prep = ctx.prepare_dse().unwrap();
    b.bench("engine/run_many_4_factors_warm", || prep.run_many(&jobs).unwrap());
    b.bench("engine/cold_prepare_plus_4_factors", || {
        let ctx = EngineContext::new(cfg());
        let prep = ctx.prepare_dse().unwrap();
        prep.run_many(&jobs).unwrap()
    });

    // Characterization scaling on the paper's headline mul8 Seeded spec
    // (scaled to 128 configs so the smoke run stays fast): the same work
    // serial, sharded over the work-stealing pool, and warm from the
    // persistent store.
    const MUL8_SAMPLES: usize = 128;
    const SHARD: usize = 32;
    let inputs = InputSet::exhaustive(Operator::MUL8);
    let mcfgs: Vec<AxoConfig> = {
        let mut rng = Rng::seed_from_u64(2023);
        AxoConfig::sample_unique(Operator::MUL8.config_len(), MUL8_SAMPLES, &mut rng)
    };
    b.bench("charac/mul8_seeded128_cold_serial", || {
        par::serial_scope(|| {
            characterize(Operator::MUL8, &mcfgs, &inputs, &Backend::Native).unwrap()
        })
    });
    b.bench("charac/mul8_seeded128_cold_sharded", || {
        characterize_sharded(Operator::MUL8, &mcfgs, &inputs, SHARD).unwrap()
    });

    // Warm-from-disk: the store directory is pre-warmed once; every
    // iteration is a fresh EngineContext (cold in-memory cache) whose
    // only source is the on-disk store.
    let tmp = TempDir::new().expect("tempdir for store bench");
    let store_cfg = ExperimentConfig {
        operator: "mul8".into(),
        train_samples: MUL8_SAMPLES,
        artifacts_dir: tmp.path().to_path_buf(),
        charac: CharacConfig { shard_size: SHARD, ..Default::default() },
        store: StoreConfig { enabled: Some(true), ..Default::default() },
        ..cfg()
    };
    EngineContext::new(store_cfg.clone())
        .dataset(Operator::MUL8)
        .expect("store warm-up characterization");
    b.bench("charac/mul8_seeded128_warm_store", || {
        let ctx = EngineContext::new(store_cfg.clone());
        let ds = ctx.dataset(Operator::MUL8).unwrap();
        assert_eq!(ctx.cache_stats().characterized, 0, "store must serve warm runs");
        ds
    });

    b.finish();
    let stamp = std::path::Path::new("BENCH_store.json");
    b.write_json(stamp).expect("write BENCH_store.json");
    println!("wrote {}", stamp.display());

    // Serve-mode overhead: the same three single-factor jobs run direct
    // through a warm DsePrepared vs spooled through the file queue and
    // drained by a two-worker JobRunner (spec JSON round-trip, claim
    // renames, result writes, event log — everything but the search
    // itself is the measured delta).
    let mut bs =
        Bench::new().with_budget(Duration::from_millis(100), Duration::from_millis(800));
    let factors = [0.4, 0.6, 0.8];
    let jobs3: Vec<DseJob> = factors.iter().map(|&f| DseJob::new(f)).collect();
    bs.bench("serve/direct_3_jobs_warm", || prep.run_many(&jobs3).unwrap());

    let qtmp = TempDir::new().expect("tempdir for serve bench");
    let queue = JobQueue::open(qtmp.path().join("jobs")).expect("open job queue");
    let serve_ctx = EngineContext::new(cfg());
    let runner = JobRunner::new(
        &serve_ctx,
        &queue,
        ServeOptions { workers: 2, ..Default::default() },
    )
    .expect("job runner");
    let round = std::cell::Cell::new(0u64);
    bs.bench("serve/queued_3_jobs_drain", || {
        let r = round.get();
        round.set(r + 1);
        for (i, f) in factors.iter().enumerate() {
            queue.submit(&JobSpec::new(format!("r{r}-j{i}"), vec![*f])).unwrap();
        }
        let summary = runner.run().unwrap();
        assert_eq!(summary.done, 3, "queued jobs must all complete");
        summary
    });
    bs.finish();
    let stamp = std::path::Path::new("BENCH_serve.json");
    bs.write_json(stamp).expect("write BENCH_serve.json");
    println!("wrote {}", stamp.display());
}
