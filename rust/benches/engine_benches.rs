//! Engine-layer benches: dataset cache (cold vs cached) and the concurrent
//! multi-factor DSE driver. CI's bench-smoke job runs this suite with
//! `REPRO_BENCH_SMOKE=1` and stamps BENCH_engine.json so the engine's perf
//! trajectory is recorded per commit.
//!
//! Run: `cargo bench --bench engine_benches`

use repro::engine::{DseJob, EngineContext};
use repro::expcfg::{ConssConfig, ExperimentConfig, GaConfig, SurrogateConfig};
use repro::operator::Operator;
use repro::surrogate::EstimatorBackend;
use repro::util::bench::Bench;
use std::time::Duration;

/// Small add4 → add8 pipeline: exhaustive spaces, exact-table surrogate,
/// tiny GA — isolates engine overhead from substrate cost.
fn cfg() -> ExperimentConfig {
    ExperimentConfig {
        operator: "add8".into(),
        surrogate: SurrogateConfig { backend: EstimatorBackend::Table, gbt_stages: None },
        conss: ConssConfig { forest_trees: Some(4), noise_bits: 2, ..Default::default() },
        ga: GaConfig { pop_size: 16, generations: 8, ..Default::default() },
        ..Default::default()
    }
}

fn main() {
    let mut b =
        Bench::new().with_budget(Duration::from_millis(100), Duration::from_millis(800));

    // Dataset path: cold characterization vs cache hit.
    b.bench("engine/dataset_add8_cold", || {
        EngineContext::new(cfg()).dataset(Operator::ADD8).unwrap()
    });
    let ctx = EngineContext::new(cfg());
    ctx.dataset(Operator::ADD8).unwrap();
    b.bench("engine/dataset_add8_cached", || ctx.dataset(Operator::ADD8).unwrap());

    // Multi-factor DSE: four concurrent jobs over a warm context vs the
    // full cold path (characterize + train + spawn + run).
    let jobs: Vec<DseJob> =
        [0.35, 0.5, 0.65, 0.8].iter().map(|&f| DseJob::new(f)).collect();
    let prep = ctx.prepare_dse().unwrap();
    b.bench("engine/run_many_4_factors_warm", || prep.run_many(&jobs).unwrap());
    b.bench("engine/cold_prepare_plus_4_factors", || {
        let ctx = EngineContext::new(cfg());
        let prep = ctx.prepare_dse().unwrap();
        prep.run_many(&jobs).unwrap()
    });

    b.finish();
}
